"""Serving demo: continuous batching as the order-preserving farm.

Requests with different prompt lengths arrive while earlier ones are still
decoding; the admitter (Emitter) recycles batch slots through the SPMC page
pool, per-slot start offsets isolate requests, and the collector emits
results in submission order.  Under the hood ``ServeEngine.run`` is now a
skeleton expression — ``Source(requests) ∘ Farm(decode_step,
feedback=still_generating)`` — lowered to the thread graph; the decode tick
circulates the wrap-around SPSC ring until loop quiescence.

Run:  PYTHONPATH=src python examples/serve_demo.py
"""
import numpy as np

from repro.configs import ARCHS
from repro.launch.serve import Request, ServeEngine

cfg = ARCHS["phi3-mini-3.8b"].smoke()
eng = ServeEngine(cfg, max_batch=3, max_len=256, seed=0)

rng = np.random.default_rng(0)
for i in range(9):
    plen = int(rng.integers(2, 9))
    eng.submit(Request(rid=i, prompt=list(rng.integers(0, cfg.vocab_size, plen)),
                       max_new=6))

results = eng.run()
print(f"served {len(results)} requests in {eng.steps_run} engine steps "
      f"(slots recycled {eng.pool.allocated}x through {eng.max_batch} pages)")
for r in results:
    print(f"  tag={r.tag} rid={r.rid} prompt_len={len(r.prompt)} out={r.generated}")
assert [r.tag for r in results] == sorted(r.tag for r in results)
print("serve_demo OK")
