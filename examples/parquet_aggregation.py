"""Parquet-style aggregation at three scale tiers: the out-of-core layer.

The same workload as ``log_aggregation.py`` — columnar record batches,
keyed shuffle, per-key fold — but grown until the hot fold state no
longer fits in memory.  That is what ``repro.core.oocore`` is for, and
this example walks the three knobs a real aggregation job turns:

tier ``small``
    everything fits: a plain budgeted ``reduce_by_key``.  The budget is
    generous, nothing spills, and the only visible change from the
    unbudgeted path is the telemetry on ``skel.stats``.
tier ``medium``
    the key space outgrows the budget: the SAME skeleton now spills —
    each partition's :class:`~repro.core.oocore.SpillFold` writes
    sorted runs to disk and merges them at EOS.  Results are identical;
    ``skel.stats.spills`` / ``spill_bytes`` show the traffic.
tier ``large``
    the full composition, :func:`~repro.core.oocore.shard_reduce`:
    sharded combining readers stream the dataset in record batches,
    pre-fold hot keys map-side, and ship ``(key, partial)`` pairs in
    :class:`~repro.core.KeyBatch` wire messages to budgeted spill-backed
    partitions — bounded memory end to end, no input list ever
    materialised.  On the procs backend every reader and every partition
    is its own process; a shared :class:`~repro.core.MemoryBudget` board
    (shm counters) aggregates spill/stall telemetry across all of them.

Run:  PYTHONPATH=src python examples/parquet_aggregation.py
      (REPRO_PQ_ROWS=200000 scales the large tier up)

Spawn-safety note: the procs backend re-imports this module in every
vertex process, so all nodes live at module level (picklable by name)
and everything executable sits behind ``if __name__ == "__main__"``.
"""
from __future__ import annotations

import os
import time

from repro.core import lower, reduce_by_key, shard_reduce

NKEYS_SMALL = 64
NKEYS_BIG = 20_000


def row_batch(lo, hi):
    """Columnar reader: rows ``[lo, hi)`` of a synthetic orders dataset,
    deterministic from the row index alone (every shard process, every
    backend regenerates the same rows — no input file)."""
    rows = []
    for i in range(lo, hi):
        h = (i * 2654435761) & 0xFFFFFFFF
        # ~80% of rows hit a small hot set, the rest spray over the
        # full key space — the skew every real aggregation sees
        key = h % NKEYS_SMALL if h % 5 else h % NKEYS_BIG
        # integer-valued floats: sums stay exact in any combine order,
        # so every tier compares == against the sequential reference
        rows.append((key, float(i % 997)))
    return rows


row_batch.nrows = 0  # patched per tier in main() (ShardReader reads it)


def order_key(row):
    return row[0]


def order_stats(acc, row):
    """Seeded fold: (count, total_amount) per key."""
    return (acc[0] + 1, acc[1] + row[1])


def merge_stats(a, b):
    """Combine two partials of one key — what spilling and map-side
    combining need on top of the fold (a seeded fold's step takes an
    *item*, not another accumulator)."""
    return (a[0] + b[0], a[1] + b[1])


def reference(nrows):
    want = {}
    for k, v in row_batch(0, nrows):
        c, t = want.get(k, (0, 0.0))
        want[k] = (c + 1, t + v)
    return want


def tier_small():
    """Budgeted reduce_by_key, budget big enough that nothing spills."""
    nrows = 5_000
    skel = reduce_by_key(order_key, order_stats, init=(0, 0.0),
                         combine=merge_stats, nright=2, budget=1 << 20)
    out = dict(lower(skel, "threads")(row_batch(0, nrows)))
    assert out == reference(nrows)
    return nrows, len(out), skel.stats


def tier_medium():
    """Same skeleton shape, tiny budget: the partitions spill to disk
    and merge at EOS — identical results, bounded hot state."""
    nrows = 20_000
    skel = reduce_by_key(order_key, order_stats, init=(0, 0.0),
                         combine=merge_stats, nright=2, budget=64 << 10)
    out = dict(lower(skel, "threads")(row_batch(0, nrows)))
    assert out == reference(nrows)
    assert skel.stats.spills > 0, "the medium tier is meant to spill"
    return nrows, len(out), skel.stats


def tier_large(backend):
    """shard_reduce: sharded readers + map-side combine + spill-backed
    partitions.  The skeleton carries its own sources, so it runs via
    ``to_graph(None)`` — there is no input iterable to feed."""
    nrows = int(os.environ.get("REPRO_PQ_ROWS", "60000"))
    row_batch.nrows = nrows
    skel = shard_reduce(row_batch, order_key, order_stats, init=(0, 0.0),
                        combine=merge_stats, nleft=2, nright=2,
                        budget=128 << 10, batch_rows=4096)
    g = lower(skel, backend).to_graph(None)
    g.run()
    out = dict(g.wait(300.0))
    assert out == reference(nrows)
    return nrows, len(out), skel.stats


def show(tier, nrows, nkeys, dt, stats):
    print(f"[{tier:16s}] {nrows:>7} rows -> {nkeys:>5} keys "
          f"in {dt * 1e3:7.1f} ms | spills={stats.spills} "
          f"spill_bytes={stats.spill_bytes} "
          f"stalls={stats.backpressure_stalls}")


def main():
    t0 = time.perf_counter()
    nrows, nkeys, stats = tier_small()
    show("small/in-memory", nrows, nkeys, time.perf_counter() - t0, stats)
    assert stats.spills == 0

    t0 = time.perf_counter()
    nrows, nkeys, stats = tier_medium()
    show("medium/spilling", nrows, nkeys, time.perf_counter() - t0, stats)

    for backend in ("threads", "procs"):
        t0 = time.perf_counter()
        nrows, nkeys, stats = tier_large(backend)
        show(f"large/{backend}", nrows, nkeys,
             time.perf_counter() - t0, stats)

    print("\nparquet_aggregation OK: all tiers agree with the reference; "
          "the large tier never held more than budget x nright bytes of "
          "hot fold state per run")


if __name__ == "__main__":
    main()
