"""End-to-end training driver demo: train a ~small mamba2 for a few hundred
steps with the full production loop — streaming emitter pipeline, jitted
train step, async checkpointing, and a mid-run injected failure that the
runner recovers from by restoring the last checkpoint (exactly-once steps).

Run:  PYTHONPATH=src python examples/streaming_train.py
"""
import tempfile

from repro.configs import ARCHS
from repro.launch.train import train
from repro.runtime.checkpoint import latest_step

cfg = ARCHS["mamba2-130m"].smoke()

with tempfile.TemporaryDirectory() as d:
    print("=== phase 1: train with failure injected at step 60 ===")
    try:
        train(cfg, steps=200, batch=4, seq=64, ckpt_dir=d, ckpt_every=25,
              seed=0, inject_failure_at=60)
    except RuntimeError as e:
        print(f"[example] failure hit as planned: {e}")
    print(f"[example] last published checkpoint: step {latest_step(d)}")

    print("=== phase 2: restart resumes from the checkpoint ===")
    _, losses = train(cfg, steps=200, batch=4, seq=64, ckpt_dir=d,
                      ckpt_every=50, seed=0)
    print(f"[example] finished: loss {losses[0]:.4f} → {losses[-1]:.4f} "
          f"over {len(losses)} post-restore steps")
print("streaming_train OK")
