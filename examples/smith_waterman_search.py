"""The paper's application end-to-end (Sec. 4.2): protein database search.

The search is wired through the composable graph API: a two-stage
``Pipeline(Farm(align), Stage(threshold))`` streams database subjects
through the TPU-adapted Smith-Waterman Pallas kernel (BLOSUM50, affine
gaps 10-2k) in an order-preserving farm whose output edge feeds a
post-processing stage — reporting per-query GCUPS and the Table-1-style
service-time spread.  Second half: the same wavefront DP expressed as a
*macro data-flow* graph over tiles (paper Sec. 5), which now runs on the
graph runtime's wrap-around (collector → emitter) edge.

Run:  PYTHONPATH=src python examples/smith_waterman_search.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import Farm, MDFExecutor, MDFTask, Pipeline, Stage
from repro.kernels import ops
from repro.kernels.ref import sw_ref
from repro.kernels.ops import build_profile

rng = np.random.default_rng(7)

# --- database search through the farm ---------------------------------------
queries = {"Q144": 144, "Q497": 497}
db = [rng.integers(0, 20, int(np.clip(rng.gamma(2.0, 176), 2, 1200))).astype(np.int32)
      for _ in range(24)]
db_cells = sum(len(s) for s in db)

for name, qlen in queries.items():
    query = jnp.asarray(rng.integers(0, 20, qlen), jnp.int32)
    times = []

    def align(subj):
        t0 = time.perf_counter()
        s = float(ops.smith_waterman(query, jnp.asarray(subj),
                                     gap_open=10.0, gap_extend=2.0))
        times.append(time.perf_counter() - t0)
        return s

    # graph-composed search: order-preserving farm → post-processing stage
    net = Pipeline(Farm(align, 2, ordered=True),
                   Stage(lambda s: round(s, 1)))
    t0 = time.perf_counter()
    scores = net.run_and_wait(db)
    wall = time.perf_counter() - t0
    gcups = qlen * db_cells / wall / 1e9
    print(f"{name}: best={max(scores):.0f}  GCUPS={gcups:.6f}  "
          f"task min/avg/max = {min(times)*1e3:.1f}/{np.mean(times)*1e3:.1f}/"
          f"{max(times)*1e3:.1f} ms")

# --- wavefront dynamic programming as macro data-flow (paper Sec. 5) --------
# Block-decompose a DP-like accumulation; dependencies (i-1,j), (i,j-1).
N = 4
def tile_fn(*deps, i=0, j=0):
    return sum(deps) + i + j

tasks = [MDFTask(tag=(i, j),
                 fn=lambda *d, i=i, j=j: tile_fn(*d, i=i, j=j),
                 deps=tuple(t for t in [(i-1, j), (i, j-1)] if min(t) >= 0))
         for i in range(N) for j in range(N)]
out = MDFExecutor(nworkers=3).run(tasks)
print(f"MDF wavefront over {N}x{N} tiles: corner value = {out[(N-1, N-1)]}")
print("smith_waterman_search OK")
