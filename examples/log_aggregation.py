"""Log aggregation: the parquet-aggregator shape on the skeleton IR.

The workload of the poc-parquet-aggregator repo (see /root/related in the
source notes): columnar record batches stream in, each batch explodes
into records, records shuffle by a key column, and every partition folds
its keys — counts and latency totals per (tenant, status).  On this
runtime that is three IR nodes:

    Source(batches)
      >> Stage(explode)                      # columnar batch -> records
      >> reduce_by_key(key, fold)            # keyed shuffle -> per-key fold

``reduce_by_key`` is an AllToAll under the hood: ``NLEFT`` explode-side
routes feed ``NRIGHT`` partition folders over an N×M matrix of SPSC
rings, each key owned by exactly one partition (stable hash — identical
routing whether the vertices are threads or spawned processes).  The SAME
skeleton object runs on both host backends below; swap the custom fold
for a named one (``"sum"``/``"count"`` + ``nkeys=``) and it compiles on
the mesh too (see quickstart §1d).

Run:  PYTHONPATH=src python examples/log_aggregation.py

Spawn-safety note: the procs backend re-imports this module in every
vertex process, so all nodes live at module level (picklable by name) and
everything executable sits behind ``if __name__ == "__main__"``.
"""
from __future__ import annotations

import random
import time

from repro.core import EmitMany, Pipeline, Stage, lower, reduce_by_key

NBATCHES = 40
ROWS_PER_BATCH = 250
NLEFT = 2        # explode/route lanes (left row of the matrix)
NRIGHT = 3       # aggregation partitions (right row)
TENANTS = ("acme", "globex", "initech", "umbrella", "stark")
STATUSES = (200, 200, 200, 404, 500)   # skewed, like real access logs


def make_batches(nbatches=None, rows=None):
    """Columnar record batches — parallel columns, parquet-row-group
    style — deterministic so both backends see identical input.  Sizes
    resolve at call time so smoke runs can shrink the module knobs."""
    nbatches = NBATCHES if nbatches is None else nbatches
    rows = ROWS_PER_BATCH if rows is None else rows
    rng = random.Random(0)
    for _ in range(nbatches):
        yield {
            "tenant": [rng.choice(TENANTS) for _ in range(rows)],
            "status": [rng.choice(STATUSES) for _ in range(rows)],
            "latency_ms": [round(rng.expovariate(1 / 30.0), 3)
                           for _ in range(rows)],
        }


def explode(batch):
    """Columnar batch -> record tuples (the row-wise view the shuffle
    keys on).  EmitMany streams each record as its own hand-off."""
    return EmitMany(zip(batch["tenant"], batch["status"],
                        batch["latency_ms"]))


def record_key(rec):
    return (rec[0], rec[1])               # (tenant, status)


def merge_stats(acc, rec):
    """Binary fold: records accumulate into (count, latency_sum) stats
    (the explicit ``init=(0, 0.0)`` seeds every key)."""
    return (acc[0] + 1, acc[1] + rec[2])


def aggregate(backend: str):
    skel = Pipeline(
        Stage(explode),
        reduce_by_key(record_key, merge_stats, init=(0, 0.0),
                      nleft=NLEFT, nright=NRIGHT),
    )
    t0 = time.perf_counter()
    out = lower(skel, backend)(make_batches())
    dt = time.perf_counter() - t0
    return dict(out), dt


def main():
    nrec = NBATCHES * ROWS_PER_BATCH
    results = {}
    for backend in ("threads", "procs"):
        table, dt = aggregate(backend)
        results[backend] = table
        print(f"[{backend:7s}] {nrec} records -> {len(table)} keys "
              f"in {dt * 1e3:.1f} ms ({dt / nrec * 1e6:.2f} us/record)")
    # counts match exactly; latency sums only to float tolerance — the
    # fold order inside a partition is arrival order, which legitimately
    # differs between runs (unordered shuffle), and float + is not
    # associative
    assert set(results["threads"]) == set(results["procs"])
    for k, (count, lat) in results["threads"].items():
        pcount, plat = results["procs"][k]
        assert count == pcount, (k, count, pcount)
        assert abs(lat - plat) <= 1e-6 * max(1.0, abs(lat)), (k, lat, plat)

    print(f"\n{'tenant':<10} {'status':>6} {'count':>7} {'avg_ms':>8}")
    table = results["threads"]
    for (tenant, status) in sorted(table):
        count, lat_sum = table[(tenant, status)]
        print(f"{tenant:<10} {status:>6} {count:>7} {lat_sum / count:>8.2f}")
    total = sum(c for c, _ in table.values())
    assert total == nrec, (total, nrec)
    print(f"\nlog_aggregation OK: {total} records, "
          f"{len(table)} (tenant, status) keys, threads == procs "
          f"(counts exact, latency sums to float tolerance)")


if __name__ == "__main__":
    main()
