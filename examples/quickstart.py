"""Quickstart: the three layers of FastFlow-JAX in ~60 lines.

  1. host streaming: lock-free SPSC farm (the paper's skeleton);
  2. the paper's application: Smith-Waterman database search through it;
  3. the LM framework: one reduced-config train step + one decode step.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.core import FnNode, TaskFarm
from repro.kernels import ops
from repro.launch.steps import make_train_step
from repro.models import init_cache, init_params, decode_step
from repro.optim import adamw_init

# -- 1. farm: square a stream of numbers, order-preserving -------------------
farm = TaskFarm(nworkers=4, preserve_order=True)
farm.add_stream(range(10))
farm.add_worker(FnNode(lambda x: x * x))
print("farm:", farm.run_and_wait())

# -- 2. the paper's app: SW database search ----------------------------------
rng = np.random.default_rng(0)
query = jnp.asarray(rng.integers(0, 20, 32), jnp.int32)
db = [jnp.asarray(rng.integers(0, 20, int(n)), jnp.int32)
      for n in rng.integers(20, 80, 8)]
sw_farm = TaskFarm(2, preserve_order=True)
sw_farm.add_stream(db)
sw_farm.add_worker(FnNode(lambda s: float(ops.smith_waterman(query, s, tile=64))))
print("SW scores:", sw_farm.run_and_wait())

# -- 3. LM framework: one train step + one decode step (reduced config) ------
cfg = ARCHS["mixtral-8x7b"].smoke()
key = jax.random.PRNGKey(0)
params = init_params(cfg, key)
opt = adamw_init(params)
step = jax.jit(make_train_step(cfg))
batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size),
         "labels": jax.random.randint(key, (2, 32), 0, cfg.vocab_size)}
params, opt, metrics = step(params, opt, batch)
print(f"train step: loss={float(metrics['loss']):.3f}")

cache = init_cache(cfg, batch=2, max_len=16)
logits, cache = jax.jit(lambda p, b, c, l: decode_step(p, b, c, l, cfg))(
    params, {"tokens": jnp.zeros((2, 1), jnp.int32)}, cache, jnp.int32(0))
print("decode logits:", logits.shape)
print("quickstart OK")
