"""Quickstart: the three layers of FastFlow-JAX in ~100 lines.

  1. the skeleton IR: ONE declarative expression, executed on THREE
     backends — the host thread/SPSC graph, the GIL-escaping process
     graph over shared-memory rings, and a single shard_map mesh program
     (no host hop between stages); plus the threads backend's pluggable
     scheduling policies (Farm(scheduling=...)), the grain-aware
     fusion pass (lower(..., fuse=...)), the all-to-all keyed
     shuffle (reduce_by_key — §1d), and its out-of-core form
     (budget= spill-to-disk folds — §1f), and the self-tuning loop
     (profile a pilot slice, retune the IR from the measurements,
     replay on any backend — §1g);
  2. the paper's application: Smith-Waterman database search through an
     ordered farm;
  3. the LM framework: one reduced-config train step + one decode step.

Run:  PYTHONPATH=src python examples/quickstart.py

Structure note: the procs backend spawns vertex processes, and spawn
re-imports this script in every child — so the worker functions live at
module level (picklable by name), the heavy imports live inside main(),
and everything executable is behind ``if __name__ == "__main__"``.
"""


# -- picklable nodes for the procs backend (children import these by name) ----
def _sq(x):
    return x * x


def _dbl(x):
    # array node: x is a numpy array, so `* 2.0` needs no import here —
    # the child that services it gets numpy when it unpickles the payload
    return x * 2.0


def _inc(x):
    return x + 1


def _mod4(x):
    # a shuffle key: array-polymorphic (x % 4 works on a jnp column too),
    # so the SAME key function routes on all three backends
    return x % 4


def main():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import ARCHS
    from repro.core import CostModel, Farm, Pipeline, Stage, lower
    from repro.kernels import ops
    from repro.launch.steps import make_train_step
    from repro.models import init_cache, init_params, decode_step
    from repro.optim import adamw_init

    # -- 1. one skeleton, two backends ---------------------------------------
    # Pipeline(Farm(f), Farm(g)) is pure data; lower() picks the runtime.
    skel = Pipeline(Farm(_sq, 4, ordered=True), Farm(_inc, 4, ordered=True))
    on_threads = lower(skel, "threads")(range(10))  # threads + SPSC rings
    on_mesh = lower(skel, "mesh")(range(10))        # ONE shard_map: fused
    print("threads:", on_threads)
    print("mesh:   ", on_mesh)
    assert on_threads == on_mesh

    # -- 1b. scheduling policies + grain-aware fusion (threads backend) ------
    # Farm(scheduling=) takes a registry name — "rr" | "ondemand" |
    # "worksteal" | "costmodel" — or a repro.core.sched.Scheduler instance;
    # placement never changes ordered-farm output, only who services what.
    stolen = lower(Farm(_sq, 4, ordered=True,
                        scheduling="worksteal"), "threads")(range(10))
    priced = lower(Farm(_sq, 4, ordered=True,
                        scheduling=CostModel()), "threads")(range(10))
    assert stolen == priced == [_sq(x) for x in range(10)]
    print("worksteal == costmodel:", stolen)

    # Stages declaring a fine grain= (µs of work per item, threads reading)
    # fuse into ONE vertex when the grain is below the calibrated hand-off
    # cost — fewer threads, fewer ring hops, identical output.
    fine = Pipeline(Stage(_inc, grain=1), Stage(_sq, grain=1))
    fused = lower(fine, "threads", fuse="auto", fuse_threshold_us=1e9)
    unfused = lower(fine, "threads", fuse=False)
    assert fused(range(8)) == unfused(range(8))
    print("fusion: vertices", len(unfused.to_graph(list(range(8))).vertices),
          "->", len(fused.to_graph(list(range(8))).vertices))

    # -- 1c. the SAME skeleton on the procs backend (GIL escape) -------------
    # lower(skel, "procs") spawns one process per vertex and replaces every
    # edge with a shared-memory SPSC ring (cache-line-separated head/tail —
    # the paper's FastForward layout, finally observable without the GIL).
    # Identical ordered output, but a farm of pure-Python svc functions now
    # actually scales with cores; nodes must be picklable (module-level
    # functions like _sq/_inc, not lambdas).
    on_procs = lower(skel, "procs")(range(10))
    print("procs:  ", on_procs)
    assert on_procs == on_threads == on_mesh

    # -- 1d. keyed shuffle: ONE reduce_by_key, THREE backends ----------------
    # reduce_by_key(by, fold) rewrites to the AllToAll building block — an
    # N×M matrix of SPSC edges on the host backends (each left vertex owns
    # one ring per right vertex: single-writer, no arbiter between the
    # layers), and ONE shard_map keyed-exchange + segment-reduction program
    # on the mesh (named fold + static nkeys make it traceable).  Output is
    # unordered (key, fold) pairs — compare as dicts.
    from repro.core import reduce_by_key
    rbk = reduce_by_key(_mod4, "sum", nleft=2, nright=2, nkeys=4)
    by_threads = dict(lower(rbk, "threads")(range(32)))
    by_procs = dict(lower(rbk, "procs")(range(32)))
    by_mesh = dict(lower(rbk, "mesh")(range(32)))
    assert by_threads == by_procs == by_mesh
    print("reduce_by_key (threads == procs == mesh):", by_threads)

    # -- 1e. zero-copy + batched lowering options (procs backend) ------------
    # For array streams, size the ring slots to the payload (slot_size=)
    # and numpy arrays travel as typed zero-copy slots: one aligned memcpy
    # into shared memory per side instead of a pickle round-trip (pickle
    # stays the fallback for arbitrary objects).  batch= packs several
    # items per slot hand-off (an int, or "grain" to read each stage's
    # declared grain), and spawned vertices come from a reusable process
    # pool — a second lower(...) run pays no spawn cost (pool_stats()
    # shows the reuse; opt out per-program with pool=False).
    from repro.core import pool_stats
    arrs = [np.full((1024,), float(i), np.float32) for i in range(12)]
    zc = lower(Farm(_dbl, 2, ordered=True), "procs",
               slot_size=8192, zero_copy=True, batch=4)(arrs)
    assert all(np.array_equal(o, a * 2.0) for o, a in zip(zc, arrs))
    print("zero-copy procs pool:", pool_stats())

    # -- 1f. bounded-memory aggregation (the out-of-core layer) --------------
    # budget= bounds each partition's hot fold state in BYTES: when a
    # partition's dict outgrows it, the coldest keys spill to a sorted
    # on-disk run and the EOS flush merges runs back — identical results,
    # bounded memory, telemetry on skel.stats.  The scatter also gains
    # byte-driven backpressure (stalls intake while the aggregate hot
    # state sits over the budget's high-water mark).  For datasets too
    # big to materialise at all, shard_reduce() composes sharded
    # combining readers with spill-backed partitions — see
    # examples/parquet_aggregation.py for the full walkthrough.
    budgeted = reduce_by_key(_mod4, "sum", nleft=2, nright=2, budget=100)
    by_budget = dict(lower(budgeted, "threads")(range(32)))
    assert by_budget == by_threads
    print(f"budgeted reduce_by_key: same result, spills="
          f"{budgeted.stats.spills} spill_bytes={budgeted.stats.spill_bytes}")
    assert budgeted.stats.spills > 0  # the 100-byte budget forced runs

    # -- 1g. self-tuning: profile -> retune -> replay ------------------------
    # Declared knobs lie (here: grain=10000 on sub-µs stages, so the
    # static lowering never fuses).  profile() runs a pilot slice through
    # an instrumented threads lowering and records per-stage service
    # times, queue high-water marks, and the calibrated hand-off cost;
    # retune() is a pure IR rewrite from those measurements — measured
    # grains, fusion at the measured threshold, rate-ratio ring sizes,
    # micro-batched survivors — and never changes results.  The same
    # profile (it is JSON: prof.save/Profile.load) retunes the procs
    # lowering too; service times are a property of the node functions.
    from repro.core import profile, retune
    misgrained = Pipeline(Stage(_inc, grain=10000), Stage(_sq, grain=10000))
    prof = profile(misgrained, range(200))          # the pilot slice
    tuned = retune(misgrained, prof)                # the rewrite
    want = [_sq(_inc(x)) for x in range(50)]
    assert lower(tuned, "threads", fuse=False)(range(50)) == want
    assert lower(tuned, "procs", fuse=False)(range(50)) == want
    print(f"retune: handoff={prof.handoff_us:.2f}us, "
          f"{len(misgrained.stages)} stages -> "
          f"{len(tuned.stages) if hasattr(tuned, 'stages') else 1}")
    # or let the runtime do both phases: lower(..., tune=True) profiles a
    # pilot off the front of the first stream, then replays the rest
    # (and every later call) through the tuned program.
    tp = lower(misgrained, "threads", tune=True, tune_pilot=64)
    assert tp(range(200)) == [_sq(_inc(x)) for x in range(200)]

    # -- 1h. observability: trace a run, read the RunReport ------------------
    # trace= hands every vertex a sampled, bounded event buffer (svc
    # spans, push-wait stalls, steals, spills, EOS markers) and metrics=
    # folds the farm boards, queue high-water marks and pool stats into
    # one RunReport; both knobs work on all three backends, and with
    # them OFF a vertex carries ``tracer = None`` and never enters
    # repro.core.obs at all.  The export is Chrome trace-event JSON —
    # drop the file on https://ui.perfetto.dev (or chrome://tracing) to
    # see one swim-lane per vertex.
    import os
    import tempfile
    traced = lower(skel, "threads", trace=True, metrics=True)
    assert traced(range(10)) == on_threads
    trace_path = os.path.join(tempfile.gettempdir(), "ff_quickstart.json")
    doc = traced.last_trace.to_chrome_json(trace_path)
    print(f"trace: {len(traced.last_trace.lanes)} lanes, "
          f"{len(doc['traceEvents'])} events -> {trace_path}")
    rep = traced.last_report                 # JSON-able: rep.save(path)
    farm = rep.farms["ff-farm@0"]            # telemetry keys by IR path
    print(f"report: farm@0 collected={farm['tasks_collected']}, "
          f"queue high-water={max(rep.queues.values())}, "
          f"wall={rep.meta['wall_s'] * 1e3:.1f}ms")
    # the report round-trips into §1g's tuning loop: to_profile() turns
    # live telemetry back into a Profile for retune()/Profile.diff.
    assert any(s.kind == "farm" for s in rep.to_profile().stages)

    # -- 1i. live monitoring: watch a run, name the bottleneck ---------------
    # monitor= attaches a background sampler (a Monitor) to the running
    # graph: every ~2ms it snapshots live queue depths, farm service
    # EWMAs and progress counters into a bounded Timeline — no ring
    # traffic, just racy-benign reads of single-writer state.  Feed the
    # timeline to analyze() and it scores each stage by queueing
    # pressure minus outbound drain, names the dominant bottleneck and
    # recommends which autotune knob (§1g) to turn.  Here the farm is
    # deliberately starved of workers, so its inbound ring backs up.
    import time as _time
    from repro.core import Monitor, analyze
    mon = Monitor(interval_s=0.001)
    skewed = Pipeline(_inc, Farm(lambda x: (_time.sleep(0.001), x)[1],
                                 nworkers=2))
    lower(skewed, "threads", monitor=mon)(range(256))
    report = analyze(mon.timeline)
    print(f"monitor: {len(mon.timeline.frames())} frames -> "
          f"bottleneck={report.stage} [{report.verdict}]")
    assert report.stage == "ff-farm@1", report.stage
    knobs = [r["knob"] for r in report.recommendations]
    print(f"monitor: recommended knobs={knobs}")   # e.g. nworkers first
    # mon.timeline.save(path) persists it; `python -m repro.core.monitor
    # <path>` renders the same analysis top-style in a terminal, and
    # to_chrome_json(path, timeline=mon.timeline) overlays the depth
    # curves as Perfetto counter tracks on §1h's swim-lanes.

    # -- 2. the paper's app: SW database search (host-only payloads) ---------
    rng = np.random.default_rng(0)
    query = jnp.asarray(rng.integers(0, 20, 32), jnp.int32)
    db = [jnp.asarray(rng.integers(0, 20, int(n)), jnp.int32)
          for n in rng.integers(20, 80, 8)]
    sw = Farm(lambda s: float(ops.smith_waterman(query, s, tile=64)), 2,
              ordered=True)
    print("SW scores:", lower(sw, "threads")(db))

    # -- 3. LM framework: one train step + one decode step (reduced config) --
    cfg = ARCHS["mixtral-8x7b"].smoke()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg))
    batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (2, 32), 0, cfg.vocab_size)}
    params, opt, metrics = step(params, opt, batch)
    print(f"train step: loss={float(metrics['loss']):.3f}")

    cache = init_cache(cfg, batch=2, max_len=16)
    logits, cache = jax.jit(lambda p, b, c, l: decode_step(p, b, c, l, cfg))(
        params, {"tokens": jnp.zeros((2, 1), jnp.int32)}, cache, jnp.int32(0))
    print("decode logits:", logits.shape)
    print("quickstart OK")


if __name__ == "__main__":
    main()
