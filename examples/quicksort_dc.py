"""Divide-and-conquer quicksort on the wrap-around farm (paper Sec. 5).

The paper's closing claim is that FastFlow's arbitrated SPSC composition
supports cyclic streaming networks, and names divide-and-conquer as the
canonical client.  This example runs quicksort exactly that way:

  * each task is an (offset, values) segment;
  * a worker either sorts a small segment directly (leaf) or partitions it
    around a pivot (split);
  * the collector routes splits BACK to the emitter over the wrap-around
    SPSC edge (``Farm(feedback=...)``) and lets sorted leaves exit the loop;
  * termination is the graph layer's loop-quiescence protocol — no task
    counting in user code.

A second phase offloads the same farm from the main thread via the
self-offloading ``Accelerator`` pattern (TR-10-03): the caller streams
segments in while continuing its own work.

Run:  PYTHONPATH=src python examples/quicksort_dc.py
"""
import time

import numpy as np

from repro.core import Accelerator, Farm

LEAF = 512


def worker(task):
    off, vals = task
    if len(vals) <= LEAF:
        return ("leaf", off, np.sort(vals))
    pivot = np.median(vals[:: max(1, len(vals) // 5)][:5])
    lo, mid, hi = vals[vals < pivot], vals[vals == pivot], vals[vals > pivot]
    return ("split", (off, lo), (off + len(lo), mid), (off + len(lo) + len(mid), hi))


def route(res):
    if res[0] == "leaf":
        return (res[1], res[2]), []      # exits the loop
    _, lo, mid, hi = res
    # the equal-to-pivot run is already sorted: emit it, loop the rest
    return (mid[0], np.sort(mid[1])), [lo, hi]


def dc_sort(vals: np.ndarray, nworkers: int = 4) -> np.ndarray:
    parts = Farm(worker, nworkers, feedback=route).run_and_wait([(0, vals)])
    out = np.empty_like(vals)
    for off, chunk in parts:
        out[off:off + len(chunk)] = chunk
    return out


def main():
    rng = np.random.default_rng(0)
    vals = rng.integers(0, 1_000_000, 200_000).astype(np.int64)

    t0 = time.perf_counter()
    got = dc_sort(vals)
    dt = time.perf_counter() - t0
    assert np.array_equal(got, np.sort(vals))
    print(f"wrap-around quicksort: {len(vals)} keys in {dt*1e3:.1f} ms "
          f"(feedback farm, 4 workers)")

    # self-offloading accelerator: main thread streams independent arrays in
    arrays = [rng.integers(0, 10_000, 20_000).astype(np.int64) for _ in range(8)]
    acc = Accelerator(Farm(lambda a: np.sort(a), 4, ordered=True))
    t0 = time.perf_counter()
    for a in arrays:
        acc.offload(a)          # returns immediately; farm works alongside
    sorted_arrays = acc.wait()
    dt = time.perf_counter() - t0
    assert all(np.array_equal(s, np.sort(a)) for s, a in zip(sorted_arrays, arrays))
    print(f"accelerator offload: {len(arrays)} arrays sorted in {dt*1e3:.1f} ms "
          f"(results in submission order)")


if __name__ == "__main__":
    main()
