import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("REPRO_DRYRUN_WIRE", "f16")
import json, sys
sys.path.insert(0, "src")
from repro.launch.dryrun import run_cell

RUNS = [
    ("deepseek-coder-33b", "decode_32k", {"REPRO_SERVE_FSDP": "1"}, [2, 4]),  # H2 baseline
    ("deepseek-coder-33b", "decode_32k", {}, [2, 4]),                         # H2 optimized
    ("mamba2-130m", "train_4k", {"REPRO_SSM_BF16": "1"}, [2, 4]),             # H3 iter1
    ("mamba2-130m", "train_4k", {"REPRO_SSM_BF16": "1", "REPRO_SSM_CHUNK": "128"}, [2, 4]),
    ("kimi-k2-1t-a32b", "train_4k", {"REPRO_MOE_BACKEND": "a2a"}, [2, 4]),    # H1 variant
]
out = open("reports/perf.jsonl", "a")
for arch, shape, env, ds in RUNS:
    for k, v in env.items():
        os.environ[k] = v
    for L in ds:
        print(f"=== perf {arch} × {shape} × L={L} env={env} ===", flush=True)
        rec = run_cell(arch, shape, False, unroll=True, n_layers=L)
        # record the env-level knobs too (serve_fsdp isn't a cfg field)
        rec["env"] = dict(env)
        print("   ->", rec["status"], rec.get("compile_s"), rec.get("error","")[:200], flush=True)
        rec.pop("trace", None)
        out.write(json.dumps(rec) + "\n"); out.flush()
    for k in env:
        del os.environ[k]
print("perf_now done", flush=True)
