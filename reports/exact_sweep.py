"""Two-point exact-cost sweep: unrolled compiles at two reduced depths per
cell; roofline.py extrapolates cost = a + b*L to the full depth."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("REPRO_DRYRUN_WIRE", "f16")
import json, sys
sys.path.insert(0, "src")
from repro.configs import ARCHS, SHAPES, cell_applicable
from repro.launch.dryrun import run_cell

def depths(cfg):
    if cfg.family == "hybrid":
        return [cfg.attn_every, 2 * cfg.attn_every]
    if cfg.family == "vlm":
        return [cfg.cross_attn_every, 2 * cfg.cross_attn_every]
    return [2, 4]

out = open("reports/exact.jsonl", "a")
only = sys.argv[1:] or sorted(ARCHS)
for arch in only:
    cfg = ARCHS[arch]
    for shape in SHAPES:
        if not cell_applicable(arch, shape.name)[0] if False else not cell_applicable(arch, shape)[1] == "" and False:
            pass
        ok, _ = cell_applicable(arch, shape)
        if not ok:
            continue
        for L in depths(cfg):
            print(f"=== exact {arch} × {shape.name} × L={L} ===", flush=True)
            rec = run_cell(arch, shape.name, False, unroll=True, n_layers=L)
            print("   ->", rec["status"], rec.get("compile_s"), flush=True)
            rec.pop("trace", None)
            out.write(json.dumps(rec) + "\n")
            out.flush()
print("exact sweep done")
