import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("REPRO_DRYRUN_WIRE", "f16")
import json, sys
sys.path.insert(0, "src")
from repro.launch.dryrun import run_cell
out = open("reports/perf.jsonl", "a")
for L in [2, 4]:
    print(f"=== perf2 dsc decode grouped-gqa L={L} ===", flush=True)
    rec = run_cell("deepseek-coder-33b", "decode_32k", False, unroll=True, n_layers=L)
    rec["env"] = {"GROUPED_GQA": "1"}
    print("   ->", rec["status"], rec.get("compile_s"), flush=True)
    out.write(json.dumps(rec) + "\n"); out.flush()
print("done")
