"""Fill EXPERIMENTS.md §Perf tables: baseline (exact.jsonl latest records)
vs variants (perf.jsonl), two-point extrapolated to full depth."""
import json
import sys

sys.path.insert(0, "src")
from repro.configs import ARCHS  # noqa: E402

PEAK, HBM, LINK = 197e12, 819e9, 50e9


def load(path, want_variant=None, want_env=None):
    pts = {}
    try:
        with open(path) as f:
            for line in f:
                r = json.loads(line)
                if r.get("status") != "OK" or not r.get("unrolled"):
                    continue
                v = r.get("variant") or {}
                if want_variant is not None and v != want_variant:
                    continue
                if want_env is not None and (r.get("env") or {}) != want_env:
                    continue
                pts.setdefault((r["arch"], r["shape"]), {})[r["n_layers"]] = r
    except FileNotFoundError:
        pass
    return pts


def extrap(pts, arch, shape, f):
    d = pts.get((arch, shape))
    if not d or len(d) < 2:
        return None
    (l1, r1), (l2, r2) = sorted(d.items())[:2]
    L = ARCHS[arch].n_layers
    return f(r1) + (f(r2) - f(r1)) / (l2 - l1) * (L - l1)


def terms(pts, arch, shape):
    fl = extrap(pts, arch, shape, lambda r: r["flops_per_device"])
    if fl is None:
        return None
    by = extrap(pts, arch, shape, lambda r: r["bytes_accessed_per_device"])
    coll = {op: extrap(pts, arch, shape, lambda r: r["collectives"][op]["bytes"])
            for op in ["all-gather", "all-reduce", "reduce-scatter",
                       "all-to-all", "collective-permute"]}
    cb = sum(v for v in coll.values() if v)
    return {"compute_s": fl / PEAK, "memory_s": by / HBM,
            "collective_s": cb / LINK, "coll_bytes_gb": cb / 1e9,
            "coll": {k: (v or 0) / 1e9 for k, v in coll.items()},
            "flops": fl, "bytes": by}


def show(tag, t):
    if t is None:
        print(f"{tag}: (pending)")
        return
    dom = max(("compute", t["compute_s"]), ("memory", t["memory_s"]),
              ("collective", t["collective_s"]), key=lambda kv: kv[1])
    print(f"{tag}: compute={t['compute_s']:.3f}s memory={t['memory_s']:.3f}s "
          f"collective={t['collective_s']:.3f}s (dom={dom[0]}) "
          f"coll_bytes={t['coll_bytes_gb']:.1f}GB "
          f"[AG={t['coll']['all-gather']:.1f} AR={t['coll']['all-reduce']:.1f} "
          f"RS={t['coll']['reduce-scatter']:.1f} A2A={t['coll']['all-to-all']:.1f} "
          f"CP={t['coll']['collective-permute']:.1f}]")


if __name__ == "__main__":
    base = load("reports/exact.jsonl", want_variant={})
    base_any = load("reports/exact.jsonl")   # includes pre-variant records
    perf = load("reports/perf.jsonl")

    print("== H1: kimi-k2 train_4k ==")
    show("baseline local_gather", terms(base, "kimi-k2-1t-a32b", "train_4k")
         or terms(base_any, "kimi-k2-1t-a32b", "train_4k"))
    show("variant a2a          ",
         terms(load("reports/perf.jsonl", {"moe_backend": "a2a"}),
               "kimi-k2-1t-a32b", "train_4k"))

    print("\n== H2: deepseek-coder-33b decode_32k ==")
    show("baseline fsdp-params ",
         terms(load("reports/perf.jsonl", {}, {"REPRO_SERVE_FSDP": "1"}),
               "deepseek-coder-33b", "decode_32k"))
    show("serve-replicated     ",
         terms(load("reports/perf.jsonl", {}, {}), "deepseek-coder-33b", "decode_32k"))
    show("+ grouped-GQA attn   ",
         terms(load("reports/perf.jsonl", {}, {"GROUPED_GQA": "1"}),
               "deepseek-coder-33b", "decode_32k"))

    print("\n== H3: mamba2-130m train_4k ==")
    show("baseline fp32 SSD    ", terms(base, "mamba2-130m", "train_4k"))
    show("bf16 SSD matmuls     ",
         terms(load("reports/perf.jsonl", {"ssm_compute_dtype": "bfloat16"}),
               "mamba2-130m", "train_4k"))
    show("bf16 + chunk 128     ",
         terms(load("reports/perf.jsonl",
                    {"ssm_compute_dtype": "bfloat16", "ssm_chunk": 128}),
               "mamba2-130m", "train_4k"))
