"""§Perf hillclimb measurement runs (exact two-point, single-pod mesh)."""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("REPRO_DRYRUN_WIRE", "f16")
import json
sys.path.insert(0, "src")
from repro.launch.dryrun import run_cell

RUNS = [
    # H1: kimi MoE dispatch — symmetric a2a vs FastFlow local_gather(baseline in exact.jsonl)
    ("kimi-k2-1t-a32b", "train_4k", {"REPRO_MOE_BACKEND": "a2a"}, [2, 4]),
    # H2: dsc decode — serve-param-replication (new code default; baseline pre-patch in exact.jsonl)
    ("deepseek-coder-33b", "decode_32k", {}, [2, 4]),
    # H3: mamba2 train — bf16 SSD matmuls
    ("mamba2-130m", "train_4k", {"REPRO_SSM_BF16": "1"}, [2, 4]),
]
out = open("reports/perf.jsonl", "a")
for arch, shape, env, depths in RUNS:
    for k, v in env.items():
        os.environ[k] = v
    for L in depths:
        print(f"=== perf {arch} × {shape} × L={L} env={env} ===", flush=True)
        rec = run_cell(arch, shape, False, unroll=True, n_layers=L)
        print("   ->", rec["status"], rec.get("compile_s"), rec.get("error", ""), flush=True)
        rec.pop("trace", None)
        out.write(json.dumps(rec) + "\n"); out.flush()
    for k in env:
        del os.environ[k]
print("hillclimb measurements done")
