"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts."""
import json
import sys

sys.path.insert(0, "src")
from benchmarks.roofline import analyse, load_cells  # noqa: E402


def fmt_bytes(b):
    if b >= 1e9:
        return f"{b/1e9:.2f}G"
    if b >= 1e6:
        return f"{b/1e6:.1f}M"
    return f"{b/1e3:.0f}K"


def dryrun_table():
    print("### Dry-run results (per cell; memory from the scanned/deployable "
          "program; per-device bytes)\n")
    for mesh in ["16x16", "2x16x16"]:
        rows = load_cells(mesh=mesh)
        print(f"\n**Mesh {mesh}** ({256 if mesh=='16x16' else 512} chips)\n")
        print("| arch | shape | status | args/dev | temp/dev | fits 16G? | "
              "compile_s | collective ops (counts) |")
        print("|---|---|---|---|---|---|---|---|")
        # also include skips
        seen = set()
        with open("reports/dryrun.jsonl") as f:
            for line in f:
                r = json.loads(line)
                if r.get("mesh") != mesh or r.get("unrolled"):
                    continue
                key = (r["arch"], r["shape"])
                if key in seen:
                    continue
                seen.add(key)
                if r["status"] == "SKIP":
                    print(f"| {r['arch']} | {r['shape']} | SKIP | — | — | — | — | "
                          f"{r['reason'][:60]}… |")
                    continue
                total = r["argument_size"] + r["temp_size"] - r.get("alias_size", 0)
                fits = "yes" if total <= 16e9 else f"NO ({total/1e9:.0f}G)"
                colls = ", ".join(f"{k.split('-')[-1] if False else k}:{v['count']}"
                                  for k, v in r["collectives"].items() if v["count"])
                print(f"| {r['arch']} | {r['shape']} | {r['status']} | "
                      f"{fmt_bytes(r['argument_size'])} | {fmt_bytes(r['temp_size'])} | "
                      f"{fits} | {r.get('compile_s','—')} | {colls} |")


def roofline_table():
    rows = [analyse(r) for r in load_cells(mesh="16x16")]
    rows = [r for r in rows if r]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    print("\n### Roofline terms (single-pod 16×16; exact unroll-extrapolated "
          "costs where available)\n")
    print("| arch | shape | compute_s | memory_s | collective_s | dominant | "
          "MODEL/HLO | roofline_frac | source |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
              f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
              f"**{r['dominant']}** | {r['useful_ratio']:.3f} | "
              f"{r['roofline_fraction']:.3f} | "
              f"{'exact' if 'cost_source' in r else 'scanned(≈1 layer)'} |")


if __name__ == "__main__":
    dryrun_table()
    roofline_table()
