"""Re-run exact two-point sweep with the fixed collective parser.
Priority: hillclimb cells -> decode cells -> train -> prefill."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("REPRO_DRYRUN_WIRE", "f16")
import json, sys
sys.path.insert(0, "src")
from repro.configs import ARCHS, SHAPES, cell_applicable
from repro.launch.dryrun import run_cell

def depths(cfg):
    if cfg.family == "hybrid":
        return [cfg.attn_every, 2 * cfg.attn_every]
    if cfg.family == "vlm":
        return [cfg.cross_attn_every, 2 * cfg.cross_attn_every]
    return [2, 4]

cells = []
for arch in sorted(ARCHS):
    for shape in SHAPES:
        if cell_applicable(arch, shape)[0]:
            cells.append((arch, shape.name, shape.kind))

PRIO = {("kimi-k2-1t-a32b","train_4k"): 0, ("deepseek-coder-33b","decode_32k"): 0,
        ("mamba2-130m","train_4k"): 0}
KIND = {"decode": 1, "train": 2, "prefill": 3}
cells.sort(key=lambda c: (PRIO.get((c[0], c[1]), KIND[c[2]])))

out = open("reports/exact.jsonl", "a")
for arch, shape, kind in cells:
    for L in depths(ARCHS[arch]):
        print(f"=== exact2 {arch} × {shape} × L={L} ===", flush=True)
        rec = run_cell(arch, shape, False, unroll=True, n_layers=L)
        print("   ->", rec["status"], rec.get("compile_s"), flush=True)
        rec.pop("trace", None)
        out.write(json.dumps(rec) + "\n"); out.flush()
print("exact2 done", flush=True)

# chain the hillclimb variants
RUNS = [
    ("kimi-k2-1t-a32b", "train_4k", {"REPRO_MOE_BACKEND": "a2a"}, [2, 4]),
    ("deepseek-coder-33b", "decode_32k", {}, [2, 4]),   # serve-replication (new code)
    ("mamba2-130m", "train_4k", {"REPRO_SSM_BF16": "1"}, [2, 4]),
    ("mamba2-130m", "train_4k", {"REPRO_SSM_BF16": "1", "REPRO_SSM_CHUNK": "128"}, [2, 4]),
]
pout = open("reports/perf.jsonl", "a")
for arch, shape, env, ds in RUNS:
    for k, v in env.items():
        os.environ[k] = v
    for L in ds:
        print(f"=== perf {arch} × {shape} × L={L} env={env} ===", flush=True)
        rec = run_cell(arch, shape, False, unroll=True, n_layers=L)
        print("   ->", rec["status"], rec.get("compile_s"), rec.get("error", ""), flush=True)
        rec.pop("trace", None)
        pout.write(json.dumps(rec) + "\n"); pout.flush()
    for k in env:
        del os.environ[k]
print("perf variants done", flush=True)
