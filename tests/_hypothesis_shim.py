"""Minimal in-repo fallback for ``hypothesis`` (property-based testing).

The real hypothesis is listed in ``requirements-test.txt`` and is used
whenever importable.  In hermetic environments without it, this shim keeps
the property-based test modules collectable and *degrades them to
example-based tests*: ``@given`` draws ``max_examples`` pseudo-random
examples from the strategies with a fixed seed (deterministic across runs —
no shrinking, no database, no health checks).

Only the strategy surface this repo's tests use is implemented:
``integers``, ``booleans``, ``floats``, ``sampled_from``, ``lists``,
``tuples``.
"""
from __future__ import annotations

import inspect
import random
import sys
import types
from typing import Any, Callable, List


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def example(self, rnd: random.Random) -> Any:
        return self._draw(rnd)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rnd: rnd.randint(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda rnd: rnd.random() < 0.5)


def floats(min_value: float = 0.0, max_value: float = 1.0, **_kw) -> _Strategy:
    return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))


def sampled_from(seq) -> _Strategy:
    seq = list(seq)
    return _Strategy(lambda rnd: rnd.choice(seq))


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    def draw(rnd: random.Random) -> List[Any]:
        n = rnd.randint(min_size, max_size)
        return [elements.example(rnd) for _ in range(n)]
    return _Strategy(draw)


def tuples(*strategies: _Strategy) -> _Strategy:
    return _Strategy(lambda rnd: tuple(s.example(rnd) for s in strategies))


class settings:  # noqa: N801 - mirrors hypothesis' API
    """Accepts (and mostly ignores) hypothesis settings; keeps
    ``max_examples`` so the shimmed ``@given`` draws that many."""

    def __init__(self, max_examples: int = 20, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._shim_settings = self
        return fn


def given(*strategies: _Strategy):
    def deco(fn):
        inner = fn

        def wrapper(*args, **kwargs):
            cfg = (getattr(wrapper, "_shim_settings", None)
                   or getattr(inner, "_shim_settings", None))
            n = cfg.max_examples if cfg is not None else 20
            rnd = random.Random(0xFA57F10)  # deterministic example stream
            for _ in range(n):
                inner(*args, *(s.example(rnd) for s in strategies), **kwargs)

        # like real hypothesis: the wrapper exposes a zero-arg signature
        # (otherwise pytest would treat the strategy params as fixtures)
        # and fn.hypothesis.inner_test (introspected by pytest plugins)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__signature__ = inspect.Signature()
        wrapper.hypothesis = types.SimpleNamespace(inner_test=inner)
        return wrapper

    return deco


def assume(condition: Any) -> bool:
    """Real hypothesis aborts the example; the shim just skips via early
    return convention — tests in this repo don't use assume, this exists
    for forward compatibility."""
    return bool(condition)


def install() -> None:
    """Register shim modules as ``hypothesis`` / ``hypothesis.strategies``."""
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "booleans", "floats", "sampled_from", "lists",
                 "tuples"):
        setattr(st, name, globals()[name])
    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
