"""Self-tuning runtime: profile -> retune -> replay.

The load-bearing guarantee is that :func:`repro.core.retune` is a *pure
IR rewrite* — whatever the pilot measured, the tuned lowering produces
exactly the same results as the untuned one, on every backend.  The
parity tests here pin that for the three rewrites the tuner performs
(Farm∘Farm merge, a2a right-row absorption, stage fusion + micro-batch)
and for the one it must refuse (fusing across a Feedback loop).  The
rest covers the profile artifact (JSON round-trip, diff), the tuning
models (auto_batch / ring_capacity), the hand-off recalibration path,
the mesh planning split, and the adaptive out-of-core budget.

Profiles are measured once at module scope (the pilot runs on the
threads backend, in-process); nodes live in ``_procs_nodes`` so the
procs backend can ship them to spawned vertices.
"""
import pytest
from hypothesis import given, settings, strategies as st

import _procs_nodes as N
from repro.core import (AllToAll, Farm, Feedback, FnNode, FusedNode, GO_ON,
                        KeyBatch, LoweringError, MemoryBudget, Pipeline,
                        Profile, Stage, TunedProgram, auto_batch,
                        calibrate_handoff_us, clear_handoff_cache, lower,
                        partition_by, plan_mesh, profile, reduce_by_key,
                        retune, ring_capacity)
from repro.core.autotune import StageProfile, _RebatchNode

# -- skeletons + their pilot profiles (measured once; threads, in-process) ---
FARM2 = Pipeline(Farm(N.f, 3, ordered=True), Farm(N.g, 3, ordered=True))
FARM2_PROF = profile(FARM2, range(256))

A2A = Pipeline(partition_by(N.mod3, 3), Stage(N.double), Stage(N.f))
A2A_PROF = profile(A2A, range(256))

# the porting-study failure mode: three cheap stages mis-declared coarse
PIPE3 = Pipeline(Stage(N.f, grain=10000), Stage(N.g, grain=10000),
                 Stage(N.sq, grain=10000))
PIPE3_PROF = profile(PIPE3, range(256))

FB = Pipeline(Stage(N.f), Feedback(N.fb_step, N.fb_pred, nworkers=2,
                                   max_trips=64), Stage(N.g))
FB_PROF = profile(FB, range(64))

# retune is deterministic given a profile: rewrite + lower once, reuse
# across every hypothesis example (procs examples each spawn a network)
FARM2_TUNED = retune(FARM2, FARM2_PROF)
A2A_TUNED = retune(A2A, A2A_PROF)
PIPE3_TUNED = retune(PIPE3, PIPE3_PROF)
FB_TUNED = retune(FB, FB_PROF)


def _prog(skel, backend):
    # retune already fused with the measured threshold — same opts
    # TunedProgram._build uses
    return lower(skel, backend, fuse=False)


# -- the profile artifact ----------------------------------------------------
def test_profile_measures_every_position():
    assert FARM2_PROF.handoff_us > 0
    assert FARM2_PROF.pilot_items == 256
    assert [sp.path for sp in FARM2_PROF.stages] == ["0", "1"]
    for sp in FARM2_PROF.stages:
        assert sp.kind == "farm" and sp.width == 3
        assert sp.items > 0 and sp.service_us > 0 and sp.service_ewma_us > 0
    # an all-to-all profiles as two rows, in pipeline order
    assert [sp.path for sp in A2A_PROF.stages] == ["0.left", "0.right",
                                                   "1", "2"]
    assert A2A_PROF.stage("0.left").kind == "a2a-left"
    assert A2A_PROF.stage("0.right").width == 3


def test_profile_json_roundtrip(tmp_path):
    p = tmp_path / "prof.json"
    FARM2_PROF.save(str(p))
    back = Profile.load(str(p))
    assert back.to_json() == FARM2_PROF.to_json()
    assert back.stage("1").service_us == FARM2_PROF.stage("1").service_us
    with pytest.raises(ValueError):
        Profile.from_json({"schema": "bench-rows/1"})


def test_profile_diff_reports_position_deltas():
    other = Profile(handoff_us=FARM2_PROF.handoff_us, pilot_items=256,
                    stages=[StageProfile(path="0", kind="farm", name="ff-farm",
                                         service_us=9.0, service_ewma_us=9.0,
                                         items=10, width=3,
                                         queue_high_water=5)])
    d = FARM2_PROF.diff(other)
    assert d["0"]["service_us"] == (FARM2_PROF.stage("0").service_us, 9.0)
    assert d["1"]["service_us"][1] is None  # missing on the other side


# -- the tuning models -------------------------------------------------------
def test_auto_batch_thresholds():
    assert auto_batch(100.0, 3.0) == 1        # hand-off already < 10% of svc
    assert auto_batch(1.0, 3.0) == 30         # ceil(3 / 0.1)
    assert auto_batch(0.001, 5.0) == 256      # capped
    assert auto_batch(5.0, 1.0, frac=0.5) == 1


def test_ring_capacity_model():
    balanced = ring_capacity(1.0, 1.0)
    assert balanced == 64
    assert ring_capacity(1.0, 8.0) > balanced      # slow consumer: deeper
    assert ring_capacity(8.0, 1.0) == 16           # slow producer: floor
    assert ring_capacity(1.0, 1000.0) == 512       # ratio clamped at 8
    assert ring_capacity(1.0, 1.0, high_water=300) == 1024  # 2x hw, pow2
    for cap in (ring_capacity(1.0, c) for c in (0.1, 0.5, 1, 3, 7)):
        assert 16 <= cap <= 8192 and cap & (cap - 1) == 0


def test_rebatch_node_batches_and_flushes():
    node = _RebatchNode(FnNode(N.double), batch=3)
    node.svc_init()
    assert node.svc(1) is GO_ON and node.svc(2) is GO_ON
    out = node.svc(3)
    assert isinstance(out, KeyBatch) and list(out) == [2, 4, 6]
    assert node.svc(4) is GO_ON
    tail = node.svc_eos()                  # remainder flushed at EOS
    assert isinstance(tail, KeyBatch) and list(tail) == [8]
    assert node.svc_eos() is None          # nothing buffered: stay silent


def test_rebatch_node_filters_like_unwrapped():
    node = _RebatchNode(FnNode(N.drop_odd), batch=2)
    assert node.svc(1) is GO_ON            # filtered, not buffered
    assert node.svc(3) is GO_ON
    out = node.svc(2)                      # 2 kept, still below batch
    assert out is GO_ON
    assert list(node.svc(4)) == [2, 4]


# -- retune structure: what the rewrite does to the IR -----------------------
def test_retune_merges_farm_farm_into_one():
    """Two back-to-back stateless sub-threshold farms become ONE farm
    (half the arbiter crossings), keeping the first farm's stats object
    so callers polling it keep their handle."""
    assert isinstance(FARM2_TUNED, Farm)
    assert FARM2_TUNED.nworkers == 3
    assert FARM2_TUNED.stats is FARM2.stages[0].stats


def test_retune_absorbs_stages_into_a2a():
    """Stateless post-shuffle stages are absorbed into the a2a right
    rows (FusedNode per partition) — the shuffle's stats identity is
    preserved."""
    assert isinstance(A2A_TUNED, AllToAll)
    assert all(isinstance(r, FusedNode) for r in A2A_TUNED.right_nodes)
    assert A2A_TUNED.stats is A2A.stages[0].stats


def test_retune_collapses_misgrained_pipeline():
    """The declared grain=10000 lie is overwritten by the measured
    sub-µs service times: the chain fuses to a single stage and the
    survivor is micro-batched (hand-off still dominates µs work)."""
    assert isinstance(PIPE3_TUNED, Stage)
    assert isinstance(PIPE3_TUNED.node, _RebatchNode)
    assert PIPE3_TUNED.node.batch > 1


def test_retune_never_fuses_across_feedback():
    """The wrap-around loop is a barrier: its ring re-enqueues items, so
    neither neighbour stage may be pulled into (or across) it."""
    assert isinstance(FB_TUNED, Pipeline)
    kinds = [type(s) for s in FB_TUNED.stages]
    assert kinds.count(Feedback) == 1
    fb = next(s for s in FB_TUNED.stages if isinstance(s, Feedback))
    assert fb.node is FB.stages[1].node    # loop body untouched


def test_retune_mesh_is_identity():
    """Mesh grain is a microbatch ROW COUNT, not µs — retune must not
    overwrite it with service times (plan_mesh owns the mesh axis)."""
    assert retune(FARM2, FARM2_PROF, backend="mesh") is FARM2


# -- retune parity: the rewrite never changes results ------------------------
@given(st.lists(st.integers(-1000, 1000), max_size=40))
@settings(max_examples=8, deadline=None)
def test_retune_parity_farm_farm_threads(xs):
    want = [N.g(N.f(x)) for x in xs]
    assert _prog(FARM2_TUNED, "threads")(xs) == want


@given(st.lists(st.integers(-200, 200), max_size=40))
@settings(max_examples=8, deadline=None)
def test_retune_parity_a2a_threads(xs):
    # unordered shuffle: compare as multisets
    want = sorted(N.f(N.double(x)) for x in xs)
    assert sorted(_prog(A2A_TUNED, "threads")(xs)) == want


@given(st.lists(st.integers(-1000, 1000), max_size=40))
@settings(max_examples=8, deadline=None)
def test_retune_parity_rebatched_pipeline_threads(xs):
    want = [N.sq(N.g(N.f(x))) for x in xs]
    assert _prog(PIPE3_TUNED, "threads")(xs) == want


@given(st.lists(st.integers(0, 60), max_size=24))
@settings(max_examples=6, deadline=None)
def test_retune_parity_feedback_threads(xs):
    want = [N.g(N.fb_ref(N.f(x))) for x in xs]
    assert _prog(FB_TUNED, "threads")(xs) == want


# Procs parity draws fewer examples: every example spawns a process
# network (seconds, not µs) — same tuned IR, same reference.
@given(st.lists(st.integers(-1000, 1000), max_size=12))
@settings(max_examples=2, deadline=None)
def test_retune_parity_farm_farm_procs(xs):
    assert _prog(FARM2_TUNED, "procs")(xs) == [N.g(N.f(x)) for x in xs]


@given(st.lists(st.integers(-200, 200), max_size=12))
@settings(max_examples=2, deadline=None)
def test_retune_parity_a2a_procs(xs):
    want = sorted(N.f(N.double(x)) for x in xs)
    assert sorted(_prog(A2A_TUNED, "procs")(xs)) == want


@given(st.lists(st.integers(-1000, 1000), max_size=12))
@settings(max_examples=2, deadline=None)
def test_retune_parity_rebatched_pipeline_procs(xs):
    # the _RebatchNode wrapper must pickle to spawned vertices and its
    # KeyBatch messages must unpack in the caller-side drain
    assert _prog(PIPE3_TUNED, "procs")(xs) == [N.sq(N.g(N.f(x))) for x in xs]


# -- the two-phase program (lower(..., tune=True)) ---------------------------
def test_tune_two_phase_threads():
    tp = lower(PIPE3, "threads", tune=True, tune_pilot=32)
    assert isinstance(tp, TunedProgram)
    assert tp.tuned is None                 # no pilot has run yet
    xs = list(range(100))
    want = [N.sq(N.g(N.f(x))) for x in xs]
    assert tp(xs) == want                   # pilot head + tuned remainder
    assert tp.profile is not None and tp.profile.pilot_items == 32
    assert isinstance(tp.tuned_skeleton, Stage)
    assert tp(xs) == want                   # second call: straight to tuned


def test_tune_two_phase_procs():
    tp = lower(FARM2, "procs", tune=True, tune_pilot=32)
    xs = list(range(64))
    assert tp(xs) == [N.g(N.f(x)) for x in xs]
    assert tp.tuned is not None and tp.tuned.backend == "procs"


def test_tune_pilot_covers_whole_stream():
    tp = lower(PIPE3, "threads", tune=True, tune_pilot=512)
    xs = list(range(40))                    # shorter than the pilot
    assert tp(xs) == [N.sq(N.g(N.f(x))) for x in xs]
    assert tp.profile.pilot_items == 40


def test_saved_profile_skips_pilot(tmp_path):
    p = tmp_path / "pipe3.json"
    PIPE3_PROF.save(str(p))
    tp = lower(PIPE3, "threads", profile=str(p))
    assert tp.tuned is not None             # built before any call
    xs = list(range(50))
    assert tp(xs) == [N.sq(N.g(N.f(x))) for x in xs]


# -- mesh planning -----------------------------------------------------------
def test_tune_two_phase_mesh():
    """tune=True on the mesh backend: pilot on threads, then plan_mesh
    picks the factorization and the skeleton lowers whole — parity with
    the host reference on exact ints."""
    tp = lower(FARM2, "mesh", tune=True, tune_pilot=32)
    xs = list(range(64))
    assert tp(xs) == [N.g(N.f(x)) for x in xs]
    assert tp.tuned_skeleton is tp.skeleton  # mesh tunes options, not IR


def test_plan_mesh_factorization_and_a2a_guard():
    plan = plan_mesh(PIPE3_PROF, PIPE3, devices=1)
    assert plan["factorization"] == (1, 1)
    # the a2a mesh program has no stage axis to factor
    assert plan_mesh(A2A_PROF, A2A, devices=4) == {}


def test_best_factorization_model():
    from repro.core.dpipeline import best_factorization
    assert best_factorization(3, 4) == (1, 4)        # not divisible: seq
    assert best_factorization(1, 8) == (1, 8)
    # a skewed chain pipelines at its slowest stage: seq wins the model
    assert best_factorization(2, 4, stage_costs=[5.0, 1.0]) == (1, 4)


def test_mesh_factorization_validation():
    with pytest.raises(LoweringError):
        lower(FARM2, "mesh", devices=4, factorization=(3, 1))  # 3 stages? no
    with pytest.raises(LoweringError):
        lower(FARM2, "mesh", devices=4, factorization=(2, 3))  # 6 > 4 devs


# -- hand-off calibration cache (the recalibrate bugfix) ---------------------
def test_handoff_recalibrate_and_cache_clear():
    from repro.core import sched
    clear_handoff_cache()
    assert sched._HANDOFF_CACHE is None
    v1 = calibrate_handoff_us(ntasks=128, repeats=1)
    assert v1 > 0 and sched._HANDOFF_CACHE == v1
    # cached: different args, same answer (no re-measure)
    assert calibrate_handoff_us(ntasks=4, repeats=1) == v1
    # recalibrate=True re-measures and replaces the cache
    v2 = calibrate_handoff_us(ntasks=128, repeats=1, recalibrate=True)
    assert v2 > 0 and sched._HANDOFF_CACHE == v2


# -- adaptive out-of-core budget ---------------------------------------------
def test_adaptive_budget_grow_shrink_hold():
    b = MemoryBudget(1024, adaptive=True)
    assert (b.min_limit, b.max_limit) == (128, 8192)
    assert b.adapt() == 2048               # clean run: grow
    b.spilled(0, 100)
    assert b.adapt() == 2048               # spills only: regime works, hold
    b.stalled()
    assert b.adapt() == 1024               # stalls: shrink
    for _ in range(10):
        b.stalled()
        b.adapt()
    assert b.limit == b.min_limit          # clamped at the floor
    for _ in range(10):
        b.adapt()
    assert b.limit == b.max_limit          # clean runs: clamped at the cap


def test_adaptive_budget_counts_deltas_not_totals():
    """adapt() reacts to THIS run's telemetry: old spills must not keep
    counting against future runs."""
    b = MemoryBudget(1024, adaptive=True)
    b.spilled(0, 10)
    b.stalled()
    assert b.adapt() == 512                # this run stalled
    assert b.adapt() == 1024               # next run clean: grow again


def test_adaptive_budget_resizes_after_run():
    """The fold_into finalizer drives adapt(): a comfortably-budgeted
    reduction run ends with the limit doubled (headroom observed)."""
    budget = MemoryBudget(1 << 20, adaptive=True)
    skel = reduce_by_key(N.mod3, "sum", nright=2, budget=budget)
    out = dict(lower(skel, "threads")(range(30)))
    assert out == {k: sum(x for x in range(30) if x % 3 == k)
                   for k in range(3)}
    assert budget.limit == 2 << 20


def test_non_adaptive_budget_never_resizes():
    budget = MemoryBudget(1 << 20)         # adaptive defaults off
    skel = reduce_by_key(N.mod3, "sum", nright=2, budget=budget)
    lower(skel, "threads")(range(30))
    assert budget.limit == 1 << 20
