"""Procs backend: the process-graph runtime over shared-memory rings.

Correctness of the ``lower(skel, "procs")`` contract (ordered output,
GO_ON filtering, emitter/collector nodes, feedback loops, all scheduling
policies), the FarmStats surface, failure semantics (a raising worker
fails the run instead of wedging it; a hung child hits the run timeout),
and hygiene (no leaked /dev/shm segments).  All nodes live in
``tests/_procs_nodes.py`` — spawned children re-import the defining
module, which must stay free of test-only deps.
"""
import glob

import pytest

import _procs_nodes as N
from repro.core import (EOS, Farm, Feedback, LoweringError, Pipeline,
                        ProcAccelerator, ProcProgram, Source, Stage, lower)


def _segments():
    return set(glob.glob("/dev/shm/psm_*"))


@pytest.fixture(autouse=True)
def no_leaked_segments():
    """Every test must unlink every segment it caused to exist."""
    before = _segments()
    yield
    assert _segments() - before == set(), "leaked SharedMemory segments"


def test_lower_returns_proc_program():
    prog = lower(Farm(N.f, 2), "procs")
    assert isinstance(prog, ProcProgram) and prog.backend == "procs"


def test_ordered_farm_matches_threads():
    xs = list(range(60))
    skel = Farm(N.f, 2, ordered=True)
    assert lower(skel, "procs")(xs) == lower(Farm(N.f, 2, ordered=True),
                                             "threads")(xs) \
        == [N.f(x) for x in xs]


def test_unordered_farm_is_a_permutation():
    xs = list(range(40))
    out = lower(Farm(N.f, 2), "procs")(xs)
    assert sorted(out) == sorted(N.f(x) for x in xs)


def test_pipeline_of_farm_and_stage():
    xs = list(range(30))
    skel = Pipeline(Farm(N.f, 2, ordered=True), Stage(N.g))
    assert lower(skel, "procs")(xs) == [N.g(N.f(x)) for x in xs]


def test_go_on_filters_and_terminates():
    out = lower(Farm(N.drop_odd, 2, ordered=True), "procs")(range(20))
    assert out == [x for x in range(20) if x % 2 == 0]


def test_emitter_and_collector_nodes_run_in_arbiters():
    skel = Farm(N.f, 2, ordered=True, emitter=N.AddTagEmitter(),
                collector=N.NegateCollector())
    assert lower(skel, "procs")(range(10)) \
        == [-N.f(x + 100) for x in range(10)]


def test_empty_stream():
    assert lower(Farm(N.f, 2, ordered=True), "procs")([]) == []


@pytest.mark.parametrize("policy", ["rr", "ondemand", "worksteal",
                                    "costmodel"])
def test_scheduling_policies_preserve_ordered_output(policy):
    xs = list(range(48))
    skel = Farm(N.f, 3, ordered=True, scheduling=policy)
    assert lower(skel, "procs")(xs) == [N.f(x) for x in xs]
    st = skel.stats
    assert st.tasks_emitted == st.tasks_collected == len(xs)
    assert sum(st.per_worker.values()) == len(xs)
    if policy == "costmodel":
        # the worker-side service EWMA crossed back over SPSC rings
        assert st.service_ewma


def test_feedback_loop_and_max_trips():
    xs = list(range(0, 30, 3))
    fb = Feedback(N.fb_step, N.fb_pred, nworkers=2)
    assert lower(fb, "procs")(xs) == [N.fb_ref(x) for x in xs]
    capped = Feedback(N.fb_step, N.fb_pred, nworkers=2, max_trips=1)
    assert lower(capped, "procs")(xs) == [N.fb_step(x) for x in xs]


def test_oversized_payloads_stream_through_the_farm():
    xs = list(range(12))
    out = lower(Farm(N.big_payload, 2, ordered=True),
                "procs", slot_size=64)(xs)
    assert out == [N.big_payload(x) for x in xs]


# -- failure semantics --------------------------------------------------------
def test_worker_exception_propagates_and_cleans_up():
    with pytest.raises(ValueError, match="boom at 7"):
        lower(Farm(N.boom_on_seven, 2, ordered=True), "procs")(range(20))


def test_hung_child_hits_the_run_timeout():
    with pytest.raises(TimeoutError, match="procs graph"):
        lower(Farm(N.sleepy, 2), "procs", timeout=3.0)(range(4))


def test_unpicklable_node_is_a_lowering_error():
    with pytest.raises(LoweringError, match="picklable"):
        lower(Farm(lambda x: x, 2), "procs")([1, 2, 3])


def test_speculative_is_threads_only():
    with pytest.raises(LoweringError, match="threads-only"):
        lower(Farm(N.f, 2, speculative=True), "procs")([1])


def test_accelerator_dead_worker_full_ring_fails_fast():
    """A worker that dies with its input ring full must surface its error
    through offload/eos/wait, never wedge the caller (the caller is the
    dispatch arbiter: nobody else can notice for it)."""
    acc = ProcAccelerator(Farm(N.boom_on_seven, 1, ordered=True),
                          capacity=16)
    with pytest.raises((ValueError, RuntimeError)):
        for x in range(500):
            acc.offload(x)
        acc.wait(30)


# -- the self-offloading accelerator ------------------------------------------
def test_accelerator_caller_side_farm():
    skel = Farm(N.sq, 2, ordered=True)
    acc = ProcAccelerator(skel)
    assert acc._farm is not None  # caller-side arbitration engaged
    for x in range(40):
        acc.offload(x)
    assert acc.wait(60) == [N.sq(x) for x in range(40)]
    st = skel.stats
    assert st.tasks_emitted == st.tasks_collected == 40
    assert sum(st.per_worker.values()) == 40


def test_accelerator_falls_back_to_graph_for_worksteal():
    acc = ProcAccelerator(Farm(N.sq, 2, ordered=True,
                               scheduling="worksteal"))
    assert acc._farm is None  # token-holding policy needs the arbiter
    for x in range(30):
        acc.offload(x)
    assert acc.wait(60) == [N.sq(x) for x in range(30)]


def test_accelerator_composition_uses_graph_path():
    acc = ProcAccelerator(Farm(N.f, 2, ordered=True) >> Stage(N.g))
    for x in range(20):
        acc.offload(x)
    assert acc.wait(60) == [N.g(N.f(x)) for x in range(20)]


def test_program_source_wrapping_matches_explicit_source():
    xs = list(range(15))
    prog = lower(Pipeline(Source(xs), Farm(N.f, 2, ordered=True)), "procs")
    g = prog.to_graph()
    assert g.run_and_wait(60) == [N.f(x) for x in xs]


# -- spawn-pool reuse + the new lowering options -----------------------------
def test_spawn_pool_reuses_processes():
    from repro.core import pool_stats
    want = [N.f(x) for x in range(30)]
    assert lower(Farm(N.f, 2, ordered=True), "procs")(list(range(30))) == want
    before = pool_stats()
    assert lower(Farm(N.f, 2, ordered=True), "procs")(list(range(30))) == want
    after = pool_stats()

    def total(stats, key):
        return sum(v[key] for v in stats.values())

    # the second run's 4 vertices (disp + merge + 2 workers) all came from
    # the pool: zero fresh spawns, at least 4 reuses
    assert total(after, "spawned") == total(before, "spawned")
    assert total(after, "reused") >= total(before, "reused") + 4


def test_pool_opt_out_direct_spawn_still_works():
    xs = list(range(20))
    prog = lower(Farm(N.f, 2, ordered=True), "procs", pool=False)
    assert prog(xs) == [N.f(x) for x in xs]


def test_batched_emit_matches_unbatched():
    xs = list(range(80))
    want = [N.g(N.f(x)) for x in xs]
    skel = Pipeline(Stage(N.f), Stage(N.g))
    assert lower(skel, "procs", batch=16)(xs) == want
    assert lower(Pipeline(Stage(N.f), Stage(N.g)), "procs", batch=1)(xs) == want


def test_batch_grain_reads_stage_grain():
    xs = list(range(60))
    skel = Pipeline(Source(xs), Stage(N.f, grain=8), Stage(N.g, grain=8))
    # fuse=False: grain must feed the emit-batch size here, not the fusion
    # pass (which reads it as µs of work)
    prog = lower(skel, "procs", batch="grain", fuse=False)
    assert prog.to_graph().run_and_wait(60) == [N.g(N.f(x)) for x in xs]


def test_numpy_payloads_through_zero_copy_farm():
    np = pytest.importorskip("numpy")
    xs = [np.full((32,), float(i), dtype=np.float32) for i in range(24)]
    skel = Farm(N.np_double, 2, ordered=True)
    out = lower(skel, "procs", batch=4, zero_copy=True)(xs)
    assert len(out) == len(xs)
    for got, x in zip(out, xs):
        assert got.dtype == np.float32 and got.shape == (32,)
        assert np.array_equal(got, x * 2.0)
