"""Graph runtime semantics: skeleton composition (pipelines of farms),
ordering under ordered composition, wrap-around (feedback) termination,
equivalence with the seed TaskFarm, the self-offloading accelerator, and
the graph-backed MDF executor — tier-1 for the composition layer."""
import time

from hypothesis import given, settings, strategies as st

from repro.core import (Accelerator, Farm, FnNode, GO_ON, Graph, LockQueue,
                        MDFExecutor, MDFTask, Pipeline, Stage, TaskFarm,
                        compose, ff_node)
from repro.core.graph import StageVertex


def _f(x):
    return x * 3 + 1


def _g(x):
    return x * x - 2


# -- acceptance: composed farms == sequential over a 10k stream --------------
def test_pipeline_of_farms_matches_sequential_10k():
    """Pipeline(Farm(f), Farm(g)) must equal g(f(x)) item-for-item over a
    10k stream (ordered farms ⇒ order preserved end-to-end)."""
    n = 10_000
    net = Pipeline(Farm(_f, 4, ordered=True), Farm(_g, 4, ordered=True))
    out = net.run_and_wait(range(n))
    assert out == [_g(_f(x)) for x in range(n)]


def test_pipeline_of_farms_unordered_same_multiset():
    n = 2_000
    out = Pipeline(Farm(_f, 3), Farm(_g, 3)).run_and_wait(range(n))
    assert sorted(out) == sorted(_g(_f(x)) for x in range(n))


def test_compose_mixes_stages_and_farms():
    out = compose(lambda x: x + 1,
                  Farm(_f, 3, ordered=True),
                  lambda x: x - 1).run_and_wait(range(500))
    assert out == [_f(x + 1) - 1 for x in range(500)]


def test_farm_of_pipelines():
    """A farm whose worker is itself a two-stage computation, and the dual:
    workers are pipeline stages (farms nest inside pipelines and both close
    under composition)."""
    inner = lambda x: _g(_f(x))
    out = Farm(inner, 4, ordered=True).run_and_wait(range(1_000))
    assert out == [_g(_f(x)) for x in range(1_000)]


def test_stage_filtering_go_on():
    """A stage returning GO_ON (or None mid-pipeline) filters the item."""
    def keep_even(x):
        return x if x % 2 == 0 else GO_ON
    out = Pipeline(Stage(FnNode(keep_even)), Stage(FnNode(lambda x: x // 2))
                   ).run_and_wait(range(100))
    assert out == [x // 2 for x in range(0, 100, 2)]


def test_lock_queue_substrate():
    out = Pipeline(Farm(_f, 2, ordered=True), Farm(_g, 2, ordered=True)
                   ).run_and_wait(range(300), queue_class=LockQueue)
    assert out == [_g(_f(x)) for x in range(300)]


# -- property: ordering preserved under ordered composition ------------------
@given(st.integers(1, 5), st.integers(1, 5), st.integers(0, 300))
@settings(max_examples=12, deadline=None)
def test_ordered_composition_preserves_order(nw1, nw2, n):
    out = Pipeline(Farm(_f, nw1, ordered=True),
                   Farm(_g, nw2, ordered=True)).run_and_wait(range(n))
    assert out == [_g(_f(x)) for x in range(n)]


# -- property: graph-backed TaskFarm ≡ seed farm semantics -------------------
@given(st.integers(1, 6), st.lists(st.integers(-1000, 1000), max_size=150),
       st.booleans())
@settings(max_examples=15, deadline=None)
def test_taskfarm_matches_reference_semantics(nworkers, stream, ordered):
    farm = TaskFarm(nworkers, preserve_order=ordered)
    farm.add_stream(list(stream))
    farm.add_worker(FnNode(_f))
    out = farm.run_and_wait()
    want = [_f(x) for x in stream]
    assert (out == want) if ordered else (sorted(out) == sorted(want))
    assert farm.stats.tasks_collected == len(stream)


# -- feedback / wrap-around edges --------------------------------------------
def test_feedback_eos_propagates_without_deadlock():
    """EOS must drain a cyclic network: every task loops back `depth` times
    before leaving, and the farm still terminates on upstream EOS."""
    def route(res):
        x, depth = res
        if depth == 0:
            return x, []            # leaves the loop
        return None, [(x, depth - 1)]  # goes back around

    stream = [(x, x % 4) for x in range(200)]
    done = []
    t0 = time.monotonic()
    out = Farm(lambda t: t, 3, feedback=route).run_and_wait(stream)
    assert sorted(out) == list(range(200))
    assert time.monotonic() - t0 < 30  # terminated, not timed out


@given(st.lists(st.integers(0, 6), min_size=0, max_size=80))
@settings(max_examples=10, deadline=None)
def test_feedback_property_token_conservation(depths):
    """Each injected token makes exactly `depth` loop trips then exits:
    results are conserved 1:1 regardless of loop interleavings."""
    def route(res):
        tag, depth = res
        return (tag, []) if depth == 0 else (None, [(tag, depth - 1)])
    stream = list(enumerate(depths))
    out = Farm(lambda t: t, 2, feedback=route).run_and_wait(stream)
    assert sorted(out) == list(range(len(depths)))


def test_feedback_divide_and_conquer_sum():
    """Recursive range-splitting through the wrap-around edge: sum(0..n)."""
    def worker(task):
        lo, hi = task
        if hi - lo <= 8:
            return ("leaf", sum(range(lo, hi)))
        mid = (lo + hi) // 2
        return ("split", (lo, mid), (mid, hi))

    def route(res):
        if res[0] == "leaf":
            return res[1], []
        return None, [res[1], res[2]]

    n = 1_000
    parts = Farm(worker, 4, feedback=route).run_and_wait([(0, n)])
    assert sum(parts) == sum(range(n))


def test_feedback_worker_exception_does_not_deadlock():
    """A raising worker inside a wrap-around farm must surface the error
    from wait(), not hang the loop-quiescence wait forever."""
    def worker(t):
        x, d = t
        if x == 13 and d == 1:
            raise ValueError("boom in the loop")
        return t

    def route(res):
        x, d = res
        return (x, []) if d == 0 else (None, [(x, d - 1)])

    import pytest
    with pytest.raises(ValueError, match="boom in the loop"):
        Farm(worker, 2, feedback=route).run_and_wait([(x, 2) for x in range(50)])


def test_dead_worker_full_ring_raises_not_hangs():
    """A non-survivable worker death with a full inbound ring must surface
    the error from wait(), not leave the dispatch arbiter spinning on
    push() to a ring whose consumer is dead."""
    import pytest

    def die(x):
        raise RuntimeError("worker died immediately")

    with pytest.raises(RuntimeError, match="worker died immediately"):
        Farm(die, 1).run_and_wait(range(5_000), capacity=8)


def test_feedback_on_lock_queue_substrate():
    """The wrap-around quiescence check must work over LockQueue too (same
    API surface as SPSCQueue, including empty())."""
    def route(res):
        x, d = res
        return (x, []) if d == 0 else (None, [(x, d - 1)])
    out = Farm(lambda t: t, 2, feedback=route).run_and_wait(
        [(x, 2) for x in range(100)], queue_class=LockQueue)
    assert sorted(out) == list(range(100))


def test_farm_worker_go_on_filters():
    """GO_ON from a farm worker emits nothing (same contract as a Stage),
    including through a composed pipeline."""
    keep_even = lambda x: x if x % 2 == 0 else GO_ON
    out = Farm(keep_even, 2, ordered=True).run_and_wait(range(10))
    assert out == [0, 2, 4, 6, 8]
    out = Pipeline(Farm(keep_even, 2, ordered=True),
                   Farm(lambda x: x * 10, 2, ordered=True)).run_and_wait(range(10))
    assert out == [0, 20, 40, 60, 80]


def test_feedback_with_source_emitter():
    """Standalone farm: a generating emitter AND a wrap-around edge (the
    dispatch arbiter must drain the loop while and after generating)."""
    class Src(ff_node):
        def __init__(self):
            self.n = 0

        def svc(self, _):
            self.n += 1
            return (self.n, 3) if self.n <= 100 else None

    def route(res):
        x, d = res
        return (x, []) if d == 0 else (None, [(x, d - 1)])

    out = Farm(lambda t: t, 3, emitter=Src(), feedback=route).run_and_wait()
    assert sorted(out) == list(range(1, 101))


# -- accelerator (self-offloading) -------------------------------------------
def test_accelerator_offload_and_wait():
    acc = Accelerator(Farm(_f, 3, ordered=True))
    for x in range(500):
        acc.offload(x)
    assert acc.wait(timeout=30) == [_f(x) for x in range(500)]


def test_accelerator_caller_overlaps_with_network():
    """The offloading thread keeps running while the farm computes."""
    acc = Accelerator(Farm(lambda x: (time.sleep(0.001), x)[1], 4))
    overlapped = 0
    for x in range(50):
        acc.offload(x)
        overlapped += 1  # main thread continues immediately
    assert overlapped == 50
    assert sorted(acc.wait(timeout=30)) == list(range(50))


# -- raw Graph API: hand-built topology --------------------------------------
def test_raw_graph_fan_out_fan_in():
    """Two parallel branches built with add/connect, merged at a sink."""
    g = Graph()
    src = g.add(StageVertex(_mk_counter(100), name="src"))
    a = g.add(StageVertex(FnNode(lambda x: ("a", x)), name="a"))
    b = g.add(StageVertex(FnNode(lambda x: ("b", x)), name="b"))
    sink = g.add(StageVertex(FnNode(lambda t: t), name="sink"))
    g.connect(src, a)
    g.connect(src, b)  # src round-robins over its two out edges
    g.connect(a, sink)
    g.connect(b, sink)
    out = g.run_and_wait()
    assert sorted(x for _, x in out) == list(range(100))
    assert {lbl for lbl, _ in out} == {"a", "b"}


def _mk_counter(n):
    it = iter(range(n))

    class _C(ff_node):
        def svc(self, _):
            return next(it, None)

    return _C()


# -- graph-backed MDF (cycle exercised through the same machinery) -----------
def test_mdf_runs_on_graph_runtime():
    tasks = [MDFTask(tag=i, fn=lambda *d, i=i: sum(d) + i,
                     deps=(i - 1,) if i else ())
             for i in range(30)]
    out = MDFExecutor(nworkers=3).run(tasks)
    assert out[29] == sum(range(30))
