"""Sharding-rule invariants that the dry-run depends on:
  * the pspec token trees structurally match the param/cache trees;
  * every sharded dim divides the production mesh axes (incl. padding);
  * shape-cell applicability matches DESIGN.md §Arch-applicability.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, SUBQUADRATIC, cell_applicable
from repro.models import model as M
from repro.models.config import pad_to
from repro.parallel.context import is_spec_leaf

DP, MP = 16, 16         # single-pod production mesh
POD = 2


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_pspecs_tree_matches_params_tree(arch):
    cfg = ARCHS[arch]
    shapes = jax.eval_shape(lambda: M.init_params(cfg.smoke(), jax.random.PRNGKey(0)))
    specs = M.params_pspecs(cfg.smoke(), MP)
    t1 = jax.tree_util.tree_structure(shapes)
    t2 = jax.tree_util.tree_structure(specs, is_leaf=is_spec_leaf)
    assert t1 == t2, f"{arch}: params vs pspecs structure drift"


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("multi_pod", [False, True])
def test_sharded_dims_divide_production_mesh(arch, multi_pod):
    """For every param: dims marked 'mp' divide 16; dims marked 'dp' divide
    16 (or 32 multi-pod). This is exactly what the dry-run requires."""
    cfg = ARCHS[arch]
    dp = DP * (POD if multi_pod else 1)
    shapes = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    specs = M.params_pspecs(cfg, MP)
    flat, treedef = jax.tree_util.tree_flatten(shapes)
    spec_flat = treedef.flatten_up_to(specs)
    for leaf, spec in zip(flat, spec_flat):
        if spec is None:
            continue
        for dim, tok in enumerate(spec):
            if tok == "mp":
                assert leaf.shape[dim] % MP == 0, \
                    f"{arch}: dim {dim} of {leaf.shape} not divisible by model={MP} ({spec})"
            elif tok == "dp":
                assert leaf.shape[dim] % dp == 0, \
                    f"{arch}: dim {dim} of {leaf.shape} not divisible by dp={dp} ({spec})"


def test_head_and_vocab_padding_rules():
    assert ARCHS["deepseek-coder-33b"].n_heads_padded == 64      # 56 → 64
    assert ARCHS["starcoder2-7b"].n_heads_padded == 48           # 36 → 48
    assert ARCHS["musicgen-medium"].n_heads_padded == 32         # 24 → 32 (MHA: kv too)
    assert ARCHS["phi3-mini-3.8b"].n_heads_padded == 32          # no padding
    assert ARCHS["mamba2-130m"].vocab_padded == pad_to(50_280, 16)
    assert ARCHS["kimi-k2-1t-a32b"].vocab_padded == 163_840      # already divisible


def test_gqa_groups_integral_after_padding():
    for arch, cfg in ARCHS.items():
        if cfg.family == "ssm":
            continue
        kv = cfg.n_heads_padded if cfg.n_kv_heads == cfg.n_heads else cfg.n_kv_heads
        assert cfg.n_heads_padded % kv == 0, arch


def test_long_context_cell_policy():
    ran, skipped = set(), set()
    for arch in ARCHS:
        ok, why = cell_applicable(arch, next(s for s in SHAPES if s.name == "long_500k"))
        (ran if ok else skipped).add(arch)
    assert ran == SUBQUADRATIC
    assert "phi3-mini-3.8b" in skipped and "kimi-k2-1t-a32b" in skipped
    # every other cell runs everywhere
    for s in SHAPES:
        if s.name != "long_500k":
            assert all(cell_applicable(a, s)[0] for a in ARCHS)


def test_40_cells_accounted():
    total = len(ARCHS) * len(SHAPES)
    assert total == 40
    runnable = sum(cell_applicable(a, s)[0] for a in ARCHS for s in SHAPES)
    assert runnable == 33 and total - runnable == 7
