"""Live monitoring (repro.core.monitor): the background sampler, the
bottleneck analyzer, the drift watcher and the SLO monitor.

The load-bearing pins:

* frame parity — the SAME skewed skeleton monitored on threads and
  procs yields frames whose depth taps use the SAME backend-neutral
  qualnames, and whose progress counters are monotone (telemetry keys
  by IR path, not by runtime object);
* the analyzer names the 10x-slower stage on BOTH host backends — the
  acceptance pin for bottleneck attribution;
* drift alerts latch: a sustained service-time shift past the saved
  profile's threshold fires exactly ONCE per excursion;
* monitor off allocates NOTHING in monitor.py — same structural
  overhead claim as tracing-off (the vertex path never enters the
  module; ``monitor=None`` is the default).
"""
import json
import os
import subprocess
import sys
import tracemalloc

import pytest

from repro.core import (DriftWatcher, Farm, MetricsRegistry, Monitor,
                        Pipeline, Profile, SLOMonitor, Stage, StageProfile,
                        Timeline, analyze, lower)
from repro.core import monitor as monitor_mod
from repro.core.obs import Histogram, Tracer
from tests._procs_nodes import fast_stage, slow_stage

# one stage 10x slower: the analyzer must name position 1
SKEW = Pipeline(Stage(fast_stage), Stage(slow_stage))
N_SKEW = 60
WANT_SKEW = sorted(slow_stage(fast_stage(x)) for x in range(N_SKEW))


def _depth_quals(tl):
    quals = set()
    for fr in tl.frames():
        quals |= set(fr["depths"])
    return quals


def _monotone(tl, key):
    vals = [fr["counters"][key] for fr in tl.frames()
            if key in fr["counters"]]
    assert vals, f"counter {key!r} never sampled"
    assert all(a <= b for a, b in zip(vals, vals[1:])), (key, vals)
    return vals


# -- the acceptance pin: frame parity + bottleneck naming, both backends -----
def test_monitor_parity_and_bottleneck_threads_procs():
    timelines = {}
    for backend in ("threads", "procs"):
        mon = Monitor(interval_s=0.001)
        prog = lower(SKEW, backend, monitor=mon)
        assert sorted(prog(range(N_SKEW))) == WANT_SKEW
        tl = mon.timeline
        assert tl.frames(), f"{backend}: monitor sampled nothing"
        assert mon.errors == 0, f"{backend}: absorbed sampler errors"
        timelines[backend] = tl
        # progress counter is monotone and lands on the stream length
        vals = _monotone(tl, "items_out")
        assert vals[-1] == N_SKEW, (backend, vals[-1])
        # the analyzer names the slow stage on this backend
        rep = analyze(tl)
        assert rep.stage == "ff-stage@1", (backend, rep.to_json())
        assert rep.verdict == "queue-bound"
        assert any(r["knob"] in ("nworkers", "grain")
                   for r in rep.recommendations), rep.recommendations
    # same backend-neutral depth tap names on both host backends
    tq, pq = _depth_quals(timelines["threads"]), _depth_quals(
        timelines["procs"])
    assert tq == pq, (sorted(tq), sorted(pq))
    assert {"ff-source@in", "ff-stage@0", "ff-stage@1"} <= tq, sorted(tq)


def test_procs_farm_live_boards_monotone():
    """Mid-run farm progress on procs comes from the single-writer
    ShmCounters boards (slot 0 = emitted by the dispatch arbiter,
    slot 1 = collected by the merge arbiter), read caller-side."""
    mon = Monitor(interval_s=0.001)
    skel = Pipeline(Stage(fast_stage), Farm(slow_stage, nworkers=2))
    prog = lower(skel, "procs", monitor=mon)
    out = prog(range(40))
    assert sorted(out) == sorted(slow_stage(fast_stage(x))
                                 for x in range(40))
    em = _monotone(mon.timeline, "ff-farm@1.emitted")
    co = _monotone(mon.timeline, "ff-farm@1.collected")
    assert em[-1] == 40 and co[-1] == 40, (em[-1], co[-1])
    # collected never runs ahead of emitted within a frame
    for fr in mon.timeline.frames():
        c = fr["counters"]
        if "ff-farm@1.emitted" in c and "ff-farm@1.collected" in c:
            assert c["ff-farm@1.collected"] <= c["ff-farm@1.emitted"], c


def test_mesh_program_level_frames():
    pytest.importorskip("jax")
    from tests._procs_nodes import double
    mon = Monitor()
    prog = lower(Farm(double, nworkers=2), "mesh", monitor=mon)
    prog([float(x) for x in range(16)])
    prog([float(x) for x in range(16)])
    frames = mon.timeline.frames()
    assert len(frames) == 2, len(frames)
    for fr in frames:
        assert not fr["depths"] and not fr["ewma_us"]  # program-level only
        assert {"mesh.calls", "mesh.items", "mesh.compiles",
                "mesh.devices", "mesh.call_us"} <= set(fr["counters"])
    assert frames[1]["counters"]["mesh.calls"] == 2
    assert frames[1]["counters"]["mesh.items"] == 32
    # second same-shaped call reused the compile cache
    assert frames[1]["counters"]["mesh.compiles"] == \
        frames[0]["counters"]["mesh.compiles"]


# -- overhead: monitor off touches monitor.py not at all ---------------------
def test_monitor_off_allocates_nothing():
    prog = lower(SKEW, "threads")  # no monitor=
    prog(range(N_SKEW))  # warm the lowering before the snapshot window
    tracemalloc.start()
    try:
        assert sorted(prog(range(N_SKEW))) == WANT_SKEW
        snap = tracemalloc.take_snapshot()
    finally:
        tracemalloc.stop()
    allocs = snap.filter_traces(
        [tracemalloc.Filter(True, monitor_mod.__file__)])
    total = sum(s.size for s in allocs.statistics("filename"))
    assert total == 0, f"monitor-off run allocated {total}B in monitor.py"


# -- the timeline ring -------------------------------------------------------
def test_timeline_ring_and_round_trip(tmp_path):
    tl = Timeline(capacity=4)
    for i in range(7):
        tl.append({"t": float(i), "depths": {"v": i}, "ewma_us": {},
                   "counters": {"items_out": i}})
    frames = tl.frames()
    assert len(frames) == 4
    assert [f["t"] for f in frames] == [3.0, 4.0, 5.0, 6.0]  # ring order
    assert tl.dropped == 3
    path = str(tmp_path / "tl.json")
    tl.save(path)
    back = Timeline.load(path)
    assert back.schema == "timeline/1"
    assert back.frames() == frames
    assert back.dropped == 3
    # analyze() accepts the raw JSON document too
    with open(path) as f:
        rep = analyze(json.load(f))
    assert rep.frames == 4


def test_timeline_chrome_counter_tracks():
    """A monitored + traced run overlays depth/counter tracks ("C"
    events under an ff-monitor process) on the swim-lane export."""
    mon = Monitor(interval_s=0.001)
    prog = lower(SKEW, "threads", trace=True, monitor=mon)
    prog(range(N_SKEW))
    doc = prog.last_trace.to_chrome_json(timeline=mon.timeline)
    cev = [e for e in doc["traceEvents"] if e["ph"] == "C"]
    assert cev, "no counter tracks in merged export"
    names = {e["name"] for e in cev}
    assert any(n.startswith("depth:") for n in names), names
    assert "items_out" in names, names
    pids = {e["pid"] for e in cev}
    procs = {e["args"]["name"] for e in doc["traceEvents"]
             if e["ph"] == "M" and e["name"] == "process_name"
             and e["pid"] in pids}
    assert "ff-monitor" in procs, procs


# -- drift watcher -----------------------------------------------------------
def _saved_profile(service_us):
    return Profile(handoff_us=1.0, pilot_items=50, stages=[
        StageProfile(path="1", kind="farm", name="ff-farm",
                     service_us=service_us, service_ewma_us=service_us,
                     items=50)])


def test_drift_watcher_fires_and_latches():
    w = DriftWatcher(_saved_profile(100.0), threshold=0.5)
    # under threshold: quiet
    assert w.check({"ff-farm@1": 120.0}) == []
    # past threshold: fires once...
    fired = w.check({"ff-farm@1": 200.0})
    assert len(fired) == 1 and fired[0]["path"] == "1"
    assert fired[0]["live_us"] == 200.0 and fired[0]["saved_us"] == 100.0
    # ...then latches, even while the drift persists or grows
    assert w.check({"ff-farm@1": 210.0}) == []
    assert w.check({"ff-farm@1": 500.0}) == []
    # re-arms only under threshold/2, then a new excursion fires again
    assert w.check({"ff-farm@1": 160.0}) == []   # rel 0.6 > 0.25: still off
    assert w.check({"ff-farm@1": 110.0}) == []   # rel 0.1 < 0.25: re-armed
    assert len(w.check({"ff-farm@1": 300.0})) == 1
    assert len(w.events) == 2


def test_drift_watcher_routes_through_registry_watch():
    seen = []
    reg = MetricsRegistry()
    reg.watch(lambda rep: seen.append(rep.meta.get("event")))
    w = DriftWatcher(_saved_profile(100.0), threshold=0.5, registry=reg)
    w.check({"ff-farm@1": 300.0})
    assert seen == ["drift"]
    assert reg.counter("monitor.drift_alerts").value == 1


def test_drift_fires_exactly_once_mid_run_threads():
    """The acceptance pin: live EWMAs vs a saved pilot profile, with the
    farm's real service time far past the saved one — the monitor's
    per-frame checks alert exactly once for the whole excursion."""
    reg = MetricsRegistry()
    alerts = []
    reg.watch(lambda rep: alerts.append(rep.meta))
    # saved profile says 100us; slow_stage services at ~2000us -> rel ~19
    mon = Monitor(interval_s=0.001, profile=_saved_profile(100.0),
                  drift_threshold=3.0, registry=reg)
    prog = lower(Pipeline(Stage(fast_stage),
                          Farm(slow_stage, nworkers=2)), "threads",
                 monitor=mon)
    prog(range(80))
    drift_events = [e for e in mon.drift.events if e["path"] == "1"]
    assert len(drift_events) == 1, drift_events
    assert [a["event"] for a in alerts] == ["drift"]
    assert reg.counter("monitor.drift_alerts").value == 1
    assert drift_events[0]["live_us"] > drift_events[0]["saved_us"]


# -- SLO monitor -------------------------------------------------------------
def test_slo_monitor_latency_latch_and_trace_instants():
    tracer = Tracer()
    reg = MetricsRegistry()
    slo = SLOMonitor(p99_us=10_000.0, registry=reg)
    slo.bind(tracer)
    hist = Histogram("serve.request_latency_us")
    for _ in range(50):
        hist.observe(50_000.0)
    assert len(slo.check(hist)) == 1      # breach fires...
    assert slo.check(hist) == []          # ...and latches
    assert reg.counter("slo.alerts").value == 1
    fresh = Histogram("serve.request_latency_us")
    for _ in range(50):
        fresh.observe(1_000.0)
    assert slo.check(fresh) == []         # recovery re-arms
    assert len(slo.check(hist)) == 1      # next excursion fires again
    tr = tracer.trace()
    kinds = [e[0] for e in tr.events()]
    assert kinds.count("alert") == 2, kinds
    assert "slo-monitor" in tr.qualnames()


def test_slo_monitor_goodput():
    slo = SLOMonitor(min_goodput=100.0)
    assert len(slo.check(goodput=40.0)) == 1
    assert slo.check(goodput=35.0) == []          # latched
    assert slo.check(goodput=150.0) == []         # re-armed
    assert len(slo.check(goodput=10.0)) == 1


# -- the CLI renderer --------------------------------------------------------
def _run_cli(*argv):
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.core.monitor", *argv],
        env=env, capture_output=True, text=True, timeout=120)


def test_cli_renders_timeline_and_report(tmp_path):
    mon = Monitor(interval_s=0.001)
    prog = lower(SKEW, "threads", metrics=True, monitor=mon)
    prog(range(N_SKEW))
    tl_path = str(tmp_path / "timeline.json")
    mon.timeline.save(tl_path)
    out = _run_cli(tl_path)
    assert out.returncode == 0, out.stderr
    assert "ff-monitor:" in out.stdout and "bottleneck:" in out.stdout
    assert "ff-stage@1" in out.stdout
    # a run-report document renders through the same entry point
    rep_path = str(tmp_path / "report.json")
    prog.last_report.save(rep_path)
    out = _run_cli(rep_path)
    assert out.returncode == 0, out.stderr
    assert "run-report" in out.stdout
    # unknown schema: exit 2, not a traceback
    bad = str(tmp_path / "bad.json")
    with open(bad, "w") as f:
        json.dump({"schema": "nope/9"}, f)
    out = _run_cli(bad)
    assert out.returncode == 2, (out.returncode, out.stderr)


# -- analyzer over a trace (post-mortem attribution) -------------------------
def test_analyze_trace_names_busy_stage():
    prog = lower(SKEW, "threads", trace=True)
    prog(range(N_SKEW))
    rep = analyze(prog.last_trace)
    assert rep.verdict == "compute-bound"
    assert rep.stage == "ff-stage@1", rep.to_json()
