"""Shared-memory SPSC ring: FIFO/lossless invariants, wrap-around,
typed zero-copy slots, batched emit, oversized-payload spill (including
the decode-failure and spill-dir-pinning regressions), EOS identity
across process boundaries, and clean SharedMemory unlink — the procs
backend's edge primitive must be as bulletproof as the in-process ring
it mirrors."""
import glob
import os
import pickle
import tempfile
import threading
import time
import uuid

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import EOS, GO_ON, ShmCounters, ShmFlag, ShmRing, SPSCQueue
from repro.core.spsc import _EOS

_EMPTY = SPSCQueue._EMPTY


@pytest.fixture
def ring():
    r = ShmRing(16, 64)
    yield r
    r.unlink()


# -- the Lamport invariants, now over a SharedMemory segment -----------------
def test_fifo_basic(ring):
    assert ring.pop() is _EMPTY
    for i in range(5):
        assert ring.push(i)
    assert [ring.pop() for _ in range(5)] == list(range(5))
    assert ring.pop() is _EMPTY


def test_capacity_bound_and_reuse(ring):
    pushed = 0
    while ring.push(pushed):
        pushed += 1
    assert pushed == ring.capacity
    assert ring.full() and not ring.push(99)
    assert ring.pop() == 0
    assert ring.push(99)  # slot freed


def test_wraparound_many_cycles():
    r = ShmRing(8, 64)
    try:
        n = 10 * (r.capacity + 1)  # many full trips around the ring
        seen = []
        for i in range(n):
            assert r.push_wait(i, timeout=1)
            if i % 3 == 0:  # drain unevenly so head/tail wrap out of phase
                while True:
                    item = r.pop()
                    if item is _EMPTY:
                        break
                    seen.append(item)
        while True:
            item = r.pop()
            if item is _EMPTY:
                break
            seen.append(item)
        assert seen == list(range(n))
    finally:
        r.unlink()


@given(st.lists(st.tuples(st.booleans(),
                          st.sampled_from(["int", "float", "list"])),
                min_size=1, max_size=60),
       st.integers(2, 16))
@settings(max_examples=25, deadline=None)
def test_interleaved_push_pop_preserves_order_and_values(ops, cap):
    """Arbitrary push/pop interleavings never reorder, lose, or corrupt
    items — for ints, floats and lists (pickled payloads round-trip)."""
    r = ShmRing(cap, 64)
    try:
        mk = {"int": lambda k: k,
              "float": lambda k: k * 0.5,
              "list": lambda k: [k, [k + 1], "x" * (k % 7)]}
        pushed, popped = [], []
        n = 0
        for is_push, kind in ops:
            if is_push:
                item = mk[kind](n)
                if r.push(item):
                    pushed.append(item)
                n += 1
            else:
                item = r.pop()
                if item is not _EMPTY:
                    popped.append(item)
        while True:
            item = r.pop()
            if item is _EMPTY:
                break
            popped.append(item)
        assert popped == pushed
    finally:
        r.unlink()


# -- oversized payloads: the spill side-channel ------------------------------
def test_oversized_payload_spills_and_roundtrips():
    r = ShmRing(8, slot_size=32)
    try:
        big = ["x" * 10_000, list(range(2_000)), "y" * 31, b"z" * 50_000]
        for item in big:
            assert r.push(item)
        spills = glob.glob(os.path.join("/tmp", f"ffshm-{r.name.lstrip('/')}-*"))
        assert spills, "oversized payloads should hit the spill side-channel"
        assert [r.pop() for _ in big] == big
        # consumed spills are deleted eagerly, not left for unlink
        assert not glob.glob(
            os.path.join("/tmp", f"ffshm-{r.name.lstrip('/')}-*"))
    finally:
        r.unlink()


def test_unconsumed_spills_swept_on_unlink():
    r = ShmRing(8, slot_size=16)
    r.push("a" * 1000)
    pattern = os.path.join("/tmp", f"ffshm-{r.name.lstrip('/')}-*")
    assert glob.glob(pattern)
    r.unlink()
    assert not glob.glob(pattern)


# -- typed zero-copy slots ---------------------------------------------------
def test_zero_copy_ndarray_roundtrips_dtype_and_shape():
    np = pytest.importorskip("numpy")
    r = ShmRing(8, slot_size=20_000)
    try:
        arrays = [np.arange(12, dtype=np.float32).reshape(4, 3),
                  np.arange(7, dtype=np.int64),
                  np.ones((2, 3, 4), dtype=np.float64) * 0.5,
                  np.zeros(4096, dtype=np.float32)]  # 16 KiB payload
        for a in arrays:
            assert r.push(a)
        # typed frames never touch the spill side-channel
        assert not glob.glob(
            os.path.join(r.spill_dir, f"ffshm-{r.name.lstrip('/')}-*"))
        for a in arrays:
            out = r.pop()
            assert out.dtype == a.dtype and out.shape == a.shape
            assert np.array_equal(out, a)
            out[...] = 0  # the copy is writable and owned, not a view
    finally:
        r.unlink()


def test_zero_copy_raw_bytes_kinds(ring):
    payloads = [b"hello", bytearray(b"world"), memoryview(b"view-me")]
    for p in payloads:
        assert ring.push(p)
    assert ring.pop() == b"hello"
    out = ring.pop()
    assert isinstance(out, bytearray) and out == bytearray(b"world")
    assert ring.pop() == b"view-me"  # memoryview decodes as bytes


def test_zero_copy_pickle_fallback_for_arbitrary_objects():
    np = pytest.importorskip("numpy")
    r = ShmRing(8, slot_size=4096)
    try:
        items = [{"k": [1, 2]},                          # plain object
                  np.asfortranarray(np.ones((3, 3))),     # non-C-contiguous
                  np.zeros(2, dtype=[("a", "i4")]),       # structured dtype
                  np.float32(1.5),                        # 0-d scalar
                  None]
        for it in items:
            assert r.push(it)
        got = [r.pop() for _ in items]
        assert got[0] == items[0]
        assert np.array_equal(got[1], items[1])
        assert np.array_equal(got[2], items[2])
        assert got[3] == items[3] and got[4] is None
    finally:
        r.unlink()


def test_zero_copy_opt_out_still_roundtrips():
    np = pytest.importorskip("numpy")
    r = ShmRing(8, slot_size=20_000, zero_copy=False)
    try:
        a = np.arange(16, dtype=np.float32)
        assert r.push(a)
        assert np.array_equal(r.pop(), a)
        peer = pickle.loads(pickle.dumps(r))
        assert peer.zero_copy is False  # the flag survives attach
        peer.close()
    finally:
        r.unlink()


# -- batched emit: push_many packs, pop unpacks in order ---------------------
def test_push_many_fifo_and_pending_accounting(ring):
    items = list(range(40))
    got = []
    i = 0
    while i < len(items):
        n = ring.push_many(items[i:])
        i += n
        if n == 0:  # ring full of batch frames: drain one, keep packing
            got.append(ring.pop())
    while not ring.empty():
        got.append(ring.pop())
    assert got == items
    assert ring.pop() is _EMPTY


def test_push_many_preserves_eos_ordering(ring):
    stream = [1, 2, 3, EOS]
    i = 0
    while i < len(stream):  # EOS may start a fresh slot: keep packing
        i += ring.push_many(stream[i:])
    assert [ring.pop() for _ in range(3)] == [1, 2, 3]
    assert ring.pop() is EOS
    assert ring.empty()


def test_push_many_oversized_first_item_falls_back_to_push():
    r = ShmRing(8, slot_size=32, spill_dir=None)
    try:
        big = "x" * 1000  # cannot fit a batch frame: spills via push()
        assert r.push_many([big, 1, 2]) == 1
        assert r.pop() == big
    finally:
        r.unlink()


def test_len_counts_consumer_pending_batch(ring):
    assert ring.push_many([10, 11, 12]) == 3
    assert ring.pop() == 10       # decodes the batch, parks 11/12 pending
    assert len(ring) == 2 and not ring.empty()
    assert ring.pop() == 11 and ring.pop() == 12
    assert ring.empty()


# -- spill regressions: decode failure + spill-dir pinning -------------------
def test_spill_decode_failure_leaves_file_and_ring_recovers(tmp_path):
    r = ShmRing(8, slot_size=64, spill_dir=str(tmp_path))
    try:
        r.push("a" * 500)   # spills
        r.push("next")       # inline behind it
        [path] = glob.glob(str(tmp_path / f"ffshm-{r.name.lstrip('/')}-*"))
        good = open(path, "rb").read()
        with open(path, "wb") as fh:
            fh.write(b"\x80garbage")  # corrupt the spill payload
        with pytest.raises(Exception):
            r.pop()
        # the file survives the failed decode (unlink happens only after a
        # successful loads), so the item is recoverable...
        assert os.path.exists(path)
        with open(path, "wb") as fh:
            fh.write(good)
        assert r.pop() == "a" * 500      # ...and head was never published
        assert not os.path.exists(path)  # consumed spill deleted eagerly
        assert r.pop() == "next"         # the stream continues undamaged
    finally:
        r.unlink()


def test_spill_dir_pinned_at_creation(tmp_path, monkeypatch):
    made = tmp_path / "made-here"
    made.mkdir()
    r = ShmRing(8, slot_size=16, spill_dir=str(made))
    try:
        r.push("b" * 500)
        assert glob.glob(str(made / f"ffshm-{r.name.lstrip('/')}-*"))
        # the consumer's TMPDIR diverges after creation: the attached copy
        # must still resolve spills against the ring's pinned directory
        monkeypatch.setattr(tempfile, "tempdir", str(tmp_path / "elsewhere"))
        peer = pickle.loads(pickle.dumps(r))
        assert peer.spill_dir == str(made)
        assert peer.pop() == "b" * 500
        peer.close()
    finally:
        r.unlink()


def test_spill_dir_survives_cross_process_tmpdir_divergence(
        tmp_path, monkeypatch):
    import multiprocessing as mp
    from _procs_nodes import echo_child
    a, b = ShmRing(8, slot_size=16), ShmRing(8, slot_size=4096)
    # the child spawns with a different TMPDIR; before spill-dir pinning it
    # would look for the parent's spill files in the wrong directory
    child_tmp = tmp_path / "child-tmp"
    child_tmp.mkdir()
    monkeypatch.setenv("TMPDIR", str(child_tmp))
    ctx = mp.get_context("spawn")
    p = ctx.Process(target=echo_child, args=(a, b), daemon=True)
    p.start()
    try:
        assert a.push_wait("c" * 500, timeout=30)  # spills in parent's dir
        assert a.push_wait(EOS, timeout=30)
        assert b.pop_wait(timeout=30) == "c" * 500
        assert b.pop_wait(timeout=30) == ("eos-is-eos", True)
        p.join(30)
        assert p.exitcode == 0
    finally:
        if p.is_alive():
            p.terminate()
        a.unlink()
        b.unlink()


def test_numpy_zero_copy_through_spawned_consumer():
    np = pytest.importorskip("numpy")
    import multiprocessing as mp
    from _procs_nodes import np_sum_child
    a, b = ShmRing(32, 20_000), ShmRing(32, 256)
    ctx = mp.get_context("spawn")
    p = ctx.Process(target=np_sum_child, args=(a, b), daemon=True)
    p.start()
    try:
        arrays = [np.arange(n, dtype=np.float32) for n in (3, 100, 4096)]
        for arr in arrays:
            assert a.push_wait(arr, timeout=30)
        assert a.push_wait(EOS, timeout=30)
        for arr in arrays:
            dt, shape, total = b.pop_wait(timeout=30)
            assert dt == arr.dtype.str and shape == arr.shape
            assert total == float(arr.sum())
        p.join(30)
        assert p.exitcode == 0
    finally:
        if p.is_alive():
            p.terminate()
        a.unlink()
        b.unlink()


# -- blocking helpers honour their deadline ----------------------------------
def test_push_wait_pop_wait_return_within_timeout():
    r = ShmRing(4, 64)
    try:
        while r.push(0):
            pass  # fill the ring
        t0 = time.monotonic()
        assert not r.push_wait(99, timeout=0.2)
        elapsed = time.monotonic() - t0
        assert 0.15 <= elapsed < 1.0, elapsed
        while r.pop() is not _EMPTY:
            pass
        t0 = time.monotonic()
        assert r.pop_wait(timeout=0.2) is _EMPTY
        elapsed = time.monotonic() - t0
        assert 0.15 <= elapsed < 1.0, elapsed
    finally:
        r.unlink()


# -- ShmFlag: the pickle-through-able failure flag ---------------------------
def test_shmflag_set_is_sticky_and_visible_through_attach():
    fl = ShmFlag()
    try:
        assert not fl.is_set()
        peer = pickle.loads(pickle.dumps(fl))
        assert not peer.is_set()
        peer.set()
        peer.set()  # idempotent
        assert fl.is_set()
        peer.close()
    finally:
        fl.unlink()


def test_shmflag_cross_process_set():
    import multiprocessing as mp
    from _procs_nodes import set_flag_child
    fl = ShmFlag()
    try:
        ctx = mp.get_context("spawn")
        p = ctx.Process(target=set_flag_child, args=(fl,), daemon=True)
        p.start()
        p.join(30)
        assert p.exitcode == 0 and fl.is_set()
    finally:
        fl.unlink()


def test_counters_explicit_name_is_honoured():
    name = f"ffctr{uuid.uuid4().hex[:12]}"
    board = ShmCounters(2, name=name)
    try:
        assert board.name == name  # regression: create path ignored name=
        board.add(0, 7)
        peer = pickle.loads(pickle.dumps(board))
        assert peer.get(0) == 7
        peer.close()
    finally:
        board.unlink()


# -- EOS identity across pickling and process boundaries (satellite) ---------
def test_eos_pickle_identity_every_protocol():
    for proto in range(pickle.HIGHEST_PROTOCOL + 1):
        assert pickle.loads(pickle.dumps(EOS, proto)) is EOS, proto
        assert pickle.loads(pickle.dumps(GO_ON, proto)) is GO_ON, proto
    assert _EOS() is EOS


def test_eos_identity_through_spawned_process():
    # the child target lives in _procs_nodes: a spawned child re-imports
    # the defining module, which must not pull in test-only deps
    import multiprocessing as mp
    from _procs_nodes import echo_child
    ctx = mp.get_context("spawn")
    a, b = ShmRing(32, 64), ShmRing(32, 64)
    p = ctx.Process(target=echo_child, args=(a, b), daemon=True)
    p.start()
    try:
        for item in (1, 2.5, [3, "four"], GO_ON, EOS):
            assert a.push_wait(item, timeout=30)
        got = [b.pop_wait(timeout=30) for _ in range(5)]
        assert got == [1, 2.5, [3, "four"],
                       ("go-on-is-go-on", True), ("eos-is-eos", True)]
        p.join(30)
        assert p.exitcode == 0
    finally:
        if p.is_alive():
            p.terminate()
        a.unlink()
        b.unlink()


# -- cross-thread stream (same API surface as SPSCQueue) ---------------------
def test_two_thread_stream_over_shared_memory():
    r = ShmRing(64, 64)
    try:
        n = 2000
        out = []

        def consume():
            while True:
                item = r.pop_wait(timeout=30)
                if item is EOS:
                    return
                out.append(item)

        t = threading.Thread(target=consume)
        t.start()
        for i in range(n):
            assert r.push_wait(i, timeout=30)
        r.push_wait(EOS, timeout=30)
        t.join(30)
        assert out == list(range(n))
        # both endpoints share one object in-process; across processes the
        # attached copy counts its own side (see the pickle test below)
        assert r.pushes == n + 1 and r.pops == n + 1
    finally:
        r.unlink()


# -- lifecycle: pickle-as-attach, unlink-means-gone --------------------------
def test_pickle_roundtrip_attaches_same_segment(ring):
    ring.push("hello")
    peer = pickle.loads(pickle.dumps(ring))
    try:
        assert not peer.owner
        assert peer.capacity == ring.capacity
        assert peer.pop() == "hello"
        assert ring.empty()
    finally:
        peer.close()


def test_unlink_destroys_segment():
    from multiprocessing import shared_memory
    r = ShmRing(8, 64)
    name = r.name
    # a second attach works while the segment lives ...
    probe = shared_memory.SharedMemory(name=name)
    probe.close()
    r.unlink()
    # ... and fails once the owner has unlinked: nothing leaked
    with pytest.raises(FileNotFoundError):
        shared_memory.SharedMemory(name=name)


def test_counters_cross_process_single_writer():
    import multiprocessing as mp
    from _procs_nodes import bump_child
    board = ShmCounters(2)
    try:
        board.add(0, 3)
        ctx = mp.get_context("spawn")
        p = ctx.Process(target=bump_child, args=(board,), daemon=True)
        p.start()
        p.join(30)
        assert p.exitcode == 0
        assert board.get(0) == 3 and board.get(1) == 5
    finally:
        board.unlink()
