"""Shared-memory SPSC ring: FIFO/lossless invariants, wrap-around,
oversized-payload spill, EOS identity across process boundaries, and
clean SharedMemory unlink — the procs backend's edge primitive must be
as bulletproof as the in-process ring it mirrors."""
import glob
import os
import pickle
import threading

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import EOS, GO_ON, ShmCounters, ShmRing, SPSCQueue
from repro.core.spsc import _EOS

_EMPTY = SPSCQueue._EMPTY


@pytest.fixture
def ring():
    r = ShmRing(16, 64)
    yield r
    r.unlink()


# -- the Lamport invariants, now over a SharedMemory segment -----------------
def test_fifo_basic(ring):
    assert ring.pop() is _EMPTY
    for i in range(5):
        assert ring.push(i)
    assert [ring.pop() for _ in range(5)] == list(range(5))
    assert ring.pop() is _EMPTY


def test_capacity_bound_and_reuse(ring):
    pushed = 0
    while ring.push(pushed):
        pushed += 1
    assert pushed == ring.capacity
    assert ring.full() and not ring.push(99)
    assert ring.pop() == 0
    assert ring.push(99)  # slot freed


def test_wraparound_many_cycles():
    r = ShmRing(8, 64)
    try:
        n = 10 * (r.capacity + 1)  # many full trips around the ring
        seen = []
        for i in range(n):
            assert r.push_wait(i, timeout=1)
            if i % 3 == 0:  # drain unevenly so head/tail wrap out of phase
                while True:
                    item = r.pop()
                    if item is _EMPTY:
                        break
                    seen.append(item)
        while True:
            item = r.pop()
            if item is _EMPTY:
                break
            seen.append(item)
        assert seen == list(range(n))
    finally:
        r.unlink()


@given(st.lists(st.tuples(st.booleans(),
                          st.sampled_from(["int", "float", "list"])),
                min_size=1, max_size=60),
       st.integers(2, 16))
@settings(max_examples=25, deadline=None)
def test_interleaved_push_pop_preserves_order_and_values(ops, cap):
    """Arbitrary push/pop interleavings never reorder, lose, or corrupt
    items — for ints, floats and lists (pickled payloads round-trip)."""
    r = ShmRing(cap, 64)
    try:
        mk = {"int": lambda k: k,
              "float": lambda k: k * 0.5,
              "list": lambda k: [k, [k + 1], "x" * (k % 7)]}
        pushed, popped = [], []
        n = 0
        for is_push, kind in ops:
            if is_push:
                item = mk[kind](n)
                if r.push(item):
                    pushed.append(item)
                n += 1
            else:
                item = r.pop()
                if item is not _EMPTY:
                    popped.append(item)
        while True:
            item = r.pop()
            if item is _EMPTY:
                break
            popped.append(item)
        assert popped == pushed
    finally:
        r.unlink()


# -- oversized payloads: the spill side-channel ------------------------------
def test_oversized_payload_spills_and_roundtrips():
    r = ShmRing(8, slot_size=32)
    try:
        big = ["x" * 10_000, list(range(2_000)), "y" * 31, b"z" * 50_000]
        for item in big:
            assert r.push(item)
        spills = glob.glob(os.path.join("/tmp", f"ffshm-{r.name.lstrip('/')}-*"))
        assert spills, "oversized payloads should hit the spill side-channel"
        assert [r.pop() for _ in big] == big
        # consumed spills are deleted eagerly, not left for unlink
        assert not glob.glob(
            os.path.join("/tmp", f"ffshm-{r.name.lstrip('/')}-*"))
    finally:
        r.unlink()


def test_unconsumed_spills_swept_on_unlink():
    r = ShmRing(8, slot_size=16)
    r.push("a" * 1000)
    pattern = os.path.join("/tmp", f"ffshm-{r.name.lstrip('/')}-*")
    assert glob.glob(pattern)
    r.unlink()
    assert not glob.glob(pattern)


# -- EOS identity across pickling and process boundaries (satellite) ---------
def test_eos_pickle_identity_every_protocol():
    for proto in range(pickle.HIGHEST_PROTOCOL + 1):
        assert pickle.loads(pickle.dumps(EOS, proto)) is EOS, proto
        assert pickle.loads(pickle.dumps(GO_ON, proto)) is GO_ON, proto
    assert _EOS() is EOS


def test_eos_identity_through_spawned_process():
    # the child target lives in _procs_nodes: a spawned child re-imports
    # the defining module, which must not pull in test-only deps
    import multiprocessing as mp
    from _procs_nodes import echo_child
    ctx = mp.get_context("spawn")
    a, b = ShmRing(32, 64), ShmRing(32, 64)
    p = ctx.Process(target=echo_child, args=(a, b), daemon=True)
    p.start()
    try:
        for item in (1, 2.5, [3, "four"], GO_ON, EOS):
            assert a.push_wait(item, timeout=30)
        got = [b.pop_wait(timeout=30) for _ in range(5)]
        assert got == [1, 2.5, [3, "four"],
                       ("go-on-is-go-on", True), ("eos-is-eos", True)]
        p.join(30)
        assert p.exitcode == 0
    finally:
        if p.is_alive():
            p.terminate()
        a.unlink()
        b.unlink()


# -- cross-thread stream (same API surface as SPSCQueue) ---------------------
def test_two_thread_stream_over_shared_memory():
    r = ShmRing(64, 64)
    try:
        n = 2000
        out = []

        def consume():
            while True:
                item = r.pop_wait(timeout=30)
                if item is EOS:
                    return
                out.append(item)

        t = threading.Thread(target=consume)
        t.start()
        for i in range(n):
            assert r.push_wait(i, timeout=30)
        r.push_wait(EOS, timeout=30)
        t.join(30)
        assert out == list(range(n))
        # both endpoints share one object in-process; across processes the
        # attached copy counts its own side (see the pickle test below)
        assert r.pushes == n + 1 and r.pops == n + 1
    finally:
        r.unlink()


# -- lifecycle: pickle-as-attach, unlink-means-gone --------------------------
def test_pickle_roundtrip_attaches_same_segment(ring):
    ring.push("hello")
    peer = pickle.loads(pickle.dumps(ring))
    try:
        assert not peer.owner
        assert peer.capacity == ring.capacity
        assert peer.pop() == "hello"
        assert ring.empty()
    finally:
        peer.close()


def test_unlink_destroys_segment():
    from multiprocessing import shared_memory
    r = ShmRing(8, 64)
    name = r.name
    # a second attach works while the segment lives ...
    probe = shared_memory.SharedMemory(name=name)
    probe.close()
    r.unlink()
    # ... and fails once the owner has unlinked: nothing leaked
    with pytest.raises(FileNotFoundError):
        shared_memory.SharedMemory(name=name)


def test_counters_cross_process_single_writer():
    import multiprocessing as mp
    from _procs_nodes import bump_child
    board = ShmCounters(2)
    try:
        board.add(0, 3)
        ctx = mp.get_context("spawn")
        p = ctx.Process(target=bump_child, args=(board,), daemon=True)
        p.start()
        p.join(30)
        assert p.exitcode == 0
        assert board.get(0) == 3 and board.get(1) == 5
    finally:
        board.unlink()
