"""Scheduling-layer + fusion semantics: ordered-output parity across all
placement policies on skewed-grain streams, work-stealing actually
rebalancing, policy-object specs, the ValueError contracts, grain-aware
stage fusion (fewer vertices, identical output, chain semantics), and the
bounded latency reservoir — tier-1 for the pluggable scheduling layer."""
import time

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CostModel, Farm, FarmStats, Feedback, FnNode,
                        FusedNode, GO_ON, EmitMany, OnDemand, Pipeline,
                        RoundRobin, Scheduler, Stage, TaskFarm, WorkStealing,
                        compose, ff_node, fuse, lower, make_scheduler)
from repro.core.graph import StageVertex
from repro.core.skeleton import LatencyReservoir

POLICIES = ("rr", "ondemand", "worksteal", "costmodel")


def _f(x):
    return x * 3 + 1


def _g(x):
    return x - 7


# -- property: ordered parity across every policy on skewed streams ----------
@given(st.lists(st.tuples(st.integers(-1000, 1000), st.integers(0, 3)),
                max_size=50),
       st.integers(1, 4))
@settings(max_examples=6, deadline=None)
def test_policy_parity_ordered_skewed(tasks, nworkers):
    """All four policies produce the SAME ordered output on a stream whose
    per-task grain is skewed (0-3 sleep quanta): placement must never leak
    into ordered-farm semantics."""
    def worker(t):
        x, skew = t
        if skew:
            time.sleep(skew * 0.0002)
        return _f(x)

    want = [_f(x) for x, _ in tasks]
    for pol in POLICIES:
        out = lower(Farm(worker, nworkers, ordered=True, scheduling=pol),
                    "threads")(tasks)
        assert out == want, pol


@given(st.lists(st.integers(-500, 500), max_size=60))
@settings(max_examples=6, deadline=None)
def test_policy_parity_unordered_multiset(xs):
    for pol in POLICIES:
        out = lower(Farm(_f, 3, scheduling=pol), "threads")(xs)
        assert sorted(out) == sorted(_f(x) for x in xs), pol


@given(st.lists(st.integers(0, 60), max_size=24))
@settings(max_examples=4, deadline=None)
def test_policy_parity_feedback_loop(xs):
    """The wrap-around loop terminates by quiescence under every policy —
    including worksteal, whose arbiter-held backlog must count against
    quiescence."""
    def ref(x):
        x = x * 2 + 1
        while x < 64:
            x = x * 2 + 1
        return x

    want = [ref(x) for x in xs]
    for pol in POLICIES:
        fb = Feedback(lambda x: x * 2 + 1, lambda x: x < 64, nworkers=3,
                      scheduling=pol)
        assert lower(fb, "threads")(xs) == want, pol


def test_policy_objects_and_classes_accepted():
    """Farm(scheduling=) takes a name, a Scheduler subclass, or an
    instance; instances are cloned per build (fresh()), so one IR node can
    be lowered repeatedly."""
    xs = list(range(120))
    pol = WorkStealing(ring_fill=1)
    skel = Farm(_f, 3, ordered=True, scheduling=pol)
    prog = lower(skel, "threads")
    assert prog(xs) == [_f(x) for x in xs]
    assert prog(xs) == [_f(x) for x in xs]  # re-run: no leaked state
    assert lower(Farm(_f, 3, ordered=True, scheduling=CostModel), "threads")(
        xs) == [_f(x) for x in xs]
    assert make_scheduler(pol) is not pol  # fresh clone
    assert make_scheduler(pol).ring_fill == 1  # config preserved


def test_unknown_policy_raises_value_error():
    with pytest.raises(ValueError, match="scheduling policy"):
        Farm(_f, 2, scheduling="bogus")
    with pytest.raises(ValueError, match="scheduling policy"):
        Feedback(_f, lambda x: False, scheduling="bogus")
    with pytest.raises(ValueError, match="scheduling policy"):
        TaskFarm(2, scheduling="bogus")
    with pytest.raises(ValueError, match="scheduling"):
        make_scheduler(42)


def test_stage_route_value_error_and_scheduler_routing():
    """StageVertex routes through the same scheduler objects as the farm
    arbiter: unknown routes raise ValueError (not assert), and 'ondemand'
    is a valid stage route."""
    with pytest.raises(ValueError, match="route"):
        StageVertex(FnNode(_f), route="bogus")
    with pytest.raises(ValueError, match="pick"):
        # token-holding policies need the farm dispatch arbiter; a stage
        # route must reject them instead of silently degrading
        StageVertex(FnNode(_f), route="worksteal")
    v = StageVertex(FnNode(_f), route="ondemand")
    assert isinstance(v._sched, OnDemand)
    assert StageVertex(FnNode(_f), route="bcast")._sched is None
    assert isinstance(StageVertex(FnNode(_f))._sched, RoundRobin)


def test_worksteal_rebalances_around_slow_worker():
    """One worker hangs on a slow task; with ring_fill=1 the remaining
    stream stays in the arbiter backlog where idle workers steal it, so
    the slow worker ends up servicing almost nothing else."""
    class Worker(ff_node):
        def __init__(self):
            self.seen = 0

        def svc(self, t):
            self.seen += 1
            if t == 0:
                time.sleep(0.25)
            return t

    workers = [Worker() for _ in range(3)]
    farm = Farm(workers, ordered=True,
                scheduling=WorkStealing(ring_fill=1))
    out = lower(farm, "threads")(range(60))
    assert out == list(range(60))
    assert farm.stats.steals > 0, "stealing never fired"
    assert min(w.seen for w in workers) < 15, \
        f"slow worker was not relieved: {[w.seen for w in workers]}"


def test_worksteal_with_straggler_speculation_dedups():
    """Steals and speculative re-issue compose: duplicates are dropped by
    tag at the merge arbiter no matter which worker serviced them."""
    def sometimes_slow(t):
        if t == 5:
            time.sleep(0.6)
        return t

    farm = Farm(sometimes_slow, 3, ordered=True, scheduling="worksteal",
                speculative=True, straggler_factor=2.0,
                min_straggler_age=0.05)
    assert lower(farm, "threads")(range(30)) == list(range(30))
    assert farm.stats.duplicates_issued >= 1


def test_costmodel_uses_service_time_stats():
    """Workers populate the per-worker service-time EWMA that the
    CostModel policy reads."""
    farm = Farm(_f, 3, ordered=True, scheduling="costmodel")
    assert lower(farm, "threads")(range(90)) == [_f(x) for x in range(90)]
    assert farm.stats.service_ewma, "workers must record service EWMAs"
    assert all(v >= 0.0 for v in farm.stats.service_ewma.values())


# -- grain-aware fusion -------------------------------------------------------
def test_fusion_fewer_vertices_identical_output():
    """Acceptance: a fused Pipeline(Stage, Stage) spawns fewer vertices
    yet produces identical output."""
    skel = Pipeline(Stage(_f, grain=1), Stage(_g, grain=1))
    xs = list(range(300))
    want = [_g(_f(x)) for x in xs]
    unfused = lower(skel, "threads", fuse=False)
    fused = lower(skel, "threads", fuse="auto", fuse_threshold_us=1e9)
    assert unfused(xs) == fused(xs) == want
    assert len(fused.to_graph(xs).vertices) < len(unfused.to_graph(xs).vertices)


def test_fusion_respects_grain_threshold():
    """Stages at or above the threshold (or with no declared grain) are
    left alone by auto mode."""
    coarse = Pipeline(Stage(_f, grain=500), Stage(_g, grain=500))
    assert isinstance(fuse(coarse, threshold_us=10.0), Pipeline)
    nograin = Pipeline(Stage(_f), Stage(_g))
    assert isinstance(fuse(nograin, threshold_us=10.0), Pipeline)
    fine = Pipeline(Stage(_f, grain=1), Stage(_g, grain=1))
    assert isinstance(fuse(fine, threshold_us=10.0), Stage)
    # merged grain is the sum, so a long run stops merging once coarse
    run = Pipeline(*[Stage(_f, grain=6) for _ in range(4)])
    fused = fuse(run, threshold_us=10.0)
    assert isinstance(fused, Pipeline) and len(fused.stages) == 2


def test_fusion_farm_absorbs_trailing_stage():
    skel = Pipeline(Farm(_f, 3, ordered=True), Stage(_g, grain=1))
    xs = list(range(200))
    want = [_g(_f(x)) for x in xs]
    unfused = lower(skel, "threads", fuse=False)
    fused = lower(skel, "threads", fuse=True)
    assert unfused(xs) == fused(xs) == want
    assert len(fused.to_graph(xs).vertices) \
        == len(unfused.to_graph(xs).vertices) - 1


def test_fusion_never_absorbs_into_feedback_or_collector_farms():
    """A wrap-around farm would re-apply the stage every loop trip; a
    collector node would run on the wrong side of the stage.  Both stay
    unfused even under force."""
    def route(res):
        x, d = res
        return (x, []) if d == 0 else (None, [(x, d - 1)])

    fb = Pipeline(Farm(lambda t: t, 2, feedback=route), Stage(_f, grain=1))
    assert isinstance(fuse(fb, force=True), Pipeline)
    coll = Pipeline(Farm(_f, 2, ordered=True, collector=FnNode(_g)),
                    Stage(_g, grain=1))
    assert isinstance(fuse(coll, force=True), Pipeline)

    class Stateful(ff_node):
        def svc(self, t):
            return t

    st_skel = Pipeline(Farm(_f, 2, ordered=True), Stage(Stateful(), grain=1))
    assert isinstance(fuse(st_skel, force=True), Pipeline)


def test_fused_node_chain_semantics():
    """GO_ON / None filtering and EmitMany flattening behave exactly as
    the separate vertices would."""
    keep_even = lambda x: x if x % 2 == 0 else GO_ON
    dup = lambda x: EmitMany([x, x + 100])
    skel = Pipeline(Stage(keep_even, grain=1), Stage(dup, grain=1),
                    Stage(_f, grain=1))
    xs = list(range(20))
    unfused = lower(skel, "threads", fuse=False)
    fused = lower(skel, "threads", fuse=True)
    out_u, out_f = unfused(xs), fused(xs)
    assert out_u == out_f
    assert out_f == [_f(v) for x in xs if x % 2 == 0 for v in (x, x + 100)]


def test_fused_none_filters_do_not_diverge():
    """None mid-pipeline filters one item on every path: in a fused stage
    chain (a later node's None must NOT end the stream in source position)
    and through a farm-absorbed tail (the merge arbiter delivers non-GO_ON
    payloads, so the fused tail must filter its own Nones)."""
    # farm + trailing None-filtering stage
    skel = Pipeline(Farm(lambda x: x * 2, 2, ordered=True),
                    Stage(lambda x: x if x % 4 == 0 else None, grain=1))
    xs = list(range(6))
    assert lower(skel, "threads", fuse=False)(xs) \
        == lower(skel, "threads", fuse=True)(xs) == [0, 4, 8]

    # fused source: the generator's None is EOS, the filter's None is not
    def src():  # fresh generator state per lowering
        it = iter(range(5))
        return Pipeline(Stage(lambda _: next(it, None), grain=1),
                        Stage(lambda x: x if x != 2 else None, grain=1))

    want = [0, 1, 3, 4]
    assert lower(src(), "threads", fuse=False).to_graph().run_and_wait() == want
    assert lower(src(), "threads", fuse=True).to_graph().run_and_wait() == want


def test_double_absorbed_stages_keep_emit_many_flattening():
    """Two stages absorbed into one farm: EmitMany between the absorbed
    stages still flattens (stage-to-stage semantics inside the tail)."""
    skel = Pipeline(Farm(_f, 2, ordered=True),
                    Stage(lambda x: EmitMany([x, -x]), grain=1),
                    Stage(lambda x: x + 1000, grain=1))
    xs = list(range(10))
    un = lower(skel, "threads", fuse=False)
    fu = lower(skel, "threads", fuse=True)
    assert un(xs) == fu(xs)
    assert len(fu.to_graph(xs).vertices) \
        == len(un.to_graph(xs).vertices) - 2


def test_worksteal_backlog_bounded_by_high_water():
    """The arbiter-side backlog must not buffer an unbounded stream: with
    slow workers, pending() stays at or below the policy's high-water mark
    while the source blocks behind it."""
    import threading
    from repro.core.graph import DispatchVertex

    pol = WorkStealing(ring_fill=2)
    seen_pending = []
    orig = DispatchVertex._dispatch

    def spy(self, task):
        orig(self, task)
        seen_pending.append(self.sched.pending())

    farm = Farm(lambda x: (time.sleep(0.0005), x)[1], 2,
                scheduling=pol, capacity=4)
    DispatchVertex._dispatch = spy
    try:
        out = lower(farm, "threads")(range(3000))
    finally:
        DispatchVertex._dispatch = orig
    assert sorted(out) == list(range(3000))
    hw = max(64, 8 * 2 * 2)
    assert max(seen_pending) <= hw, \
        f"backlog exceeded high water: {max(seen_pending)} > {hw}"


def test_fused_node_lifecycle_hooks_run_once_each():
    calls = []

    class N(ff_node):
        def __init__(self, tag):
            self.tag = tag

        def svc_init(self):
            calls.append(("init", self.tag))

        def svc(self, t):
            return t

        def svc_end(self):
            calls.append(("end", self.tag))

    skel = Pipeline(Stage(N("a"), grain=1), Stage(N("b"), grain=1))
    assert lower(skel, "threads", fuse=True)([1, 2]) == [1, 2]
    assert calls == [("init", "a"), ("init", "b"), ("end", "b"), ("end", "a")]


def test_fusion_auto_calibration_is_cached():
    from repro.core.sched import calibrate_handoff_us
    a = calibrate_handoff_us(ntasks=300, force=True)
    b = calibrate_handoff_us()
    assert a == b and a > 0.0


# -- bounded latency reservoir ------------------------------------------------
def test_latency_reservoir_bounded_with_correct_p95():
    stats = FarmStats()
    assert stats.p95_latency() == 0.0  # empty sample is safe
    for i in range(10_000):
        stats.latencies.append(float(i))
    assert len(stats.latencies) <= 2048, "reservoir must be bounded"
    assert stats.latencies.count == 10_000
    # the window holds the most recent values, so p95 is near the top
    assert 10_000 - 2048 <= stats.p95_latency() < 10_000

    small = LatencyReservoir(cap=4)
    for v in (1.0, 2.0):
        small.append(v)
    assert sorted(small) == [1.0, 2.0]
    for v in (3.0, 4.0, 5.0, 6.0):
        small.append(v)
    assert sorted(small) == [3.0, 4.0, 5.0, 6.0]  # oldest overwritten


def test_long_farm_run_keeps_latency_sample_bounded():
    farm = Farm(lambda x: x, 2, ordered=True)
    n = 6_000
    assert lower(farm, "threads")(range(n)) == list(range(n))
    assert farm.stats.tasks_collected == n
    assert len(farm.stats.latencies) <= 2048
    assert farm.stats.latencies.count == n


# -- CPU placement hints (consumed by the procs backend's vertices) ----------
def test_spread_cpus_partitions_the_affinity_set():
    import os
    from repro.core import spread_cpus
    cpus = sorted(os.sched_getaffinity(0))
    n = min(2, len(cpus))
    shares = [spread_cpus(i, n) for i in range(n)]
    assert all(s for s in shares)
    flat = sorted(c for s in shares for c in s)
    assert flat == cpus  # disjoint shares that cover every allowed CPU
    # more workers than CPUs: each still gets one CPU, wrapping around
    one = spread_cpus(0, len(cpus) + 5)
    assert one is not None and len(one) == 1 and one[0] in cpus


def test_worker_cpus_gated_by_pin_cpus():
    from repro.core import spread_cpus

    class Pinning(Scheduler):
        pin_cpus = True

        def route(self, nworkers, task, stats):
            return 0

    assert Scheduler.pin_cpus is False
    assert RoundRobin().worker_cpus(0, 2) is None  # hints are opt-in
    assert Pinning().worker_cpus(1, 2) == spread_cpus(1, 2)
