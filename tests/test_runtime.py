"""Runtime substrate: streaming pipeline determinism, async checkpointing,
restore-with-reshard (elastic), fault-tolerant restart, optimizer,
compression, serving engine."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.data import SyntheticLM, make_batch_stream
from repro.launch.serve import Request, ServeEngine
from repro.launch.train import train
from repro.optim import (adamw_init, adamw_update, cosine_schedule,
                         int8_dequantize, int8_quantize)
from repro.runtime.checkpoint import (AsyncCheckpointer, latest_step, restore,
                                      save_sync)


# -- data pipeline -----------------------------------------------------------
def test_pipeline_deterministic_replay():
    cfg = ARCHS["phi3-mini-3.8b"].smoke()
    src = SyntheticLM(cfg, batch=2, seq=8, seed=42)
    a = src(7)
    b = src(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # stream from step 3 matches direct source calls
    pipe = make_batch_stream(cfg, 2, 8, seed=42, start_step=3, n_steps=4)
    got = list(pipe)
    assert [s for s, _ in got] == [3, 4, 5, 6]
    np.testing.assert_array_equal(got[0][1]["tokens"], src(3)["tokens"])


def test_pipeline_labels_are_shifted_tokens():
    cfg = ARCHS["phi3-mini-3.8b"].smoke()
    b = SyntheticLM(cfg, batch=2, seq=8, seed=0)(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# -- checkpointing ------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    state = {"w": jnp.arange(12.0).reshape(3, 4),
             "nested": {"b": jnp.ones((5,), jnp.bfloat16)},
             "step": jnp.int32(7)}
    save_sync(state, 7, str(tmp_path))
    assert latest_step(str(tmp_path)) == 7
    got = restore(state, str(tmp_path))
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(state["w"]))
    assert got["nested"]["b"].dtype == jnp.bfloat16


def test_async_checkpointer_writes_and_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in [10, 20, 30, 40]:
        ck.save({"x": jnp.full((4,), s)}, s)
    ck.wait()
    ck.close()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [30, 40]  # older ones garbage-collected
    got = restore({"x": jnp.zeros((4,))}, str(tmp_path))
    np.testing.assert_array_equal(np.asarray(got["x"]), np.full((4,), 40.0))


def test_restore_onto_different_mesh_shape(tmp_path):
    """Elastic restart: save unsharded, restore with explicit sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.compat import make_mesh
    state = {"w": jnp.arange(8.0)}
    save_sync(state, 1, str(tmp_path))
    mesh = make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data"))}
    got = restore(state, str(tmp_path), shardings=sh)
    assert got["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(8.0))


# -- fault tolerance: end-to-end train with injected failure -------------------
def test_train_restarts_from_checkpoint_after_failure(tmp_path):
    cfg = ARCHS["mamba2-130m"].smoke()
    # run A: uninterrupted 20 steps
    _, losses_a = train(cfg, steps=20, batch=2, seq=16, ckpt_dir=None, seed=3)
    # run B: fails at step 12, restarts from ckpt at 10, finishes
    ckpt = str(tmp_path / "ck")
    try:
        train(cfg, steps=20, batch=2, seq=16, ckpt_dir=ckpt, ckpt_every=10,
              seed=3, inject_failure_at=12)
    except RuntimeError:
        pass
    assert latest_step(ckpt) is not None
    _, losses_b = train(cfg, steps=20, batch=2, seq=16, ckpt_dir=ckpt,
                        ckpt_every=10, seed=3)
    # deterministic pipeline + restore ⇒ identical final loss
    np.testing.assert_allclose(losses_a[-1], losses_b[-1], rtol=1e-4)


def test_train_loss_decreases_on_learnable_data():
    """A tiny model memorises a repeating synthetic stream."""
    cfg = ARCHS["phi3-mini-3.8b"].smoke().replace(vocab_size=64)
    class Repeat:
        def __call__(self, step):
            rng = np.random.default_rng(0)  # SAME batch every step
            t = rng.integers(0, 64, (4, 17), dtype=np.int32)
            return {"tokens": t[:, :-1], "labels": t[:, 1:]}
    from repro.launch import train as T
    import repro.data as D
    orig = D.make_batch_stream
    state, losses = None, None
    from repro.data.pipeline import StreamingPipeline
    pipe_src = Repeat()
    # run the loop manually (no monkeypatching train internals)
    import jax
    from repro.models import init_params
    from repro.optim import adamw_init
    from repro.launch.steps import make_train_step
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, peak_lr=5e-3, warmup=5, total_steps=60))
    losses = []
    for i in range(60):
        b = jax.tree.map(jnp.asarray, pipe_src(i))
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])


# -- optimizer / schedules / compression ---------------------------------------
def test_adamw_moves_toward_minimum():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw of w^2
        params, opt, _ = adamw_update(params, grads, opt, lr=jnp.float32(0.05),
                                      weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_cosine_schedule_shape():
    s = cosine_schedule(jnp.arange(100), peak_lr=1.0, warmup_steps=10,
                        total_steps=100, min_ratio=0.1)
    assert float(s[0]) == 0.0
    assert abs(float(s[10]) - 1.0) < 0.11
    assert float(s[99]) < 0.2
    assert np.all(np.asarray(s) >= 0)


def test_int8_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3
    q, s = int8_quantize(x)
    back = int8_dequantize(q, s, x.shape, x.dtype)
    err = np.abs(np.asarray(back - x))
    assert err.max() <= float(np.abs(np.asarray(x)).max()) / 127.0 + 1e-6


def test_bf16_moments_halve_optimizer_bytes():
    params = {"w": jnp.zeros((1024,), jnp.bfloat16)}
    o32 = adamw_init(params, jnp.float32)
    o16 = adamw_init(params, jnp.bfloat16)
    assert o32.mu["w"].dtype == jnp.float32 and o16.mu["w"].dtype == jnp.bfloat16


# -- serving farm ---------------------------------------------------------------
def test_serve_engine_order_and_isolation():
    cfg = ARCHS["phi3-mini-3.8b"].smoke()
    eng = ServeEngine(cfg, max_batch=3, max_len=128, seed=0)
    rng = np.random.default_rng(1)
    prompts = [list(rng.integers(0, cfg.vocab_size, int(rng.integers(2, 6))))
               for _ in range(7)]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=5))
    results = eng.run()
    assert len(results) == 7
    assert [r.tag for r in results] == list(range(7))  # order-preserving
    assert all(len(r.generated) == 5 for r in results)
    # isolation: a request's output depends only on its own prompt —
    # resubmit prompt 0 alone and compare
    eng2 = ServeEngine(cfg, max_batch=3, max_len=128, seed=0)
    eng2.submit(Request(rid=0, prompt=prompts[0], max_new=5))
    solo = eng2.run()[0]
    batched = next(r for r in results if r.rid == 0)
    assert solo.generated == batched.generated


def test_serve_engine_resumes_after_truncated_run():
    """A run() cut short by max_steps strands its batch mid-generation; a
    later run() with no new submissions must seed a tick and finish it
    (the old driver loop's `while self.active` behaviour)."""
    cfg = ARCHS["phi3-mini-3.8b"].smoke()
    eng = ServeEngine(cfg, max_batch=2, max_len=128, seed=0)
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new=4))
    assert eng.run(max_steps=2) == []      # budget exhausted mid-prompt
    results = eng.run()
    assert len(results) == 1 and len(results[0].generated) == 4


def test_serve_engine_recycles_slots():
    cfg = ARCHS["phi3-mini-3.8b"].smoke()
    eng = ServeEngine(cfg, max_batch=2, max_len=200)
    for i in range(6):  # 6 requests through 2 slots
        eng.submit(Request(rid=i, prompt=[1, 2, 3], max_new=4))
    results = eng.run()
    assert len(results) == 6
    assert eng.pool.allocated == 6


def test_serve_engine_slo_monitor_alerts():
    """An impossible p99 budget must fire exactly one latched latency
    alert for the run, stamp it on the engine's trace as an ``alert``
    instant, and land the slo.alerts counter in last_report."""
    from repro.core import SLOMonitor
    cfg = ARCHS["phi3-mini-3.8b"].smoke()
    slo = SLOMonitor(p99_us=0.001)           # any real request breaches
    eng = ServeEngine(cfg, max_batch=2, max_len=128, seed=0, slo=slo)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=[1, 2, 3], max_new=4))
    results = eng.run()
    assert len(results) == 3
    assert [e["signal"] for e in slo.events] == ["p99_latency_us"]
    assert eng.last_report.counters["slo.alerts"] == 1
    assert eng.last_trace is not None
    lane = next(vt for vt in eng.last_trace.lanes
                if vt.qualname == "slo-monitor")
    assert any(e[0] == "alert" for e in lane.events)
