"""SPSC queue: FIFO/lossless invariants, single-threaded + threaded +
hypothesis property tests (the paper's core primitive must be bulletproof)."""
import threading

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import EOS, LockQueue, SPSCQueue


@pytest.mark.parametrize("qcls", [SPSCQueue, LockQueue])
def test_fifo_basic(qcls):
    q = qcls(8)
    assert q.pop() is SPSCQueue._EMPTY
    for i in range(5):
        assert q.push(i)
    assert [q.pop() for _ in range(5)] == list(range(5))
    assert q.pop() is SPSCQueue._EMPTY


def test_capacity_bound():
    q = SPSCQueue(4)  # rounds to 8 slots, 7 usable
    pushed = 0
    while q.push(pushed):
        pushed += 1
    assert pushed == q.capacity
    assert not q.push(99)
    assert q.pop() == 0
    assert q.push(99)  # slot freed


@given(st.lists(st.integers(2, 40), min_size=1, max_size=60),
       st.integers(2, 16))
@settings(max_examples=50, deadline=None)
def test_interleaved_push_pop_preserves_order(ops, cap):
    """Arbitrary interleaving of pushes/pops never reorders or loses items."""
    q = SPSCQueue(cap)
    pushed, popped = [], []
    n = 0
    for op in ops:
        if op % 2 == 0:
            if q.push(n):
                pushed.append(n)
            n += 1
        else:
            item = q.pop()
            if item is not SPSCQueue._EMPTY:
                popped.append(item)
    while True:
        item = q.pop()
        if item is SPSCQueue._EMPTY:
            break
        popped.append(item)
    assert popped == pushed


@pytest.mark.parametrize("qcls", [SPSCQueue, LockQueue])
def test_two_thread_stream(qcls):
    """1 producer + 1 consumer threads: every item arrives once, in order."""
    q = qcls(64)
    n = 5000
    out = []

    def produce():
        for i in range(n):
            q.push_wait(i)
        q.push_wait(EOS)

    def consume():
        while True:
            item = q.pop_wait()
            if item is EOS:
                return
            out.append(item)

    t1 = threading.Thread(target=produce)
    t2 = threading.Thread(target=consume)
    t1.start(); t2.start(); t1.join(10); t2.join(10)
    assert out == list(range(n))
    assert q.pushes == n + 1 and q.pops == n + 1


# -- the shared backoff helper: deadline before sleep, truncated sleeps ------
def test_backoff_deadline_checked_before_sleeping():
    from repro.core.spsc import Backoff
    b = Backoff()
    for _ in range(Backoff.SPINS):
        assert b.pause(deadline=None) or True  # burn the spin phase
    import time
    t0 = time.monotonic()
    assert not b.pause(deadline=t0 - 1.0)  # expired: no sleep, just False
    assert time.monotonic() - t0 < 0.05


def test_backoff_sleeps_are_truncated_to_the_deadline():
    from repro.core.spsc import Backoff
    import time
    b = Backoff()
    deadline = time.monotonic() + 0.05
    while b.pause(deadline):
        pass
    # the last sleep is min(delay, remaining): total overshoot stays tiny
    assert time.monotonic() - deadline < 0.1


def test_push_wait_pop_wait_return_within_timeout():
    import time
    q = SPSCQueue(4)
    while q.push(0):
        pass
    t0 = time.monotonic()
    assert not q.push_wait(99, timeout=0.2)
    assert 0.15 <= time.monotonic() - t0 < 1.0
    while q.pop() is not SPSCQueue._EMPTY:
        pass
    t0 = time.monotonic()
    assert q.pop_wait(timeout=0.2) is SPSCQueue._EMPTY
    assert 0.15 <= time.monotonic() - t0 < 1.0
