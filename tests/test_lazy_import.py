"""Import hygiene for the host runtime (PR-4's PEP 562 lazy loading).

The procs backend spawns one process per vertex and every child imports
``repro.core`` cold; pulling jax (seconds of XLA start-up) into that path
would silently multiply spawn cost by every vertex in every run.  These
tests pin, via a *subprocess* (the parent test process has long since
imported jax), that the host-side surface — ``repro.core`` and the whole
all-to-all/stream_ops layer — never imports jax as a side effect."""
import os
import subprocess
import sys

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_isolated(code: str) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_core_import_stays_jax_free():
    _run_isolated(
        "import sys; import repro.core; "
        "assert 'jax' not in sys.modules, 'repro.core imported jax'")


def test_a2a_and_stream_ops_import_stays_jax_free():
    """The new subsystem must keep the same discipline: the mesh program
    class is importable, but jax loads only when it is instantiated."""
    _run_isolated(
        "import sys; "
        "from repro.core import A2AMeshProgram, AllToAll, reduce_by_key, "
        "partition_by, window, KeyAffinity; "
        "import repro.core.a2a, repro.core.stream_ops; "
        "assert 'jax' not in sys.modules, 'a2a/stream_ops imported jax'")


def test_oocore_stays_jax_free():
    """The out-of-core layer ships to every spawned vertex (SpillFold
    partitions, combining readers): importing it, building a budgeted
    shard_reduce, and running a spill-forced fold must never load jax."""
    _run_isolated(
        "import sys\n"
        "from repro.core import (KeyBatch, MemoryBudget, SpillFold, "
        "shard_reduce, shard_source, rekey_reduce)\n"
        "import repro.core.oocore\n"
        "def key(r): return r[0]\n"
        "sf = SpillFold(abs, max, budget=MemoryBudget(400))\n"
        "for x in range(-200, 200): sf.svc(x)\n"
        "out = [kv for chunk in sf.svc_eos() for kv in chunk]\n"
        "assert len(out) == 201 and sf._dir is None, (len(out), sf._dir)\n"
        "assert 'jax' not in sys.modules, 'oocore imported jax'")


def test_autotune_stays_jax_free():
    """The self-tuning loop is host-side: profiling a pilot, retuning the
    IR, and replaying on threads must never load jax (plan_mesh is the
    only device-aware entry point and imports it lazily)."""
    _run_isolated(
        "import sys\n"
        "from repro.core import (Pipeline, Stage, TunedProgram, lower, "
        "profile, retune)\n"
        "import repro.core.autotune\n"
        "def f(x): return x + 1\n"
        "def g(x): return x * 2\n"
        "skel = Pipeline(Stage(f, grain=10000), Stage(g, grain=10000))\n"
        "prof = profile(skel, range(64))\n"
        "tuned = lower(retune(skel, prof), 'threads', fuse=False)\n"
        "assert tuned(range(10)) == [(x + 1) * 2 for x in range(10)]\n"
        "tp = lower(skel, 'threads', tune=True, tune_pilot=16)\n"
        "assert tp(range(40)) == [(x + 1) * 2 for x in range(40)]\n"
        "assert 'jax' not in sys.modules, 'autotune imported jax'")


def test_obs_stays_jax_free():
    """The observability layer rides in every spawned vertex (child-side
    VertexTracer construction) and in the eager ``repro.core`` surface:
    importing it, tracing a lowered run, and exporting Chrome JSON must
    never load jax."""
    _run_isolated(
        "import sys\n"
        "import repro.core.obs\n"
        "from repro.core import Farm, MetricsRegistry, Tracer, lower\n"
        "def f(x): return x + 1\n"
        "prog = lower(Farm(f, nworkers=2), 'threads', trace=True, "
        "metrics=True)\n"
        "out = prog(range(50))\n"
        "assert sorted(out) == list(range(1, 51)), out\n"
        "doc = prog.last_trace.to_chrome_json()\n"
        "assert doc['traceEvents'], 'empty trace'\n"
        "assert prog.last_report.farms, 'no farm stats in report'\n"
        "assert 'jax' not in sys.modules, 'obs/tracing imported jax'")


def test_ir_construction_stays_jax_free():
    """Building and thread-lowering a keyed reduction — the exact work a
    spawned vertex's unpickle path does — must not touch jax either."""
    _run_isolated(
        "import sys\n"
        "from repro.core import lower, reduce_by_key\n"
        "def mod(x): return x % 3\n"
        "out = dict(lower(reduce_by_key(mod, 'sum', nright=2), "
        "'threads')(range(10)))\n"
        "assert out == {0: 18, 1: 12, 2: 15}, out\n"
        "assert 'jax' not in sys.modules, 'thread lowering imported jax'")


def test_monitor_stays_jax_free():
    """The live-monitoring layer is eagerly imported by ``repro.core``
    and its sampler thread rides inside monitored host runs: importing
    it, monitoring a threads run, analyzing the timeline and rendering
    the report must never load jax."""
    _run_isolated(
        "import sys\n"
        "import repro.core.monitor\n"
        "from repro.core import Farm, Monitor, Pipeline, analyze, lower\n"
        "def f(x): return x + 1\n"
        "mon = Monitor(interval_s=0.001)\n"
        "prog = lower(Pipeline(f, Farm(f, nworkers=2)), 'threads', "
        "monitor=mon)\n"
        "out = prog(range(80))\n"
        "assert sorted(out) == [x + 2 for x in range(80)], out[:5]\n"
        "assert mon.timeline.frames(), 'monitor sampled nothing'\n"
        "rep = analyze(mon.timeline)\n"
        "assert rep.render(), 'empty report render'\n"
        "assert 'jax' not in sys.modules, 'monitor imported jax'")
