"""Multi-device semantics tests.

Device count is locked at first jax init, so these run in SUBPROCESSES with
XLA_FLAGS=--xla_force_host_platform_device_count=8 while the main pytest
session keeps the real single CPU device.  All mesh/shard_map construction
goes through ``repro.compat`` so the same code runs on pinned 0.4.x JAX
(no ``AxisType``, no top-level ``jax.shard_map``) and on newer releases.
"""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Genuinely environment-dependent, not version-dependent: the subprocesses
# force 8 *host* (CPU) devices, which only takes effect when the CPU backend
# is the default — on a GPU/TPU container jax would pick that backend and
# the (8,) meshes would want 8 physical accelerators.
pytestmark = pytest.mark.skipif(
    jax.default_backend() != "cpu",
    reason="subprocess tests force 8 host devices via "
           "--xla_force_host_platform_device_count, which only applies to "
           f"the CPU backend (default backend here: {jax.default_backend()!r})",
)


def run_sub(code: str, timeout=560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["REPRO_DRYRUN_WIRE"] = "f16"
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert p.returncode == 0, f"stdout:\n{p.stdout}\nstderr:\n{p.stderr[-3000:]}"
    return p.stdout


def test_dispatch_combine_roundtrip_and_ring_equivalence():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.core import dispatch, combine
        mesh = make_mesh((8,), ("w",))
        items = jnp.arange(64*4, dtype=jnp.float32).reshape(64, 4)
        dest = (jnp.arange(64) * 7 % 8).astype(jnp.int32)
        def f(backend):
            def body(it, de):
                recv, info = dispatch(it, de, "w", capacity=16, backend=backend)
                return combine(recv * 2.0, info, "w", backend=backend)
            return jax.jit(shard_map(body, mesh=mesh, in_specs=(P("w"), P("w")),
                                         out_specs=P("w")))(items, dest)
        a2a = np.asarray(f("a2a")); ring = np.asarray(f("ring"))
        np.testing.assert_allclose(a2a, np.asarray(items)*2.0)
        np.testing.assert_allclose(ring, a2a)
        print("dispatch ok")
    """)


def test_moe_sharded_matches_single_device_oracle():
    """The full-manual sharded MoE (ep and tp layouts) must equal the
    single-device dense oracle."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.config import ModelConfig
        from repro.models.moe import moe_apply, moe_init
        from repro.models import model as M
        from repro.parallel.context import mesh_context
        from repro.launch.mesh import make_test_mesh

        for E, name in [(8, "ep"), (6, "tp")]:
            cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                              n_heads=4, n_kv_heads=4, d_ff=16, vocab_size=64,
                              n_experts=E, top_k=2, capacity_factor=8.0,
                              pad_heads_to=0, pad_vocab_to=0, dtype="float32")
            params = {"moe": moe_init(jax.random.PRNGKey(0), cfg),
                      "norm2": jnp.ones((32,), jnp.float32)}
            x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32), jnp.float32)
            from repro.models.layers import rms_norm
            hn = rms_norm(x, params["norm2"], cfg.norm_eps)
            want, _ = moe_apply(hn, params["moe"], cfg,
                                axis_name=None, backend="dense")
            want = x + want
            mesh = make_test_mesh(2, 4)
            with mesh_context(mesh):
                got, aux = jax.jit(lambda xx, pp: M._ffn_part(pp, xx, cfg))(x, params)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=1e-4, rtol=1e-4)
            print("moe", name, "ok")
    """)


def test_sharded_loss_matches_single_device():
    """Same params/batch: loss on a 2×4 mesh == loss on 1 device."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS
        from repro.models import init_params, loss_fn
        from repro.parallel.context import mesh_context
        from repro.launch.mesh import make_test_mesh
        for arch in ["phi3-mini-3.8b", "mixtral-8x7b", "mamba2-130m"]:
            cfg = ARCHS[arch].smoke().replace(capacity_factor=8.0)
            params = init_params(cfg, jax.random.PRNGKey(0))
            batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size),
                     "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0, cfg.vocab_size)}
            l1, _ = jax.jit(lambda p, b: loss_fn(p, b, cfg))(params, batch)
            mesh = make_test_mesh(2, 4)
            with mesh_context(mesh):
                l8, _ = jax.jit(lambda p, b: loss_fn(p, b, cfg))(params, batch)
            np.testing.assert_allclose(float(l1), float(l8), rtol=3e-3), arch
            print(arch, float(l1), float(l8), "ok")
    """)


def test_pipeline_skeleton_and_grads():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.core import pipeline_apply, pipeline_utilisation
        mesh = make_mesh((8,), ("stage",))
        M, mb, d = 5, 2, 3
        params = jnp.arange(8, dtype=jnp.float32).reshape(8, 1, 1)
        xs = jnp.ones((M, mb, d))
        def pipe(pl, x):
            return pipeline_apply(lambda p, v: v + p[0], pl, x, axis_name="stage")
        f = jax.jit(shard_map(pipe, mesh=mesh, in_specs=(P("stage"), P()), out_specs=P()))
        out = np.asarray(f(params, xs))
        np.testing.assert_allclose(out, np.full((M, mb, d), 1 + sum(range(8))))
        g = jax.jit(jax.grad(lambda p: jnp.sum(shard_map(pipe, mesh=mesh,
            in_specs=(P("stage"), P()), out_specs=P())(p, xs))))(params)
        np.testing.assert_allclose(np.asarray(g).ravel(), [M*mb*d]*8)
        assert abs(pipeline_utilisation(8, 5) - 5/12) < 1e-9
        print("pipeline ok")
    """)


def test_skeleton_mesh_lowering_nests_pipeline_over_farms():
    """With 8 devices and a 2-stage skeleton, negotiate_stage_axis gives a
    (2, 4) mesh and lower(..., "mesh") streams microbatches through
    pipeline_apply with a farm_map per stage row — the genuinely nested
    device-flavour composition — and still matches the threads backend."""
    run_sub("""
        from repro.core import Farm, Feedback, Pipeline, lower
        f = lambda x: x * 3 + 1
        g = lambda x: x - 7
        skel = Pipeline(Farm(f, 4, ordered=True), Farm(g, 4, ordered=True))
        prog = lower(skel, "mesh", grain=8)
        assert (prog.n_stage, prog.n_worker) == (2, 4), \\
            (prog.n_stage, prog.n_worker)
        xs = list(range(-50, 163))
        out = prog(xs)
        assert out == [g(f(x)) for x in xs] == lower(skel, "threads")(xs)
        fb = Feedback(lambda x: x * 2 + 1, lambda x: x < 64, max_trips=32)
        pfb = lower(fb, "mesh")
        assert pfb.n_worker == 8
        assert pfb(list(range(40))) == lower(fb, "threads")(list(range(40)))
        print("skeleton mesh nested ok")
    """)


def test_ring_attention_matches_reference():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.parallel.ring_attention import ring_attention
        from repro.kernels.ref import attention_ref
        mesh = make_mesh((8,), ("sp",))
        B, S, H, D = 2, 64, 4, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, S, H, D))
        k = jax.random.normal(ks[1], (B, S, H, D))
        v = jax.random.normal(ks[2], (B, S, H, D))
        f = jax.jit(shard_map(
            lambda q, k, v: ring_attention(q, k, v, axis_name="sp", causal=True),
            mesh=mesh, in_specs=(P(None, "sp"), P(None, "sp"), P(None, "sp")),
            out_specs=P(None, "sp")))
        got = np.asarray(f(q, k, v))
        want = np.asarray(attention_ref(q.transpose(0,2,1,3), k.transpose(0,2,1,3),
                                        v.transpose(0,2,1,3), causal=True).transpose(0,2,1,3))
        np.testing.assert_allclose(got, want, atol=2e-5)
        print("ring attention ok")
    """)


def test_ef_int8_psum_compression():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.optim import ef_int8_psum
        mesh = make_mesh((8,), ("dp",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 256))
        r0 = jnp.zeros((256,))
        def body(g_loc, r):
            out, r2 = ef_int8_psum({"g": g_loc[0]}, {"g": r}, "dp")
            return out["g"], r2["g"]
        f = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("dp"), P()),
                              out_specs=(P(), P()), check_vma=False))
        approx, resid = f(g, r0)
        exact = np.asarray(g).mean(0)            # ef_int8_psum returns the MEAN
        err = np.abs(np.asarray(approx) - exact).max()
        scale = np.abs(np.asarray(g)).max()
        assert err < scale / 32, (err, scale)   # int8 quantisation error bound
        # error feedback: residual carries what quantisation dropped
        assert np.abs(np.asarray(resid)).max() <= scale / 64
        print("ef-int8 ok", err)
    """)
