"""Farm skeleton semantics: completeness, order preservation, scheduling
policies, straggler re-dispatch, lock-based interchangeability, MDF cycles,
and the SPMC allocator."""
import time

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (EOS, FnNode, LockQueue, MDFExecutor, MDFTask,
                        PagePool, PoolExhausted, SPSCQueue, TaskFarm, ff_node)


@pytest.mark.parametrize("nworkers", [1, 3, 8])
@pytest.mark.parametrize("qcls", [SPSCQueue, LockQueue])
def test_farm_completeness(nworkers, qcls):
    farm = TaskFarm(nworkers, queue_class=qcls)
    farm.add_stream(range(200))
    farm.add_worker(FnNode(lambda x: x * 3))
    out = farm.run_and_wait()
    assert sorted(out) == [x * 3 for x in range(200)]
    assert farm.stats.tasks_collected == 200


def test_order_preserving_farm():
    """Tagged-token collector (paper Fig. 1 right): output == input order
    even with variable task latency."""
    import random
    rnd = random.Random(0)

    def slow_sq(x):
        time.sleep(rnd.random() * 0.003)
        return x * x

    farm = TaskFarm(4, preserve_order=True)
    farm.add_stream(range(60))
    farm.add_worker(FnNode(slow_sq))
    assert farm.run_and_wait() == [x * x for x in range(60)]


def test_ondemand_scheduling_balances():
    """On-demand must not starve: with one slow worker, round-robin piles
    onto it, on-demand doesn't."""
    class Worker(ff_node):
        def __init__(self):
            self.seen = 0

        def svc(self, t):
            self.seen += 1
            if t == 0:
                time.sleep(0.2)  # worker that got task 0 becomes slow
            return t

    workers = [Worker() for _ in range(3)]
    farm = TaskFarm(3, scheduling="ondemand", capacity=2)
    farm.add_stream(range(40))
    for w in workers:
        farm.add_worker(w)
    out = farm.run_and_wait()
    assert sorted(out) == list(range(40))
    slow = max(workers, key=lambda w: 1 if w.seen and 0 in range(1) else 0)
    # the two fast workers should have absorbed most of the stream
    assert sorted(w.seen for w in workers)[0] < 15


def test_straggler_speculation_dedup():
    """A hung worker's tasks are re-issued; collector sees each tag once."""
    class Sometimes(ff_node):
        def svc(self, t):
            if t == 5:
                time.sleep(1.0)   # straggler
            return t

    farm = TaskFarm(3, speculative=True, straggler_factor=2.0,
                    min_straggler_age=0.05, preserve_order=True)
    farm.add_stream(range(30))
    farm.add_worker(Sometimes())
    out = farm.run_and_wait()
    assert out == list(range(30))             # exactly-once at the collector
    assert farm.stats.duplicates_issued >= 1  # speculation actually fired


def test_worker_failure_recovered_by_speculation():
    """A worker thread that dies mid-stream: its tasks age out and are
    re-issued to the live workers."""
    class Dies(ff_node):
        def __init__(self):
            self.count = 0

        def svc(self, t):
            self.count += 1
            if self.count == 3 and t % 3 == 1:
                raise RuntimeError("simulated node failure")
            return t

    farm = TaskFarm(3, speculative=True, straggler_factor=2.0,
                    min_straggler_age=0.05)
    farm.add_stream(range(30))
    for _ in range(3):
        farm.add_worker(Dies())
    out = farm.run_and_wait()
    assert sorted(out) == list(range(30))
    assert farm.stats.worker_failures, "a worker should have died"


@given(st.integers(1, 6), st.integers(0, 120))
@settings(max_examples=20, deadline=None)
def test_farm_property_any_size(nworkers, n):
    farm = TaskFarm(nworkers, preserve_order=True)
    farm.add_stream(range(n))
    farm.add_worker(FnNode(lambda x: x + 7))
    assert farm.run_and_wait() == [x + 7 for x in range(n)]


# -- MDF executor -----------------------------------------------------------
def test_mdf_wavefront_dependencies_respected():
    order = []

    def record(*deps, tag=None):
        order.append(tag)
        return sum(deps) + 1

    n = 5
    tasks = []
    for i in range(n):
        for j in range(n):
            deps = tuple(t for t in [(i - 1, j), (i, j - 1)]
                         if t[0] >= 0 and t[1] >= 0)
            tasks.append(MDFTask(tag=(i, j), fn=lambda *d, tag=(i, j): record(*d, tag=tag),
                                 deps=deps))
    out = MDFExecutor(nworkers=4).run(tasks)
    assert len(out) == n * n
    pos = {t: i for i, t in enumerate(order)}
    for i in range(n):
        for j in range(n):
            if i: assert pos[(i - 1, j)] < pos[(i, j)]
            if j: assert pos[(i, j - 1)] < pos[(i, j)]


# -- SPMC page pool -----------------------------------------------------------
def test_pool_exhaustion_and_recycle():
    pool = PagePool(4, nfreers=2)
    pages = [pool.alloc() for _ in range(4)]
    assert sorted(pages) == [0, 1, 2, 3]
    with pytest.raises(PoolExhausted):
        pool.alloc()
    pool.free(pages[0], 0)
    pool.free(pages[1], 1)
    got = {pool.alloc(), pool.alloc()}
    assert got == {pages[0], pages[1]}


@given(st.lists(st.booleans(), min_size=1, max_size=200))
@settings(max_examples=30, deadline=None)
def test_pool_never_double_allocates(ops):
    pool = PagePool(8, nfreers=1)
    held = set()
    for do_alloc in ops:
        if do_alloc:
            p = pool.try_alloc()
            if p is not None:
                assert p not in held, "double allocation!"
                held.add(p)
        elif held:
            p = held.pop()
            pool.free(p, 0)
    assert len(held) + pool.available() + len(pool._free_rings[0]) == 8
