"""Picklable nodes and child targets for the procs-backend tests.

Spawned vertex processes unpickle their nodes by *importing the defining
module* — so everything a procs test ships to a child lives here, in a
module with no test-only imports (no hypothesis, no pytest, no jax):
a child must be able to import it cold, cheaply.
"""
from __future__ import annotations

import time


# -- plain svc functions ------------------------------------------------------
def f(x):
    return x * 3 + 1


def g(x):
    return x - 7


def sq(x):
    return x * x


def fb_step(x):
    return x * 2 + 1


def fb_pred(x):
    return x < 64


def fb_ref(x):
    x = fb_step(x)
    while fb_pred(x):
        x = fb_step(x)
    return x


def drop_odd(x):
    from repro.core import GO_ON
    return x if x % 2 == 0 else GO_ON


def boom_on_seven(x):
    if x == 7:
        raise ValueError("boom at 7")
    return x


def sleepy(x):
    time.sleep(60.0)  # a wedged worker: only the run timeout can save us
    return x


def big_payload(x):
    return ("#" * 5000, x)  # forces the shm ring's spill side-channel


# -- monitor-test nodes: a synthetic skewed pipeline (one stage 10x slower) --
def fast_stage(x):
    time.sleep(0.0002)
    return x + 1


def slow_stage(x):
    time.sleep(0.002)  # the 10x-slower stage the analyzer must name
    return x * 2


# -- all-to-all / stream_ops nodes (spawned children re-import these) --------
def mod3(x):
    return x % 3


def mod5(x):
    return x % 5


def mod7(x):
    return x % 7


def double(x):
    return x * 2


def second(t):
    return t[1]


def mod2int(x):
    # array-polymorphic float key: floor-div keeps it traceable on the
    # mesh (host key 0.0 and mesh key 0 hash equal, so dicts agree)
    return (x // 1) % 2


def keep_larger(a, b):
    return a if a >= b else b


def emit_twice(x):
    from repro.core import EmitMany
    return EmitMany([x, x])


def emit_pair_batch(x):
    # one KeyBatch per input: a single wire message whose items a plain
    # downstream stage must still see individually (test_oocore)
    from repro.core import KeyBatch
    return KeyBatch([(x, 0.5 * x), (x, 0.5 * x + 1)])


class Dedup:
    """Stateful per-partition worker: emits each value once (GO_ON after),
    used to pin that partition_by instantiates worker classes fresh."""

    def __init__(self):
        self.seen = set()

    def __call__(self, x):
        from repro.core import GO_ON
        if x in self.seen:
            return GO_ON
        self.seen.add(x)
        return x


class TagPartition:
    """Right-row worker that stamps its partition index on every item —
    lets tests observe which partition serviced which key."""

    def __init__(self, j):
        self.j = j

    def __call__(self, x):
        return (self.j, x)


# -- ff_node-style emitter/collector -----------------------------------------
class AddTagEmitter:
    """Emitter node: runs inside the dispatch arbiter's process."""

    def svc_init(self):
        pass

    def svc_end(self):
        pass

    def svc(self, task):
        return task + 100


class NegateCollector:
    """Collector node: runs inside the merge arbiter's process."""

    def svc_init(self):
        pass

    def svc_end(self):
        pass

    def svc(self, task):
        return -task


def np_double(x):
    # numpy payload node: lazy import, so children that never service an
    # array keep their cold import cheap (and repro.core stays numpy-free)
    import numpy as np
    return np.asarray(x) * 2.0


# -- out-of-core aggregation nodes (test_oocore; spawned children) -----------
def mod10_pair(kv):
    return kv[0] % 10


def add_val(acc, kv):
    return acc + kv[1]


def add2(a, b):
    return a + b


def row_key(row):
    return row[0]


def row_stats(acc, row):
    """Seeded fold over (key, value) rows -> (count, total)."""
    return (acc[0] + 1, acc[1] + row[1])


def merge_stats(a, b):
    """Combine two (count, total) partials of one key."""
    return (a[0] + b[0], a[1] + b[1])


class RangeRows:
    """Synthetic columnar dataset: ``reader(lo, hi)`` -> list of
    ``(key, value)`` rows, deterministic from the row index alone, so
    every shard (and every process) reads the same dataset with no file.
    ``nrows``/``nkeys`` make it a drop-in for ``shard_source``."""

    def __init__(self, nrows, nkeys):
        self.nrows = nrows
        self.nkeys = nkeys

    def __call__(self, lo, hi):
        nk = self.nkeys
        return [((i * 2654435761) % nk, float(i % 97)) for i in range(lo, hi)]


# -- child targets for test_shm ----------------------------------------------
def echo_child(inbound, outbound):
    """Pop until EOS; report whether each sentinel kept identity."""
    from repro.core import EOS, GO_ON
    while True:
        item = inbound.pop_wait(timeout=30)
        if item is EOS:
            outbound.push_wait(("eos-is-eos", True), timeout=30)
            return
        if item is GO_ON:
            outbound.push_wait(("go-on-is-go-on", True), timeout=30)
            continue
        outbound.push_wait(item, timeout=30)


def bump_child(board):
    board.add(1, 5)  # slot 1 is this process's single-writer counter


def set_flag_child(flag):
    flag.set()


def np_sum_child(inbound, outbound):
    """Pop numpy arrays until EOS; reply (dtype str, shape, scalar sum)
    per array so the parent can assert zero-copy decode fidelity."""
    from repro.core import EOS
    while True:
        item = inbound.pop_wait(timeout=30)
        if item is EOS:
            return
        outbound.push_wait(
            (item.dtype.str, item.shape, float(item.sum())), timeout=30)
