"""Out-of-core keyed aggregation (``repro.core.oocore``): SpillFold ==
_KeyFold parity (including forced-spill runs under a tiny budget), the
shared ``MemoryBudget`` board surfacing spill/stall telemetry in
``FarmStats`` across the process boundary, columnar ``shard_source``
coverage, the map-side ``CombiningReader``, multi-stage shuffles
(``rekey_reduce`` — ``a2a∘a2a``) with the fuse-boundary and mesh
one-shuffle guarantees, ``KeyBatch`` transport transparency, and the
``benchmarks/run.py --only`` CLI error contract."""
import os
import sys

import pytest
from hypothesis import given, settings, strategies as st

import _procs_nodes as N
from repro.core import (AllToAll, KeyBatch, LoweringError, MemoryBudget,
                        Pipeline, SpillFold, Stage, fuse, lower,
                        reduce_by_key, shard_reduce, shard_source)
from repro.core.oocore import CombiningReader, ShardReader, rekey_reduce
from repro.core.sched import SCHEDULERS, BudgetBackpressure
from repro.core.skeleton import FusedNode
from repro.core.stream_ops import _KeyFold


def ref_rbk(xs, by, fold, seed=None):
    d = {}
    for x in xs:
        k = by(x)
        d[k] = fold(d[k], x) if k in d else (x if seed is None else fold(seed, x))
    return d


def run_source_skel(skel, backend, timeout=60):
    """Source-left skeletons (shard_reduce) carry their own stream: run
    via ``to_graph(None)`` instead of feeding an input iterable."""
    g = lower(skel, backend).to_graph(None)
    g.run()
    return g.wait(timeout)


# Programs built once at module scope (procs examples spawn real process
# networks; the budgeted skeleton is shared to also pin re-runnability).
# budget=256 < the ~150 bytes/entry × 5 keys hot state, so every example
# that touches enough keys spills — the spill path runs constantly here.
BRBK = reduce_by_key(N.mod5, "sum", nleft=2, nright=3, nkeys=5, budget=256)
BRBK_T = lower(BRBK, "threads")
BRBK_M = lower(BRBK, "mesh")
BRBK_P = lower(BRBK, "procs")


# -- SpillFold == _KeyFold (the drop-in contract) ----------------------------
@given(st.lists(st.integers(-60, 60), max_size=80))
@settings(max_examples=15, deadline=None)
def test_spillfold_matches_keyfold_forced_spills(xs):
    """Same by/fn, one instance each, tiny budget: the spill/merge path
    must be observationally identical to the in-memory dict — and the
    instance must clean its run directory and be back to initial state."""
    fn = N.keep_larger
    kf = _KeyFold(abs, fn)
    sf = SpillFold(abs, fn, budget=MemoryBudget(300))
    for x in xs:
        kf.svc(x)
        sf.svc(x)
    want = kf.svc_eos() or []
    got = []
    for chunk in (sf.svc_eos() or []):
        got.extend(chunk)
    assert dict(got) == dict(want)
    keys = [k for k, _v in got]
    assert keys == sorted(keys)          # flush is sorted by key
    assert sf._acc == {} and not sf._runs and sf._dir is None


def test_spillfold_spills_and_accounts():
    b = MemoryBudget(500)
    sf = SpillFold(abs, N.keep_larger, budget=b)
    for x in range(-300, 300):
        sf.svc(x)
    assert b.spills() > 0 and b.spill_bytes() > 0
    assert b.held_total() <= b.limit
    out = []
    for chunk in sf.svc_eos():
        out.extend(chunk)
    assert dict(out) == ref_rbk(range(-300, 300), abs, N.keep_larger)
    assert b.held_total() == 0           # flush released every byte


def test_spillfold_seeded_fold_needs_combine():
    with pytest.raises(ValueError, match="combine"):
        SpillFold(N.row_key, N.row_stats, (0, 0.0), False,
                  budget=MemoryBudget(1000))
    with pytest.raises(ValueError, match="combine"):
        shard_reduce(N.RangeRows(100, 5), N.row_key, N.row_stats,
                     init=(0, 0.0), budget=1000)
    # the Fold registry carries combine for "count": no explicit combine
    skel = reduce_by_key(N.mod5, "count", nright=2, budget=400)
    assert dict(lower(skel, "threads")(range(23))) == \
        {k: sum(1 for x in range(23) if x % 5 == k) for k in range(5)}


# -- three-backend parity of the SAME budgeted skeleton object ---------------
@given(st.lists(st.integers(0, 1000), max_size=40))
@settings(max_examples=8, deadline=None)
def test_budgeted_rbk_parity_threads_mesh(xs):
    """The mesh program compiles from the static KeyedReduce spec and
    never looks at the right row — a budgeted skeleton must still lower
    and agree (spilling is a host-side execution detail, not semantics)."""
    want = ref_rbk(xs, N.mod5, lambda a, b: a + b)
    assert dict(BRBK_T(xs)) == want
    assert dict(BRBK_M(xs)) == want


@given(st.lists(st.integers(0, 1000), max_size=16))
@settings(max_examples=3, deadline=None)
def test_budgeted_rbk_parity_procs(xs):
    assert dict(BRBK_P(xs)) == ref_rbk(xs, N.mod5, lambda a, b: a + b)


def test_budgeted_flush_byte_identical_across_backends():
    """nright=1: one partition holds every key, so the full flush order
    is observable — threads and procs must emit the identical sorted
    list (the determinism the sorted _KeyFold/SpillFold flush buys)."""
    skel = reduce_by_key(abs, "sum", nright=1, budget=2000)
    xs = [x - 200 for x in range(400)]
    t = lower(skel, "threads")(xs)
    p = lower(skel, "procs")(xs)
    assert t == p == sorted(t, key=lambda kv: kv[0])
    assert skel.stats.spills > 0         # the tiny budget really spilled


# -- the shared budget board across the process boundary ---------------------
def test_procs_budget_board_surfaces_stats_cumulatively():
    """Child-process spill counters must land in the parent's FarmStats
    (ShmCounters board swap), and stay cumulative across runs of the
    same skeleton object — the counters are lifetime totals."""
    skel = reduce_by_key(abs, "sum", nright=2, budget=1500)
    prog = lower(skel, "procs")
    xs = [x - 300 for x in range(600)]
    assert dict(prog(xs)) == ref_rbk(xs, abs, lambda a, b: a + b)
    first = skel.stats.spills
    assert first > 0 and skel.stats.spill_bytes > 0
    assert dict(prog(xs)) == ref_rbk(xs, abs, lambda a, b: a + b)
    assert skel.stats.spills > first     # second run adds to the totals


def test_budget_backpressure_policy():
    """The 'budget' scheduling policy stalls intake while the aggregate
    held bytes sit over the high-water mark, counts each stall — and has
    hysteresis: a stall that times out still over the line must not
    repeat per placement (nothing downstream can drop held bytes without
    new input), only after the aggregate first dips below the line."""
    assert SCHEDULERS["budget"] is BudgetBackpressure
    b = MemoryBudget(1000, nparts=2)
    pol = BudgetBackpressure(b, max_stall_s=0.01).fresh()
    pol.bind([None, None], None)
    assert pol.pick() == 0               # under budget: plain round-robin
    assert b.stalls() == 0
    b.charge(0, 1000)
    b.charge(1, 900)                     # 1900/2000 held > ¾ high-water
    assert b.over_total()
    assert pol.pick() == 1               # stalls (bounded), then proceeds
    assert b.stalls() == 1
    assert pol.pick() == 0               # still over, stall exhausted:
    assert b.stalls() == 1               # no repeat stall per placement
    b.charge(0, -1000)
    b.charge(1, -900)
    assert not b.over_total()
    assert pol.pick() == 1               # dip below the line re-armed it
    b.charge(0, 1000)
    b.charge(1, 900)
    assert pol.pick() == 0
    assert b.stalls() == 2


# -- columnar sharding -------------------------------------------------------
def test_shard_source_covers_rows_exactly_once():
    reader = N.RangeRows(1000, 7)
    shards = shard_source(reader, 3, batch_rows=64)
    seen = []
    for s in shards:
        while True:
            out = s.svc(None)
            if out is None:
                break
            seen.extend(out)
    assert sorted(seen) == sorted(reader(0, 1000))


def test_shard_reader_is_rerunnable():
    s = ShardReader(N.RangeRows(100, 5), 0, 2, batch_rows=16)
    def drain():
        out = []
        while True:
            b = s.svc(None)
            if b is None:
                return out
            out.extend(b)
    assert drain() == drain()            # cursor reset at EOS


def test_combining_reader_prefolds_and_evicts_batches():
    """Map-side combine under a tiny bound: evictions leave as KeyBatch
    partials, and re-combining every emission reproduces the exact fold."""
    from repro.core import GO_ON
    reader = N.RangeRows(2000, 50)
    cr = CombiningReader(ShardReader(reader, 0, 1, batch_rows=128),
                         N.row_key, N.row_stats, (0, 0.0), False,
                         combine=N.merge_stats, limit_bytes=2000)
    cr.svc_init()
    pairs, batches = [], 0
    while True:
        out = cr.svc(None)
        if out is None:
            break
        if out is GO_ON:
            continue
        assert type(out) is KeyBatch
        batches += 1
        pairs.extend(out)
    tail = cr.svc_eos()
    if tail:
        pairs.extend(tail)
    assert batches > 0                   # the bound really evicted early
    assert len(pairs) > 50               # partials: more emissions than keys
    acc = {}
    for k, v in pairs:
        acc[k] = N.merge_stats(acc[k], v) if k in acc else v
    want = {}
    for k, v in reader(0, 2000):
        c, t = want.get(k, (0, 0.0))
        want[k] = (c + 1, t + v)
    assert acc == want


# -- the whole composition: shard_reduce on both host backends ---------------
@given(st.integers(2, 4), st.integers(1, 3))
@settings(max_examples=4, deadline=None)
def test_shard_reduce_threads(nleft, nright):
    reader = N.RangeRows(3000, 200)
    skel = shard_reduce(reader, N.row_key, N.row_stats, init=(0, 0.0),
                        combine=N.merge_stats, nleft=nleft, nright=nright,
                        budget=3000, batch_rows=256)
    want = {}
    for k, v in reader(0, 3000):
        c, t = want.get(k, (0, 0.0))
        want[k] = (c + 1, t + v)
    assert dict(run_source_skel(skel, "threads")) == want
    assert skel.stats.spills > 0


def test_shard_reduce_procs_with_stats():
    reader = N.RangeRows(4000, 300)
    skel = shard_reduce(reader, N.row_key, N.row_stats, init=(0, 0.0),
                        combine=N.merge_stats, nleft=2, nright=2,
                        budget=3000, batch_rows=256)
    want = {}
    for k, v in reader(0, 4000):
        c, t = want.get(k, (0, 0.0))
        want[k] = (c + 1, t + v)
    assert dict(run_source_skel(skel, "procs")) == want
    assert skel.stats.spills > 0 and skel.stats.spill_bytes > 0


# -- multi-stage shuffles: a2a ∘ a2a -----------------------------------------
def test_rekey_reduce_threads_and_procs():
    first = reduce_by_key(abs, "sum", nright=2, budget=2000)
    chain = rekey_reduce(first, N.mod10_pair, N.add_val, init=0.0,
                         combine=N.add2, nright=2, budget=1500)
    xs = [x - 300 for x in range(600)]
    ref1 = ref_rbk(xs, abs, lambda a, b: a + b)
    want = {}
    for k, v in ref1.items():
        want[k % 10] = want.get(k % 10, 0.0) + v
    assert dict(lower(chain, "threads")(xs)) == want
    assert dict(lower(chain, "procs")(xs)) == want


def test_rekey_reduce_is_two_a2a_and_fuse_never_crosses():
    first = reduce_by_key(abs, "sum", nright=2, budget=2000)
    chain = rekey_reduce(first, N.mod10_pair, N.add_val, init=0.0,
                         combine=N.add2, nright=2)
    assert [type(s) for s in chain.stages] == [AllToAll, AllToAll]
    padded = Pipeline(Stage(N.f), Stage(N.g), chain.stages[0],
                      Stage(N.sq), Stage(N.double), chain.stages[1])
    fused = fuse(padded, force=True)
    kinds = [type(s) for s in fused.stages]
    assert kinds.count(AllToAll) == 2    # both shuffles survive as barriers
    assert fused.stages[1] is chain.stages[0]   # untouched, not rebuilt
    assert fused.stages[3] is chain.stages[1]
    assert isinstance(fused.stages[0].node, FusedNode)  # fusion still runs
    assert isinstance(fused.stages[2].node, FusedNode)  # between barriers


def test_mesh_rejects_multi_stage_shuffle():
    first = reduce_by_key(N.mod5, "sum", nkeys=5, nright=2)
    chain = rekey_reduce(first, N.mod10_pair, N.add_val, init=0.0,
                         combine=N.add2)
    with pytest.raises(LoweringError, match="exactly one"):
        lower(chain, "mesh")


# -- KeyBatch transport transparency -----------------------------------------
def test_keybatch_unpacks_for_batch_oblivious_nodes():
    """A KeyBatch is one wire message, but a plain downstream node (and
    the caller's results) must still see items — batching is transport,
    not semantics, on both host backends."""
    skel = Pipeline(Stage(N.emit_pair_batch), Stage(N.second))
    want = sorted([0.5 * x for x in range(20)] + [0.5 * x + 1 for x in range(20)])
    assert sorted(lower(skel, "threads")(range(20))) == want
    assert sorted(lower(skel, "procs")(range(20))) == want


# -- benchmarks/run.py CLI contract ------------------------------------------
def _bench_main():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    try:
        from benchmarks import run as bench_run
    finally:
        sys.path.pop(0)
    return bench_run


def test_bench_only_unknown_module_errors():
    with pytest.raises(SystemExit) as e:
        _bench_main().main(["--only", "definitely_not_a_benchmark"])
    assert e.value.code != 0


def test_bench_only_empty_selection_errors():
    with pytest.raises(SystemExit) as e:
        _bench_main().main(["--only", " , "])
    assert e.value.code != 0


def test_bench_registers_ooc_module():
    assert "ooc_aggregation" in _bench_main().MODULES
