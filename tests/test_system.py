"""End-to-end behaviour tests for the full system (paper-level claims).

These tie the layers together: the farm + SW kernel reproduce the paper's
application; param counts match the assigned architecture table; MoE routed
cost is genuinely sparse.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import FnNode, TaskFarm
from repro.kernels import ops
from repro.models import active_param_count, param_count


def test_sw_database_search_via_farm():
    """The paper's application, end to end: a farm streams (query, subject)
    pairs through the Smith-Waterman kernel; collector preserves DB order."""
    rng = np.random.default_rng(0)
    query = jnp.asarray(rng.integers(0, 20, 24), jnp.int32)
    db = [jnp.asarray(rng.integers(0, 20, int(rng.integers(10, 60))), jnp.int32)
          for _ in range(12)]

    farm = TaskFarm(3, preserve_order=True)
    farm.add_stream(db)
    farm.add_worker(FnNode(lambda subj: float(
        ops.smith_waterman(query, subj, gap_open=10.0, gap_extend=2.0, tile=64))))
    scores = farm.run_and_wait()

    from repro.kernels.ref import sw_ref
    from repro.kernels.ops import build_profile
    prof, _ = build_profile(query)
    want = [float(sw_ref(prof, s, 10.0, 2.0)) for s in db]
    assert scores == want


def test_gcups_accounting():
    """GCUPS = |Q|·|D| / (T·1e9) — the bench harness formula (paper Sec 4.2)."""
    from benchmarks.smith_waterman import gcups
    assert abs(gcups(100, 1000, 0.001) - 0.1) < 1e-9


@pytest.mark.parametrize("arch,expected_b,tol", [
    ("kimi-k2-1t-a32b", 1040.0, 0.05),      # ~1T total
    ("mixtral-8x7b", 46.7, 0.05),
    ("phi3-mini-3.8b", 3.8, 0.06),
    ("mistral-nemo-12b", 12.2, 0.06),
    ("deepseek-coder-33b", 33.3, 0.06),
    ("llama-3.2-vision-90b", 88.0, 0.06),
    ("zamba2-2.7b", 2.1, 0.3),              # shared block trims params
    ("mamba2-130m", 0.17, 0.3),
])
def test_param_counts_match_arch_names(arch, expected_b, tol):
    got = param_count(ARCHS[arch]) / 1e9
    assert abs(got - expected_b) / expected_b < tol, (arch, got)


def test_kimi_active_params_are_32b_scale():
    active = active_param_count(ARCHS["kimi-k2-1t-a32b"]) / 1e9
    assert 25 < active < 45, active


def test_moe_cheaper_than_dense_flops():
    """Routed-FLOPs sanity: active ≪ total for the MoE archs."""
    for arch in ["kimi-k2-1t-a32b", "mixtral-8x7b"]:
        cfg = ARCHS[arch]
        assert active_param_count(cfg) < 0.5 * param_count(cfg)


def test_roofline_analysis_from_dryrun_artifacts():
    """If the dry-run has been run, every OK cell must produce finite terms
    and a dominant bottleneck."""
    from benchmarks.roofline import table
    rows = table()
    if not rows:
        pytest.skip("no reports/dryrun.jsonl yet")
    assert len(rows) >= 30                      # 33 applicable cells
    for r in rows:
        assert r["compute_s"] >= 0 and np.isfinite(r["compute_s"])
        assert r["dominant"] in ("compute", "memory", "collective")
        if "cost_source" in r:
            # exact (unroll-extrapolated) accounting: compiled FLOPs must
            # be at least the model FLOPs (ratio ≤ 1 + padding/remat slack)
            assert 0 < r["useful_ratio"] <= 1.05, r
