"""Skeleton IR semantics: backend parity (threads vs mesh vs procs),
ordering included; IR edge cases (empty stream, all-GO_ON); the
single-shard_map guarantee of the mesh lowering; and compat hygiene (no
version probes outside repro/compat.py) — tier-1 for the unified
skeleton layer."""
import pathlib
import re

import numpy as np
from hypothesis import given, settings, strategies as st

import _procs_nodes as N
import repro
from repro import compat
from repro.core import (Farm, Feedback, GO_ON, LoweringError, Net, Pipeline,
                        ProcProgram, Skeleton, Source, Stage, TaskFarm,
                        compose, lower)

# The parity nodes live in _procs_nodes (picklable, test-dep-free): the
# procs backend ships them to spawned vertex processes, which re-import
# the defining module.  All THREE backends lower the same skeletons.
_f = N.f
_g = N.g


# Programs are built once at module scope: the mesh lowering caches its
# compiled shard_map per (rows, dtype) bucket, so every hypothesis example
# reuses one compile.
PIPE = Pipeline(Farm(_f, 4, ordered=True), Farm(_g, 4, ordered=True))
PIPE_T = lower(PIPE, "threads")
PIPE_M = lower(PIPE, "mesh")
PIPE_P = lower(PIPE, "procs")

FB = Feedback(N.fb_step, N.fb_pred, nworkers=3, max_trips=32)
FB_T = lower(FB, "threads")
FB_M = lower(FB, "mesh")
FB_P = lower(FB, "procs")


# -- backend parity: identical ordered outputs -------------------------------
@given(st.lists(st.integers(-1000, 1000), max_size=40))
@settings(max_examples=10, deadline=None)
def test_parity_pipeline_of_farms_ints(xs):
    """lower(Pipeline(Farm(f), Farm(g)), threads|mesh): same ordered output
    (ints are exact on both backends)."""
    want = [_g(_f(x)) for x in xs]
    assert PIPE_T(xs) == want
    assert PIPE_M(xs) == want


@given(st.lists(st.floats(-100.0, 100.0), max_size=40))
@settings(max_examples=10, deadline=None)
def test_parity_pipeline_of_farms_floats(xs):
    """Float streams agree to float32 tolerance (the mesh program computes
    in f32; the thread workers in Python f64)."""
    t = PIPE_T(xs)
    m = PIPE_M(xs)
    assert len(t) == len(m) == len(xs)
    np.testing.assert_allclose(t, m, rtol=1e-4, atol=1e-4)


@given(st.lists(st.integers(0, 60), max_size=32))
@settings(max_examples=10, deadline=None)
def test_parity_feedback_farm(xs):
    """The wrap-around loop: f applied until the predicate releases the
    item, input order preserved — identical on the wrap-around SPSC ring
    (threads) and the masked while_loop (mesh)."""
    def ref(x):
        x = x * 2 + 1
        while x < 64:
            x = x * 2 + 1
        return x

    want = [ref(x) for x in xs]
    assert FB_T(xs) == want
    assert FB_M(xs) == want


# Procs parity draws fewer examples: every example spawns a full process
# network (13 vertices for PIPE), which costs seconds, not microseconds —
# the *same* skeleton objects, the same reference, one more backend.
@given(st.lists(st.integers(-1000, 1000), max_size=16))
@settings(max_examples=3, deadline=None)
def test_parity_procs_pipeline_of_farms(xs):
    """lower(Pipeline(Farm(f), Farm(g)), procs): same ordered output as
    the threads/mesh lowerings of the identical IR."""
    assert PIPE_P(xs) == [_g(_f(x)) for x in xs]


@given(st.lists(st.integers(0, 60), max_size=10))
@settings(max_examples=3, deadline=None)
def test_parity_procs_feedback_farm(xs):
    """The wrap-around loop on spawned processes: the loop ring is a
    shared-memory SPSC ring, quiescence reads the ShmCounters board —
    output identical to the threads ring and the mesh while_loop."""
    assert FB_P(xs) == [N.fb_ref(x) for x in xs]


def test_parity_empty_stream():
    assert PIPE_T([]) == PIPE_M([]) == PIPE_P([]) == []
    assert FB_T([]) == FB_M([]) == FB_P([]) == []


def test_procs_lowering_registered():
    assert isinstance(PIPE_P, ProcProgram) and ProcProgram.backend == "procs"


def test_all_go_on_stream_on_ir():
    """A farm whose worker filters everything (GO_ON) must terminate and
    emit nothing — the EOS protocol outruns the empty output."""
    drop_all = Farm(lambda x: GO_ON, 3, ordered=True)
    assert lower(drop_all, "threads")(range(100)) == []
    mixed = Pipeline(Farm(lambda x: x if x % 2 else GO_ON, 2, ordered=True),
                     Farm(_f, 2, ordered=True))
    assert lower(mixed, "threads")(range(10)) == [_f(x) for x in (1, 3, 5, 7, 9)]


# -- acceptance: the mesh lowering is ONE shard_map program ------------------
def test_mesh_lowering_is_single_shard_map(monkeypatch):
    """Pipeline(Farm(f), Farm(g)) on the mesh backend compiles whole: one
    shard_map (and no thread graph), so there is no host SPSC hop between
    f and g."""
    calls = []
    real = compat.shard_map

    def counting_shard_map(*args, **kw):
        calls.append(kw.get("mesh"))
        return real(*args, **kw)

    monkeypatch.setattr(compat, "shard_map", counting_shard_map)
    prog = lower(Pipeline(Farm(_f, 4, ordered=True),
                          Farm(_g, 4, ordered=True)), "mesh")
    xs = list(range(48))
    assert prog(xs) == [_g(_f(x)) for x in xs]
    assert len(calls) == 1, f"expected ONE shard_map program, saw {len(calls)}"
    # same-bucket re-execution reuses the compiled program
    assert prog(list(range(10))) == [_g(_f(x)) for x in range(10)]
    assert len(calls) == 1


def test_mesh_rejects_host_only_features():
    import pytest
    with pytest.raises(LoweringError, match="Feedback"):
        lower(Farm(_f, 2, feedback=lambda r: (r, [])), "mesh")
    with pytest.raises(LoweringError, match="backend"):
        lower(Farm(_f, 2), "cuda-graphs")
    with pytest.raises(LoweringError, match="Source"):
        lower(Pipeline(Source(range(4)), Farm(_f, 2)), "mesh")


def test_mesh_feedback_padding_rows_do_not_gate_loop():
    """Bucket-padding zeros must not drive the feedback while_loop: with
    worker(0)=0 a fixed point and loop_while(0) true, an unguarded pad row
    would spin forever (no max_trips here on purpose)."""
    fb = Feedback(lambda x: x * 2, lambda x: x < 10)
    assert lower(fb, "mesh")([5]) == lower(fb, "threads")([5]) == [10]


def test_mesh_rejects_int_overflow_instead_of_wrapping():
    """Ints beyond int32 would silently wrap on the mesh while the threads
    backend computes exact Python ints — that divergence must be loud."""
    import pytest
    with pytest.raises(LoweringError, match="int32"):
        PIPE_M([2 ** 31])


def test_mesh_rejects_undersized_capacity_instead_of_dropping():
    """A capacity below the round-robin bucket fill would silently combine
    dropped items to zeros — refuse at trace time instead."""
    import pytest
    with pytest.raises(LoweringError, match="capacity"):
        lower(Farm(_f, 4, ordered=True), "mesh", capacity=1)(range(16))


def test_feedback_max_trips_parity_on_both_backends():
    """max_trips bounds the loop on BOTH backends: a predicate that never
    releases (identity worker) emits after exactly max_trips services on
    threads too, instead of spinning the wrap-around ring forever."""
    fb = Feedback(lambda x: x, lambda x: x < 10, max_trips=3)
    xs = [1, 2, 50]
    assert lower(fb, "threads")(xs) == lower(fb, "mesh")(xs) == [1, 2, 50]


# -- IR composition sugar and facades ----------------------------------------
def test_compose_and_rshift_build_the_same_ir():
    a = compose(_f, Farm(_g, 2, ordered=True))
    b = Stage(_f) >> Farm(_g, 2, ordered=True)
    assert [type(s) for s in a.stages] == [type(s) for s in b.stages]
    xs = list(range(20))
    assert lower(a, "threads")(xs) == lower(b, "threads")(xs) \
        == [_g(_f(x)) for x in xs]


def test_legacy_surfaces_are_ir_facades():
    """PR-1's Net API and the seed's TaskFarm both resolve to the one IR."""
    from repro.core import graph, skeleton
    assert Net is Skeleton
    assert graph.Farm is skeleton.Farm and graph.Pipeline is skeleton.Pipeline
    farm = TaskFarm(2, preserve_order=True)
    farm.add_stream([1, 2, 3])
    farm.add_worker(skeleton.FnNode(_f))
    assert farm.run_and_wait() == [_f(x) for x in [1, 2, 3]]


# -- compat hygiene -----------------------------------------------------------
def test_no_version_probes_outside_compat():
    """repro/compat.py is the single JAX version-split point: no
    hasattr(jax...) / jax.__version__ probes anywhere else in the package."""
    root = pathlib.Path(next(iter(repro.__path__)))
    probe = re.compile(r"hasattr\(\s*jax|jax\.__version__|"
                       r"version\.parse|importlib_metadata")
    offenders = []
    for path in root.rglob("*.py"):
        if path.name == "compat.py":
            continue
        if probe.search(path.read_text()):
            offenders.append(str(path))
    assert not offenders, f"version probes outside compat.py: {offenders}"
