"""Model correctness: per-arch smoke (fwd/train step, shapes + no NaNs),
MoE dispatch vs dense oracle, SSD vs sequential recurrence, attention
chunking vs naive, decode-vs-prefill consistency, head-padding exactness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import (decode_step, init_cache, init_params, loss_fn,
                          prefill)
from repro.models.attention import attention, naive_attention
from repro.models.config import ModelConfig
from repro.models.moe import moe_apply, moe_init
from repro.models.ssm import ssd_chunked, ssd_reference

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=32, key=KEY):
    ks = jax.random.split(key, 4)
    if cfg.family == "audio":
        batch = {"frames": jax.random.normal(ks[0], (B, S, cfg.d_model), jnp.float32),
                 "labels": jax.random.randint(ks[1], (B, cfg.n_codebooks, S), 0, cfg.vocab_size)}
    else:
        batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
                 "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(ks[2], (B, cfg.vision_patches, cfg.vision_dim))
    return batch


# --------------------------------------------------------------------------
# per-arch smoke: one forward + one backward on the reduced config
# --------------------------------------------------------------------------
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_forward_and_grad(arch):
    cfg = ARCHS[arch].smoke()
    params = init_params(cfg, KEY)
    batch = make_batch(cfg)
    (loss, metrics), grads = jax.jit(jax.value_and_grad(
        lambda p, b: loss_fn(p, b, cfg), has_aux=True))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: NaN loss"
    gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, f"{arch}: bad grads"
    # ~ln(vocab) at init (uniform predictions)
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.0


@pytest.mark.parametrize("arch", ["deepseek-coder-33b", "mixtral-8x7b",
                                  "mamba2-130m", "zamba2-2.7b",
                                  "llama-3.2-vision-90b", "musicgen-medium"])
def test_arch_decode_runs(arch):
    cfg = ARCHS[arch].smoke()
    params = init_params(cfg, KEY)
    B, S = 2, 16
    cache = init_cache(cfg, B, S + 4)
    if cfg.family == "audio":
        dbatch = {"frames": jax.random.normal(KEY, (B, 1, cfg.d_model), jnp.float32)}
    else:
        dbatch = {"tokens": jax.random.randint(KEY, (B, 1), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        dbatch["vision_embeds"] = jax.random.normal(KEY, (B, cfg.vision_patches, cfg.vision_dim))
    logits, cache2 = jax.jit(lambda p, b, c, l: decode_step(p, b, c, l, cfg))(
        params, dbatch, cache, jnp.int32(0))
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    # cache must actually change
    diff = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
               for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2)))
    assert diff > 0


def test_decode_matches_full_forward():
    """Greedy decode over a prompt step-by-step == teacher-forced forward.
    (dense arch; the strongest end-to-end consistency check we have)"""
    cfg = ARCHS["phi3-mini-3.8b"].smoke()
    params = init_params(cfg, KEY)
    B, S = 1, 12
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0, cfg.vocab_size)
    # full forward logits at final position, via prefill
    logits_full, _ = prefill(params, {"tokens": toks}, cfg)
    # token-by-token decode
    cache = init_cache(cfg, B, S + 2)
    step = jax.jit(lambda p, b, c, l: decode_step(p, b, c, l, cfg))
    for t in range(S):
        logits_step, cache = step(params, {"tokens": toks[:, t:t + 1]},
                                  cache, jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits_step), np.asarray(logits_full),
                               atol=2e-2, rtol=2e-2)


def test_head_padding_exactness():
    """Padded-head model == unpadded model numerically (same seed)."""
    base = ARCHS["phi3-mini-3.8b"].smoke().replace(
        n_heads=3, n_kv_heads=3, head_dim=16, d_model=48)
    unpadded = base.replace(pad_heads_to=0)
    padded = base.replace(pad_heads_to=4)   # pads 3 → 4 heads
    batch = make_batch(unpadded, B=2, S=16)
    p_un = init_params(unpadded, KEY)
    p_pad = init_params(padded, KEY)
    # copy the unpadded weights into the padded allocation
    def inject(pu, pp):
        pp = jax.tree.map(lambda x: x, pp)
        for blk in ["wq", "wk", "wv"]:
            pp["blocks"][blk] = pp["blocks"][blk].at[:, :, :3].set(pu["blocks"][blk])
        pp["blocks"]["wo"] = pp["blocks"]["wo"].at[:, :3].set(pu["blocks"]["wo"])
        for k in ["norm1", "norm2", "mlp", "embed", "lm_head", "final_norm"]:
            if k in pu["blocks"]:
                pp["blocks"][k] = pu["blocks"][k]
            elif k in pu:
                pp[k] = pu[k]
        return pp
    p_pad = inject(p_un, p_pad)
    l_un, _ = loss_fn(p_un, batch, unpadded)
    l_pad, _ = loss_fn(p_pad, batch, padded)
    np.testing.assert_allclose(float(l_un), float(l_pad), rtol=2e-3)


# --------------------------------------------------------------------------
# MoE: capacity dispatch vs dense oracle
# --------------------------------------------------------------------------
def test_moe_local_gather_matches_dense_oracle():
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=16, vocab_size=64,
                      n_experts=4, top_k=2, capacity_factor=8.0,  # no drops
                      pad_heads_to=0, pad_vocab_to=0)
    params = moe_init(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32), jnp.float32)
    params = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    out_d, aux_d = moe_apply(x, params, cfg, axis_name=None, backend="dense")
    # single-device "sharded" semantics: axis_name=None → local_gather path
    # still runs through _dispatch_local with e_loc == E
    out_l, aux_l = moe_apply(x, params, cfg, axis_name=None, backend="local_gather")
    np.testing.assert_allclose(np.asarray(out_l), np.asarray(out_d),
                               atol=1e-4, rtol=1e-4)


def test_moe_capacity_drops_are_bounded():
    """With cf=1.0 and adversarial routing, dropped tokens produce zeros,
    never garbage."""
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=8, vocab_size=64,
                      n_experts=2, top_k=1, capacity_factor=0.25,
                      pad_heads_to=0, pad_vocab_to=0)
    params = jax.tree.map(lambda p: p.astype(jnp.float32), moe_init(KEY, cfg))
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 16, 16), jnp.float32)
    out, _ = moe_apply(x, params, cfg, axis_name=None, backend="dense")
    assert np.all(np.isfinite(np.asarray(out)))


# --------------------------------------------------------------------------
# SSD: chunked == sequential
# --------------------------------------------------------------------------
@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_ssd_chunked_matches_reference(chunk):
    ks = jax.random.split(KEY, 5)
    b, T, H, P, N = 2, 64, 3, 8, 16
    x = jax.random.normal(ks[0], (b, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, T, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (b, T, N))
    C = jax.random.normal(ks[4], (b, T, N))
    y1, h1 = ssd_chunked(x, dt, A, B, C, chunk=chunk)
    y2, h2 = ssd_reference(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-4)


# --------------------------------------------------------------------------
# attention: chunked == naive, incl. SWA & GQA
# --------------------------------------------------------------------------
@pytest.mark.parametrize("window", [None, 24])
@pytest.mark.parametrize("groups", [1, 4])
def test_chunked_attention_matches_naive(window, groups):
    ks = jax.random.split(KEY, 3)
    B, S, Hkv, D = 2, 96, 2, 16
    H = Hkv * groups
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    got = attention(q, k, v, causal=True, window=window, impl="chunked",
                    q_chunk=32, kv_chunk=16)
    want = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_causal_skip_matches_full_schedule():
    ks = jax.random.split(KEY, 3)
    B, S, H, D = 1, 128, 2, 16
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    from repro.models.attention import chunked_attention
    a = chunked_attention(q, k, v, causal=True, window=None, q_chunk=32,
                          kv_chunk=32, causal_skip=True)
    b = chunked_attention(q, k, v, causal=True, window=None, q_chunk=32,
                          kv_chunk=32, causal_skip=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
