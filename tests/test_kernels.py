"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret mode), plus hypothesis property tests for Smith-Waterman."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.ops import AA_ALPHABET, BLOSUM50, build_profile


# --------------------------------------------------------------------------
# Smith-Waterman
# --------------------------------------------------------------------------
def test_blosum50_symmetric():
    m = np.asarray(BLOSUM50)
    assert m.shape == (24, 24)
    assert np.array_equal(m, m.T)
    assert m[0, 0] == 5 and m[4, 4] == 13  # A-A=5, C-C=13


def test_sw_known_alignment():
    """Identical sequences: score == sum of diagonal substitution scores."""
    seq = ops.encode_seq("HEAGAWGHEE")
    diag = float(sum(BLOSUM50[c, c] for c in np.asarray(seq)))
    got = float(ops.smith_waterman(seq, seq, tile=64))
    assert got == diag


def test_sw_empty_overlap_zero():
    a = ops.encode_seq("AAAA")
    b = ops.encode_seq("WWWW")  # A-W = -3: no positive local alignment
    assert float(ops.smith_waterman(a, b, tile=64)) == 0.0


@pytest.mark.parametrize("gaps", [(10.0, 2.0), (5.0, 2.0)])  # paper's two regimes
@pytest.mark.parametrize("qlen,dlen", [(7, 13), (30, 64), (64, 200), (129, 70)])
def test_sw_matches_sequential_ref(gaps, qlen, dlen):
    go, ge = gaps
    rng = np.random.default_rng(qlen * dlen)
    q = jnp.asarray(rng.integers(0, 20, qlen), jnp.int32)
    d = jnp.asarray(rng.integers(0, 20, dlen), jnp.int32)
    got = float(ops.smith_waterman(q, d, gap_open=go, gap_extend=ge, tile=64))
    prof, _ = build_profile(q)
    want = float(ref.sw_ref(prof, d, go, ge))
    assert got == want


@given(st.integers(1, 25), st.integers(1, 40), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_sw_property_triple_check(qlen, dlen, seed):
    """pallas == sequential-jax-ref == cell-by-cell numpy, random cases."""
    rng = np.random.default_rng(seed)
    q = rng.integers(0, 20, qlen)
    d = rng.integers(0, 20, dlen)
    m = np.asarray(BLOSUM50)
    got = float(ops.smith_waterman(jnp.asarray(q, jnp.int32),
                                   jnp.asarray(d, jnp.int32), tile=64))
    qs = "".join(AA_ALPHABET[i] for i in q)
    ds = "".join(AA_ALPHABET[i] for i in d)
    want = ref.sw_numpy(qs, ds,
                        lambda a, b: float(m[AA_ALPHABET.index(a), AA_ALPHABET.index(b)]),
                        10.0, 2.0)
    assert got == want


def test_sw_tile_invariance():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.integers(0, 20, 40), jnp.int32)
    d = jnp.asarray(rng.integers(0, 20, 300), jnp.int32)
    scores = {t: float(ops.smith_waterman(q, d, tile=t)) for t in (64, 128, 256)}
    assert len(set(scores.values())) == 1, scores


# --------------------------------------------------------------------------
# Flash attention
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Hkv,S,T,D", [
    (1, 2, 2, 64, 64, 16),
    (2, 4, 2, 96, 160, 32),   # GQA + ragged
    (1, 8, 1, 128, 128, 64),  # MQA
])
def test_flash_attention_sweep(dtype, B, H, Hkv, S, T, D):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(B * S + T), 3)
    q = jax.random.normal(k1, (B, H, S, D), dtype)
    k = jax.random.normal(k2, (B, Hkv, T, D), dtype)
    v = jax.random.normal(k3, (B, Hkv, T, D), dtype)
    got = ops.flash_attention_op(q, k, v, causal=True, bq=32, bk=64)
    want = ref.attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                             v.astype(jnp.float32), causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [16, 48])
def test_flash_attention_sliding_window(window):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (1, 2, 96, 16))
    k = jax.random.normal(k2, (1, 2, 96, 16))
    v = jax.random.normal(k3, (1, 2, 96, 16))
    got = ops.flash_attention_op(q, k, v, causal=True, window=window, bq=32, bk=32)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_matches_model_chunked_path():
    """Pallas kernel vs the model's pure-jnp chunked attention (the path the
    dry-run lowers): same math, two implementations."""
    from repro.models.attention import attention as model_attn
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    B, H, Hkv, S, D = 2, 4, 2, 256, 32
    q = jax.random.normal(k1, (B, S, H, D))
    k = jax.random.normal(k2, (B, S, Hkv, D))
    v = jax.random.normal(k3, (B, S, Hkv, D))
    got_model = model_attn(q, k, v, causal=True, impl="chunked",
                           q_chunk=64, kv_chunk=64)
    got_kernel = ops.flash_attention_op(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True, bq=64, bk=64).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got_model), np.asarray(got_kernel),
                               atol=3e-5)


# --------------------------------------------------------------------------
# SSD scan
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,T,H,P,N,chunk", [
    (1, 32, 2, 8, 16, 8),
    (2, 64, 3, 8, 16, 16),
    (1, 128, 4, 16, 32, 32),
])
def test_ssd_scan_sweep(dtype, b, T, H, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(T + H), 5)
    x = jax.random.normal(ks[0], (b, T, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, T, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (b, T, N), dtype)
    C = jax.random.normal(ks[4], (b, T, N), dtype)
    y, h = ops.ssd_scan_op(x, dt, A, B, C, chunk=chunk)
    y_ref, h_ref = ref.ssd_ref(x.astype(jnp.float32), dt, A,
                               B.astype(jnp.float32), C.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=tol, rtol=tol)


def test_ssd_kernel_matches_model_chunked():
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    b, T, H, P, N = 2, 64, 3, 8, 16
    x = jax.random.normal(ks[0], (b, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, T, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (b, T, N))
    C = jax.random.normal(ks[4], (b, T, N))
    y1, h1 = ops.ssd_scan_op(x, dt, A, B, C, chunk=16)
    y2, h2 = ssd_chunked(x, dt, A, B, C, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5)
