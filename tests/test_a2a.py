"""All-to-all building block + stream_ops semantics: three-backend parity
of the SAME ``reduce_by_key`` skeleton (threads / procs / mesh, unordered
compare), EOS fan-in termination on an nleft≠nright matrix, key-affinity
routing (every key owned by exactly one right vertex — across processes,
where builtin ``hash`` salting would split it), ordered a2a via the
tagged-token machinery, the fuse-never-crosses-AllToAll guarantee, the
``KeyAffinity`` scheduling policy, and the lowering error contracts."""
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

_REPO = os.path.join(os.path.dirname(__file__), "..")
_ENV = {k: v for k, v in os.environ.items() if k != "PYTHONHASHSEED"}

import _procs_nodes as N
from repro.core import (AllToAll, Farm, FnNode, KeyAffinity, LoweringError,
                        Pipeline, Stage, fuse, lower, partition_by,
                        reduce_by_key, stable_hash, window)
from repro.core.skeleton import FusedNode


def ref_rbk(xs, by, fold, seed=None):
    d = {}
    for x in xs:
        k = by(x)
        d[k] = fold(d[k], x) if k in d else (x if seed is None else fold(seed, x))
    return d


# Programs are built once at module scope: the mesh keyed shuffle caches
# its compiled shard_map per (rows, dtype) bucket, and every hypothesis
# example reuses one compile.  All THREE backends lower the same IR node.
RBK = reduce_by_key(N.mod5, "sum", nleft=2, nright=3, nkeys=5)
RBK_T = lower(RBK, "threads")
RBK_M = lower(RBK, "mesh")
RBK_P = lower(RBK, "procs")


# -- acceptance: three-backend parity on the same keyed reduction ------------
@given(st.lists(st.integers(0, 1000), max_size=40))
@settings(max_examples=8, deadline=None)
def test_reduce_by_key_parity_threads_mesh(xs):
    """The same reduce_by_key IR: host N×M shuffle + per-key fold vs the
    one-shard_map segment exchange — identical key→fold maps."""
    want = ref_rbk(xs, N.mod5, lambda a, b: a + b)
    assert dict(RBK_T(xs)) == want
    assert dict(RBK_M(xs)) == want


# Procs parity draws fewer examples: every example spawns a full process
# network (2 left + 3 right + scatter), which costs seconds.
@given(st.lists(st.integers(0, 1000), max_size=16))
@settings(max_examples=3, deadline=None)
def test_reduce_by_key_parity_procs(xs):
    assert dict(RBK_P(xs)) == ref_rbk(xs, N.mod5, lambda a, b: a + b)


def test_parity_empty_stream():
    assert RBK_T([]) == RBK_M([]) == RBK_P([]) == []


@pytest.mark.parametrize("fold,ref", [("min", min), ("max", max)])
def test_named_folds_threads_vs_mesh(fold, ref):
    xs = list(range(7, 43))
    skel = reduce_by_key(N.mod5, fold, nkeys=5)
    want = ref_rbk(xs, N.mod5, ref)
    assert dict(lower(skel, "threads")(xs)) == want
    assert dict(lower(skel, "mesh")(xs)) == want


def test_count_fold_threads_vs_mesh():
    xs = list(range(23))
    skel = reduce_by_key(N.mod5, "count", nkeys=5)
    want = {k: sum(1 for x in xs if x % 5 == k) for k in range(5)}
    assert dict(lower(skel, "threads")(xs)) == want
    assert dict(lower(skel, "mesh")(xs)) == want


def test_mesh_float_fold_tolerance():
    xs = [0.25 * i for i in range(40)]
    skel = reduce_by_key(N.mod2int, "sum", nkeys=2)
    t = dict(lower(skel, "threads")(xs))
    m = dict(lower(skel, "mesh")(xs))
    assert set(t) == set(m)
    for k in t:
        np.testing.assert_allclose(t[k], m[k], rtol=1e-5)


# -- EOS fan-in termination + key-partition integrity (nleft != nright) ------
def test_eos_fanin_nleft_ne_nright_threads():
    """A 3×2 matrix terminates by per-edge EOS counting: each right vertex
    waits for all 3 left EOSes, and no item is lost or duplicated."""
    skel = AllToAll(N.double, [N.TagPartition(0), N.TagPartition(1)],
                    by=N.mod3, nleft=3, nright=2)
    out = lower(skel, "threads")(range(200))
    assert sorted(v for _, v in out) == sorted(x * 2 for x in range(200))
    owners = {}
    for j, v in out:
        owners.setdefault(N.mod3(v), set()).add(j)
    # key-affinity: every key serviced by exactly one right vertex
    assert all(len(s) == 1 for s in owners.values()), owners


def test_eos_fanin_nleft_ne_nright_procs():
    """Same matrix across processes: stable_hash keeps all left vertices
    (separate interpreters, separate hash salts) agreeing on key owners."""
    skel = AllToAll(N.double, [N.TagPartition(0), N.TagPartition(1)],
                    by=N.mod3, nleft=3, nright=2)
    out = lower(skel, "procs")(range(60))
    assert sorted(v for _, v in out) == sorted(x * 2 for x in range(60))
    owners = {}
    for j, v in out:
        owners.setdefault(N.mod3(v), set()).add(j)
    assert all(len(s) == 1 for s in owners.values()), owners


def test_matrix_topology_is_nxm():
    """The threads lowering wires exactly N×M edges between the rows, one
    private ring per (left, right) pair — no arbiter between the layers."""
    skel = AllToAll(N.double, N.double, by=N.mod3, nleft=3, nright=4)
    g = lower(skel, "threads").to_graph(list(range(8)))
    lefts = [v for v in g.vertices if "-L" in v.name]
    rights = [v for v in g.vertices if "-R" in v.name]
    assert len(lefts) == 3 and len(rights) == 4
    assert all(len(lv.outs) == 4 for lv in lefts)
    assert all(len(rv.ins) == 3 for rv in rights)


# -- ordered= via the tagged-token machinery ---------------------------------
def test_ordered_a2a_preserves_stream_order():
    skel = AllToAll(N.double, N.double, by=N.mod3, nleft=2, nright=3,
                    ordered=True)
    xs = list(range(80))
    assert lower(skel, "threads")(xs) == [x * 4 for x in xs]


def test_ordered_a2a_procs():
    skel = AllToAll(N.double, N.double, by=N.mod3, nleft=2, nright=3,
                    ordered=True)
    xs = list(range(24))
    assert lower(skel, "procs")(xs) == [x * 4 for x in xs]


# -- composability inside Pipeline -------------------------------------------
def test_a2a_composes_in_pipeline_threads_and_procs():
    """Stage → shuffle → Stage: the downstream stage fan-in-merges the
    right row's rings (EOS counted per edge) on both host backends."""
    skel = Pipeline(Stage(N.double), reduce_by_key(N.mod3, "sum", nright=2),
                    Stage(N.second))
    want = ref_rbk([x * 2 for x in range(30)], N.mod3, lambda a, b: a + b)
    assert sorted(lower(skel, "threads")(range(30))) == sorted(want.values())
    assert sorted(lower(skel, "procs")(range(30))) == sorted(want.values())


def test_a2a_into_farm():
    """A Farm after an AllToAll: the dispatch arbiter merges the matrix's
    output rings like any other fan-in."""
    skel = Pipeline(AllToAll(N.double, N.double, by=N.mod3, nleft=2, nright=2),
                    Farm(N.f, 3))
    out = lower(skel, "threads")(range(40))
    assert sorted(out) == sorted(N.f(x * 4) for x in range(40))


# -- fuse must not cross an AllToAll boundary --------------------------------
def test_fuse_does_not_cross_a2a():
    a2a = reduce_by_key(N.mod3, "sum", nright=2)
    skel = Pipeline(Stage(N.f, grain=1), Stage(N.g, grain=1), a2a,
                    Stage(N.second, grain=1), Stage(N.double, grain=1))
    fused = fuse(skel, force=True)
    assert isinstance(fused, Pipeline)
    kinds = [type(s) for s in fused.stages]
    assert kinds == [Stage, AllToAll, Stage]
    assert fused.stages[1] is a2a  # the shuffle is untouched, not rebuilt
    assert isinstance(fused.stages[0].node, FusedNode)
    assert isinstance(fused.stages[2].node, FusedNode)
    # and the fused pipeline still computes the same reduction
    want = ref_rbk([N.g(N.f(x)) for x in range(20)], N.mod3,
                   lambda a, b: a + b)
    want = sorted(v * 2 for v in want.values())
    assert sorted(lower(fused, "threads", fuse=False)(range(20))) == want


def test_fused_stage_flushes_svc_eos():
    """Fusing a window stage with a neighbour must not lose the EOS flush:
    FusedNode chains each constituent's svc_eos through the rest."""
    skel = Pipeline(window(4, "sum"), Stage(N.double, grain=1))
    fused = fuse(skel, force=True)
    assert not isinstance(fused, Pipeline)  # collapsed into one stage
    assert lower(fused, "threads")(range(10)) == [12, 44, 34]
    assert lower(skel, "threads", fuse=False)(range(10)) == [12, 44, 34]


# -- stream_ops --------------------------------------------------------------
def test_window_tumbling_and_eos_flush():
    w = window(4, "sum")
    assert lower(w, "threads")(range(10)) == [6, 22, 17]
    assert lower(w, "procs")(range(10)) == [6, 22, 17]
    assert lower(window(3, "max"), "threads")([5, 1, 9, 2, 8]) == [9, 8]
    assert lower(window(5, "sum"), "threads")([]) == []


def test_partition_by_pure_shuffle():
    out = lower(partition_by(N.mod3, 3), "threads")(range(50))
    assert sorted(out) == list(range(50))


def test_partition_by_class_instantiates_per_partition():
    skel = partition_by(N.mod3, 2, worker=N.Dedup)
    out = lower(skel, "threads")([1, 2, 1, 3, 2, 4, 1])
    assert sorted(out) == [1, 2, 3, 4]
    assert len({id(n) for n in skel.right_nodes}) == 2  # fresh per partition


def test_custom_callable_fold_host_backends():
    skel = reduce_by_key(N.mod3, N.keep_larger)
    xs = [3, 10, 5, 9, 14, 2]
    want = ref_rbk(xs, N.mod3, N.keep_larger)
    assert dict(lower(skel, "threads")(xs)) == want


# -- KeyAffinity scheduling policy -------------------------------------------
def test_keyaffinity_farm_threads_and_procs():
    farm = Farm([N.TagPartition(0), N.TagPartition(1), N.TagPartition(2)],
                scheduling=KeyAffinity(N.mod3))
    for backend, n in (("threads", 60), ("procs", 18)):
        out = lower(farm, backend)(range(n))
        owners = {}
        for j, x in out:
            owners.setdefault(N.mod3(x), set()).add(j)
        assert all(len(s) == 1 for s in owners.values()), (backend, owners)


def test_keyaffinity_stage_route():
    """route()-based policies are legal for Stage fan-out (unlike
    token-holding place() policies such as worksteal)."""
    from repro.core.graph import StageVertex
    v = StageVertex(FnNode(N.double), route=KeyAffinity(N.mod3))
    assert v._sched is not None
    with pytest.raises(ValueError, match="token-holding"):
        StageVertex(FnNode(N.double), route="worksteal")


def test_stable_hash_is_deterministic_and_typed():
    assert stable_hash(7) == 7 and stable_hash(-3) == -3
    assert stable_hash(True) == 1
    assert stable_hash("tenant-a") == stable_hash("tenant-a")
    assert stable_hash(b"k") == stable_hash(b"k")
    assert stable_hash(("a", 1)) == stable_hash(("a", 1))
    assert stable_hash(("a", 1)) != stable_hash(("a", 2))
    assert stable_hash((2 ** 80, "x")) == stable_hash((2 ** 80, "x"))
    assert stable_hash(None) == 0 and stable_hash(2.5) == stable_hash(2.5)
    # frozensets combine order-independently (their iteration order is
    # interpreter-salted — the exact trap stable_hash exists to avoid)
    assert stable_hash(frozenset({"a", "b", "c"})) == \
        stable_hash(frozenset({"c", "a", "b"}))


def test_stable_hash_is_stable_across_interpreters():
    """The whole point: a spawned vertex with a different hash salt must
    compute identical routes (builtin hash('x') would differ)."""
    import subprocess
    import sys

    code = ("import sys; sys.path.insert(0, 'src')\n"
            "from repro.core import stable_hash\n"
            "print(stable_hash('tenant-a'), stable_hash(('a', frozenset("
            "{'x', 'y'}))))")
    outs = {subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=_REPO, env={**_ENV, "PYTHONHASHSEED": str(seed)},
    ).stdout for seed in (1, 2, 3)}
    assert len(outs) == 1 and outs != {""}, outs


def test_stable_hash_agrees_with_dict_equality_for_numbers():
    """dict-equal keys (3 == 3.0 == True==1, -0.0 == 0.0) fold together at
    the right vertex, so they must route together too — a type-sensitive
    hash would split one logical key across partitions."""
    assert stable_hash(3.0) == stable_hash(3)
    assert stable_hash(-0.0) == stable_hash(0.0) == stable_hash(0)
    assert stable_hash(True) == stable_hash(1)
    assert stable_hash(2.5) == stable_hash(2.5)  # non-integral still works
    # end to end: a mixed int/float stream folds each logical key once
    skel = reduce_by_key(N.mod3, "sum", nright=3)
    out = dict(lower(skel, "threads")([3, 3.0, 4, 4.0]))
    assert out == {0: 6.0, 1: 8.0}, out


def test_mesh_rejects_out_of_range_keys():
    """Keys outside [0, nkeys) must raise, not silently clip into the
    boundary segment (the host backends would fold them correctly, so
    clipping is a silent three-backend divergence)."""
    skel = reduce_by_key(N.mod7, "sum", nkeys=5)
    with pytest.raises(LoweringError, match="nkeys"):
        lower(skel, "mesh")(range(35))
    # in-range keys on the same program shape still work
    ok = reduce_by_key(N.mod5, "sum", nkeys=5)
    assert dict(lower(ok, "mesh")(range(35))) == \
        ref_rbk(range(35), N.mod5, lambda a, b: a + b)


def test_stable_hash_rejects_unstable_key_types():
    class Opaque:
        pass

    with pytest.raises(TypeError, match="process-stable"):
        stable_hash(Opaque())
    with pytest.raises(TypeError, match="process-stable"):
        stable_hash({"a": 1})  # dicts: use sorted tuples instead


def test_ordered_a2a_rejects_multi_emit():
    """Tags are 1:1: a left node multi-emitting under ordered= must fail
    loudly instead of routing the EmitMany container as one payload."""
    skel = AllToAll(N.emit_twice, N.double, by=N.mod3, nleft=2, nright=2,
                    ordered=True)
    with pytest.raises(RuntimeError, match="EmitMany"):
        lower(skel, "threads")(range(8))
    # unordered multi-emit routes per element, as StageVertex would
    out = lower(AllToAll(N.emit_twice, N.double, by=N.mod3, nright=2),
                "threads")(range(8))
    assert sorted(out) == sorted([x * 2 for x in range(8)] * 2)


# -- error contracts ---------------------------------------------------------
def test_mesh_rejects_generic_a2a():
    with pytest.raises(LoweringError, match="keyed"):
        lower(AllToAll(N.double, N.double, by=N.mod3, nright=2), "mesh")


def test_mesh_rejects_custom_fold():
    with pytest.raises(LoweringError, match="keyed"):
        lower(reduce_by_key(N.mod3, N.keep_larger, nkeys=3), "mesh")


def test_mesh_rejects_missing_nkeys():
    with pytest.raises(LoweringError, match="nkeys"):
        lower(reduce_by_key(N.mod3, "sum"), "mesh")


def test_mesh_rejects_stage_after_shuffle():
    with pytest.raises(LoweringError, match="ONE AllToAll"):
        lower(Pipeline(reduce_by_key(N.mod3, "sum", nkeys=3),
                       Stage(N.second)), "mesh")


def test_a2a_rejects_token_holding_scatter_policy():
    with pytest.raises(ValueError, match="token-holding"):
        AllToAll(N.double, N.double, nleft=2, nright=2,
                 scheduling="worksteal")


def test_ordered_a2a_requires_upstream():
    skel = AllToAll(N.double, N.double, by=N.mod3, ordered=True)
    with pytest.raises(LoweringError, match="upstream"):
        lower(skel, "threads").to_graph(None)


def test_ordered_reduce_is_rejected_at_ir():
    with pytest.raises(AssertionError, match="unordered|undefined"):
        AllToAll(N.double, N.double, by=N.mod3, ordered=True,
                 reduce=object())


def test_unknown_fold_name():
    with pytest.raises(ValueError, match="unknown fold"):
        reduce_by_key(N.mod3, "median")


# -- init= conflicts with a self-seeding fold spec (regression) --------------
def test_init_conflicts_with_named_fold():
    with pytest.raises(ValueError, match="conflicts with the named fold"):
        reduce_by_key(N.mod3, "sum", init=5)
    with pytest.raises(ValueError, match="conflicts with the named fold"):
        window(3, "count", init=2)
    with pytest.raises(ValueError, match="conflicts with the named fold"):
        reduce_by_key(N.mod3, "count", init=0)  # 0 is a conflict, not falsy


def test_init_conflicts_with_fold_spec():
    from repro.core import FOLDS
    with pytest.raises(ValueError, match="conflicts with the Fold spec"):
        reduce_by_key(N.mod3, FOLDS["max"], init=0)
    with pytest.raises(ValueError, match="conflicts with the Fold spec"):
        window(2, FOLDS["min"], init=1)


def test_init_with_bare_callable_seeds_the_accumulator():
    # the documented escape hatch: a bare callable takes a custom seed
    out = lower(window(2, N.keep_larger, init=100), "threads")([3, 7, 50, 9])
    assert out == [100, 100]  # every window folds from the 100 seed
    skel = reduce_by_key(N.mod3, N.keep_larger, init=1000)
    assert dict(lower(skel, "threads")([5, 9, 14])) == {0: 1000, 2: 1000}


# -- three backends, same skeleton objects, new lowering options -------------
def test_three_backend_parity_with_batched_zero_copy_procs():
    xs = list(range(64))
    want = ref_rbk(xs, N.mod5, lambda a, b: a + b)
    assert dict(lower(RBK, "threads")(xs)) == want
    assert dict(lower(RBK, "procs", batch=8, zero_copy=True)(xs)) == want
    assert dict(lower(RBK, "mesh")(xs)) == want
