"""Unified observability layer (repro.core.obs): vertex tracing, the
metrics registry, and the run-report surface.

The load-bearing pins:

* span-topology parity — the SAME skeleton lowered to threads and procs
  produces the SAME lanes with the SAME span vocabulary, because the
  vertex names and IR paths are backend-neutral (the whole point of
  qualifying telemetry by IR path instead of by runtime object);
* tracing off allocates NOTHING in obs.py — the overhead claim is
  structural (vertices carry ``tracer = None`` and never enter the
  module), not statistical;
* Chrome trace-event export is schema-valid: every event is a metadata
  ("M"), complete ("X", dur >= 0) or instant ("i", thread scope) record
  tied to a named lane.
"""
import json
import tracemalloc

import pytest

from repro.core import (Farm, Histogram, MetricsRegistry, Pipeline, Stage,
                        Tracer, lower)
from repro.core import obs as obs_mod
from tests._procs_nodes import double, f, g

SKEL = Pipeline(Stage(f), Farm(double, nworkers=2), Stage(g))
XS = list(range(120))
WANT = sorted(g(double(f(x))) for x in XS)


def _lane_topology(trace):
    """(qualname, span-kind set) per lane — the backend-neutral shape."""
    return {vt.qualname: frozenset(e[0] for e in vt.events
                                   if e[0] in obs_mod.SPAN_KINDS)
            for vt in trace.lanes}


# -- parity ------------------------------------------------------------------
def test_span_topology_parity_threads_procs():
    tprog = lower(SKEL, "threads", trace=True)
    assert sorted(tprog(XS)) == WANT
    pprog = lower(SKEL, "procs", trace=True)
    assert sorted(pprog(XS)) == WANT

    tt, pt = _lane_topology(tprog.last_trace), _lane_topology(pprog.last_trace)
    assert sorted(tt) == sorted(pt), (sorted(tt), sorted(pt))
    for qual in tt:
        assert tt[qual] == pt[qual], (qual, tt[qual], pt[qual])
    # the farm lanes exist under their backend-neutral names, qualified
    # by the farm's IR path (stage 1 of the pipeline)
    for qual in ("ff-emitter@1", "ff-collector@1", "ff-worker-0@1",
                 "ff-worker-1@1", "ff-stage@0", "ff-stage@2",
                 "ff-source@in"):
        assert qual in tt, (qual, sorted(tt))
    # every lane closed out: exactly one eos instant and one life span
    for trace in (tprog.last_trace, pprog.last_trace):
        for vt in trace.lanes:
            kinds = [e[0] for e in vt.events]
            assert kinds.count("eos") == 1, (vt.qualname, kinds)
            assert kinds.count("life") == 1, (vt.qualname, kinds)


def test_mesh_program_level_events():
    pytest.importorskip("jax")
    prog = lower(Farm(double, nworkers=2), "mesh", trace=True, metrics=True)
    out = prog([float(x) for x in range(32)])
    assert sorted(out) == [2.0 * x for x in range(32)]
    tr = prog.last_trace
    assert tr.qualnames() == ["mesh-program"]
    kinds = [e[0] for e in tr.events()]
    assert "devices" in kinds and "compile" in kinds and "call" in kinds
    # a second same-shaped call reuses the compile: calls grow, compiles
    # don't
    prog([float(x) for x in range(32)])
    assert prog.metrics.counter("mesh.compiles").value == 1
    assert prog.metrics.counter("mesh.calls").value == 2


# -- overhead: tracing off touches obs.py not at all -------------------------
def test_tracer_off_allocates_nothing():
    prog = lower(SKEL, "threads")  # no trace=
    prog(XS)  # warm the lowering before the snapshot window
    tracemalloc.start()
    try:
        assert sorted(prog(XS)) == WANT
        snap = tracemalloc.take_snapshot()
    finally:
        tracemalloc.stop()
    obs_allocs = snap.filter_traces(
        [tracemalloc.Filter(True, obs_mod.__file__)])
    total = sum(s.size for s in obs_allocs.statistics("filename"))
    assert total == 0, f"tracing-off run allocated {total}B in obs.py"
    assert prog.last_trace is None and prog.last_report is None


# -- chrome export -----------------------------------------------------------
def test_chrome_json_schema_valid(tmp_path):
    prog = lower(SKEL, "threads", trace=True)
    prog(XS)
    path = tmp_path / "trace.json"
    doc = prog.last_trace.to_chrome_json(str(path))
    on_disk = json.loads(path.read_text())
    assert on_disk == doc
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert evs, "empty export"
    lanes_named = set()
    for e in evs:
        assert {"name", "ph", "pid", "tid"} <= set(e), e
        if e["ph"] == "M":
            assert e["name"] == "thread_name" and e["args"]["name"]
            lanes_named.add((e["pid"], e["tid"]))
        elif e["ph"] == "X":
            assert e["dur"] >= 0 and "ts" in e, e
        else:
            assert e["ph"] == "i" and e["s"] == "t" and "ts" in e, e
    # every event's lane carries a thread_name metadata record
    for e in evs:
        assert (e["pid"], e["tid"]) in lanes_named, e


# -- metrics + run report ----------------------------------------------------
def test_histogram_percentiles_and_merge():
    h = Histogram("t")
    for v in range(1, 101):
        h.observe(float(v))
    assert h.count == 100 and h.mean == pytest.approx(50.5)
    assert 50.0 <= h.p50 <= 52.0
    assert 95.0 <= h.p95 <= 97.0
    assert 99.0 <= h.p99 <= 100.0
    other = Histogram("t")
    other.observe(1000.0)
    h.merge(other)
    assert h.count == 101 and h.vmax == 1000.0


def test_metrics_registry_watch_fires_on_finalize():
    reg = MetricsRegistry()
    seen = []
    reg.watch(seen.append)
    reg.counter("c").inc(3)
    rep = reg.finalize(reg.report(meta={"k": "v"}))
    assert seen == [rep]
    assert rep.counters == {"c": 3} and rep.meta == {"k": "v"}


def test_run_report_merge_across_procs_farm_runs():
    # fresh skeletons: FarmStats boards are cumulative per-skeleton, and
    # the merge semantics under test are per-report
    prog1 = lower(Farm(double, nworkers=2), "procs", metrics=True)
    assert sorted(prog1(range(60))) == [2 * x for x in range(60)]
    first = prog1.last_report
    prog2 = lower(Farm(double, nworkers=2), "procs", metrics=True)
    assert sorted(prog2(range(40))) == [2 * x for x in range(40)]
    second = prog2.last_report
    for rep in (first, second):
        assert "ff-farm" in rep.farms, rep.farms
        assert rep.meta["backend"] == "procs"
        assert rep.queues, "no high-water marks sampled"
    merged = first.merge(second)
    assert merged.meta["items_in"] == 40  # meta is last-write
    fs = merged.farms["ff-farm"]
    assert fs["tasks_collected"] == 40  # farms too: one stats board re-read
    for k, v in second.queues.items():
        assert merged.queues[k] >= v


def test_run_report_json_round_trip(tmp_path):
    prog = lower(SKEL, "threads", metrics=True)
    prog(XS)
    rep = prog.last_report
    p = tmp_path / "report.json"
    rep.save(str(p))
    doc = json.loads(p.read_text())
    assert doc["schema"] == "run-report/1"
    assert doc["meta"]["items_out"] == len(XS)
    assert "ff-farm@1" in doc["farms"], doc["farms"]


def test_queue_highwater_keys_namespace_by_ir_path():
    # two stages sharing a default name land at different IR paths, so
    # their telemetry keys cannot collide
    prog = lower(Pipeline(Stage(f), Stage(g)), "threads", metrics=True)
    prog(range(50))
    keys = set(prog.last_report.queues)
    assert "ff-stage@0" in keys, keys
    assert "ff-source@in" in keys, keys


def test_report_to_profile():
    # fresh skeleton: the shared SKEL's stats board is cumulative
    skel = Pipeline(Stage(f), Farm(double, nworkers=2), Stage(g))
    prog = lower(skel, "threads", metrics=True)
    prog(XS)
    prof = prog.last_report.to_profile()
    farm_rows = [s for s in prof.stages if s.kind == "farm"]
    assert farm_rows and farm_rows[0].items == len(XS)


def test_tracer_sampling_and_capacity_bounds():
    vt = Tracer(sample=4, capacity=8).vertex("v")
    for _ in range(64):
        t0 = vt.begin()
        vt.end(t0, "svc")
    # 1-in-4 sampling over 64 spans = 16 sampled, capacity 8 keeps 8
    assert len(vt.events) == 8
    assert vt.dropped == 8


# -- hist merge: reservoir samples concatenate, not last-write ---------------
def _hist_report(vals, cap=8):
    reg = MetricsRegistry()
    h = reg.histogram("lat", cap=cap)
    for v in vals:
        h.observe(float(v))
    return reg.report()


def test_run_report_hist_merge_is_commutative():
    """merge() on a reservoir histogram used to be last-writer-wins: the
    second report's percentiles replaced the first's.  The samples must
    concatenate (capped at the window size) so both sides survive, and
    a.merge(b) must equal b.merge(a)."""
    lows, highs = [1.0] * 50, [1000.0] * 50
    ab = _hist_report(lows).merge(_hist_report(highs)).hists["lat"]
    ba = _hist_report(highs).merge(_hist_report(lows)).hists["lat"]
    assert ab == ba, (ab, ba)
    assert ab["count"] == 100
    assert ab["mean"] == pytest.approx(500.5)
    # both populations survived into the merged reservoir
    assert min(ab["samples"]) == 1.0 and max(ab["samples"]) == 1000.0
    assert len(ab["samples"]) <= ab["cap"]  # capped at the window size
    assert ab["p99"] == 1000.0


def test_run_report_hist_merge_three_way_keeps_all_populations():
    """Chained merges subsample (the reservoir is bounded), so exact
    associativity is out of reach — but the lifetime count/mean stay
    exact in any order, and every population must survive into the
    final reservoir regardless of merge order."""
    parts = ([5.0] * 20, [50.0] * 20, [500.0] * 20)
    fwd = _hist_report(parts[0]).merge(
        _hist_report(parts[1])).merge(_hist_report(parts[2]))
    rev = _hist_report(parts[2]).merge(
        _hist_report(parts[1])).merge(_hist_report(parts[0]))
    for h in (fwd.hists["lat"], rev.hists["lat"]):
        assert h["count"] == 60
        assert h["mean"] == pytest.approx(185.0)
        assert h["max"] == 500.0
        assert {5.0, 50.0, 500.0} <= set(h["samples"]), h["samples"]
        assert len(h["samples"]) <= h["cap"]


# -- short runs: the drain-time tap lands exactly one sample per edge --------
def test_short_run_samples_every_edge():
    """A one-item stream finishes before the caller-side poll loop's
    first tick; the drain sampler inside wait() must still land one
    high-water sample per edge — no key may be missing, and the sink
    edge must not race the results drain."""
    skel = Pipeline(Stage(f), Farm(double, nworkers=2), Stage(g))
    prog = lower(skel, "threads", metrics=True)
    assert prog(range(1)) == [g(double(f(0)))]
    keys = set(prog.last_report.queues)
    assert {"ff-source@in", "ff-stage@0", "ff-emitter@1", "ff-worker-0@1",
            "ff-worker-1@1", "ff-collector@1"} <= keys, keys
