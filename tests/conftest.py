# NOTE: deliberately NO XLA_FLAGS device-count forcing here — unit/smoke
# tests must see the real single CPU device (the dry-run forces 512 devices
# itself, and multi-device semantics tests spawn subprocesses).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
