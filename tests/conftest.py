# NOTE: deliberately NO XLA_FLAGS device-count forcing here — unit/smoke
# tests must see the real single CPU device (the dry-run forces 512 devices
# itself, and multi-device semantics tests spawn subprocesses).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

# Property-based tests use hypothesis when available (requirements-test.txt);
# hermetic environments fall back to the in-repo shim, which degrades @given
# to a deterministic example-based sweep so the suites still collect and run.
try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import _hypothesis_shim

    _hypothesis_shim.install()
