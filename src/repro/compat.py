"""JAX version-compat shims, centralised.

The repo targets a range of JAX versions; the pinned container ships
0.4.x, where several APIs the newer code paths use do not exist yet:

  * ``jax.sharding.AxisType`` / ``jax.make_mesh(..., axis_types=...)``
    (explicit-sharding axis typing landed in 0.5/0.6);
  * ``jax.shard_map`` as a top-level API with ``check_vma=`` (0.4.x has
    ``jax.experimental.shard_map.shard_map`` with ``check_rep=``);
  * ``jax.lax.pvary`` and ``jax.typeof(...).vma`` (varying-manual-axes
    typing).

Everything that needs one of these goes through this module so the
version split lives in exactly one place.  All helpers degrade to the
closest older-API equivalent, never to a behaviour change.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax

__all__ = ["HAS_AXIS_TYPE", "HAS_TOP_LEVEL_SHARD_MAP", "HAS_PVARY",
           "HAS_AXIS_SIZE", "WHILE_NEEDS_UNCHECKED_REP", "make_mesh",
           "shard_map", "pvary", "needs_pvary", "axis_size", "vma_align"]

HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
HAS_TOP_LEVEL_SHARD_MAP = hasattr(jax, "shard_map")
HAS_PVARY = hasattr(jax.lax, "pvary")
HAS_AXIS_SIZE = hasattr(jax.lax, "axis_size")

# 0.4.x's experimental shard_map replication checker has no rule for
# ``lax.while_loop`` ("No replication rule for while"); the vma-typed
# checker that ships with top-level ``jax.shard_map`` does.  Callers putting
# a while_loop inside shard_map (the skeleton mesh backend's feedback farm)
# must disable the checker on old JAX — behaviour is unchanged, only the
# static replication audit is skipped.
WHILE_NEEDS_UNCHECKED_REP = not HAS_TOP_LEVEL_SHARD_MAP


def axis_size(axis_name: str) -> int:
    """Static size of a manual mesh axis, from inside ``shard_map``.

    ``lax.axis_size`` is recent; on older JAX the classic idiom
    ``psum(1, axis)`` constant-folds to the same static int."""
    if HAS_AXIS_SIZE:
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, auto: bool = True) -> "jax.sharding.Mesh":
    """``jax.make_mesh`` with ``AxisType.Auto`` axes where supported.

    On JAX without ``jax.sharding.AxisType`` every mesh axis is implicitly
    auto, so simply omitting ``axis_types`` is the exact equivalent.
    """
    if HAS_AXIS_TYPE and auto:
        types = (jax.sharding.AxisType.Auto,) * len(tuple(axis_names))
        return jax.make_mesh(axis_shapes, axis_names, axis_types=types)
    return jax.make_mesh(axis_shapes, axis_names)


def shard_map(fn, *, mesh, in_specs, out_specs,
              check_vma: Optional[bool] = None):
    """Top-level ``jax.shard_map`` if present, else the 0.4.x experimental
    one.  ``check_vma`` maps onto the older ``check_rep`` (both toggle the
    replication/varying-axes checker)."""
    if HAS_TOP_LEVEL_SHARD_MAP:
        kwargs = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    kwargs = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kwargs)


def pvary(x: Any, axis_names: Sequence[str]) -> Any:
    """``lax.pvary`` where it exists; identity on older JAX, where manual
    values are not tracked as axis-varying and no cast is needed."""
    if HAS_PVARY:
        return jax.lax.pvary(x, tuple(axis_names))
    return x


def needs_pvary(x: Any, axis_name: str) -> bool:
    """True if ``x`` does not yet vary over ``axis_name`` (shard_map vma
    typing).  Always False on JAX without vma typing."""
    if not HAS_PVARY:
        return False
    try:
        return axis_name not in jax.typeof(x).vma
    except Exception:  # pragma: no cover - vma typing shape changed
        return False


def vma_align(x: Any, axis_names: Sequence[str]) -> Any:
    """Make ``x`` vary over every axis in ``axis_names`` it does not vary
    over yet.

    The skeleton mesh lowering mixes values of different provenance inside
    one ``shard_map`` body — stage-invariant microbatches, worker-varying
    farm buffers, ``axis_index``-derived stage selectors — and newer JAX's
    varying-manual-axes typing requires the operands of ``select_n`` /
    ``where`` / ``ppermute`` to agree.  On JAX without vma typing (0.4.x)
    manual values carry no axis-varying type and this is the identity."""
    if not HAS_PVARY:
        return x
    missing = tuple(a for a in axis_names if needs_pvary(x, a))
    return pvary(x, missing) if missing else x
