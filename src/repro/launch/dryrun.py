import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede every other import: jax locks the device count on first init.
os.environ.setdefault("REPRO_DRYRUN_WIRE", "f16")  # bf16-width collectives on CPU
# (No `from __future__` here for the same reason — keep the two lines first.)
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves the distribution config is coherent end-to-end:
the sharded step function partitions over the production mesh, compiles,
and reports memory_analysis() (fits / doesn't) and cost_analysis() (FLOPs,
bytes) plus the collective schedule parsed from the optimized HLO — the
inputs to EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-mini-3.8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both --out reports/dryrun
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax

from ..configs import ARCHS, SHAPES, cell_applicable
from ..parallel.context import mesh_context
from .mesh import DP_AXES, make_production_mesh
from .steps import input_specs, step_fn_for

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def parse_collectives(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the optimized HLO."""
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    dtype_bytes = {"f64": 8, "f32": 4, "s32": 4, "u32": 4, "bf16": 2,
                   "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                   "s64": 8, "u64": 8, "c64": 8}
    # Strip /*index=N*/ comments (their '=' breaks definition matching for
    # tuple-shaped collectives), then match DEFINITIONS only:
    # "%x = f32[...]{...} all-gather(..." / "%x = (bf16[..], ...) all-to-all(...".
    # The opcode must be followed by "(" — otherwise operand *references*
    # (e.g. "fusion(%all-reduce.1)") would count once per consumer.
    hlo_text = re.sub(r"/\*.*?\*/", "", hlo_text)
    pat = re.compile(r"=\s*(\(?[^=\n]*?)\s(" + "|".join(COLLECTIVES) +
                     r")(?:-start)?(?:\.\d+)?\(")
    shape_pat = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
    for m in pat.finditer(hlo_text):
        blob, op = m.group(1), m.group(2)
        total = 0
        for dt, dims in shape_pat.findall(blob):   # sums all tuple elements
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * dtype_bytes.get(dt, 4)
        out[op]["count"] += 1
        out[op]["bytes"] += total
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, compile_: bool = True,
             unroll: bool = False, n_layers: int = 0) -> dict:
    cfg = ARCHS[arch]
    shape = next(s for s in SHAPES if s.name == shape_name)
    ok, why = cell_applicable(arch, shape)
    os.environ["REPRO_UNROLL"] = "1" if unroll else "0"
    if n_layers:
        cfg = cfg.replace(n_layers=n_layers)
    if unroll:
        # bigger tiles: same FLOPs, far fewer unrolled chunk bodies to compile
        cfg = cfg.replace(attn_q_chunk=4096, attn_kv_chunk=4096,
                          loss_chunk=0 if shape.kind != "train" else 4096)
    # §Perf hillclimb variant knobs (recorded in the output record)
    variant = {}
    if os.environ.get("REPRO_MOE_BACKEND"):
        variant["moe_backend"] = os.environ["REPRO_MOE_BACKEND"]
    if os.environ.get("REPRO_SSM_BF16") == "1":
        variant["ssm_compute_dtype"] = "bfloat16"
    if os.environ.get("REPRO_LOSS_CHUNK"):
        variant["loss_chunk"] = int(os.environ["REPRO_LOSS_CHUNK"])
    if os.environ.get("REPRO_MOE_WIRE"):
        variant["moe_wire_dtype"] = os.environ["REPRO_MOE_WIRE"]
    if os.environ.get("REPRO_SSM_CHUNK"):
        variant["ssm_chunk"] = int(os.environ["REPRO_SSM_CHUNK"])
    if os.environ.get("REPRO_CAUSAL_SKIP") == "1":
        variant["causal_skip"] = True
    if variant:
        cfg = cfg.replace(**variant)
    rec = {"arch": arch, "shape": shape_name, "unrolled": unroll,
           "n_layers": cfg.n_layers, "variant": variant,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        rec.update(status="SKIP", reason=why)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        with mesh_context(mesh, dp_axes=DP_AXES(multi_pod)) as ctx:
            fn, argnames = step_fn_for(cfg, shape, ctx)
            specs = input_specs(cfg, shape, ctx)
            args = [specs[a] for a in argnames]
            donate = tuple(i for i, a in enumerate(argnames)
                           if a in ("opt_state", "cache"))
            jitted = jax.jit(fn, donate_argnums=donate)
            lowered = jitted.lower(*args)
            rec["lower_s"] = round(time.time() - t0, 1)
            if not compile_:
                rec["status"] = "LOWERED"
                return rec
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
            rec.update(
                status="OK",
                flops_per_device=cost.get("flops", 0.0),
                bytes_accessed_per_device=cost.get("bytes accessed", 0.0),
                argument_size=getattr(mem, "argument_size_in_bytes", 0),
                output_size=getattr(mem, "output_size_in_bytes", 0),
                temp_size=getattr(mem, "temp_size_in_bytes", 0),
                alias_size=getattr(mem, "alias_size_in_bytes", 0),
                generated_code_size=getattr(mem, "generated_code_size_in_bytes", 0),
                collectives=parse_collectives(hlo),
                n_devices=mesh.devices.size,
            )
            print(f"  memory_analysis: {mem}")
            print(f"  cost_analysis: flops/dev={rec['flops_per_device']:.3e} "
                  f"bytes/dev={rec['bytes_accessed_per_device']:.3e}")
    except Exception as e:
        rec.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=sorted(ARCHS))
    ap.add_argument("--shape", default=None, choices=[s.name for s in SHAPES])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"], default="off")
    ap.add_argument("--out", default=None, help="JSONL output path")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="fully unroll scans: exact FLOP/byte/collective "
                         "accounting (XLA counts while bodies once)")
    ap.add_argument("--layers", type=int, default=0,
                    help="override n_layers (two-point exact-cost extrapolation)")
    args = ap.parse_args()

    cells = []
    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or not args.shape) else [args.shape]
    pods = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]
    failures = 0
    out_f = open(args.out, "a") if args.out else None
    for mp in pods:
        for a in archs:
            for s in shapes:
                print(f"=== {a} × {s} × mesh={'2x16x16' if mp else '16x16'} ===",
                      flush=True)
                rec = run_cell(a, s, mp, compile_=not args.no_compile,
                               unroll=args.unroll, n_layers=args.layers)
                print(f"  -> {rec['status']}"
                      + (f" ({rec.get('reason','')})" if rec["status"] == "SKIP" else "")
                      + (f" lower={rec.get('lower_s')}s compile={rec.get('compile_s')}s"
                         if rec["status"] == "OK" else ""), flush=True)
                if rec["status"] == "FAIL":
                    failures += 1
                    print(rec["error"])
                    print(rec.get("trace", "")[-1500:])
                if out_f:
                    rec.pop("trace", None)
                    out_f.write(json.dumps(rec) + "\n")
                    out_f.flush()
                cells.append(rec)
    print(f"\n{sum(c['status']=='OK' for c in cells)} OK / "
          f"{sum(c['status']=='SKIP' for c in cells)} SKIP / {failures} FAIL "
          f"of {len(cells)} cells")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
