"""Serving driver — continuous batching as the order-preserving farm,
running ON the skeleton graph (``Source(requests) ∘ Farm(decode_step,
feedback=still_generating)``).

The mapping from paper Sec. 3.1 to an inference engine:

  Emitter   = the **admitter**: pulls requests off an SPSC ring, assigns a
              monotone tag, a decode-batch slot and KV pages from the SPMC
              ``PagePool`` (one allocating entity — the admitter; freers —
              the collector — return pages over SPSC free-rings);
  Workers   = the decode step itself: every mesh device advances its shard
              of the (continuously re-filled) batch each iteration;
  Collector = detokeniser: detects finished sequences, releases their pages,
              and emits results **in tag order** (the reorder buffer of the
              order-preserving farm).

Since the skeleton-IR redesign, ``run()`` no longer drives a hand-rolled
while loop: it lowers ``compose(Source(submitted_requests),
Farm(decode_step, feedback=still_generating))`` to the thread graph.
Requests stream through the farm's dispatch arbiter; each *decode tick*
token circulates the wrap-around (collector → emitter) SPSC ring while any
admitted sequence is still generating, and the loop-quiescence protocol —
upstream EOS ∧ all tokens retired ∧ wrap-around ring drained — is exactly
the engine's old termination condition, now provided by the runtime.  One
tick = one jitted decode step advancing the whole continuous batch, so the
batching behaviour (and ``steps_run`` accounting) is unchanged.

Requests are admitted into recycled slots mid-stream; per-slot ``start_pos``
masks each request's attention to its own KV span.  Prompt ingestion is
token-by-token (one decode step per prompt token), which keeps one jitted
step for everything; a batched prefill path is the obvious production
extension and exists as ``steps.make_prefill_step`` for the dry-run.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS
from ..core.allocator import PagePool
from ..core.obs import MetricsRegistry, Tracer
from ..core.sched import CostModel
from ..core.skeleton import Farm, Source, compose, lower
from ..core.spsc import SPSCQueue
from ..models import decode_step as model_decode, init_cache, init_params
from ..models.config import ModelConfig

__all__ = ["Request", "ServeEngine"]

_TICK = object()  # the decode-tick token circulating the wrap-around ring


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    eos_id: Optional[int] = None
    # filled by the engine:
    submitted: float = 0.0  # monotonic submit() timestamp (latency origin)
    tag: int = -1
    slot: int = -1
    start: int = -1
    generated: List[int] = dataclasses.field(default_factory=list)
    fed: int = 0  # prompt tokens consumed


class ServeEngine:
    """``slo=`` takes a :class:`~repro.core.monitor.SLOMonitor` — after
    every ``run()`` its thresholds (p99 latency over the engine's
    ``serve.request_latency_us`` histogram, goodput in tokens/s) are
    checked; alerts land in ``slo.events``, in the registry's
    ``slo.alerts`` counter (and its ``watch()`` callbacks), and as
    ``alert`` instants on an ``slo-monitor`` trace lane
    (``engine.last_trace``), time-aligned with the run."""

    def __init__(self, cfg: ModelConfig, *, max_batch: int = 4,
                 max_len: int = 256, seed: int = 0, params=None,
                 slo=None):
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.params = params if params is not None else init_params(
            cfg, jax.random.PRNGKey(seed))
        self.cache = init_cache(cfg, max_batch, max_len)
        # SPMC pool: slots are the pages (admitter allocs, collector frees)
        self.pool = PagePool(max_batch, nfreers=1)
        self.in_q = SPSCQueue(1024)
        self._pending: deque = deque()             # admitted-to-graph queue
        self.active: Dict[int, Request] = {}       # slot -> request
        self.done: Dict[int, Request] = {}         # tag -> finished request
        self.emit_next = 0
        self.results: List[Request] = []
        self.cache_len = 0
        self.tag_counter = 0
        self._step = jax.jit(
            lambda p, b, c, l: model_decode(p, b, c, l, cfg),
            donate_argnums=(2,))
        self.steps_run = 0
        self.metrics = MetricsRegistry()
        self._latency = self.metrics.histogram("serve.request_latency_us")
        self.last_report = None
        self.slo = slo
        self.tracer = None
        self.last_trace = None
        if slo is not None:
            if slo.registry is None:
                slo.registry = self.metrics
            self.tracer = Tracer()
            slo.bind(self.tracer)

    # -- emitter side --------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.submitted == 0.0:
            req.submitted = time.monotonic()
        self.in_q.push_wait(req)

    def _admit(self) -> None:
        while self.pool.available() or self.pool.drain():
            if self._pending:                      # streamed in via the graph
                nxt = self._pending.popleft()
            else:
                nxt = self.in_q.pop()
                if nxt is SPSCQueue._EMPTY:
                    return
            slot = self.pool.alloc()
            nxt.tag = self.tag_counter
            self.tag_counter += 1
            nxt.slot = slot
            nxt.start = self.cache_len
            self._reset_slot(slot)
            self.active[slot] = nxt

    def _reset_slot(self, slot: int) -> None:
        """Zero the recycled slot's cache state (SSM state must reset;
        attention K/V is masked by start_pos, zeroing is belt-and-braces)."""
        def z(leaf):
            if leaf.ndim >= 2 and leaf.shape[-4:-3] != ():  # kv caches (.., B, T, H, D)
                pass
            return leaf

        def zero_slot(leaf):
            # batch dim position differs per leaf family; all our cache
            # leaves carry batch at axis -4 (kv: L,B,T,H,D) or -3/-2 (ssm)
            for ax in range(leaf.ndim):
                if leaf.shape[ax] == self.max_batch:
                    idx = [slice(None)] * leaf.ndim
                    idx[ax] = slot
                    return leaf.at[tuple(idx)].set(0)
            return leaf

        self.cache = jax.tree.map(zero_slot, self.cache)

    # -- one farm iteration ----------------------------------------------------
    def step(self) -> None:
        self._admit()
        if not self.active:
            return
        tokens = np.zeros((self.max_batch, 1), np.int32)
        start = np.zeros((self.max_batch,), np.int32)
        for slot, req in self.active.items():
            if req.fed < len(req.prompt):
                tokens[slot, 0] = req.prompt[req.fed]
            else:
                tokens[slot, 0] = req.generated[-1] if req.generated else 0
            start[slot] = req.start
        batch = {"tokens": jnp.asarray(tokens), "start_pos": jnp.asarray(start)}
        if self.cfg.family == "audio":
            raise NotImplementedError("audio serving uses frame embeddings")
        logits, self.cache = self._step(self.params, batch, self.cache,
                                        jnp.int32(self.cache_len))
        self.cache_len += 1
        self.steps_run += 1
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        finished = []
        for slot, req in self.active.items():
            if req.fed < len(req.prompt):
                req.fed += 1          # still ingesting the prompt
                continue
            tok = int(nxt[slot])
            req.generated.append(tok)
            if (req.eos_id is not None and tok == req.eos_id) or \
               len(req.generated) >= req.max_new:
                finished.append(slot)
        # -- collector: free pages, emit in tag order -------------------------
        for slot in finished:
            req = self.active.pop(slot)
            self.pool.free(slot, 0)
            self.done[req.tag] = req
        while self.emit_next in self.done:
            req = self.done.pop(self.emit_next)
            if req.submitted:
                self._latency.observe(
                    (time.monotonic() - req.submitted) * 1e6)
            self.results.append(req)
            self.emit_next += 1

    def _drain_submitted(self) -> List[Request]:
        """Everything submitted so far, in submission order (the stream the
        serving graph's Source replays)."""
        reqs: List[Request] = []
        while True:
            r = self.in_q.pop()
            if r is SPSCQueue._EMPTY:
                return reqs
            reqs.append(r)

    def run(self, *, max_steps: int = 10_000) -> List[Request]:
        """Serve everything submitted so far, by running the serving graph

            Source(requests) ∘ Farm(decode_step, feedback=still_generating)

        to loop quiescence.  Request tasks flow from the Source through the
        farm's dispatch arbiter into the single decode worker (which owns
        params/cache — SPSC discipline makes the shared state race-free);
        the worker admits them next tick.  A ``_TICK`` token circulates the
        wrap-around ring while anything is still generating; each pass runs
        one jitted decode step over the whole continuous batch.  Results
        are emitted in tag order by the engine's reorder buffer, exactly as
        before — only the driver loop moved into the runtime.  Requests
        submitted concurrently while ticks are in flight are still served
        (``_admit`` and the ``more`` check fall through to ``in_q``); a
        run() entered with an empty queue returns immediately, as the old
        while-loop did."""
        budget = [max_steps]

        def decode_step(task):
            if task is not _TICK:
                self._pending.append(task)         # admitted on the next tick
                return ("enq",)
            self._admit()
            if self.active and self.cache_len < self.max_len and budget[0]:
                budget[0] -= 1
                self.step()
            more = bool(self.active or self._pending or len(self.in_q)) \
                and self.cache_len < self.max_len and budget[0] > 0
            return ("tick", more)

        tick_in_flight = [False]                   # touched only by the route

        def still_generating(result):
            if result[0] == "enq":
                if tick_in_flight[0]:
                    return None, []
                tick_in_flight[0] = True
                return None, [_TICK]
            _, more = result
            if more:
                tick_in_flight[0] = True   # seeded ticks arrive via Source
                return None, [_TICK]
            tick_in_flight[0] = False
            return None, []

        stream: List = self._drain_submitted()
        if self.active or self._pending:
            # a previous run() was truncated (budget / max_len): seed a
            # tick so the leftover batch resumes without new submissions
            stream.insert(0, _TICK)
        # CostModel placement: the decode worker's per-tick service time
        # feeds stats.service_ewma, so when the decode farm is widened to
        # several workers (data-parallel replicas), a replica pinned by a
        # slow sequence stops accumulating queue — requests no longer
        # serialize behind a round-robin slot.  With today's single shared
        # -cache worker it is placement-neutral, and the EWMA doubles as
        # live tick-latency telemetry.
        net = compose(Source(stream),
                      Farm(decode_step, feedback=still_generating,
                           scheduling=CostModel()))
        n_before = len(self.results)
        toks_before = sum(len(r.generated) for r in self.results)
        t0 = time.monotonic()
        prog = lower(net, "threads",
                     trace=self.tracer if self.tracer is not None else False)
        prog.to_graph().run_and_wait()
        wall = time.monotonic() - t0
        served = len(self.results) - n_before
        toks = sum(len(r.generated) for r in self.results) - toks_before
        reg = self.metrics
        reg.counter("serve.requests").inc(served)
        reg.counter("serve.tokens").inc(toks)
        reg.counter("serve.steps").inc(self.steps_run)
        if wall > 0:
            reg.gauge("serve.tokens_per_s").set(toks / wall)
        if self.slo is not None:
            # SLO pass before the final report, so last_report carries the
            # slo.alerts counter; each alert is an instant on the trace's
            # slo-monitor lane and a watch() firing of its own
            self.slo.check(self._latency,
                           goodput=(toks / wall) if wall > 0 else None)
        self.last_report = reg.finalize(reg.report(meta={
            "backend": "threads", "engine": "serve",
            "requests": served, "tokens": toks, "wall_s": wall}))
        if self.tracer is not None:
            self.last_trace = self.tracer.trace()
        return self.results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()
    cfg = ARCHS[args.arch].smoke()
    eng = ServeEngine(cfg, max_batch=4, max_len=256)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(3, 10))
        eng.submit(Request(rid=i, prompt=list(rng.integers(0, cfg.vocab_size, plen)),
                           max_new=args.max_new))
    results = eng.run()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in results)
    print(f"[serve] {len(results)} requests, {toks} tokens, "
          f"{eng.steps_run} engine steps, {toks/dt:.1f} tok/s")
    lat = eng._latency
    tok_s = eng.last_report.gauges.get("serve.tokens_per_s", 0.0) \
        if eng.last_report is not None else 0.0
    print(f"[serve] latency p50={lat.p50/1e3:.1f}ms "
          f"p95={lat.p95/1e3:.1f}ms p99={lat.p99/1e3:.1f}ms, "
          f"{tok_s:.1f} tok/s (engine wall)")
    for r in results[:4]:
        print(f"  tag={r.tag} rid={r.rid} out={r.generated[:8]}")
    assert [r.tag for r in results] == sorted(r.tag for r in results), \
        "collector must emit in tag order"


if __name__ == "__main__":
    main()
