"""Serving driver — continuous batching as the order-preserving farm.

The mapping from paper Sec. 3.1 to an inference engine:

  Emitter   = the **admitter**: pulls requests off an SPSC ring, assigns a
              monotone tag, a decode-batch slot and KV pages from the SPMC
              ``PagePool`` (one allocating entity — the admitter; freers —
              the collector — return pages over SPSC free-rings);
  Workers   = the decode step itself: every mesh device advances its shard
              of the (continuously re-filled) batch each iteration;
  Collector = detokeniser: detects finished sequences, releases their pages,
              and emits results **in tag order** (the reorder buffer of the
              order-preserving farm).

Requests are admitted into recycled slots mid-stream; per-slot ``start_pos``
masks each request's attention to its own KV span.  Prompt ingestion is
token-by-token (one decode step per prompt token), which keeps one jitted
step for everything; a batched prefill path is the obvious production
extension and exists as ``steps.make_prefill_step`` for the dry-run.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS
from ..core.allocator import PagePool
from ..core.spsc import SPSCQueue
from ..models import decode_step as model_decode, init_cache, init_params
from ..models.config import ModelConfig

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    eos_id: Optional[int] = None
    # filled by the engine:
    tag: int = -1
    slot: int = -1
    start: int = -1
    generated: List[int] = dataclasses.field(default_factory=list)
    fed: int = 0  # prompt tokens consumed


class ServeEngine:
    def __init__(self, cfg: ModelConfig, *, max_batch: int = 4,
                 max_len: int = 256, seed: int = 0, params=None):
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.params = params if params is not None else init_params(
            cfg, jax.random.PRNGKey(seed))
        self.cache = init_cache(cfg, max_batch, max_len)
        # SPMC pool: slots are the pages (admitter allocs, collector frees)
        self.pool = PagePool(max_batch, nfreers=1)
        self.in_q = SPSCQueue(1024)
        self.active: Dict[int, Request] = {}       # slot -> request
        self.done: Dict[int, Request] = {}         # tag -> finished request
        self.emit_next = 0
        self.results: List[Request] = []
        self.cache_len = 0
        self.tag_counter = 0
        self._step = jax.jit(
            lambda p, b, c, l: model_decode(p, b, c, l, cfg),
            donate_argnums=(2,))
        self.steps_run = 0

    # -- emitter side --------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.in_q.push_wait(req)

    def _admit(self) -> None:
        while self.pool.available() or self.pool.drain():
            nxt = self.in_q.pop()
            if nxt is SPSCQueue._EMPTY:
                return
            slot = self.pool.alloc()
            nxt.tag = self.tag_counter
            self.tag_counter += 1
            nxt.slot = slot
            nxt.start = self.cache_len
            self._reset_slot(slot)
            self.active[slot] = nxt

    def _reset_slot(self, slot: int) -> None:
        """Zero the recycled slot's cache state (SSM state must reset;
        attention K/V is masked by start_pos, zeroing is belt-and-braces)."""
        def z(leaf):
            if leaf.ndim >= 2 and leaf.shape[-4:-3] != ():  # kv caches (.., B, T, H, D)
                pass
            return leaf

        def zero_slot(leaf):
            # batch dim position differs per leaf family; all our cache
            # leaves carry batch at axis -4 (kv: L,B,T,H,D) or -3/-2 (ssm)
            for ax in range(leaf.ndim):
                if leaf.shape[ax] == self.max_batch:
                    idx = [slice(None)] * leaf.ndim
                    idx[ax] = slot
                    return leaf.at[tuple(idx)].set(0)
            return leaf

        self.cache = jax.tree.map(zero_slot, self.cache)

    # -- one farm iteration ----------------------------------------------------
    def step(self) -> None:
        self._admit()
        if not self.active:
            return
        tokens = np.zeros((self.max_batch, 1), np.int32)
        start = np.zeros((self.max_batch,), np.int32)
        for slot, req in self.active.items():
            if req.fed < len(req.prompt):
                tokens[slot, 0] = req.prompt[req.fed]
            else:
                tokens[slot, 0] = req.generated[-1] if req.generated else 0
            start[slot] = req.start
        batch = {"tokens": jnp.asarray(tokens), "start_pos": jnp.asarray(start)}
        if self.cfg.family == "audio":
            raise NotImplementedError("audio serving uses frame embeddings")
        logits, self.cache = self._step(self.params, batch, self.cache,
                                        jnp.int32(self.cache_len))
        self.cache_len += 1
        self.steps_run += 1
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        finished = []
        for slot, req in self.active.items():
            if req.fed < len(req.prompt):
                req.fed += 1          # still ingesting the prompt
                continue
            tok = int(nxt[slot])
            req.generated.append(tok)
            if (req.eos_id is not None and tok == req.eos_id) or \
               len(req.generated) >= req.max_new:
                finished.append(slot)
        # -- collector: free pages, emit in tag order -------------------------
        for slot in finished:
            req = self.active.pop(slot)
            self.pool.free(slot, 0)
            self.done[req.tag] = req
        while self.emit_next in self.done:
            self.results.append(self.done.pop(self.emit_next))
            self.emit_next += 1

    def run(self, *, max_steps: int = 10_000) -> List[Request]:
        while (len(self.active) or len(self.in_q) or self.done) and \
                self.cache_len < self.max_len and max_steps:
            self.step()
            max_steps -= 1
        return self.results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()
    cfg = ARCHS[args.arch].smoke()
    eng = ServeEngine(cfg, max_batch=4, max_len=256)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(3, 10))
        eng.submit(Request(rid=i, prompt=list(rng.integers(0, cfg.vocab_size, plen)),
                           max_new=args.max_new))
    results = eng.run()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in results)
    print(f"[serve] {len(results)} requests, {toks} tokens, "
          f"{eng.steps_run} engine steps, {toks/dt:.1f} tok/s")
    for r in results[:4]:
        print(f"  tag={r.tag} rid={r.rid} out={r.generated[:8]}")
    assert [r.tag for r in results] == sorted(r.tag for r in results), \
        "collector must emit in tag order"


if __name__ == "__main__":
    main()
