"""Step builders: train / prefill / decode, with shardings attached.

These are the functions the dry-run lowers and the drivers execute.  All
sharding decisions funnel through ``parallel.rules``; input ShapeDtypeStructs
carry their shardings so ``jax.jit(...).lower(*specs)`` needs no separate
in_shardings (donation is still declared for the state arguments).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import model as M
from ..models.config import ModelConfig
from ..optim import adamw_init, adamw_update, cosine_schedule
from ..parallel import rules
from ..parallel.context import MeshCtx, current_ctx

__all__ = ["make_train_step", "make_decode_step", "make_prefill_step",
           "train_state_specs", "input_specs"]


# --------------------------------------------------------------------------
# step functions (pure; trace under an active mesh_context)
# --------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, *, peak_lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10_000):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            M.loss_fn, has_aux=True)(params, batch, cfg)
        lr = cosine_schedule(opt_state.step, peak_lr=peak_lr,
                             warmup_steps=warmup, total_steps=total_steps)
        params, opt_state, om = adamw_update(params, grads, opt_state, lr=lr)
        out_metrics = {"loss": loss, "lr": lr, **metrics, **om}
        return params, opt_state, out_metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return M.prefill(params, batch, cfg)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, batch, cache, cache_len):
        return M.decode_step(params, batch, cache, cache_len, cfg)
    return serve_step


# --------------------------------------------------------------------------
# specs (ShapeDtypeStruct stand-ins, shardings attached)
# --------------------------------------------------------------------------
def _sds(tree_shapes, tok_tree, ctx: Optional[MeshCtx]):
    """Attach resolved shardings to a ShapeDtypeStruct tree."""
    if ctx is None:
        return tree_shapes
    sh = rules.to_shardings(ctx, tok_tree)
    return jax.tree.map(
        lambda s, d: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=d),
        tree_shapes, sh)


def train_state_specs(cfg: ModelConfig, ctx: Optional[MeshCtx]):
    """(params, opt_state) ShapeDtypeStructs with shardings — no allocation."""
    p_shapes = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    mdt = jnp.dtype(cfg.optimizer_dtype)
    o_shapes = jax.eval_shape(lambda: adamw_init(p_shapes, mdt))
    p_tok = M.params_pspecs(cfg, ctx.mp_size if ctx else 1)
    o_tok = type(o_shapes)(step=None, mu=p_tok, nu=p_tok)
    return (_sds(p_shapes, p_tok, ctx),
            _sds(o_shapes, o_tok, ctx))


HBM_SERVE_BUDGET = 8e9   # bytes of params per chip we allow replicated-dp


def serve_cfg(cfg: ModelConfig, shape, ctx: Optional[MeshCtx]) -> ModelConfig:
    """For inference cells, replicate params over dp when they fit — kills
    the per-step ZeRO gathers that otherwise dominate the decode collective
    term (EXPERIMENTS §Perf, serving hillclimb)."""
    import os
    if shape.kind == "train" or ctx is None or \
            os.environ.get("REPRO_SERVE_FSDP") == "1":   # §Perf baseline knob
        return cfg
    from ..models import param_count
    per_chip = param_count(cfg) * 2 / max(ctx.mp_size, 1)   # bf16
    if per_chip <= HBM_SERVE_BUDGET:
        return cfg.replace(serve_params_replicated=True)
    return cfg


def input_specs(cfg: ModelConfig, shape, ctx: Optional[MeshCtx]) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one assigned shape cell.

    Returns kwargs for the step function of the cell's kind:
      train   → {params, opt_state, batch}
      prefill → {params, batch}
      decode  → {params, batch, cache, cache_len}
    """
    cfg = serve_cfg(cfg, shape, ctx)
    B, S = shape.global_batch, shape.seq_len
    dt = cfg.param_dtype
    dp = ctx.dp_size if ctx else 1

    def batch_of(seq):
        out = {}
        if cfg.family == "audio":
            out["frames"] = jax.ShapeDtypeStruct((B, seq, cfg.d_model), dt)
            out["labels"] = jax.ShapeDtypeStruct((B, cfg.n_codebooks, seq), jnp.int32)
        else:
            out["tokens"] = jax.ShapeDtypeStruct((B, seq), jnp.int32)
            out["labels"] = jax.ShapeDtypeStruct((B, seq), jnp.int32)
        if cfg.family == "vlm":
            out["vision_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.vision_patches, cfg.vision_dim), jnp.float32)
        if shape.kind != "train":
            out.pop("labels", None)
        return _sds(out, {k: v for k, v in M.batch_pspecs(cfg, B, dp).items()
                          if k in out}, ctx)

    params, opt = train_state_specs(cfg, ctx)
    if shape.kind == "train":
        return {"params": params, "opt_state": opt, "batch": batch_of(S)}
    if shape.kind == "prefill":
        return {"params": params, "batch": batch_of(S)}
    # decode: one new token against a cache of S
    cache_shapes = jax.eval_shape(lambda: M.init_cache(cfg, B, S))
    cache_tok = M.cache_pspecs(cfg, B, dp_divisible=(B % max(dp, 1) == 0))
    cache = _sds(cache_shapes, cache_tok, ctx)
    return {"params": params, "batch": batch_of(1), "cache": cache,
            "cache_len": jax.ShapeDtypeStruct((), jnp.int32)}


def step_fn_for(cfg: ModelConfig, shape, ctx: Optional[MeshCtx] = None):
    cfg = serve_cfg(cfg, shape, ctx)
    if shape.kind == "train":
        return make_train_step(cfg), ("params", "opt_state", "batch")
    if shape.kind == "prefill":
        return make_prefill_step(cfg), ("params", "batch")
    return make_decode_step(cfg), ("params", "batch", "cache", "cache_len")
