"""End-to-end training driver.

Composes every substrate in this repo: streaming data pipeline (Emitter →
SPSC ring), jitted train step (GSPMD + manual farm regions), async
checkpointing (Collector thread), fault-tolerant runner (restore-on-failure)
and deterministic replay.  On this CPU container it trains reduced configs
for real (examples/streaming_train.py runs a ~few-hundred-step job); on a
TPU pod the same driver runs the full configs via ``--arch``.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS
from ..data import make_batch_stream
from ..models import init_params
from ..optim import adamw_init
from ..parallel.context import mesh_context
from ..runtime.checkpoint import AsyncCheckpointer, latest_step, restore
from .steps import make_train_step


def train(cfg, *, steps: int, batch: int, seq: int, ckpt_dir: str | None,
          ckpt_every: int = 50, seed: int = 0, mesh=None, dp_axes=("data",),
          log_every: int = 10, peak_lr: float = 3e-4, inject_failure_at=None):
    """Returns (final_state, losses). Deterministic given (cfg, seed)."""
    key = jax.random.PRNGKey(seed)

    def build():
        params = init_params(cfg, key)
        opt = adamw_init(params, jnp.dtype(cfg.optimizer_dtype))
        return {"params": params, "opt": opt}

    ctx_mgr = mesh_context(mesh, dp_axes=dp_axes) if mesh is not None else None
    step_fn = make_train_step(cfg, peak_lr=peak_lr, total_steps=max(steps, 2))
    if ctx_mgr is not None:
        ctx_mgr.__enter__()
    try:
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
        state = build()
        start = 0
        ckpt = None
        if ckpt_dir:
            ckpt = AsyncCheckpointer(ckpt_dir)
            last = latest_step(ckpt_dir)
            if last is not None:
                state = restore(state, ckpt_dir, last)
                start = last
                print(f"[train] restored step {start} from {ckpt_dir}")
        losses = []
        pipe = make_batch_stream(cfg, batch, seq, seed=seed, start_step=start,
                                 n_steps=steps - start)
        t0 = time.time()
        try:
            for step, np_batch in pipe:
                if inject_failure_at is not None and step == inject_failure_at:
                    inject_failure_at = None
                    raise RuntimeError("injected failure (test)")
                dev_batch = jax.tree.map(jnp.asarray, np_batch)
                params, opt, metrics = jit_step(state["params"], state["opt"], dev_batch)
                state = {"params": params, "opt": opt}
                loss = float(metrics["loss"])
                losses.append(loss)
                if step % log_every == 0:
                    dt = time.time() - t0
                    print(f"[train] step={step} loss={loss:.4f} "
                          f"gnorm={float(metrics['grad_norm']):.3f} "
                          f"lr={float(metrics['lr']):.2e} ({dt:.1f}s)", flush=True)
                if ckpt and (step + 1) % ckpt_every == 0:
                    ckpt.save(state, step + 1)
        finally:
            pipe.close()
            if ckpt:
                ckpt.wait()   # publish in-flight checkpoints even on failure
        if ckpt:
            ckpt.save(state, steps)
            ckpt.wait()
            ckpt.close()
        return state, losses
    finally:
        if ctx_mgr is not None:
            ctx_mgr.__exit__(None, None, None)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="train the reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()
    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = cfg.smoke()
    _, losses = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                      ckpt_dir=args.ckpt_dir, seed=args.seed, peak_lr=args.lr)
    print(f"[train] done: first loss {losses[0]:.4f} → last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
