"""Production mesh factories.

Defined as FUNCTIONS so importing this module never touches jax device
state (device count is locked at first jax init — the dry-run sets
XLA_FLAGS before importing anything else).

Mesh construction goes through :func:`repro.compat.make_mesh` so the same
helper serves every pinned JAX version (``axis_types=`` only exists on
newer JAX); the tests build their meshes with the same helper.
"""
from __future__ import annotations

from ..compat import make_mesh

__all__ = ["make_production_mesh", "make_test_mesh", "DP_AXES", "MODEL_AXIS"]

MODEL_AXIS = "model"


def DP_AXES(multi_pod: bool = False):
    return ("pod", "data") if multi_pod else ("data",)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for multi-device tests (requires forced host devices)."""
    if pod:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))
