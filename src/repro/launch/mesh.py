"""Production mesh factories.

Defined as FUNCTIONS so importing this module never touches jax device
state (device count is locked at first jax init — the dry-run sets
XLA_FLAGS before importing anything else).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType

__all__ = ["make_production_mesh", "make_test_mesh", "DP_AXES", "MODEL_AXIS"]

MODEL_AXIS = "model"


def DP_AXES(multi_pod: bool = False):
    return ("pod", "data") if multi_pod else ("data",)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for multi-device tests (requires forced host devices)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             axis_types=(AxisType.Auto,) * 3)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
