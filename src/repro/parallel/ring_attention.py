"""Ring attention — sequence parallelism as a cyclic SPSC network.

The paper's claim is that arbitrary streaming networks, cycles included,
compose from SPSC channels.  Ring attention is the flagship device-level
cycle: the sequence is sharded over a mesh axis, each device keeps its Q
shard resident, and the K/V shards circulate hop-by-hop on an SPSC ring
(``collective-permute``), with flash-style online-softmax accumulation per
hop.  Communication is perfectly balanced point-to-point and each hop's
transfer overlaps the previous hop's attention compute (double buffering) —
no all-gather of the sequence ever happens.

Use: inside shard_map, q/k/v sharded on the sequence axis over
``axis_name``; returns the local output shard.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import axis_size as _axis_size, needs_pvary, pvary
from ..core.dchannel import ring_send
from ..models.attention import _chunk_body

__all__ = ["ring_attention"]


def ring_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                   axis_name: str, causal: bool = True,
                   window: Optional[int] = None) -> jnp.ndarray:
    """q (B, S_loc, H, Dh); k/v (B, S_loc, Hkv, Dh), sequence-sharded."""
    B, s_loc, H, Dh = q.shape
    n = _axis_size(axis_name)
    me = lax.axis_index(axis_name)
    groups = H // k.shape[2]
    scale = Dh ** -0.5
    qpos = me * s_loc + jnp.arange(s_loc)

    m0 = jnp.full((B, H, s_loc), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, s_loc), jnp.float32)
    a0 = jnp.zeros((B, H, s_loc, Dh), jnp.float32)
    # the accumulators become axis-varying once a hop folds in a kv block
    if needs_pvary(m0, axis_name):
        m0, l0, a0 = (pvary(t, (axis_name,)) for t in (m0, l0, a0))

    def hop(state, h_idx):
        (m, l, acc), (k_blk, v_blk) = state
        # issue the next hop's send first: overlaps with this hop's compute
        k_next = ring_send(k_blk, axis_name)
        v_next = ring_send(v_blk, axis_name)
        src = (me - h_idx) % n
        kpos = src * s_loc + jnp.arange(s_loc)
        kk = jnp.repeat(k_blk, groups, axis=2) if groups > 1 else k_blk
        vv = jnp.repeat(v_blk, groups, axis=2) if groups > 1 else v_blk
        m, l, acc = _chunk_body(q, kk, vv, (m, l, acc), qpos, kpos,
                                jnp.int32(n * s_loc), causal=causal,
                                window=window, scale=scale)
        return ((m, l, acc), (k_next, v_next)), None

    ((m, l, acc), _), _ = lax.scan(hop, ((m0, l0, a0), (k, v)), jnp.arange(n))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)   # (B, S_loc, H, Dh)
