"""Sharding rule sets: translate model/cache/batch spec-token trees into
``NamedSharding``s for a given mesh context.  Single source of truth for the
token trees is ``models/model.py`` (kept adjacent to init so the structures
cannot drift — enforced by ``tests/test_sharding_rules.py``)."""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import model as _model
from ..models.config import ModelConfig
from .context import MeshCtx

__all__ = ["param_shardings", "cache_shardings", "batch_shardings", "to_shardings"]


def to_shardings(ctx: MeshCtx, token_tree: Any):
    def leaf(tokens):
        if tokens is None:
            return NamedSharding(ctx.mesh, P())
        return ctx.sharding(*tokens)

    from .context import is_spec_leaf
    return jax.tree.map(leaf, token_tree, is_leaf=is_spec_leaf)


def param_shardings(ctx: MeshCtx, cfg: ModelConfig):
    return to_shardings(ctx, _model.params_pspecs(cfg, ctx.mp_size))


def cache_shardings(ctx: MeshCtx, cfg: ModelConfig, batch: int):
    dp_div = batch % ctx.dp_size == 0
    return to_shardings(ctx, _model.cache_pspecs(cfg, batch, dp_div))


def batch_shardings(ctx: MeshCtx, cfg: ModelConfig, batch: int):
    return to_shardings(ctx, _model.batch_pspecs(cfg, batch, ctx.dp_size))
