"""Mesh context: how model code learns about the distribution environment.

The model is written once; the distribution strategy is ambient.  A
``mesh_context`` names the data-parallel axes (possibly several — e.g.
``("pod", "data")`` on the multi-pod mesh) and the tensor/expert-parallel
axis.  Model code calls :func:`shard` for GSPMD constraints and
:func:`manual_model` for the few regions that need hand-placed collectives
(embedding lookup, vocab-parallel CE, MoE dispatch).  With no context
active, everything degrades to plain single-device semantics — which is
what smoke tests exercise.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map as _compat_shard_map

__all__ = ["MeshCtx", "mesh_context", "current_ctx", "shard", "manual_model",
           "is_spec_leaf"]


def is_spec_leaf(v) -> bool:
    """Leaf predicate for sharding-token trees: None or a PLAIN tuple of
    tokens (NamedTuples — e.g. optimizer states — are containers, not specs)."""
    return v is None or (type(v) is tuple)


def psum_compat(x, axis_name: str):
    """bf16 psum that survives the XLA-CPU partial-manual bug.

    XLA's CPU backend check-fails ("Invalid binary instruction opcode copy")
    on a bf16 all-reduce emitted from a partially-manual shard_map; f32 and
    f16 are fine, and TPU is unaffected.  Workaround policy:
      * default (correctness paths/tests): upcast to f32 around the psum;
      * REPRO_DRYRUN_WIRE=f16 (set by launch/dryrun.py): reduce in f16 so
        the HLO's collective byte-widths match what bf16 would be on TPU —
        keeps the roofline collective term honest.
    """
    import os
    import jax.numpy as jnp
    if x.dtype == jnp.bfloat16 and jax.default_backend() == "cpu":
        wire = jnp.float16 if os.environ.get("REPRO_DRYRUN_WIRE") == "f16" else jnp.float32
        return jax.lax.psum(x.astype(wire), axis_name).astype(x.dtype)
    return jax.lax.psum(x, axis_name)

_TLS = threading.local()

# spec tokens: "dp" → all data axes, "mp" → model axis, None → replicated
DP, MP = "dp", "mp"


@dataclass(frozen=True)
class MeshCtx:
    mesh: Mesh
    dp_axes: Tuple[str, ...]
    model_axis: str

    @property
    def dp_size(self) -> int:
        s = 1
        for a in self.dp_axes:
            s *= self.mesh.shape[a]
        return s

    @property
    def mp_size(self) -> int:
        return self.mesh.shape[self.model_axis]

    def resolve(self, *tokens) -> P:
        """Translate ("dp", None, "mp") tokens into a PartitionSpec."""
        out = []
        for t in tokens:
            if t == DP:
                out.append(self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0])
            elif t == MP:
                out.append(self.model_axis)
            elif t is None:
                out.append(None)
            else:
                out.append(t)
        return P(*out)

    def sharding(self, *tokens) -> NamedSharding:
        return NamedSharding(self.mesh, self.resolve(*tokens))


@contextmanager
def mesh_context(mesh: Mesh, dp_axes: Sequence[str] = ("data",),
                 model_axis: str = "model"):
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = MeshCtx(mesh, tuple(dp_axes), model_axis)
    try:
        with mesh:
            yield _TLS.ctx
    finally:
        _TLS.ctx = prev


def current_ctx() -> Optional[MeshCtx]:
    return getattr(_TLS, "ctx", None)


def shard(x: Any, *tokens) -> Any:
    """GSPMD sharding constraint (no-op without a mesh context)."""
    ctx = current_ctx()
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(x, ctx.sharding(*tokens))


def manual_model(fn: Callable, in_specs, out_specs) -> Callable:
    """FULL-manual shard_map region (all mesh axes manual).

    Specs are written with the tokens of :func:`shard` and must account for
    the data axes explicitly (dp-sharded params are gathered inside with
    :func:`fsdp_gather`, making ZeRO-3's collectives visible in the HLO).
    Full-manual is deliberate: partially-manual shard_map + grad + scan
    check-fails XLA's CPU backend ("Invalid binary instruction opcode
    copy"), full-manual does not — see tests/test_sharding_rules.py.
    Without a context, returns ``fn`` unchanged (axis size 1 semantics must
    hold — keep ``lax.psum(..., axis)`` out of that path)."""
    ctx = current_ctx()
    if ctx is None:
        return fn

    def tok2spec(ts):
        if ts is None:
            return P()
        return ctx.resolve(*ts) if isinstance(ts, tuple) else ts

    # NOTE: multi-arg/multi-output specs are passed as LISTS (a plain tuple
    # would itself parse as one spec leaf); converted to tuples after mapping.
    ispecs = jax.tree.map(tok2spec, in_specs, is_leaf=is_spec_leaf)
    ospecs = jax.tree.map(tok2spec, out_specs, is_leaf=is_spec_leaf)
    if isinstance(ispecs, list):
        ispecs = tuple(ispecs)
    if isinstance(ospecs, list):
        ospecs = tuple(ospecs)
    return _compat_shard_map(fn, mesh=ctx.mesh, in_specs=ispecs,
                             out_specs=ospecs, check_vma=False)


def fsdp_gather(tree: Any, spec_tree: Any) -> Any:
    """Inside a full-manual region: all-gather every 'dp'-sharded dim of the
    params (the explicit ZeRO-3 gather; its transpose is the grad
    reduce-scatter).  No-op without a context."""
    ctx = current_ctx()
    if ctx is None:
        return tree

    def leaf(x, toks):
        if toks is None:
            return x
        for dim, t in enumerate(toks):
            if t == "dp":
                for ax in reversed(ctx.dp_axes):
                    x = jax.lax.all_gather(x, ax, axis=dim, tiled=True)
        return x

    flat, treedef = jax.tree_util.tree_flatten(tree)
    spec_flat = treedef.flatten_up_to(spec_tree)
    return jax.tree_util.tree_unflatten(
        treedef, [leaf(x, s) for x, s in zip(flat, spec_flat)])
