from .context import MeshCtx, current_ctx, mesh_context, shard, manual_model
from .ring_attention import ring_attention
from . import rules

__all__ = ["MeshCtx", "current_ctx", "mesh_context", "shard", "manual_model",
           "ring_attention", "rules"]
