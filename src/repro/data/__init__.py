from .pipeline import StreamingPipeline, SyntheticLM, make_batch_stream

__all__ = ["StreamingPipeline", "SyntheticLM", "make_batch_stream"]
