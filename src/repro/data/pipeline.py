"""Streaming input pipeline — the paper's Emitter, feeding the train loop.

Topology: a producer thread (the Emitter) materialises batches and pushes
them through a lock-free SPSC ring; the training loop (the Worker) pops and
transfers to device while the Emitter prepares the next batch — the
communication/computation overlap the paper gets from buffered queues.

Determinism & fault tolerance: the source is a pure function of
(seed, step), so after a checkpoint restore at step k the pipeline resumes
*exactly* (no data loss / duplication); this is the property the restart
test in ``tests/test_runtime.py`` asserts.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, Iterator, Optional

import numpy as np

from ..core.spsc import EOS, SPSCQueue
from ..models.config import ModelConfig

__all__ = ["SyntheticLM", "StreamingPipeline", "make_batch_stream"]


class SyntheticLM:
    """Deterministic synthetic LM batches: batch(step) is a pure function."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
        self.cfg, self.batch, self.seq, self.seed = cfg, batch, seq, seed

    def __call__(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        cfg = self.cfg
        if cfg.family == "audio":
            out = {
                "frames": rng.standard_normal(
                    (self.batch, self.seq, cfg.d_model), dtype=np.float32),
                "labels": rng.integers(0, cfg.vocab_size,
                                       (self.batch, cfg.n_codebooks, self.seq),
                                       dtype=np.int32),
            }
        else:
            toks = rng.integers(0, cfg.vocab_size, (self.batch, self.seq + 1),
                                dtype=np.int32)
            out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.family == "vlm":
            out["vision_embeds"] = rng.standard_normal(
                (self.batch, cfg.vision_patches, cfg.vision_dim)).astype(np.float32)
        return out


class StreamingPipeline:
    """Emitter-thread batch producer over an SPSC ring (capacity = prefetch)."""

    def __init__(self, source: Callable[[int], Dict], start_step: int = 0,
                 prefetch: int = 2, n_steps: Optional[int] = None):
        self.source = source
        self.start_step = start_step
        self.n_steps = n_steps
        self._ring = SPSCQueue(max(2, prefetch))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._emit, name="data-emitter",
                                        daemon=True)
        self._thread.start()

    def _emit(self) -> None:
        step = self.start_step
        while not self._stop.is_set():
            if self.n_steps is not None and step >= self.start_step + self.n_steps:
                break
            batch = self.source(step)
            while not self._ring.push((step, batch)):
                if self._stop.is_set():
                    return
                self._stop.wait(0.0005)
            step += 1
        self._ring.push_wait(EOS)

    def __iter__(self) -> Iterator:
        while True:
            item = self._ring.pop_wait(timeout=30.0)
            if item is EOS or item is SPSCQueue._EMPTY:
                return
            yield item

    def close(self) -> None:
        self._stop.set()


def make_batch_stream(cfg: ModelConfig, batch: int, seq: int, *, seed: int = 0,
                      start_step: int = 0, n_steps: Optional[int] = None,
                      prefetch: int = 2) -> StreamingPipeline:
    return StreamingPipeline(SyntheticLM(cfg, batch, seq, seed),
                             start_step=start_step, n_steps=n_steps,
                             prefetch=prefetch)
