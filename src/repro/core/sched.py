"""Pluggable scheduling policies for the threads backend.

The paper's headline numbers on fine-grain streams (Sec. 6: 35-226% over
OpenMP/Cilk/TBB on Smith-Waterman) come from two knobs working together:
cheap lock-free hand-offs *and* smart task placement.  The hand-offs live
in ``spsc.py``; this module is the placement knob, extracted out of the
dispatch arbiter (``graph.DispatchVertex``) into a policy hierarchy so
``lower(skel, "threads", ...)`` / ``Farm(scheduling=...)`` can pick — or a
user can subclass — without touching the runtime:

``RoundRobin``    the paper's default emitter policy (Fig. 1-2);
``OnDemand``      FastFlow's on-demand mode: shortest worker ring wins
                  (reading ``len()`` of a peer SPSC ring from the arbiter
                  thread is heuristically stale but safe);
``WorkStealing``  idle workers steal from the deepest peer backlog via a
                  steal side-channel.  SPSC discipline makes literal
                  ring-revocation impossible (one consumer per ring), so
                  the policy keeps each worker ring shallow (``ring_fill``)
                  and holds the depth in arbiter-side per-worker backlogs;
                  an idle worker posts its index on its idle ring (SPSC,
                  worker → arbiter) and the arbiter migrates the oldest
                  task from the deepest backlog to the thief.  Tags ride
                  the tokens untouched, so tagged-token ordering and
                  straggler re-issue interact correctly with steals (the
                  merge arbiter reorders/dedups by tag no matter which
                  worker serviced the token).
``CostModel``     adaptive placement fed by the per-worker service-time
                  EWMA that ``FarmStats`` collects: a task goes to the
                  worker with the least expected completion time,
                  ``(queued + 1) × ewma_service``, so a worker pinned by a
                  slow task (e.g. a long decode sequence in the serving
                  farm) stops accumulating queue behind it.

Policies are per-build mutable state: :func:`make_scheduler` always
returns a **fresh** instance (``Scheduler.fresh``), so one ``Farm`` IR
node — pure data — can be lowered or run many times without policies
leaking counters between graphs.

This module also owns the fusion threshold calibration
(:func:`calibrate_handoff_us`): the same measurement
``benchmarks/skeleton_parity.py`` reports (per-item hand-off cost vs the
fused lowering), in-library and cached, so ``lower(..., fuse="auto")`` can
calibrate itself.
"""
from __future__ import annotations

import os
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple, Type

from .spsc import SPSCQueue

__all__ = [
    "Scheduler", "RoundRobin", "OnDemand", "WorkStealing", "CostModel",
    "KeyAffinity", "BudgetBackpressure",
    "SCHEDULERS", "make_scheduler", "calibrate_handoff_us",
    "clear_handoff_cache", "spread_cpus",
]

_EMPTY = SPSCQueue._EMPTY


def spread_cpus(index: int, nworkers: int) -> Optional[Tuple[int, ...]]:
    """Partition the process's allowed CPUs round-robin over ``nworkers``
    and return worker ``index``'s share (``None`` where the platform has
    no affinity API).  With more workers than CPUs the shares wrap, so
    every worker still gets a non-empty set."""
    try:
        cpus = sorted(os.sched_getaffinity(0))
    except (AttributeError, OSError):  # pragma: no cover - non-Linux
        return None
    if not cpus or nworkers <= 0:  # pragma: no cover - defensive
        return None
    share = tuple(cpus[index % len(cpus)::nworkers]) if nworkers <= len(cpus) \
        else (cpus[index % len(cpus)],)
    return share or (cpus[index % len(cpus)],)


class Scheduler:
    """Base class: decides which worker ring each task token lands on.

    Lifecycle (all calls happen in the dispatch arbiter's thread, which is
    what keeps the single-writer SPSC discipline intact):

    * ``worker_channel(i, channel)`` — at graph-build time, once per
      worker: return an SPSC side-channel (worker → arbiter) or ``None``;
    * ``bind(outs, stats)`` — at arbiter start, with the worker rings and
      the farm's :class:`~repro.core.skeleton.FarmStats`;
    * ``place(tok, emit)`` — one token: default is ``emit(pick(), tok)``
      (a blocking push that keeps the wrap-around ring drained);
    * ``pump()`` — called every arbiter iteration: flush any policy-held
      backlog, service steal requests; returns True on progress;
    * ``pending()`` — tokens still held inside the policy (the arbiter
      refuses to EOS until this reaches zero).
    """

    name = "scheduler"
    # set True by policies that read FarmStats.service_ewma: workers only
    # pay the per-task timing when some policy actually consumes it
    needs_service_stats = False
    # policies that hold tokens (pending() > 0) set this in bind(): the
    # dispatch arbiter blocks new intake while pending() exceeds it, so a
    # policy backlog cannot buffer an unbounded stream (ring-capacity
    # backpressure, re-established one level up)
    high_water: Optional[int] = None
    # opt-in placement hint: when True, ``worker_cpus`` spreads the farm's
    # workers over the allowed CPUs and the procs backend pins each worker
    # process (best-effort ``sched_setaffinity``; the spawn pool undoes
    # the pin when it re-arms a process for the next graph)
    pin_cpus = False

    def __init__(self) -> None:
        self.outs: List[Any] = []
        self.stats: Any = None
        self._rr = 0

    def fresh(self) -> "Scheduler":
        """A new instance with the same configuration, no shared state."""
        return type(self)()

    def worker_channel(self, index: int, channel: Callable[[int], Any]):
        return None

    def worker_cpus(self, index: int,
                    nworkers: int) -> Optional[Tuple[int, ...]]:
        """Placement hint: CPUs worker ``index`` should be pinned to, or
        ``None`` for no pin.  Consumed at build time by the procs backend
        (``ProcVertex.cpus``); the threads backend ignores it (one
        process, the OS balances threads)."""
        if not self.pin_cpus:
            return None
        return spread_cpus(index, nworkers)

    def bind(self, outs: List[Any], stats: Any) -> None:
        self.outs = outs
        self.stats = stats

    def pick(self) -> int:
        raise NotImplementedError

    def route(self, payload: Any) -> Optional[int]:
        """Payload-dependent routing hook for single-token fan-out (Stage
        routes, the all-to-all scatter): return a worker index computed
        *from the payload*, or ``None`` (the default) to defer to
        ``pick()``.  Unlike ``place``, a policy implementing ``route``
        never holds tokens, so it stays usable wherever only pick()-based
        policies are allowed."""
        return None

    def place(self, tok: Any, emit: Callable[[int, Any], None]) -> None:
        emit(self.pick(), tok)

    def pump(self) -> bool:
        return False

    def pending(self) -> int:
        return 0

    def observe_service(self, index: int, ewma: float) -> None:
        """Fold one worker's service-time EWMA into the policy's stats.

        On the threads backend workers write ``FarmStats.service_ewma``
        directly (single writer per key).  The procs backend has no shared
        ``FarmStats`` object, so workers stream their EWMA over a
        worker→arbiter SPSC ring and the dispatch arbiter feeds it in
        here — arbiter-side state stays in the arbiter's process, and
        policies like :class:`CostModel` read the same dict either way."""
        if self.stats is not None:
            self.stats.service_ewma[index] = ewma


class RoundRobin(Scheduler):
    """The paper's default emitter policy: worker ``i mod N`` (Fig. 1-2)."""

    name = "rr"

    def pick(self) -> int:
        w = self._rr % len(self.outs)
        self._rr += 1
        return w


class OnDemand(Scheduler):
    """FastFlow's on-demand mode: shortest worker ring wins.  Reading a
    peer ring's ``len()`` from the arbiter thread is heuristically stale
    but safe (the consumer can only shrink it)."""

    name = "ondemand"

    def pick(self) -> int:
        return min(range(len(self.outs)), key=lambda w: len(self.outs[w]))


class WorkStealing(Scheduler):
    """Arbiter-mediated work stealing over the steal side-channel.

    Placement is round-robin into per-worker **backlogs** held by the
    arbiter; each worker ring is kept at most ``ring_fill`` deep, so queue
    depth stays where it can still be re-balanced (a token already pushed
    onto an SPSC ring has exactly one legal consumer and cannot be
    revoked).  An idle worker posts its index on its idle ring; ``pump``
    answers by migrating the *oldest* task from the *deepest* backlog to
    the thief — oldest-first keeps the ordered farm's reorder buffer
    shallow, deepest-victim is the classic steal heuristic.  Straggler
    re-issue duplicates bypass the backlog (``pick`` = shortest ring) and
    the merge arbiter dedups by tag, exactly as with the other policies.
    A dead-but-survivable worker's backlog is rescued the same way: the
    moment any live worker goes idle it steals the corpse's queue.
    """

    name = "worksteal"

    def __init__(self, ring_fill: int = 8, idle_capacity: int = 8) -> None:
        super().__init__()
        self.ring_fill = ring_fill
        self.idle_capacity = idle_capacity
        self.idle_rings: List[Any] = []
        self.backlogs: List[deque] = []

    def fresh(self) -> "WorkStealing":
        return WorkStealing(self.ring_fill, self.idle_capacity)

    def worker_channel(self, index: int, channel: Callable[[int], Any]):
        ring = channel(self.idle_capacity)
        self.idle_rings.append(ring)
        return ring

    def bind(self, outs: List[Any], stats: Any) -> None:
        super().bind(outs, stats)
        self.backlogs = [deque() for _ in outs]
        # total backlog the arbiter may hold before it stops taking input:
        # a few refill windows per worker, so stealing has depth to work
        # with but an unbounded stream cannot buffer in memory
        self.high_water = max(64, 8 * self.ring_fill * len(outs))

    def pick(self) -> int:  # duplicates from straggler re-issue only
        return min(range(len(self.outs)), key=lambda w: len(self.outs[w]))

    def place(self, tok: Any, emit: Callable[[int, Any], None]) -> None:
        # O(1) hot path: append to the round-robin backlog and top up that
        # ring only; steal servicing runs in the arbiter's per-iteration
        # pump(), not per token
        w = self._rr % len(self.outs)
        self._rr += 1
        bl = self.backlogs[w]
        bl.append(tok)
        out = self.outs[w]
        while bl and len(out) < self.ring_fill and out.push(bl[0]):
            bl.popleft()

    def pending(self) -> int:
        return sum(len(b) for b in self.backlogs)

    def pump(self) -> bool:
        progress = False
        outs, backlogs = self.outs, self.backlogs
        # 1. keep every ring primed up to ring_fill from its own backlog
        for w, bl in enumerate(backlogs):
            while bl and len(outs[w]) < self.ring_fill and outs[w].push(bl[0]):
                bl.popleft()
                progress = True
        # 2. answer steal requests: the thief batch-refills from the
        #    deepest peer backlogs, oldest task first (signals are
        #    advisory — a stale one is dropped).  Batching matters: the
        #    arbiter only gets scheduled every so often (GIL quantum), so
        #    one-task steals would cap the whole farm at the arbiter's
        #    wake-up rate.
        for ring in self.idle_rings:
            while True:
                w = ring.pop()
                if w is _EMPTY:
                    break
                if backlogs[w] or not outs[w].empty():
                    continue  # got work since signalling
                while len(outs[w]) < self.ring_fill:
                    victim = max(range(len(backlogs)),
                                 key=lambda v: len(backlogs[v]))
                    if victim == w or not backlogs[victim]:
                        break
                    tok = backlogs[victim].popleft()
                    if outs[w].push(tok):
                        progress = True
                        if self.stats is not None:
                            self.stats.steals += 1
                    else:
                        backlogs[w].appendleft(tok)
                        break
        return progress


class CostModel(Scheduler):
    """Adaptive placement off the per-worker service-time EWMA in
    ``FarmStats`` (each worker writes only its own key — single-writer).

    Expected completion on worker ``w`` is ``(len(ring_w) + 1) × ewma_w``:
    the new task waits behind the queue, then pays that worker's observed
    service time.  Until a worker has a sample it is costed at the mean of
    the known workers (with no samples at all this degrades to shortest
    queue).  Ties rotate round-robin so an idle farm doesn't pile onto
    worker 0."""

    name = "costmodel"
    needs_service_stats = True

    def pick(self) -> int:
        outs = self.outs
        n = len(outs)
        ewma: Dict[int, float] = (self.stats.service_ewma
                                  if self.stats is not None else {})
        if not ewma:
            return min(range(n), key=lambda w: len(outs[w]))
        default = sum(ewma.values()) / len(ewma)
        start = self._rr % n
        self._rr += 1
        return min(range(n),
                   key=lambda w: ((len(outs[w]) + 1) * ewma.get(w, default),
                                  (w - start) % n))


class KeyAffinity(Scheduler):
    """Key-affinity placement — the all-to-all routing rule as a farm
    policy: tasks whose keys are equal always land on the *same* worker
    (``stable_hash(by(payload)) % nworkers``, the deterministic hash every
    keyed shuffle uses on its left→right edge matrix).  This is the policy
    surface a plain ``Farm`` needs to host per-key state — stateful fold
    workers, per-key caches, sticky sessions — without building a full
    shuffle network.

    ``by`` extracts the key from the payload (default: the payload itself)
    and must be picklable for the procs backend (module-level function).
    Placement is payload-dependent: the policy implements ``route`` (so
    ``Stage`` fan-out and the all-to-all scatter can use it — it never
    holds tokens) and ``place`` on top of it for the farm arbiters; only
    the caller-side ``ProcAccelerator`` fast path falls back to the full
    arbiter graph.  ``pick`` (used only by straggler re-issue duplicates)
    degrades to shortest-ring — a duplicate may run off-key, which is
    safe: affinity is a placement preference, and the merge arbiter
    dedups by tag regardless of who serviced it.  Speculation plus
    *stateful* per-key workers is the caller's contract to avoid, exactly
    as with ``WorkStealing``."""

    name = "keyaffinity"

    def __init__(self, by: Optional[Callable[[Any], Any]] = None) -> None:
        super().__init__()
        self.by = by
        # bound once, off the per-item path (import is safe here: sched is
        # fully loaded before any policy can be instantiated)
        from .a2a import stable_hash
        self._hash = stable_hash

    def fresh(self) -> "KeyAffinity":
        return KeyAffinity(self.by)

    def pick(self) -> int:  # duplicates from straggler re-issue only
        return min(range(len(self.outs)), key=lambda w: len(self.outs[w]))

    def route(self, payload: Any) -> int:
        key = payload if self.by is None else self.by(payload)
        return self._hash(key) % len(self.outs)

    def place(self, tok: Any, emit: Callable[[int, Any], None]) -> None:
        # tok is graph.Token (threads), a (tag, issued, payload) tuple
        # (procs wire format), or a raw payload (caller-side arbitration)
        payload = tok.payload if hasattr(tok, "payload") else (
            tok[2] if isinstance(tok, tuple) and len(tok) == 3 else tok)
        emit(self.route(payload), tok)


class BudgetBackpressure(RoundRobin):
    """Bounded-memory intake throttle for keyed reductions — the scatter
    policy a budgeted ``reduce_by_key`` installs by default.

    The policy holds the reduction's :class:`~repro.core.oocore.
    MemoryBudget`; before each placement it checks whether the hot fold
    state across all partitions is over the *global* budget
    (``limit × nparts``) and, if so, counts one backpressure stall and
    briefly stops taking input (bounded wait, so a wedged reduction can
    never deadlock the scatter — the partitions relieve pressure by
    draining their inbound rings, spilling as they fold, which drops
    their held bytes below the line).  While the scatter stalls, its
    inbound ring fills and ring-capacity backpressure propagates
    upstream — the usual FastFlow mechanism, now driven by a byte budget
    instead of slot counts.

    The wait has hysteresis: partitions spill *on ingest*, so once their
    rings are drained the held bytes cannot fall further without new
    input — a stall that times out still over the line would then repeat
    for every placement while the partitions hover in the over-high-water
    band (each costing the full bounded wait: a ~1000× slowdown, not
    backpressure).  After a timed-out stall the policy places freely and
    re-arms only when the aggregate first dips back below the line, so
    one stall is paid per spill cycle instead of one per item.

    Works identically on both host backends: on threads the budget's
    counters are plain shared-object state; on procs the scatter process
    reads the same :class:`~repro.core.shm.ShmCounters` board the
    partition processes write (single writer per counter, any reader).
    Placement itself is round-robin over the left row.  Constructed
    bare (registry name ``"budget"``) it has no budget and degrades to
    plain round-robin."""

    name = "budget"

    def __init__(self, budget: Any = None, *,
                 max_stall_s: float = 0.02) -> None:
        super().__init__()
        self.budget = budget
        self.max_stall_s = max_stall_s
        self._exhausted = False  # last stall timed out still over the line

    def fresh(self) -> "BudgetBackpressure":
        # the budget is configuration, not run state: clones keep it (its
        # counters are cumulative across runs by design)
        return BudgetBackpressure(self.budget, max_stall_s=self.max_stall_s)

    def pick(self) -> int:
        b = self.budget
        if b is not None:
            over = b.over_total()
            if not over:
                self._exhausted = False  # below the line again: re-arm
            elif not self._exhausted:
                b.stalled()
                deadline = time.monotonic() + self.max_stall_s
                while b.over_total() and time.monotonic() < deadline:
                    time.sleep(0.0005)
                self._exhausted = b.over_total()
        return super().pick()


SCHEDULERS: Dict[str, Type[Scheduler]] = {
    "rr": RoundRobin,
    "ondemand": OnDemand,
    "worksteal": WorkStealing,
    "costmodel": CostModel,
    "keyaffinity": KeyAffinity,
    "budget": BudgetBackpressure,
}


def make_scheduler(spec: Any) -> Scheduler:
    """Resolve a scheduling spec — a registry name, a policy class, or a
    policy instance (cloned via ``fresh()`` so IR nodes stay pure data) —
    into a fresh :class:`Scheduler`.  Raises :class:`ValueError` on an
    unknown spec, which is also how ``Farm(scheduling=...)`` validates."""
    if isinstance(spec, Scheduler):
        return spec.fresh()
    if isinstance(spec, type) and issubclass(spec, Scheduler):
        return spec()
    if isinstance(spec, str):
        try:
            return SCHEDULERS[spec]()
        except KeyError:
            raise ValueError(
                f"unknown scheduling policy {spec!r} "
                f"(have {sorted(SCHEDULERS)}, or pass a Scheduler)") from None
    raise ValueError(
        f"scheduling must be a policy name, Scheduler subclass or instance, "
        f"got {spec!r}")


# ---------------------------------------------------------------------------
# fusion-threshold calibration (the skeleton_parity measurement, in-library)
# ---------------------------------------------------------------------------
_HANDOFF_CACHE: Optional[float] = None


def clear_handoff_cache() -> None:
    """Drop the process-wide hand-off calibration so the next
    ``calibrate_handoff_us()`` re-measures.  Autotune pilots and tests
    call this when the cached value may describe a different load regime
    (e.g. a measurement taken while an earlier benchmark saturated the
    cores)."""
    global _HANDOFF_CACHE
    _HANDOFF_CACHE = None


def calibrate_handoff_us(ntasks: int = 2000, repeats: int = 2,
                         force: bool = False, *,
                         recalibrate: bool = False) -> float:
    """Measured per-item cost (µs) of ONE vertex hand-off on this machine:
    the same stream through ``Pipeline(Stage(a), Stage(b))`` (one SPSC
    hand-off) vs the pre-fused single ``Stage(b∘a)``, best of ``repeats``
    — the measurement ``benchmarks/skeleton_parity.py`` makes against the
    mesh backend, reused as the auto threshold for ``fuse(skel)``: a stage
    declaring ``grain=`` below this is cheaper to fuse than to stream.
    Cached per process; ``force=True`` / ``recalibrate=True`` re-measure
    (and refresh the cache), ``clear_handoff_cache()`` just invalidates."""
    global _HANDOFF_CACHE
    if _HANDOFF_CACHE is not None and not (force or recalibrate):
        return _HANDOFF_CACHE
    from .skeleton import Pipeline, Stage, lower

    def _a(x):
        return x + 1

    def _b(x):
        return x * 2

    def _ab(x):
        return (x + 1) * 2

    xs = list(range(ntasks))
    want = [_ab(x) for x in xs]
    split = lower(Pipeline(Stage(_a), Stage(_b)), "threads", fuse=False)
    whole = lower(Stage(_ab), "threads", fuse=False)

    def best(prog):
        dts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            out = prog(xs)
            dts.append(time.perf_counter() - t0)
            assert out == want, "calibration program output mismatch"
        return min(dts)

    _HANDOFF_CACHE = max((best(split) - best(whole)) / ntasks * 1e6, 0.05)
    return _HANDOFF_CACHE
