"""Lock-free Single-Producer/Single-Consumer ring buffer (Lamport 1983).

This is the paper's primitive (Sec. 3.1): a *wait-free, fence-free* bounded
queue correct under exactly one producer thread and one consumer thread.

The algorithm:
  - ``_tail`` is written only by the producer, read by the consumer;
  - ``_head`` is written only by the consumer, read by the producer;
  - a slot is published by writing the payload *then* advancing ``_tail``
    (program order; CPython's GIL gives us the store ordering the paper gets
    from x86 TSO), and reclaimed by reading the payload *then* advancing
    ``_head``.

No locks, no compare-and-swap, no fetch-and-add anywhere on the data path —
that is the whole point of the paper.  ``push``/``pop`` are non-blocking and
return success; blocking helpers spin with an exponential yield backoff
(the paper's queues are non-blocking; blocking is a convenience wrapper).

The FastForward-style cache-line separation of head/tail (Giacomoni et al.,
PPoPP'08) has no observable analogue in CPython, but the single-writer
discipline — the property that makes the algorithm correct — is preserved
exactly and is what the hypothesis tests in ``tests/test_spsc.py`` check.
"""
from __future__ import annotations

import time
from typing import Any, List, Optional

__all__ = ["SPSCQueue", "EOS", "Backoff"]


class Backoff:
    """Truncated-exponential spin/yield backoff for the blocking helpers.

    One instance per ``push_wait``/``pop_wait`` call: 64 pure spins (the
    uncontended hand-off resolves in nanoseconds), then sleeps that double
    from 20µs up to a 1ms cap.  ``pause`` checks the deadline *before*
    sleeping and never sleeps past it, so a blocking call returns within
    ``timeout`` plus at most one scheduler quantum — not ``timeout`` plus
    a full backoff step.  Shared by ``SPSCQueue`` and ``ShmRing`` so the
    two rings keep identical blocking semantics.
    """

    __slots__ = ("_spins", "_delay")

    SPINS = 64
    FLOOR = 0.000_02
    CAP = 0.001

    def __init__(self) -> None:
        self._spins = 0
        self._delay = self.FLOOR

    def pause(self, deadline: Optional[float] = None) -> bool:
        """Back off once; returns False when the deadline has passed."""
        if self._spins < self.SPINS:
            self._spins += 1
            return True
        if deadline is None:
            time.sleep(self._delay)
        else:
            now = time.monotonic()
            if now >= deadline:
                return False
            time.sleep(min(self._delay, deadline - now))
        self._delay = min(self._delay * 2.0, self.CAP)
        return True


class _EOS:
    """End-of-stream sentinel (FastFlow's ``NULL`` return from ``svc``).

    A singleton *per process*: every ``item is EOS`` check in the runtime
    relies on identity.  ``__reduce__`` makes pickling return the
    constructor, so an EOS crossing a process boundary (the ``procs``
    backend ships it through a shared-memory ring) unpickles to the far
    side's canonical instance under **every** protocol — without it,
    protocol ≤ 1 reconstructs via ``object.__new__`` and breaks every
    identity check downstream."""

    _instance: Optional["_EOS"] = None

    def __new__(cls) -> "_EOS":
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __reduce__(self):
        return (_EOS, ())

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return "<EOS>"


EOS = _EOS()


class SPSCQueue:
    """Bounded wait-free SPSC FIFO.

    ``capacity`` is rounded up to a power of two so the ring index is a mask
    (as in FastFlow's implementation).  One slot is sacrificed to distinguish
    full from empty (classic Lamport formulation).
    """

    __slots__ = ("_buf", "_mask", "_head", "_tail", "pushes", "pops")

    def __init__(self, capacity: int = 512):
        if capacity < 2:
            capacity = 2
        size = 1
        while size < capacity + 1:
            size <<= 1
        self._buf: List[Any] = [None] * size
        self._mask = size - 1
        # Producer-private and consumer-private indices, stored masked to
        # the ring size (not monotonic: every advance re-wraps with
        # ``& _mask``, so len()/full() mask both sides before comparing).
        self._head = 0  # next slot to read  (consumer writes)
        self._tail = 0  # next slot to write (producer writes)
        self.pushes = 0
        self.pops = 0

    # -- introspection (safe from either side; values may be stale) --------
    def __len__(self) -> int:
        return (self._tail - self._head) & self._mask

    @property
    def capacity(self) -> int:
        return self._mask  # one slot reserved

    def empty(self) -> bool:
        return self._head == self._tail

    def full(self) -> bool:
        return ((self._tail + 1) & self._mask) == (self._head & self._mask)

    # -- producer side ------------------------------------------------------
    def push(self, item: Any) -> bool:
        """Non-blocking enqueue. Returns False when full. Producer-only."""
        tail = self._tail
        nxt = (tail + 1) & self._mask
        if nxt == (self._head & self._mask):
            return False
        self._buf[tail & self._mask] = item  # write payload ...
        self._tail = nxt                     # ... then publish (order matters)
        self.pushes += 1
        return True

    def push_wait(self, item: Any, timeout: Optional[float] = None) -> bool:
        """Blocking enqueue with spin/yield backoff."""
        deadline = None if timeout is None else time.monotonic() + timeout
        backoff = Backoff()
        while not self.push(item):
            if not backoff.pause(deadline):
                return False
        return True

    # -- consumer side ------------------------------------------------------
    _EMPTY = object()

    def pop(self) -> Any:
        """Non-blocking dequeue. Returns ``SPSCQueue._EMPTY`` when empty."""
        head = self._head
        if head == self._tail:
            return SPSCQueue._EMPTY
        idx = head & self._mask
        item = self._buf[idx]
        self._buf[idx] = None   # read payload / drop ref ...
        self._head = (head + 1) & self._mask  # ... then release the slot
        self.pops += 1
        return item

    def pop_wait(self, timeout: Optional[float] = None) -> Any:
        """Blocking dequeue with spin/yield backoff.

        Returns ``SPSCQueue._EMPTY`` on timeout.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        backoff = Backoff()
        while True:
            item = self.pop()
            if item is not SPSCQueue._EMPTY:
                return item
            if not backoff.pause(deadline):
                return SPSCQueue._EMPTY
