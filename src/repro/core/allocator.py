"""SPMC page-pool allocator (paper Sec. 3.1, "FastFlow allocator").

The paper's observation: in a streaming network, allocation is asymmetric —
*one* entity allocates (the Emitter materialising tasks) and *other*
entities free (Workers/Collector).  Exploiting that asymmetry, the allocator
needs no lock at all: frees travel back to the allocating entity over
per-freer SPSC rings, and every mutation of the pool happens on the
allocator's own thread.

Here the same design backs the production use-case of this repo: the
**paged KV-cache pool** of the serving farm (`launch/serve.py`).  The
admitter (Emitter) allocates pages for new requests; decode workers release
pages of finished requests through their private free-rings.  This is the
2026 re-materialisation of the paper's SPMC allocator — vLLM-style paging
with FastFlow's synchronisation-free bookkeeping.

Pages are integer ids into an optional caller-owned backing store, so the
allocator is equally usable for host numpy slabs and for device KV pages
(where the id indexes a page table fed to the decode step).
"""
from __future__ import annotations

from typing import List, Optional

from .spsc import SPSCQueue

__all__ = ["PagePool", "PoolExhausted"]


class PoolExhausted(RuntimeError):
    pass


class PagePool:
    """Lock-free SPMC pool of ``npages`` integer page ids.

    Contract (enforced by discipline, checked by tests):
      * ``alloc``/``drain`` are called only from the allocator entity's thread;
      * ``free(page, freer)`` is called only from freer ``freer``'s thread.
    """

    def __init__(self, npages: int, nfreers: int = 1, ring_capacity: Optional[int] = None):
        assert npages >= 1 and nfreers >= 1
        self.npages = npages
        self.nfreers = nfreers
        self._free_list: List[int] = list(range(npages - 1, -1, -1))
        cap = ring_capacity or (npages + 2)
        self._free_rings = [SPSCQueue(cap) for _ in range(nfreers)]
        self.allocated = 0
        self.freed = 0

    # -- allocator-thread side ----------------------------------------------
    def drain(self) -> int:
        """Pull returned pages from all free-rings back into the pool."""
        n = 0
        for ring in self._free_rings:
            while True:
                page = ring.pop()
                if page is SPSCQueue._EMPTY:
                    break
                self._free_list.append(page)
                n += 1
        return n

    def alloc(self) -> int:
        if not self._free_list:
            self.drain()
        if not self._free_list:
            raise PoolExhausted(f"all {self.npages} pages in flight")
        self.allocated += 1
        return self._free_list.pop()

    def try_alloc(self) -> Optional[int]:
        try:
            return self.alloc()
        except PoolExhausted:
            return None

    def alloc_many(self, n: int) -> List[int]:
        pages = []
        try:
            for _ in range(n):
                pages.append(self.alloc())
        except PoolExhausted:
            # all-or-nothing: return what we grabbed
            self._free_list.extend(pages)
            self.allocated -= len(pages)
            raise
        return pages

    def available(self) -> int:
        """Lower bound (free-rings may hold more)."""
        return len(self._free_list)

    # -- freer-thread side ----------------------------------------------------
    def free(self, page: int, freer: int = 0) -> None:
        assert 0 <= page < self.npages
        self._free_rings[freer].push_wait(page)
        self.freed += 1

    def free_many(self, pages: List[int], freer: int = 0) -> None:
        for p in pages:
            self.free(p, freer)
