"""Profile-guided re-lowering — the self-tuning half of the runtime.

The static knobs (`grain=`, ring `capacity=`, the fusion threshold) are
all declared at `lower()` time, and the porting literature around the
source paper shows exactly how they fail: a grain mis-declared by 100×
turns the farm speedup curve flat.  This module closes the loop the
ROADMAP calls "profile, re-lower, repeat":

1. **Profile** — :func:`profile` runs a bounded *pilot* slice of the
   stream through an instrumented threads lowering of the skeleton and
   records, per IR position: the measured per-item service time (mean +
   EWMA, the same 0.8/0.2 smoothing `FarmStats.service_ewma` uses),
   the outbound-queue high-water mark (sampled by the caller through
   :meth:`~repro.core.graph.Graph.sample_high_water`), and the machine's
   calibrated per-hand-off cost (:func:`~repro.core.sched.
   calibrate_handoff_us`).  The result is a JSON-serializable
   :class:`Profile` that can be saved, diffed, and replayed.

2. **Retune** — :func:`retune` is a *pure IR rewrite*: it re-declares
   each stage's ``grain=`` as its measured service time, re-runs
   :func:`~repro.core.skeleton.fuse` with the measured hand-off cost as
   the threshold (which now also merges ``Farm∘Farm`` pairs and absorbs
   stateless post-shuffle stages into a2a right rows), sizes each
   Stage/Source outbound ring from the producer/consumer service-rate
   ratio (:func:`ring_capacity`), and micro-batches the survivors whose
   hand-off cost still dominates (:func:`auto_batch`, riding the
   existing :class:`~repro.core.skeleton.KeyBatch` wire format).  The
   rewrite never changes results — that is pinned by three-backend
   parity tests.

3. **Replay** — ``lower(skel, backend, tune=True)`` wraps both phases
   in a :class:`TunedProgram`: the first call profiles a pilot slice,
   retunes, and runs the remainder through the tuned program; later
   calls go straight to the tuned program.  ``profile=`` (a
   :class:`Profile` or a path) skips the pilot entirely.

The mesh backend is different in kind: its ``grain`` is a microbatch
*row count* and its tuning axis is the ``(stage, worker)`` mesh
factorization, so :func:`retune` leaves the IR alone and
:func:`plan_mesh` instead derives program options from the bubble model
(:func:`~repro.core.dpipeline.best_factorization` /
``pipeline_utilisation``).

This module must stay importable without jax (``import repro.core`` is
pinned jax-free): everything device-side is imported lazily inside
:func:`plan_mesh`.
"""
from __future__ import annotations

import json
import math
import time
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, Iterable, List, Optional

from .skeleton import (GO_ON, AllToAll, EmitMany, Farm, Feedback, FnNode,
                       FusedNode, KeyBatch, Pipeline, Skeleton, Source, Stage,
                       _stateless, as_skeleton, ff_node, fuse, lower)

__all__ = ["Profile", "StageProfile", "profile", "retune", "plan_mesh",
           "auto_batch", "ring_capacity", "TunedProgram", "DEFAULT_PILOT"]

DEFAULT_PILOT = 512          # pilot slice length when tune=True gives none
_EWMA_OLD, _EWMA_NEW = 0.8, 0.2   # FarmStats.service_ewma's smoothing


# ---------------------------------------------------------------------------
# the profile: measured signals, serializable
# ---------------------------------------------------------------------------
@dataclass
class StageProfile:
    """Measured signals for one IR position.

    ``path`` is the position in the (flattened) top-level pipeline:
    ``"1"`` is stage index 1, ``"2.left"``/``"2.right"`` are an
    all-to-all's rows.  ``width`` is the row's parallel width (a farm's
    ``nworkers``), so a consumer's *effective* per-item service rate is
    ``service_us / width``.  ``queue_high_water`` is the deepest the
    position's outbound ring got during the pilot (0 when the tap cannot
    see it — farm-internal rings are not sampled)."""

    path: str
    kind: str                      # stage|source|farm|feedback|a2a-left|...
    name: str
    service_us: float              # mean per-item service time
    service_ewma_us: float         # EWMA, same smoothing as FarmStats
    items: int                     # items measured (0 = no signal)
    width: int = 1
    queue_high_water: int = 0


@dataclass
class Profile:
    """A pilot run's measurements, ready to re-lower from (or save)."""

    handoff_us: float              # calibrated per-hand-off cost
    pilot_items: int               # stream slice length that was measured
    stages: List[StageProfile] = field(default_factory=list)
    schema: str = "autotune-profile/1"

    def stage(self, path: str) -> Optional[StageProfile]:
        for sp in self.stages:
            if sp.path == path:
                return sp
        return None

    # -- serialization -------------------------------------------------------
    def to_json(self) -> Dict[str, Any]:
        return {"schema": self.schema, "handoff_us": self.handoff_us,
                "pilot_items": self.pilot_items,
                "stages": [asdict(sp) for sp in self.stages]}

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "Profile":
        if d.get("schema") != "autotune-profile/1":
            raise ValueError(f"not an autotune profile: {d.get('schema')!r}")
        return cls(handoff_us=float(d["handoff_us"]),
                   pilot_items=int(d["pilot_items"]),
                   stages=[StageProfile(**sp) for sp in d["stages"]])

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "Profile":
        with open(path) as f:
            return cls.from_json(json.load(f))

    def diff(self, other: "Profile") -> Dict[str, Dict[str, Any]]:
        """Per-position deltas vs another profile of the same skeleton —
        what changed between two pilot runs (drifted service times,
        deeper queues).  Positions missing on either side are reported
        with ``None`` on that side."""
        mine = {sp.path: sp for sp in self.stages}
        theirs = {sp.path: sp for sp in other.stages}
        out: Dict[str, Dict[str, Any]] = {}
        for p in sorted(set(mine) | set(theirs)):
            a, b = mine.get(p), theirs.get(p)
            out[p] = {
                "service_us": ((a.service_us if a else None),
                               (b.service_us if b else None)),
                "queue_high_water": ((a.queue_high_water if a else None),
                                     (b.queue_high_water if b else None)),
            }
        return out


# ---------------------------------------------------------------------------
# instrumentation: a structural copy with timed nodes
# ---------------------------------------------------------------------------
class _StageAcc:
    """Service-time accumulator shared by one IR position's wrappers.

    Counter updates are plain ``+=`` — a farm row's workers share one
    accumulator, so concurrent updates can race and drop an increment.
    That is deliberate: a lock on the nanosecond path would distort the
    very quantity being measured, and a profile tolerates ~1% undercount
    where it would not tolerate +100ns per item."""

    __slots__ = ("count", "total", "ewma")

    def __init__(self) -> None:
        self.count = 0
        self.total = 0.0
        self.ewma: Optional[float] = None

    def add(self, dt_us: float) -> None:
        self.count += 1
        self.total += dt_us
        self.ewma = (dt_us if self.ewma is None
                     else _EWMA_OLD * self.ewma + _EWMA_NEW * dt_us)

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0


class _TimedNode(ff_node):
    """Transparent timing wrapper: forwards the whole ``ff_node`` protocol
    to ``inner`` and records each ``svc`` call's duration into ``acc``.
    The inner node instance is shared with the original skeleton, so any
    state it builds during the pilot (and flushes at EOS) behaves exactly
    as an untimed run's would."""

    def __init__(self, inner: ff_node, acc: _StageAcc):
        self.inner = inner
        self.acc = acc
        # duck-typed markers the builders probe with getattr — a wrapper
        # must not hide them (batch-aware folds, budget boards)
        self.accepts_batches = getattr(inner, "accepts_batches", False)
        self.budget = getattr(inner, "budget", None)

    def svc_init(self) -> None:
        self.inner.svc_init()

    def svc_end(self) -> None:
        self.inner.svc_end()

    def svc(self, task: Any) -> Any:
        t0 = time.perf_counter()
        r = self.inner.svc(task)
        self.acc.add((time.perf_counter() - t0) * 1e6)
        return r

    def svc_eos(self) -> Any:
        return self.inner.svc_eos()


def _wrap_row(nodes: List[ff_node], acc: _StageAcc) -> List[ff_node]:
    """Wrap a farm/a2a row, one wrapper per slot (each runs in exactly
    one vertex thread).  Nodes carrying builder-probed markers that a
    wrapper cannot fully reproduce cross-process are left untimed."""
    out: List[ff_node] = []
    for n in nodes:
        if getattr(n, "accepts_batches", False) \
                or getattr(n, "budget", None) is not None:
            out.append(n)          # e.g. SpillFold: leave the real node
        else:
            out.append(_TimedNode(n, acc))
    return out


def _instrument(skel: Skeleton, accs: Dict[str, Any]):
    """Structural copy of ``skel`` with per-position timing.  ``accs``
    maps path -> (kind, name, width, acc)."""
    stages = skel.stages if isinstance(skel, Pipeline) else [skel]
    out: List[Skeleton] = []
    for i, s in enumerate(stages):
        p = str(i)
        if isinstance(s, Source):
            acc = _StageAcc()
            accs[p] = ("source", s.name, 1, acc)
            out.append(Source(_TimedNode(s.node, acc), name=s.name,
                              grain=s.grain, capacity=s.capacity))
        elif isinstance(s, Stage):
            acc = _StageAcc()
            accs[p] = ("stage", s.name, 1, acc)
            out.append(Stage(_TimedNode(s.node, acc), name=s.name,
                             grain=s.grain, capacity=s.capacity))
        elif isinstance(s, Farm):
            acc = _StageAcc()
            accs[p] = ("farm", "ff-farm", s.nworkers, acc)
            out.append(Farm(
                _wrap_row(s.worker_nodes, acc), s.nworkers,
                emitter=s.emitter, collector=s.collector, ordered=s.ordered,
                grain=s.grain, scheduling=s.scheduling,
                speculative=s.speculative,
                straggler_factor=s.straggler_factor,
                min_straggler_age=s.min_straggler_age, feedback=s.feedback,
                feedback_capacity=s.feedback_capacity,
                queue_class=s.queue_class, capacity=s.capacity))
        elif isinstance(s, AllToAll):
            la, ra = _StageAcc(), _StageAcc()
            accs[f"{p}.left"] = ("a2a-left", s.name, s.nleft, la)
            accs[f"{p}.right"] = ("a2a-right", s.name, s.nright, ra)
            out.append(AllToAll(
                _wrap_row(s.left_nodes, la), _wrap_row(s.right_nodes, ra),
                by=s.by, nleft=s.nleft, nright=s.nright, ordered=s.ordered,
                scheduling=s.scheduling, reduce=s.reduce, grain=s.grain,
                name=s.name, queue_class=s.queue_class,
                capacity=s.capacity))
        elif isinstance(s, Feedback):
            acc = _StageAcc()
            accs[p] = ("feedback", s.name, s.nworkers, acc)
            out.append(Feedback(_TimedNode(s.node, acc), s.loop_while,
                                nworkers=s.nworkers, max_trips=s.max_trips,
                                scheduling=s.scheduling, grain=s.grain,
                                name=s.name))
        else:
            out.append(s)          # unknown composite: run untimed
    return Pipeline(*out) if len(out) > 1 else out[0]


def _profiled_run(skel: Skeleton, xs: List[Any], *,
                  recalibrate: bool = False):
    """Run ``xs`` through an instrumented threads lowering; return
    ``(Profile, outputs)``.  The caller thread samples queue depths
    while the pilot drains (the profile tap)."""
    from .sched import calibrate_handoff_us
    handoff = calibrate_handoff_us(recalibrate=recalibrate)
    accs: Dict[str, Any] = {}
    instr = _instrument(skel, accs)
    g = lower(instr, "threads", fuse=False).to_graph(list(xs))
    hw: Dict[str, int] = {}
    # the drain sampler runs once inside wait(), after the vertex threads
    # join but before teardown — a pilot short enough to finish before the
    # first poll below still lands every edge key exactly once
    g.drain_samplers.append(lambda: g.sample_high_water(hw))
    g.run()
    while any(t.is_alive() for t in g._threads):
        g.sample_high_water(hw)
        time.sleep(0.0002)
    out = g.wait()
    stages = []
    for path in sorted(accs, key=lambda p: [int(x) if x.isdigit() else x
                                            for x in p.split(".")]):
        kind, name, width, acc = accs[path]
        stages.append(StageProfile(
            path=path, kind=kind, name=name, service_us=acc.mean(),
            service_ewma_us=acc.ewma or 0.0, items=acc.count, width=width,
            queue_high_water=hw.get(f"{name}@{path}", 0)))
    return Profile(handoff_us=handoff, pilot_items=len(xs),
                   stages=stages), out


def profile(skel: Any, items: Iterable[Any], *,
            recalibrate: bool = False) -> Profile:
    """Measure ``skel`` on a pilot stream: per-position service times,
    queue high-water marks, and the machine's hand-off cost.  Runs on
    the threads backend (in-process, no spawn cost) — service times are
    a property of the node functions, so the same profile retunes the
    procs lowering too.  ``recalibrate=True`` re-measures the hand-off
    cost instead of trusting the process-wide cache."""
    prof, _ = _profiled_run(as_skeleton(skel), list(items),
                            recalibrate=recalibrate)
    return prof


# ---------------------------------------------------------------------------
# the tuning models
# ---------------------------------------------------------------------------
def auto_batch(service_us: float, handoff_us: float, *,
               frac: float = 0.10, cap: int = 256) -> int:
    """Auto-grain: the emit-batch size that amortizes the per-item
    hand-off cost below ``frac`` (~10%) of the measured service time.
    1 means the hand-off is already cheap enough to pay per item."""
    svc = max(service_us, 0.05)
    if handoff_us <= frac * svc:
        return 1
    return min(cap, max(2, math.ceil(handoff_us / (frac * svc))))


def ring_capacity(prod_us: float, cons_us: float, high_water: int = 0, *,
                  base: int = 64, lo: int = 16, hi: int = 8192) -> int:
    """Size an SPSC ring from the producer/consumer service-rate ratio:
    a slow consumer (``cons/prod > 1``) earns a deeper ring so bursts
    queue instead of stalling the producer; a slow producer needs almost
    none.  The pilot's observed high-water mark sets a floor (×2 for
    headroom), and the result is a power of two in ``[lo, hi]``."""
    ratio = 1.0 if prod_us <= 0 or cons_us <= 0 else cons_us / prod_us
    ratio = min(8.0, max(0.125, ratio))
    need = max(int(base * ratio), 2 * high_water, lo)
    return min(hi, 1 << (need - 1).bit_length())


# ---------------------------------------------------------------------------
# micro-batching rewrite: KeyBatch emission for surviving fine hand-offs
# ---------------------------------------------------------------------------
class _RebatchNode(ff_node):
    """Buffer a stage's outputs and emit them ``batch`` at a time as ONE
    :class:`KeyBatch` wire message — one ring slot (and on procs one
    pickle) per batch instead of per item.

    Transparent by construction: every consumer-side vertex unpacks
    ``KeyBatch`` back into items before its node's ``svc`` (and the
    terminal result drain does the same), so downstream nodes never see
    the batching.  The wrapper only ever wraps *stateless* mid-pipeline
    stages whose successor is a Stage / AllToAll / Feedback / the caller
    — never a Farm, whose dispatch arbiter routes payloads whole."""

    def __init__(self, inner: ff_node, batch: int):
        self.inner = inner
        self.batch = max(2, int(batch))
        self._buf: List[Any] = []

    def svc_init(self) -> None:
        self.inner.svc_init()

    def svc_end(self) -> None:
        self.inner.svc_end()

    def _flush(self) -> KeyBatch:
        out = KeyBatch(self._buf)
        self._buf = []
        return out

    def svc(self, task: Any) -> Any:
        r = self.inner.svc(task)
        if r is None or r is GO_ON:
            # mid-pipeline None filters one item, exactly like the
            # unwrapped vertex (this node is never placed in source
            # position, where None would instead mean EOS)
            return GO_ON
        if isinstance(r, EmitMany):
            self._buf.extend(r)
        else:
            self._buf.append(r)
        return self._flush() if len(self._buf) >= self.batch else GO_ON

    def svc_eos(self) -> Any:
        r = self.inner.svc_eos()
        if r is not None and r is not GO_ON:
            self._buf.extend(r if isinstance(r, EmitMany) else [r])
        return self._flush() if self._buf else None


def _rebatch_ok_after(nxt: Optional[Skeleton]) -> bool:
    # KeyBatch unpacking happens in StageVertex/ProcStageVertex inbound
    # loops, the a2a scatter, and the caller-side result drain.  A farm's
    # DispatchVertex routes payloads whole — never batch into one.
    return nxt is None or isinstance(nxt, (Stage, AllToAll, Feedback))


# ---------------------------------------------------------------------------
# retune: the pure IR rewrite
# ---------------------------------------------------------------------------
def _effective_cons_us(sp: Optional[StageProfile]) -> float:
    if sp is None or not sp.items:
        return 0.0
    return sp.service_us / max(1, sp.width)


def _consumer_profile(prof: Profile, i: int) -> Optional[StageProfile]:
    """The profile entry that consumes position ``i``'s output: the next
    top-level position, or its left row if that is an all-to-all."""
    return prof.stage(str(i + 1)) or prof.stage(f"{i + 1}.left")


def _retune_one(s: Skeleton, sp: Optional[StageProfile],
                cons: Optional[StageProfile], terminal: bool) -> Skeleton:
    if sp is None or not sp.items:
        return s
    grain = int(round(sp.service_us))
    cap = s.capacity if terminal else ring_capacity(
        sp.service_us, _effective_cons_us(cons), sp.queue_high_water)
    if isinstance(s, Source):
        return Source(s.node, name=s.name, grain=s.grain, capacity=cap)
    if isinstance(s, Stage):
        return Stage(s.node, name=s.name, grain=grain, capacity=cap)
    if isinstance(s, Farm):
        return Farm(s.worker_nodes, s.nworkers, emitter=s.emitter,
                    collector=s.collector, ordered=s.ordered, grain=grain,
                    scheduling=s.scheduling, speculative=s.speculative,
                    straggler_factor=s.straggler_factor,
                    min_straggler_age=s.min_straggler_age,
                    feedback=s.feedback,
                    feedback_capacity=s.feedback_capacity,
                    queue_class=s.queue_class, capacity=s.capacity,
                    stats=s.stats)
    return s                      # AllToAll / Feedback: leave untouched


def retune(skel: Any, prof: Profile, *, backend: str = "threads"):
    """Re-lower ``skel`` from a measured :class:`Profile` — a pure IR
    rewrite that never changes results.

    Host backends (threads / procs): each Stage/Source/Farm gets its
    measured service time as ``grain=`` and a ring capacity from the
    producer/consumer rate ratio; :func:`~repro.core.skeleton.fuse` then
    collapses every hand-off cheaper than the measured hand-off cost
    (including ``Farm∘Farm`` merges and a2a right-row absorption); and
    surviving fine-grain stateless stages get :class:`_RebatchNode`
    micro-batching.  The mesh backend tunes *program options*, not IR —
    its grain is a row count and its axis is the mesh factorization —
    so ``backend="mesh"`` returns the skeleton unchanged (see
    :func:`plan_mesh`)."""
    skel = as_skeleton(skel)
    if backend == "mesh":
        return skel
    stages = skel.stages if isinstance(skel, Pipeline) else [skel]
    rebuilt = [
        _retune_one(s, prof.stage(str(i)) or prof.stage(f"{i}.right"),
                    _consumer_profile(prof, i),
                    terminal=(i == len(stages) - 1))
        for i, s in enumerate(stages)
    ]
    tuned = fuse(Pipeline(*rebuilt) if len(rebuilt) > 1 else rebuilt[0],
                 threshold_us=prof.handoff_us)
    # micro-batch what fusion could not absorb
    out_stages = list(tuned.stages) if isinstance(tuned, Pipeline) \
        else [tuned]
    final: List[Skeleton] = []
    for i, s in enumerate(out_stages):
        nxt = out_stages[i + 1] if i + 1 < len(out_stages) else None
        if isinstance(s, Stage) and _stateless(s.node) \
                and s.grain is not None and _rebatch_ok_after(nxt):
            b = auto_batch(float(s.grain), prof.handoff_us)
            if b > 1:
                s = Stage(_RebatchNode(s.node, b), name=s.name,
                          grain=s.grain, capacity=s.capacity)
        final.append(s)
    return Pipeline(*final) if len(final) > 1 else final[0]


# ---------------------------------------------------------------------------
# mesh planning: factorization + microbatch grain from the bubble model
# ---------------------------------------------------------------------------
def plan_mesh(prof: Profile, skel: Any,
              devices: Optional[int] = None) -> Dict[str, Any]:
    """Mesh program options from a profile: the ``(stage, worker)``
    factorization with the higher modelled throughput
    (:func:`~repro.core.dpipeline.best_factorization` over the measured
    per-stage costs) and, when the pipelined factorization wins, a
    microbatch ``grain`` sized so the fill/drain bubble stays under ~10%
    (``M ≥ 9·(S-1)`` microbatches ⇒ ``pipeline_utilisation ≥ 0.9``).
    Imports jax lazily — call this only on a mesh-capable host."""
    import jax

    from . import dpipeline
    skel = as_skeleton(skel)
    stages = skel.stages if isinstance(skel, Pipeline) else [skel]
    if any(isinstance(s, AllToAll) for s in stages):
        return {}                 # the a2a mesh program has no stage axis
    costs = []
    for i, s in enumerate(stages):
        sp = prof.stage(str(i))
        costs.append(sp.service_us if sp and sp.items else 1.0)
    ndev = devices if devices is not None else len(jax.devices())
    fact = dpipeline.best_factorization(len(costs), ndev, stage_costs=costs,
                                        n_micro=9 * max(1, len(costs) - 1))
    plan: Dict[str, Any] = {"factorization": fact}
    n_stage = fact[0]
    if n_stage > 1:
        plan["grain"] = max(1, prof.pilot_items // (9 * (n_stage - 1)))
    return plan


# ---------------------------------------------------------------------------
# the two-phase program
# ---------------------------------------------------------------------------
class TunedProgram:
    """``lower(skel, backend, tune=True)``: profile a pilot slice, retune,
    replay.

    The first call takes ``pilot`` items off the front of the stream,
    runs them through an instrumented **threads** lowering (in-process —
    the pilot's outputs are real outputs and are returned with the
    rest), builds the :class:`Profile`, retunes the IR, and lowers the
    tuned skeleton on the target backend for the remainder.  Later calls
    go straight to the tuned program.  Passing ``profile=`` (a
    :class:`Profile` or a JSON path) skips the pilot entirely — the
    saved-profile replay path.

    Attributes after tuning: ``profile`` (the measurements), ``tuned``
    (the lowered tuned program), ``tuned_skeleton`` (the rewritten IR,
    host backends only)."""

    def __init__(self, skeleton: Skeleton, backend: str, *,
                 pilot: Optional[int] = None, profile: Any = None,
                 opts: Optional[Dict[str, Any]] = None):
        self.skeleton = as_skeleton(skeleton)
        self.backend = backend
        self.pilot = DEFAULT_PILOT if pilot is None else max(1, int(pilot))
        self.opts = dict(opts or {})
        self.profile: Optional[Profile] = (
            Profile.load(profile) if isinstance(profile, str)
            else profile)
        self.recalibrate = bool(self.opts.pop("recalibrate", False))
        self.tuned: Any = None
        self.tuned_skeleton: Optional[Skeleton] = None
        if self.profile is not None:
            self._build(self.profile)

    def _build(self, prof: Profile) -> None:
        self.profile = prof
        if self.backend == "mesh":
            plan = plan_mesh(prof, self.skeleton,
                             self.opts.get("devices"))
            merged = {**plan, **self.opts}
            self.tuned = lower(self.skeleton, "mesh", **merged)
            self.tuned_skeleton = self.skeleton
        else:
            self.tuned_skeleton = retune(self.skeleton, prof,
                                         backend=self.backend)
            o = dict(self.opts)
            o.setdefault("fuse", False)   # retune already fused
            self.tuned = lower(self.tuned_skeleton, self.backend, **o)

    def __call__(self, items: Iterable[Any]) -> List[Any]:
        xs = list(items)
        if self.tuned is None:
            n = min(len(xs), self.pilot)
            prof, head = _profiled_run(self.skeleton, xs[:n],
                                       recalibrate=self.recalibrate)
            self._build(prof)
            if n == len(xs):
                return head
            return head + self.tuned(xs[n:])
        return self.tuned(xs)
