"""Streaming aggregation operators on top of the all-to-all block.

Everything here is an **IR rewrite**: each operator returns plain skeleton
nodes (:class:`~repro.core.skeleton.AllToAll`, :class:`~repro.core.
skeleton.Stage`), so every operator inherits the backends the IR has —
threads, procs, and (for statically-keyed reductions) mesh — without one
line of backend code of its own.  This is the aggregation shape of the
parquet-aggregator workload: record streams → keyed shuffle → per-key
fold (``examples/log_aggregation.py`` runs it end to end).

=================  =========================================================
operator           rewrite
=================  =========================================================
``partition_by``   ``AllToAll(identity, worker×n, by=key)`` — all items
                   sharing a key are serviced by the same right-vertex
                   instance (keyed affinity without a reduction)
``reduce_by_key``  ``AllToAll(left, _KeyFold×n, by=key, reduce=spec)`` —
                   per-key fold, flushed at EOS; named folds carry a
                   segment implementation, so the mesh backend compiles
                   the same IR node to one ``shard_map`` keyed shuffle
``window``         ``Stage(_WindowNode)`` — tumbling n-item windows folded
                   in-stream (host backends; the node is stateful, which
                   the mesh cannot trace)
=================  =========================================================

Host fold state lives in the right vertices (one ``_KeyFold`` instance
per partition — never shared), accumulates via ``svc`` and leaves the
network through the EOS flush hook (``ff_node.svc_eos``), so a fold's
results are on the wire *before* its vertex's EOS propagates — no side
channel, no post-run collection step.
"""
from __future__ import annotations

import operator
from dataclasses import dataclass
from typing import Any, Callable, List, Optional, Union

from .a2a import _ident
from .skeleton import GO_ON, AllToAll, EmitMany, Stage, ff_node

__all__ = [
    "Fold", "FOLDS", "KeyedReduce",
    "partition_by", "reduce_by_key", "window",
]


def _count_step(acc: int, _x: Any) -> int:
    return acc + 1


@dataclass(frozen=True)
class Fold:
    """A named reduction: the host-side binary fold plus the mesh-side
    segment kind.  ``seed_first=True`` seeds each key's accumulator with
    its first item (sum/min/max — no neutral element needed, and int/float
    types are preserved exactly); ``count`` instead starts from ``init``.

    ``kind`` names the segment/collective implementation the mesh keyed
    shuffle uses (``segment_sum``+``psum`` etc.); it is a string, not a
    jax callable, so importing this module never touches jax.

    ``combine`` merges two *partial accumulators* of the same key — what
    spill-to-disk folds and map-side combining (:mod:`repro.core.oocore`)
    need on top of the per-item step.  Seed-first folds default to the
    step fn itself (``fn`` is accumulator-closed there); seeded folds
    like ``count`` step with an item, so they carry an explicit one."""

    name: str
    fn: Callable[[Any, Any], Any]
    init: Any = None
    seed_first: bool = True
    kind: Optional[str] = None
    combine: Optional[Callable[[Any, Any], Any]] = None


FOLDS = {
    "sum": Fold("sum", operator.add, kind="sum"),
    "min": Fold("min", min, kind="min"),
    "max": Fold("max", max, kind="max"),
    "count": Fold("count", _count_step, init=0, seed_first=False,
                  kind="count", combine=operator.add),
}


@dataclass(frozen=True)
class KeyedReduce:
    """The static part of a keyed reduction — what the mesh backend needs
    to compile the shuffle as one ``shard_map`` program: the key function
    (array-polymorphic, integer keys in ``[0, nkeys)``), the named fold,
    and the key-space bound.  Host backends ignore it (their ``_KeyFold``
    right nodes carry the same semantics dynamically)."""

    by: Callable[[Any], Any]
    fold: Fold
    nkeys: Optional[int] = None


class _KeyFold(ff_node):
    """Right-vertex node of a keyed reduction: fold every arriving item
    into its key's accumulator, emit nothing until EOS, then flush all
    ``(key, fold)`` pairs (``svc_eos``) — one instance per partition, and
    the shuffle guarantees each key visits exactly one instance."""

    def __init__(self, by: Callable[[Any], Any], fn: Callable[[Any, Any], Any],
                 init: Any = None, seed_first: bool = True):
        self.by = by
        self.fn = fn
        self.init = init
        self.seed_first = seed_first
        self._acc: dict = {}

    def svc(self, x):
        k = self.by(x)
        if k in self._acc:
            self._acc[k] = self.fn(self._acc[k], x)
        elif self.seed_first:
            self._acc[k] = x
        else:
            self._acc[k] = self.fn(self.init, x)
        return GO_ON

    def svc_eos(self):
        items = list(self._acc.items())
        self._acc = {}
        try:
            # sorted-key flush: dict insertion order differs per partition
            # history, so two processes folding the same partition would
            # emit the same pairs in different orders — sorting makes
            # threads/procs runs byte-identical (unorderable keys keep
            # arrival order, and the spill path's _OrdKey total order
            # covers the exotic cases)
            items.sort(key=lambda kv: kv[0])
        except TypeError:
            pass
        out = EmitMany(items)
        return out if out else None


class _WindowNode(ff_node):
    """Tumbling window: fold each run of ``n`` consecutive items into one
    emission; the final partial window flushes at EOS."""

    def __init__(self, n: int, fn: Callable[[Any, Any], Any],
                 init: Any = None, seed_first: bool = True):
        assert n >= 1
        self.n = n
        self.fn = fn
        self.init = init
        self.seed_first = seed_first
        self._acc: Any = None
        self._count = 0

    def svc(self, x):
        if self._count == 0:
            self._acc = x if self.seed_first else self.fn(self.init, x)
        else:
            self._acc = self.fn(self._acc, x)
        self._count += 1
        if self._count < self.n:
            return GO_ON
        out, self._acc, self._count = self._acc, None, 0
        return out

    def svc_eos(self):
        if self._count == 0:
            return None
        out, self._acc, self._count = self._acc, None, 0
        return out


def _resolve_fold(fold: Union[str, Fold, Callable], init: Any) \
        -> tuple:
    """-> (host fn, init, seed_first, Fold-or-None).

    A registry name or :class:`Fold` carries its own seed, so a
    user-passed ``init=`` would be silently discarded — that conflict is
    an error, not a preference fight the spec always wins."""
    if isinstance(fold, Fold):
        if init is not None:
            raise ValueError(
                f"init={init!r} conflicts with the Fold spec "
                f"{fold.name!r}, which already defines init={fold.init!r}"
                f" — pass a bare callable to use a custom seed")
        return fold.fn, fold.init, fold.seed_first, fold
    if isinstance(fold, str):
        try:
            spec = FOLDS[fold]
        except KeyError:
            raise ValueError(
                f"unknown fold {fold!r} (have {sorted(FOLDS)}, or pass a "
                f"binary callable)") from None
        if init is not None:
            raise ValueError(
                f"init={init!r} conflicts with the named fold {fold!r}, "
                f"which already defines init={spec.init!r} — pass a bare "
                f"callable to use a custom seed")
        return spec.fn, spec.init, spec.seed_first, spec
    if callable(fold):
        # custom binary fold: host backends only (no segment form); with
        # no init the first item seeds the accumulator
        return fold, init, init is None, None
    raise ValueError(f"fold must be a name, Fold, or callable, got {fold!r}")


def _worker_row(worker: Any, n: int) -> List[Any]:
    if worker is None:
        return [_ident] * n
    if isinstance(worker, (list, tuple)):
        assert len(worker) == n, "worker list must match partition count"
        return list(worker)
    if isinstance(worker, type):
        return [worker() for _ in range(n)]  # fresh instance per partition
    return [worker] * n  # shared by reference — stateless callers only


def partition_by(by: Callable[[Any], Any], nparts: int,
                 worker: Any = None, *, nleft: int = 1,
                 scheduling: Any = "rr",
                 name: str = "partition-by") -> AllToAll:
    """Keyed repartition: every item whose key hashes alike is serviced by
    the *same* right-vertex ``worker`` instance — keyed affinity as a
    network, for per-key state that a reduction does not cover (dedup
    sets, per-tenant caches, sticky sessions).

    ``worker`` may be ``None`` (pure shuffle), one node/callable shared by
    the row, a *class* (instantiated fresh per partition — the right way
    to ship per-partition state), or a list of ``nparts`` nodes."""
    return AllToAll(_ident, _worker_row(worker, nparts), by=by,
                    nleft=nleft, nright=nparts, scheduling=scheduling,
                    name=name)


def reduce_by_key(by: Callable[[Any], Any],
                  fold: Union[str, Fold, Callable] = "sum", *,
                  init: Any = None, nleft: int = 1, nright: int = 2,
                  nkeys: Optional[int] = None, left: Any = None,
                  scheduling: Any = "rr", budget: Any = None,
                  spill_dir: Optional[str] = None,
                  combine: Optional[Callable[[Any, Any], Any]] = None,
                  name: str = "reduce-by-key") -> AllToAll:
    """Partitioned keyed reduction: shuffle by ``by``, fold each key's
    items on the partition that owns it, flush ``(key, fold)`` pairs at
    EOS (sorted per partition, unordered across partitions — compare as
    a dict).

    ``fold`` is a registry name (``"sum"``/``"min"``/``"max"``/
    ``"count"``), a :class:`Fold`, or any binary callable (host backends
    only).  Named folds make the node mesh-lowerable when ``nkeys`` bounds
    the key space (``by`` must then be array-polymorphic with integer
    keys in ``[0, nkeys)``).  ``left`` optionally maps items before the
    shuffle (the columnar-explode stage of an aggregation pipeline).

    ``budget`` bounds the host partitions' fold state: a per-partition
    byte count or a :class:`~repro.core.oocore.MemoryBudget` — the right
    row becomes spill-backed :class:`~repro.core.oocore.SpillFold` stores
    (cold keys go to sorted on-disk runs under ``spill_dir``, merged back
    at the EOS flush), the default scatter policy upgrades to the
    budget-aware backpressure policy, and spill/stall telemetry lands in
    the node's ``.stats``.  The mesh lowering is untouched (it compiles
    the static ``reduce`` spec and never runs the right row), so one
    budgeted skeleton still runs on all three backends.  ``combine``
    merges two partial accumulators of one key — required for spilling
    with a seeded *custom* fold (named folds carry their own)."""
    fn, init, seed_first, spec = _resolve_fold(fold, init)
    if budget is not None:
        # lazy import: oocore composes on top of this module
        from .oocore import MemoryBudget, SpillFold, resolve_combine
        from .sched import BudgetBackpressure
        if not isinstance(budget, MemoryBudget):
            budget = MemoryBudget(int(budget), nparts=nright)
        comb = resolve_combine(spec, fn, seed_first, combine)
        rights: List[Any] = [
            SpillFold(by, fn, init, seed_first, combine=comb,
                      budget=budget, part=j, spill_dir=spill_dir)
            for j in range(nright)]
        if scheduling == "rr":
            scheduling = BudgetBackpressure(budget)
    else:
        rights = [_KeyFold(by, fn, init, seed_first) for _ in range(nright)]
    reduce_spec = (KeyedReduce(by=by, fold=spec, nkeys=nkeys)
                   if spec is not None and spec.kind else None)
    return AllToAll(left if left is not None else _ident, rights, by=by,
                    nleft=nleft, nright=nright, scheduling=scheduling,
                    reduce=reduce_spec, name=name)


def window(n: int, fold: Union[str, Fold, Callable] = "sum", *,
           init: Any = None, name: str = "window") -> Stage:
    """Tumbling window: fold each run of ``n`` consecutive stream items
    into one emission (partial tail flushes at EOS).  A single stateful
    stage — threads and procs backends (the mesh cannot trace stream
    state); composes freely before or after a shuffle."""
    fn, init, seed_first, _ = _resolve_fold(fold, init)
    return Stage(_WindowNode(n, fn, init, seed_first), name=name)
