"""Thread-graph runtime — the skeleton IR's host backend.

FastFlow (paper Sec. 2-3) is a *layered* design: between the lock-free SPSC
ring (``spsc.py``, paper Sec. 3.1) and the declarative skeletons
(``skeleton.py``) sits a runtime for **arbitrary streaming networks** in
which any ``ff_node`` is a vertex, every edge is an SPSC ring, and all
multi-party coordination is performed by *active arbiters* walking their
private ring endpoints — never a lock or an atomic RMW on the data path.

As of the skeleton-IR redesign this module is the **threads backend** of
:func:`repro.core.skeleton.lower`: the declarative ``Pipeline`` / ``Farm``
/ ``Feedback`` / ``Source`` / ``Stage`` vocabulary lives in
:mod:`repro.core.skeleton` (pure data), and :func:`build` below wires an IR
tree into a :class:`Graph` of vertices and rings — PR 1's ``Net._build``
machinery, now driven by the IR.  The old names (``Net``, ``Pipeline``,
``Farm``, ``compose``, ``ff_node``, ...) remain importable from here as
shims for existing callers.

Construct-to-paper map
----------------------
===============================  ==============================================
Construct (this module)          Paper section / figure
===============================  ==============================================
``SPSCQueue`` edge               Sec. 3.1 "Fast SPSC queues" (Lamport ring)
``Graph`` / ``Vertex``           Sec. 2, Fig. 1: streaming networks as graphs
                                 of concurrent entities over SPSC channels
``ff_node`` (svc/svc_init/_end)  Fig. 2: the programming-model node API
``DispatchVertex``               Fig. 1-2 "Emitter" — active arbiter that
                                 fans one logical stream out over private
                                 SPSC rings, driving a pluggable
                                 ``sched.Scheduler`` policy (rr / ondemand
                                 / worksteal / costmodel)
``MergeVertex``                  Fig. 1-2 "Collector" — active arbiter that
                                 fans many rings into one logical stream
``Farm(ordered=True)``           Fig. 1 (right): tagged tokens reordered at
                                 the collector (tagged-token macro data-flow)
``Pipeline`` / ``compose``       Sec. 3.1 "pipeline skeleton": chain of
                                 nodes over SPSC edges
``Farm(feedback=...)``           Sec. 5 wrap-around (collector→emitter) edge
                                 for divide-and-conquer and cyclic networks;
                                 termination by loop quiescence
``Accelerator``                  TR-10-03 "self-offloading": the caller
                                 thread is the source, ``offload()`` is a
                                 push onto the accelerator's inbound ring
macro data-flow executor         Sec. 5 (see ``mdf.py``, built on
                                 ``Farm(feedback=...)``)
===============================  ==============================================

Beyond-paper features carried over from the seed farm (now reusable by any
farm in any composition):

* **straggler re-issue** — the dispatch arbiter speculatively re-sends tasks
  whose age exceeds ``straggler_factor × p95`` of completed latencies; the
  merge arbiter deduplicates by tag (exactly-once delivery downstream);
* **worker-failure tolerance** — a worker thread that dies stops draining
  its ring; its outstanding tags age out and re-speculate to live workers.

Single-writer discipline (what makes this lock-free): every ring has one
producer and one consumer vertex; tag bookkeeping in ``TagSpace`` is split
into dispatch-arbiter-written fields (``next_tag``/``inflight``/``entered``)
and merge-arbiter-written fields (``done``/``retired``).  Cross-thread reads
of the other side's fields are benignly stale — the worst case is one
redundant duplicate, which the merge arbiter drops.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple, Type

from .obs import qualname as _qualname
from .sched import Scheduler, make_scheduler
from .skeleton import (GO_ON, AllToAll, EmitMany, Farm, FarmStats, Feedback,
                       FnNode, KeyBatch, Pipeline, Skeleton, Source, Stage,
                       _FarmEmitMany, _SeqNode, as_skeleton, compose, ff_node)
from .spsc import EOS, SPSCQueue

__all__ = [
    "GO_ON", "Token", "FarmStats", "TagSpace",
    "ff_node", "FnNode",
    "Graph", "Vertex", "StageVertex", "DispatchVertex", "WorkerVertex",
    "MergeVertex", "build", "ring_list",
    "Net", "Stage", "Source", "Pipeline", "Farm", "Feedback", "AllToAll",
    "compose", "Accelerator",
]

_EMPTY = SPSCQueue._EMPTY
_POLL = 0.000_05  # arbiter poll backoff (matches the SPSC blocking helpers)


# ---------------------------------------------------------------------------
# tagged tokens (paper Fig. 1 right) + farm bookkeeping
# ---------------------------------------------------------------------------
@dataclass
class Token:
    tag: int
    payload: Any
    issued_at: float = 0.0
    duplicate: bool = False


class TagSpace:
    """Per-farm tag bookkeeping shared by the two arbiters.

    Single-writer split: the dispatch arbiter writes ``next_tag``,
    ``inflight`` and ``entered``; the merge arbiter writes ``done`` and
    ``retired``.  ``entered``/``retired`` count tokens entering/leaving a
    wrap-around loop (see ``MergeVertex._complete`` for the ordering that
    makes the quiescence check race-free)."""

    __slots__ = ("inflight", "done", "next_tag", "entered", "retired", "stats")

    def __init__(self, stats: Optional[FarmStats] = None):
        self.inflight: Dict[int, Token] = {}
        self.done: Dict[int, bool] = {}
        self.next_tag = 0
        self.entered = 0
        self.retired = 0
        self.stats = stats if stats is not None else FarmStats()


# ---------------------------------------------------------------------------
# graph runtime: vertices (threads) + SPSC edges
# ---------------------------------------------------------------------------
class _Aborted(Exception):
    """Internal: this vertex gave up because another vertex already failed
    (its consumer may be dead and its ring full — blocking would hang)."""


class Vertex:
    """A network vertex: one thread, private SPSC endpoints."""

    # observability (obs.py): ``tracer`` is bound by ``Graph.run()`` when a
    # Tracer is installed, ``path`` is the vertex's IR path assigned by
    # ``build()``.  Class-level defaults keep the untraced hot path at one
    # attribute read that resolves against the class dict — no per-vertex
    # storage, no allocation, when tracing is off.
    tracer = None
    path = ""

    def __init__(self, node: Optional[ff_node] = None, *, name: str = "ff-vertex"):
        self.node = node
        self.name = name
        # batch-aware nodes (SpillFold) take a whole KeyBatch in one svc
        # call; everyone else gets it unpacked by the vertex loop
        self._takes_batches = bool(getattr(node, "accepts_batches", False))
        self.ins: List[Any] = []
        self.outs: List[Any] = []
        self.graph: Optional["Graph"] = None

    # -- lifecycle (runs in the vertex's own thread) ------------------------
    def _run(self) -> None:
        tr = self.tracer
        t_birth = time.monotonic() if tr is not None else 0.0
        try:
            if self.node is not None:
                if tr is not None and getattr(self.node, "wants_tracer",
                                              False):
                    # opt-in node-level events (SpillFold's spill instants):
                    # the node records into ITS vertex's lane, so the
                    # single-writer-per-buffer discipline holds
                    self.node.tracer = tr
                self.node.svc_init()
            self._loop()
        except _Aborted:
            pass  # secondary shutdown; the original error is in graph.failed
        except BaseException as e:
            self._on_error(e)
        finally:
            for q in self.outs:
                self._push_abortable(q, EOS)
            if self.node is not None:
                try:
                    self.node.svc_end()
                except BaseException as e:  # pragma: no cover - defensive
                    self.graph.failed.append(e)
            if tr is not None:
                tr.instant("eos")
                tr.span("life", t_birth, time.monotonic())

    def _on_error(self, e: BaseException) -> None:
        self.graph.failed.append(e)

    def _loop(self) -> None:
        raise NotImplementedError

    def _push_abortable(self, q: Any, item: Any) -> bool:
        """Blocking push that gives up (returns False) once the graph has a
        recorded failure — the ring's consumer may be dead, and blocking on
        a full ring would hang the whole network teardown."""
        spins = 0
        while not q.push(item):
            if self.graph.failed:
                return False
            spins += 1
            if spins > 64:
                time.sleep(_POLL)
        return True

    def _deliver(self, payload: Any) -> None:
        """Emit one raw payload downstream, or into the graph's result sink
        when this vertex has no outbound edge."""
        if self.outs:
            if not self._push_abortable(self.outs[0], payload):
                raise _Aborted()
        else:
            self.graph.results.append(payload)


class StageVertex(Vertex):
    """Generic vertex: any fan-in (nondeterministic merge of untagged
    payloads), any fan-out (``"bcast"`` broadcast, or any scheduling
    policy — name or :class:`~repro.core.sched.Scheduler` — for the
    single-consumer routes, so ``Stage`` and ``Farm`` share one dispatch
    code path).  With no inbound edges it is a *source*: ``svc(None)`` is
    called until it returns ``None`` (EOS) — paper Fig. 2's emitter
    protocol."""

    def __init__(self, node: ff_node, *, route: Any = "rr",
                 name: str = "ff-stage"):
        super().__init__(node, name=name)
        if route == "bcast":
            self._sched: Optional[Scheduler] = None
            self._route: Optional[Callable] = None
        else:
            try:
                self._sched = make_scheduler(route)
            except ValueError:
                raise ValueError(
                    f"unknown Stage route {route!r}: expected 'bcast', a "
                    f"scheduling policy name, or a Scheduler") from None
            # resolve the payload-dependent hook ONCE: the per-emission
            # path must not pay a route() virtual call for the policies
            # (rr/ondemand/costmodel) that never override it
            self._route = (self._sched.route
                           if type(self._sched).route is not Scheduler.route
                           else None)
            if type(self._sched).place is not Scheduler.place \
                    and self._route is None:
                # stage fan-out is routed per emission (payload-dependent
                # route() or stateless pick()); a policy that holds tokens
                # in the arbiter (custom place/pump, e.g. worksteal)
                # needs the farm dispatch arbiter
                raise ValueError(
                    f"Stage route {route!r} is a token-holding policy "
                    f"(custom place()); stage fan-out supports only "
                    f"pick()/route()-based policies — use a Farm for it")
        self.route = route

    def _loop(self) -> None:
        if self._sched is not None:
            self._sched.bind(self.outs, None)
        tr = self.tracer
        if not self.ins:  # source
            while True:
                if tr is not None:
                    t0 = tr.begin()
                    out = self.node.svc(None)
                    tr.end(t0, "svc")
                else:
                    out = self.node.svc(None)
                if out is None or out is EOS:
                    break
                if out is GO_ON:
                    continue
                self._emit(out)
            self._flush_eos()
            return
        eos: set = set()
        while len(eos) < len(self.ins):
            progress = False
            for i, q in enumerate(self.ins):
                if i in eos:
                    continue
                item = q.pop()
                if item is _EMPTY:
                    continue
                progress = True
                if item is EOS:
                    eos.add(i)
                    continue
                if type(item) is KeyBatch and not self._takes_batches:
                    # batched wire format: unpack here so the node still
                    # sees items (batching is transport, not semantics)
                    for x in item:
                        if tr is not None:
                            t0 = tr.begin()
                            out = self.node.svc(x)
                            tr.end(t0, "svc")
                        else:
                            out = self.node.svc(x)
                        if out is None or out is GO_ON:
                            continue
                        self._emit(out)
                    continue
                if tr is not None:
                    t0 = tr.begin()
                    out = self.node.svc(item)
                    tr.end(t0, "svc")
                else:
                    out = self.node.svc(item)
                if out is None or out is GO_ON:
                    continue  # filtered
                self._emit(out)
            if not progress:
                time.sleep(_POLL)
        self._flush_eos()

    def _flush_eos(self) -> None:
        """EOS flush (FastFlow's eosnotify): give the node one chance to
        emit buffered state (``svc_eos``) into the stream before this
        vertex's own EOS propagates — how the keyed folds and window
        operators release their accumulators."""
        out = self.node.svc_eos()
        if out is not None and out is not GO_ON:
            self._emit(out)

    def _emit(self, out: Any) -> None:
        if type(out) is KeyBatch:  # one wire message; consumers unpack
            if not out:
                return
            if not self.outs:
                self.graph.results.extend(out)  # the caller sees items
                return
        elif isinstance(out, EmitMany):  # multi-emit (e.g. a reorder flush)
            for o in out:
                self._emit(o)
            return
        if not self.outs:
            self.graph.results.append(out)
        elif self.route == "bcast":
            for q in self.outs:
                if not self._push_abortable(q, out):
                    raise _Aborted()
        else:
            w = self._sched.pick() if self._route is None else self._route(out)
            if not self._push_abortable(self.outs[w], out):
                raise _Aborted()


class DispatchVertex(Vertex):
    """The farm's Emitter arbiter (paper Figs. 1-2).

    One logical input — a source ``ff_node``, an upstream ring, or a
    wrap-around ring — fanned out over private SPSC rings to the workers.
    Owns tag assignment and straggler re-issue; task *placement* is
    delegated to a pluggable :class:`~repro.core.sched.Scheduler` (rr /
    ondemand / worksteal / costmodel, or user-supplied), driven entirely
    from this arbiter's thread so the single-writer SPSC discipline is
    untouched.  When ``loop_ring`` is set this vertex is also the loop
    master: it terminates only when every upstream edge has delivered EOS
    *and* the loop is quiescent (``entered == retired``, the wrap-around
    ring drained, and no tokens left inside the scheduling policy)."""

    def __init__(
        self,
        tags: TagSpace,
        node: Optional[ff_node] = None,
        *,
        scheduling: Any = "rr",
        speculative: bool = False,
        straggler_factor: float = 4.0,
        min_straggler_age: float = 0.05,
        loop_ring: Optional[Any] = None,
        name: str = "ff-emitter",
    ):
        super().__init__(node, name=name)
        self.sched = make_scheduler(scheduling)
        self.scheduling = self.sched.name
        self.tags = tags
        self.speculative = speculative
        self.straggler_factor = straggler_factor
        self.min_straggler_age = min_straggler_age
        self.loop_ring = loop_ring
        # wrap-around tokens stashed while a worker ring is full (see
        # _push_with_loop_drain: this is what breaks cyclic backpressure)
        self._stash: List[Any] = []

    def _push_with_loop_drain(self, q: Any, tok: Token) -> None:
        """Blocking push that keeps draining the wrap-around ring while the
        target worker ring is full.  Without this, a full worker ring can
        deadlock the cycle: workers blocked on the merge arbiter, the merge
        arbiter blocked on the wrap-around ring, and this arbiter blocked
        here — draining into the local stash breaks the wait cycle.  Gives
        up once the graph has failed (the ring's worker may be dead)."""
        if q.push(tok):
            return  # fast path: no stall, no clock read
        tr = self.tracer
        t0 = time.monotonic() if tr is not None else 0.0
        spins = 0
        while not q.push(tok):
            if self.graph.failed:
                raise _Aborted()
            if self.loop_ring is not None:
                item = self.loop_ring.pop()
                if item is not _EMPTY:
                    self._stash.append(item)
                    continue
            spins += 1
            if spins > 64:
                time.sleep(_POLL)
        if tr is not None:
            tr.span("stall", t0, time.monotonic())

    def _dispatch(self, task: Any) -> None:
        ts = self.tags
        tok = Token(tag=ts.next_tag, payload=task, issued_at=time.monotonic())
        ts.next_tag += 1
        ts.inflight[tok.tag] = tok
        if self.loop_ring is not None:
            ts.entered += 1
        self.sched.place(tok, self._emit_to)
        ts.stats.tasks_emitted += 1
        # backpressure for token-holding policies (worksteal): stop taking
        # input while the policy backlog is over its high-water mark,
        # draining the wrap-around ring meanwhile (same deadlock-avoidance
        # as _push_with_loop_drain)
        hw = self.sched.high_water
        if hw is not None and self.sched.pending() > hw:
            tr = self.tracer
            t0 = time.monotonic() if tr is not None else 0.0
            spins = 0
            while self.sched.pending() > hw:
                if self.sched.pump():
                    continue
                if self.graph.failed:
                    raise _Aborted()
                if self.loop_ring is not None:
                    item = self.loop_ring.pop()
                    if item is not _EMPTY:
                        self._stash.append(item)
                        continue
                spins += 1
                if spins > 64:
                    time.sleep(_POLL)
            if tr is not None:
                tr.span("stall", t0, time.monotonic())

    def _emit_to(self, widx: int, tok: Token) -> None:
        """Blocking-push callback handed to ``Scheduler.place`` (policies
        that hold tokens, like worksteal, never call it and push
        non-blockingly from ``pump`` instead)."""
        self._push_with_loop_drain(self.outs[widx], tok)

    def _respeculate(self) -> None:
        ts = self.tags
        now = time.monotonic()
        p95 = max(ts.stats.p95_latency(), self.min_straggler_age)
        threshold = self.straggler_factor * p95
        for t, tok in list(ts.inflight.items()):
            if t in ts.done:
                continue
            if now - tok.issued_at > threshold:
                dup = Token(tag=t, payload=tok.payload, issued_at=now, duplicate=True)
                widx = self.sched.pick()
                if self.outs[widx].push(dup):
                    # re-arm the age clock; a still-stale tag (e.g. its copy
                    # landed on a dead worker) will speculate again, to a
                    # different worker (rr advanced) — this is what makes the
                    # farm survive worker loss, not just slowness.
                    tok.issued_at = now
                    ts.stats.duplicates_issued += 1

    def _loop(self) -> None:
        ts = self.tags
        self.sched.bind(self.outs, ts.stats)
        tr = self.tracer
        steals0 = ts.stats.steals if tr is not None else 0
        ndisp = 0
        if self.node is not None and not self.ins:
            # source mode: the emitter node generates the stream
            while True:
                if tr is not None:
                    t0 = tr.begin()
                    task = self.node.svc(None)
                    tr.end(t0, "svc")
                else:
                    task = self.node.svc(None)
                if task is None or task is EOS:
                    break
                if task is GO_ON:
                    continue
                self._dispatch(task)
                ndisp += 1
                self.sched.pump()  # flush/steal while we generate
                if tr is not None and ts.stats.steals != steals0:
                    tr.instant("steal",
                               {"count": ts.stats.steals - steals0})
                    steals0 = ts.stats.steals
                # keep the wrap-around ring moving while we generate
                if self.loop_ring is not None:
                    while True:
                        item = self.loop_ring.pop()
                        if item is _EMPTY:
                            break
                        self._dispatch(item)
                        ndisp += 1
                        if tr is not None:
                            tr.tick("loop")
                if self.speculative and ndisp % 32 == 0:
                    self._respeculate()
            # source exhausted; drain the loop to quiescence
            while self.loop_ring is not None:
                progress = self.sched.pump()
                while self._stash:
                    self._dispatch(self._stash.pop(0))
                    progress = True
                while True:
                    item = self.loop_ring.pop()
                    if item is _EMPTY:
                        break
                    progress = True
                    self._dispatch(item)
                    if tr is not None:
                        tr.tick("loop")
                if not self._stash and not self.sched.pending() \
                        and ts.entered == ts.retired \
                        and self.loop_ring.empty():
                    break
                if self.graph.failed:
                    break  # a vertex died: tokens can never retire
                if not progress:
                    # yield (not sleep) while the policy still holds
                    # tokens: a fine-grain worker drains its primed ring
                    # in far less than a poll tick
                    time.sleep(0 if self.sched.pending() else _POLL)
            # flush tokens still held by the policy (e.g. worksteal
            # backlogs) before the EOS goes out behind them
            while self.sched.pending() and not self.graph.failed:
                if not self.sched.pump():
                    time.sleep(0)
        else:
            eos: set = set()
            spec_mark = 0  # dispatches at the last speculation sweep
            while True:
                progress = self.sched.pump()
                if tr is not None and ts.stats.steals != steals0:
                    tr.instant("steal",
                               {"count": ts.stats.steals - steals0})
                    steals0 = ts.stats.steals
                # wrap-around tokens first: looped-back work is older
                while self._stash:
                    self._dispatch(self._stash.pop(0))
                    ndisp += 1
                    progress = True
                if self.loop_ring is not None:
                    while True:
                        item = self.loop_ring.pop()
                        if item is _EMPTY:
                            break
                        progress = True
                        self._dispatch(item)
                        ndisp += 1
                        if tr is not None:
                            tr.tick("loop")
                for i, q in enumerate(self.ins):
                    if i in eos:
                        continue
                    item = q.pop()
                    if item is _EMPTY:
                        continue
                    progress = True
                    if item is EOS:
                        eos.add(i)
                        continue
                    if self.node is not None:
                        # emitter node as per-item scheduler/filter
                        if tr is not None:
                            t0 = tr.begin()
                            item = self.node.svc(item)
                            tr.end(t0, "svc")
                        else:
                            item = self.node.svc(item)
                        if item is None or item is GO_ON:
                            continue
                    self._dispatch(item)
                    ndisp += 1
                if self.speculative and ndisp - spec_mark >= 32:
                    # per-32-dispatches, not per poll iteration: _respeculate
                    # sorts the whole latency list and must not run while idle
                    spec_mark = ndisp
                    self._respeculate()
                if len(eos) == len(self.ins) and not self._stash \
                        and not self.sched.pending():
                    if self.loop_ring is None:
                        break
                    # Quiescence check — read order matters: ``retired``
                    # first, then the ring.  The merge arbiter pushes
                    # wrap-around tasks *before* incrementing ``retired``,
                    # so if entered == retired here, every looped-back task
                    # from completed tokens is already visible in the ring.
                    if ts.entered == ts.retired and self.loop_ring.empty():
                        break
                if self.graph.failed:
                    break  # a vertex died: quiescence can never be reached
                if not progress:
                    # yield while the policy holds tokens (see above)
                    time.sleep(0 if self.sched.pending() else _POLL)
        # straggler watchdog: keep re-issuing until everything is collected
        while self.speculative and any(t not in ts.done for t in ts.inflight):
            if self.graph.failed:
                break  # e.g. the collector died: tags can never complete
            self._respeculate()
            time.sleep(0.002)


class WorkerVertex(Vertex):
    """Farm worker: one inbound and one outbound ring, tags carried
    through untouched (the worker never sees the tag).

    When the farm's policy asks for it (``needs_service_stats``, e.g.
    ``costmodel``), each worker maintains its own service-time EWMA in
    ``stats.service_ewma[index]`` (single writer per key); other policies
    skip the per-task timing entirely.  With an ``idle_ring`` (the
    ``worksteal`` policy's side-channel) the worker advertises itself to
    the dispatch arbiter whenever its inbound ring runs dry, which is what
    triggers a steal from the deepest peer backlog."""

    def __init__(self, node: ff_node, index: int, stats: FarmStats, *,
                 survivable: bool = False, idle_ring: Optional[Any] = None,
                 record_service: bool = False, name: str = "ff-worker"):
        super().__init__(node, name=name)
        self.index = index
        self.stats = stats
        self.survivable = survivable
        self.idle_ring = idle_ring
        self.record_service = record_service

    def _loop(self) -> None:
        q_in, q_out = self.ins[0], self.outs[0]
        stats = self.stats
        tr = self.tracer
        record = self.record_service  # opt-in: only pay the timing when a
        signaled = False              # policy consumes the EWMA
        spins = 0
        while True:
            if self.idle_ring is None:
                tok = q_in.pop_wait()
            else:
                tok = q_in.pop()
                if tok is _EMPTY:
                    # steal side-channel: advertise idleness (re-advertise
                    # periodically — a signal consumed while the arbiter
                    # had nothing to give must not strand this worker)
                    if not signaled or spins % 512 == 511:
                        signaled = self.idle_ring.push(self.index) or signaled
                    spins += 1
                    if spins > 64:
                        time.sleep(_POLL)
                    continue
                signaled = False
                spins = 0
            if tok is EOS:
                return
            tb = tr.begin() if tr is not None else 0.0
            if record:
                t0 = time.monotonic()
                result = self.node.svc(tok.payload)
                dt = time.monotonic() - t0
                prev = stats.service_ewma.get(self.index)
                stats.service_ewma[self.index] = \
                    dt if prev is None else 0.8 * prev + 0.2 * dt
            else:
                result = self.node.svc(tok.payload)
            if tr is not None:
                tr.end(tb, "svc")
            out = Token(tag=tok.tag, payload=result,
                        issued_at=tok.issued_at, duplicate=tok.duplicate)
            if not self._push_abortable(q_out, out):
                raise _Aborted()
            stats.per_worker[self.index] = stats.per_worker.get(self.index, 0) + 1

    def _on_error(self, e: BaseException) -> None:
        if self.survivable:
            # fault tolerance: a dying worker is survivable — its
            # outstanding tags age out and re-speculate to live workers.
            self.stats.worker_failures.append((self.index, repr(e)))
        else:
            self.graph.failed.append(e)


class MergeVertex(Vertex):
    """The farm's Collector arbiter (paper Figs. 1-2).

    Merges the worker rings into one logical stream: exactly-once by tag
    (duplicates from speculation are dropped), optional reorder-by-tag
    (``ordered`` — the tagged-token collector of Fig. 1 right), optional
    collector ``ff_node``, and optional wrap-around routing: ``feedback``
    decides, per result, what leaves the loop and what goes back around."""

    def __init__(
        self,
        tags: TagSpace,
        node: Optional[ff_node] = None,
        *,
        ordered: bool = False,
        loop_ring: Optional[Any] = None,
        feedback: Optional[Callable[[Any], Tuple[Any, Iterable[Any]]]] = None,
        name: str = "ff-collector",
    ):
        super().__init__(node, name=name)
        self.tags = tags
        self.ordered = ordered
        self.loop_ring = loop_ring
        self.feedback = feedback

    def _loop(self) -> None:
        ts = self.tags
        eos: set = set()
        next_tag = 0
        reorder: Dict[int, Any] = {}
        while len(eos) < len(self.ins):
            progress = False
            for i, q in enumerate(self.ins):
                if i in eos:
                    continue
                tok = q.pop()
                if tok is _EMPTY:
                    continue
                progress = True
                if tok is EOS:
                    eos.add(i)
                    continue
                if tok.tag in ts.done:
                    ts.stats.duplicates_dropped += 1
                    continue
                ts.done[tok.tag] = True
                ts.stats.tasks_collected += 1
                ts.stats.latencies.append(time.monotonic() - tok.issued_at)
                if self.ordered:
                    reorder[tok.tag] = tok.payload
                    while next_tag in reorder:
                        self._complete(reorder.pop(next_tag))
                        next_tag += 1
                else:
                    self._complete(tok.payload)
            if not progress:
                time.sleep(_POLL)
        # flush any residue (can only happen if tags were skipped upstream)
        for t in sorted(reorder):
            self._complete(reorder.pop(t))

    def _complete(self, payload: Any) -> None:
        if payload is GO_ON:
            # a worker returning GO_ON emits nothing (ff_node contract);
            # the tag is already done, the token just retires silently
            self._retire()
            return
        tr = self.tracer
        if self.node is not None:
            if tr is not None:
                t0 = tr.begin()
                payload = self.node.svc(payload)
                tr.end(t0, "svc")
            else:
                payload = self.node.svc(payload)
            if payload is None or payload is GO_ON:
                self._retire()
                return
        if self.feedback is not None:
            emit, new_tasks = self.feedback(payload)
            # push wrap-around tasks BEFORE retiring the token: the dispatch
            # arbiter's quiescence check relies on this ordering.
            for t in new_tasks:
                if not self._push_abortable(self.loop_ring, t):
                    raise _Aborted()
                if tr is not None:
                    tr.tick("loop")
            self._retire()
            if emit is None:
                return
            payload = emit
        else:
            self._retire()
        if isinstance(payload, _FarmEmitMany):
            # a farm-absorbed tail multi-emitted: flatten downstream, as
            # the unfused trailing StageVertex would have
            for p in payload:
                self._deliver(p)
            return
        self._deliver(payload)

    def _retire(self) -> None:
        if self.loop_ring is not None:
            self.tags.retired += 1


class Graph:
    """A streaming network: vertices (one thread each) + SPSC edges.

    The low-level API (``add`` / ``connect``) supports arbitrary topologies;
    the skeleton layer (``Pipeline`` / ``Farm`` / ``compose``) builds graphs
    for the common shapes.  ``results`` collects whatever reaches a vertex
    with no outbound edge."""

    def __init__(self, *, queue_class: Type = SPSCQueue, capacity: int = 512):
        self.queue_class = queue_class
        self.capacity = capacity
        self.vertices: List[Vertex] = []
        self.results: List[Any] = []
        self.failed: List[BaseException] = []
        self._threads: List[threading.Thread] = []
        # post-run hooks (builders register them): fold telemetry boards
        # back into the IR node's stats once the vertices have joined
        self.finalizers: List[Callable[[], None]] = []
        # observability: when set (obs.Tracer), run() hands each vertex its
        # own single-writer lane before the threads start
        self.tracer = None
        # drain-time taps: callables run exactly once inside wait(), after
        # the vertex threads have joined but BEFORE the finalizers tear any
        # telemetry down — the only point where "the stream is complete"
        # and "the rings still exist" are both true, so a short run that
        # finished before the first caller-side poll still lands exactly
        # one sample per edge (and never races the caller's results drain)
        self.drain_samplers: List[Callable[[], None]] = []
        # set by a monitored lowering: farm workers opt into service-EWMA
        # timing so the live sampler has a signal to read (see monitor.py)
        self.live_telemetry = False

    def channel(self, capacity: Optional[int] = None,
                queue_class: Optional[Type] = None) -> Any:
        qc = queue_class or self.queue_class
        return qc(capacity or self.capacity)

    def add(self, v: Vertex) -> Vertex:
        v.graph = self
        self.vertices.append(v)
        return v

    def connect(self, src: Vertex, dst: Vertex, *, capacity: Optional[int] = None,
                queue_class: Optional[Type] = None) -> Any:
        ring = self.channel(capacity, queue_class)
        src.outs.append(ring)
        dst.ins.append(ring)
        return ring

    def run(self) -> "Graph":
        assert not self._threads, "graph already running"
        tr = self.tracer
        if tr is not None:
            for v in self.vertices:
                if v.tracer is None:
                    v.tracer = tr.vertex(v.name, v.path)
        self._threads = [
            threading.Thread(target=v._run, name=v.name, daemon=True)
            for v in self.vertices
        ]
        for t in self._threads:
            t.start()
        return self

    def wait(self, timeout: Optional[float] = None) -> List[Any]:
        for t in self._threads:
            t.join(timeout)
        while self.drain_samplers:
            self.drain_samplers.pop()()  # run once, even if wait() re-enters
        while self.finalizers:
            self.finalizers.pop()()  # run once, even if wait() is re-entered
        if self.failed:
            raise self.failed[0]
        return self.results

    def run_and_wait(self) -> List[Any]:
        return self.run().wait()

    def sample_high_water(self, into: Dict[str, int]) -> Dict[str, int]:
        """Profile tap: record each vertex's current outbound queue depth
        into ``into``, keeping the per-name maximum across calls.  Autotune
        polls this from the caller thread while a pilot run drains —
        ``len()`` on every ring class is a racy-but-benign read of the
        head/tail indices, so no locks and no effect on the stream.

        Keys are IR-path qualified (``name@path``) so two farms — or two
        stages sharing a user-visible name — cannot collide in one merged
        report."""
        for v in self.vertices:
            depth = 0
            for ring in v.outs:
                try:
                    depth = max(depth, len(ring))
                except TypeError:
                    pass
            key = _qualname(v.name, v.path)
            if depth > into.get(key, -1):
                into[key] = depth
        return into

    def sample_depths(self, into: Dict[str, int]) -> Dict[str, int]:
        """Live-monitor tap: the *instantaneous* outbound queue depth per
        vertex (overwrite semantics — each call is one timeline frame,
        unlike :meth:`sample_high_water`'s running max).  Same lock-free
        racy-but-benign ``len()`` reads, same ``name@path`` keys."""
        for v in self.vertices:
            depth = 0
            for ring in v.outs:
                try:
                    depth = max(depth, len(ring))
                except TypeError:
                    pass
            into[_qualname(v.name, v.path)] = depth
        return into


# ---------------------------------------------------------------------------
# threads lowering: IR tree -> vertices + rings
# ---------------------------------------------------------------------------
# Back-compat shims: the declarative layer now lives in skeleton.py; the old
# names keep working for existing callers (PR-1's Net API).
Net = Skeleton
_as_net = as_skeleton


def ring_list(in_ring: Optional[Any]) -> List[Any]:
    """Normalise a build edge: ``None`` (no upstream), one ring, or a list
    of rings (an all-to-all's right row emits one ring per vertex — the
    downstream vertex fan-in-merges them all, EOS counted per edge)."""
    if in_ring is None:
        return []
    return list(in_ring) if isinstance(in_ring, (list, tuple)) else [in_ring]


def build(skel: Skeleton, g: Graph, in_ring: Optional[Any],
          terminal: bool, path: str = "") -> Optional[Any]:
    """Wire a skeleton IR node into ``g`` between an optional inbound ring
    (or ring *list* — see :func:`ring_list`) and (unless terminal) a
    freshly created outbound ring — the threads backend of
    :func:`repro.core.skeleton.lower`.

    ``path`` is the node's position in the IR tree (``"1"``, ``"1.2"`` …);
    vertices remember it so telemetry keys (``sample_high_water``, trace
    lanes) are namespaced per IR path and two same-named nodes never
    collide.

    This is what makes skeletons close under composition: a ``Farm`` is a
    vertex of the enclosing ``Pipeline``, and vice versa."""
    if isinstance(skel, AllToAll):
        from .a2a import build_thread_a2a  # lazy: a2a imports this module
        return build_thread_a2a(skel, g, ring_list(in_ring), terminal,
                                path=path)

    if isinstance(skel, Source):
        assert in_ring is None, "Source cannot have an upstream edge"
        return build(Stage(skel.node, name=skel.name,
                           capacity=skel.capacity), g, None, terminal, path)

    if isinstance(skel, Pipeline):
        ring = in_ring
        last = len(skel.stages) - 1
        for i, s in enumerate(skel.stages):
            p = f"{path}.{i}" if path else str(i)
            if i == last:
                return build(s, g, ring, terminal, p)
            ring = build(s, g, ring, False, p)

    if isinstance(skel, Feedback):
        # predicate loop -> tagger + wrap-around farm + reorder (Sec. 5)
        return build(skel.as_thread_net(), g, in_ring, terminal, path)

    if isinstance(skel, Farm):
        qc = skel.queue_class or g.queue_class
        cap = skel.capacity or g.capacity
        ts = TagSpace(skel.stats)
        loop_ring = (qc(skel.feedback_capacity)
                     if skel.feedback is not None else None)

        disp = g.add(DispatchVertex(
            ts, skel.emitter,
            scheduling=skel.scheduling, speculative=skel.speculative,
            straggler_factor=skel.straggler_factor,
            min_straggler_age=skel.min_straggler_age,
            loop_ring=loop_ring,
        ))
        disp.path = path
        if in_ring is not None:
            disp.ins.extend(ring_list(in_ring))
        else:
            assert skel.emitter is not None, \
                "a standalone farm needs an emitter (or compose it after a Source)"

        merge = g.add(MergeVertex(
            ts, skel.collector, ordered=skel.ordered,
            loop_ring=loop_ring, feedback=skel.feedback,
        ))
        merge.path = path
        for i, node in enumerate(skel.worker_nodes):
            # the policy may want a steal side-channel (worker -> arbiter)
            idle = disp.sched.worker_channel(i, qc)
            w = g.add(WorkerVertex(node, i, ts.stats,
                                   survivable=skel.speculative,
                                   idle_ring=idle,
                                   record_service=(
                                       disp.sched.needs_service_stats
                                       # a live monitor consumes the EWMAs
                                       or getattr(g, "live_telemetry", False)),
                                   name=f"ff-worker-{i}"))
            w.path = path
            g.connect(disp, w, capacity=cap, queue_class=qc)
            g.connect(w, merge, capacity=cap, queue_class=qc)
        if terminal:
            return None
        ring = g.channel(skel.capacity)
        merge.outs.append(ring)
        return ring

    if isinstance(skel, Stage):
        v = g.add(StageVertex(skel.node, name=skel.name))
        v.path = path
        v.ins.extend(ring_list(in_ring))
        if terminal:
            return None
        # per-edge capacity: a tuned Stage sizes its own outbound ring
        ring = g.channel(getattr(skel, "capacity", None))
        v.outs.append(ring)
        return ring

    raise TypeError(f"cannot lower {skel!r} to the thread graph")


class Accelerator:
    """Self-offloading accelerator (TR-10-03): run a network alongside the
    caller, who streams tasks into it and harvests results later.

    The caller thread is the single producer of the inbound ring (SPSC
    discipline holds: ``offload`` must be called from one thread), so the
    main thread of an application can offload kernels to a farm and keep
    computing — the paper's "accelerator" usage of FastFlow.

        acc = Accelerator(Farm(FnNode(f), 4))
        for x in tasks: acc.offload(x)
        results = acc.wait()
    """

    def __init__(self, net: Any, *, queue_class: Type = SPSCQueue,
                 capacity: int = 512):
        self._g = Graph(queue_class=queue_class, capacity=capacity)
        self._in = self._g.channel()
        build(as_skeleton(net), self._g, self._in, True)
        self._g.run()
        self._closed = False

    @property
    def results(self) -> List[Any]:
        return self._g.results

    def offload(self, task: Any) -> None:
        assert not self._closed, "accelerator already EOS'd"
        self._in.push_wait(task)

    def eos(self) -> None:
        if not self._closed:
            self._closed = True
            self._in.push_wait(EOS)

    def wait(self, timeout: Optional[float] = None) -> List[Any]:
        self.eos()
        return self._g.wait(timeout)
