"""Unified runtime observability — vertex tracing, metrics, run reports.

FastFlow's whole argument (TR-09-12) lives at the microsecond scale: a
farm hand-off costs a few hundred nanoseconds, so any instrumentation
that costs more than a few of those when idle destroys the property
being measured.  This module is the one observability substrate every
lowering shares, built around that constraint:

:class:`Tracer` / :class:`VertexTracer`
    Typed span/instant events (``svc`` begin/end, ``stall`` push-waits,
    ``steal``, ``spill``, ``eos``, ``loop`` tokens) recorded into
    bounded per-vertex buffers.  Spans are sampled 1-in-N with the same
    mask trick the ordered-farm latency sampling uses (``n & mask``), so
    the hot path pays ~two clock reads on a sampled-in event, one
    counter increment otherwise — and **nothing at all** when tracing is
    off, because vertices then carry ``tracer = None`` and never enter
    this module (pinned by the tracer-off allocation test).  Every
    buffer has one writer — its vertex — so the single-writer discipline
    of the runtime survives; procs vertices ship their buffers back over
    the existing control-ring machinery at EOS, and the clock is
    ``time.monotonic()`` (CLOCK_MONOTONIC — system-wide on Linux), so
    lanes from different processes share one timeline.

:class:`Trace`
    The merged snapshot: one lane per vertex (qualified by IR path, so
    two same-named stages cannot collide), exported via
    :meth:`Trace.to_chrome_json` in Chrome trace-event format — any run
    opens in Perfetto / ``chrome://tracing`` with one named lane per
    vertex/process.

:class:`MetricsRegistry` / :class:`RunReport`
    Counters, gauges and reservoir histograms (p50/p95/p99) absorbing
    the telemetry the runtime already produces in disconnected places —
    ``FarmStats``, ``MemoryBudget`` spill/stall counters,
    ``pool_stats()``, ``sample_high_water`` queue depths — into a single
    :class:`RunReport` snapshot attached to every program run.
    ``watch()`` callbacks fire on every finalized report, and
    :meth:`RunReport.to_profile` rebuilds an autotune ``Profile`` from a
    report so ``Profile.diff`` (the ROADMAP's online re-tuning seam) can
    compare live runs against a saved pilot.

Everything here is stdlib-only: no jax, no numpy — the module is safe
in the eager ``repro.core`` import set and the ~0.1s spawn-import
budget (pinned in ``tests/test_lazy_import.py``).
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = [
    "Tracer", "VertexTracer", "Trace", "MetricsRegistry", "Counter",
    "Gauge", "Histogram", "RunReport", "qualname", "farm_stats_snapshot",
]

#: event-kind vocabulary (the typed part of "typed events"); spans and
#: instants share one namespace so a lane reads as one story
SPAN_KINDS = ("svc", "stall", "compile", "call", "life")
INSTANT_KINDS = ("steal", "spill", "eos", "loop", "devices",
                 "alert", "drift")

_monotonic = time.monotonic


def qualname(name: str, path: str = "") -> str:
    """The collision-free key for one vertex: ``name@path`` where
    ``path`` is the vertex's IR path (empty for direct graph users, who
    get the bare name back).  Two farms — or two stages sharing a
    user-visible name — land at different IR paths, so their stats and
    lanes cannot merge."""
    return f"{name}@{path}" if path else name


def _pow2(n: int) -> int:
    n = max(1, int(n))
    return 1 << (n - 1).bit_length()


class VertexTracer:
    """One vertex's private event buffer — single writer, bounded, cheap.

    ``begin()``/``end()`` bracket a span with 1-in-``sample`` sampling:
    the off-sample path is one counter increment and a constant ``0.0``
    return (``end`` then no-ops), the on-sample path is two
    ``monotonic()`` reads and one tuple append.  ``instant()`` records
    rare events (steal/spill/eos) unsampled; ``tick()`` is the sampled
    instant for high-frequency ones (loop tokens).  The buffer is a
    plain list capped at ``capacity`` — overflow increments ``dropped``
    instead of growing, so a runaway vertex cannot eat the heap.

    Events are plain tuples ``(kind, t0, t1)`` (``t1 is None`` for an
    instant, optionally ``(kind, t0, t1, args)``), picklable as-is for
    the procs EOS ship-back.
    """

    __slots__ = ("name", "path", "pid", "capacity", "events", "dropped",
                 "_n", "_mask")

    def __init__(self, name: str, path: str = "", *, sample: int = 16,
                 capacity: int = 2048, pid: Optional[int] = None):
        self.name = name
        self.path = path
        self.pid = os.getpid() if pid is None else pid
        self.capacity = int(capacity)
        self.events: List[tuple] = []
        self.dropped = 0
        self._n = 0
        self._mask = _pow2(sample) - 1

    @property
    def qualname(self) -> str:
        return qualname(self.name, self.path)

    # -- the hot path --------------------------------------------------------
    def begin(self) -> float:
        """Start a sampled span; returns the start stamp, or ``0.0`` when
        this occurrence is sampled out (``end`` then no-ops)."""
        n = self._n
        self._n = n + 1
        if n & self._mask:
            return 0.0
        return _monotonic()

    def end(self, t0: float, kind: str) -> None:
        """Close the span opened by the matching :meth:`begin`."""
        if not t0:
            return
        if len(self.events) < self.capacity:
            self.events.append((kind, t0, _monotonic()))
        else:
            self.dropped += 1

    def tick(self, kind: str) -> None:
        """Sampled instant — for per-item-frequency events (loop tokens);
        shares the span counter, so one 1-in-N stream covers both."""
        n = self._n
        self._n = n + 1
        if n & self._mask:
            return
        if len(self.events) < self.capacity:
            self.events.append((kind, _monotonic(), None))
        else:
            self.dropped += 1

    # -- the rare path -------------------------------------------------------
    def instant(self, kind: str, args: Optional[dict] = None) -> None:
        """Unsampled instant — for rare events (steal, spill, EOS)."""
        if len(self.events) < self.capacity:
            if args is None:
                self.events.append((kind, _monotonic(), None))
            else:
                self.events.append((kind, _monotonic(), None, args))
        else:
            self.dropped += 1

    def span(self, kind: str, t0: float, t1: float,
             args: Optional[dict] = None) -> None:
        """Unsampled span with caller-supplied stamps — program-level
        events (mesh compile/call walls) and already-timed stalls."""
        if len(self.events) < self.capacity:
            if args is None:
                self.events.append((kind, t0, t1))
            else:
                self.events.append((kind, t0, t1, args))
        else:
            self.dropped += 1


class Tracer:
    """The per-run collector: hands each vertex its private
    :class:`VertexTracer` lane, absorbs procs lanes shipped back at EOS,
    and snapshots everything into a :class:`Trace`.

    ``sample`` is rounded up to a power of two (the mask trick needs
    it); ``capacity`` bounds every lane independently.  Construction and
    lane registration happen at lowering/start time, never on the data
    path."""

    def __init__(self, *, sample: int = 16, capacity: int = 2048):
        self.sample = _pow2(sample)
        self.capacity = int(capacity)
        self._lanes: List[VertexTracer] = []

    def vertex(self, name: str, path: str = "") -> VertexTracer:
        vt = VertexTracer(name, path, sample=self.sample,
                          capacity=self.capacity)
        self._lanes.append(vt)
        return vt

    def absorb(self, name: str, path: str, pid: int, events: List[tuple],
               dropped: int = 0) -> None:
        """Adopt a lane recorded in another process (the procs EOS
        ship-back): the child's buffer becomes a lane here verbatim —
        monotonic stamps are system-wide, so no clock translation."""
        vt = VertexTracer(name, path, sample=self.sample,
                          capacity=self.capacity, pid=pid)
        vt.events = list(events)
        vt.dropped = int(dropped)
        self._lanes.append(vt)

    def trace(self) -> "Trace":
        return Trace(list(self._lanes))


class Trace:
    """An immutable snapshot of every lane a run recorded."""

    def __init__(self, lanes: List[VertexTracer]):
        self.lanes = lanes

    def lane(self, qual: str) -> Optional[VertexTracer]:
        for vt in self.lanes:
            if vt.qualname == qual:
                return vt
        return None

    def qualnames(self) -> List[str]:
        return sorted(vt.qualname for vt in self.lanes)

    def events(self, kind: Optional[str] = None) -> List[tuple]:
        out = []
        for vt in self.lanes:
            for e in vt.events:
                if kind is None or e[0] == kind:
                    out.append(e)
        return out

    def to_chrome_json(self, path: Optional[str] = None, *,
                       timeline: Any = None) -> dict:
        """Export in Chrome trace-event format (the JSON-object form:
        ``{"traceEvents": [...]}``), one named lane per vertex —
        ``pid`` is the recording process, ``tid`` a per-lane id with a
        ``thread_name`` metadata event carrying the vertex qualname, so
        Perfetto / ``chrome://tracing`` renders the run as labelled
        swim-lanes.  Spans are ``"X"`` complete events, instants ``"i"``
        (thread scope); timestamps are microseconds on the shared
        monotonic clock.  ``timeline=`` (a
        :class:`~repro.core.monitor.Timeline`) merges the live monitor's
        frames in as ``"C"`` counter tracks — queue depths and service
        EWMAs render as value graphs above the span lanes, on the same
        clock.  Returns the document; also writes it to ``path`` when
        given."""
        evs: List[dict] = []
        for tid, vt in enumerate(self.lanes, start=1):
            evs.append({"name": "thread_name", "ph": "M", "pid": vt.pid,
                        "tid": tid, "args": {"name": vt.qualname}})
            for e in vt.events:
                kind, t0, t1 = e[0], e[1], e[2]
                d: Dict[str, Any] = {"name": kind, "pid": vt.pid,
                                     "tid": tid, "ts": t0 * 1e6}
                if t1 is None:
                    d["ph"] = "i"
                    d["s"] = "t"
                else:
                    d["ph"] = "X"
                    d["dur"] = max(0.0, (t1 - t0) * 1e6)
                if len(e) > 3:
                    d["args"] = e[3]
                evs.append(d)
            if vt.dropped:
                evs.append({"name": "dropped", "ph": "i", "s": "t",
                            "pid": vt.pid, "tid": tid,
                            "ts": (vt.events[-1][1] if vt.events else 0.0)
                            * 1e6,
                            "args": {"count": vt.dropped}})
        if timeline is not None:
            evs.extend(timeline.chrome_events())
        doc = {"traceEvents": evs, "displayTimeUnit": "ms"}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------
class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Bounded-reservoir histogram with a proper percentile surface —
    the same keep-the-last-``cap`` discipline as ``LatencyReservoir``
    (lifetime ``count``/``total`` stay exact; percentiles come from the
    most recent ``cap`` observations, which is the regime a stream
    cares about)."""

    __slots__ = ("name", "cap", "count", "total", "vmax", "_buf")

    def __init__(self, name: str, cap: int = 2048):
        self.name = name
        self.cap = int(cap)
        self.count = 0
        self.total = 0.0
        self.vmax = 0.0
        self._buf: List[float] = []

    def observe(self, v: float) -> None:
        v = float(v)
        if len(self._buf) < self.cap:
            self._buf.append(v)
        else:
            self._buf[self.count % self.cap] = v
        self.count += 1
        self.total += v
        if v > self.vmax:
            self.vmax = v

    def percentile(self, p: float) -> float:
        if not self._buf:
            return 0.0
        s = sorted(self._buf)
        i = min(len(s) - 1, max(0, int(p / 100.0 * len(s))))
        return s[i]

    @property
    def p50(self) -> float:
        return self.percentile(50)

    @property
    def p95(self) -> float:
        return self.percentile(95)

    @property
    def p99(self) -> float:
        return self.percentile(99)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def merge(self, other: "Histogram") -> None:
        self.count += other.count
        self.total += other.total
        self.vmax = max(self.vmax, other.vmax)
        room = self.cap - len(self._buf)
        if room > 0:
            self._buf.extend(other._buf[:room])

    def snapshot(self) -> dict:
        # the reservoir samples ride along so cross-run RunReport.merge
        # can recompute percentiles over BOTH runs' observations instead
        # of averaging two percentile scalars (which is meaningless)
        return {"count": self.count, "mean": self.mean, "max": self.vmax,
                "p50": self.p50, "p95": self.p95, "p99": self.p99,
                "cap": self.cap, "samples": list(self._buf)}


def _percentile_sorted(s: List[float], p: float) -> float:
    if not s:
        return 0.0
    return s[min(len(s) - 1, max(0, int(p / 100.0 * len(s))))]


def _merge_hist_snapshots(a: dict, b: dict) -> dict:
    """Commutative merge of two histogram snapshots.  When both carry
    reservoir samples, concatenate them (sorted, evenly subsampled back
    to the window cap when over it) and recompute the percentiles over
    the union — cross-run p95/p99 then cover both runs' observations.
    Sorting before the deterministic even-spaced subsample makes the
    result order-independent, so ``a.merge(b) == b.merge(a)`` (pinned by
    the commutativity test).  Snapshots from before samples shipped fall
    back to the old count-weighted average."""
    n1, n2 = a.get("count", 0), b.get("count", 0)
    n = n1 + n2
    merged = {"count": n, "max": max(a.get("max", 0.0), b.get("max", 0.0))}
    s1, s2 = a.get("samples"), b.get("samples")
    if s1 is not None and s2 is not None:
        cap = int(a.get("cap") or b.get("cap") or 2048)
        samples = sorted(list(s1) + list(s2))
        if len(samples) > cap:
            samples = [samples[i * len(samples) // cap] for i in range(cap)]
        merged["cap"] = cap
        merged["samples"] = samples
        merged["mean"] = (a.get("mean", 0.0) * n1 +
                          b.get("mean", 0.0) * n2) / n if n else 0.0
        for p, key in ((50, "p50"), (95, "p95"), (99, "p99")):
            merged[key] = _percentile_sorted(samples, p)
    else:
        for key in ("mean", "p50", "p95", "p99"):
            x, y = a.get(key, 0.0), b.get(key, 0.0)
            merged[key] = (x * n1 + y * n2) / n if n else 0.0
    return merged


class MetricsRegistry:
    """Named counters/gauges/histograms plus the ``watch()`` hook.

    One registry per program (or shared across programs — names are the
    namespace).  ``report()`` snapshots everything into a
    :class:`RunReport`; ``finalize(report)`` fires every watcher with it
    — the seam the online re-tuner and the elastic-farm controller hang
    off (they read ``report.to_profile().diff(saved)`` / queue depths
    and decide, without the runtime knowing they exist)."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}
        self._watchers: List[Callable[["RunReport"], None]] = []

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str, cap: int = 2048) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram(name, cap)
        return h

    def watch(self, fn: Callable[["RunReport"], None]) -> None:
        self._watchers.append(fn)

    def report(self, *, farms: Optional[Dict[str, dict]] = None,
               queues: Optional[Dict[str, int]] = None,
               pool: Optional[dict] = None,
               meta: Optional[dict] = None) -> "RunReport":
        return RunReport(
            counters={k: c.value for k, c in self._counters.items()},
            gauges={k: g.value for k, g in self._gauges.items()},
            hists={k: h.snapshot() for k, h in self._hists.items()},
            farms=dict(farms or {}), queues=dict(queues or {}),
            pool=dict(pool or {}), meta=dict(meta or {}))

    def finalize(self, report: "RunReport") -> "RunReport":
        for fn in self._watchers:
            fn(report)
        return report


def farm_stats_snapshot(stats: Any) -> dict:
    """One ``FarmStats`` as a plain dict (the RunReport wire form):
    every counter the board carries plus the latency percentiles."""
    lat = getattr(stats, "latencies", None)
    d = {
        "tasks_emitted": stats.tasks_emitted,
        "tasks_collected": stats.tasks_collected,
        "duplicates_issued": stats.duplicates_issued,
        "duplicates_dropped": stats.duplicates_dropped,
        "steals": stats.steals,
        "spills": stats.spills,
        "spill_bytes": stats.spill_bytes,
        "backpressure_stalls": stats.backpressure_stalls,
        "service_ewma": dict(stats.service_ewma),
        "worker_failures": len(stats.worker_failures),
    }
    if lat is not None and len(lat):
        vals = sorted(lat)

        def pct(p: float) -> float:
            return vals[min(len(vals) - 1, max(0, int(p / 100 * len(vals))))]

        d["latency"] = {"count": lat.count, "p50": pct(50), "p95": pct(95),
                        "p99": pct(99)}
    return d


class RunReport:
    """The single snapshot attached to every program run: registry
    metrics + absorbed ``FarmStats`` (keyed by IR-path qualname, so two
    farms never collide), queue high-water marks, spawn-pool stats, and
    free-form meta (vertex/edge topology, wall time, item count).

    ``merge`` folds another report in (counters add, gauges last-write,
    queue high-waters max) — the procs collector uses it to merge the
    per-run child telemetry, and callers can fold many runs into one
    trend point.  ``to_profile`` rebuilds an autotune ``Profile`` so
    ``Profile.diff`` compares a live run against a saved pilot — the
    online re-tuning seam."""

    schema = "run-report/1"

    def __init__(self, counters: Optional[Dict[str, int]] = None,
                 gauges: Optional[Dict[str, float]] = None,
                 hists: Optional[Dict[str, dict]] = None,
                 farms: Optional[Dict[str, dict]] = None,
                 queues: Optional[Dict[str, int]] = None,
                 pool: Optional[dict] = None,
                 meta: Optional[dict] = None):
        self.counters = dict(counters or {})
        self.gauges = dict(gauges or {})
        self.hists = dict(hists or {})
        self.farms = dict(farms or {})
        self.queues = dict(queues or {})
        self.pool = dict(pool or {})
        self.meta = dict(meta or {})

    def merge(self, other: "RunReport") -> "RunReport":
        for k, v in other.counters.items():
            self.counters[k] = self.counters.get(k, 0) + v
        self.gauges.update(other.gauges)
        for k, h in other.hists.items():
            mine = self.hists.get(k)
            if mine is None:
                self.hists[k] = dict(h)
            else:
                self.hists[k] = _merge_hist_snapshots(mine, h)
        self.farms.update(other.farms)
        for k, v in other.queues.items():
            if v > self.queues.get(k, -1):
                self.queues[k] = v
        self.pool.update(other.pool)
        self.meta.update(other.meta)
        return self

    def to_json(self) -> dict:
        return {"schema": self.schema, "counters": self.counters,
                "gauges": self.gauges, "hists": self.hists,
                "farms": self.farms, "queues": self.queues,
                "pool": self.pool, "meta": self.meta}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)

    def to_profile(self, handoff_us: Optional[float] = None) -> Any:
        """Rebuild an autotune ``Profile`` from this report, so
        ``report.to_profile().diff(saved_profile)`` answers "has the
        live run drifted from the pilot?" — the hook online re-tuning
        hangs off.  Farm rows become farm-kind stage profiles (service
        from the worker EWMA mean, items from ``tasks_collected``,
        queue high-water from the matching dispatch lane)."""
        from .autotune import Profile, StageProfile

        stages = []
        items = 0
        for qual, fs in sorted(self.farms.items()):
            name, _, path = qual.partition("@")
            ewma = fs.get("service_ewma") or {}
            svc = (sum(ewma.values()) / len(ewma) * 1e6) if ewma else 0.0
            n = int(fs.get("tasks_collected", 0))
            items = max(items, n)
            hw = 0
            for q, v in self.queues.items():
                if q.endswith(f"@{path}") or (not path and "@" not in q):
                    hw = max(hw, v)
            stages.append(StageProfile(
                path=path, kind="farm", name=name, service_us=svc,
                service_ewma_us=svc, items=n, width=len(ewma) or 1,
                queue_high_water=hw))
        h = handoff_us if handoff_us is not None \
            else float(self.gauges.get("handoff_us", 1.0))
        return Profile(handoff_us=h, pilot_items=items, stages=stages)

    def __repr__(self) -> str:
        return (f"RunReport(counters={len(self.counters)}, "
                f"hists={sorted(self.hists)}, farms={sorted(self.farms)}, "
                f"queues={len(self.queues)})")
