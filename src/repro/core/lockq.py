"""Lock-based MPMC queue — the *baseline* the paper argues against.

The paper's experimental claim is that traditional mutex/condition-variable
queues (what OpenMP critical sections, TBB ``concurrent_queue`` in its
blocking mode, and naive pthread code boil down to) impose a per-item
synchronisation cost that dominates fine-grained streaming.  To reproduce
that comparison we need the baseline too, with the *same* API surface as
``SPSCQueue`` so the farm can be instantiated over either.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Optional

from .spsc import SPSCQueue

__all__ = ["LockQueue"]


class LockQueue:
    """Mutex-protected bounded MPMC FIFO (the "fence-full" baseline)."""

    def __init__(self, capacity: int = 512):
        self._buf: deque = deque()
        self._capacity = capacity
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)
        self.pushes = 0
        self.pops = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)

    @property
    def capacity(self) -> int:
        return self._capacity

    def empty(self) -> bool:
        with self._lock:
            return not self._buf

    def full(self) -> bool:
        with self._lock:
            return len(self._buf) >= self._capacity

    def push(self, item: Any) -> bool:
        with self._lock:
            if len(self._buf) >= self._capacity:
                return False
            self._buf.append(item)
            self.pushes += 1
            self._not_empty.notify()
            return True

    def push_wait(self, item: Any, timeout: Optional[float] = None) -> bool:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            while len(self._buf) >= self._capacity:
                remaining = None if deadline is None else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    return False
                self._not_full.wait(remaining)
            self._buf.append(item)
            self.pushes += 1
            self._not_empty.notify()
            return True

    def pop(self) -> Any:
        with self._lock:
            if not self._buf:
                return SPSCQueue._EMPTY
            item = self._buf.popleft()
            self.pops += 1
            self._not_full.notify()
            return item

    def pop_wait(self, timeout: Optional[float] = None) -> Any:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            while not self._buf:
                remaining = None if deadline is None else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    return SPSCQueue._EMPTY
                self._not_empty.wait(remaining)
            item = self._buf.popleft()
            self.pops += 1
            self._not_full.notify()
            return item
