"""Backend-neutral skeleton IR — one vocabulary, two runtimes.

FastFlow's central claim (paper Sec. 2, tutorial TR-12-04) is that one small
skeleton vocabulary — pipeline, farm, feedback — covers every streaming
application while the machinery underneath stays swappable.  This module is
that vocabulary as *pure data*: declarative :class:`Stage`, :class:`Source`,
:class:`Pipeline`, :class:`Farm` and :class:`Feedback` nodes, composable
with ``compose``/``>>`` (the paper's ∘), carrying ``ordered=``,
``nworkers=`` and ``grain=`` attributes, and *no* execution state.

Execution is a separate step, :func:`lower`:

``lower(skel, backend="threads")``
    produces a :class:`ThreadProgram` over today's thread/SPSC-ring graph
    runtime — PR 1's ``Net._build`` machinery, now driven by the IR (see
    :func:`repro.core.graph.build`).  Ordered-stream semantics come from the
    tagged-token collector.

``lower(skel, backend="mesh")``
    produces a :class:`MeshProgram`: **one** ``shard_map`` program over a
    2-D ``(skel_stage, skel_worker)`` mesh that nests
    ``dpipeline.pipeline_apply`` (stage axis) over ``dfarm.farm_map``
    (worker axis), so ``Pipeline(Farm(f), Farm(g))`` compiles whole — no
    host SPSC hop between f and g.  Ordering is structural: the farm's
    ``(dest, pos)`` tags and the pipeline's microbatch realignment preserve
    item order by construction.

Both lowerings of the same skeleton produce identical ordered outputs
(``tests/test_skeleton.py`` proves it property-style); the thread backend
additionally supports host-only features (``GO_ON`` filtering, emitter /
collector nodes, speculative re-issue, arbitrary ``feedback=`` routing),
which the mesh lowering rejects with a :class:`LoweringError` rather than
silently approximating.

The programming-model primitives (``ff_node``, ``FnNode``, ``GO_ON``) live
here too: they are the *node* vocabulary both backends share (the mesh
backend unwraps ``FnNode`` to its callable and requires it to be
jax-traceable and batch-polymorphic — it is applied to ``(rows, d)``
arrays, which for elementwise arithmetic is identical to the scalar form).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple, Type

from .obs import (MetricsRegistry, Tracer, farm_stats_snapshot,
                  qualname as _obs_qualname)

__all__ = [
    "GO_ON", "EmitMany", "KeyBatch", "ff_node", "FnNode", "FusedNode",
    "FarmStats", "LatencyReservoir",
    "Skeleton", "Stage", "Source", "Pipeline", "Farm", "Feedback",
    "AllToAll",
    "compose", "as_skeleton", "fuse", "walk_stats",
    "LoweringError", "lower", "BACKENDS", "ThreadProgram", "MeshProgram",
]

STAGE_AXIS = "skel_stage"
WORKER_AXIS = "skel_worker"


# ---------------------------------------------------------------------------
# programming model (paper Fig. 2) — shared by every backend
# ---------------------------------------------------------------------------
class ff_node:
    """Base class for network entities (paper Fig. 2)."""

    def svc_init(self) -> None:  # noqa: D401
        """Called once in the entity's own thread before the stream starts."""

    def svc(self, task: Any) -> Any:
        """Process one task.  Sources receive ``None`` and return the next
        task (``None`` = end-of-stream); other nodes receive a task and
        return a result (``GO_ON`` = nothing to emit, keep streaming)."""
        raise NotImplementedError

    def svc_end(self) -> None:
        """Called once after EOS has been processed."""

    def svc_eos(self) -> Any:
        """EOS flush (FastFlow's ``eosnotify``): called once when every
        inbound edge has delivered EOS, *before* this vertex's own EOS
        propagates downstream.  Return a payload (or :class:`EmitMany`)
        to flush buffered state into the stream — the keyed folds in
        :mod:`repro.core.stream_ops` emit their per-key accumulators
        here — or ``None``/``GO_ON`` for nothing (the default)."""
        return None


class FnNode(ff_node):
    """Wrap a plain callable as an ``ff_node``."""

    def __init__(self, fn: Callable[[Any], Any]):
        self._fn = fn

    def svc(self, task: Any) -> Any:
        return self._fn(task)


class _SeqNode(ff_node):
    """Source node replaying a finite iterable (then EOS)."""

    def __init__(self, items: Iterable[Any]):
        self._it = iter(items)

    def svc(self, _):
        try:
            return next(self._it)
        except StopIteration:
            return None


class _GoOn:
    _instance: Optional["_GoOn"] = None

    def __new__(cls) -> "_GoOn":
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __reduce__(self):
        # identity survives pickling (same contract as _EOS: a worker
        # process returning GO_ON must satisfy `payload is GO_ON` in the
        # merge arbiter's process)
        return (_GoOn, ())

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return "<GO_ON>"


GO_ON = _GoOn()


class EmitMany(list):
    """Return type for a *Stage* node's ``svc`` when one input produces
    several outputs: ``return EmitMany([a, b])`` emits ``a`` then ``b``
    downstream (an empty ``EmitMany`` emits nothing, like ``GO_ON``).
    Plain lists stay ordinary payloads — multi-emit is opt-in by type.
    Only ``StageVertex`` flattens it (the reorder stage's flush is the
    canonical use); farm workers and collectors pass it through as an
    ordinary payload, because their tokens are 1:1 by tag."""


class KeyBatch(EmitMany):
    """A multi-emit that rides the stream as **one message**: the producing
    vertex pushes the whole batch onto a single ring (one pickle, one slot)
    and the *consuming* vertex unpacks it — ``svc`` still sees items, so
    nodes stay batch-oblivious unless they opt in (``accepts_batches =
    True``, e.g. :class:`~repro.core.oocore.SpillFold`).  The a2a left
    vertices instead *split* a batch by routing key into one sub-batch per
    destination ring, which is what lets a keyed shuffle amortize its
    per-hand-off cost over thousands of pairs (the map-side combiner's
    eviction chunks).  As an :class:`EmitMany` subclass it degrades to a
    plain per-item flatten everywhere no batch-aware path exists."""


class _FarmEmitMany(EmitMany):
    """Marker: a farm-absorbed tail chain multi-emitted.  The merge
    arbiter flattens this downstream (one ``_deliver`` per element) — the
    behaviour the unfused trailing ``StageVertex`` would have had —
    whereas an ordinary ``EmitMany`` worker payload still crosses the
    collector whole (tokens are 1:1 by tag)."""


class FusedNode(ff_node):
    """Several nodes executed back-to-back inside ONE vertex — the result
    of the :func:`fuse` pass collapsing a sub-threshold-grain hand-off.

    Chain semantics mirror what the separate vertices would have done.

    ``flatten=True`` (stage∘stage fusion): ``GO_ON`` anywhere filters the
    item; ``None`` from the FIRST node propagates as ``None`` (in source
    position that is EOS, mid-pipeline the vertex filters it — both
    exactly the unfused behaviour), while ``None`` from a later node
    becomes ``GO_ON`` (the downstream vertex would merely have skipped
    that one item, never ended the stream).  An intermediate
    :class:`EmitMany` fans each element through the rest of the chain,
    because ``StageVertex._emit`` would have flattened it onto the ring.

    ``flatten=False`` (farm-worker∘stage fusion): a worker's token is 1:1
    by tag and the merge arbiter retires ``GO_ON`` payloads silently but
    delivers anything else — including ``None`` and whole ``EmitMany``
    payloads — so the fused tail runs on every non-``GO_ON`` worker
    result; a tail result of ``None``/``GO_ON`` returns ``GO_ON`` (the
    token retires, nothing is emitted — what the downstream stage
    vertex's filtering would have produced), and a tail result that IS an
    ``EmitMany`` is wrapped in :class:`_FarmEmitMany` so the merge
    arbiter flattens it downstream — because unfused, the trailing
    ``StageVertex`` flattens whatever ``EmitMany`` its node returns.

    ``svc_init``/``svc_end`` run once per constituent, in stream order
    (``svc_end`` reversed, like unwinding the pipeline)."""

    def __init__(self, nodes: Iterable[Any], *, flatten: bool = True):
        self.nodes: List[ff_node] = [_as_node(n) for n in nodes]
        self.flatten = flatten

    def svc_init(self) -> None:
        for n in self.nodes:
            n.svc_init()

    def svc_end(self) -> None:
        for n in reversed(self.nodes):
            n.svc_end()

    def svc(self, task: Any) -> Any:
        if not self.flatten:
            return self._apply_farm(task)
        return self._apply(0, task)

    def svc_eos(self) -> Any:
        """Chain the EOS flush: each constituent's ``svc_eos`` output runs
        through the *rest* of the chain, exactly as its separate vertex's
        flush would have streamed through the downstream vertices.  Only
        meaningful for ``flatten=True`` (stage∘stage) fusions — farm
        workers are never flushed by the merge arbiter, so the
        ``flatten=False`` junction keeps the default no-op."""
        if not self.flatten:
            return None
        out = EmitMany()
        for i, n in enumerate(self.nodes):
            r = n.svc_eos()
            if r is None or r is GO_ON:
                continue
            for t in (r if isinstance(r, EmitMany) else [r]):
                rr = self._apply(i + 1, t)
                if rr is None or rr is GO_ON:
                    continue
                if isinstance(rr, EmitMany):
                    out.extend(rr)
                else:
                    out.append(rr)
        return out if out else None

    def _apply(self, i: int, task: Any) -> Any:
        nodes = self.nodes
        start = i
        while i < len(nodes):
            task = nodes[i].svc(task)
            i += 1
            if task is None:
                # only the head of the chain may signal EOS/None onward;
                # a later node's None filters one item, like its vertex
                return None if (start == 0 and i == 1) else GO_ON
            if task is GO_ON:
                return GO_ON
            if isinstance(task, EmitMany) and i < len(nodes):
                out = EmitMany()
                for t in task:
                    r = self._apply(i, t)
                    if r is None or r is GO_ON:
                        continue
                    if isinstance(r, EmitMany):
                        out.extend(r)
                    else:
                        out.append(r)
                return out
        return task

    def _apply_farm(self, task: Any) -> Any:
        nodes = self.nodes
        task = nodes[0].svc(task)          # the original worker
        for n in nodes[1:]:                # the absorbed stage chain
            if task is GO_ON:
                return GO_ON               # merge would have retired it
            task = n.svc(task)             # unfused stages see None too
        if task is None or task is GO_ON:
            return GO_ON
        return _FarmEmitMany(task) if isinstance(task, EmitMany) else task


class LatencyReservoir:
    """Bounded sliding-window latency sample (most recent ``cap`` values).

    The merge arbiter appends one latency per collected task; a plain list
    grew without bound, which leaked memory in long-running farms (the
    ``ServeEngine`` decode loop appends one per tick, forever).  A ring
    overwrite of the oldest entry keeps the sample bounded AND makes the
    p95 a *recent-window* statistic, which is the better straggler signal
    anyway — ancient latencies from a cold start should not set today's
    re-issue threshold.  ``count`` still tracks lifetime appends.

    Single-writer: only the merge arbiter appends; the dispatch arbiter's
    reads (p95) are benignly stale, same as every other cross-arbiter read
    in the runtime."""

    __slots__ = ("_cap", "_buf", "_next", "count")

    def __init__(self, cap: int = 2048):
        assert cap > 0
        self._cap = cap
        self._buf: List[float] = []
        self._next = 0
        self.count = 0

    def append(self, x: float) -> None:
        if len(self._buf) < self._cap:
            self._buf.append(x)
        else:
            self._buf[self._next] = x
            self._next = (self._next + 1) % self._cap
        self.count += 1

    def __len__(self) -> int:
        return len(self._buf)

    def __iter__(self):
        return iter(self._buf)

    def __bool__(self) -> bool:
        return bool(self._buf)


@dataclass
class FarmStats:
    """Thread-backend farm telemetry (dispatch/merge arbiters and the
    workers fill it in; every field has exactly one writer thread — or,
    for the per-worker dicts, one writer per key)."""

    tasks_emitted: int = 0
    tasks_collected: int = 0
    duplicates_issued: int = 0
    duplicates_dropped: int = 0
    steals: int = 0
    # out-of-core keyed aggregation (oocore.MemoryBudget folds these in
    # through the graph finalizer hook): spill runs written, bytes spilled
    # to disk, and scatter intake stalls from budget backpressure
    spills: int = 0
    spill_bytes: int = 0
    backpressure_stalls: int = 0
    per_worker: Dict[int, int] = field(default_factory=dict)
    # worker i's service-time EWMA, written only by worker i; the
    # CostModel scheduling policy reads it for adaptive placement
    service_ewma: Dict[int, float] = field(default_factory=dict)
    latencies: LatencyReservoir = field(default_factory=LatencyReservoir)
    worker_failures: List = field(default_factory=list)

    def p95_latency(self) -> float:
        xs = sorted(self.latencies)
        if not xs:
            return 0.0
        return xs[min(len(xs) - 1, int(0.95 * len(xs)))]


def _as_node(x: Any) -> ff_node:
    return x if isinstance(x, ff_node) else FnNode(x)


# ---------------------------------------------------------------------------
# the IR: declarative skeleton nodes (pure data)
# ---------------------------------------------------------------------------
class Skeleton:
    """A declarative description of a streaming network.

    Skeletons are pure data: they carry nodes and attributes, never threads
    or device buffers.  ``a >> b`` (or ``compose(a, b)``) chains skeletons
    into a :class:`Pipeline` — the paper's ∘.  Execution goes through
    :func:`lower`; the ``to_graph``/``run``/``run_and_wait`` methods below
    are thread-backend conveniences that preserve PR 1's ``Net`` API
    (``repro.core.graph.Net`` is now an alias of this class).
    """

    def __rshift__(self, other: Any) -> "Pipeline":
        return Pipeline(self, other)

    def __rrshift__(self, other: Any) -> "Pipeline":
        return Pipeline(other, self)

    # -- thread-backend conveniences (the PR-1 Net surface) -----------------
    def to_graph(self, stream: Optional[Iterable[Any]] = None, *,
                 queue_class: Optional[Type] = None, capacity: int = 512):
        return lower(self, "threads", queue_class=queue_class,
                     capacity=capacity).to_graph(stream)

    def run(self, stream: Optional[Iterable[Any]] = None, **kw):
        return self.to_graph(stream, **kw).run()

    def run_and_wait(self, stream: Optional[Iterable[Any]] = None,
                     **kw) -> List[Any]:
        return self.to_graph(stream, **kw).run_and_wait()


def as_skeleton(x: Any) -> Skeleton:
    """Coerce a skeleton / ``ff_node`` / plain callable into IR."""
    if isinstance(x, Skeleton):
        return x
    if isinstance(x, ff_node) or callable(x):
        return Stage(x)
    raise TypeError(f"cannot interpret {x!r} as a network stage")


class Stage(Skeleton):
    """A single sequential node (paper Fig. 2) as a one-vertex network.

    ``capacity`` sizes this stage's *outbound* SPSC ring on the host
    backends (``None`` = the graph-wide default) — the per-edge knob the
    autotune pass (:mod:`repro.core.autotune`) sets from the measured
    producer/consumer service-rate ratio."""

    def __init__(self, node: Any, *, name: str = "ff-stage",
                 grain: Optional[int] = None,
                 capacity: Optional[int] = None):
        self.node = _as_node(node)
        self.name = name
        self.grain = grain
        self.capacity = capacity


class Source(Skeleton):
    """A stream source: an ``ff_node`` (``svc(None)`` protocol) or any
    iterable, replayed then EOS.  ``grain`` carries the same per-stage
    hint as :class:`Stage` (the procs backend's ``batch="grain"`` reads
    it as the source's emit-batch size); ``capacity`` sizes the outbound
    ring like :class:`Stage`."""

    def __init__(self, items: Any, *, name: str = "ff-source",
                 grain: Optional[int] = None,
                 capacity: Optional[int] = None):
        self.node = items if isinstance(items, ff_node) else _SeqNode(items)
        self.name = name
        self.grain = grain
        self.capacity = capacity


class Pipeline(Skeleton):
    """Chain sub-networks over streaming edges (paper Sec. 3.1 pipeline).

    Nested pipelines are flattened, so ``Pipeline(a, Pipeline(b, c))`` and
    ``compose(a, b, c)`` are the same IR — handy for the mesh lowering,
    which plans over the flat stage list."""

    def __init__(self, *stages: Any):
        assert stages, "empty pipeline"
        flat: List[Skeleton] = []
        for s in stages:
            s = as_skeleton(s)
            flat.extend(s.stages if isinstance(s, Pipeline) else [s])
        self.stages = flat


def compose(*stages: Any) -> Pipeline:
    """``compose(a, b, c)`` == ``Pipeline(a, b, c)`` — functional spelling."""
    return Pipeline(*stages)


class Farm(Skeleton):
    """The farm skeleton (paper Sec. 3.1, Figs. 1-2), backend-neutral.

    Parameters
    ----------
    workers: one ``ff_node``/callable shared by all workers, or a list with
        one node per worker (thread backend only — the mesh backend needs a
        single jax-traceable function).
    nworkers: worker-pool width (defaults to ``len(workers)`` for a list).
        On the mesh backend actual parallelism is the worker-axis size.
    emitter / collector: optional ``ff_node``s (thread backend only).
    ordered: reorder results by tag — Fig. 1 (right) tagged-token collector.
        The mesh lowering is always order-preserving (its ``(dest, pos)``
        routing tags are the same construction).
    grain: items per microbatch hint — the mesh lowering uses it as the
        ``pipeline_apply`` microbatch size; the fusion policy (ROADMAP) will
        use it on the thread side.
    scheduling: thread-backend placement policy — a registry name
        (``"rr"`` | ``"ondemand"`` | ``"worksteal"`` | ``"costmodel"``) or
        a :class:`repro.core.sched.Scheduler` instance/subclass (cloned
        per build, so the IR stays pure data).  The mesh emitter policy is
        round-robin by global item index — see ``dfarm.roundrobin_dest``.
    speculative / straggler_factor / min_straggler_age: straggler re-issue
        (thread backend).
    feedback: wrap-around (collector → emitter) edge, paper Sec. 5, called
        per result as ``feedback(result) -> (emit, tasks)``.  This is the
        thread backend's fully general routing protocol; for a
        backend-neutral loop use :class:`Feedback`.
    """

    def __init__(
        self,
        workers: Any,
        nworkers: Optional[int] = None,
        *,
        emitter: Optional[ff_node] = None,
        collector: Optional[ff_node] = None,
        ordered: bool = False,
        grain: Optional[int] = None,
        scheduling: Any = "rr",
        speculative: bool = False,
        straggler_factor: float = 4.0,
        min_straggler_age: float = 0.05,
        feedback: Optional[Callable[[Any], Tuple[Any, Iterable[Any]]]] = None,
        feedback_capacity: int = 1 << 16,
        queue_class: Optional[Type] = None,
        capacity: Optional[int] = None,
        stats: Optional[FarmStats] = None,
    ):
        if isinstance(workers, (list, tuple)):
            nodes = [_as_node(w) for w in workers]
            nworkers = len(nodes) if nworkers is None else nworkers
        else:
            node = _as_node(workers)
            nworkers = 1 if nworkers is None else nworkers
            nodes = [node] * nworkers
        assert nworkers >= 1 and len(nodes) == nworkers
        from .sched import make_scheduler
        make_scheduler(scheduling)  # raises ValueError on an unknown policy
        assert not (ordered and feedback is not None), \
            "ordering across a wrap-around edge is undefined (tags are " \
            "re-assigned per loop trip) — use ordered=False with feedback"
        self.worker_nodes = nodes
        self.nworkers = nworkers
        self.emitter = emitter
        self.collector = collector
        self.ordered = ordered
        self.grain = grain
        self.scheduling = scheduling
        self.speculative = speculative
        self.straggler_factor = straggler_factor
        self.min_straggler_age = min_straggler_age
        self.feedback = feedback
        self.feedback_capacity = feedback_capacity
        self.queue_class = queue_class
        self.capacity = capacity
        self.stats = stats if stats is not None else FarmStats()


class AllToAll(Skeleton):
    """FastFlow's third core building block (tutorial TR-12-04): ``nleft``
    left workers, each able to route every emission to any of ``nright``
    right workers — the shape that unlocks keyed shuffles, partitioned
    reduction and data-parallel aggregation, none of which Pipeline/Farm
    can express.

    Host lowerings (threads AND procs) wire an **N×M matrix of SPSC
    edges**: each left vertex owns one private ring per right vertex, so
    the single-writer discipline holds with *no arbiter between the
    layers* — the configuration where the paper's per-hand-off overhead
    argument matters most.  Each right vertex counts EOS once per inbound
    edge (fan-in termination), then flushes its node's buffered state
    (:meth:`ff_node.svc_eos`) before its own EOS propagates.

    Parameters
    ----------
    left / right: one ``ff_node``/callable shared by the whole row, or a
        list with one node per vertex.  A single *stateful* node instance
        is shared by reference across the row on the threads backend
        (same convention as ``Farm``); pass a list of fresh instances —
        what :mod:`repro.core.stream_ops` does — for per-vertex state.
        With no upstream edge the left nodes run as sources (``svc(None)``
        until ``None``), the tutorial's generators-into-shuffle shape.
    by: key function for the left→right route: an emission ``x`` lands on
        right vertex ``stable_hash(by(x)) % nright`` (deterministic across
        processes — see :func:`repro.core.a2a.stable_hash`), so every left
        vertex agrees on each key's owner with zero coordination.
        ``None`` degrades to per-left-vertex round-robin (a plain
        repartition).
    ordered: preserve input stream order via the existing tagged-token
        machinery: a tagger assigns stream indices at the scatter, tags
        ride the matrix untouched, and a reorder stage downstream releases
        in index order.  Requires an upstream stream and 1:1 nodes
        (EOS-flushing right nodes cannot be tagged).
    scheduling: how the scatter distributes upstream items over the left
        row — any pick()-based policy (``"rr"``/``"ondemand"``/
        ``"costmodel"``/:class:`~repro.core.sched.KeyAffinity`).
    reduce: optional static keyed-reduction spec
        (:class:`repro.core.stream_ops.KeyedReduce`) that lets the mesh
        backend lower the shuffle to ONE ``shard_map`` program
        (dispatch-by-key exchange + segment reduction); host backends
        ignore it and run the ``right`` nodes.
    """

    def __init__(self, left: Any, right: Any, *, by: Optional[Callable] = None,
                 nleft: Optional[int] = None, nright: Optional[int] = None,
                 ordered: bool = False, scheduling: Any = "rr",
                 reduce: Any = None, grain: Optional[int] = None,
                 name: str = "ff-a2a", queue_class: Optional[Type] = None,
                 capacity: Optional[int] = None):
        def pool(spec: Any, n: Optional[int]) -> Tuple[List[ff_node], int]:
            if isinstance(spec, (list, tuple)):
                nodes = [_as_node(s) for s in spec]
                n = len(nodes) if n is None else n
                assert len(nodes) == n, "node list does not match row width"
                return nodes, n
            n = 1 if n is None else n
            return [_as_node(spec)] * n, n

        from .sched import Scheduler, make_scheduler
        s = make_scheduler(scheduling)  # raises ValueError on unknown policy
        if type(s).place is not Scheduler.place \
                and type(s).route is Scheduler.route:
            raise ValueError(
                f"AllToAll scatter routing supports only pick()/route()-"
                f"based policies (rr / ondemand / costmodel / keyaffinity),"
                f" not the token-holding {s.name!r}")
        self.left_nodes, self.nleft = pool(left, nleft)
        self.right_nodes, self.nright = pool(right, nright)
        assert self.nleft >= 1 and self.nright >= 1
        assert not (ordered and reduce is not None), \
            "a keyed reduction emits per-key folds at EOS — stream order " \
            "across it is undefined; use ordered=False"
        self.by = by
        self.ordered = ordered
        self.scheduling = scheduling
        self.reduce = reduce
        self.grain = grain
        self.name = name
        self.queue_class = queue_class
        self.capacity = capacity
        # telemetry surface (same convention as Farm.stats): budgeted
        # reductions fold spill/backpressure counters in after each run
        self.stats = FarmStats()


class _ReorderNode(ff_node):
    """Buffer ``(i, x)`` pairs and release ``x``s in index order."""

    def __init__(self):
        self._buf: Dict[int, Any] = {}
        self._next = 0

    def svc(self, t):
        idx, value = t
        self._buf[idx] = value
        out = EmitMany()
        while self._next in self._buf:
            out.append(self._buf.pop(self._next))
            self._next += 1
        return out if out else GO_ON

    def svc_eos(self):
        # residue flush: indices skipped upstream (e.g. a GO_ON filter
        # inside an ordered all-to-all) leave a gap that would otherwise
        # strand everything behind it — release in tag order at EOS
        out = EmitMany(self._buf.pop(k) for k in sorted(self._buf))
        return out if out else None


# Loop-plumbing nodes for Feedback.as_thread_net.  These are classes (not
# closures) so the lowered net is picklable: the procs backend ships each
# vertex to a spawned process, and every piece of state below lives in
# exactly one vertex (tagger counter in the tagger's process, trip caps in
# the merge arbiter's), so replication-by-pickle is semantically inert.
class _LoopTagger(ff_node):
    """Attach ``(stream_index, trip_count)`` to each item entering a loop."""

    def __init__(self):
        self._next = 0

    def svc(self, x):
        idx = self._next
        self._next += 1
        return idx, 0, x


class _LoopBody(ff_node):
    """Run the user's worker under the loop's (index, trips) envelope."""

    def __init__(self, node: ff_node):
        self._node = node

    def svc_init(self) -> None:
        self._node.svc_init()

    def svc_end(self) -> None:
        self._node.svc_end()

    def svc(self, task):
        idx, trips, x = task
        return idx, trips + 1, self._node.svc(x)


class _LoopRoute:
    """The wrap-around route: loop while the predicate holds and the trip
    cap allows, else emit ``(index, value)`` for the reorder stage."""

    def __init__(self, pred: Callable[[Any], Any], max_trips: Optional[int]):
        self._pred = pred
        self._cap = max_trips

    def __call__(self, result):
        idx, trips, value = result
        if bool(self._pred(value)) and \
                (self._cap is None or trips < self._cap):
            return None, [result]       # back around the loop
        return (idx, value), []         # leaves the loop


class Feedback(Skeleton):
    """Backend-neutral wrap-around loop: re-apply ``worker`` while
    ``loop_while(result)`` holds, emit the first result for which it is
    false (do-while: every item is serviced at least once).  Unlike the raw
    ``Farm(feedback=route)`` protocol, ``Feedback`` preserves input order
    on both backends.

    Thread lowering: a :class:`Farm` whose ``feedback=`` route sends
    still-looping results back over the wrap-around SPSC ring (paper
    Sec. 5), bracketed by an index tagger and a reorder stage; termination
    by loop quiescence.  Mesh lowering: a masked ``lax.while_loop`` between
    the farm's dispatch and ordered combine (``dfarm.farm_until``) — the
    wrap-around ring becomes the loop carry.

    ``loop_while`` must be jax-traceable for the mesh backend (on the
    thread backend any callable returning truthy works).  ``max_trips``
    bounds the trip count on BOTH backends (``None`` = loop until the
    predicate releases the item): a still-looping result is emitted as-is
    once it has been serviced ``max_trips`` times.
    """

    def __init__(self, worker: Any, loop_while: Callable[[Any], Any], *,
                 nworkers: int = 1, max_trips: Optional[int] = None,
                 scheduling: Any = "rr", grain: Optional[int] = None,
                 name: str = "ff-feedback"):
        from .sched import make_scheduler
        make_scheduler(scheduling)  # raises ValueError on an unknown policy
        self.node = _as_node(worker)
        self.loop_while = loop_while
        self.nworkers = nworkers
        self.max_trips = max_trips
        self.scheduling = scheduling
        self.grain = grain
        self.name = name

    def as_thread_net(self) -> "Pipeline":
        """The predicate loop as a wrap-around farm (threads AND procs
        backends — both host graph runtimes share this lowering).

        The wrap-around ring emits in *completion* order (loop tags are
        re-assigned per trip), but the :class:`Feedback` contract — like the
        mesh lowering, whose ``(dest, pos)`` tags survive the while_loop —
        is input order.  So items carry a stream index and a trip counter
        through the loop (the counter enforces ``max_trips``, mirroring the
        mesh ``while_loop`` bound) and a reorder stage restores order
        downstream.  The plumbing nodes are picklable classes
        (:class:`_LoopTagger` / :class:`_LoopBody` / :class:`_LoopRoute`),
        never closures, so the procs backend can ship them to spawned
        vertex processes."""
        return Pipeline(
            Stage(_LoopTagger(), name=f"{self.name}-tagger"),
            Farm(_LoopBody(self.node), self.nworkers,
                 feedback=_LoopRoute(self.loop_while, self.max_trips),
                 scheduling=self.scheduling),
            Stage(_ReorderNode(), name=f"{self.name}-reorder"),
        )


# ---------------------------------------------------------------------------
# grain-aware stage fusion (IR -> IR rewrite for the threads lowering)
# ---------------------------------------------------------------------------
def _stage_fusible(s: "Skeleton", threshold_us: Optional[float],
                   force: bool) -> bool:
    if not isinstance(s, Stage):
        return False
    if force:
        return True
    return (s.grain is not None and threshold_us is not None
            and s.grain < threshold_us)


def _stateless(node: ff_node) -> bool:
    """Conservatively 'safe to replicate across farm workers': FnNode
    wrappers (pure-callable convention) and fusions thereof."""
    if isinstance(node, FusedNode):
        return all(_stateless(n) for n in node.nodes)
    return isinstance(node, FnNode)


def _merge_stages(a: "Stage", b: "Stage") -> "Stage":
    def parts(s: "Stage") -> List[ff_node]:
        n = s.node
        return list(n.nodes) if isinstance(n, FusedNode) and n.flatten else [n]

    # the fused stage's grain is the combined per-item work, so a run of
    # fine-grain stages stops merging once the fusion itself gets coarse
    grain = (a.grain + b.grain
             if a.grain is not None and b.grain is not None else None)
    return Stage(FusedNode(parts(a) + parts(b)),
                 name=f"fuse({a.name}+{b.name})", grain=grain)


def _farm_can_absorb(farm: "Farm", stage: "Stage") -> bool:
    # feedback would re-apply the stage every loop trip; a collector node
    # runs between merge and the stage, so absorbing would reorder them;
    # a stateful stage node cannot be replicated across workers.
    return (farm.feedback is None and farm.collector is None
            and _stateless(stage.node))


def _chain_parts(node: ff_node) -> List[ff_node]:
    return (list(node.nodes)
            if isinstance(node, FusedNode) and node.flatten else [node])


def _absorb_one(worker: ff_node, snode: ff_node) -> FusedNode:
    """Fuse ``snode`` behind ``worker``: flatten=False exactly at the
    worker/stage junction (the collector crossing), while repeated
    absorptions keep the stage side one flatten=True chain (stage-to-stage
    EmitMany flattening is preserved between absorbed stages)."""
    if isinstance(worker, FusedNode) and not worker.flatten:
        head, tail = worker.nodes[0], worker.nodes[1]
        parts = _chain_parts(tail) + _chain_parts(snode)
        return FusedNode([head, FusedNode(parts)], flatten=False)
    return FusedNode([worker, snode], flatten=False)


def _absorb_stage_into_farm(farm: "Farm", stage: "Stage") -> "Farm":
    return Farm(
        [_absorb_one(w, stage.node) for w in farm.worker_nodes],
        emitter=farm.emitter, ordered=farm.ordered, grain=farm.grain,
        scheduling=farm.scheduling, speculative=farm.speculative,
        straggler_factor=farm.straggler_factor,
        min_straggler_age=farm.min_straggler_age,
        queue_class=farm.queue_class, capacity=farm.capacity,
        stats=farm.stats)


def _farm_fusible(f: "Skeleton", threshold_us: Optional[float],
                  force: bool) -> bool:
    if not isinstance(f, Farm):
        return False
    if force:
        return True
    return (f.grain is not None and threshold_us is not None
            and f.grain < threshold_us)


def _farms_mergeable(a: "Farm", b: "Farm") -> bool:
    """Farm∘Farm is collapsible when the junction between them carries no
    semantics of its own: no wrap-around loop on either side (the fused
    worker would re-run both nodes every trip), no collector on ``a`` / no
    emitter on ``b`` (both run *between* the farms, which fusion removes),
    no speculation (a re-issued fused task would redo both halves), equal
    ``ordered`` (a's merge establishes the order b's dispatch re-tags —
    fusing an ordered with an unordered farm would invent or destroy an
    ordering the unfused network had), and every worker stateless (the
    fused farm replicates ``max(nworkers)`` copies).  ``b``'s workers must
    be plain chains, not already-absorbed ``flatten=False`` junctions
    (their ``_FarmEmitMany`` flattening belongs to b's own merge)."""
    return (a.feedback is None and b.feedback is None
            and a.collector is None and b.emitter is None
            and not a.speculative and not b.speculative
            and a.ordered == b.ordered
            and all(_stateless(n) for n in a.worker_nodes)
            and all(_stateless(n) for n in b.worker_nodes)
            and all(not (isinstance(n, FusedNode) and not n.flatten)
                    for n in b.worker_nodes))


def _merge_farms(a: "Farm", b: "Farm") -> "Farm":
    """ONE farm of fused workers: worker i runs a's node then b's
    (``_absorb_one`` — the same worker∘stage junction semantics the
    farm-absorb rewrite uses, so a ``GO_ON`` from a's half retires the
    token exactly as a's merge would have, and a multi-emit from a's half
    crosses into b's node whole, as b's dispatch would have seen it).
    ``a``'s scheduling/emitter and ``b``'s collector-free tail survive;
    ``b``'s scheduling is subsumed by the fused dispatch."""
    n = max(a.nworkers, b.nworkers)
    workers = [_absorb_one(a.worker_nodes[i % a.nworkers],
                           b.worker_nodes[i % b.nworkers])
               for i in range(n)]
    grain = (a.grain + b.grain
             if a.grain is not None and b.grain is not None else None)
    return Farm(workers, emitter=a.emitter, ordered=a.ordered, grain=grain,
                scheduling=a.scheduling,
                queue_class=a.queue_class or b.queue_class,
                capacity=a.capacity or b.capacity, stats=a.stats)


def _a2a_can_absorb(a2a: "Skeleton", stage: "Stage") -> bool:
    """A stateless post-shuffle stage can sink into the right row when the
    rewrite is invisible: unordered (the ordered reorder stage runs *after*
    the rights — absorbing under it would re-tag flush items), no
    ``reduce=`` spec (the mesh shuffle program runs the spec INSTEAD of the
    right nodes, so an absorbed stage would silently vanish there), and no
    batch-aware or budget-carrying right nodes (the ``FusedNode`` wrapper
    would hide ``accepts_batches``/``budget`` from the vertex and the
    budget-board plumbing — see :func:`repro.core.a2a._a2a_budgets`)."""
    return (isinstance(a2a, AllToAll) and not a2a.ordered
            and a2a.reduce is None and _stateless(stage.node)
            and not any(getattr(n, "accepts_batches", False)
                        or getattr(n, "budget", None) is not None
                        for n in a2a.right_nodes))


def _absorb_stage_into_a2a(a2a: "AllToAll", stage: "Stage") -> "AllToAll":
    """Rebuild the shuffle with the stage chained behind every right-row
    vertex (flatten=True: the rights ARE stage vertices, so stage∘stage
    chain semantics apply — including ``svc_eos`` flush items streaming
    through the absorbed stage, exactly as the separate vertex saw them)."""
    rights = [FusedNode(_chain_parts(n) + _chain_parts(stage.node))
              for n in a2a.right_nodes]
    new = AllToAll(a2a.left_nodes, rights, by=a2a.by, nleft=a2a.nleft,
                   nright=a2a.nright, ordered=False,
                   scheduling=a2a.scheduling, reduce=None, grain=a2a.grain,
                   name=a2a.name, queue_class=a2a.queue_class,
                   capacity=a2a.capacity)
    new.stats = a2a.stats  # telemetry identity survives the rewrite
    return new


def fuse(skel: Any, *, threshold_us: Optional[float] = None,
         force: bool = False) -> "Skeleton":
    """Grain-aware fusion pass (ROADMAP "graph-level fusion"): rewrite the
    IR so hand-offs that cost more than the work they move disappear.

    Two rewrites, applied left-to-right over a :class:`Pipeline`:

    * **stage ∘ stage** — adjacent ``Stage``\\ s whose declared ``grain=``
      (per-item service time, µs, the threads-side reading of the grain
      attribute; the mesh backend reads it as microbatch rows) is below
      ``threshold_us`` collapse into one vertex running a
      :class:`FusedNode` chain.  The merged stage's grain is the sum, so
      runs stop merging once the fusion itself gets coarse.
    * **farm ∘ trailing stage** — a ``Farm`` followed by a sub-threshold
      stateless ``Stage`` absorbs it into every worker (the hand-off
      through the collector ring disappears; ordering still holds because
      tags reorder at the merge arbiter regardless of what ran in the
      worker).  Farms with ``feedback=`` or a collector node, and stateful
      stage nodes, are never absorbed.

    Two more rewrites landed with the autotune pass (ROADMAP "self-tuning
    runtime"), both driven by the same grain-vs-threshold test:

    * **farm ∘ farm** — adjacent ``Farm``\\ s whose grains BOTH sit under
      the threshold collapse into ONE farm of :class:`FusedNode` workers
      (``_merge_farms``): four arbiters and a full ring layer become two
      arbiters, and each item pays one dispatch instead of two.  Requires
      stateless workers, matching ``ordered``, and a semantically empty
      junction (no collector on the left / emitter on the right, no
      feedback, no speculation) — see :func:`_farms_mergeable`.
    * **a2a ∘ trailing stage** — a sub-threshold stateless ``Stage`` after
      an *unordered, spec-free* :class:`AllToAll` sinks into every
      right-row vertex (``_absorb_stage_into_a2a``), removing the M→1
      fan-in hand-off behind the shuffle.

    ``force=True`` fuses every adjacent eligible pair regardless of grain
    (used by tests/benchmarks to pin behaviour); the default ``"auto"``
    mode of ``lower(skel, "threads")`` calls this with the calibrated
    hand-off threshold (:func:`repro.core.sched.calibrate_handoff_us`)
    only when some stage actually declares a grain — skeletons that don't
    opt in are untouched.

    An :class:`AllToAll` otherwise stays a hard fusion boundary: merging a
    stage into (or across) the shuffle's scatter side, an *ordered* or
    ``reduce=``-carrying shuffle, or a budgeted right row would change
    what the N×M matrix computes or hide the budget/batch plumbing — only
    the narrow right-row absorption above is ever applied, and
    ``tests/test_a2a.py`` pins that a ``reduce_by_key`` shuffle is
    untouched even under ``force=True``.
    """
    skel = as_skeleton(skel)
    if not isinstance(skel, Pipeline):
        return skel
    out: List[Skeleton] = []
    for s in skel.stages:
        prev = out[-1] if out else None
        if _stage_fusible(s, threshold_us, force):
            if isinstance(prev, Stage) and _stage_fusible(prev, threshold_us,
                                                          force):
                out[-1] = _merge_stages(prev, s)
                continue
            if isinstance(prev, Farm) and _farm_can_absorb(prev, s):
                out[-1] = _absorb_stage_into_farm(prev, s)
                continue
            if isinstance(prev, AllToAll) and _a2a_can_absorb(prev, s):
                out[-1] = _absorb_stage_into_a2a(prev, s)
                continue
        elif _farm_fusible(s, threshold_us, force) \
                and _farm_fusible(prev, threshold_us, force) \
                and _farms_mergeable(prev, s):
            out[-1] = _merge_farms(prev, s)
            continue
        out.append(s)
    return out[0] if len(out) == 1 else Pipeline(*out)


def _has_grained_stage(skel: "Skeleton") -> bool:
    if isinstance(skel, Pipeline):
        return any(_has_grained_stage(s) for s in skel.stages)
    return isinstance(skel, Stage) and skel.grain is not None


_fuse_pass = fuse  # ThreadProgram's `fuse=` parameter shadows the name


# ---------------------------------------------------------------------------
# lowering: backend registry + programs
# ---------------------------------------------------------------------------
class LoweringError(ValueError):
    """A skeleton uses a feature its target backend cannot express."""


BACKENDS: Dict[str, Type] = {}


def lower(skel: Any, backend: str = "threads", **opts: Any):
    """Lower a skeleton to an executable program on ``backend``.

    Programs are callables: ``lower(skel, b)(items)`` runs the finite
    stream ``items`` through the network and returns the output list.
    Backends are a registry (``BACKENDS``) so scheduling policies and
    fused runtimes can plug in without touching the IR.

    ``tune=True`` makes the compile two-phase: the first call runs a
    bounded pilot slice of the stream through an instrumented threads
    lowering, records per-stage service times / queue high-water marks /
    hand-off cost into a :class:`repro.core.autotune.Profile`, re-lowers
    via ``retune()`` with measured grains and ring capacities, and runs
    the remainder (plus all later calls) through the tuned program.
    ``tune_pilot=`` bounds the pilot slice (item count); ``profile=``
    skips the pilot entirely and re-lowers from a saved/loaded Profile.
    """
    skel = as_skeleton(skel)
    tune = opts.pop("tune", False)
    tune_pilot = opts.pop("tune_pilot", None)
    profile = opts.pop("profile", None)
    if tune or profile is not None:
        from .autotune import TunedProgram
        return TunedProgram(skel, backend, pilot=tune_pilot,
                            profile=profile, opts=opts)
    try:
        cls = BACKENDS[backend]
    except KeyError:
        raise LoweringError(
            f"unknown backend {backend!r} (have {sorted(BACKENDS)})") from None
    return cls(skel, **opts)


def walk_stats(skel: Skeleton, path: str = "") -> Iterable[Tuple[str, Any]]:
    """Yield ``(qualname, FarmStats)`` for every stats-carrying node in
    the IR tree — the walk a :class:`~repro.core.obs.RunReport` absorbs.
    Keys are IR-path qualified (``ff-farm@1``), so two farms in one
    pipeline land in separate report rows."""
    if isinstance(skel, Pipeline):
        for i, s in enumerate(skel.stages):
            yield from walk_stats(s, f"{path}.{i}" if path else str(i))
    elif isinstance(skel, Farm):
        yield _obs_qualname("ff-farm", path), skel.stats
    elif isinstance(skel, AllToAll):
        yield _obs_qualname(skel.name, path), skel.stats


def _coerce_tracer(trace: Any) -> Optional[Tracer]:
    if isinstance(trace, Tracer):
        return trace
    return Tracer() if trace else None


def _coerce_metrics(metrics: Any) -> Optional[MetricsRegistry]:
    if isinstance(metrics, MetricsRegistry):
        return metrics
    return MetricsRegistry() if metrics else None


def _coerce_monitor(monitor: Any):
    """``monitor=`` on lower(): None/False -> off, True -> a fresh
    default :class:`~repro.core.monitor.Monitor`, an instance -> shared.
    The import is lazy so ``monitor=None`` programs never touch
    monitor.py at all (pinned by the tracemalloc test, same discipline
    as the obs pin)."""
    if not monitor:
        return None
    from .monitor import Monitor
    if isinstance(monitor, Monitor):
        return monitor
    return Monitor()


class ThreadProgram:
    """Threads lowering: the skeleton wired onto the PR-1 graph runtime
    (one thread per vertex, lock-free SPSC rings for every edge).

    ``fuse`` controls the grain-aware fusion pass: ``"auto"`` (default)
    collapses hand-offs whose declared stage ``grain=`` is below the
    calibrated threshold (``fuse_threshold_us``, or the measured per-item
    hand-off cost when None — calibration only runs if some stage declares
    a grain); ``True`` force-fuses every eligible adjacent pair; ``False``
    disables the pass.

    ``trace=True`` (or a :class:`~repro.core.obs.Tracer`) gives every
    vertex a sampled event lane; the merged
    :class:`~repro.core.obs.Trace` lands on ``last_trace`` after each
    call.  ``metrics=True`` (or a
    :class:`~repro.core.obs.MetricsRegistry`) samples queue depths while
    the run drains and absorbs the skeleton's ``FarmStats`` into a
    :class:`~repro.core.obs.RunReport` on ``last_report``.

    ``monitor=True`` (or a :class:`~repro.core.monitor.Monitor`) attaches
    the continuous live sampler for the duration of each call: queue
    depths, farm EWMAs and counters land in ``monitor.timeline`` while
    the stream runs — see :mod:`repro.core.monitor`."""

    backend = "threads"

    def __init__(self, skeleton: Skeleton, *,
                 queue_class: Optional[Type] = None, capacity: int = 512,
                 fuse: Any = "auto", fuse_threshold_us: Optional[float] = None,
                 trace: Any = False, metrics: Any = False,
                 monitor: Any = None):
        if fuse and isinstance(skeleton, Pipeline):
            force = fuse is True
            thr = fuse_threshold_us
            if not force and thr is None and _has_grained_stage(skeleton):
                from .sched import calibrate_handoff_us
                thr = calibrate_handoff_us()
            skeleton = _fuse_pass(skeleton, threshold_us=thr, force=force)
        self.skeleton = skeleton
        self.queue_class = queue_class
        self.capacity = capacity
        self.tracer = _coerce_tracer(trace)
        self.metrics = _coerce_metrics(metrics)
        self.monitor = _coerce_monitor(monitor)
        self.last_trace = None
        self.last_report = None

    def to_graph(self, stream: Optional[Iterable[Any]] = None):
        from . import graph  # the threads backend (PR-1 vertex machinery)
        from .spsc import SPSCQueue
        g = graph.Graph(queue_class=self.queue_class or SPSCQueue,
                        capacity=self.capacity)
        # a live monitor wants per-worker service EWMAs: opt the farm
        # workers into the timing they otherwise skip (same flag the
        # procs backend uses to arm its live counter boards)
        g.live_telemetry = self.monitor is not None
        # Build the driving Source separately (at path "in") so the user
        # skeleton keeps its root IR paths — telemetry keys vertices by
        # path, and wrapping in a fresh Pipeline would shift every
        # top-level index by one.
        in_ring = None
        if stream is not None:
            in_ring = graph.build(Source(stream), g, None, False, "in")
        graph.build(self.skeleton, g, in_ring, True)
        if self.tracer is not None:
            g.tracer = self.tracer
        return g

    def __call__(self, items: Iterable[Any]) -> List[Any]:
        xs = list(items)
        g = self.to_graph(xs)
        reg = self.metrics
        mon = self.monitor
        if mon is not None:
            mon.attach(g, skeleton=self.skeleton, backend="threads")
        try:
            if reg is None:
                out = g.run_and_wait()
            else:
                hw: Dict[str, int] = {}
                t0 = time.monotonic()
                # a short run can finish before the first poll below: the
                # drain sampler runs inside wait() after the vertex threads
                # join but before teardown, so every edge key still lands
                # exactly once — and never races the caller's results drain
                g.drain_samplers.append(lambda: g.sample_high_water(hw))
                g.run()
                while any(t.is_alive() for t in g._threads):
                    g.sample_high_water(hw)
                    time.sleep(0.0005)
                out = g.wait()
                farms = {q: farm_stats_snapshot(st)
                         for q, st in walk_stats(self.skeleton)}
                self.last_report = reg.finalize(reg.report(
                    farms=farms, queues=hw,
                    meta={"backend": "threads", "vertices": len(g.vertices),
                          "items_in": len(xs), "items_out": len(out),
                          "wall_s": time.monotonic() - t0}))
        finally:
            if mon is not None:
                mon.detach()
        if self.tracer is not None:
            self.last_trace = self.tracer.trace()
        return out


BACKENDS["threads"] = ThreadProgram


# ---------------------------------------------------------------------------
# mesh lowering: one shard_map program for the whole skeleton
# ---------------------------------------------------------------------------
@dataclass
class _MeshStage:
    # NOTE: no per-stage worker count — mesh parallelism is always the
    # negotiated worker-axis size (see the Farm docstring)
    kind: str                                  # "map" | "farm" | "feedback"
    fn: Callable
    loop_while: Optional[Callable] = None
    max_trips: Optional[int] = None


def _jax_callable(node: ff_node) -> Callable:
    """The jax-traceable function behind a node (FnNode unwraps)."""
    return node._fn if isinstance(node, FnNode) else node.svc


def _mesh_plan(skel: Skeleton) -> List[_MeshStage]:
    """Flatten a skeleton into the mesh backend's stage list, rejecting
    host-only features instead of silently approximating them."""
    if isinstance(skel, Pipeline):
        return [ms for s in skel.stages for ms in _mesh_plan(s)]
    if isinstance(skel, Stage):
        return [_MeshStage("map", _jax_callable(skel.node))]
    if isinstance(skel, Feedback):
        return [_MeshStage("feedback", _jax_callable(skel.node),
                           loop_while=skel.loop_while,
                           max_trips=skel.max_trips)]
    if isinstance(skel, Farm):
        if skel.feedback is not None:
            raise LoweringError(
                "Farm(feedback=route) is the thread backend's general "
                "routing protocol; use Feedback(worker, loop_while) for a "
                "backend-neutral wrap-around loop")
        if skel.emitter is not None or skel.collector is not None:
            raise LoweringError(
                "emitter/collector nodes are host-side arbiters; the mesh "
                "farm's dispatch/combine replace them")
        if len({id(n) for n in skel.worker_nodes}) != 1:
            raise LoweringError(
                "mesh farms are SPMD: all workers must share one function")
        return [_MeshStage("farm", _jax_callable(skel.worker_nodes[0]))]
    if isinstance(skel, Source):
        raise LoweringError(
            "a mesh program takes its stream as the call argument; drop "
            "the Source stage")
    raise LoweringError(f"cannot lower {skel!r} to the mesh backend")


def _skeleton_grain(skel: Skeleton) -> Optional[int]:
    if isinstance(skel, Pipeline):
        for s in skel.stages:
            g = _skeleton_grain(s)
            if g:
                return g
        return None
    return getattr(skel, "grain", None)


class MeshProgram:
    """Mesh lowering: the whole skeleton as ONE ``shard_map`` program.

    A 2-D ``(skel_stage, skel_worker)`` mesh is negotiated from the device
    count (``dpipeline.negotiate_stage_axis``): with enough devices each
    pipeline stage owns a row of workers and the program is
    ``pipeline_apply`` (stage axis, microbatch streaming over SPSC
    collective-permute edges) of ``farm_map`` (worker axis, all-to-all
    dispatch + ordered combine); with fewer devices the stage chain runs
    sequentially *inside the same program* — either way there is exactly
    one compiled ``shard_map`` and no host hop between stages.

    Items are packed host-side into a ``(rows, d)`` array (scalars become
    ``d=1``), padded to a per-device row bucket (power of two, so repeated
    calls with nearby sizes reuse the compiled program), and unpacked in
    order on the way out — ordering is preserved end to end by the farm's
    ``(dest, pos)`` tags and the pipeline's microbatch realignment.
    """

    backend = "mesh"

    def __init__(self, skeleton: Skeleton, *, devices: Optional[int] = None,
                 grain: Optional[int] = None, capacity: Optional[int] = None,
                 block: int = 64, check_vma: Optional[bool] = None,
                 factorization: Optional[Tuple[int, int]] = None,
                 trace: Any = False, metrics: Any = False,
                 monitor: Any = None):
        import jax
        from . import dpipeline

        self.skeleton = skeleton
        self.stages = _mesh_plan(skeleton)
        assert self.stages, "empty skeleton"
        self.grain = grain if grain is not None else _skeleton_grain(skeleton)
        self.capacity = capacity
        self.block = block
        self.check_vma = check_vma
        ndev = len(jax.devices()) if devices is None else devices
        if factorization is not None:
            # autotune override (plan_mesh): the pipelined path still
            # requires n_stage == len(stages), so only (1, ndev) or
            # (len(stages), ndev // len(stages)) are legal here.
            n_stage, n_worker = factorization
            if n_stage not in (1, len(self.stages)) \
                    or n_stage * n_worker > ndev or n_worker < 1:
                raise LoweringError(
                    f"factorization {factorization} is not expressible on "
                    f"{ndev} devices for {len(self.stages)} stages")
            self.n_stage, self.n_worker = n_stage, n_worker
        else:
            self.n_stage, self.n_worker = dpipeline.negotiate_stage_axis(
                len(self.stages), ndev)
        from .. import compat
        self.mesh = compat.make_mesh((self.n_stage, self.n_worker),
                                     (STAGE_AXIS, WORKER_AXIS))
        self._programs: Dict[Tuple[int, int, str], Callable] = {}
        # observability: a mesh run has no host vertices, so the trace is
        # program-level — one "mesh-program" lane carrying a devices
        # instant, one compile span per cache miss, one call span per run
        self.tracer = _coerce_tracer(trace)
        self.metrics = _coerce_metrics(metrics)
        # live monitoring: no host vertices to sample, so each call pushes
        # one program-level counter frame (Monitor.program_frame)
        self.monitor = _coerce_monitor(monitor)
        self._mon_calls = 0
        self._mon_items = 0
        self.last_trace = None
        self.last_report = None
        self._lane = None
        if self.tracer is not None:
            self._lane = self.tracer.vertex("mesh-program")
            self._lane.instant("devices", {
                "devices": self.n_stage * self.n_worker,
                "n_stage": self.n_stage, "n_worker": self.n_worker})

    # -- host-side packing ---------------------------------------------------
    def _bucket_rows(self, n: int) -> int:
        """Per-device row count: enough for ``n`` items over the worker
        axis, floored at ``block`` and rounded to a power of two (bounds
        recompiles), then aligned to the microbatch grain."""
        rows = max(-(-n // self.n_worker), 1, self.block)
        rows = 1 << (rows - 1).bit_length()
        if self.grain:
            rows = self.grain * (-(-rows // self.grain))
        return rows

    def __call__(self, items: Iterable[Any]) -> List[Any]:
        import numpy as np

        xs = list(items)
        if not xs:
            return []
        arr = np.asarray(xs)
        if arr.dtype.kind == "f":
            arr = arr.astype(np.float32)
        elif arr.dtype.kind in "iub":
            cast = arr.astype(np.int32)
            if not np.array_equal(cast, arr):
                raise LoweringError(
                    "integer payloads exceed int32 (the mesh compute "
                    "dtype); the threads backend computes exact Python "
                    "ints — refusing to silently diverge")
            arr = cast
        else:
            raise LoweringError(
                f"mesh payloads must be numeric, got dtype {arr.dtype}")
        squeeze = arr.ndim == 1
        if squeeze:
            arr = arr[:, None]
        if arr.ndim != 2:
            raise LoweringError("mesh payloads must be scalars or 1-D items")
        n, d = arr.shape
        rows = self._bucket_rows(n)
        # last column is the validity flag: bucket-padding rows carry 0 so
        # they can never gate a feedback while_loop (see dfarm.farm_until)
        padded = np.zeros((self.n_worker * rows, d + 1), arr.dtype)
        padded[:n, :d] = arr
        padded[:n, d] = 1
        prog = self._program(rows, d, str(arr.dtype))
        t0 = time.monotonic()
        out = np.asarray(prog(padded))
        t1 = time.monotonic()
        if self._lane is not None:
            self._lane.span("call", t0, t1, {"items": n, "rows": rows})
            self.last_trace = self.tracer.trace()
        if self.metrics is not None:
            reg = self.metrics
            reg.counter("mesh.calls").inc()
            reg.counter("mesh.items").inc(n)
            reg.gauge("mesh.devices").set(self.n_stage * self.n_worker)
            reg.histogram("mesh.call_us").observe((t1 - t0) * 1e6)
            self.last_report = reg.finalize(reg.report(
                meta={"backend": "mesh", "n_stage": self.n_stage,
                      "n_worker": self.n_worker}))
        if self.monitor is not None:
            self._mon_calls += 1
            self._mon_items += n
            self.monitor.program_frame({
                "mesh.calls": self._mon_calls,
                "mesh.items": self._mon_items,
                "mesh.compiles": len(self._programs),
                "mesh.devices": self.n_stage * self.n_worker,
                "mesh.call_us": (t1 - t0) * 1e6})
        out = out[:n, :d]
        if squeeze:
            return [v.item() for v in out[:, 0]]
        return [row.tolist() for row in out]

    # -- the single shard_map program ---------------------------------------
    def _program(self, rows: int, d: int, dtype: str) -> Callable:
        key = (rows, d, dtype)
        if key in self._programs:
            return self._programs[key]
        t_compile = time.monotonic()

        import jax
        import jax.numpy as jnp
        from jax import lax
        from jax.sharding import PartitionSpec as P

        from .. import compat
        from . import dfarm, dpipeline

        stages, W = self.stages, self.n_worker
        pipelined = self.n_stage > 1        # one stage per mesh row
        check_vma = self.check_vma
        if check_vma is None and compat.WHILE_NEEDS_UNCHECKED_REP \
                and any(st.kind == "feedback" for st in stages):
            check_vma = False               # see compat.WHILE_NEEDS_UNCHECKED_REP

        def apply_stage(st: _MeshStage, xf):
            # xf carries the payload plus the validity-flag column; stages
            # compute on the payload, the flag rides along untouched (the
            # farm's ordered combine returns rows to their origin, so the
            # resident flag stays aligned)
            x, flag = xf[:, :-1], xf[:, -1:]
            k = x.shape[0]
            if st.kind == "map":
                y = st.fn(x)
            else:
                dest = dfarm.roundrobin_dest(k, WORKER_AXIS)
                need = -(-k // W)   # max bucket fill under round-robin dest
                cap = self.capacity or need + 1
                if cap < need:
                    raise LoweringError(
                        f"capacity={cap} would drop items: round-robin "
                        f"dispatch of {k} rows over {W} workers needs "
                        f"≥ {need} slots per (source, worker) pair")
                if st.kind == "farm":
                    y = dfarm.farm_map(st.fn, x, dest, WORKER_AXIS, cap)
                else:
                    y = dfarm.farm_until(st.fn, st.loop_while, x, dest,
                                         WORKER_AXIS, cap, valid=flag,
                                         max_trips=st.max_trips)
            return jnp.concatenate([y, flag], axis=1)

        def body(x):                 # (rows, d+1) per worker column
            if not pipelined:
                for st in stages:
                    x = apply_stage(st, x)
                return x
            mb = self.grain or rows
            mbs = x.reshape(rows // mb, mb, d + 1)

            def stage_fn(_, v):
                # branchless stage dispatch: every row computes all stages'
                # collectives in the same order (SPMD-safe), select_n keeps
                # this row's own stage — virtualisation of Fig. 1's
                # "one entity per stage" onto whatever mesh exists.
                cases = [compat.vma_align(apply_stage(st, v),
                                          (STAGE_AXIS, WORKER_AXIS))
                         for st in stages]
                return lax.select_n(lax.axis_index(STAGE_AXIS), *cases)

            out = dpipeline.pipeline_apply(stage_fn, None, mbs,
                                           axis_name=STAGE_AXIS,
                                           vary_axes=(WORKER_AXIS,))
            return out.reshape(rows, d + 1)

        fn = jax.jit(compat.shard_map(
            body, mesh=self.mesh, in_specs=(P(WORKER_AXIS),),
            out_specs=P(WORKER_AXIS), check_vma=check_vma))
        self._programs[key] = fn
        if self._lane is not None:
            self._lane.span("compile", t_compile, time.monotonic(),
                            {"rows": rows, "d": d, "dtype": dtype})
        if self.metrics is not None:
            self.metrics.counter("mesh.compiles").inc()
        return fn


def _contains_a2a(skel: Skeleton) -> bool:
    if isinstance(skel, Pipeline):
        return any(_contains_a2a(s) for s in skel.stages)
    return isinstance(skel, AllToAll)


def _mesh_backend(skeleton: Skeleton, **opts: Any):
    """Mesh-backend factory: skeletons containing an :class:`AllToAll`
    compile to the keyed-shuffle program (:class:`repro.core.a2a.
    A2AMeshProgram` — dispatch-by-key exchange + segment reduction in one
    ``shard_map``); everything else to :class:`MeshProgram`."""
    if _contains_a2a(skeleton):
        from .a2a import A2AMeshProgram
        return A2AMeshProgram(skeleton, **opts)
    return MeshProgram(skeleton, **opts)


BACKENDS["mesh"] = _mesh_backend
