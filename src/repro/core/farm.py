"""The farm skeleton (paper Sec. 3.1, Figs. 1-2), host-thread flavour.

Topology (all edges are lock-free SPSC rings — never a shared MPMC):

    Emitter --spsc--> Worker_0 --spsc--\
            --spsc--> Worker_1 --spsc---> Collector
            --spsc--> ...      --spsc--/

As of the skeleton-IR redesign this module is a thin facade twice over:
``TaskFarm`` is the seed's original API bound to a one-farm
:class:`repro.core.skeleton.Farm` IR node lowered on the threads backend
(:mod:`.graph`), where the Emitter and Collector arbiters, tagged-token
ordering, straggler re-issue and the EOS protocol live as reusable
machinery shared by every skeleton.  New code should build the declarative
IR directly — ``skeleton.Farm`` / ``Pipeline`` / ``compose`` — and pick a
runtime with ``lower(skel, backend="threads"|"mesh")``.

Features reproduced from the paper:
  * ``ff_node`` API with ``svc`` / ``svc_init`` / ``svc_end`` (Fig. 2);
  * round-robin and on-demand (shortest-queue) scheduling policies;
  * optional **order-preserving** farm via tagged tokens (Fig. 1, right) —
    the collector reorders by tag, as in tagged-token macro data-flow;
  * EOS protocol: emitter returning ``None`` ends the stream.

Beyond-paper (scale features required for a production runtime):
  * **straggler mitigation** — the emitter speculatively re-issues tasks
    whose age exceeds ``straggler_factor × p95`` of completed latencies; the
    collector deduplicates by tag, so duplicates are harmless (exactly-once
    delivery at the collector).
  * **worker-failure tolerance** — a worker thread that dies mid-task simply
    stops draining its queue; its outstanding tags age out and are re-issued
    to live workers by the same speculation path.

The queue class is pluggable (``SPSCQueue`` vs ``LockQueue``) so the
benchmarks can compare the paper's design against the lock-based baseline
over an otherwise identical farm.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Type

from .graph import Graph
from .sched import make_scheduler
from .skeleton import Farm, FarmStats, FnNode, _SeqNode, ff_node
from .spsc import SPSCQueue

__all__ = ["ff_node", "FnNode", "TaskFarm", "FarmStats"]


class TaskFarm:
    """Emitter → N workers → Collector over SPSC rings (graph-backed).

    Parameters
    ----------
    nworkers: worker-pool width.
    queue_class: ``SPSCQueue`` (paper) or ``LockQueue`` (baseline).
    capacity: per-ring capacity.
    preserve_order: emit collector output in emission (tag) order.
    scheduling: policy name (``"rr"`` | ``"ondemand"`` | ``"worksteal"`` |
        ``"costmodel"``) or a ``repro.core.sched.Scheduler``.
    speculative: enable straggler re-dispatch.
    straggler_factor: age threshold multiplier over p95 latency.
    """

    def __init__(
        self,
        nworkers: int,
        *,
        queue_class: Type = SPSCQueue,
        capacity: int = 512,
        preserve_order: bool = False,
        scheduling: Any = "rr",
        speculative: bool = False,
        straggler_factor: float = 4.0,
        min_straggler_age: float = 0.05,
    ):
        assert nworkers >= 1
        make_scheduler(scheduling)  # raises ValueError on an unknown policy
        self.nworkers = nworkers
        self.queue_class = queue_class
        self.capacity = capacity
        self.preserve_order = preserve_order
        self.scheduling = scheduling
        self.speculative = speculative
        self.straggler_factor = straggler_factor
        self.min_straggler_age = min_straggler_age
        self._emitter: Optional[ff_node] = None
        self._workers: List[ff_node] = []
        self._collector: Optional[ff_node] = None
        self._graph: Optional[Graph] = None
        self.results: List[Any] = []
        self.stats = FarmStats()

    # -- wiring (paper Fig. 2 API) -----------------------------------------
    def add_emitter(self, node: ff_node) -> "TaskFarm":
        self._emitter = node
        return self

    def add_worker(self, node: ff_node) -> "TaskFarm":
        self._workers.append(node)
        return self

    def add_collector(self, node: ff_node) -> "TaskFarm":
        self._collector = node
        return self

    def add_stream(self, items: Sequence[Any]) -> "TaskFarm":
        """Convenience: emitter that replays a finite sequence."""
        return self.add_emitter(_SeqNode(items))

    # -- lifecycle -----------------------------------------------------------
    def run(self) -> "TaskFarm":
        assert self._emitter is not None, "farm needs an emitter"
        if len(self._workers) == 1 and self.nworkers > 1:
            self._workers = self._workers * self.nworkers
        assert len(self._workers) == self.nworkers
        net = Farm(
            list(self._workers),
            emitter=self._emitter,
            collector=self._collector,
            ordered=self.preserve_order,
            scheduling=self.scheduling,
            speculative=self.speculative,
            straggler_factor=self.straggler_factor,
            min_straggler_age=self.min_straggler_age,
            stats=self.stats,
        )
        self._graph = net.to_graph(queue_class=self.queue_class,
                                   capacity=self.capacity)
        self._graph.results = self.results  # alias the pre-exposed sink
        self._graph.run()
        return self

    def wait(self, timeout: Optional[float] = None) -> List[Any]:
        assert self._graph is not None, "call run() first"
        return self._graph.wait(timeout)

    def run_and_wait(self) -> List[Any]:
        return self.run().wait()
