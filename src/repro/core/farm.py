"""The farm skeleton (paper Sec. 3.1, Figs. 1-2), host-thread flavour.

Topology (all edges are lock-free SPSC rings — never a shared MPMC):

    Emitter --spsc--> Worker_0 --spsc--\
            --spsc--> Worker_1 --spsc---> Collector
            --spsc--> ...      --spsc--/

The Emitter and Collector are the paper's *active arbiters*: the only
multi-party coordination in the network is performed by them walking their
private SPSC endpoints, so no lock or atomic op ever guards a queue.

Features reproduced from the paper:
  * ``ff_node`` API with ``svc`` / ``svc_init`` / ``svc_end`` (Fig. 2);
  * round-robin and on-demand (shortest-queue) scheduling policies;
  * optional **order-preserving** farm via tagged tokens (Fig. 1, right) —
    the collector reorders by tag, as in tagged-token macro data-flow;
  * EOS protocol: emitter returning ``None`` ends the stream.

Beyond-paper (scale features required for a production runtime):
  * **straggler mitigation** — the emitter speculatively re-issues tasks
    whose age exceeds ``straggler_factor × p95`` of completed latencies; the
    collector deduplicates by tag, so duplicates are harmless (exactly-once
    delivery at the collector).
  * **worker-failure tolerance** — a worker thread that dies mid-task simply
    stops draining its queue; its outstanding tags age out and are re-issued
    to live workers by the same speculation path.

The queue class is pluggable (``SPSCQueue`` vs ``LockQueue``) so the
benchmarks can compare the paper's design against the lock-based baseline
over an otherwise identical farm.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Type

from .spsc import EOS, SPSCQueue

__all__ = ["ff_node", "FnNode", "TaskFarm", "FarmStats"]


class ff_node:
    """Base class for farm entities (paper Fig. 2)."""

    def svc_init(self) -> None:  # noqa: D401
        """Called once in the entity's own thread before the stream starts."""

    def svc(self, task: Any) -> Any:
        """Process one task. Emitters receive ``None`` and return the next
        task (or ``None`` for end-of-stream); workers/collectors receive a
        task and return a result."""
        raise NotImplementedError

    def svc_end(self) -> None:
        """Called once after EOS has been processed."""


class FnNode(ff_node):
    """Wrap a plain callable as an ``ff_node``."""

    def __init__(self, fn: Callable[[Any], Any]):
        self._fn = fn

    def svc(self, task: Any) -> Any:
        return self._fn(task)


@dataclass
class _Msg:
    tag: int
    payload: Any
    issued_at: float = 0.0
    duplicate: bool = False


@dataclass
class FarmStats:
    tasks_emitted: int = 0
    tasks_collected: int = 0
    duplicates_issued: int = 0
    duplicates_dropped: int = 0
    per_worker: Dict[int, int] = field(default_factory=dict)
    latencies: List[float] = field(default_factory=list)
    worker_failures: List = field(default_factory=list)

    def p95_latency(self) -> float:
        if not self.latencies:
            return 0.0
        xs = sorted(self.latencies)
        return xs[min(len(xs) - 1, int(0.95 * len(xs)))]


class TaskFarm:
    """Emitter → N workers → Collector over SPSC rings.

    Parameters
    ----------
    nworkers: worker-pool width.
    queue_class: ``SPSCQueue`` (paper) or ``LockQueue`` (baseline).
    capacity: per-ring capacity.
    preserve_order: emit collector output in emission (tag) order.
    scheduling: ``"rr"`` round-robin | ``"ondemand"`` shortest-queue.
    speculative: enable straggler re-dispatch.
    straggler_factor: age threshold multiplier over p95 latency.
    """

    def __init__(
        self,
        nworkers: int,
        *,
        queue_class: Type = SPSCQueue,
        capacity: int = 512,
        preserve_order: bool = False,
        scheduling: str = "rr",
        speculative: bool = False,
        straggler_factor: float = 4.0,
        min_straggler_age: float = 0.05,
    ):
        assert nworkers >= 1
        assert scheduling in ("rr", "ondemand")
        self.nworkers = nworkers
        self.preserve_order = preserve_order
        self.scheduling = scheduling
        self.speculative = speculative
        self.straggler_factor = straggler_factor
        self.min_straggler_age = min_straggler_age
        self._to_worker = [queue_class(capacity) for _ in range(nworkers)]
        self._from_worker = [queue_class(capacity) for _ in range(nworkers)]
        self._emitter: Optional[ff_node] = None
        self._workers: List[ff_node] = []
        self._collector: Optional[ff_node] = None
        self._threads: List[threading.Thread] = []
        self.results: List[Any] = []
        self.stats = FarmStats()
        # Collector-written / emitter-read completion set.  Single writer
        # (collector) per key; the emitter only reads — a benign race whose
        # worst case is one redundant duplicate, which the collector drops.
        self._done_tags: Dict[int, bool] = {}
        self._inflight: Dict[int, _Msg] = {}
        self._stream_closed = threading.Event()
        self._failed: List[BaseException] = []

    # -- wiring (paper Fig. 2 API) -----------------------------------------
    def add_emitter(self, node: ff_node) -> "TaskFarm":
        self._emitter = node
        return self

    def add_worker(self, node: ff_node) -> "TaskFarm":
        self._workers.append(node)
        return self

    def add_collector(self, node: ff_node) -> "TaskFarm":
        self._collector = node
        return self

    def add_stream(self, items: Sequence[Any]) -> "TaskFarm":
        """Convenience: emitter that replays a finite sequence."""
        it = iter(items)

        class _Seq(ff_node):
            def svc(self, _):
                try:
                    return next(it)
                except StopIteration:
                    return None

        return self.add_emitter(_Seq())

    # -- threads -------------------------------------------------------------
    def _emitter_loop(self) -> None:
        em = self._emitter
        assert em is not None
        em.svc_init()
        rr = 0
        tag = 0
        try:
            while True:
                task = em.svc(None)
                if task is None:
                    break
                msg = _Msg(tag=tag, payload=task, issued_at=time.monotonic())
                self._inflight[tag] = msg
                widx = self._pick_worker(rr)
                rr += 1
                self._to_worker[widx].push_wait(msg)
                self.stats.tasks_emitted += 1
                tag += 1
                if self.speculative and tag % 32 == 0:
                    self._respeculate(rr)
            # watchdog phase: keep re-issuing stragglers until all collected
            while self.speculative and any(
                t not in self._done_tags for t in self._inflight
            ):
                rr = self._respeculate(rr)
                time.sleep(0.002)
        except BaseException as e:  # pragma: no cover - surfaced in wait()
            self._failed.append(e)
        finally:
            for q in self._to_worker:
                q.push_wait(EOS)
            em.svc_end()
            self._stream_closed.set()

    def _pick_worker(self, rr: int) -> int:
        if self.scheduling == "ondemand":
            # shortest-queue: reading len() of an SPSC from a third thread is
            # heuristically stale but safe — exactly FastFlow's on-demand mode.
            return min(range(self.nworkers), key=lambda w: len(self._to_worker[w]))
        return rr % self.nworkers

    def _respeculate(self, rr: int) -> int:
        now = time.monotonic()
        p95 = max(self.stats.p95_latency(), self.min_straggler_age)
        threshold = self.straggler_factor * p95
        for t, msg in list(self._inflight.items()):
            if t in self._done_tags:
                continue
            if now - msg.issued_at > threshold:
                dup = _Msg(tag=msg.tag, payload=msg.payload, issued_at=now, duplicate=True)
                widx = self._pick_worker(rr)
                rr += 1
                if self._to_worker[widx].push(dup):
                    # re-arm the age clock; a still-stale tag (e.g. its copy
                    # landed on a dead worker) will speculate again, to a
                    # different worker (rr advanced) — this is what makes the
                    # farm survive worker loss, not just slowness.
                    msg.issued_at = now
                    self.stats.duplicates_issued += 1
        return rr

    def _worker_loop(self, widx: int) -> None:
        node = self._workers[widx]
        node.svc_init()
        q_in, q_out = self._to_worker[widx], self._from_worker[widx]
        try:
            while True:
                msg = q_in.pop_wait()
                if msg is EOS:
                    break
                result = node.svc(msg.payload)
                q_out.push_wait(_Msg(tag=msg.tag, payload=result, issued_at=msg.issued_at))
                self.stats.per_worker[widx] = self.stats.per_worker.get(widx, 0) + 1
        except BaseException as e:
            if self.speculative:
                # fault tolerance: a dying worker is survivable — its
                # outstanding tags age out and re-speculate to live workers.
                self.stats.worker_failures.append((widx, repr(e)))
            else:
                self._failed.append(e)
        finally:
            q_out.push_wait(EOS)
            node.svc_end()

    def _collector_loop(self) -> None:
        col = self._collector
        if col is not None:
            col.svc_init()
        eos_seen = 0
        next_tag = 0
        reorder: Dict[int, Any] = {}

        def deliver(payload: Any) -> None:
            if col is not None:
                out = col.svc(payload)
                if out is not None:
                    self.results.append(out)
            else:
                self.results.append(payload)

        try:
            while eos_seen < self.nworkers:
                progress = False
                for q in self._from_worker:
                    msg = q.pop()
                    if msg is SPSCQueue._EMPTY:
                        continue
                    progress = True
                    if msg is EOS:
                        eos_seen += 1
                        continue
                    if msg.tag in self._done_tags:
                        self.stats.duplicates_dropped += 1
                        continue
                    self._done_tags[msg.tag] = True
                    self.stats.tasks_collected += 1
                    self.stats.latencies.append(time.monotonic() - msg.issued_at)
                    if self.preserve_order:
                        reorder[msg.tag] = msg.payload
                        while next_tag in reorder:
                            deliver(reorder.pop(next_tag))
                            next_tag += 1
                    else:
                        deliver(msg.payload)
                if not progress:
                    time.sleep(0.000_05)
            # flush any residue (can only happen if tags were skipped upstream)
            for t in sorted(reorder):
                deliver(reorder.pop(t))
        except BaseException as e:  # pragma: no cover
            self._failed.append(e)
        finally:
            if col is not None:
                col.svc_end()

    # -- lifecycle -----------------------------------------------------------
    def run(self) -> "TaskFarm":
        assert self._emitter is not None, "farm needs an emitter"
        if len(self._workers) == 1 and self.nworkers > 1:
            self._workers = self._workers * self.nworkers
        assert len(self._workers) == self.nworkers
        mk = threading.Thread
        self._threads = [mk(target=self._collector_loop, name="ff-collector", daemon=True)]
        self._threads += [
            mk(target=self._worker_loop, args=(w,), name=f"ff-worker-{w}", daemon=True)
            for w in range(self.nworkers)
        ]
        self._threads.append(mk(target=self._emitter_loop, name="ff-emitter", daemon=True))
        for t in self._threads:
            t.start()
        return self

    def wait(self, timeout: Optional[float] = None) -> List[Any]:
        for t in self._threads:
            t.join(timeout)
        if self._failed:
            raise self._failed[0]
        return self.results

    def run_and_wait(self) -> List[Any]:
        return self.run().wait()
