"""Device farm skeleton — Emitter/Workers/Collector over a mesh axis.

The farm's three entities map onto SPMD pieces:

  * the **Emitter** is the dispatch step: every device computes, for each of
    its resident items, the destination worker and a slot inside that
    worker's inbound buffer (round-robin is just the identity sharding; the
    general data-dependent case is bucket-by-destination);
  * the **Workers** are the devices along ``axis_name``, each processing the
    buffer it received;
  * the **Collector** is the combine step, which routes results back to the
    device that emitted the item and restores item order (the tagged-token /
    order-preserving farm of paper Fig. 1: (dest, pos) *is* the tag).

``dispatch``/``combine`` are the generic mechanism; MoE expert-parallel
routing (`models/moe.py`) is its headline client — a token-to-expert farm.
The communication backend is pluggable:

  * ``"a2a"``   — one ``lax.all_to_all`` (the symmetric, "fence-like"
                  baseline: a single mesh-wide exchange);
  * ``"ring"``  — the FastFlow-style schedule: the exchange is decomposed
                  into ``n-1`` SPSC ring hops (collective-permute) so each
                  hop's transfer can overlap the per-hop worker compute.
                  Same payload bytes, no global exchange on the data path.

Shape-polymorphism note: everything is static-shaped (capacity-bounded
buffers with overflow dropping, as in capacity-factor MoE routing), so it
lowers cleanly under ``shard_map`` + ``jit``.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import axis_size as _axis_size

from .dchannel import ring_send

__all__ = ["dispatch", "combine", "farm_map", "DispatchInfo"]


class DispatchInfo(Tuple):
    """(dest, pos, valid) routing tag triple."""


def _bucket_positions(dest: jnp.ndarray, n_buckets: int, capacity: int):
    """Slot index of each item within its destination bucket (+validity)."""
    onehot = jax.nn.one_hot(dest, n_buckets, dtype=jnp.int32)       # (L, n)
    pos = jnp.cumsum(onehot, axis=0) - onehot                        # rank in bucket
    pos = jnp.sum(pos * onehot, axis=1)                              # (L,)
    valid = pos < capacity
    return pos, valid


def dispatch(
    items: jnp.ndarray,          # (L, d) local items
    dest: jnp.ndarray,           # (L,) destination worker in [0, axis_size)
    axis_name: str,
    capacity: int,
    *,
    backend: str = "a2a",
    wire_dtype=None,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]:
    """Route items to workers along ``axis_name``.

    Returns ``(recv, (dest, pos, valid))`` where ``recv`` has shape
    ``(axis_size, capacity, d)``: ``recv[s]`` are the items sent by source
    device ``s`` to *this* worker.  ``wire_dtype`` optionally quantises the
    payload on the wire (e.g. bf16 dispatch for fp32 compute) — a
    collective-bytes optimisation logged in EXPERIMENTS §Perf.
    """
    n = _axis_size(axis_name)
    L, d = items.shape
    pos, valid = _bucket_positions(dest, n, capacity)
    send = jnp.zeros((n, capacity, d), items.dtype)
    send = send.at[dest, pos].set(
        jnp.where(valid[:, None], items, 0), mode="drop"
    )
    if wire_dtype is not None and wire_dtype != items.dtype:
        send = send.astype(wire_dtype)
    if backend == "a2a":
        recv = lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0, tiled=False)
        # all_to_all with split/concat 0 keeps (n, capacity, d): row i now
        # holds the bucket sent by device i.
    elif backend == "ring":
        recv = _ring_exchange(send, axis_name)
    else:
        raise ValueError(f"unknown dispatch backend {backend!r}")
    if wire_dtype is not None and wire_dtype != items.dtype:
        recv = recv.astype(items.dtype)
    return recv, (dest, pos, valid)


def _ring_exchange(send: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """All-to-all decomposed into n-1 SPSC ring hops.

    At hop h, the block in flight left its producer h hops ago; this device
    (index i) extracts the bucket addressed to it — ``send`` row ``i`` of the
    block originating at device ``i - h`` — and forwards the rest.  XLA's
    async collective-permute lets hop h+1's transfer overlap hop h's
    extraction/compute; in the MoE client the per-hop expert matmul sits in
    that shadow.
    """
    n = _axis_size(axis_name)
    me = lax.axis_index(axis_name)

    def hop(block, h):
        src = (me - h) % n
        mine = lax.dynamic_index_in_dim(block, me, axis=0, keepdims=False)
        nxt = ring_send(block, axis_name)
        return nxt, (src, mine)

    block0 = send
    _, (srcs, buckets) = lax.scan(hop, block0, jnp.arange(n))
    # buckets[h] came from device (me - h); scatter into source-indexed rows
    recv = jnp.zeros_like(send)
    recv = recv.at[srcs].set(buckets)
    return recv


def combine(
    processed: jnp.ndarray,      # (axis_size, capacity, d) worker outputs
    info: Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray],
    axis_name: str,
    *,
    backend: str = "a2a",
    wire_dtype=None,
) -> jnp.ndarray:
    """Inverse of :func:`dispatch`: results return to their emitters in
    item order (the order-preserving collector). Invalid (dropped) items
    combine to zeros."""
    dest, pos, valid = info
    out_dtype = processed.dtype
    if wire_dtype is not None and wire_dtype != processed.dtype:
        processed = processed.astype(wire_dtype)
    if backend == "a2a":
        back = lax.all_to_all(processed, axis_name, split_axis=0, concat_axis=0, tiled=False)
    elif backend == "ring":
        back = _ring_exchange(processed, axis_name)
    else:
        raise ValueError(f"unknown combine backend {backend!r}")
    back = back.astype(out_dtype)
    gathered = back[dest, pos]                       # (L, d)
    return jnp.where(valid[:, None], gathered, 0)


def farm_map(
    worker_fn: Callable[[jnp.ndarray], jnp.ndarray],
    items: jnp.ndarray,
    dest: jnp.ndarray,
    axis_name: str,
    capacity: int,
    *,
    backend: str = "a2a",
) -> jnp.ndarray:
    """Full farm round-trip: dispatch → worker → collect, order-preserving."""
    recv, info = dispatch(items, dest, axis_name, capacity, backend=backend)
    flat = recv.reshape(-1, recv.shape[-1])
    out = worker_fn(flat).reshape(recv.shape[0], capacity, -1)
    return combine(out, info, axis_name, backend=backend)
