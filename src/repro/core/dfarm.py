"""Device farm skeleton — Emitter/Workers/Collector over a mesh axis.

The farm's three entities map onto SPMD pieces:

  * the **Emitter** is the dispatch step: every device computes, for each of
    its resident items, the destination worker and a slot inside that
    worker's inbound buffer (round-robin is just the identity sharding; the
    general data-dependent case is bucket-by-destination);
  * the **Workers** are the devices along ``axis_name``, each processing the
    buffer it received;
  * the **Collector** is the combine step, which routes results back to the
    device that emitted the item and restores item order (the tagged-token /
    order-preserving farm of paper Fig. 1: (dest, pos) *is* the tag).

``dispatch``/``combine`` are the generic mechanism; MoE expert-parallel
routing (`models/moe.py`) is its headline client — a token-to-expert farm —
and the skeleton mesh lowering (`skeleton.MeshProgram`) is the composable
one: ``farm_map`` is its farm stage, ``roundrobin_dest`` its emitter policy
and ``farm_until`` its wrap-around (feedback) loop.
The communication backend is pluggable:

  * ``"a2a"``   — one ``lax.all_to_all`` (the symmetric, "fence-like"
                  baseline: a single mesh-wide exchange);
  * ``"ring"``  — the FastFlow-style schedule: the exchange is decomposed
                  into ``n-1`` SPSC ring hops (collective-permute) so each
                  hop's transfer can overlap the per-hop worker compute.
                  Same payload bytes, no global exchange on the data path.

Shape-polymorphism note: everything is static-shaped (capacity-bounded
buffers with overflow dropping, as in capacity-factor MoE routing), so it
lowers cleanly under ``shard_map`` + ``jit``.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import axis_size as _axis_size

from .dchannel import ring_send

__all__ = ["dispatch", "combine", "farm_map", "farm_until",
           "roundrobin_dest", "farm_utilisation", "DispatchInfo"]


def farm_utilisation(n_items: int, n_workers: int) -> float:
    """Worker-axis occupancy for ``n_items`` over ``n_workers``: the last
    dispatch round is ragged, so utilisation is ``n / (W * ceil(n/W))``.
    The autotuner's factorization model uses this with
    :func:`repro.core.dpipeline.pipeline_utilisation` to trade worker
    raggedness against pipeline fill/drain bubbles."""
    if n_items <= 0 or n_workers <= 0:
        return 0.0
    rounds = -(-n_items // n_workers)
    return n_items / (n_workers * rounds)


class DispatchInfo(Tuple):
    """(dest, pos, valid) routing tag triple."""


def _bucket_positions(dest: jnp.ndarray, n_buckets: int, capacity: int):
    """Slot index of each item within its destination bucket (+validity)."""
    onehot = jax.nn.one_hot(dest, n_buckets, dtype=jnp.int32)       # (L, n)
    pos = jnp.cumsum(onehot, axis=0) - onehot                        # rank in bucket
    pos = jnp.sum(pos * onehot, axis=1)                              # (L,)
    valid = pos < capacity
    return pos, valid


def dispatch(
    items: jnp.ndarray,          # (L, d) local items
    dest: jnp.ndarray,           # (L,) destination worker in [0, axis_size)
    axis_name: str,
    capacity: int,
    *,
    backend: str = "a2a",
    wire_dtype=None,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]:
    """Route items to workers along ``axis_name``.

    Returns ``(recv, (dest, pos, valid))`` where ``recv`` has shape
    ``(axis_size, capacity, d)``: ``recv[s]`` are the items sent by source
    device ``s`` to *this* worker.  ``wire_dtype`` optionally quantises the
    payload on the wire (e.g. bf16 dispatch for fp32 compute) — a
    collective-bytes optimisation logged in EXPERIMENTS §Perf.
    """
    n = _axis_size(axis_name)
    L, d = items.shape
    pos, valid = _bucket_positions(dest, n, capacity)
    send = jnp.zeros((n, capacity, d), items.dtype)
    send = send.at[dest, pos].set(
        jnp.where(valid[:, None], items, 0), mode="drop"
    )
    if wire_dtype is not None and wire_dtype != items.dtype:
        send = send.astype(wire_dtype)
    if backend == "a2a":
        recv = lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0, tiled=False)
        # all_to_all with split/concat 0 keeps (n, capacity, d): row i now
        # holds the bucket sent by device i.
    elif backend == "ring":
        recv = _ring_exchange(send, axis_name)
    else:
        raise ValueError(f"unknown dispatch backend {backend!r}")
    if wire_dtype is not None and wire_dtype != items.dtype:
        recv = recv.astype(items.dtype)
    return recv, (dest, pos, valid)


def _ring_exchange(send: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """All-to-all decomposed into n-1 SPSC ring hops.

    At hop h, the block in flight left its producer h hops ago; this device
    (index i) extracts the bucket addressed to it — ``send`` row ``i`` of the
    block originating at device ``i - h`` — and forwards the rest.  XLA's
    async collective-permute lets hop h+1's transfer overlap hop h's
    extraction/compute; in the MoE client the per-hop expert matmul sits in
    that shadow.
    """
    n = _axis_size(axis_name)
    me = lax.axis_index(axis_name)

    def hop(block, h):
        src = (me - h) % n
        mine = lax.dynamic_index_in_dim(block, me, axis=0, keepdims=False)
        nxt = ring_send(block, axis_name)
        return nxt, (src, mine)

    block0 = send
    _, (srcs, buckets) = lax.scan(hop, block0, jnp.arange(n))
    # buckets[h] came from device (me - h); scatter into source-indexed rows
    recv = jnp.zeros_like(send)
    recv = recv.at[srcs].set(buckets)
    return recv


def combine(
    processed: jnp.ndarray,      # (axis_size, capacity, d) worker outputs
    info: Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray],
    axis_name: str,
    *,
    backend: str = "a2a",
    wire_dtype=None,
) -> jnp.ndarray:
    """Inverse of :func:`dispatch`: results return to their emitters in
    item order (the order-preserving collector). Invalid (dropped) items
    combine to zeros."""
    dest, pos, valid = info
    out_dtype = processed.dtype
    if wire_dtype is not None and wire_dtype != processed.dtype:
        processed = processed.astype(wire_dtype)
    if backend == "a2a":
        back = lax.all_to_all(processed, axis_name, split_axis=0, concat_axis=0, tiled=False)
    elif backend == "ring":
        back = _ring_exchange(processed, axis_name)
    else:
        raise ValueError(f"unknown combine backend {backend!r}")
    back = back.astype(out_dtype)
    gathered = back[dest, pos]                       # (L, d)
    return jnp.where(valid[:, None], gathered, 0)


def farm_map(
    worker_fn: Callable[[jnp.ndarray], jnp.ndarray],
    items: jnp.ndarray,
    dest: jnp.ndarray,
    axis_name: str,
    capacity: int,
    *,
    backend: str = "a2a",
) -> jnp.ndarray:
    """Full farm round-trip: dispatch → worker → collect, order-preserving."""
    recv, info = dispatch(items, dest, axis_name, capacity, backend=backend)
    flat = recv.reshape(-1, recv.shape[-1])
    out = worker_fn(flat).reshape(recv.shape[0], capacity, -1)
    return combine(out, info, axis_name, backend=backend)


def roundrobin_dest(n_local: int, axis_name: str) -> jnp.ndarray:
    """The Emitter's round-robin policy on the mesh: destination worker of
    each local item is its *global* stream index mod the axis size (the
    skeleton mesh lowering's default scheduling, mirroring the thread
    dispatch arbiter's ``"rr"`` mode)."""
    w = _axis_size(axis_name)
    me = lax.axis_index(axis_name)
    return (me * n_local + jnp.arange(n_local, dtype=jnp.int32)) % w


def farm_until(
    worker_fn: Callable[[jnp.ndarray], jnp.ndarray],
    loop_while: Callable[[jnp.ndarray], jnp.ndarray],
    items: jnp.ndarray,          # (L, d) local items
    dest: jnp.ndarray,           # (L,) destination worker
    axis_name: str,
    capacity: int,
    *,
    valid=None,
    max_trips=None,
    backend: str = "a2a",
) -> jnp.ndarray:
    """Feedback farm on the mesh: dispatch → re-apply ``worker_fn`` while
    ``loop_while`` holds → ordered combine.

    This is the device flavour of the thread farm's wrap-around
    (collector → emitter) edge: instead of tokens circulating over an SPSC
    ring, the still-looping rows are a mask on a ``lax.while_loop`` carry
    between the farm's dispatch and its order-preserving combine — one
    compiled loop, no host round-trip per trip.

    Semantics match the thread backend's :class:`~repro.core.skeleton.
    Feedback` exactly (do-while): every item is serviced at least once and
    emits the first result for which ``loop_while`` is false.  A validity
    flag travels the wire as an extra feature column so receivers can tell
    real items from buffer padding: ``valid`` (shape ``(L,)`` or ``(L, 1)``,
    nonzero = real, default all-valid) marks the caller's own padding rows
    — e.g. the skeleton mesh program's bucket padding, whose zero rows
    could otherwise gate the loop forever — and unfilled dispatch capacity
    slots arrive as zeros, so neither ever drives ``cond``.  ``loop_while``
    is applied to the ``(rows, d)`` buffer and reduced conjunctively over
    feature dims; ``max_trips`` (if given) bounds the trip count."""
    L, d = items.shape
    if valid is None:
        flag = jnp.ones((L, 1), items.dtype)
    else:
        flag = (valid.reshape(L, 1) != 0).astype(items.dtype)
    aug = jnp.concatenate([items, flag], axis=1)
    recv, info = dispatch(aug, dest, axis_name, capacity, backend=backend)
    flat = recv.reshape(-1, d + 1)
    valid = flat[:, d] != 0

    def live(x, trips):
        m = jnp.reshape(loop_while(x), (x.shape[0], -1)).all(axis=1)
        m = m & valid
        if max_trips is not None:
            m = m & (trips < max_trips)
        return m

    def cond(state):
        x, trips = state
        return jnp.any(live(x, trips))

    def trip(state):
        x, trips = state
        y = worker_fn(x)
        x = jnp.where(live(x, trips)[:, None], y, x)
        return x, trips + 1

    x0 = worker_fn(flat[:, :d])          # do-while: first trip unconditional
    x, _ = lax.while_loop(cond, trip, (x0, jnp.int32(1)))
    out = jnp.concatenate([x, flat[:, d:]], axis=1).reshape(recv.shape)
    return combine(out, info, axis_name, backend=backend)[:, :d]
