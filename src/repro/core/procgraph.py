"""Process-graph runtime — the ``procs`` backend of the skeleton IR.

``graph.py`` runs every vertex as a *thread*, which keeps the runtime
cheap but leaves pure-Python stages serialised behind the GIL: the
FastFlow speedup story (paper Sec. 6) only materialises there for
GIL-releasing kernels.  This module mirrors the same vertex machinery —
source/stage vertices, dispatch + merge arbiters, tagged-token ordered
farms, EOS propagation, loop quiescence for wrap-around edges — with each
vertex a **spawned process** and every edge a :class:`~repro.core.shm.ShmRing`
(the paper's SPSC ring over genuinely shared memory, cache-line-separated
head/tail and all).  A farm of pure-Python ``svc`` functions finally
scales with cores.

Construct map (vs the threads backend)
--------------------------------------
=============================  =============================================
threads (``graph.py``)         procs (this module)
=============================  =============================================
``threading.Thread`` vertex    ``spawn``-ed ``multiprocessing.Process``
``SPSCQueue`` edge             ``ShmRing`` edge (pickled = attach by name)
``Graph.results`` list         a results ring drained by the calling process
``Graph.failed`` list          a shared failure flag (:class:`ShmFlag`) + a
                               per-vertex control ring carrying ready/error
                               messages back to the caller
``TagSpace.entered/retired``   ``ShmCounters`` board: two single-writer
                               cache-line-separated u64s (dispatch writes
                               ``entered``, merge writes ``retired``)
``FarmStats`` (shared object)  per-arbiter local stats, merged at EOS and
                               surfaced to the caller over a stats ring
``sched.Scheduler`` policies   the same policy objects, driven from the
                               dispatch arbiter's process (idle/steal and
                               service-EWMA side-channels become ShmRings)
=============================  =============================================

Single-writer discipline is preserved end to end: every ring has one
producer and one consumer process; the quiescence board splits its
counters by writer; the scheduling policy lives entirely inside the
dispatch arbiter's process.  Even the *control plane* is shared-memory
SPSC: ready/error messages ride a per-vertex control ring (vertex →
caller) and the failure signal is a :class:`~repro.core.shm.ShmFlag`
(idempotent multi-writer store).  Nothing on any path needs a lock —
and, unlike ``multiprocessing``'s Queue/Event, every control primitive
pickles as a plain segment attach, which is what lets vertices ride
through a queue to **pooled** worker processes.

Spawn-pool reuse: starting a spawned interpreter costs ~0.1s (import of
``repro.core`` dominates); a program that lowers the same skeleton
repeatedly would pay it per run, per vertex.  ``run()`` therefore leases
processes from a module-level pool (one per start method): each pooled
worker loops ``job = jobq.get(); vertex._run()``, re-arming between
graphs, so only the first run pays the spawn.  Workers whose graph
failed or timed out are terminated and replaced; clean workers return to
the pool.  Opt out per program (``lower(skel, "procs", pool=False)``)
or globally (``REPRO_PROCS_POOL=0``).

Constraints of the process world (all spawn-start-method induced):

* nodes, payloads and scheduling policies must be **picklable** —
  module-level functions, ``functools.partial``, or ``ff_node``
  subclasses; lambdas and closures are rejected at ``run()`` with a
  :class:`~repro.core.skeleton.LoweringError`;
* ``speculative=`` straggler re-issue is threads-only (its tag bookkeeping
  is cross-arbiter shared state), rejected at lowering;
* ``Farm.stats`` is updated *after* the run (merged snapshot), not live.

The start method defaults to ``spawn`` (fork would duplicate JAX/XLA
runtime threads); override with ``REPRO_PROCS_START`` if you must.
"""
from __future__ import annotations

import atexit
import os
import pickle
import time
import multiprocessing as mp
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

from .obs import VertexTracer, farm_stats_snapshot, qualname as _qualname
from .sched import Scheduler, make_scheduler
from .shm import ShmCounters, ShmFlag, ShmRing
from .skeleton import (BACKENDS, GO_ON, AllToAll, EmitMany, Farm, FarmStats,
                       Feedback, KeyBatch, LoweringError, Pipeline, Skeleton,
                       Source, Stage, _FarmEmitMany, _coerce_metrics,
                       _coerce_monitor, _coerce_tracer, _has_grained_stage,
                       as_skeleton, ff_node, fuse as _fuse_pass, walk_stats)
from .spsc import EOS, SPSCQueue

__all__ = [
    "ProcGraph", "ProcVertex", "ProcStageVertex", "ProcDispatchVertex",
    "ProcWorkerVertex", "ProcMergeVertex", "build", "ProcProgram",
    "ProcAccelerator", "pool_stats", "pool_shutdown",
]

_EMPTY = SPSCQueue._EMPTY
_POLL = 0.000_05          # poll backoff (matches the SPSC blocking helpers)
_BATCH = 256              # max items drained per ring per arbiter wake-up
_ENTERED, _RETIRED = 0, 1  # quiescence-board slots (see ShmCounters)


# Wire format: a farm token is a plain ``(tag, issued_at, payload)`` tuple,
# not graph.py's Token dataclass — a tuple pickles in a third of the bytes
# and time, and the procs backend has no speculation, so the ``duplicate``
# flag would be dead weight on every hop.  ``issued_at`` is 0.0 except on a
# 1-in-16 latency sample: clock reads are syscalls, expensive under
# sandboxed kernels, and the latency reservoir only needs a sample.
_LAT_SAMPLE = 15  # tag & _LAT_SAMPLE == 0 -> stamp and measure


class _WorkerStats:
    """A worker's final telemetry, sent down its own data ring just before
    it acknowledges EOS — the single-writer way to get worker-side numbers
    (the service-time EWMA) into the merge arbiter's FarmStats without any
    shared object."""

    __slots__ = ("index", "ewma")

    def __init__(self, index: int, ewma: Optional[float]):
        self.index = index
        self.ewma = ewma


def _start_ctx():
    return mp.get_context(os.environ.get("REPRO_PROCS_START", "spawn"))


class _Aborted(Exception):
    """Internal: this vertex gave up because another vertex already failed
    (its peer may be dead and its ring full — blocking would hang)."""


class _Backoff:
    """Adaptive idle backoff: 50µs doubling to 1ms while nothing moves.

    The thread backend can poll at a fixed 50µs because a sleeping thread
    is nearly free; here every vertex is a *process* competing for the
    same cores as the workers, and on a small machine every arbiter
    wake-up is a context switch that preempts a worker mid-task (markedly
    expensive under sandboxed kernels, where ``sleep(50µs)`` rounds up to
    ~1ms anyway).  Doubling the sleep caps the idle wake rate at ~200/s
    per vertex while bounding added latency at 5ms — noise against any
    grain worth sending to a process farm, and the arbiters batch-drain
    their rings per wake (``_BATCH``) so throughput never rides on the
    wake rate.

    AIMD, not reset-to-floor: progress *halves* the delay, idleness
    doubles it.  A full reset on every popped token would pin a collector
    at the maximum wake rate whenever results trickle in one at a time —
    exactly the steady state of a coarse-grain farm — while halving
    converges the wake rate to ~2× the arrival rate and lets the batch
    drain do the rest."""

    __slots__ = ("delay",)
    _CAP = 0.005

    def __init__(self):
        self.delay = _POLL

    def reset(self) -> None:
        self.delay = max(self.delay / 2, _POLL)

    def idle(self) -> None:
        time.sleep(self.delay)
        self.delay = min(self.delay * 2, self._CAP)


def _vertex_main(vertex: "ProcVertex") -> None:
    """Child-process entry point (module-level: spawn pickles by name)."""
    vertex._run()


class _CtlRing:
    """Vertex-side endpoint of the control ring (vertex → caller).

    Wraps the ring behind a ``put()`` so vertex code keeps its queue-ish
    control surface; the ring never legitimately fills (≤ 3 messages per
    vertex against capacity 8: ready, an optional error, an optional
    EOS-time trace ship-back), so a timeout here means the caller is gone
    and the message is dropped rather than wedging teardown."""

    __slots__ = ("_ring",)

    def __init__(self, ring: ShmRing):
        self._ring = ring

    def put(self, msg: Tuple) -> None:
        self._ring.push_wait(msg, timeout=10.0)


# ---------------------------------------------------------------------------
# the spawn pool: reusable vertex-host processes, one pool per start method
# ---------------------------------------------------------------------------
def _pool_main(jobq, doneq) -> None:
    """Pooled vertex-host: run one vertex per job, then re-arm.  The spawn
    and import cost is paid once per *process*, not once per run."""
    base_cpus = None
    if hasattr(os, "sched_getaffinity"):
        try:
            base_cpus = os.sched_getaffinity(0)
        except OSError:  # pragma: no cover - exotic kernels
            pass
    while True:
        vertex = jobq.get()
        if vertex is None:
            return
        try:
            _vertex_main(vertex)
        finally:
            if base_cpus is not None and vertex.cpus:
                try:  # undo the vertex's pin: the next job chooses its own
                    os.sched_setaffinity(0, base_cpus)
                except OSError:  # pragma: no cover
                    pass
            vertex = None  # drop ring attachments before signalling done
            doneq.put(True)


class _PoolWorker:
    """One leased process: a job queue in, a done-token queue out."""

    __slots__ = ("jobq", "doneq", "proc", "busy")

    def submit(self, vertex: "ProcVertex") -> None:
        # SimpleQueue.put pickles synchronously in THIS thread — an
        # unpicklable vertex raises here, before any bytes hit the pipe,
        # so the worker stays clean and reusable
        self.jobq.put(vertex)
        self.busy = True

    def poll_done(self) -> bool:
        if self.busy:
            while not self.doneq.empty():
                self.doneq.get()
                self.busy = False
        return not self.busy


class _ProcPool:
    """Reusable spawned processes for one start method.

    ``acquire`` hands out an idle worker (or spawns one), ``release``
    parks it for the next graph.  Workers are generic vertex hosts — a
    process that ran a farm worker last graph may run a merge arbiter in
    the next — so the pool needs no shape bookkeeping, only liveness."""

    MAX_IDLE = 12  # parked interpreters cost memory; beyond this, retire

    def __init__(self, ctx):
        self._ctx = ctx
        self._idle: List[_PoolWorker] = []
        self.spawned = 0  # telemetry: processes ever started
        self.reused = 0   # telemetry: acquisitions that skipped a spawn

    def acquire(self) -> _PoolWorker:
        while self._idle:
            w = self._idle.pop()
            if w.proc.is_alive():
                self.reused += 1
                return w
            self.discard(w)
        w = _PoolWorker()
        w.jobq = self._ctx.SimpleQueue()
        w.doneq = self._ctx.SimpleQueue()
        w.busy = False
        self.spawned += 1
        w.proc = self._ctx.Process(target=_pool_main,
                                   args=(w.jobq, w.doneq),
                                   name=f"ff-pool-{self.spawned}",
                                   daemon=True)
        w.proc.start()
        return w

    def release(self, w: _PoolWorker) -> None:
        if w.proc.is_alive() and not w.busy \
                and len(self._idle) < self.MAX_IDLE:
            self._idle.append(w)
        else:
            self.discard(w)

    def discard(self, w: _PoolWorker) -> None:
        try:
            if w.proc.is_alive() and not w.busy:
                w.jobq.put(None)  # polite: let the loop return
                w.proc.join(0.5)
        except Exception:  # pragma: no cover - pipes may already be gone
            pass
        if w.proc.is_alive():
            w.proc.terminate()
            w.proc.join(5.0)
        for q in (w.jobq, w.doneq):
            try:
                q.close()
            except Exception:  # pragma: no cover
                pass

    def shutdown(self) -> None:
        while self._idle:
            self.discard(self._idle.pop())


_POOLS: Dict[str, _ProcPool] = {}


def _get_pool(ctx) -> _ProcPool:
    key = ctx.get_start_method()
    pool = _POOLS.get(key)
    if pool is None:
        pool = _POOLS[key] = _ProcPool(ctx)
    return pool


def _pool_enabled(pool: Optional[bool]) -> bool:
    if pool is not None:
        return pool
    return os.environ.get("REPRO_PROCS_POOL", "1") != "0"


def pool_stats() -> Dict[str, Dict[str, int]]:
    """Spawn-pool telemetry per start method (spawned/reused/idle)."""
    return {k: {"spawned": p.spawned, "reused": p.reused,
                "idle": len(p._idle)}
            for k, p in _POOLS.items()}


def pool_shutdown() -> None:
    """Retire every idle pooled worker (tests and interpreter exit)."""
    for pool in _POOLS.values():
        pool.shutdown()


atexit.register(pool_shutdown)


# ---------------------------------------------------------------------------
# vertices: one spawned process each, private ShmRing endpoints
# ---------------------------------------------------------------------------
class ProcVertex:
    """A network vertex: one process, private shared-memory SPSC endpoints.

    ``failed`` (:class:`ShmFlag`) and ``ctl`` (:class:`_CtlRing`) are
    attached by :meth:`ProcGraph.add` — the control plane.  Both pickle
    as segment attaches, so a vertex travels equally well through
    ``Process`` args (direct spawn) and a pool worker's job queue.
    ``cpus`` is an optional placement hint (see ``Scheduler.worker_cpus``)
    applied best-effort on entry and undone by the pool between jobs.
    """

    def __init__(self, node: Optional[ff_node] = None, *,
                 name: str = "ff-pvertex"):
        self.node = node
        self.name = name
        # batch-aware nodes (SpillFold) take a whole KeyBatch in one svc
        # call; everyone else gets it unpacked by the vertex loop
        self._takes_batches = bool(getattr(node, "accepts_batches", False))
        self.ins: List[ShmRing] = []
        self.outs: List[ShmRing] = []
        self.failed: Any = None   # ShmFlag, set by ProcGraph.add
        self.ctl: Any = None      # _CtlRing, set by ProcGraph.add
        self.cpus: Optional[Tuple[int, ...]] = None
        # observability: ``path`` is the IR path assigned by build();
        # trace config travels as plain ints (picklable through spawn and
        # the pool job queue) — the VertexTracer itself is built child-
        # side in _run() and shipped back over the control ring at EOS
        self.path = ""
        self.trace_sample = 0     # 0 = tracing off
        self.trace_capacity = 0
        self.tracer: Optional[VertexTracer] = None

    # -- lifecycle (runs in the vertex's own process) -----------------------
    def _run(self) -> None:
        t_birth = 0.0
        try:
            if self.trace_sample:
                self.tracer = VertexTracer(self.name, self.path,
                                           sample=self.trace_sample,
                                           capacity=self.trace_capacity)
                t_birth = time.monotonic()
                if self.node is not None and \
                        getattr(self.node, "wants_tracer", False):
                    self.node.tracer = self.tracer
            if self.cpus:
                try:
                    os.sched_setaffinity(0, self.cpus)
                except (AttributeError, OSError):  # hint only: never fatal
                    pass
            if self.node is not None:
                self.node.svc_init()
            self.ctl.put(("ready", self.name))
            self._loop()
        except _Aborted:
            pass  # secondary shutdown; the original error is on the ctl queue
        except BaseException as e:
            self._report_error(e)
        finally:
            for q in self.outs:
                self._push_abortable(q, EOS)
            if self.node is not None:
                try:
                    self.node.svc_end()
                except BaseException as e:  # pragma: no cover - defensive
                    self._report_error(e)
            tr = self.tracer
            if tr is not None:
                tr.instant("eos")
                tr.span("life", t_birth, time.monotonic())
                try:  # ship the lane home; best-effort at teardown
                    self.ctl.put(("trace", self.name, self.path,
                                  os.getpid(), tr.events, tr.dropped))
                except Exception:  # pragma: no cover - caller gone
                    pass
            self._flush_stats()
            for q in self.ins + self.outs:
                q.close()

    def _report_error(self, e: BaseException) -> None:
        self.failed.set()
        # the control ring pickles synchronously in put(), so an
        # unpicklable exception would raise mid-report and LOSE the
        # message — probe first and degrade to the repr
        try:
            pickle.dumps(e)
        except Exception:
            self.ctl.put(("error", self.name, repr(e), None))
        else:
            self.ctl.put(("error", self.name, repr(e), e))

    def _flush_stats(self) -> None:
        """Hook: arbiters surface their stats snapshots at shutdown."""

    def _loop(self) -> None:
        raise NotImplementedError

    def _push_abortable(self, q: ShmRing, item: Any) -> bool:
        """Blocking push that gives up once the graph has failed (the
        ring's consumer may be dead; blocking would hang the teardown)."""
        spins = 0
        while not q.push(item):
            spins += 1
            if spins > 64:
                if self.failed.is_set():
                    return False
                time.sleep(_POLL)
        return True

    def _deliver(self, payload: Any) -> None:
        if not self._push_abortable(self.outs[0], payload):
            raise _Aborted()


class ProcStageVertex(ProcVertex):
    """Generic vertex: nondeterministic fan-in merge, single-out.  With no
    inbound edges it is a *source*: ``svc(None)`` until ``None`` (EOS) —
    paper Fig. 2's emitter protocol, same as ``graph.StageVertex``.

    ``batch > 1`` turns on the batched-emit wire format: outputs gather
    in a local buffer and ship ``batch`` at a time through
    :meth:`ShmRing.push_many` — one slot header and one tail store per
    run of items instead of per item, which is what lets fine-grain
    streams amortize the per-hop cost.  The buffer is flushed after the
    node's EOS hook and *before* the EOS sentinel leaves this vertex, so
    stream ordering (including the eosnotify release of keyed folds) is
    byte-identical to the unbatched wire."""

    def __init__(self, node: ff_node, *, name: str = "ff-pstage",
                 batch: int = 1):
        super().__init__(node, name=name)
        self.batch = max(1, int(batch))
        self._obuf: List[Any] = []

    def _deliver(self, payload: Any) -> None:
        if self.batch <= 1:
            super()._deliver(payload)
            return
        self._obuf.append(payload)
        if len(self._obuf) >= self.batch:
            self._flush_batch()

    def _flush_batch(self) -> None:
        buf = self._obuf
        if not buf:
            return
        out = self.outs[0]
        backoff = _Backoff()
        i = 0
        while i < len(buf):
            n = out.push_many(buf[i:] if i else buf)
            if n:
                i += n
                continue
            if self.failed.is_set():
                self._obuf = []
                raise _Aborted()
            backoff.idle()
        self._obuf = []

    def _loop(self) -> None:
        tr = self.tracer
        if not self.ins:  # source
            while True:
                if tr is not None:
                    t0 = tr.begin()
                    out = self.node.svc(None)
                    tr.end(t0, "svc")
                else:
                    out = self.node.svc(None)
                if out is None or out is EOS:
                    break
                if out is GO_ON:
                    continue
                self._emit(out)
            self._flush_eos()
            self._flush_batch()
            return
        eos: set = set()
        backoff = _Backoff()
        while len(eos) < len(self.ins):
            progress = False
            for i, q in enumerate(self.ins):
                if i in eos:
                    continue
                # batch-drain: a sleeping process pays ~1ms to wake, so one
                # wake must move everything the ring has (bounded, for
                # fairness across inbound edges)
                for _ in range(_BATCH):
                    item = q.pop()
                    if item is _EMPTY:
                        break
                    progress = True
                    if item is EOS:
                        eos.add(i)
                        break
                    if type(item) is KeyBatch and not self._takes_batches:
                        # batched wire format: unpack here so the node
                        # still sees items (batching is transport only)
                        for x in item:
                            if tr is not None:
                                t0 = tr.begin()
                                out = self.node.svc(x)
                                tr.end(t0, "svc")
                            else:
                                out = self.node.svc(x)
                            if out is None or out is GO_ON:
                                continue
                            self._emit(out)
                        continue
                    if tr is not None:
                        t0 = tr.begin()
                        out = self.node.svc(item)
                        tr.end(t0, "svc")
                    else:
                        out = self.node.svc(item)
                    if out is None or out is GO_ON:
                        continue  # filtered
                    self._emit(out)
            if progress:
                backoff.reset()
            else:
                if self.failed.is_set():
                    raise _Aborted()
                # nothing inbound: ship the partial batch rather than
                # holding the stream's tail hostage to the batch size
                self._flush_batch()
                backoff.idle()
        self._flush_eos()
        self._flush_batch()

    def _flush_eos(self) -> None:
        """EOS flush (eosnotify), mirroring ``graph.StageVertex``: the node
        may emit buffered state into the stream before this vertex's EOS
        goes out — keyed folds and window operators release here."""
        out = self.node.svc_eos()
        if out is not None and out is not GO_ON:
            self._emit(out)

    def _emit(self, out: Any) -> None:
        if type(out) is KeyBatch:  # one wire message; consumers unpack
            if out:
                self._deliver(out)
            return
        if isinstance(out, EmitMany):  # multi-emit (e.g. a reorder flush)
            for o in out:
                self._emit(o)
            return
        self._deliver(out)


class ProcDispatchVertex(ProcVertex):
    """The farm's Emitter arbiter as a process (paper Figs. 1-2).

    Drives the same pluggable :class:`~repro.core.sched.Scheduler` policy
    hierarchy as the thread backend — the policy object (and all its
    state: worksteal backlogs, costmodel EWMAs) lives entirely in this
    arbiter's process, so the single-writer discipline is untouched.
    Worker side-channels (worksteal idle rings, costmodel service-EWMA
    rings) are ShmRings, drained here.  When ``loop_ring`` is set this
    vertex is the loop master: quiescence reads the merge arbiter's
    ``retired`` counter off the shared :class:`ShmCounters` board.
    """

    def __init__(self, sched: Scheduler, node: Optional[ff_node] = None, *,
                 loop_ring: Optional[ShmRing] = None,
                 loop_board: Optional[ShmCounters] = None,
                 service_rings: Optional[List[ShmRing]] = None,
                 stats_out: Optional[ShmRing] = None,
                 live_board: Optional[ShmCounters] = None,
                 name: str = "ff-emitter"):
        super().__init__(node, name=name)
        self.sched = sched
        self.loop_ring = loop_ring
        self.loop_board = loop_board
        self.live_board = live_board  # monitor tap: slot 0 = emitted
        self.service_rings = service_rings or []
        self.stats_out = stats_out  # dispatch -> merge stats hand-off
        self.stats = FarmStats()
        self._next_tag = 0
        self._entered = 0
        self._stash: List[Any] = []

    def _drain_service(self) -> None:
        """Fold worker service-EWMA updates into the policy's stats (the
        cross-process replacement for workers writing ``FarmStats``
        directly — arbiter-side state stays in the arbiter process)."""
        for ring in self.service_rings:
            while True:
                upd = ring.pop()
                if upd is _EMPTY:
                    break
                self.sched.observe_service(upd[0], upd[1])

    def _push_with_loop_drain(self, q: ShmRing, tok: tuple) -> None:
        """Blocking push that keeps draining the wrap-around ring while
        the target worker ring is full (breaks cyclic backpressure, same
        argument as ``graph.DispatchVertex._push_with_loop_drain``)."""
        if q.push(tok):
            return  # fast path: no stall, no clock read
        tr = self.tracer
        t0 = time.monotonic() if tr is not None else 0.0
        spins = 0
        while not q.push(tok):
            if self.loop_ring is not None:
                item = self.loop_ring.pop()
                if item is not _EMPTY:
                    self._stash.append(item)
                    continue
            spins += 1
            if spins > 64:
                if self.failed.is_set():
                    raise _Aborted()
                time.sleep(_POLL)
        if tr is not None:
            tr.span("stall", t0, time.monotonic())

    def _emit_to(self, widx: int, tok: tuple) -> None:
        self._push_with_loop_drain(self.outs[widx], tok)

    def _dispatch(self, task: Any) -> None:
        tag = self._next_tag
        issued = time.monotonic() if tag & _LAT_SAMPLE == 0 else 0.0
        tok = (tag, issued, task)
        self._next_tag += 1
        if self.loop_board is not None:
            self._entered += 1
            self.loop_board.add(_ENTERED, 1)
        self.sched.place(tok, self._emit_to)
        self.stats.tasks_emitted += 1
        if self.live_board is not None:
            self.live_board.add(0, 1)  # single writer: this arbiter only
        # backpressure for token-holding policies (worksteal): stop intake
        # while the policy backlog is over its high-water mark
        hw = self.sched.high_water
        if hw is not None and self.sched.pending() > hw:
            tr = self.tracer
            t0 = time.monotonic() if tr is not None else 0.0
            spins = 0
            while self.sched.pending() > hw:
                if self.sched.pump():
                    continue
                if self.failed.is_set():
                    raise _Aborted()
                if self.loop_ring is not None:
                    item = self.loop_ring.pop()
                    if item is not _EMPTY:
                        self._stash.append(item)
                        continue
                spins += 1
                if spins > 64:
                    time.sleep(_POLL)
            if tr is not None:
                tr.span("stall", t0, time.monotonic())

    def _quiescent(self) -> bool:
        """entered == retired and the wrap-around ring is drained.  Read
        order matters: ``retired`` first, then the ring — the merge
        arbiter pushes looped-back tasks *before* bumping ``retired``."""
        retired = self.loop_board.get(_RETIRED)
        return self._entered == retired and self.loop_ring.empty()

    def _loop(self) -> None:
        self.sched.bind(self.outs, self.stats)
        tr = self.tracer
        steals0 = self.stats.steals if tr is not None else 0
        backoff = _Backoff()
        if self.node is not None and not self.ins:
            # source mode: the emitter node generates the stream
            while True:
                self._drain_service()
                if tr is not None:
                    t0 = tr.begin()
                    task = self.node.svc(None)
                    tr.end(t0, "svc")
                else:
                    task = self.node.svc(None)
                if task is None or task is EOS:
                    break
                if task is GO_ON:
                    continue
                self._dispatch(task)
                self.sched.pump()
                if tr is not None and self.stats.steals != steals0:
                    tr.instant("steal",
                               {"count": self.stats.steals - steals0})
                    steals0 = self.stats.steals
                if self.loop_ring is not None:
                    while True:
                        item = self.loop_ring.pop()
                        if item is _EMPTY:
                            break
                        self._dispatch(item)
                        if tr is not None:
                            tr.tick("loop")
            # source exhausted; drain the loop to quiescence
            while self.loop_ring is not None:
                progress = self.sched.pump()
                while self._stash:
                    self._dispatch(self._stash.pop(0))
                    progress = True
                while True:
                    item = self.loop_ring.pop()
                    if item is _EMPTY:
                        break
                    progress = True
                    self._dispatch(item)
                    if tr is not None:
                        tr.tick("loop")
                if not self._stash and not self.sched.pending() \
                        and self._quiescent():
                    break
                if self.failed.is_set():
                    raise _Aborted()
                if progress:
                    backoff.reset()
                elif self.sched.pending():
                    time.sleep(0)  # yield: the policy still holds tokens
                else:
                    backoff.idle()
        else:
            eos: set = set()
            while True:
                progress = self.sched.pump()
                self._drain_service()
                if tr is not None and self.stats.steals != steals0:
                    tr.instant("steal",
                               {"count": self.stats.steals - steals0})
                    steals0 = self.stats.steals
                # wrap-around tokens first: looped-back work is older
                while self._stash:
                    self._dispatch(self._stash.pop(0))
                    progress = True
                if self.loop_ring is not None:
                    while True:
                        item = self.loop_ring.pop()
                        if item is _EMPTY:
                            break
                        progress = True
                        self._dispatch(item)
                        if tr is not None:
                            tr.tick("loop")
                for i, q in enumerate(self.ins):
                    if i in eos:
                        continue
                    for _ in range(_BATCH):  # amortise the wake-up cost
                        item = q.pop()
                        if item is _EMPTY:
                            break
                        progress = True
                        if item is EOS:
                            eos.add(i)
                            break
                        if self.node is not None:
                            # emitter node as per-item scheduler/filter
                            if tr is not None:
                                t0 = tr.begin()
                                item = self.node.svc(item)
                                tr.end(t0, "svc")
                            else:
                                item = self.node.svc(item)
                            if item is None or item is GO_ON:
                                continue
                        self._dispatch(item)
                if len(eos) == len(self.ins) and not self._stash \
                        and not self.sched.pending():
                    if self.loop_ring is None or self._quiescent():
                        break
                if self.failed.is_set():
                    raise _Aborted()  # a vertex died: no quiescence possible
                if progress:
                    backoff.reset()
                elif self.sched.pending():
                    time.sleep(0)  # yield: the policy still holds tokens
                else:
                    backoff.idle()
        # flush tokens still held by the policy (worksteal backlogs)
        # before the EOS goes out behind them
        while self.sched.pending():
            if self.failed.is_set():
                raise _Aborted()
            if not self.sched.pump():
                time.sleep(0)

    def _flush_stats(self) -> None:
        # hand the dispatch-side counters to the merge arbiter, which owns
        # the farm's merged FarmStats snapshot (SPSC: one producer, one
        # consumer; the data rings to the workers are already EOS'd)
        if self.stats_out is not None:
            self.stats_out.push_wait(self.stats, timeout=2.0)
            self.stats_out.close()


class ProcWorkerVertex(ProcVertex):
    """Farm worker process: one inbound and one outbound ring, tags carried
    through untouched.  With an ``idle_ring`` (worksteal) it advertises
    idleness to the arbiter; with a ``service_ring`` (costmodel) it streams
    its service-time EWMA back — both SPSC ShmRings, worker → arbiter."""

    def __init__(self, node: ff_node, index: int, *,
                 idle_ring: Optional[ShmRing] = None,
                 service_ring: Optional[ShmRing] = None,
                 name: str = "ff-worker"):
        super().__init__(node, name=name)
        self.index = index
        self.idle_ring = idle_ring
        self.service_ring = service_ring

    def _loop(self) -> None:
        q_in, q_out = self.ins[0], self.outs[0]
        tr = self.tracer
        record = self.service_ring is not None
        ewma: Optional[float] = None
        backoff = _Backoff()
        signaled = False
        spins = 0
        while True:
            tok = q_in.pop()
            if tok is _EMPTY:
                if self.idle_ring is not None and \
                        (not signaled or spins % 512 == 511):
                    # steal side-channel: advertise idleness (re-advertise
                    # periodically — a signal consumed while the arbiter
                    # had nothing to give must not strand this worker)
                    signaled = self.idle_ring.push(self.index) or signaled
                spins += 1
                if spins > 64:
                    if self.failed.is_set():
                        raise _Aborted()
                    backoff.idle()
                continue
            signaled = False
            spins = 0
            backoff.reset()
            if tok is EOS:
                if record:
                    self._push_abortable(q_out, _WorkerStats(self.index, ewma))
                return
            tag, issued, payload = tok
            tb = tr.begin() if tr is not None else 0.0
            if record:
                t0 = time.monotonic()
                result = self.node.svc(payload)
                dt = time.monotonic() - t0
                ewma = dt if ewma is None else 0.8 * ewma + 0.2 * dt
                self.service_ring.push((self.index, ewma))  # drop-if-full ok
            else:
                result = self.node.svc(payload)
            if tr is not None:
                tr.end(tb, "svc")
            if not self._push_abortable(q_out, (tag, issued, result)):
                raise _Aborted()


class ProcMergeVertex(ProcVertex):
    """The farm's Collector arbiter as a process (paper Figs. 1-2).

    Optional reorder-by-tag (``ordered``), optional collector node,
    optional wrap-around routing (``feedback``), as in
    ``graph.MergeVertex`` — minus the dedup-by-tag bookkeeping: the procs
    backend rejects speculation at lowering, so duplicates are impossible
    by construction and a per-tag seen-dict would only be an unbounded
    leak in a long-lived farm.  Owns the farm's merged :class:`FarmStats`:
    collects its own side, folds in the dispatch side from the ``d2m``
    stats ring at EOS, and surfaces the snapshot to the calling process
    over the farm's stats ring."""

    def __init__(self, node: Optional[ff_node] = None, *,
                 ordered: bool = False,
                 loop_ring: Optional[ShmRing] = None,
                 loop_board: Optional[ShmCounters] = None,
                 feedback: Optional[Callable[[Any], Tuple[Any, Iterable[Any]]]] = None,
                 stats_in: Optional[ShmRing] = None,
                 stats_out: Optional[ShmRing] = None,
                 live_board: Optional[ShmCounters] = None,
                 name: str = "ff-collector"):
        super().__init__(node, name=name)
        self.ordered = ordered
        self.loop_ring = loop_ring
        self.loop_board = loop_board
        self.live_board = live_board  # monitor tap: slot 1 = collected
        self.feedback = feedback
        self.stats_in = stats_in    # dispatch -> merge counter hand-off
        self.stats_out = stats_out  # merge -> caller snapshot
        self.stats = FarmStats()

    def _loop(self) -> None:
        st = self.stats
        eos: set = set()
        next_tag = 0
        reorder: Dict[int, Any] = {}
        backoff = _Backoff()
        while len(eos) < len(self.ins):
            progress = False
            for i, q in enumerate(self.ins):
                if i in eos:
                    continue
                for _ in range(_BATCH):  # amortise the wake-up cost
                    tok = q.pop()
                    if tok is _EMPTY:
                        break
                    progress = True
                    if tok is EOS:
                        eos.add(i)
                        break
                    if isinstance(tok, _WorkerStats):
                        if tok.ewma is not None:
                            st.service_ewma[tok.index] = tok.ewma
                        continue
                    tag, issued, payload = tok
                    st.tasks_collected += 1
                    if self.live_board is not None:
                        self.live_board.add(1, 1)  # single writer: merge only
                    st.per_worker[i] = st.per_worker.get(i, 0) + 1
                    if issued:
                        st.latencies.append(time.monotonic() - issued)
                    if self.ordered:
                        reorder[tag] = payload
                        while next_tag in reorder:
                            self._complete(reorder.pop(next_tag))
                            next_tag += 1
                    else:
                        self._complete(payload)
            if progress:
                backoff.reset()
            else:
                if self.failed.is_set():
                    raise _Aborted()
                backoff.idle()
        # flush any residue (can only happen if tags were skipped upstream)
        for t in sorted(reorder):
            self._complete(reorder.pop(t))

    def _complete(self, payload: Any) -> None:
        if payload is GO_ON:
            self._retire()
            return
        tr = self.tracer
        if self.node is not None:
            if tr is not None:
                t0 = tr.begin()
                payload = self.node.svc(payload)
                tr.end(t0, "svc")
            else:
                payload = self.node.svc(payload)
            if payload is None or payload is GO_ON:
                self._retire()
                return
        if self.feedback is not None:
            emit, new_tasks = self.feedback(payload)
            # push wrap-around tasks BEFORE retiring the token: the
            # dispatch arbiter's quiescence check relies on this ordering
            # (now across processes, on x86-TSO store order).
            for t in new_tasks:
                if not self._push_abortable(self.loop_ring, t):
                    raise _Aborted()
                if tr is not None:
                    tr.tick("loop")
            self._retire()
            if emit is None:
                return
            payload = emit
        else:
            self._retire()
        if isinstance(payload, _FarmEmitMany):
            for p in payload:
                self._deliver(p)
            return
        self._deliver(payload)

    def _retire(self) -> None:
        if self.loop_board is not None:
            self.loop_board.add(_RETIRED, 1)

    def _flush_stats(self) -> None:
        if self.stats_in is not None:
            # fold the dispatch side in (it flushes right after EOS'ing
            # the workers, so it is normally already here)
            disp = self.stats_in.pop_wait(timeout=2.0)
            if disp is not _EMPTY and isinstance(disp, FarmStats):
                _fold_stats(self.stats, disp)
            self.stats_in.close()
        if self.stats_out is not None:
            self.stats_out.push_wait(self.stats, timeout=2.0)
            self.stats_out.close()


def _fold_stats(dst: FarmStats, src: FarmStats) -> None:
    """Merge one FarmStats snapshot into another (disjoint writers: each
    counter was filled by exactly one arbiter/worker, so += is exact)."""
    dst.tasks_emitted += src.tasks_emitted
    dst.tasks_collected += src.tasks_collected
    dst.duplicates_issued += src.duplicates_issued
    dst.duplicates_dropped += src.duplicates_dropped
    dst.steals += src.steals
    dst.spills += src.spills
    dst.spill_bytes += src.spill_bytes
    dst.backpressure_stalls += src.backpressure_stalls
    for k, v in src.per_worker.items():
        dst.per_worker[k] = dst.per_worker.get(k, 0) + v
    dst.service_ewma.update(src.service_ewma)
    for x in src.latencies:
        dst.latencies.append(x)
    dst.worker_failures.extend(src.worker_failures)


# ---------------------------------------------------------------------------
# the graph: spawned vertices + shared-memory edges, driven by the caller
# ---------------------------------------------------------------------------
class ProcGraph:
    """A streaming network of processes over shared-memory SPSC rings.

    Mirrors :class:`graph.Graph`'s API (``add``/``connect``/``run``/
    ``wait``) with process semantics: the caller is the single consumer of
    the results ring, errors arrive over per-vertex control rings, and
    ``wait`` tears everything down — returns pooled workers (or joins /
    terminates direct-spawned ones) and unlinks every shared-memory
    segment, so no run leaks processes or ``/dev/shm`` entries.

    ``zero_copy`` flows to every edge ring (typed buffer-protocol slots);
    ``batch`` turns on batched emit for stage vertices — ``None`` off,
    an int for a global batch size, or ``"grain"`` to read each stage's
    declared ``grain=`` as its batch size; ``pool`` selects spawn-pool
    reuse (default: on unless ``REPRO_PROCS_POOL=0``)."""

    def __init__(self, *, capacity: int = 512, slot_size: int = 248,
                 zero_copy: bool = True, batch: Any = None,
                 pool: Optional[bool] = None):
        self.capacity = capacity
        self.slot_size = slot_size
        self.zero_copy = zero_copy
        self.batch = batch
        self._ctx = _start_ctx()
        self._pool = _get_pool(self._ctx) if _pool_enabled(pool) else None
        self.vertices: List[ProcVertex] = []
        self.results: List[Any] = []
        self.failed: List[BaseException] = []
        self._rings: List[Any] = []          # every segment, for unlink
        self.failed_event = ShmFlag()
        self._rings.append(self.failed_event)
        self._ctl_rings: List[ShmRing] = []  # one per vertex, vertex->caller
        self._procs: List[Any] = []
        self._pool_workers: List[_PoolWorker] = []
        self._farm_stats: List[Tuple[Farm, ShmRing]] = []
        # post-run hooks (builders register them): read telemetry boards
        # back into the IR node's stats BEFORE shared memory is unlinked
        self.finalizers: List[Callable[[], None]] = []
        self._results_rings: List[ShmRing] = []
        self._eos_rings: set = set()
        self._eos_seen = False
        self._ready = 0
        self._cleaned = False
        # observability: when set (obs.Tracer), run() hands each vertex
        # its sampling config; lanes come home over the control rings at
        # EOS and are absorbed here (caller side) by _on_ctl
        self.tracer = None
        # live monitoring: when live_telemetry is set before build(), each
        # farm gets a 2-slot single-writer ShmCounters board (slot 0 =
        # emitted by the dispatch arbiter, slot 1 = collected by the merge
        # arbiter) registered here by farm qualname — the Monitor reads
        # them caller-side with peek(), no ring traffic
        self.live_telemetry = False
        self.live_boards: Dict[str, ShmCounters] = {}

    # -- construction -------------------------------------------------------
    def channel(self, capacity: Optional[int] = None,
                slot_size: Optional[int] = None) -> ShmRing:
        ring = ShmRing(capacity or self.capacity,
                       slot_size or self.slot_size,
                       zero_copy=self.zero_copy)
        self._rings.append(ring)
        return ring

    def counters(self, n: int = 2) -> ShmCounters:
        board = ShmCounters(n)
        self._rings.append(board)
        return board

    def batch_for(self, grain: Optional[int]) -> int:
        """Resolve the effective emit-batch size for a stage declaring
        ``grain`` (1 = unbatched; see the class docstring)."""
        if self.batch is None:
            return 1
        if self.batch == "grain":
            return int(grain) if grain else 1
        return max(1, int(self.batch))

    def sample_high_water(self, into: Dict[str, int]) -> Dict[str, int]:
        """Profile tap, mirroring :meth:`graph.Graph.sample_high_water`:
        record each vertex's current outbound queue depth into ``into``,
        keeping the per-name maximum across calls.  The caller owns the
        ring segments, so ``len()`` (a read of the shared head/tail
        counters) works cross-process without touching the stream.  Keys
        are IR-path qualified (``name@path``), mirroring the threads
        backend, so merged reports cannot collide."""
        for v in self.vertices:
            depth = 0
            for ring in v.outs:
                try:
                    depth = max(depth, len(ring))
                except (TypeError, OSError, ValueError):
                    pass  # ValueError: memoryview released mid-teardown
            key = _qualname(v.name, v.path)
            if depth > into.get(key, -1):
                into[key] = depth
        return into

    def sample_depths(self, into: Dict[str, int]) -> Dict[str, int]:
        """Live-monitor tap, mirroring :meth:`graph.Graph.sample_depths`:
        the *instantaneous* outbound depth per vertex (overwrite
        semantics — one call = one timeline frame).  Safe against the
        monitor thread racing ``_cleanup()``: a ring whose segment is
        already unlinked reads as depth 0, never raises."""
        for v in self.vertices:
            depth = 0
            for ring in v.outs:
                try:
                    depth = max(depth, len(ring))
                except (TypeError, OSError, ValueError):
                    pass
            into[_qualname(v.name, v.path)] = depth
        return into

    def add(self, v: ProcVertex) -> ProcVertex:
        v.failed = self.failed_event
        # control edge: SPSC (this vertex produces, the caller consumes);
        # plain pickle — identity and fidelity over speed off the data path
        ring = ShmRing(8, 512, zero_copy=False)
        self._rings.append(ring)
        self._ctl_rings.append(ring)
        v.ctl = _CtlRing(ring)
        self.vertices.append(v)
        return v

    def connect(self, src: ProcVertex, dst: ProcVertex, *,
                capacity: Optional[int] = None) -> ShmRing:
        ring = self.channel(capacity)
        src.outs.append(ring)
        dst.ins.append(ring)
        return ring

    def results_ring(self) -> ShmRing:
        """A terminal edge: produced by ONE sink vertex, consumed by the
        calling process (SPSC discipline includes the caller).  Every call
        creates a fresh ring — a network with several sinks (the right row
        of a terminal all-to-all) gets one ring per sink, each
        single-producer, and the caller drains them all; the stream is
        complete when every ring has delivered EOS."""
        ring = self.channel(max(self.capacity, 1024))
        self._results_rings.append(ring)
        return ring

    def register_farm_stats(self, farm: Farm, ring: ShmRing) -> None:
        self._farm_stats.append((farm, ring))

    # -- execution ----------------------------------------------------------
    def run(self) -> "ProcGraph":
        assert not self._procs, "graph already running"
        tr = self.tracer
        if tr is not None:
            for v in self.vertices:
                v.trace_sample = tr.sample
                v.trace_capacity = tr.capacity
        pickling_errors = (pickle.PicklingError, AttributeError, TypeError)
        if self._pool is not None:
            for v in self.vertices:
                w = self._pool.acquire()
                try:
                    w.submit(v)
                except pickling_errors as e:
                    self._pool.release(w)  # put failed pre-pipe: still clean
                    self.shutdown()
                    raise self._lowering_error(e) from e
                self._pool_workers.append(w)
                self._procs.append(w.proc)
            return self
        try:
            for v in self.vertices:
                p = self._ctx.Process(target=_vertex_main, args=(v,),
                                      name=v.name, daemon=True)
                p.start()
                self._procs.append(p)
        except pickling_errors as e:
            self.shutdown()
            raise self._lowering_error(e) from e
        return self

    @staticmethod
    def _lowering_error(e: BaseException) -> LoweringError:
        return LoweringError(
            f"the procs backend spawns vertices, so nodes/payloads/"
            f"policies must be picklable (module-level functions, "
            f"functools.partial, or ff_node subclasses — not lambdas "
            f"or closures): {e!r}")

    def wait_ready(self, timeout: float = 60.0) -> None:
        """Block until every vertex has finished ``svc_init`` (used to
        exclude spawn/import cost from steady-state measurements)."""
        deadline = time.monotonic() + timeout
        while self._ready < len(self.vertices):
            if time.monotonic() > deadline:
                self.shutdown()
                raise TimeoutError(
                    f"procs graph: {self._ready}/{len(self.vertices)} "
                    f"vertices ready after {timeout}s")
            self._drain_ctl()
            if not self.failed:
                self._check_liveness()
            if self.failed:
                self.shutdown()
                raise self.failed[0]
            if self._ready < len(self.vertices):
                time.sleep(0.002)

    def poll_results(self) -> bool:
        """Drain whatever the results rings hold right now (non-blocking).
        Returns True once EVERY results ring has delivered EOS."""
        if self._eos_seen or not self._results_rings:
            return self._eos_seen
        for i, ring in enumerate(self._results_rings):
            if i in self._eos_rings:
                continue
            while True:
                item = ring.pop()
                if item is _EMPTY:
                    break
                if item is EOS:
                    self._eos_rings.add(i)
                    break
                if type(item) is KeyBatch:  # batched wire: caller sees items
                    self.results.extend(item)
                else:
                    self.results.append(item)
        self._eos_seen = len(self._eos_rings) == len(self._results_rings)
        return self._eos_seen

    def _on_ctl(self, msg: Tuple) -> None:
        if msg[0] == "ready":
            self._ready += 1
        elif msg[0] == "error":
            _, name, rep, exc = msg
            self.failed.append(
                exc if exc is not None else RuntimeError(f"{name}: {rep}"))
        elif msg[0] == "trace":
            _, name, path, pid, events, dropped = msg
            if self.tracer is not None:
                self.tracer.absorb(name, path, pid, events, dropped)

    def _drain_ctl(self) -> None:
        for ring in self._ctl_rings:
            while True:
                msg = ring.pop()
                if msg is _EMPTY:
                    break
                self._on_ctl(msg)

    def _all_vertices_exited(self) -> bool:
        if self._pool is not None:
            return bool(self._pool_workers) and all(
                w.poll_done() or not w.proc.is_alive()
                for w in self._pool_workers)
        return bool(self._procs) and all(not p.is_alive()
                                         for p in self._procs)

    def _check_liveness(self) -> None:
        for p in self._procs:
            if not p.is_alive() and p.exitcode not in (0, None):
                self._drain_ctl()
                if not self.failed:
                    self.failed.append(RuntimeError(
                        f"vertex process {p.name!r} died with exit code "
                        f"{p.exitcode} (killed?)"))
                return
        if self._results_rings and self._all_vertices_exited() \
                and not self.poll_results():
            self._drain_ctl()
            if not self.failed:  # pragma: no cover - defensive
                self.failed.append(RuntimeError(
                    "every vertex exited but EOS never reached the "
                    "results ring"))

    def wait(self, timeout: Optional[float] = None) -> List[Any]:
        """Drain results to EOS, join every vertex, surface FarmStats,
        unlink all shared memory; raise the first vertex error (or
        TimeoutError after terminating a wedged network)."""
        return self._wait_until(self.poll_results, timeout)

    def _wait_until(self, done_fn: Callable[[], bool],
                    timeout: Optional[float]) -> List[Any]:
        """Shared teardown: poll ``done_fn`` (which drains whatever rings
        the caller consumes and returns True once the stream has fully
        arrived), then join/terminate and unlink everything."""
        deadline = None if timeout is None else time.monotonic() + timeout
        timed_out = False
        try:
            backoff = _Backoff()
            last_ctl_check = 0.0
            while not done_fn():
                now = time.monotonic()
                if deadline is not None and now > deadline:
                    timed_out = True
                    break
                if now - last_ctl_check > 0.05:
                    # error/liveness checks off the hot path: the caller
                    # is a polling process too, and must not tax the cores
                    # the workers are using
                    last_ctl_check = now
                    self._drain_ctl()
                    if not self.failed:
                        self._check_liveness()
                    if self.failed:
                        break
                backoff.idle()
            if timed_out or self.failed:
                self.failed_event.set()  # unblock every vertex
            self._join_vertices(deadline,
                                aborting=timed_out or bool(self.failed))
            self._drain_ctl()
            if self.failed_event.is_set() and not self.failed \
                    and not timed_out:  # timeout sets the flag itself
                # belt over _report_error: a set flag with no message must
                # never let a truncated stream pass as success
                self.failed.append(RuntimeError(
                    "a vertex signalled failure but its error report was "
                    "lost"))
            self._collect_stats()
        finally:
            self._cleanup()
        if self.failed:
            raise self.failed[0]
        if timed_out:
            raise TimeoutError(
                f"procs graph did not reach EOS within {timeout}s "
                f"(vertices terminated, shared memory unlinked)")
        return self.results

    def run_and_wait(self, timeout: Optional[float] = None) -> List[Any]:
        return self.run().wait(timeout)

    def _collect_stats(self) -> None:
        for farm, ring in self._farm_stats:
            snap = ring.pop()
            if snap is not _EMPTY and isinstance(snap, FarmStats):
                _fold_stats(farm.stats, snap)
        while self.finalizers:
            self.finalizers.pop()()  # runs before _cleanup unlinks boards

    def _join_vertices(self, deadline: Optional[float],
                       aborting: bool) -> None:
        """Wait for every vertex to finish, then hand processes back.

        Pool mode: poll each worker's done token; clean live workers
        return to the pool, wedged or dead ones are terminated and
        retired (a failed graph must never donate a poisoned process).
        Direct-spawn mode: join, then terminate stragglers — as before.
        """
        if self._pool is not None:
            grace = 2.0 if aborting else (
                10.0 if deadline is None
                else max(0.1, deadline - time.monotonic()))
            end = time.monotonic() + grace
            while time.monotonic() < end:
                if all(w.poll_done() or not w.proc.is_alive()
                       for w in self._pool_workers):
                    break
                time.sleep(0.001)
            for w in self._pool_workers:
                if w.poll_done() and w.proc.is_alive():
                    self._pool.release(w)
                else:
                    w.proc.terminate()
                    w.proc.join(5.0)
                    self._pool.discard(w)
            self._pool_workers = []
            self._procs = []
            return
        for p in self._procs:
            grace = 10.0 if deadline is None \
                else max(0.1, deadline - time.monotonic())
            p.join(grace if not aborting else 2.0)
        for p in self._procs:
            if p.is_alive():
                p.terminate()
                p.join(5.0)

    def shutdown(self) -> None:
        """Hard stop: abort live vertices, unlink all shared memory.

        Pooled workers get a short grace to notice the failure flag and
        finish their job cleanly (so the pool keeps them); anything still
        busy after that is terminated and retired."""
        self.failed_event.set()
        if self._pool is not None:
            self._join_vertices(time.monotonic() + 1.0, aborting=True)
        else:
            for p in self._procs:
                if p.is_alive():
                    p.terminate()
            for p in self._procs:
                p.join(5.0)
        self._cleanup()

    def _cleanup(self) -> None:
        if self._cleaned:
            return
        self._cleaned = True
        for ring in self._rings:
            ring.unlink()


# ---------------------------------------------------------------------------
# procs lowering: IR tree -> spawned vertices + shared-memory rings
# ---------------------------------------------------------------------------
def build(skel: Skeleton, g: ProcGraph, in_ring: Optional[Any],
          terminal: bool, path: str = "") -> Optional[Any]:
    """Wire a skeleton IR node into ``g`` — the procs twin of
    :func:`repro.core.graph.build`, one spawned process per vertex.
    ``in_ring`` may be one ring or a list (a terminal all-to-all row).
    ``path`` is the node's IR path, carried onto every vertex so
    telemetry keys match the threads backend's."""
    from .graph import ring_list

    if isinstance(skel, AllToAll):
        from .a2a import build_proc_a2a  # lazy: a2a imports this module
        return build_proc_a2a(skel, g, ring_list(in_ring), terminal,
                              path=path)

    if isinstance(skel, Source):
        assert in_ring is None, "Source cannot have an upstream edge"
        return build(Stage(skel.node, name=skel.name, grain=skel.grain,
                           capacity=skel.capacity), g, None, terminal, path)

    if isinstance(skel, Pipeline):
        ring = in_ring
        last = len(skel.stages) - 1
        for i, s in enumerate(skel.stages):
            p = f"{path}.{i}" if path else str(i)
            if i == last:
                return build(s, g, ring, terminal, p)
            ring = build(s, g, ring, False, p)

    if isinstance(skel, Feedback):
        # predicate loop -> tagger + wrap-around farm + reorder (Sec. 5)
        return build(skel.as_thread_net(), g, in_ring, terminal, path)

    if isinstance(skel, Farm):
        if skel.speculative:
            raise LoweringError(
                "speculative straggler re-issue is threads-only (its tag "
                "bookkeeping is shared between the two arbiters); use "
                "lower(skel, 'threads') for it")
        cap = skel.capacity or g.capacity
        has_loop = skel.feedback is not None
        # the wrap-around ring: merge -> dispatch, plus the quiescence
        # board (entered/retired, one single-writer counter each)
        loop_ring = (g.channel(min(skel.feedback_capacity, 4096))
                     if has_loop else None)
        board = g.counters(2) if has_loop else None
        d2m = g.channel(4)          # dispatch -> merge stats hand-off
        stats_ring = g.channel(4)   # merge -> caller FarmStats snapshot
        g.register_farm_stats(skel, stats_ring)
        live = None
        if getattr(g, "live_telemetry", False):
            live = g.counters(2)    # monitor tap: emitted / collected
            g.live_boards[_qualname("ff-farm", path)] = live

        sched = make_scheduler(skel.scheduling)
        service_rings: List[ShmRing] = []
        disp = g.add(ProcDispatchVertex(
            sched, skel.emitter, loop_ring=loop_ring, loop_board=board,
            service_rings=service_rings, stats_out=d2m, live_board=live))
        disp.path = path
        if in_ring is not None:
            disp.ins.extend(ring_list(in_ring))
        else:
            assert skel.emitter is not None, \
                "a standalone farm needs an emitter (or compose it after a Source)"

        merge = g.add(ProcMergeVertex(
            skel.collector, ordered=skel.ordered, loop_ring=loop_ring,
            loop_board=board, feedback=skel.feedback,
            stats_in=d2m, stats_out=stats_ring, live_board=live))
        merge.path = path
        for i, node in enumerate(skel.worker_nodes):
            idle = sched.worker_channel(i, g.channel)
            # a live monitor consumes the EWMAs too: arm the service
            # rings so the detach-time frame carries real service times
            service = (g.channel(64)
                       if sched.needs_service_stats
                       or getattr(g, "live_telemetry", False) else None)
            if service is not None:
                service_rings.append(service)
            w = g.add(ProcWorkerVertex(node, i, idle_ring=idle,
                                       service_ring=service,
                                       name=f"ff-worker-{i}"))
            w.path = path
            w.cpus = sched.worker_cpus(i, len(skel.worker_nodes))
            g.connect(disp, w, capacity=cap)
            g.connect(w, merge, capacity=cap)
        if terminal:
            merge.outs.append(g.results_ring())
            return None
        ring = g.channel(skel.capacity)
        merge.outs.append(ring)
        return ring

    if isinstance(skel, Stage):
        v = g.add(ProcStageVertex(skel.node, name=skel.name,
                                  batch=g.batch_for(skel.grain)))
        v.path = path
        v.ins.extend(ring_list(in_ring))
        if terminal:
            v.outs.append(g.results_ring())
            return None
        # per-edge capacity: a tuned Stage sizes its own outbound ring
        ring = g.channel(getattr(skel, "capacity", None))
        v.outs.append(ring)
        return ring

    raise TypeError(f"cannot lower {skel!r} to the process graph")


class ProcProgram:
    """Procs lowering: the skeleton wired onto spawned processes over
    shared-memory SPSC rings — ``lower(skel, "procs")``.

    Same ordered-output contract as the other two backends; the win is
    that pure-Python (GIL-holding) ``svc`` functions actually run in
    parallel.  ``timeout`` bounds the whole run: a hung child process is
    terminated (and all shared memory unlinked) instead of wedging the
    caller.  ``fuse`` is the same grain-aware pass as the threads backend
    — with processes costing more per vertex than threads, collapsing
    sub-threshold hand-offs pays off even sooner.

    Data-plane options (see :class:`ProcGraph`): ``zero_copy`` (typed
    buffer-protocol slots, default on), ``batch`` (batched emit: ``None``
    off / int / ``"grain"``), ``pool`` (spawn-pool reuse; ``None`` =
    honour ``REPRO_PROCS_POOL``, default on)."""

    backend = "procs"

    def __init__(self, skeleton: Skeleton, *, capacity: int = 512,
                 slot_size: int = 248, timeout: Optional[float] = 120.0,
                 fuse: Any = "auto", fuse_threshold_us: Optional[float] = None,
                 zero_copy: bool = True, batch: Any = None,
                 pool: Optional[bool] = None,
                 trace: Any = False, metrics: Any = False,
                 monitor: Any = None):
        if fuse and isinstance(skeleton, Pipeline):
            force = fuse is True
            thr = fuse_threshold_us
            if not force and thr is None and _has_grained_stage(skeleton):
                from .sched import calibrate_handoff_us
                thr = calibrate_handoff_us()
            skeleton = _fuse_pass(skeleton, threshold_us=thr, force=force)
        self.skeleton = skeleton
        self.capacity = capacity
        self.slot_size = slot_size
        self.timeout = timeout
        self.zero_copy = zero_copy
        self.batch = batch
        self.pool = pool
        self.tracer = _coerce_tracer(trace)
        self.metrics = _coerce_metrics(metrics)
        self.monitor = _coerce_monitor(monitor)
        self.last_trace = None
        self.last_report = None

    def to_graph(self, stream: Optional[Iterable[Any]] = None) -> ProcGraph:
        g = ProcGraph(capacity=self.capacity, slot_size=self.slot_size,
                      zero_copy=self.zero_copy, batch=self.batch,
                      pool=self.pool)
        # per-farm live counter boards exist only when a monitor will read
        # them — a monitorless lowering allocates nothing extra
        g.live_telemetry = self.monitor is not None
        try:
            # Build the driving Source separately (at path "in") so the
            # user skeleton keeps its root IR paths — telemetry keys
            # vertices by path, and wrapping in a fresh Pipeline would
            # shift every top-level index by one.
            in_ring = None
            if stream is not None:
                in_ring = build(Source(stream), g, None, False, "in")
            build(self.skeleton, g, in_ring, True)
        except BaseException:
            g.shutdown()  # unlink whatever the partial build created
            raise
        if self.tracer is not None:
            g.tracer = self.tracer
        return g

    def __call__(self, items: Iterable[Any]) -> List[Any]:
        xs = list(items)
        if not xs:
            return []  # nothing to stream; skip the spawn entirely
        g = self.to_graph(xs)
        reg = self.metrics
        mon = self.monitor
        if mon is not None:
            mon.attach(g, skeleton=self.skeleton, backend="procs")
        try:
            if reg is None:
                out = g.run_and_wait(self.timeout)
            else:
                hw: Dict[str, int] = {}
                t0 = time.monotonic()
                g.run()

                def drain() -> bool:  # the wait loop doubles as the hw tap
                    g.sample_high_water(hw)
                    return g.poll_results()

                out = g._wait_until(drain, self.timeout)
                farms = {q: farm_stats_snapshot(st)
                         for q, st in walk_stats(self.skeleton)}
                self.last_report = reg.finalize(reg.report(
                    farms=farms, queues=hw, pool=pool_stats(),
                    meta={"backend": "procs", "vertices": len(g.vertices),
                          "items_in": len(xs), "items_out": len(out),
                          "wall_s": time.monotonic() - t0}))
        finally:
            if mon is not None:
                mon.detach()
        if self.tracer is not None:
            self.last_trace = self.tracer.trace()
        return out


BACKENDS["procs"] = ProcProgram


class ProcAccelerator:
    """Self-offloading accelerator over processes (TR-10-03, procs twin of
    :class:`graph.Accelerator`): the *caller* is the single producer of
    the inbound ring(s) and the single consumer of the results, so a
    Python main thread can offload pure-Python kernels to a process farm
    and keep computing.

        acc = ProcAccelerator(Farm(f, 4))   # f must be picklable
        for x in tasks: acc.offload(x)
        results = acc.wait()

    For a plain farm — no emitter/collector node, no feedback edge, a
    ``pick()``-based scheduling policy (rr / ondemand / costmodel) — the
    accelerator runs **caller-side arbitration**: the calling thread IS
    the dispatch and merge arbiter (tagging, placement, dedup-free
    collection, reorder-by-tag), so the network is exactly ``nworkers``
    processes and zero polling arbiters.  That is the paper's
    self-offloading design taken literally, and on a small machine it
    matters: every extra polling process is a core-thief.  Skeletons that
    need an arbiter process (compositions, feedback loops, worksteal's
    pump) fall back to the full process graph transparently.

    ``offload`` opportunistically drains results while the target ring is
    full — the caller is part of the network, so it must not create a
    blocking cycle through itself."""

    def __init__(self, net: Any, *, capacity: int = 512,
                 slot_size: int = 248, ready_timeout: float = 60.0,
                 zero_copy: bool = True, pool: Optional[bool] = None):
        skel = as_skeleton(net)
        self._g = ProcGraph(capacity=capacity, slot_size=slot_size,
                            zero_copy=zero_copy, pool=pool)
        self._farm: Optional[Farm] = None
        try:
            if self._caller_side_ok(skel):
                self._build_caller_farm(skel)
            else:
                self._in = self._g.channel()
                build(skel, self._g, self._in, True)
        except BaseException:
            self._g.shutdown()  # unlink whatever the partial build created
            raise
        self._g.run()
        self._g.wait_ready(ready_timeout)
        self._closed = False

    @staticmethod
    def _caller_side_ok(skel: Skeleton) -> bool:
        if not isinstance(skel, Farm):
            return False
        if skel.emitter is not None or skel.collector is not None \
                or skel.feedback is not None or skel.speculative:
            return False
        sched = make_scheduler(skel.scheduling)
        # token-holding policies (custom place/pump, e.g. worksteal) need
        # the dispatch arbiter's pump loop — same test StageVertex uses
        return type(sched).place is Scheduler.place

    def _build_caller_farm(self, skel: Farm) -> None:
        g = self._g
        self._farm = skel
        self._sched = make_scheduler(skel.scheduling)
        self._stats = FarmStats()
        self._in_rings: List[ShmRing] = []
        self._out_rings: List[ShmRing] = []
        self._service_rings: List[ShmRing] = []
        cap = skel.capacity or g.capacity
        for i, node in enumerate(skel.worker_nodes):
            service = (g.channel(64)
                       if self._sched.needs_service_stats else None)
            if service is not None:
                self._service_rings.append(service)
            w = g.add(ProcWorkerVertex(node, i, service_ring=service,
                                       name=f"ff-worker-{i}"))
            w.cpus = self._sched.worker_cpus(i, len(skel.worker_nodes))
            q_in, q_out = g.channel(cap), g.channel(cap)
            w.ins.append(q_in)
            w.outs.append(q_out)
            self._in_rings.append(q_in)
            self._out_rings.append(q_out)
        self._sched.bind(self._in_rings, self._stats)
        self._next_tag = 0
        self._reorder: Dict[int, Any] = {}
        self._next_out = 0
        self._worker_eos = 0
        self._drain_backoff = _Backoff()

    # -- caller-side merge ---------------------------------------------------
    def _collect(self, payload: Any) -> None:
        if payload is GO_ON:
            return  # the merge arbiter would have retired it silently
        if isinstance(payload, _FarmEmitMany):
            self._g.results.extend(payload)
            return
        self._g.results.append(payload)

    def _drain(self) -> bool:
        """One pass over the worker output (and service) rings; returns
        True if anything moved.  This IS MergeVertex._loop, inlined into
        the caller."""
        moved = False
        for ring in self._service_rings:
            while True:
                upd = ring.pop()
                if upd is _EMPTY:
                    break
                self._sched.observe_service(upd[0], upd[1])
        st = self._stats
        for i, q in enumerate(self._out_rings):
            for _ in range(_BATCH):
                tok = q.pop()
                if tok is _EMPTY:
                    break
                moved = True
                if tok is EOS:
                    self._worker_eos += 1
                    break
                if isinstance(tok, _WorkerStats):
                    if tok.ewma is not None:
                        st.service_ewma[tok.index] = tok.ewma
                    continue
                tag, issued, payload = tok
                st.tasks_collected += 1
                st.per_worker[i] = st.per_worker.get(i, 0) + 1
                if issued:
                    st.latencies.append(time.monotonic() - issued)
                if self._farm.ordered:
                    self._reorder[tag] = payload
                    while self._next_out in self._reorder:
                        self._collect(self._reorder.pop(self._next_out))
                        self._next_out += 1
                else:
                    self._collect(payload)
        return moved

    def _caller_done(self) -> bool:
        self._drain()
        return self._worker_eos >= len(self._out_rings)

    def _network_dead(self) -> bool:
        """A vertex raised (failure Event) or silently died (liveness
        probe): the caller's push loops must stop blocking on rings no
        process will ever drain."""
        if self._g.failed_event.is_set():
            return True
        self._g._check_liveness()
        return bool(self._g.failed)

    # -- public surface ------------------------------------------------------
    @property
    def results(self) -> List[Any]:
        return self._g.results

    def offload(self, task: Any) -> None:
        assert not self._closed, "accelerator already EOS'd"
        if self._farm is None:
            spins = 0
            while not self._in.push(task):
                self._g.poll_results()
                if self._network_dead():
                    self._g.wait(timeout=5.0)  # raises the vertex error
                    raise RuntimeError("accelerator network failed")
                spins += 1
                if spins > 64:
                    time.sleep(_POLL)
            return
        tag = self._next_tag
        issued = time.monotonic() if tag & _LAT_SAMPLE == 0 else 0.0
        tok = (tag, issued, task)
        self._next_tag += 1
        ring = self._in_rings[self._sched.pick()]
        while not ring.push(tok):
            if self._drain():
                self._drain_backoff.reset()
                continue
            if self._network_dead():
                self._g._wait_until(self._caller_done, 5.0)  # raises
                raise RuntimeError("accelerator network failed")
            self._drain_backoff.idle()
        self._stats.tasks_emitted += 1

    def eos(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._farm is None:
            spins = 0
            while not self._in.push(EOS):
                self._g.poll_results()
                if self._network_dead():
                    return  # wait() will surface the vertex error
                spins += 1
                if spins > 64:
                    time.sleep(_POLL)
            return
        for q in self._in_rings:
            # keep draining while pushing: a full out-ring must not wedge
            # the caller against a full in-ring (the caller is both
            # arbiters — it cannot block on itself).  A dead vertex never
            # drains its ring: bail and let wait() raise its error.
            while not q.push(EOS):
                if self._drain():
                    self._drain_backoff.reset()
                    continue
                if self._network_dead():
                    return
                self._drain_backoff.idle()

    def wait(self, timeout: Optional[float] = None) -> List[Any]:
        self.eos()
        if self._farm is None:
            return self._g.wait(timeout)
        try:
            return self._g._wait_until(self._caller_done, timeout)
        finally:
            # flush reorder residue + surface the merged FarmStats onto
            # the IR node, as the graph path's stats ring would have
            for t in sorted(self._reorder):
                self._collect(self._reorder.pop(t))
            _fold_stats(self._farm.stats, self._stats)
