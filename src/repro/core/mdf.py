"""Macro data-flow executor over the farm (paper Sec. 5).

The paper closes by proposing FastFlow as "a fast macro data-flow executor
(actually wrapping around the order preserving farm) ... including dynamic
programming".  This module is that executor, expressed as a facade over the
skeleton IR's wrap-around machinery (:class:`repro.core.skeleton.Farm` with
``feedback=``, lowered on the threads backend): completed-task events flow
from the merge arbiter back to
the dispatch arbiter over the wrap-around SPSC ring — i.e. the network is
*cyclic*, exercising the paper's claim that arbitrated SPSC composition
supports arbitrary streaming graphs, loops included.

    Emitter (releases ready tasks) ──> Workers ──> Collector
        ^                                              │
        └────────── wrap-around SPSC (graph.py) ───────┘

Tasks whose dependencies are all satisfied are fed in as the initial
stream; each completion releases its newly-ready successors back around
the loop.  Termination is the graph layer's loop-quiescence protocol (no
tokens in flight, wrap-around ring drained) — no task counting here.

`examples/smith_waterman_search.py` uses it to run blocked Smith-Waterman
as a wavefront dynamic program — the exact workload class the paper names.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Sequence, Tuple

from .skeleton import Farm, FnNode, Pipeline, Source

__all__ = ["MDFTask", "MDFExecutor"]


@dataclass
class MDFTask:
    tag: Any
    fn: Callable[..., Any]
    deps: Tuple[Any, ...] = ()
    extra_args: Tuple[Any, ...] = ()
    kwargs: Dict[str, Any] = field(default_factory=dict)


class MDFExecutor:
    """Execute a static task DAG with tagged-token matching."""

    def __init__(self, nworkers: int = 4, capacity: int = 1024):
        self.nworkers = nworkers
        self.capacity = capacity
        self.results: Dict[Any, Any] = {}

    def run(self, tasks: Sequence[MDFTask]) -> Dict[Any, Any]:
        by_tag = {t.tag: t for t in tasks}
        assert len(by_tag) == len(tasks), "duplicate tags"
        indeg = {t.tag: len(t.deps) for t in tasks}
        succs: Dict[Any, List[Any]] = {t.tag: [] for t in tasks}
        for t in tasks:
            for d in t.deps:
                assert d in by_tag, f"unknown dep {d!r} of {t.tag!r}"
                succs[d].append(t.tag)

        results = self.results
        total = len(tasks)

        def work(task: MDFTask) -> Tuple[Any, Any]:
            # dep results were stored by the collector BEFORE the task was
            # released around the loop, so these reads are safe
            args = tuple(results[d] for d in task.deps) + tuple(task.extra_args)
            return (task.tag, task.fn(*args, **task.kwargs))

        def release(item: Tuple[Any, Any]):
            tag, value = item
            results[tag] = value              # store BEFORE releasing successors
            ready = []
            for s in succs[tag]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    ready.append(by_tag[s])
            return None, ready                # nothing leaves the loop early

        initial = [by_tag[t] for t, d in indeg.items() if d == 0]
        farm = Farm(FnNode(work), self.nworkers, feedback=release,
                    feedback_capacity=max(self.capacity, total + 1))
        Pipeline(Source(initial), farm).run_and_wait(capacity=self.capacity)
        assert len(results) == total, f"deadlock or lost tokens: {len(results)}/{total}"
        return results
