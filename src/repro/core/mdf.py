"""Macro data-flow executor over the farm (paper Sec. 5).

The paper closes by proposing FastFlow as "a fast macro data-flow executor
(actually wrapping around the order preserving farm) ... including dynamic
programming".  This module is that executor: a DAG of named tasks is
streamed through a farm; the Collector feeds completion events back to the
Emitter over an SPSC ring — i.e. the network is *cyclic*, exercising the
paper's claim that arbitrated SPSC composition supports arbitrary streaming
graphs, loops included.

    Emitter (releases ready tasks) ──> Workers ──> Collector
        ^                                              │
        └────────────── feedback SPSC ─────────────────┘

`examples/mdf_wavefront.py` uses it to run blocked Smith-Waterman as a
wavefront dynamic program — the exact workload class the paper names.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Sequence, Tuple

from .farm import TaskFarm, ff_node
from .spsc import SPSCQueue

__all__ = ["MDFTask", "MDFExecutor"]


@dataclass
class MDFTask:
    tag: Any
    fn: Callable[..., Any]
    deps: Tuple[Any, ...] = ()
    extra_args: Tuple[Any, ...] = ()
    kwargs: Dict[str, Any] = field(default_factory=dict)


class MDFExecutor:
    """Execute a static task DAG with tagged-token matching."""

    def __init__(self, nworkers: int = 4, capacity: int = 1024):
        self.nworkers = nworkers
        self.capacity = capacity
        self.results: Dict[Any, Any] = {}

    def run(self, tasks: Sequence[MDFTask]) -> Dict[Any, Any]:
        by_tag = {t.tag: t for t in tasks}
        assert len(by_tag) == len(tasks), "duplicate tags"
        indeg = {t.tag: len(t.deps) for t in tasks}
        succs: Dict[Any, List[Any]] = {t.tag: [] for t in tasks}
        for t in tasks:
            for d in t.deps:
                assert d in by_tag, f"unknown dep {d!r} of {t.tag!r}"
                succs[d].append(t.tag)

        results = self.results
        feedback = SPSCQueue(self.capacity)  # collector -> emitter (the cycle)
        total = len(tasks)

        class _Emitter(ff_node):
            def __init__(self) -> None:
                self.ready = [tag for tag, d in indeg.items() if d == 0]
                self.released = 0
                self.completed = 0

            def svc(self, _):
                while True:
                    # 1. fold in completion events from the feedback ring
                    while True:
                        ev = feedback.pop()
                        if ev is SPSCQueue._EMPTY:
                            break
                        self.completed += 1
                        for s in succs[ev]:
                            indeg[s] -= 1
                            if indeg[s] == 0:
                                self.ready.append(s)
                    # 2. release a ready task, or terminate, or spin
                    if self.ready:
                        self.released += 1
                        return by_tag[self.ready.pop()]
                    if self.completed >= total:
                        return None  # EOS
                    time.sleep(0.000_05)

        class _Worker(ff_node):
            def svc(self, task: MDFTask):
                args = tuple(results[d] for d in task.deps) + tuple(task.extra_args)
                return (task.tag, task.fn(*args, **task.kwargs))

        class _Collector(ff_node):
            def svc(self, item):
                tag, value = item
                results[tag] = value          # store BEFORE signalling readiness
                feedback.push_wait(tag)
                return None

        farm = TaskFarm(self.nworkers, preserve_order=False)
        farm.add_emitter(_Emitter())
        farm.add_worker(_Worker())
        farm.add_collector(_Collector())
        farm.run_and_wait()
        assert len(results) == total, f"deadlock or lost tokens: {len(results)}/{total}"
        return results
