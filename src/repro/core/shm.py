"""Lock-free SPSC ring over OS shared memory — the paper's queue, off-GIL.

``spsc.py`` is the Lamport/FastForward ring for one *process*: correct
under exactly one producer thread and one consumer thread, with CPython's
GIL standing in for x86 store ordering.  This module is the same algorithm
over ``multiprocessing.shared_memory``, so producer and consumer can be
separate *processes* — which is where the paper's speedup story finally
applies to pure-Python stages (a thread farm of GIL-holding ``svc``
functions serialises; a process farm does not; see ``procgraph.py``).

What is byte-for-byte faithful to the paper here (Sec. 3.1, after
Giacomoni et al.'s FastForward, PPoPP'08):

* **single-writer counters** — ``head`` is written only by the consumer,
  ``tail`` only by the producer; each side reads the other's counter
  benignly stale.  No locks, no CAS, no fetch-and-add on the data path.
* **cache-line separation** — head and tail live 64 bytes apart in the
  shared segment (offsets 0 and 64; slots start at 128 and each slot is
  padded to a cache-line multiple), so the two cores never false-share a
  line.  In ``spsc.py`` this discipline "has no observable analogue";
  here it is real: both counters are plain 8-byte stores into mapped
  memory with no interpreter lock between the cores.
* **publication order** — the producer writes the payload *then* the
  tail; the consumer reads the payload *then* the head.  CPython emits
  these as ordinary stores in program order; x86-TSO keeps them ordered,
  exactly the assumption the paper makes for its fence-free queue.

Payloads are pickled into fixed-size slots.  An item whose pickle exceeds
the slot goes through the **spill side-channel**: the producer writes the
blob to a private spill file (named by the ring + a producer-owned
sequence number — still single-writer) and the slot carries only the
sequence number; the consumer reads and deletes the file.  The ring stays
wait-free for the common case and merely degrades to file I/O for the
rare oversized item.

``push``/``pop`` are non-blocking; ``push_wait``/``pop_wait`` spin with
the same exponential yield backoff as ``SPSCQueue``, and the ``EOS``
sentinel pickles to the canonical instance on the far side
(``_EOS.__reduce__``), so the two rings are drop-in interchangeable.
"""
from __future__ import annotations

import glob
import os
import pickle
import struct
import tempfile
import time
from typing import Any, Optional

from multiprocessing import shared_memory

from .spsc import EOS, SPSCQueue  # noqa: F401  (EOS re-exported: ring protocol)

__all__ = ["ShmRing", "ShmCounters", "EOS"]

_CACHE_LINE = 64
_HEAD_OFF = 0            # consumer-written counter, own cache line
_TAIL_OFF = _CACHE_LINE  # producer-written counter, own cache line
_DATA_OFF = 2 * _CACHE_LINE
_SLOT_HDR = struct.Struct("<IB3x")  # payload length, kind (inline/spill)
_KIND_INLINE = 0
_KIND_SPILL = 1
_PICKLE_PROTO = pickle.HIGHEST_PROTOCOL  # sentinel __reduce__ needs >= 2
_POLL = 0.000_05   # blocking-helper backoff (matches SPSCQueue)


def _spill_dir() -> str:
    return tempfile.gettempdir()


class ShmRing:
    """Bounded wait-free SPSC FIFO in a ``SharedMemory`` segment.

    ``capacity`` is rounded up to a power of two minus the one sacrificial
    Lamport slot, exactly like ``SPSCQueue``; ``slot_size`` is the inline
    payload budget per slot (larger pickles spill, see module docstring).

    The creating process *owns* the segment: only ``unlink()`` from the
    owner destroys it (and sweeps leftover spill files).  The object
    pickles as an **attach**: sending a ring to a spawned child re-opens
    the same segment by name, which is how ``procgraph`` wires edges.
    ``pushes``/``pops`` are endpoint-local telemetry (each side counts its
    own operations; they are not shared state).
    """

    def __init__(self, capacity: int = 512, slot_size: int = 248, *,
                 name: Optional[str] = None, _attach: bool = False):
        if capacity < 2:
            capacity = 2
        size = 1
        while size < capacity + 1:
            size <<= 1
        self._mask = size - 1
        self.slot_size = slot_size
        self._stride = -(-(_SLOT_HDR.size + slot_size) // _CACHE_LINE) \
            * _CACHE_LINE
        nbytes = _DATA_OFF + size * self._stride
        if _attach:
            self._shm = shared_memory.SharedMemory(name=name)
            self.owner = False
        else:
            self._shm = shared_memory.SharedMemory(
                create=True, size=nbytes, name=name)
            self.owner = True
        self.name = self._shm.name
        self._mv = self._shm.buf
        self._idx = self._mv.cast("Q")  # [0] = head, [8] = tail (64B apart)
        if self.owner:
            self._idx[_HEAD_OFF // 8] = 0
            self._idx[_TAIL_OFF // 8] = 0
        self._spill_seq = 0  # producer-private; consumer tracks via slots
        self.pushes = 0
        self.pops = 0
        self._closed = False

    # -- pickling = attach (how edges reach spawned vertices) ---------------
    def __reduce__(self):
        return (_attach_ring, (self.name, self._mask, self.slot_size))

    # -- introspection (either side; cross-side values benignly stale) ------
    def __len__(self) -> int:
        return (self._idx[_TAIL_OFF // 8] - self._idx[_HEAD_OFF // 8]) \
            & self._mask

    @property
    def capacity(self) -> int:
        return self._mask  # one slot reserved (Lamport full/empty)

    def empty(self) -> bool:
        return self._idx[_HEAD_OFF // 8] == self._idx[_TAIL_OFF // 8]

    def full(self) -> bool:
        return ((self._idx[_TAIL_OFF // 8] + 1) & self._mask) \
            == self._idx[_HEAD_OFF // 8]

    # -- producer side ------------------------------------------------------
    def _spill_path(self, seq: int) -> str:
        return os.path.join(_spill_dir(),
                            f"ffshm-{self.name.lstrip('/')}-{seq}.spill")

    def push(self, item: Any) -> bool:
        """Non-blocking enqueue. Returns False when full. Producer-only."""
        idx = self._idx
        tail = idx[_TAIL_OFF // 8]
        nxt = (tail + 1) & self._mask
        if nxt == idx[_HEAD_OFF // 8]:
            return False
        blob = pickle.dumps(item, _PICKLE_PROTO)
        base = _DATA_OFF + (tail & self._mask) * self._stride
        if len(blob) <= self.slot_size:
            _SLOT_HDR.pack_into(self._mv, base, len(blob), _KIND_INLINE)
            self._mv[base + _SLOT_HDR.size:base + _SLOT_HDR.size + len(blob)] \
                = blob
        else:
            # spill side-channel: blob to a producer-owned file, slot
            # carries the sequence number (file is durable before the
            # tail store below publishes the slot)
            seq = self._spill_seq
            self._spill_seq += 1
            with open(self._spill_path(seq), "wb") as f:
                f.write(blob)
            _SLOT_HDR.pack_into(self._mv, base, 8, _KIND_SPILL)
            struct.pack_into("<Q", self._mv, base + _SLOT_HDR.size, seq)
        idx[_TAIL_OFF // 8] = nxt  # publish AFTER the payload (order matters)
        self.pushes += 1
        return True

    def push_wait(self, item: Any, timeout: Optional[float] = None) -> bool:
        """Blocking enqueue with spin/yield backoff."""
        deadline = None if timeout is None else time.monotonic() + timeout
        spins = 0
        while not self.push(item):
            spins += 1
            if spins > 64:
                time.sleep(_POLL)
            if deadline is not None and time.monotonic() > deadline:
                return False
        return True

    # -- consumer side ------------------------------------------------------
    def pop(self) -> Any:
        """Non-blocking dequeue. Returns ``SPSCQueue._EMPTY`` when empty."""
        idx = self._idx
        head = idx[_HEAD_OFF // 8]
        if head == idx[_TAIL_OFF // 8]:
            return SPSCQueue._EMPTY
        base = _DATA_OFF + (head & self._mask) * self._stride
        length, kind = _SLOT_HDR.unpack_from(self._mv, base)
        raw = bytes(self._mv[base + _SLOT_HDR.size:
                             base + _SLOT_HDR.size + length])
        if kind == _KIND_SPILL:
            seq = struct.unpack("<Q", raw)[0]
            path = self._spill_path(seq)
            with open(path, "rb") as f:
                raw = f.read()
            os.unlink(path)
        item = pickle.loads(raw)
        idx[_HEAD_OFF // 8] = (head + 1) & self._mask  # release AFTER reading
        self.pops += 1
        return item

    def pop_wait(self, timeout: Optional[float] = None) -> Any:
        """Blocking dequeue with spin/yield backoff.

        Returns ``SPSCQueue._EMPTY`` on timeout.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        spins = 0
        while True:
            item = self.pop()
            if item is not SPSCQueue._EMPTY:
                return item
            spins += 1
            if spins > 64:
                time.sleep(_POLL)
            if deadline is not None and time.monotonic() > deadline:
                return SPSCQueue._EMPTY

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        """Drop this process's mapping (the segment survives for peers)."""
        if self._closed:
            return
        self._closed = True
        self._idx.release()
        self._mv = None
        self._shm.close()

    def __del__(self):
        # release the cast view before SharedMemory's own __del__ runs, or
        # its close() raises BufferError ("exported pointers exist")
        try:
            self.close()
        except Exception:  # pragma: no cover - interpreter-shutdown races
            pass

    def unlink(self) -> None:
        """Owner-only: destroy the segment and sweep leftover spill files."""
        self.close()
        if not self.owner:
            return
        for path in glob.glob(os.path.join(
                _spill_dir(), f"ffshm-{self.name.lstrip('/')}-*.spill")):
            try:
                os.unlink(path)
            except OSError:  # pragma: no cover - another sweep won the race
                pass
        try:
            self._shm.unlink()
        except FileNotFoundError:  # pragma: no cover - already unlinked
            pass


def _attach_ring(name: str, mask: int, slot_size: int) -> ShmRing:
    ring = ShmRing.__new__(ShmRing)
    ShmRing.__init__(ring, mask, slot_size, name=name, _attach=True)
    return ring


class ShmCounters:
    """``n`` single-writer u64 counters, one per cache line, in shared
    memory — the cross-process analogue of ``TagSpace``'s split counters.

    ``procgraph`` uses a 2-counter board per wrap-around farm: slot 0
    (``entered``) is written only by the dispatch arbiter, slot 1
    (``retired``) only by the merge arbiter; each side reads the other's
    slot benignly stale, with the same store-ordering argument as the
    ring (the merge arbiter pushes looped-back tasks *before* bumping
    ``retired``, so the dispatcher's quiescence check stays race-free).
    """

    def __init__(self, n: int = 2, *, name: Optional[str] = None,
                 _attach: bool = False):
        self.n = n
        if _attach:
            self._shm = shared_memory.SharedMemory(name=name)
            self.owner = False
        else:
            self._shm = shared_memory.SharedMemory(
                create=True, size=n * _CACHE_LINE)
            self.owner = True
        self.name = self._shm.name
        self._idx = self._shm.buf.cast("Q")
        if self.owner:
            for i in range(n):
                self._idx[i * (_CACHE_LINE // 8)] = 0
        self._closed = False

    def __reduce__(self):
        return (_attach_counters, (self.name, self.n))

    def get(self, i: int) -> int:
        return self._idx[i * (_CACHE_LINE // 8)]

    def add(self, i: int, delta: int = 1) -> None:
        """Single-writer increment (exactly one process may write slot i)."""
        off = i * (_CACHE_LINE // 8)
        self._idx[off] = self._idx[off] + delta

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._idx.release()
        self._shm.close()

    def __del__(self):
        try:
            self.close()
        except Exception:  # pragma: no cover - interpreter-shutdown races
            pass

    def unlink(self) -> None:
        self.close()
        if self.owner:
            try:
                self._shm.unlink()
            except FileNotFoundError:  # pragma: no cover
                pass


def _attach_counters(name: str, n: int) -> ShmCounters:
    board = ShmCounters.__new__(ShmCounters)
    ShmCounters.__init__(board, n, name=name, _attach=True)
    return board
