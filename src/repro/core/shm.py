"""Lock-free SPSC ring over OS shared memory — the paper's queue, off-GIL.

``spsc.py`` is the Lamport/FastForward ring for one *process*: correct
under exactly one producer thread and one consumer thread, with CPython's
GIL standing in for x86 store ordering.  This module is the same algorithm
over ``multiprocessing.shared_memory``, so producer and consumer can be
separate *processes* — which is where the paper's speedup story finally
applies to pure-Python stages (a thread farm of GIL-holding ``svc``
functions serialises; a process farm does not; see ``procgraph.py``).

What is byte-for-byte faithful to the paper here (Sec. 3.1, after
Giacomoni et al.'s FastForward, PPoPP'08):

* **single-writer counters** — ``head`` is written only by the consumer,
  ``tail`` only by the producer; each side reads the other's counter
  benignly stale.  No locks, no CAS, no fetch-and-add on the data path.
* **cache-line separation** — head and tail live 64 bytes apart in the
  shared segment (offsets 0 and 64; slots start at 128 and each slot is
  padded to a cache-line multiple), so the two cores never false-share a
  line.  In ``spsc.py`` this discipline "has no observable analogue";
  here it is real: both counters are plain 8-byte stores into mapped
  memory with no interpreter lock between the cores.
* **publication order** — the producer writes the payload *then* the
  tail; the consumer reads the payload *then* the head.  CPython emits
  these as ordinary stores in program order; x86-TSO keeps them ordered,
  exactly the assumption the paper makes for its fence-free queue.

Slots are **typed**.  Payloads exposing the buffer protocol skip pickle
entirely — the bytes are copied exactly once, straight into the mapped
segment, under a small header:

* kind ``RAW``   — ``bytes``/``bytearray``/C-contiguous ``memoryview``
  (one subkind byte restores the concrete type; a memoryview comes back
  as ``bytes``, the only faithful owner once detached from its source);
* kind ``NDARRAY`` — a C-contiguous unstructured numpy array: dtype
  string + shape in the header, raw data after it.  The consumer
  allocates ``np.empty`` and copies the segment bytes in — exactly one
  copy per side, no serialisation.  numpy is never imported here: the
  fast path engages only when ``sys.modules`` says the caller already
  has it (the lazy-import guardrail — ``import repro.core`` stays cheap
  in spawned vertices).
* kind ``INLINE``/``SPILL`` — everything else pickles as before.  An
  item whose encoding exceeds the slot goes through the **spill
  side-channel**: the producer writes the blob to a spill file (named by
  the ring + a producer-owned sequence number — still single-writer) and
  the slot carries only the sequence number; the consumer reads,
  decodes, *then* deletes the file and only then publishes the head, so
  a consumer dying mid-decode leaves the item on disk for the owner's
  sweep instead of losing it.  The spill directory is pinned at ring
  creation and travels through ``__reduce__`` so producer and consumer
  agree on paths even under divergent ``TMPDIR``.
* kind ``BATCH`` — ``push_many`` packs a run of small items into one
  slot (one header + one counter store amortised over the run); ``pop``
  unpacks transparently, holding the tail of the batch in a consumer-
  local pending queue that ``empty()``/``len()`` account for.

``push``/``pop`` are non-blocking; ``push_wait``/``pop_wait`` share
``SPSCQueue``'s truncated-exponential ``Backoff`` (deadline checked
before sleeping), and the ``EOS`` sentinel pickles to the canonical
instance on the far side (``_EOS.__reduce__``), so the two rings are
drop-in interchangeable.
"""
from __future__ import annotations

import glob
import os
import pickle
import struct
import sys
import tempfile
import time
from collections import deque
from typing import Any, Optional, Sequence

from multiprocessing import shared_memory

from .spsc import EOS, Backoff, SPSCQueue  # noqa: F401  (EOS re-exported)

__all__ = ["ShmRing", "ShmCounters", "ShmFlag", "EOS"]

_CACHE_LINE = 64
_HEAD_OFF = 0            # consumer-written counter, own cache line
_TAIL_OFF = _CACHE_LINE  # producer-written counter, own cache line
_DATA_OFF = 2 * _CACHE_LINE
_SLOT_HDR = struct.Struct("<IB3x")   # payload length, kind
_FRAME_HDR = struct.Struct("<IB")    # per-item header inside a BATCH slot
_KIND_INLINE = 0   # pickle, inline
_KIND_SPILL = 1    # pickle, spill file (slot carries the sequence number)
_KIND_RAW = 2      # buffer-protocol bytes: 1 subkind byte + raw payload
_KIND_NDARRAY = 3  # ndim,dtype-len,pad + dtype-str + shape(u64 each) + pad
                   # zeros + raw C data (64B-aligned when the frame starts
                   # a slot: aligned memcpy is ~4x an unaligned one)
_KIND_BATCH = 4    # u32 count + count frames of _FRAME_HDR + payload
_RAW_BYTES = 0
_RAW_BYTEARRAY = 1
_RAW_MEMORYVIEW = 2
_PICKLE_PROTO = pickle.HIGHEST_PROTOCOL  # sentinel __reduce__ needs >= 2


class ShmRing:
    """Bounded wait-free SPSC FIFO in a ``SharedMemory`` segment.

    ``capacity`` is rounded up to a power of two minus the one sacrificial
    Lamport slot, exactly like ``SPSCQueue``; ``slot_size`` is the inline
    payload budget per slot (larger encodings spill, see module
    docstring).  ``zero_copy`` (default on) enables the typed RAW/NDARRAY
    slot kinds; off, every payload takes the pickle path — useful as a
    benchmark baseline and for payload types whose identity must survive
    the hop exactly.

    The creating process *owns* the segment: only ``unlink()`` from the
    owner destroys it (and sweeps leftover spill files).  The object
    pickles as an **attach**: sending a ring to a spawned child re-opens
    the same segment by name, which is how ``procgraph`` wires edges.
    ``pushes``/``pops`` are endpoint-local telemetry (each side counts its
    own operations; they are not shared state).
    """

    def __init__(self, capacity: int = 512, slot_size: int = 248, *,
                 name: Optional[str] = None, spill_dir: Optional[str] = None,
                 zero_copy: bool = True, _attach: bool = False):
        if capacity < 2:
            capacity = 2
        size = 1
        while size < capacity + 1:
            size <<= 1
        self._mask = size - 1
        self.slot_size = slot_size
        self._stride = -(-(_SLOT_HDR.size + slot_size) // _CACHE_LINE) \
            * _CACHE_LINE
        nbytes = _DATA_OFF + size * self._stride
        if _attach:
            self._shm = shared_memory.SharedMemory(name=name)
            self.owner = False
        else:
            self._shm = shared_memory.SharedMemory(
                create=True, size=nbytes, name=name)
            self.owner = True
        # pinned at creation and carried through __reduce__: producer and
        # consumer must resolve identical spill paths even when their
        # environments disagree about TMPDIR
        self.spill_dir = spill_dir if spill_dir is not None \
            else tempfile.gettempdir()
        self.zero_copy = zero_copy
        self.name = self._shm.name
        self._mv = self._shm.buf
        self._idx = self._mv.cast("Q")  # [0] = head, [8] = tail (64B apart)
        if self.owner:
            self._idx[_HEAD_OFF // 8] = 0
            self._idx[_TAIL_OFF // 8] = 0
        self._spill_seq = 0  # producer-private; consumer tracks via slots
        self._pending: deque = deque()  # consumer-local tail of a BATCH slot
        # codec caches: streams are overwhelmingly homogeneous, so the
        # ndarray meta header (producer) and the parsed dtype (consumer)
        # are computed once per (dtype, shape) / dtype-string, not per item
        self._nd_meta: dict = {}
        self._nd_dtypes: dict = {}
        self.pushes = 0
        self.pops = 0
        self._closed = False

    # -- pickling = attach (how edges reach spawned vertices) ---------------
    def __reduce__(self):
        return (_attach_ring, (self.name, self._mask, self.slot_size,
                               self.spill_dir, self.zero_copy))

    # -- introspection (either side; cross-side values benignly stale) ------
    def __len__(self) -> int:
        return len(self._pending) \
            + ((self._idx[_TAIL_OFF // 8] - self._idx[_HEAD_OFF // 8])
               & self._mask)

    @property
    def capacity(self) -> int:
        return self._mask  # one slot reserved (Lamport full/empty)

    def empty(self) -> bool:
        return not self._pending \
            and self._idx[_HEAD_OFF // 8] == self._idx[_TAIL_OFF // 8]

    def full(self) -> bool:
        return ((self._idx[_TAIL_OFF // 8] + 1) & self._mask) \
            == self._idx[_HEAD_OFF // 8]

    # -- producer side ------------------------------------------------------
    def _spill_path(self, seq: int) -> str:
        return os.path.join(self.spill_dir,
                            f"ffshm-{self.name.lstrip('/')}-{seq}.spill")

    def _typed_frame(self, item: Any):
        """``(kind, meta, buf)`` for a buffer-protocol payload, else None.

        Exact-type checks only: subclasses may carry state the raw bytes
        would silently drop, so they take the pickle path.
        """
        t = type(item)
        if t is bytes:
            return _KIND_RAW, _RAW_BYTES_META, item
        if t is bytearray:
            return _KIND_RAW, _RAW_BYTEARRAY_META, item
        if t is memoryview:
            if not item.c_contiguous:
                return None
            if item.format != "B" or item.ndim != 1:
                item = item.cast("B")
            return _KIND_RAW, _RAW_MEMORYVIEW_META, item
        np = sys.modules.get("numpy")
        if np is not None and t is np.ndarray:
            key = (item.dtype, item.shape)
            meta = self._nd_meta.get(key, False)
            if meta is False:
                meta = self._build_nd_meta(item)
                if len(self._nd_meta) < 256:  # bounded: hetero streams
                    self._nd_meta[key] = meta
            if meta is None or not item.flags.c_contiguous:
                return None
            return _KIND_NDARRAY, meta, item.data.cast("B")
        return None

    @staticmethod
    def _build_nd_meta(item: Any) -> Optional[bytes]:
        """ndarray meta header, or None when the dtype/shape is untyped
        (object/structured/0-d): those fall back to pickle.

        The header is zero-padded so the raw data lands on a 64-byte
        boundary when the frame starts a slot (slots are cache-line
        aligned): an unaligned 16 KiB memcpy measures ~4x slower than an
        aligned one, which is most of a zero-copy hand-off's budget."""
        if (item.dtype.hasobject or item.dtype.names is not None
                or item.ndim == 0 or item.ndim > 255):
            return None
        ds = item.dtype.str.encode("ascii")
        if len(ds) > 255:
            return None
        head = 3 + len(ds) + 8 * item.ndim
        pad = -(_SLOT_HDR.size + head) % _CACHE_LINE
        return struct.pack("<BBB", item.ndim, len(ds), pad) + ds \
            + struct.pack(f"<{item.ndim}Q", *item.shape) + b"\x00" * pad

    def _write_frame(self, off: int, meta: bytes, buf) -> None:
        mv = self._mv
        mlen = len(meta)
        if mlen:
            mv[off:off + mlen] = meta
        blen = len(buf)
        if blen:
            mv[off + mlen:off + mlen + blen] = buf

    def _write_pickled(self, base: int, item: Any) -> None:
        blob = pickle.dumps(item, _PICKLE_PROTO)
        if len(blob) <= self.slot_size:
            _SLOT_HDR.pack_into(self._mv, base, len(blob), _KIND_INLINE)
            self._mv[base + _SLOT_HDR.size:
                     base + _SLOT_HDR.size + len(blob)] = blob
        else:
            # spill side-channel: blob to a producer-owned file, slot
            # carries the sequence number (file is durable before the
            # tail store publishes the slot)
            seq = self._spill_seq
            self._spill_seq += 1
            with open(self._spill_path(seq), "wb") as f:
                f.write(blob)
            _SLOT_HDR.pack_into(self._mv, base, 8, _KIND_SPILL)
            struct.pack_into("<Q", self._mv, base + _SLOT_HDR.size, seq)

    def push(self, item: Any) -> bool:
        """Non-blocking enqueue. Returns False when full. Producer-only."""
        idx = self._idx
        tail = idx[_TAIL_OFF // 8]
        nxt = (tail + 1) & self._mask
        if nxt == idx[_HEAD_OFF // 8]:
            return False
        base = _DATA_OFF + (tail & self._mask) * self._stride
        frame = self._typed_frame(item) if self.zero_copy else None
        if frame is not None:
            kind, meta, buf = frame
            size = len(meta) + len(buf)
            if size <= self.slot_size:
                _SLOT_HDR.pack_into(self._mv, base, size, kind)
                self._write_frame(base + _SLOT_HDR.size, meta, buf)
            else:
                frame = None  # larger than a slot: spill the pickle
        if frame is None:
            self._write_pickled(base, item)
        idx[_TAIL_OFF // 8] = nxt  # publish AFTER the payload (order matters)
        self.pushes += 1
        return True

    def push_many(self, items: Sequence[Any]) -> int:
        """Pack a run of ``items`` into ONE slot (kind ``BATCH``).

        Returns how many leading items were consumed: 0 when the ring is
        full, otherwise at least 1 — an item whose frame alone exceeds
        the slot budget ships unbatched through ``push`` (taking the
        spill path if needed) so the caller's loop always advances.
        FIFO order is preserved; the consumer unpacks transparently.
        """
        if not items:
            return 0
        idx = self._idx
        tail = idx[_TAIL_OFF // 8]
        nxt = (tail + 1) & self._mask
        if nxt == idx[_HEAD_OFF // 8]:
            return 0
        base = _DATA_OFF + (tail & self._mask) * self._stride
        start = base + _SLOT_HDR.size
        limit = start + self.slot_size
        pos = start + 4  # u32 batch count, patched below
        count = 0
        for item in items:
            frame = self._typed_frame(item) if self.zero_copy else None
            if frame is None:
                kind, meta, buf = _KIND_INLINE, b"", \
                    pickle.dumps(item, _PICKLE_PROTO)
            else:
                kind, meta, buf = frame
            size = len(meta) + len(buf)
            if pos + _FRAME_HDR.size + size > limit:
                break
            _FRAME_HDR.pack_into(self._mv, pos, size, kind)
            pos += _FRAME_HDR.size
            self._write_frame(pos, meta, buf)
            pos += size
            count += 1
        if count == 0:
            # first item alone blows the batch budget: ship it solo
            return 1 if self.push(items[0]) else 0
        struct.pack_into("<I", self._mv, start, count)
        _SLOT_HDR.pack_into(self._mv, base, pos - start, _KIND_BATCH)
        idx[_TAIL_OFF // 8] = nxt
        self.pushes += count
        return count

    def push_wait(self, item: Any, timeout: Optional[float] = None) -> bool:
        """Blocking enqueue with truncated-exponential spin/yield backoff."""
        deadline = None if timeout is None else time.monotonic() + timeout
        backoff = Backoff()
        while not self.push(item):
            if not backoff.pause(deadline):
                return False
        return True

    # -- consumer side ------------------------------------------------------
    def _decode_frame(self, kind: int, off: int, length: int) -> Any:
        mv = self._mv
        if kind == _KIND_INLINE:
            return pickle.loads(mv[off:off + length])
        if kind == _KIND_RAW:
            raw = bytes(mv[off + 1:off + length])
            return bytearray(raw) if mv[off] == _RAW_BYTEARRAY else raw
        if kind == _KIND_NDARRAY:
            import numpy as np  # producer proved it importable (kind check)
            ndim, dlen, pad = mv[off], mv[off + 1], mv[off + 2]
            pos = off + 3
            dbytes = bytes(mv[pos:pos + dlen])
            dtype = self._nd_dtypes.get(dbytes)
            if dtype is None:
                dtype = np.dtype(dbytes.decode("ascii"))
                self._nd_dtypes[dbytes] = dtype
            pos += dlen
            shape = _shape_struct(ndim).unpack_from(mv, pos)
            pos += 8 * ndim + pad
            count = 1
            for dim in shape:
                count *= dim
            if not count:
                return np.empty(shape, dtype)
            # one aligned memcpy out of the segment (copy() owns its data:
            # the slot is free for reuse the moment head publishes)
            return np.frombuffer(mv, dtype, count, pos).reshape(shape).copy()
        raise ValueError(f"corrupt slot kind {kind!r}")  # pragma: no cover

    def pop(self) -> Any:
        """Non-blocking dequeue. Returns ``SPSCQueue._EMPTY`` when empty."""
        if self._pending:
            self.pops += 1
            return self._pending.popleft()
        idx = self._idx
        head = idx[_HEAD_OFF // 8]
        if head == idx[_TAIL_OFF // 8]:
            return SPSCQueue._EMPTY
        base = _DATA_OFF + (head & self._mask) * self._stride
        length, kind = _SLOT_HDR.unpack_from(self._mv, base)
        off = base + _SLOT_HDR.size
        if kind == _KIND_SPILL:
            seq = struct.unpack_from("<Q", self._mv, off)[0]
            path = self._spill_path(seq)
            with open(path, "rb") as f:
                raw = f.read()
            # decode BEFORE unlink and BEFORE the head store: a consumer
            # dying here leaves the file for the owner's sweep and the
            # slot intact for a retry — the item is never lost
            item = pickle.loads(raw)
            os.unlink(path)
        elif kind == _KIND_BATCH:
            count = struct.unpack_from("<I", self._mv, off)[0]
            pos = off + 4
            item = None
            pending = self._pending
            for i in range(count):
                flen, fkind = _FRAME_HDR.unpack_from(self._mv, pos)
                pos += _FRAME_HDR.size
                decoded = self._decode_frame(fkind, pos, flen)
                pos += flen
                if i == 0:
                    item = decoded
                else:
                    pending.append(decoded)
        else:
            item = self._decode_frame(kind, off, length)
        idx[_HEAD_OFF // 8] = (head + 1) & self._mask  # release AFTER reading
        self.pops += 1
        return item

    def pop_wait(self, timeout: Optional[float] = None) -> Any:
        """Blocking dequeue with truncated-exponential spin/yield backoff.

        Returns ``SPSCQueue._EMPTY`` on timeout.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        backoff = Backoff()
        while True:
            item = self.pop()
            if item is not SPSCQueue._EMPTY:
                return item
            if not backoff.pause(deadline):
                return SPSCQueue._EMPTY

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        """Drop this process's mapping (the segment survives for peers)."""
        if self._closed:
            return
        self._closed = True
        self._idx.release()
        self._mv = None
        self._shm.close()

    def __del__(self):
        # release the cast view before SharedMemory's own __del__ runs, or
        # its close() raises BufferError ("exported pointers exist")
        try:
            self.close()
        except Exception:  # pragma: no cover - interpreter-shutdown races
            pass

    def unlink(self) -> None:
        """Owner-only: destroy the segment and sweep leftover spill files."""
        self.close()
        if not self.owner:
            return
        for path in glob.glob(os.path.join(
                self.spill_dir, f"ffshm-{self.name.lstrip('/')}-*.spill")):
            try:
                os.unlink(path)
            except OSError:  # pragma: no cover - another sweep won the race
                pass
        try:
            self._shm.unlink()
        except FileNotFoundError:  # pragma: no cover - already unlinked
            pass


_SHAPE_STRUCTS: dict = {}


def _shape_struct(ndim: int) -> struct.Struct:
    s = _SHAPE_STRUCTS.get(ndim)
    if s is None:
        s = _SHAPE_STRUCTS[ndim] = struct.Struct(f"<{ndim}Q")
    return s


_RAW_BYTES_META = bytes([_RAW_BYTES])
_RAW_BYTEARRAY_META = bytes([_RAW_BYTEARRAY])
_RAW_MEMORYVIEW_META = bytes([_RAW_MEMORYVIEW])


def _attach_ring(name: str, mask: int, slot_size: int,
                 spill_dir: Optional[str] = None,
                 zero_copy: bool = True) -> ShmRing:
    ring = ShmRing.__new__(ShmRing)
    ShmRing.__init__(ring, mask, slot_size, name=name, spill_dir=spill_dir,
                     zero_copy=zero_copy, _attach=True)
    return ring


class ShmCounters:
    """``n`` single-writer u64 counters, one per cache line, in shared
    memory — the cross-process analogue of ``TagSpace``'s split counters.

    ``procgraph`` uses a 2-counter board per wrap-around farm: slot 0
    (``entered``) is written only by the dispatch arbiter, slot 1
    (``retired``) only by the merge arbiter; each side reads the other's
    slot benignly stale, with the same store-ordering argument as the
    ring (the merge arbiter pushes looped-back tasks *before* bumping
    ``retired``, so the dispatcher's quiescence check stays race-free).
    """

    def __init__(self, n: int = 2, *, name: Optional[str] = None,
                 _attach: bool = False):
        self.n = n
        if _attach:
            self._shm = shared_memory.SharedMemory(name=name)
            self.owner = False
        else:
            self._shm = shared_memory.SharedMemory(
                create=True, size=n * _CACHE_LINE, name=name)
            self.owner = True
        self.name = self._shm.name
        self._idx = self._shm.buf.cast("Q")
        if self.owner:
            for i in range(n):
                self._idx[i * (_CACHE_LINE // 8)] = 0
        self._closed = False

    def __reduce__(self):
        return (_attach_counters, (self.name, self.n))

    def get(self, i: int) -> int:
        return self._idx[i * (_CACHE_LINE // 8)]

    def snapshot(self) -> tuple:
        """All ``n`` values at once (each slot individually racy-fresh —
        fine for telemetry readouts like ``oocore.MemoryBudget.collect``,
        which runs after the writers have joined anyway)."""
        step = _CACHE_LINE // 8
        return tuple(self._idx[i * step] for i in range(self.n))

    def add(self, i: int, delta: int = 1) -> None:
        """Single-writer increment (exactly one process may write slot i)."""
        off = i * (_CACHE_LINE // 8)
        self._idx[off] = self._idx[off] + delta

    def peek(self) -> Optional[tuple]:
        """Teardown-safe :meth:`snapshot` for outside observers (the live
        monitor samples these boards from its own thread, which may race
        the graph's cleanup): returns ``None`` instead of raising once
        the board is closed or its memoryview released mid-read."""
        if self._closed:
            return None
        try:
            return self.snapshot()
        except (ValueError, OSError):  # released buf / vanished segment
            return None

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._idx.release()
        self._shm.close()

    def __del__(self):
        try:
            self.close()
        except Exception:  # pragma: no cover - interpreter-shutdown races
            pass

    def unlink(self) -> None:
        self.close()
        if self.owner:
            try:
                self._shm.unlink()
            except FileNotFoundError:  # pragma: no cover
                pass


def _attach_counters(name: str, n: int) -> ShmCounters:
    board = ShmCounters.__new__(ShmCounters)
    ShmCounters.__init__(board, n, name=name, _attach=True)
    return board


class ShmFlag:
    """A one-way cross-process flag in its own shared segment.

    Unlike the single-writer counters, *any* attached process may
    ``set()`` it: every writer stores the same value (1), so racing
    stores are idempotent and the usual single-writer discipline is not
    needed.  ``procgraph`` uses one per graph as the failure flag —
    vertices poll ``is_set()`` in their blocking loops and abort instead
    of wedging, and unlike ``multiprocessing.Event`` the flag pickles as
    a plain attach, so it can ride through queues to pooled workers.
    """

    def __init__(self, *, name: Optional[str] = None, _attach: bool = False):
        if _attach:
            self._shm = shared_memory.SharedMemory(name=name)
            self.owner = False
        else:
            self._shm = shared_memory.SharedMemory(
                create=True, size=_CACHE_LINE, name=name)
            self.owner = True
        self.name = self._shm.name
        self._idx = self._shm.buf.cast("Q")
        if self.owner:
            self._idx[0] = 0
        self._closed = False

    def __reduce__(self):
        return (_attach_flag, (self.name,))

    def set(self) -> None:
        self._idx[0] = 1

    def is_set(self) -> bool:
        return self._idx[0] != 0

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._idx.release()
        self._shm.close()

    def __del__(self):
        try:
            self.close()
        except Exception:  # pragma: no cover - interpreter-shutdown races
            pass

    def unlink(self) -> None:
        self.close()
        if self.owner:
            try:
                self._shm.unlink()
            except FileNotFoundError:  # pragma: no cover
                pass


def _attach_flag(name: str) -> ShmFlag:
    flag = ShmFlag.__new__(ShmFlag)
    ShmFlag.__init__(flag, name=name, _attach=True)
    return flag
