"""Live monitoring — continuous in-run telemetry, drift detection, and
bottleneck attribution.

PR 9's observability layer (:mod:`repro.core.obs`) records spans and
folds stats into a :class:`~repro.core.obs.RunReport` — but only at EOS.
Nothing can be observed *while* a stream runs, which is exactly when the
paper's fine-grain pathologies (a stalled SPSC edge, a mis-grained farm
— TR-09-12 Sec. 6) actually bite.  This module is the live half:

:class:`Monitor`
    A background sampler thread attached to a running
    :class:`~repro.core.graph.Graph` or
    :class:`~repro.core.procgraph.ProcGraph` through
    ``lower(skel, backend, monitor=...)``.  Every ``interval_s`` it
    snapshots live queue depths (the caller-side ``sample_depths()``
    tap — ``len()`` on a ring is a racy-but-benign read of the
    head/tail indices, cross-process included), per-farm service EWMAs
    and task counters (threads: the live ``FarmStats`` boards; procs:
    single-writer :class:`~repro.core.shm.ShmCounters` boards, no ring
    traffic), throughput (caller-side ``results`` length), and
    spill/stall counters (:class:`~repro.core.oocore.MemoryBudget`
    boards) into a :class:`Timeline`.  The mesh backend has no host
    vertices, so its program pushes one program-level frame per call
    (:meth:`Monitor.program_frame`).

:class:`Timeline`
    A bounded ring of timestamped frames (schema ``timeline/1``),
    JSON round-trippable, exportable as Perfetto **counter tracks**
    (``"ph": "C"``) that merge straight into
    :meth:`~repro.core.obs.Trace.to_chrome_json` output via its
    ``timeline=`` argument.

:func:`analyze` / :class:`BottleneckReport`
    Queueing attribution over a timeline (or busy-time attribution over
    a :class:`~repro.core.obs.Trace`): a stage is the bottleneck when
    its *inbound* pressure is high while its *outbound* queue runs dry
    — the classic upstream-full/downstream-empty signature — scored as
    ``pressure − outbound`` so the saturation cascade upstream of the
    slow stage does not steal the blame.  Recommendations are keyed to
    the autotune knobs (``grain``, ``capacity``, ``nworkers``,
    ``batch``) so the report plugs into ``retune()``'s vocabulary.

:class:`DriftWatcher`
    Diffs live service EWMAs against a saved autotune
    :class:`~repro.core.autotune.Profile` (via ``Profile.diff``) and
    fires :meth:`~repro.core.obs.MetricsRegistry.watch` callbacks when
    the relative drift crosses a threshold — the trigger half of the
    ROADMAP's online re-tuning arc.  A per-path latch fires exactly
    once per excursion and re-arms below half the threshold.

:class:`SLOMonitor`
    p99-latency / goodput thresholds over the serving engine's existing
    ``serve.request_latency_us`` histogram, with ``alert`` instants
    recorded into the trace.

``python -m repro.core.monitor report.json`` renders a one-shot
top-like terminal summary of a saved timeline (or run report).

Everything here is stdlib-only — no jax, no numpy — so the module is
safe in the eager ``repro.core`` import set and the spawn-import budget
(pinned in ``tests/test_lazy_import.py``).  With ``monitor=None`` (the
default) programs never enter this module at all (pinned by the
tracemalloc test, same pattern as the obs pin).
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

from .autotune import Profile, StageProfile
from .obs import MetricsRegistry, Trace
from .skeleton import (AllToAll, Farm, Feedback, Pipeline, Skeleton, Source,
                       Stage, walk_stats)

__all__ = ["Timeline", "Monitor", "DriftWatcher", "SLOMonitor",
           "BottleneckReport", "analyze", "KNOBS", "main"]

#: the tuning vocabulary recommendations are keyed to — the same knobs
#: ``retune()`` / ``plan_mesh()`` turn (see repro.core.autotune)
KNOBS = ("grain", "capacity", "nworkers", "batch")

#: vertex names that mark a position as a farm (threads and procs use
#: the same arbiter names, so attribution is backend-neutral)
_FARM_INTERNAL = ("ff-emitter", "ff-worker")
_FARM_OUT = "ff-collector"

_monotonic = time.monotonic


# ---------------------------------------------------------------------------
# the timeline: a bounded ring of timestamped frames
# ---------------------------------------------------------------------------
class Timeline:
    """Time-series storage for monitor frames — a bounded ring, so a
    long-lived stream cannot eat the heap: once ``capacity`` frames are
    held, the oldest is overwritten and ``dropped`` counts what fell
    off.  A frame is a plain dict::

        {"t": <monotonic seconds>,
         "depths":   {qualname: int},      # instantaneous queue depths
         "ewma_us":  {qualname: float},    # per-farm service EWMA, µs
         "counters": {name: int|float}}    # monotone counters

    JSON round-trips through :meth:`to_json` / :meth:`from_json`
    (schema ``timeline/1``); :meth:`chrome_events` renders the frames
    as Chrome trace-event counter tracks (``"ph": "C"``) that
    :meth:`repro.core.obs.Trace.to_chrome_json` merges via its
    ``timeline=`` argument."""

    schema = "timeline/1"

    def __init__(self, capacity: int = 4096):
        self.capacity = max(1, int(capacity))
        self._buf: List[dict] = []
        self._n = 0              # frames ever appended
        self._base_dropped = 0   # dropped count carried through from_json

    def append(self, frame: dict) -> None:
        if len(self._buf) < self.capacity:
            self._buf.append(frame)
        else:
            self._buf[self._n % self.capacity] = frame
        self._n += 1

    def __len__(self) -> int:
        return len(self._buf)

    @property
    def dropped(self) -> int:
        return self._base_dropped + max(0, self._n - self.capacity)

    def frames(self) -> List[dict]:
        """The held frames, oldest first (ring order reconstructed)."""
        if self._n <= self.capacity:
            return list(self._buf)
        cut = self._n % self.capacity
        return self._buf[cut:] + self._buf[:cut]

    def span_s(self) -> float:
        fs = self.frames()
        if len(fs) < 2:
            return 0.0
        return max(0.0, fs[-1]["t"] - fs[0]["t"])

    # -- serialization -------------------------------------------------------
    def to_json(self) -> dict:
        return {"schema": self.schema, "capacity": self.capacity,
                "dropped": self.dropped, "frames": self.frames()}

    @classmethod
    def from_json(cls, d: dict) -> "Timeline":
        if d.get("schema") != cls.schema:
            raise ValueError(f"not a timeline: {d.get('schema')!r}")
        tl = cls(capacity=int(d.get("capacity", 4096)))
        for f in d.get("frames", []):
            tl.append(f)
        tl._base_dropped = int(d.get("dropped", 0))
        return tl

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)

    @classmethod
    def load(cls, path: str) -> "Timeline":
        with open(path) as f:
            return cls.from_json(json.load(f))

    # -- Perfetto export -----------------------------------------------------
    def chrome_events(self, pid: int = 0) -> List[dict]:
        """The frames as Chrome trace-event **counter** records: one
        ``"C"`` event per (frame, series) under a dedicated
        ``ff-monitor`` process, so Perfetto draws queue depths, service
        EWMAs and counters as value tracks right above the span lanes
        the :class:`~repro.core.obs.Trace` exports."""
        evs: List[dict] = [{"name": "process_name", "ph": "M", "pid": pid,
                            "tid": 0, "args": {"name": "ff-monitor"}}]

        def counter(name: str, ts: float, value: Any) -> dict:
            return {"name": name, "ph": "C", "pid": pid, "tid": 0,
                    "ts": ts, "args": {"value": value}}

        for f in self.frames():
            ts = f.get("t", 0.0) * 1e6
            for qual, v in sorted(f.get("depths", {}).items()):
                evs.append(counter(f"depth:{qual}", ts, v))
            for qual, v in sorted(f.get("ewma_us", {}).items()):
                evs.append(counter(f"svc_us:{qual}", ts, v))
            for k, v in sorted(f.get("counters", {}).items()):
                evs.append(counter(k, ts, v))
        return evs


# ---------------------------------------------------------------------------
# skeleton walks: live telemetry boards the sampler reads
# ---------------------------------------------------------------------------
def _walk_budgets(skel: Skeleton, path: str = "") -> Iterable[Tuple[str, Any]]:
    """Yield ``(qualname, MemoryBudget)`` for every budget-carrying node
    in the IR tree (spill-to-disk folds), deduplicated — one a2a row
    shares one budget across its partitions."""
    seen: set = set()

    def walk(s: Skeleton, p: str) -> Iterable[Tuple[str, Any]]:
        if isinstance(s, Pipeline):
            for i, sub in enumerate(s.stages):
                yield from walk(sub, f"{p}.{i}" if p else str(i))
            return
        if isinstance(s, Farm):
            nodes, name = list(s.worker_nodes), "ff-farm"
        elif isinstance(s, AllToAll):
            nodes, name = list(s.left_nodes) + list(s.right_nodes), s.name
        elif isinstance(s, (Stage, Source, Feedback)):
            nodes, name = [s.node], s.name
        else:
            return
        for n in nodes:
            b = getattr(n, "budget", None)
            if b is not None and id(b) not in seen:
                seen.add(id(b))
                yield (f"{name}@{p}" if p else name), b

    yield from walk(skel, path)


# ---------------------------------------------------------------------------
# the monitor: a background sampler thread
# ---------------------------------------------------------------------------
class Monitor:
    """Continuous in-run telemetry: a daemon thread sampling a running
    graph into a :class:`Timeline` every ``interval_s``.

    Wire it through lowering — ``lower(skel, "threads", monitor=True)``
    (or a shared ``Monitor`` instance; ``"procs"`` likewise, ``"mesh"``
    gets one program-level frame per call) — or drive it by hand with
    :meth:`attach` / :meth:`detach` around ``graph.run()``.

    The sampler is an outside observer: every read is a racy-but-benign
    snapshot of single-writer state (ring head/tail indices, FarmStats
    fields, ShmCounters slots), so it costs the stream nothing but
    cache traffic.  Teardown races (a procs ring unlinked mid-sample)
    are absorbed, counted in ``errors``, never raised.

    ``profile=`` (an autotune :class:`Profile` or a path) arms a
    :class:`DriftWatcher` over the live service EWMAs;
    ``registry=`` routes drift events through
    :meth:`~repro.core.obs.MetricsRegistry.watch` callbacks;
    ``on_frame=`` is called with every completed frame (the seam an
    elastic-farm controller hangs off)."""

    def __init__(self, *, interval_s: float = 0.002, capacity: int = 4096,
                 profile: Any = None, drift_threshold: float = 0.5,
                 registry: Optional[MetricsRegistry] = None,
                 on_frame: Optional[Callable[[dict], None]] = None):
        self.interval_s = float(interval_s)
        self.timeline = Timeline(capacity)
        self.registry = registry
        self.on_frame = on_frame
        self.drift: Optional[DriftWatcher] = None
        if profile is not None:
            self.drift = DriftWatcher(profile, threshold=drift_threshold,
                                      registry=registry)
        self.backend: Optional[str] = None
        self.errors = 0           # absorbed sampling failures (teardown races)
        self._target: Any = None
        self._stats: List[Tuple[str, Any]] = []
        self._budgets: List[Tuple[str, Any]] = []
        self._boards: Dict[str, Any] = {}
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle -----------------------------------------------------------
    def attach(self, target: Any, skeleton: Optional[Skeleton] = None,
               backend: Optional[str] = None) -> "Monitor":
        """Start sampling ``target`` (a :class:`~repro.core.graph.Graph`
        or :class:`~repro.core.procgraph.ProcGraph`).  ``skeleton``
        supplies the stats/budget boards to read alongside the queue
        depths.  Reattaching after :meth:`detach` appends to the same
        timeline (frames carry monotonic stamps, so runs concatenate)."""
        if self._thread is not None:
            raise RuntimeError("monitor already attached; detach() first")
        self._target = target
        self._boards = dict(getattr(target, "live_boards", None) or {})
        self.backend = backend or ("procs" if hasattr(target, "live_boards")
                                   else "threads")
        self._stats = list(walk_stats(skeleton)) if skeleton is not None \
            else []
        self._budgets = list(_walk_budgets(skeleton)) \
            if skeleton is not None else []
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, name="ff-monitor",
                                        daemon=True)
        self._thread.start()
        return self

    def detach(self) -> Timeline:
        """Stop the sampler, take one final drain-time frame (the procs
        backend has folded its FarmStats home by now, so this frame
        carries the run's final EWMAs), drop every target reference."""
        th = self._thread
        if th is not None:
            self._stop.set()
            th.join(timeout=5.0)
            self._thread = None
            self.sample()
        self._target = None
        self._stats = []
        self._budgets = []
        self._boards = {}
        return self.timeline

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.sample()

    # -- sampling ------------------------------------------------------------
    def sample(self) -> Optional[dict]:
        """Take one frame now (the thread calls this; callers may too,
        e.g. for deterministic tests).  Never raises: a sampler must not
        be able to kill the stream it watches."""
        try:
            frame = self._frame()
        except Exception:
            self.errors += 1
            return None
        self.timeline.append(frame)
        if self.drift is not None and frame["ewma_us"]:
            try:
                self.drift.check(frame["ewma_us"])
            except Exception:
                self.errors += 1
        if self.on_frame is not None:
            try:
                self.on_frame(frame)
            except Exception:
                self.errors += 1
        return frame

    def _frame(self) -> dict:
        target = self._target
        depths: Dict[str, int] = {}
        if target is not None:
            try:
                target.sample_depths(depths)
            except Exception:
                self.errors += 1
        ewma: Dict[str, float] = {}
        counters: Dict[str, Any] = {}
        results = getattr(target, "results", None)
        if results is not None:
            counters["items_out"] = len(results)
        # threads: the FarmStats boards are live shared objects; procs
        # fills them only at EOS (the detach-time frame picks those up)
        for qual, st in self._stats:
            try:
                d = st.service_ewma
                if d:
                    ewma[qual] = sum(d.values()) / len(d) * 1e6
                counters[f"{qual}.emitted"] = st.tasks_emitted
                counters[f"{qual}.collected"] = st.tasks_collected
            except Exception:
                self.errors += 1
        # procs: live single-writer counter boards, read caller-side —
        # no ring traffic, no arbiter involvement (overwrites the stale
        # FarmStats zeros above while the run is in flight)
        for qual, board in self._boards.items():
            vals = board.peek()
            if vals is not None:
                counters[f"{qual}.emitted"] = int(vals[0])
                counters[f"{qual}.collected"] = int(vals[1])
        for qual, budget in self._budgets:
            try:
                counters[f"{qual}.spills"] = budget.spills()
                counters[f"{qual}.stalls"] = budget.stalls()
            except Exception:
                pass    # board mid-teardown: keep the frame
        return {"t": _monotonic(), "depths": depths, "ewma_us": ewma,
                "counters": counters}

    def program_frame(self, counters: Dict[str, Any]) -> dict:
        """Mesh tap: the program has no host vertices to sample, so it
        pushes one program-level counter frame per call."""
        frame = {"t": _monotonic(), "depths": {}, "ewma_us": {},
                 "counters": dict(counters)}
        self.timeline.append(frame)
        if self.on_frame is not None:
            try:
                self.on_frame(frame)
            except Exception:
                self.errors += 1
        return frame


# ---------------------------------------------------------------------------
# the drift watcher: live EWMAs vs a saved pilot profile
# ---------------------------------------------------------------------------
class DriftWatcher:
    """The trigger half of online re-tuning: compare live service EWMAs
    against a saved autotune :class:`Profile` (through ``Profile.diff``
    — the ROADMAP's designated seam) and fire when the relative drift
    crosses ``threshold``.

    Each IR path carries a latch: one firing per excursion, re-armed
    only once the drift falls back under ``threshold / 2`` — so a
    stage sitting *at* the threshold cannot machine-gun callbacks.
    Firings append to ``events`` and, when a ``registry`` is given,
    run through ``registry.finalize(registry.report(meta=event))`` so
    every ``registry.watch()`` callback sees them."""

    def __init__(self, saved: Any, *, threshold: float = 0.5,
                 registry: Optional[MetricsRegistry] = None):
        self.saved: Profile = Profile.load(saved) if isinstance(saved, str) \
            else saved
        self.threshold = float(threshold)
        self.registry = registry
        self.events: List[dict] = []
        self._armed: Dict[str, bool] = {}

    def check(self, live_ewma_us: Dict[str, float]) -> List[dict]:
        """One comparison pass over ``{qualname: live EWMA µs}``;
        returns the events fired by this pass (also kept in
        ``events``)."""
        stages = []
        for qual, us in sorted(live_ewma_us.items()):
            name, _, path = qual.rpartition("@") if "@" in qual \
                else (qual, "", "")
            stages.append(StageProfile(path=path, kind="live", name=name,
                                       service_us=float(us),
                                       service_ewma_us=float(us), items=1))
        live = Profile(handoff_us=self.saved.handoff_us, pilot_items=0,
                       stages=stages)
        fired: List[dict] = []
        for path, d in live.diff(self.saved).items():
            mine, theirs = d["service_us"]
            if mine is None or theirs is None or theirs <= 0:
                continue
            rel = abs(mine - theirs) / theirs
            armed = self._armed.get(path, True)
            if rel > self.threshold and armed:
                self._armed[path] = False
                ev = {"event": "drift", "path": path, "live_us": mine,
                      "saved_us": theirs, "rel": rel,
                      "threshold": self.threshold}
                self.events.append(ev)
                fired.append(ev)
                reg = self.registry
                if reg is not None:
                    reg.counter("monitor.drift_alerts").inc()
                    reg.finalize(reg.report(meta=ev))
            elif rel < self.threshold / 2 and not armed:
                self._armed[path] = True
        return fired


# ---------------------------------------------------------------------------
# the SLO monitor: latency/goodput thresholds for the serving engine
# ---------------------------------------------------------------------------
class SLOMonitor:
    """Service-level thresholds over live serving telemetry: fire when
    the request-latency p99 exceeds ``p99_us`` or goodput falls under
    ``min_goodput`` (tokens/s — any rate the caller supplies).

    Same latch discipline as :class:`DriftWatcher` (one alert per
    excursion, re-armed when the signal recovers).  Alerts append to
    ``events``; :meth:`bind` a :class:`~repro.core.obs.Tracer` to also
    record each alert as an ``alert`` instant on an ``slo-monitor``
    lane, so the trace shows *when* the SLO broke relative to the
    decode spans; a ``registry`` routes alerts through its ``watch()``
    callbacks and counts them in ``slo.alerts``."""

    def __init__(self, *, p99_us: Optional[float] = None,
                 min_goodput: Optional[float] = None,
                 registry: Optional[MetricsRegistry] = None):
        self.p99_us = p99_us
        self.min_goodput = min_goodput
        self.registry = registry
        self.events: List[dict] = []
        self._lane = None
        self._armed = {"latency": True, "goodput": True}

    def bind(self, tracer: Any) -> "SLOMonitor":
        self._lane = tracer.vertex("slo-monitor")
        return self

    def _fire(self, kind: str, ev: dict) -> None:
        self._armed[kind] = False
        self.events.append(ev)
        if self._lane is not None:
            self._lane.instant("alert", ev)
        reg = self.registry
        if reg is not None:
            reg.counter("slo.alerts").inc()
            reg.finalize(reg.report(meta=ev))

    def check(self, hist: Any = None,
              goodput: Optional[float] = None) -> List[dict]:
        """One evaluation pass: ``hist`` is a latency
        :class:`~repro.core.obs.Histogram` (µs), ``goodput`` a rate.
        Returns the alerts fired by this pass."""
        before = len(self.events)
        if self.p99_us is not None and hist is not None \
                and getattr(hist, "count", 0):
            p99 = hist.p99
            if p99 > self.p99_us and self._armed["latency"]:
                self._fire("latency", {
                    "event": "slo", "signal": "p99_latency_us",
                    "value": p99, "threshold": self.p99_us})
            elif p99 <= self.p99_us:
                self._armed["latency"] = True
        if self.min_goodput is not None and goodput is not None:
            if goodput < self.min_goodput and self._armed["goodput"]:
                self._fire("goodput", {
                    "event": "slo", "signal": "goodput",
                    "value": goodput, "threshold": self.min_goodput})
            elif goodput >= self.min_goodput:
                self._armed["goodput"] = True
        return self.events[before:]


# ---------------------------------------------------------------------------
# the bottleneck analyzer
# ---------------------------------------------------------------------------
class BottleneckReport:
    """Structured verdict from :func:`analyze`.

    ``stage`` names the dominant bottleneck (``None`` when the network
    is balanced), ``edge`` the producer vertex whose outbound ring
    carries the pressure, ``verdict`` is ``queue-bound`` /
    ``compute-bound`` / ``balanced``.  ``utilization`` is per-stage
    (fraction of frames with work queued inbound, or busy-time fraction
    from a trace); ``attribution`` shares out the blame (positive
    scores, normalised); ``recommendations`` are keyed to the autotune
    knobs (:data:`KNOBS`)."""

    schema = "bottleneck-report/1"

    def __init__(self, *, stage: Optional[str], edge: Optional[str],
                 verdict: str, utilization: Dict[str, float],
                 attribution: Dict[str, float],
                 recommendations: List[Dict[str, str]],
                 mean_depths: Optional[Dict[str, float]] = None,
                 frames: int = 0, throughput: Optional[float] = None):
        self.stage = stage
        self.edge = edge
        self.verdict = verdict
        self.utilization = utilization
        self.attribution = attribution
        self.recommendations = recommendations
        self.mean_depths = dict(mean_depths or {})
        self.frames = frames
        self.throughput = throughput

    def to_json(self) -> dict:
        return {"schema": self.schema, "stage": self.stage,
                "edge": self.edge, "verdict": self.verdict,
                "utilization": self.utilization,
                "attribution": self.attribution,
                "recommendations": self.recommendations,
                "mean_depths": self.mean_depths, "frames": self.frames,
                "throughput": self.throughput}

    def render(self) -> str:
        lines = [f"bottleneck: {self.stage or '(none)'}  [{self.verdict}]"]
        if self.edge:
            lines.append(f"  hottest edge: {self.edge} -> {self.stage}")
        if self.throughput is not None:
            lines.append(f"  throughput: {self.throughput:.1f} items/s")
        if self.utilization:
            lines.append(f"  {'stage':<28}{'util':>7}{'share':>8}")
            for label in sorted(self.utilization):
                util = self.utilization[label]
                share = self.attribution.get(label, 0.0)
                lines.append(f"  {label:<28}{util:>6.0%}{share:>7.0%}")
        for rec in self.recommendations:
            lines.append(f"  -> {rec['knob']}: {rec['action']}")
        return "\n".join(lines)

    def __repr__(self) -> str:
        return (f"BottleneckReport(stage={self.stage!r}, "
                f"verdict={self.verdict!r}, "
                f"recommend={[r['knob'] for r in self.recommendations]})")


def _split_qual(qual: str) -> Tuple[str, str]:
    if "@" in qual:
        name, _, path = qual.rpartition("@")
        return name, path
    return qual, ""


def _pos_key(pos: str) -> Tuple[int, Any]:
    if pos == "in":
        return (-1, "")
    head = pos.split(".", 1)[0]
    return (int(head), pos) if head.isdigit() else (10**9, pos)


def analyze(source: Any, *, min_depth: float = 0.5) -> BottleneckReport:
    """Attribute the bottleneck in a :class:`Timeline` (or its
    ``timeline/1`` JSON dict), or — busy-time flavour — in a
    :class:`~repro.core.obs.Trace`.

    Queueing attribution has to beat the saturation cascade: when one
    stage runs 10× slow, *every* queue upstream of it fills (the source
    stalls against stage 0, stage 0 against stage 1, …), so naive
    argmax-over-depth blames the frontmost edge.  The score here is
    ``pressure − outbound``: the slow stage is the one whose inbound
    (or farm-internal) queues are deep while its own outbound queue is
    drained by an idle consumer.  ``min_depth`` is the mean-depth floor
    under which the network is called balanced."""
    if isinstance(source, Trace):
        return _analyze_trace(source)
    if isinstance(source, Timeline):
        frames = source.frames()
    elif isinstance(source, dict):
        if source.get("schema") != Timeline.schema:
            raise ValueError(
                f"analyze() wants timeline/1 JSON, got "
                f"{source.get('schema')!r}")
        frames = list(source.get("frames", []))
    else:
        raise TypeError(f"cannot analyze {type(source).__name__}")
    return _analyze_frames(frames, min_depth)


def _analyze_frames(frames: List[dict], min_depth: float) -> BottleneckReport:
    n = len(frames)
    sums: Dict[str, float] = {}
    nonzero: Dict[str, int] = {}
    for f in frames:
        for qual, v in f.get("depths", {}).items():
            sums[qual] = sums.get(qual, 0.0) + v
            if v > 0:
                nonzero[qual] = nonzero.get(qual, 0) + 1
    means = {q: s / max(1, n) for q, s in sums.items()}
    busy = {q: nonzero.get(q, 0) / max(1, n) for q in sums}

    # group vertices by top-level IR position ("in" = the driving source)
    groups: Dict[str, List[str]] = {}
    for qual in means:
        _, path = _split_qual(qual)
        pos = path.split(".", 1)[0] if path else ""
        groups.setdefault(pos, []).append(qual)
    order = sorted(groups, key=_pos_key)

    # per position: the outbound tap, the farm-internal taps, a label
    info: Dict[str, dict] = {}
    for pos, members in groups.items():
        internal = [q for q in members
                    if _split_qual(q)[0].startswith(_FARM_INTERNAL)]
        out_q = next((q for q in members
                      if _split_qual(q)[0].startswith(_FARM_OUT)), None)
        if internal or out_q:
            label = f"ff-farm@{pos}"
        else:
            label = max(members, key=lambda q: means[q])
        if out_q is None:
            out_q = label if label in means else members[0]
        info[pos] = {"label": label, "out": out_q, "internal": internal}

    # score: pressure (inbound or farm-internal depth) minus outbound
    scored: List[dict] = []
    prev_out: Optional[str] = None
    for pos in order:
        d = info[pos]
        inbound = means.get(prev_out, 0.0) if prev_out is not None else 0.0
        inbound_q = prev_out
        internal = max((means[q] for q in d["internal"]), default=0.0)
        internal_q = max(d["internal"], key=lambda q: means[q]) \
            if d["internal"] else None
        out = means.get(d["out"], 0.0)
        if inbound >= internal:
            pressure, pressure_q = inbound, inbound_q
        else:
            pressure, pressure_q = internal, internal_q
        if pos != "in":      # the driving source has no inbound edge
            scored.append({
                "pos": pos, "label": d["label"], "pressure": pressure,
                "edge": pressure_q, "out": out,
                "score": pressure - out,
                "util": busy.get(pressure_q, 0.0) if pressure_q else 0.0,
                "is_farm": bool(d["internal"])})
        prev_out = d["out"]

    throughput = _throughput(frames)
    utilization = {s["label"]: s["util"] for s in scored}
    positive = {s["label"]: s["score"] for s in scored if s["score"] > 0}
    total = sum(positive.values())
    attribution = {k: v / total for k, v in positive.items()} if total else {}

    if not scored:
        return BottleneckReport(stage=None, edge=None, verdict="balanced",
                                utilization={}, attribution={},
                                recommendations=[], mean_depths=means,
                                frames=n, throughput=throughput)
    top = max(scored, key=lambda s: s["score"])
    if top["pressure"] < min_depth:
        return BottleneckReport(
            stage=None, edge=None, verdict="balanced",
            utilization=utilization, attribution={}, recommendations=[],
            mean_depths=means, frames=n, throughput=throughput)
    recs = _recommend(top)
    return BottleneckReport(
        stage=top["label"], edge=top["edge"], verdict="queue-bound",
        utilization=utilization, attribution=attribution,
        recommendations=recs, mean_depths=means, frames=n,
        throughput=throughput)


def _throughput(frames: List[dict]) -> Optional[float]:
    pts = [(f["t"], f["counters"]["items_out"]) for f in frames
           if "items_out" in f.get("counters", {})]
    if len(pts) < 2 or pts[-1][0] <= pts[0][0]:
        return None
    return (pts[-1][1] - pts[0][1]) / (pts[-1][0] - pts[0][0])


def _recommend(top: dict) -> List[Dict[str, str]]:
    """Knob-keyed recommendations (the autotune vocabulary, so the
    report plugs into ``retune()``'s levers)."""
    label = top["label"]
    if top["is_farm"]:
        recs = [{"knob": "nworkers", "target": label,
                 "action": f"widen {label}: workers are saturated "
                           f"(pressure depth {top['pressure']:.1f} vs "
                           f"outbound {top['out']:.1f})"}]
        if top["pressure"] > 4 * max(top["out"], 0.25):
            recs.append({"knob": "capacity", "target": label,
                         "action": f"deepen the inbound ring of {label} "
                                   f"only if the imbalance is bursty; "
                                   f"sustained imbalance needs width"})
        return recs
    return [{"knob": "nworkers", "target": label,
             "action": f"parallelise {label}: wrap it in a Farm "
                       f"(inbound queue depth {top['pressure']:.1f}, "
                       f"outbound {top['out']:.1f})"},
            {"knob": "grain", "target": label,
             "action": f"declare the measured grain on {label} so "
                       f"retune() can size rings and micro-batch "
                       f"around it"}]


def _analyze_trace(trace: Trace) -> BottleneckReport:
    """Busy-time attribution from span lanes: the stage whose vertices
    spend the largest fraction of the RUN in ``svc`` is the critical
    path.  The denominator is the common run window, not each lane's
    own lifetime — a fast stage's lane dies early, so dividing by its
    short life would score it as busy as the stage everyone waits on
    (and sampled spans scale every lane's numerator equally, so the
    window-relative ranking survives sampling)."""
    t_lo, t_hi = None, None
    svc_by_qual: Dict[str, float] = {}
    for vt in trace.lanes:
        for e in vt.events:
            if e[1] is not None:
                t_lo = e[1] if t_lo is None else min(t_lo, e[1])
            if len(e) > 2 and isinstance(e[2], (int, float)):
                t_hi = e[2] if t_hi is None else max(t_hi, e[2])
        svc = sum(e[2] - e[1] for e in vt.events
                  if e[0] == "svc" and e[2] is not None)
        if svc > 0:
            svc_by_qual[vt.qualname] = svc
    window = (t_hi - t_lo) if t_lo is not None and t_hi is not None else 0.0
    util: Dict[str, float] = {}
    if window > 0:
        util = {q: min(1.0, s / window) for q, s in svc_by_qual.items()}
    if not util:
        return BottleneckReport(stage=None, edge=None, verdict="balanced",
                                utilization={}, attribution={},
                                recommendations=[], frames=0)
    top = max(util, key=lambda q: util[q])
    total = sum(util.values())
    attribution = {q: v / total for q, v in util.items()}
    name, path = _split_qual(top)
    is_farm = name.startswith(_FARM_INTERNAL + (_FARM_OUT,))
    label = f"ff-farm@{path.split('.', 1)[0]}" if is_farm and path else top
    fake = {"label": label, "pressure": util[top], "out": 0.0,
            "is_farm": is_farm}
    return BottleneckReport(
        stage=label, edge=None, verdict="compute-bound",
        utilization=util, attribution=attribution,
        recommendations=_recommend(fake), frames=len(trace.lanes))


# ---------------------------------------------------------------------------
# the CLI: one-shot top-like summary of a saved timeline / run report
# ---------------------------------------------------------------------------
def _render_timeline(tl: Timeline) -> str:
    frames = tl.frames()
    lines = [f"ff-monitor: {len(frames)} frames over {tl.span_s():.3f}s"
             f" ({tl.dropped} dropped)"]
    sums: Dict[str, float] = {}
    maxes: Dict[str, int] = {}
    nonzero: Dict[str, int] = {}
    for f in frames:
        for q, v in f.get("depths", {}).items():
            sums[q] = sums.get(q, 0.0) + v
            maxes[q] = max(maxes.get(q, 0), v)
            if v > 0:
                nonzero[q] = nonzero.get(q, 0) + 1
    if sums:
        lines.append(f"  {'queue (producer vertex)':<28}"
                     f"{'mean':>7}{'max':>6}{'busy':>6}")
        for q in sorted(sums, key=lambda x: -sums[x]):
            mean = sums[q] / max(1, len(frames))
            busy = nonzero.get(q, 0) / max(1, len(frames))
            lines.append(f"  {q:<28}{mean:>7.1f}{maxes[q]:>6}{busy:>6.0%}")
    if frames:
        last = frames[-1].get("counters", {})
        if last:
            kv = " ".join(f"{k}={last[k]}" for k in sorted(last))
            lines.append(f"  counters: {kv}")
        ewma = frames[-1].get("ewma_us", {})
        for q in sorted(ewma):
            lines.append(f"  svc ewma {q}: {ewma[q]:.1f}us")
    return "\n".join(lines)


def _render_run_report(doc: dict) -> str:
    lines = ["run-report/1 summary"]
    meta = doc.get("meta", {})
    if meta:
        kv = " ".join(f"{k}={meta[k]}" for k in sorted(meta))
        lines.append(f"  meta: {kv}")
    for k in sorted(doc.get("counters", {})):
        lines.append(f"  counter {k} = {doc['counters'][k]}")
    for k in sorted(doc.get("hists", {})):
        h = doc["hists"][k]
        lines.append(f"  hist {k}: count={h.get('count', 0)} "
                     f"p50={h.get('p50', 0.0):.1f} "
                     f"p99={h.get('p99', 0.0):.1f}")
    queues = doc.get("queues", {})
    if queues:
        deepest = sorted(queues, key=lambda q: -queues[q])[:8]
        for q in deepest:
            lines.append(f"  queue high-water {q} = {queues[q]}")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.monitor",
        description="One-shot top-like summary of a saved timeline/1 "
                    "(with bottleneck attribution) or run-report/1 JSON.")
    ap.add_argument("report", help="path to a timeline/1 or run-report/1 "
                                   "JSON file")
    args = ap.parse_args(argv)
    with open(args.report) as f:
        doc = json.load(f)
    schema = doc.get("schema")
    if schema == Timeline.schema:
        tl = Timeline.from_json(doc)
        print(_render_timeline(tl))
        print(analyze(tl).render())
        return 0
    if schema == "run-report/1":
        print(_render_run_report(doc))
        return 0
    print(f"unrecognised schema {schema!r} "
          f"(want {Timeline.schema!r} or 'run-report/1')", file=sys.stderr)
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
