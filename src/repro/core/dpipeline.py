"""Pipeline skeleton on a mesh axis — streaming microbatches over SPSC edges.

FastFlow's pipeline is a chain of nodes connected by SPSC queues.  Mapped to
the mesh: each *stage* is a device group along the ``stage`` axis; each edge
is a ``chain_send`` (non-wrapping collective-permute).  Microbatches stream
through the chain; at tick t, stage s processes microbatch (t - s) — the
GPipe/1F1B family expressed as a static streaming-network schedule rather
than an imperative scheduler.

The implementation is SPMD: every stage runs the same ``lax.scan``; stage
identity comes from ``lax.axis_index``.  The pipeline is differentiable
(gradients flow back through the ppermute edges, which transpose to the
reverse-chain sends), so the same skeleton serves training and inference.

Bubble accounting (recorded in EXPERIMENTS.md): with S stages and M
microbatches, utilisation = M / (M + S - 1).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import (axis_size as _axis_size, needs_pvary as _needs_pvary,
                      pvary as _pvary)
from .dchannel import chain_send

__all__ = ["pipeline_apply", "pipeline_utilisation"]


def pipeline_utilisation(n_stages: int, n_micro: int) -> float:
    return n_micro / (n_micro + n_stages - 1)


def pipeline_apply(
    stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
    stage_params: Any,
    microbatches: jnp.ndarray,
    *,
    axis_name: str = "stage",
    collect: str = "psum",
) -> jnp.ndarray:
    """Stream ``microbatches`` through the stage chain.

    Must be called inside ``shard_map`` with ``axis_name`` in scope and with
    ``stage_params`` already sharded so each device group holds its own
    stage's parameters.

    Args:
      stage_fn: ``y = stage_fn(params_local, x)`` — one stage's compute.
      stage_params: this stage's parameter shard.
      microbatches: ``(M, mb, ...)`` array, replicated view; stage 0 reads
        microbatch t at tick t, later stages ignore it and consume their
        inbound SPSC slot instead.

    Returns:
      ``(M, mb, ...)`` outputs as produced by the *last* stage.  With
      ``collect="psum"`` (default) they are summed over the stage axis
      (inactive stages contribute zeros) so the result is replicated and can
      leave the shard_map with an unsharded spec; ``collect="local"`` returns
      the raw per-stage emit.
    """
    n_stages = _axis_size(axis_name)
    stage = lax.axis_index(axis_name)
    m = microbatches.shape[0]
    ticks = m + n_stages - 1
    mb_shape = microbatches.shape[1:]

    def tick(carry, t):
        inbound = carry  # slot arriving over the SPSC edge from stage-1
        # stage 0's "queue" is the input stream itself
        idx = jnp.clip(t, 0, m - 1)
        first_in = lax.dynamic_index_in_dim(microbatches, idx, keepdims=False)
        first_in = _pvary(first_in, (axis_name,)) if _needs_pvary(first_in, axis_name) else first_in
        x = jnp.where(stage == 0, first_in, inbound)
        active = (t >= stage) & (t - stage < m)
        y = stage_fn(stage_params, x)
        y = jnp.where(active, y, jnp.zeros_like(y))
        # push onto the outbound SPSC edge (last stage's send is dropped)
        out_slot = chain_send(y, axis_name)
        # last stage emits: place the finished microbatch in the output slot
        emit = jnp.where((stage == n_stages - 1) & active, y, jnp.zeros_like(y))
        return out_slot, emit

    init = jnp.zeros(mb_shape, microbatches.dtype)
    if _needs_pvary(init, axis_name):
        init = _pvary(init, (axis_name,))
    _, emitted = lax.scan(tick, init, jnp.arange(ticks))
    # emitted[t] holds microbatch (t - (S-1)); realign to microbatch order
    out = lax.dynamic_slice_in_dim(emitted, n_stages - 1, m, axis=0)
    if collect == "psum":
        out = lax.psum(out, axis_name)
    return out
