"""Pipeline skeleton on a mesh axis — streaming microbatches over SPSC edges.

FastFlow's pipeline is a chain of nodes connected by SPSC queues.  Mapped to
the mesh: each *stage* is a device group along the ``stage`` axis; each edge
is a ``chain_send`` (non-wrapping collective-permute).  Microbatches stream
through the chain; at tick t, stage s processes microbatch (t - s) — the
GPipe/1F1B family expressed as a static streaming-network schedule rather
than an imperative scheduler.

The implementation is SPMD: every stage runs the same ``lax.scan``; stage
identity comes from ``lax.axis_index``.  The pipeline is differentiable
(gradients flow back through the ppermute edges, which transpose to the
reverse-chain sends), so the same skeleton serves training and inference.

Bubble accounting (recorded in EXPERIMENTS.md): with S stages and M
microbatches, utilisation = M / (M + S - 1).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import axis_size as _axis_size, vma_align as _vma_align
from .dchannel import chain_send

__all__ = ["pipeline_apply", "pipeline_utilisation", "negotiate_stage_axis",
           "best_factorization"]


def pipeline_utilisation(n_stages: int, n_micro: int) -> float:
    return n_micro / (n_micro + n_stages - 1)


def best_factorization(n_stages: int, n_devices: int,
                       stage_costs=None, n_micro=None):
    """Pick the ``(stage, worker)`` mesh factorization with the higher
    modelled throughput — the autotuner's mesh counterpart of auto-grain.

    Only two factorizations are expressible (the pipelined ``select_n``
    schedule requires the stage axis to equal the stage count):
    ``(1, n_devices)`` runs the stage chain sequentially inside one
    ``shard_map`` with all devices on the worker axis; ``(n_stages,
    n_devices / n_stages)`` streams microbatches through the chain.
    With measured per-stage costs (µs, e.g. from an autotune pilot) the
    model scores sequential as ``n_devices / sum(costs)`` and pipelined
    as ``workers * pipeline_utilisation(S, M) / max(costs)`` — the
    pipeline clocks at its slowest stage but overlaps stages, minus the
    fill/drain bubble.  Returns the winning ``(n_stage, n_worker)``."""
    seq = (1, max(1, n_devices))
    if n_stages <= 1 or n_devices < n_stages or n_devices % n_stages:
        return seq
    piped = (n_stages, n_devices // n_stages)
    costs = list(stage_costs) if stage_costs else [1.0] * n_stages
    if len(costs) != n_stages or min(costs) <= 0:
        costs = [1.0] * n_stages
    m = n_micro if n_micro and n_micro > 0 else 4 * n_stages
    seq_score = n_devices / sum(costs)
    piped_score = (piped[1] * pipeline_utilisation(n_stages, m)
                   / max(costs))
    return piped if piped_score > seq_score else seq


def negotiate_stage_axis(n_stages: int, n_devices: int):
    """Factor ``n_devices`` into a ``(stage, worker)`` mesh for a skeleton
    with ``n_stages`` pipeline stages.

    When the device count divides evenly, each stage owns a row of
    ``n_devices / n_stages`` workers and the skeleton mesh lowering streams
    microbatches with :func:`pipeline_apply`; otherwise the stage axis
    collapses to 1 and the stage chain runs sequentially inside the same
    ``shard_map`` body (still one compiled program — the stages are fused,
    not round-tripped through the host)."""
    if n_stages > 1 and n_devices >= n_stages and n_devices % n_stages == 0:
        return n_stages, n_devices // n_stages
    return 1, max(1, n_devices)


def pipeline_apply(
    stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
    stage_params: Any,
    microbatches: jnp.ndarray,
    *,
    axis_name: str = "stage",
    collect: str = "psum",
    vary_axes: tuple = (),
) -> jnp.ndarray:
    """Stream ``microbatches`` through the stage chain.

    Must be called inside ``shard_map`` with ``axis_name`` in scope and with
    ``stage_params`` already sharded so each device group holds its own
    stage's parameters.

    Args:
      stage_fn: ``y = stage_fn(params_local, x)`` — one stage's compute.
      stage_params: this stage's parameter shard.
      microbatches: ``(M, mb, ...)`` array, replicated view; stage 0 reads
        microbatch t at tick t, later stages ignore it and consume their
        inbound SPSC slot instead.
      vary_axes: extra manual axes the microbatch stream varies over (e.g.
        the skeleton mesh lowering shards items over its worker axis while
        pipelining over the stage axis); the carry and the injected stream
        are vma-aligned over ``(axis_name, *vary_axes)`` so the per-tick
        ``where`` type-checks on vma-typed JAX.

    Returns:
      ``(M, mb, ...)`` outputs as produced by the *last* stage.  With
      ``collect="psum"`` (default) they are summed over the stage axis
      (inactive stages contribute zeros) so the result is replicated and can
      leave the shard_map with an unsharded spec; ``collect="local"`` returns
      the raw per-stage emit.
    """
    n_stages = _axis_size(axis_name)
    stage = lax.axis_index(axis_name)
    m = microbatches.shape[0]
    ticks = m + n_stages - 1
    mb_shape = microbatches.shape[1:]

    def tick(carry, t):
        inbound = carry  # slot arriving over the SPSC edge from stage-1
        # stage 0's "queue" is the input stream itself
        idx = jnp.clip(t, 0, m - 1)
        first_in = lax.dynamic_index_in_dim(microbatches, idx, keepdims=False)
        first_in = _vma_align(first_in, (axis_name, *vary_axes))
        x = jnp.where(stage == 0, first_in, inbound)
        active = (t >= stage) & (t - stage < m)
        y = stage_fn(stage_params, x)
        y = jnp.where(active, y, jnp.zeros_like(y))
        # push onto the outbound SPSC edge (last stage's send is dropped)
        out_slot = chain_send(y, axis_name)
        # last stage emits: place the finished microbatch in the output slot
        emit = jnp.where((stage == n_stages - 1) & active, y, jnp.zeros_like(y))
        return out_slot, emit

    init = _vma_align(jnp.zeros(mb_shape, microbatches.dtype),
                      (axis_name, *vary_axes))
    _, emitted = lax.scan(tick, init, jnp.arange(ticks))
    # emitted[t] holds microbatch (t - (S-1)); realign to microbatch order
    out = lax.dynamic_slice_in_dim(emitted, n_stages - 1, m, axis=0)
    if collect == "psum":
        out = lax.psum(out, axis_name)
    return out
