# The paper's primary contribution: FastFlow's lock-free streaming layer as
# ONE skeleton vocabulary (skeleton.py: Pipeline/Farm/Feedback IR) with three
# backends — host thread flavour (threads + Lamport SPSC rings + the graph
# runtime), host process flavour (spawned vertices over shared-memory SPSC
# rings — the GIL-escaping procs backend), and device flavour (one shard_map
# mesh program over collective-permute SPSC channels).
# `lower(skel, backend=...)` picks the runtime.
#
# The device-side modules (dchannel/dfarm/dpipeline) import JAX, which costs
# seconds of interpreter start-up; the procs backend spawns one process per
# vertex and every child imports this package.  Those modules are therefore
# loaded lazily (PEP 562): `from repro.core import farm_map` still works, but
# a vertex process that only needs the host runtime never pays for XLA.
from .spsc import EOS, SPSCQueue
from .lockq import LockQueue
from .shm import ShmCounters, ShmFlag, ShmRing
from .sched import (SCHEDULERS, BudgetBackpressure, CostModel, KeyAffinity,
                    OnDemand, RoundRobin, Scheduler, WorkStealing,
                    calibrate_handoff_us, clear_handoff_cache, make_scheduler,
                    spread_cpus)
from .obs import (Histogram, MetricsRegistry, RunReport, Trace, Tracer,
                  VertexTracer)
from .skeleton import (GO_ON, AllToAll, EmitMany, Farm, FarmStats, Feedback,
                       FnNode, FusedNode, KeyBatch,
                       LatencyReservoir, LoweringError, MeshProgram, Pipeline,
                       Skeleton, Source, Stage, ThreadProgram, as_skeleton,
                       compose, ff_node, fuse, lower, walk_stats)
from .graph import Accelerator, Graph, Net, Token, build
from .procgraph import (ProcAccelerator, ProcGraph, ProcProgram,
                        pool_shutdown, pool_stats)
from .a2a import A2AMeshProgram, stable_hash
from .stream_ops import (FOLDS, Fold, KeyedReduce, partition_by,
                         reduce_by_key, window)
from .oocore import (CombiningReader, MemoryBudget, ShardReader, SpillFold,
                     rekey_reduce, shard_reduce, shard_source)
from .autotune import (Profile, StageProfile, TunedProgram, auto_batch,
                       plan_mesh, profile, retune, ring_capacity)
from .monitor import (BottleneckReport, DriftWatcher, Monitor, SLOMonitor,
                      Timeline, analyze)
from .farm import TaskFarm
from .allocator import PagePool, PoolExhausted
from .mdf import MDFExecutor, MDFTask

# device-flavour names, resolved on first touch (see module docstring)
_LAZY = {
    "RingChannel": ".dchannel", "chain_send": ".dchannel",
    "double_buffered_ring": ".dchannel", "ring_send": ".dchannel",
    "combine": ".dfarm", "dispatch": ".dfarm", "farm_map": ".dfarm",
    "farm_until": ".dfarm", "roundrobin_dest": ".dfarm",
    "negotiate_stage_axis": ".dpipeline", "pipeline_apply": ".dpipeline",
    "pipeline_utilisation": ".dpipeline",
}

__all__ = [
    "EOS", "SPSCQueue", "LockQueue", "ShmRing", "ShmCounters", "ShmFlag",
    "GO_ON", "EmitMany", "KeyBatch", "Accelerator", "Farm", "Feedback",
    "Graph", "Net",
    "Pipeline", "AllToAll",
    "Skeleton", "Source", "Stage", "Token", "compose",
    "LoweringError", "MeshProgram", "ThreadProgram", "as_skeleton", "build",
    "lower", "fuse", "FusedNode",
    "ProcAccelerator", "ProcGraph", "ProcProgram",
    "pool_stats", "pool_shutdown", "spread_cpus",
    "A2AMeshProgram", "stable_hash",
    "FOLDS", "Fold", "KeyedReduce", "partition_by", "reduce_by_key",
    "window",
    "MemoryBudget", "SpillFold", "ShardReader", "CombiningReader",
    "shard_source", "shard_reduce", "rekey_reduce",
    "SCHEDULERS", "Scheduler", "RoundRobin", "OnDemand", "WorkStealing",
    "CostModel", "KeyAffinity", "BudgetBackpressure", "make_scheduler",
    "calibrate_handoff_us", "clear_handoff_cache",
    "Profile", "StageProfile", "TunedProgram", "profile", "retune",
    "plan_mesh", "auto_batch", "ring_capacity",
    "FarmStats", "LatencyReservoir", "FnNode", "TaskFarm", "ff_node",
    "PagePool", "PoolExhausted",
    "MDFExecutor", "MDFTask",
    "Tracer", "VertexTracer", "Trace", "MetricsRegistry", "Histogram",
    "RunReport", "walk_stats",
    "Monitor", "Timeline", "DriftWatcher", "SLOMonitor",
    "BottleneckReport", "analyze",
] + sorted(_LAZY)


def __getattr__(name):
    target = _LAZY.get(name)
    if target is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    value = getattr(importlib.import_module(target, __name__), name)
    globals()[name] = value  # cache: next access skips this hook
    return value
