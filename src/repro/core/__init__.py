# The paper's primary contribution: FastFlow's lock-free streaming layer as
# ONE skeleton vocabulary (skeleton.py: Pipeline/Farm/Feedback IR) with two
# backends — host flavour (threads + Lamport SPSC rings + the graph runtime)
# and device flavour (one shard_map mesh program over collective-permute
# SPSC channels).  `lower(skel, backend=...)` picks the runtime.
from .spsc import EOS, SPSCQueue
from .lockq import LockQueue
from .sched import (SCHEDULERS, CostModel, OnDemand, RoundRobin, Scheduler,
                    WorkStealing, calibrate_handoff_us, make_scheduler)
from .skeleton import (GO_ON, EmitMany, Farm, FarmStats, Feedback, FnNode,
                       FusedNode,
                       LatencyReservoir, LoweringError, MeshProgram, Pipeline,
                       Skeleton, Source, Stage, ThreadProgram, as_skeleton,
                       compose, ff_node, fuse, lower)
from .graph import Accelerator, Graph, Net, Token, build
from .farm import TaskFarm
from .allocator import PagePool, PoolExhausted
from .mdf import MDFExecutor, MDFTask
from .dchannel import RingChannel, chain_send, double_buffered_ring, ring_send
from .dfarm import combine, dispatch, farm_map, farm_until, roundrobin_dest
from .dpipeline import negotiate_stage_axis, pipeline_apply, pipeline_utilisation

__all__ = [
    "EOS", "SPSCQueue", "LockQueue",
    "GO_ON", "EmitMany", "Accelerator", "Farm", "Feedback", "Graph", "Net",
    "Pipeline",
    "Skeleton", "Source", "Stage", "Token", "compose",
    "LoweringError", "MeshProgram", "ThreadProgram", "as_skeleton", "build",
    "lower", "fuse", "FusedNode",
    "SCHEDULERS", "Scheduler", "RoundRobin", "OnDemand", "WorkStealing",
    "CostModel", "make_scheduler", "calibrate_handoff_us",
    "FarmStats", "LatencyReservoir", "FnNode", "TaskFarm", "ff_node",
    "PagePool", "PoolExhausted",
    "MDFExecutor", "MDFTask",
    "RingChannel", "chain_send", "double_buffered_ring", "ring_send",
    "combine", "dispatch", "farm_map", "farm_until", "roundrobin_dest",
    "negotiate_stage_axis", "pipeline_apply", "pipeline_utilisation",
]
