# The paper's primary contribution: FastFlow's lock-free streaming layer,
# host flavour (threads + Lamport SPSC rings + the graph runtime) and device
# flavour (mesh axes + collective-permute SPSC channels).
from .spsc import EOS, SPSCQueue
from .lockq import LockQueue
from .graph import (GO_ON, Accelerator, Farm, FarmStats, FnNode, Graph, Net,
                    Pipeline, Source, Stage, Token, compose, ff_node)
from .farm import TaskFarm
from .allocator import PagePool, PoolExhausted
from .mdf import MDFExecutor, MDFTask
from .dchannel import RingChannel, chain_send, double_buffered_ring, ring_send
from .dfarm import combine, dispatch, farm_map
from .dpipeline import pipeline_apply, pipeline_utilisation

__all__ = [
    "EOS", "SPSCQueue", "LockQueue",
    "GO_ON", "Accelerator", "Farm", "Graph", "Net", "Pipeline", "Source",
    "Stage", "Token", "compose",
    "FarmStats", "FnNode", "TaskFarm", "ff_node",
    "PagePool", "PoolExhausted",
    "MDFExecutor", "MDFTask",
    "RingChannel", "chain_send", "double_buffered_ring", "ring_send",
    "combine", "dispatch", "farm_map",
    "pipeline_apply", "pipeline_utilisation",
]
