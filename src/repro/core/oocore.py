"""Out-of-core keyed aggregation — spill-to-disk folds under a byte budget.

The parquet-aggregator scenario (ROADMAP) aggregates key spaces and
inputs that exceed memory; PR 5's ``reduce_by_key`` holds every key's
accumulator in an unbounded ``_KeyFold`` dict, which loses exactly that
fight.  This module is the missing layer, and — like everything in
``stream_ops`` — it is **pure IR + ff_node code**: every piece slots into
the existing :class:`~repro.core.skeleton.AllToAll` lowering, so threads
and procs inherit it with no backend code of their own (and the mesh
backend keeps compiling the *same* skeleton from its static
``KeyedReduce`` spec, which never looks at the right row).

Four pieces:

:class:`SpillFold`
    A drop-in ``_KeyFold`` replacement: a bounded *hot* dict with
    recency order; when the per-partition byte budget is exceeded, the
    coldest half of the keys is written out as one **sorted run**
    (length-framed pickle records) and its bytes are released.  The EOS
    flush (``svc_eos`` — the same hook ``_KeyFold`` uses, so results are
    on the wire before EOS propagates) k-way-merges all runs plus the
    hot remainder with ``heapq.merge`` and re-combines equal keys, so
    peak flush memory is ``O(runs)``, not ``O(keys)``, until the final
    ``(key, fold)`` pairs materialise.  Output is sorted by key — a
    superset of the determinism the in-memory flush now guarantees.

:class:`MemoryBudget`
    The accounting board shared by one reduction's partitions: bytes
    held / spill count / spilled bytes per partition plus one global
    backpressure-stall counter.  Plain Python counters on the threads
    backend; on procs, :func:`~repro.core.a2a.build_proc_a2a` swaps in a
    :class:`~repro.core.shm.ShmCounters` board (``share``) before the
    vertices are pickled, every partition process writes only its own
    slots (single-writer per counter), and the runner copies the board
    back (``collect``) before shared memory is unlinked.  Either way the
    totals fold into the skeleton's ``FarmStats`` (``spills`` /
    ``spill_bytes`` / ``backpressure_stalls``) through the graph
    finalizer hook.

:func:`shard_source` / :class:`CombiningReader`
    Columnar record-batch sharding: ``nshards`` source nodes split one
    dataset by row ranges (round-robin over batches, so skew spreads),
    each streaming its batches independently — many left vertices, one
    dataset.  ``CombiningReader`` additionally pre-folds rows *inside
    the reader* under its own byte bound and emits ``(key, partial)``
    pairs — the map-side combiner: shuffle volume drops from rows to
    distinct keys, which is what lets the parallel aggregation beat the
    single-process in-memory loop on wall time, not just RSS.

:func:`shard_reduce` / :func:`rekey_reduce`
    The compositions.  ``shard_reduce`` assembles readers → (N×M keyed
    shuffle) → pair-mode ``SpillFold`` row into ONE ``AllToAll``.
    ``rekey_reduce`` chains a *second* keyed reduction after a first
    shuffle (``a2a∘a2a`` — the groupby-then-join shape): a pure
    ``Pipeline`` of two ``AllToAll`` nodes, which the host lowerings
    already wire (the second scatter fan-in-merges the first right row's
    rings) and ``fuse`` provably never crosses.

Everything here is host-only Python: no jax, no eager numpy — the
module is safe in the eager ``repro.core`` import set and the ~0.1s
spawn-import budget.
"""
from __future__ import annotations

import heapq
import os
import pickle
import shutil
import sys
import tempfile
from typing import Any, Callable, Iterable, Iterator, List, Optional, Tuple

from .skeleton import GO_ON, AllToAll, EmitMany, KeyBatch, Pipeline, ff_node

__all__ = [
    "MemoryBudget", "SpillFold", "ShardReader", "CombiningReader",
    "shard_source", "shard_reduce", "rekey_reduce", "pair_key",
]

_MISSING = object()


def pair_key(kv: Any) -> Any:
    """Routing key of a ``(key, value)`` pair — the shuffle ``by=`` for
    streams of pre-keyed pairs (combiner output, a second reduction's
    input).  A module-level function, so it pickles by name."""
    return kv[0]


class _OrdKey:
    """Sort key giving *any* key set a deterministic total order: natural
    ``<`` where the keys support it, falling back to ``(type name, repr)``
    where they don't (``None`` vs ``int``, mixed exotic keys).  Keys of a
    well-typed reduction are homogeneous, so the fallback is a safety
    net, not the common path."""

    __slots__ = ("k",)

    def __init__(self, k: Any):
        self.k = k

    def __lt__(self, other: "_OrdKey") -> bool:
        try:
            return self.k < other.k
        except TypeError:
            a, b = self.k, other.k
            return (type(a).__name__, repr(a)) < (type(b).__name__, repr(b))


def _sort_pairs(items: List[Tuple[Any, Any]]) -> List[Tuple[Any, Any]]:
    items.sort(key=lambda kv: _OrdKey(kv[0]))
    return items


def _entry_nbytes(k: Any, v: Any) -> int:
    """Approximate resident cost of one hot-dict entry: the dict slot plus
    the shallow sizes of key and value (one level into tuples, the common
    accumulator shape).  An estimate, not an audit — the budget bounds
    the *tracked* state, and the benchmark pins the resulting RSS."""
    n = 120 + sys.getsizeof(k) + sys.getsizeof(v)
    if type(v) is tuple:
        for e in v:
            n += sys.getsizeof(e)
    return n


# ---------------------------------------------------------------------------
# the accounting board
# ---------------------------------------------------------------------------
class MemoryBudget:
    """Byte budget + spill/stall telemetry for one keyed reduction.

    ``limit`` is the per-partition hot-state bound (bytes); a reduction
    with ``nparts`` partitions may hold at most ``limit × nparts`` in
    total.  Slot layout: three counters per partition (bytes held,
    spills, spilled bytes) and one trailing global stall counter —
    the exact shape :class:`~repro.core.shm.ShmCounters` boards carry on
    the procs backend.  Each counter has one writer: partition ``j``
    writes only its own three slots, the scatter writes the stall slot.

    The object is plain picklable state: on procs every vertex process
    gets a copy, and the shared board travels by segment name
    (``ShmCounters.__reduce__``), so all copies write the same memory.
    """

    SLOTS_PER_PART = 3
    _BYTES, _SPILLS, _SPILL_BYTES = 0, 1, 2

    def __init__(self, limit_bytes: int, nparts: int = 1, *,
                 adaptive: bool = False, min_limit: Optional[int] = None,
                 max_limit: Optional[int] = None):
        if int(limit_bytes) <= 0:
            raise ValueError(f"budget must be positive, got {limit_bytes!r}")
        self.limit = int(limit_bytes)
        self.nparts = max(1, int(nparts))
        self._local = [0] * self.n_slots
        self._board: Any = None
        # adaptive mode: after each run (the fold_into finalizer) the
        # limit is resized from that run's spill/stall deltas — stalls
        # mean intake outran the hot set (shrink it so spilling starts
        # earlier and the scatter stops blocking); a clean run grows the
        # hot set back toward max_limit to spill less next time.
        self.adaptive = bool(adaptive)
        self.min_limit = int(min_limit) if min_limit is not None \
            else max(1, self.limit // 8)
        self.max_limit = int(max_limit) if max_limit is not None \
            else self.limit * 8
        self._seen_spills = 0
        self._seen_stalls = 0

    @property
    def n_slots(self) -> int:
        return self.SLOTS_PER_PART * self.nparts + 1

    # -- board lifecycle (procs backend; see build_proc_a2a) ----------------
    def share(self, board: Any) -> None:
        """Swap in a shared counter board (``ShmCounters(self.n_slots)``).
        Carried-over local totals (from earlier runs of the same skeleton)
        seed the board so the counters stay cumulative across runs."""
        for i, v in enumerate(self._local):
            if v:
                board.add(i, v)
        self._board = board

    def collect(self) -> None:
        """Copy the shared board back into local counters and drop the
        board reference — called by the graph finalizer *before* the
        shared memory is unlinked, so the budget object (and the IR node
        holding it) stays readable and re-runnable afterwards."""
        if self._board is not None:
            self._local = [int(v) for v in self._board.snapshot()]
            self._board = None

    # -- counter access ------------------------------------------------------
    def _add(self, i: int, d: int) -> None:
        if self._board is not None:
            self._board.add(i, d)
        else:
            self._local[i] += d

    def _get(self, i: int) -> int:
        return int(self._board.get(i)) if self._board is not None \
            else self._local[i]

    def charge(self, part: int, nbytes: int) -> None:
        self._add(part * self.SLOTS_PER_PART + self._BYTES, nbytes)

    def spilled(self, part: int, nbytes: int) -> None:
        self._add(part * self.SLOTS_PER_PART + self._SPILLS, 1)
        self._add(part * self.SLOTS_PER_PART + self._SPILL_BYTES, nbytes)

    def stalled(self) -> None:
        self._add(self.SLOTS_PER_PART * self.nparts, 1)

    # -- readouts ------------------------------------------------------------
    def held(self, part: int) -> int:
        return self._get(part * self.SLOTS_PER_PART + self._BYTES)

    def held_total(self) -> int:
        return sum(self.held(j) for j in range(self.nparts))

    def over_total(self) -> bool:
        """Global high-water for intake backpressure: ¾ of the aggregate
        budget.  A partition spills itself back to ``LOW_WATER × limit``,
        so each hovers in ``[½, 1]×limit`` and the aggregate can approach
        but never exceed the full budget — throttling must therefore cut
        in *below* the roof to ever engage, and ¾ is the midpoint of the
        hover band (all-partitions-hot ⇒ stall, all-just-spilled ⇒ run)."""
        return self.held_total() * 4 > self.limit * self.nparts * 3

    def spills(self) -> int:
        return sum(self._get(j * self.SLOTS_PER_PART + self._SPILLS)
                   for j in range(self.nparts))

    def spill_bytes(self) -> int:
        return sum(self._get(j * self.SLOTS_PER_PART + self._SPILL_BYTES)
                   for j in range(self.nparts))

    def stalls(self) -> int:
        return self._get(self.SLOTS_PER_PART * self.nparts)

    def adapt(self) -> int:
        """Resize the limit from the spill/stall deltas since the last
        call (one run, when driven by the ``fold_into`` finalizer).

        Stalls dominate the cost ladder (a stalled scatter blocks the
        whole intake, a spill costs one sorted-run write), so any stalls
        ⇒ halve the limit: a smaller hot set spills earlier and keeps the
        aggregate under the ¾ high-water that trips backpressure.  A run
        with neither stalls nor spills had headroom ⇒ double the limit
        (toward ``max_limit``) so future runs keep more keys hot.  A run
        that spilled but never stalled is the regime working as designed
        — hold.  Returns the (possibly unchanged) limit."""
        d_spills = self.spills() - self._seen_spills
        d_stalls = self.stalls() - self._seen_stalls
        self._seen_spills += d_spills
        self._seen_stalls += d_stalls
        if d_stalls > 0:
            self.limit = max(self.min_limit, self.limit // 2)
        elif d_spills == 0:
            self.limit = min(self.max_limit, self.limit * 2)
        return self.limit

    def fold_into(self, stats: Any) -> None:
        """Surface the telemetry in a ``FarmStats``.  The budget's
        counters are cumulative across runs of the same skeleton, so the
        graph finalizer *assigns* (not adds) — ``stats`` then always
        shows lifetime totals, matching the counters it mirrors.  An
        ``adaptive=True`` budget also re-sizes its limit here, so each
        ``lower()`` run of the same skeleton starts from the adapted
        value (procs vertices get the new limit with the next pickle)."""
        stats.spills = self.spills()
        stats.spill_bytes = self.spill_bytes()
        stats.backpressure_stalls = self.stalls()
        if self.adaptive:
            self.adapt()

    def __repr__(self) -> str:
        return (f"MemoryBudget(limit={self.limit}, nparts={self.nparts}, "
                f"held={self.held_total()}, spills={self.spills()}, "
                f"spill_bytes={self.spill_bytes()}, stalls={self.stalls()})")


def resolve_combine(spec: Any, fn: Callable, seed_first: bool,
                    combine: Optional[Callable]) -> Optional[Callable]:
    """The merge op for two *partial accumulators* of the same key — what
    spilling (and map-side combining) needs on top of a fold.  For a
    seed-first fold the step function is its own combiner (``sum``/
    ``min``/``max``: associative over values); seeded folds (``count``,
    custom ``init=`` folds) step with an *item*, which a partial
    accumulator is not, so they need an explicit combiner — the ``Fold``
    registry carries one for ``count``."""
    if combine is not None:
        return combine
    if spec is not None and getattr(spec, "combine", None) is not None:
        return spec.combine
    if seed_first:
        return fn
    return None


# ---------------------------------------------------------------------------
# the spill store
# ---------------------------------------------------------------------------
class SpillFold(ff_node):
    """Keyed fold with a bounded hot dict and sorted on-disk runs — the
    out-of-core ``_KeyFold``.

    Ingest (``svc``) folds each arriving item into its key's hot
    accumulator (recency order: an updated key moves to the back, so the
    front of the dict is always the coldest state).  When the tracked
    bytes exceed ``budget.limit``, the coldest half of the keys is
    sorted, written as one run file, and released.  The EOS flush merges
    every run with the hot remainder (``heapq.merge`` over sorted
    streams), combines equal keys with ``combine``, deletes the run
    directory, and emits sorted ``(key, fold)`` pairs — the same
    ``svc_eos`` contract as ``_KeyFold``, so the surrounding a2a wiring
    is untouched.

    ``pairs=True`` switches the input contract to pre-keyed ``(key,
    partial)`` pairs (a :class:`CombiningReader` row upstream, or a
    second reduction consuming a first one's output): the value IS a
    partial accumulator, so ingest combines instead of folding.

    One instance per partition; after a full run the instance is back to
    its initial state (empty dict, no runs, no temp dir), so the same
    skeleton object lowers and runs repeatedly — and pickles cleanly to
    spawned vertex processes at run start.
    """

    #: spill down to this fraction of the budget, so one spill buys many
    #: inserts of headroom instead of thrashing at the boundary
    LOW_WATER = 0.5
    #: EOS flush ships this many pairs per :class:`KeyBatch` wire message
    FLUSH_CHUNK = 4096
    #: the vertex loop hands whole :class:`KeyBatch` messages to ``svc``
    #: instead of unpacking them — ingest amortizes per-call overhead
    accepts_batches = True
    #: the hosting vertex binds its lane here before svc_init, so spills
    #: surface as trace instants (child-side on procs, shipped at EOS)
    wants_tracer = True
    tracer = None

    def __init__(self, by: Callable[[Any], Any], fn: Callable[[Any, Any], Any],
                 init: Any = None, seed_first: bool = True, *,
                 combine: Optional[Callable[[Any, Any], Any]] = None,
                 budget: Optional[MemoryBudget] = None, part: int = 0,
                 spill_dir: Optional[str] = None, pairs: bool = False):
        self.by = by
        self.fn = fn
        self.init = init
        self.seed_first = seed_first
        self.combine = combine if combine is not None else \
            resolve_combine(None, fn, seed_first, None)
        if self.combine is None:
            raise ValueError(
                "SpillFold needs a combine(acc, acc) op to merge spilled "
                "partials: a seeded fold's step fn takes (acc, item), not "
                "two accumulators — pass combine= (for fold='count' the "
                "registry already carries one)")
        self.budget = budget
        self.part = part
        self.spill_dir = spill_dir
        self.pairs = pairs
        self._acc: dict = {}          # key -> (accumulator, est. bytes)
        self._bytes = 0
        self._runs: List[str] = []
        self._dir: Optional[str] = None

    # -- accounting ----------------------------------------------------------
    def _charge(self, d: int) -> None:
        self._bytes += d
        if self.budget is not None:
            self.budget.charge(self.part, d)

    def _ensure_dir(self) -> str:
        if self._dir is None:
            self._dir = tempfile.mkdtemp(
                prefix=f"ff-spill-p{self.part}-", dir=self.spill_dir)
        return self._dir

    def _drop_dir(self) -> None:
        if self._dir is not None:
            shutil.rmtree(self._dir, ignore_errors=True)
            self._dir = None
        self._runs = []

    # -- ingest --------------------------------------------------------------
    def svc(self, x):
        if type(x) is KeyBatch:       # batched wire format (combiner chunks)
            one = self._svc_one
            for e in x:
                one(e)
            return GO_ON
        return self._svc_one(x)

    def _svc_one(self, x):
        if self.pairs:
            k, v = x
            ent = self._acc.pop(k, _MISSING)
            val = v if ent is _MISSING else self.combine(ent[0], v)
        else:
            k = self.by(x)
            ent = self._acc.pop(k, _MISSING)
            if ent is not _MISSING:
                val = self.fn(ent[0], x)
            elif self.seed_first:
                val = x
            else:
                val = self.fn(self.init, x)
        sz = _entry_nbytes(k, val)
        self._acc[k] = (val, sz)      # pop+reinsert: recency order
        self._charge(sz - (0 if ent is _MISSING else ent[1]))
        if self.budget is not None and self._bytes > self.budget.limit:
            self._spill()
        return GO_ON

    # -- spill ---------------------------------------------------------------
    def _spill(self) -> None:
        target = int(self.budget.limit * self.LOW_WATER)
        evicted: List[Tuple[Any, Any]] = []
        freed = 0
        for k in list(self._acc):     # dict front = coldest keys
            if self._bytes - freed <= target:
                break
            val, sz = self._acc.pop(k)
            evicted.append((k, val))
            freed += sz
        if not evicted:               # one giant entry: nothing to trade
            return
        _sort_pairs(evicted)
        path = os.path.join(self._ensure_dir(),
                            f"run-{len(self._runs):06d}.pkl")
        with open(path, "wb") as f:
            for kv in evicted:
                pickle.dump(kv, f, protocol=pickle.HIGHEST_PROTOCOL)
        self._runs.append(path)
        self._charge(-freed)
        run_bytes = os.path.getsize(path)
        if self.budget is not None:
            self.budget.spilled(self.part, run_bytes)
        if self.tracer is not None:
            self.tracer.instant("spill", {
                "items": len(evicted), "bytes": run_bytes,
                "runs": len(self._runs)})

    @staticmethod
    def _run_iter(path: str) -> Iterator[Tuple[Any, Any]]:
        with open(path, "rb") as f:
            while True:
                try:
                    yield pickle.load(f)
                except EOFError:
                    return

    def _chunked(self, items: List[Tuple[Any, Any]]):
        """Flush wire format: the sorted pairs ride in :class:`KeyBatch`
        chunks — one message per chunk instead of one per pair (the
        vertex/results drains unpack, so consumers still see pairs)."""
        if not items:
            return None
        step = self.FLUSH_CHUNK
        return EmitMany(KeyBatch(items[i:i + step])
                        for i in range(0, len(items), step))

    # -- EOS flush: k-way merge of runs + hot remainder ----------------------
    def svc_eos(self):
        hot = _sort_pairs([(k, v) for k, (v, _sz) in self._acc.items()])
        self._acc = {}
        self._charge(-self._bytes)
        if not self._runs:
            return self._chunked(hot)
        streams = [self._run_iter(p) for p in self._runs] + [iter(hot)]
        merged = heapq.merge(*streams, key=lambda kv: _OrdKey(kv[0]))
        out_items: List[Tuple[Any, Any]] = []
        ck: Any = _MISSING
        cv: Any = None
        for k, v in merged:
            if ck is not _MISSING and k == ck:
                cv = self.combine(cv, v)
            else:
                if ck is not _MISSING:
                    out_items.append((ck, cv))
                ck, cv = k, v
        if ck is not _MISSING:
            out_items.append((ck, cv))
        self._drop_dir()
        return self._chunked(out_items)

    def svc_end(self) -> None:
        # error-path teardown: an aborted run must not leak /tmp run files
        # (the normal path already cleaned up in svc_eos)
        self._drop_dir()


# ---------------------------------------------------------------------------
# columnar record-batch sharding
# ---------------------------------------------------------------------------
class ShardReader(ff_node):
    """Source node streaming shard ``shard``-of-``nshards`` of one dataset
    as row-range batches: ``reader(lo, hi)`` is any callable returning the
    rows in ``[lo, hi)`` (a parquet row-group slice, a numpy view, a list
    slice).  Batches are dealt round-robin over the shards so a skewed
    tail spreads.  Emits one batch per ``svc(None)`` call — or, with
    ``explode=``, the batch's rows (``EmitMany``) — then ``None`` (EOS);
    the exhausted cursor resets, so the same instance re-runs."""

    def __init__(self, reader: Callable[[int, int], Any], shard: int,
                 nshards: int, *, batch_rows: int = 4096,
                 nrows: Optional[int] = None,
                 explode: Optional[Callable[[Any], Iterable[Any]]] = None):
        if nrows is None:
            nrows = getattr(reader, "nrows", None)
        if nrows is None:
            raise ValueError(
                "ShardReader needs the dataset length: pass nrows= or give "
                "the reader an .nrows attribute")
        assert 0 <= shard < nshards and batch_rows >= 1
        self.reader = reader
        self.explode = explode
        self.ranges: List[Tuple[int, int]] = [
            (lo, min(lo + batch_rows, nrows))
            for i, lo in enumerate(range(0, int(nrows), batch_rows))
            if i % nshards == shard]
        self._pos = 0

    def svc(self, _task):
        if self._pos >= len(self.ranges):
            self._pos = 0
            return None
        lo, hi = self.ranges[self._pos]
        self._pos += 1
        batch = self.reader(lo, hi)
        if self.explode is None:
            return batch
        out = EmitMany(self.explode(batch))
        return out if out else GO_ON


def shard_source(reader: Callable[[int, int], Any], nshards: int, *,
                 batch_rows: int = 4096, nrows: Optional[int] = None,
                 explode: Optional[Callable] = None) -> List[ShardReader]:
    """``nshards`` source nodes over one dataset — the left row of an
    :class:`AllToAll` (no upstream edge: the lowering runs them as
    sources), so many left vertices stream one dataset in parallel."""
    return [ShardReader(reader, i, nshards, batch_rows=batch_rows,
                        nrows=nrows, explode=explode)
            for i in range(nshards)]


class CombiningReader(ff_node):
    """Map-side combiner source: wraps a batch source (``svc(None)``
    protocol, e.g. :class:`ShardReader`), pre-folds its rows into a
    bounded local dict, and emits ``(key, partial)`` pairs — evicting the
    coldest partials early when the local bound fills, flushing the rest
    at EOS (sorted, same determinism as the right row).  Shuffle volume
    drops from rows to ~distinct keys, which is what makes the parallel
    aggregation cheaper than the single-process loop on wall time.
    Downstream must re-combine: pair with a ``SpillFold(pairs=True)``
    right row (:func:`shard_reduce` wires exactly that)."""

    def __init__(self, source: ff_node, by: Callable[[Any], Any],
                 fn: Callable[[Any, Any], Any], init: Any = None,
                 seed_first: bool = True, *,
                 combine: Optional[Callable] = None,
                 limit_bytes: int = 1 << 20,
                 explode: Optional[Callable[[Any], Iterable[Any]]] = None):
        self.source = source
        self.by = by
        self.fn = fn
        self.init = init
        self.seed_first = seed_first
        self.combine = resolve_combine(None, fn, seed_first, combine)
        self.limit = int(limit_bytes)
        self.explode = explode
        self._acc: dict = {}
        self._bytes = 0

    def svc_init(self) -> None:
        self.source.svc_init()

    def svc_end(self) -> None:
        self.source.svc_end()

    def svc(self, _task):
        batch = self.source.svc(None)
        while batch is GO_ON:
            batch = self.source.svc(None)
        if batch is None:
            return None               # svc_eos flushes the remainder
        rows = batch if self.explode is None else self.explode(batch)
        if isinstance(rows, EmitMany) or not isinstance(
                rows, (list, tuple)):
            rows = list(rows)
        # the per-row hot loop: locals hoisted — this is the cost every
        # row pays, and it competes with the single-process baseline
        acc, by, fn = self._acc, self.by, self.fn
        pop, sizeof = acc.pop, _entry_nbytes
        seed_first, init = self.seed_first, self.init
        nbytes = self._bytes
        for x in rows:
            k = by(x)
            ent = pop(k, _MISSING)
            if ent is not _MISSING:
                val = fn(ent[0], x)
                sz = sizeof(k, val)
                nbytes += sz - ent[1]
            elif seed_first:
                val = x
                sz = sizeof(k, val)
                nbytes += sz
            else:
                val = fn(init, x)
                sz = sizeof(k, val)
                nbytes += sz
            acc[k] = (val, sz)        # pop+reinsert: recency order
        self._bytes = nbytes
        if nbytes <= self.limit:
            return GO_ON
        target = self.limit // 2      # emit the coldest half as partials
        evicted = KeyBatch()          # one wire message per destination
        for k in list(acc):
            if nbytes <= target:
                break
            val, sz = pop(k)
            evicted.append((k, val))
            nbytes -= sz
        self._bytes = nbytes
        return evicted if evicted else GO_ON

    def svc_eos(self):
        items = _sort_pairs([(k, v) for k, (v, _sz) in self._acc.items()])
        self._acc = {}
        self._bytes = 0
        out = KeyBatch(items)
        return out if out else None


# ---------------------------------------------------------------------------
# compositions
# ---------------------------------------------------------------------------
def shard_reduce(reader: Callable[[int, int], Any],
                 by: Callable[[Any], Any], fold: Any = "sum", *,
                 init: Any = None, combine: Optional[Callable] = None,
                 nleft: int = 4, nright: int = 2,
                 budget: Any = None, spill_dir: Optional[str] = None,
                 batch_rows: int = 4096, nrows: Optional[int] = None,
                 explode: Optional[Callable] = None,
                 combine_limit: Optional[int] = None,
                 name: str = "shard-reduce") -> AllToAll:
    """The whole out-of-core aggregation as ONE :class:`AllToAll`:
    ``nleft`` sharded combining readers over one dataset → keyed shuffle
    on the pair key → ``nright`` pair-mode :class:`SpillFold` partitions
    under a shared :class:`MemoryBudget`.  Host backends only (the left
    row is stateful source nodes); ``budget`` is a byte count or a
    :class:`MemoryBudget`, ``None`` for unbounded right-row dicts."""
    from .stream_ops import _resolve_fold
    fn, init, seed_first, spec = _resolve_fold(fold, init)
    comb = resolve_combine(spec, fn, seed_first, combine)
    if comb is None:
        raise ValueError(
            "shard_reduce pre-combines on the readers, which needs a "
            "combine(acc, acc) op — pass combine= for seeded custom folds")
    if budget is not None and not isinstance(budget, MemoryBudget):
        budget = MemoryBudget(int(budget), nparts=nright)
    lim = combine_limit if combine_limit is not None else (
        budget.limit if budget is not None else 1 << 20)
    lefts = [CombiningReader(src, by, fn, init, seed_first, combine=comb,
                             limit_bytes=lim, explode=explode)
             for src in shard_source(reader, nleft, batch_rows=batch_rows,
                                     nrows=nrows)]
    rights = [SpillFold(by, fn, init, seed_first, combine=comb,
                        budget=budget, part=j, spill_dir=spill_dir,
                        pairs=True)
              for j in range(nright)]
    return AllToAll(lefts, rights, by=pair_key, nleft=nleft, nright=nright,
                    name=name)


def rekey_reduce(first: AllToAll, by: Callable[[Any], Any],
                 fold: Any = "sum", *, init: Any = None,
                 combine: Optional[Callable] = None,
                 nleft: int = 1, nright: int = 2, budget: Any = None,
                 spill_dir: Optional[str] = None,
                 left: Any = None, name: str = "rekey-reduce") -> Pipeline:
    """Chain a second keyed reduction after ``first`` — ``a2a∘a2a`` with
    key re-partitioning between the reductions, the groupby-then-join
    shape.  Pure IR: ``Pipeline(first, second)``; the host lowerings
    already wire it (the second scatter fan-in-merges the first right
    row's out rings), ``fuse`` treats both shuffles as hard boundaries,
    and the mesh backend rejects it (one shuffle per mesh program).

    The second reduction consumes the first's ``(key, fold)`` pairs: its
    ``by`` and ``fold`` see whole pairs (use ``left=`` to re-map them
    first).  ``budget=`` makes the second row spill-backed too."""
    from .stream_ops import reduce_by_key
    second = reduce_by_key(by, fold, init=init, nleft=nleft, nright=nright,
                           left=left, budget=budget, spill_dir=spill_dir,
                           combine=combine, name=name)
    return Pipeline(first, second)
