"""All-to-all subsystem — the keyed-shuffle lowerings of :class:`AllToAll`.

FastFlow's tutorial (TR-12-04) makes **all-to-all** the third core
building block next to pipeline and farm: N left workers, each able to
route every emission to any of M right workers.  It is the shape that
keyed shuffles, partitioned reduction and data-parallel aggregation (the
parquet-aggregator workload) are made of, and the configuration where the
paper's per-hand-off overhead argument bites hardest — a single streamed
item crosses ``O(1)`` edges, but the *network* holds ``N×M`` of them.

Three lowerings of the same IR node:

**threads / procs** (:func:`build_thread_a2a` / :func:`build_proc_a2a`)
    An N×M matrix of SPSC rings.  Each left vertex owns one private ring
    per right vertex, so the single-writer discipline of the whole runtime
    survives with *no arbiter between the layers*: routing is a pure
    function of the emission (``stable_hash(by(x)) % nright``) computed in
    the producing vertex, and termination is per-edge EOS fan-in counting
    at each right vertex (a right vertex EOSes only after all N of its
    inbound edges have).  ``ordered=`` composes with the existing
    tagged-token machinery: a tagger at the scatter, tags riding the
    matrix untouched, a reorder stage downstream.

**mesh** (:class:`A2AMeshProgram`)
    A keyed shuffle as ONE ``shard_map`` program, for skeletons carrying a
    static keyed-reduction spec (:class:`repro.core.stream_ops.
    KeyedReduce`): map stages apply elementwise, keys pick a destination
    worker (``key % axis_size``), :func:`repro.core.dfarm.dispatch` moves
    every row to its key's owner (``all_to_all`` or the collective-permute
    ring schedule), and a segment reduction + one tiny per-key collective
    folds each partition — the device-side image of "all rows of a key
    meet at one worker".

Routing determinism matters more here than anywhere else in the runtime:
two left vertices in *different processes* must agree where key ``"a"``
lives, so the route hashes with :func:`stable_hash`, never the
interpreter-salted builtin ``hash``.
"""
from __future__ import annotations

import math
import time
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

from . import graph as _graph
from . import procgraph as _procgraph
from .skeleton import (GO_ON, AllToAll, EmitMany, FnNode, KeyBatch,
                       LoweringError, Pipeline, Skeleton, Stage, WORKER_AXIS,
                       _ReorderNode, _coerce_metrics, _coerce_tracer,
                       _jax_callable, ff_node)

__all__ = [
    "stable_hash", "KeyRouter", "build_thread_a2a", "build_proc_a2a",
    "A2AMeshProgram",
]


def stable_hash(key: Any) -> int:
    """Deterministic, process-independent hash for shuffle routing.

    Python's builtin ``hash`` is salted per interpreter (PYTHONHASHSEED),
    so two left vertices running as *processes* (the procs backend) would
    route the same string key to different right vertices — silently
    splitting every key's partition across workers.  Route on a stable
    digest instead: ints map to themselves (so mod-partitioning stays the
    obvious one, and the host route agrees with the mesh's ``key % W`` for
    integer keys); str/bytes/float via crc32 of a canonical encoding;
    tuples recursively; frozensets order-independently (their iteration
    order is itself hash-salted).  Any other type raises — a default
    ``repr`` embeds the object's address, which would differ per process
    (and per object) and silently split partitions; route on a canonical
    key (int / str / tuple of those) instead.
    """
    if isinstance(key, bool):
        return int(key)
    if isinstance(key, int):
        return key
    if isinstance(key, bytes):
        return zlib.crc32(key)
    if isinstance(key, str):
        return zlib.crc32(key.encode("utf-8"))
    if key is None:
        return 0
    if isinstance(key, float):
        # hash-consistency with dict equality: 3.0 == 3 and -0.0 == 0.0,
        # and the fold dict at the right vertex merges them — so they must
        # route identically too, or one logical key splits across workers
        if math.isfinite(key) and key == int(key):
            return int(key)
        return zlib.crc32(repr(key).encode("utf-8"))  # repr is canonical
    if isinstance(key, tuple):
        acc = 1
        for k in key:
            # decimal repr of the element hash: canonical and unbounded
            # (int keys hash to themselves, at any magnitude)
            acc = zlib.crc32(b"%d," % stable_hash(k), acc)
        return acc
    if isinstance(key, frozenset):
        return sum(stable_hash(k) for k in key) & 0xFFFFFFFF
    raise TypeError(
        f"no process-stable hash for key type {type(key).__name__!r} "
        f"(its repr/hash varies per interpreter or per object, which "
        f"would split the key's partition across workers) — route on a "
        f"canonical key: int, str, bytes, float, None, or tuples/"
        f"frozensets of those")


def _ident(x: Any) -> Any:
    return x


class KeyRouter:
    """Per-left-vertex routing rule: which of the M private rings an
    emission takes.  ``by=None`` degrades to per-vertex round-robin (a
    plain repartition); otherwise ``stable_hash(by(x)) % nright``, so all
    left vertices agree on every key's owner with zero coordination.
    Plain picklable state — the procs backend ships one per left-vertex
    process, and the counter/keys are private to that process."""

    def __init__(self, by: Optional[Callable[[Any], Any]], nright: int,
                 tagged: bool = False):
        self.by = by
        self.nright = nright
        self.tagged = tagged
        self._rr = 0

    def __call__(self, out: Any) -> int:
        x = out[1] if self.tagged else out
        if self.by is None:
            w = self._rr
            self._rr = (self._rr + 1) % self.nright
            return w
        return stable_hash(self.by(x)) % self.nright

    def split(self, batch: KeyBatch) -> List[Tuple[int, KeyBatch]]:
        """Partition a :class:`~repro.core.skeleton.KeyBatch` by
        destination: one sub-batch per right vertex that owns any of its
        keys — the whole batch then costs one ring message per *destination*
        instead of one per item."""
        if self.nright == 1:
            return [(0, batch)] if batch else []
        buckets: List[Optional[KeyBatch]] = [None] * self.nright
        for x in batch:
            w = self(x)
            b = buckets[w]
            if b is None:
                buckets[w] = b = KeyBatch()
            b.append(x)
        return [(w, b) for w, b in enumerate(buckets) if b]


# ---------------------------------------------------------------------------
# tag plumbing for ordered= (the existing tagged-token machinery, N×M shape)
# ---------------------------------------------------------------------------
class _A2ATagger(ff_node):
    """Attach the global stream index at the scatter of an ordered a2a."""

    def __init__(self):
        self._next = 0

    def svc(self, x):
        i = self._next
        self._next += 1
        return i, x


class _TagCarry(ff_node):
    """Run a node under the ``(index, payload)`` envelope; tags ride the
    matrix untouched.  ``GO_ON``/``None`` filters the item — the reorder
    stage's EOS residue flush releases everything past the gap."""

    def __init__(self, node: ff_node):
        self._node = node

    def svc_init(self) -> None:
        self._node.svc_init()

    def svc_end(self) -> None:
        self._node.svc_end()

    def svc(self, task):
        i, x = task
        r = self._node.svc(x)
        if r is None or r is GO_ON:
            return GO_ON
        if isinstance(r, EmitMany):
            raise RuntimeError(
                "multi-emit (EmitMany) under AllToAll(ordered=True) is "
                "unsupported: stream tags are 1:1, so several emissions "
                "cannot share one index — use ordered=False for 1:n nodes")
        return i, r

    def svc_eos(self):
        out = self._node.svc_eos()
        if out is not None and out is not GO_ON:
            raise RuntimeError(
                "an EOS-flushing node (svc_eos) cannot run under "
                "AllToAll(ordered=True): flush items carry no stream index "
                "— keyed reductions are unordered by construction")
        return None


def _a2a_budgets(skel: AllToAll) -> List[Any]:
    """The distinct memory-budget boards carried by the right row.

    Duck-typed (a budget exposes ``fold_into``, and ``share``/``collect``/
    ``n_slots`` for the procs board swap — :class:`repro.core.oocore.
    MemoryBudget` is the implementation), so the builders stay free of an
    oocore import; identity-deduped because one reduction's partitions
    share one budget."""
    out: List[Any] = []
    for n in skel.right_nodes:
        # a fused right row (autotune's a2a absorption) hides the budget
        # holder behind a FusedNode wrapper — look through its parts
        parts = getattr(n, "nodes", None) or [n]
        for p in parts:
            b = getattr(p, "budget", None)
            if b is not None and hasattr(b, "fold_into") \
                    and not any(b is x for x in out):
                out.append(b)
    return out


# ---------------------------------------------------------------------------
# threads lowering: N×M matrix of SPSC rings, one thread per vertex
# ---------------------------------------------------------------------------
class A2ALeftVertex(_graph.StageVertex):
    """Left vertex of the matrix: applies its node, then key-routes each
    emission onto its own private ring to the owning right vertex —
    single writer per edge, no arbiter between the layers."""

    def __init__(self, node: ff_node, router: KeyRouter, *,
                 name: str = "ff-a2a-left"):
        super().__init__(node, route="rr", name=name)
        self.router = router

    def _emit(self, out: Any) -> None:
        if type(out) is KeyBatch:
            if not self.outs:
                self.graph.results.extend(out)
                return
            for w, sub in self.router.split(out):  # one message per dest
                if not self._push_abortable(self.outs[w], sub):
                    raise _graph._Aborted()
            return
        if isinstance(out, EmitMany):
            for o in out:
                self._emit(o)
            return
        if not self.outs:  # degenerate: a2a as terminal with nright==0
            self.graph.results.append(out)
            return
        if not self._push_abortable(self.outs[self.router(out)], out):
            raise _graph._Aborted()


def _wrap_rows(skel: AllToAll) -> Tuple[List[ff_node], List[ff_node]]:
    if skel.ordered:
        return ([_TagCarry(n) for n in skel.left_nodes],
                [_TagCarry(n) for n in skel.right_nodes])
    return list(skel.left_nodes), list(skel.right_nodes)


def _scatter_node(skel: AllToAll) -> ff_node:
    return _A2ATagger() if skel.ordered else FnNode(_ident)


def build_thread_a2a(skel: AllToAll, g: "_graph.Graph", in_rings: List[Any],
                     terminal: bool, path: str = "") -> Optional[Any]:
    """Wire an :class:`AllToAll` into the thread graph.

    Topology: ``[scatter] → N left → (N×M rings) → M right → [reorder]``.
    The scatter exists only when there is an upstream stream (without one
    the left nodes run as sources); the reorder stage only under
    ``ordered=``.  Returns the outbound ring list — one ring per right
    vertex (the downstream vertex fan-in-merges them), or a single ring
    after a reorder stage.  Every vertex carries ``path`` (the a2a's IR
    position) so telemetry lanes key collision-free."""
    qc = skel.queue_class or g.queue_class
    cap = skel.capacity or g.capacity
    lnodes, rnodes = _wrap_rows(skel)
    for b in _a2a_budgets(skel):
        # same process: the partitions already write the budget's local
        # counters — just surface the totals once the run has joined
        g.finalizers.append(lambda b=b: b.fold_into(skel.stats))

    if in_rings:
        scatter = g.add(_graph.StageVertex(
            _scatter_node(skel), route=skel.scheduling,
            name=f"{skel.name}-scatter"))
        scatter.path = path
        scatter.ins.extend(in_rings)
    elif skel.ordered:
        raise LoweringError(
            "AllToAll(ordered=True) needs an upstream stream to assign "
            "stream indices; compose it after a Source")
    else:
        scatter = None  # left nodes are sources (svc(None) protocol)

    lefts = []
    for i, node in enumerate(lnodes):
        lv = g.add(A2ALeftVertex(
            node, KeyRouter(skel.by, skel.nright, tagged=skel.ordered),
            name=f"{skel.name}-L{i}"))
        lv.path = path
        if scatter is not None:
            g.connect(scatter, lv, capacity=cap, queue_class=qc)
        lefts.append(lv)
    rights = []
    for j, n in enumerate(rnodes):
        rv = g.add(_graph.StageVertex(n, name=f"{skel.name}-R{j}"))
        rv.path = path
        rights.append(rv)
    for lv in lefts:           # the N×M edge matrix
        for rv in rights:
            g.connect(lv, rv, capacity=cap, queue_class=qc)

    if skel.ordered:
        tail = g.add(_graph.StageVertex(_ReorderNode(),
                                        name=f"{skel.name}-reorder"))
        tail.path = path
        for rv in rights:
            g.connect(rv, tail, capacity=cap, queue_class=qc)
        tails = [tail]
    else:
        tails = rights
    if terminal:
        return None  # sink vertices append straight to graph.results
    out_rings = []
    for tv in tails:
        ring = g.channel(cap, qc)
        tv.outs.append(ring)
        out_rings.append(ring)
    return out_rings[0] if len(out_rings) == 1 else out_rings


# ---------------------------------------------------------------------------
# procs lowering: the same matrix, every vertex a spawned process
# ---------------------------------------------------------------------------
class A2AProcScatterVertex(_procgraph.ProcStageVertex):
    """Scatter as a process: fans the upstream stream over the left row
    via a pick()/route()-based scheduling policy (the policy object lives
    entirely in this vertex's process — single-writer discipline holds)."""

    def __init__(self, node: ff_node, scheduling: Any, *,
                 name: str = "ff-a2a-pscatter"):
        super().__init__(node, name=name)
        from .sched import Scheduler, make_scheduler
        self.sched = make_scheduler(scheduling)
        # resolved once, not per emission (mirrors graph.StageVertex)
        self._route = (self.sched.route
                       if type(self.sched).route is not Scheduler.route
                       else None)

    def _loop(self) -> None:
        self.sched.bind(self.outs, None)
        super()._loop()

    def _emit(self, out: Any) -> None:
        if isinstance(out, EmitMany):
            for o in out:
                self._emit(o)
            return
        w = self.sched.pick() if self._route is None else self._route(out)
        if not self._push_abortable(self.outs[w], out):
            raise _procgraph._Aborted()


class A2AProcLeftVertex(_procgraph.ProcStageVertex):
    """Left vertex as a process: key-routes onto its M private ShmRings."""

    def __init__(self, node: ff_node, router: KeyRouter, *,
                 name: str = "ff-a2a-pleft"):
        super().__init__(node, name=name)
        self.router = router

    def _emit(self, out: Any) -> None:
        if type(out) is KeyBatch:
            for w, sub in self.router.split(out):  # one message per dest
                if not self._push_abortable(self.outs[w], sub):
                    raise _procgraph._Aborted()
            return
        if isinstance(out, EmitMany):
            for o in out:
                self._emit(o)
            return
        if not self._push_abortable(self.outs[self.router(out)], out):
            raise _procgraph._Aborted()


def build_proc_a2a(skel: AllToAll, g: "_procgraph.ProcGraph",
                   in_rings: List[Any], terminal: bool,
                   path: str = "") -> Optional[Any]:
    """The procs twin of :func:`build_thread_a2a`: one spawned process per
    vertex, every edge a shared-memory SPSC ring.  A terminal all-to-all
    gets one results ring per sink vertex (each single-producer; the
    caller drains them all and counts EOS per ring)."""
    cap = skel.capacity or g.capacity
    lnodes, rnodes = _wrap_rows(skel)
    for b in _a2a_budgets(skel):
        if hasattr(b, "share") and hasattr(b, "n_slots"):
            # swap in a shared counter board NOW, before run() pickles the
            # vertices: every partition process attaches the same segment
            # (ShmCounters travels by name) and writes only its own slots
            b.share(g.counters(b.n_slots))

            def _collect_budget(b=b, stats=skel.stats):
                b.collect()      # copy the board out before it is unlinked
                b.fold_into(stats)
            g.finalizers.append(_collect_budget)

    if in_rings:
        scatter = g.add(A2AProcScatterVertex(
            _scatter_node(skel), skel.scheduling,
            name=f"{skel.name}-scatter"))
        scatter.path = path
        scatter.ins.extend(in_rings)
    elif skel.ordered:
        raise LoweringError(
            "AllToAll(ordered=True) needs an upstream stream to assign "
            "stream indices; compose it after a Source")
    else:
        scatter = None

    lefts = []
    for i, node in enumerate(lnodes):
        lv = g.add(A2AProcLeftVertex(
            node, KeyRouter(skel.by, skel.nright, tagged=skel.ordered),
            name=f"{skel.name}-L{i}"))
        lv.path = path
        if scatter is not None:
            g.connect(scatter, lv, capacity=cap)
        lefts.append(lv)
    rights = []
    for j, n in enumerate(rnodes):
        rv = g.add(_procgraph.ProcStageVertex(n, name=f"{skel.name}-R{j}"))
        rv.path = path
        rights.append(rv)
    for lv in lefts:           # the N×M edge matrix
        for rv in rights:
            g.connect(lv, rv, capacity=cap)

    if skel.ordered:
        tail = g.add(_procgraph.ProcStageVertex(
            _ReorderNode(), name=f"{skel.name}-reorder"))
        tail.path = path
        for rv in rights:
            g.connect(rv, tail, capacity=cap)
        tails = [tail]
    else:
        tails = rights
    if terminal:
        for tv in tails:
            tv.outs.append(g.results_ring())
        return None
    out_rings = []
    for tv in tails:
        ring = g.channel(cap)
        tv.outs.append(ring)
        out_rings.append(ring)
    return out_rings[0] if len(out_rings) == 1 else out_rings


# ---------------------------------------------------------------------------
# mesh lowering: the keyed shuffle as ONE shard_map program
# ---------------------------------------------------------------------------
def _plan_mesh_a2a(skel: Skeleton) -> Tuple[List[Callable], AllToAll]:
    """Flatten a skeleton into (elementwise pre-maps, the one AllToAll).
    The shuffle must be the last stage: whatever follows it would consume
    ``(key, fold)`` pairs, which have no array form on the mesh."""
    stages = skel.stages if isinstance(skel, Pipeline) else [skel]
    pre: List[Callable] = []
    a2a: Optional[AllToAll] = None
    for s in stages:
        if isinstance(s, AllToAll):
            if a2a is not None:
                raise LoweringError(
                    "the mesh keyed-shuffle program lowers exactly one "
                    "AllToAll; chain reductions on the host backends")
            a2a = s
        elif a2a is None and isinstance(s, Stage):
            pre.append(_jax_callable(s.node))
        else:
            raise LoweringError(
                f"the mesh keyed-shuffle program is Stage maps followed by "
                f"ONE AllToAll; cannot place {type(s).__name__} "
                f"{'after the shuffle' if a2a is not None else 'here'}")
    assert a2a is not None
    if len({id(n) for n in a2a.left_nodes}) != 1:
        raise LoweringError(
            "the mesh all-to-all is SPMD: all left workers must share one "
            "jax-traceable function")
    pre.append(_jax_callable(a2a.left_nodes[0]))
    if a2a.reduce is None:
        raise LoweringError(
            "the mesh backend lowers AllToAll only as a static keyed "
            "reduction (stream_ops.reduce_by_key with a named fold and "
            "nkeys=): generic host-side right nodes cannot be traced — "
            "use the threads or procs backend for them")
    return pre, a2a


# mesh-side segment/collective implementation of each named fold kind
_SEG_KINDS = ("sum", "min", "max", "count")


class A2AMeshProgram:
    """The keyed shuffle compiled whole: ONE ``shard_map`` over a 1-D
    ``(skel_worker,)`` mesh.

    Per call: items pack into a padded ``(rows, payload+flag)`` array per
    worker (same bucketing discipline as :class:`~repro.core.skeleton.
    MeshProgram`, so nearby sizes reuse the compile); inside the program
    each row computes its key (``reduce.by``, applied to the whole column
    — it must be array-polymorphic, which for arithmetic like ``x % k``
    is the scalar form verbatim), every row travels to the worker that
    owns its key (``key % axis_size`` — the same mod-partitioning the host
    route's :func:`stable_hash` gives integer keys) via
    :func:`repro.core.dfarm.dispatch`, and a segment reduction folds each
    key's partition locally; one per-key collective (psum/pmin/pmax)
    assembles the replicated result.  Returns ``[(key, fold), ...]`` for
    the keys that actually occurred — the same unordered contract as the
    host backends' EOS flush.

    Static key space required: ``reduce.nkeys`` bounds the segment arrays,
    and ``by`` must yield integer keys in ``[0, nkeys)``.
    """

    backend = "mesh"

    def __init__(self, skeleton: Skeleton, *, devices: Optional[int] = None,
                 block: int = 64, check_vma: Optional[bool] = None,
                 capacity: Optional[int] = None, grain: Optional[int] = None,
                 trace: Any = False, metrics: Any = False):
        import jax

        self.skeleton = skeleton
        self.pre, self.a2a = _plan_mesh_a2a(skeleton)
        red = self.a2a.reduce
        kind = getattr(red.fold, "kind", None)
        if kind not in _SEG_KINDS:
            raise LoweringError(
                f"mesh keyed reduction needs a named fold with a segment "
                f"implementation (have {_SEG_KINDS}), got {kind!r}")
        if red.nkeys is None:
            raise LoweringError(
                "mesh keyed reduction needs a static key space: pass "
                "nkeys= to reduce_by_key (keys must lie in [0, nkeys))")
        self.by = red.by
        self.kind = kind
        self.nkeys = int(red.nkeys)
        self.block = block
        self.check_vma = check_vma
        ndev = len(jax.devices()) if devices is None else devices
        self.n_worker = max(1, ndev)
        from .. import compat
        self.mesh = compat.make_mesh((self.n_worker,), (WORKER_AXIS,))
        self._programs: Dict[Tuple[int, str], Callable] = {}
        self.tracer = _coerce_tracer(trace)
        self.metrics = _coerce_metrics(metrics)
        self.last_trace = None
        self.last_report = None
        self._lane = None
        if self.tracer is not None:
            self._lane = self.tracer.vertex("mesh-program")
            self._lane.instant("devices", {
                "devices": self.n_worker, "n_stage": 1,
                "n_worker": self.n_worker})

    def _bucket_rows(self, n: int) -> int:
        rows = max(-(-n // self.n_worker), 1, self.block)
        return 1 << (rows - 1).bit_length()

    def __call__(self, items: Any) -> List[Tuple[int, Any]]:
        import numpy as np

        xs = list(items)
        if not xs:
            return []
        arr = np.asarray(xs)
        if arr.dtype.kind == "f":
            arr = arr.astype(np.float32)
        elif arr.dtype.kind in "iub":
            cast = arr.astype(np.int32)
            if not np.array_equal(cast, arr):
                raise LoweringError(
                    "integer payloads exceed int32 (the mesh compute "
                    "dtype); the host backends fold exact Python ints — "
                    "refusing to silently diverge")
            arr = cast
        else:
            raise LoweringError(
                f"mesh payloads must be numeric, got dtype {arr.dtype}")
        if arr.ndim != 1:
            raise LoweringError(
                "the mesh keyed shuffle streams scalar items (fold values "
                "are per-key scalars)")
        n = arr.shape[0]
        # key-range precondition, checked host-side with the same pre-map
        # and key fns (array-polymorphic, so eager semantics match the
        # traced program): an out-of-range key would otherwise clip into
        # the boundary segment and silently diverge from the threads/procs
        # fold
        col = arr[:, None]
        for f in self.pre:
            col = np.asarray(f(col))
        keys = np.asarray(self.by(col[:, 0])).astype(np.int64)
        if keys.size and (keys.min() < 0 or keys.max() >= self.nkeys):
            raise LoweringError(
                f"mesh keyed reduction saw keys in "
                f"[{keys.min()}, {keys.max()}] but nkeys={self.nkeys}: "
                f"keys must lie in [0, nkeys) — refusing to silently "
                f"merge out-of-range keys into the boundary segment")
        rows = self._bucket_rows(n)
        padded = np.zeros((self.n_worker * rows, 2), arr.dtype)
        padded[:n, 0] = arr
        padded[:n, 1] = 1  # validity flag: padding rows never reduce
        prog = self._program(rows, str(arr.dtype))
        t0 = time.monotonic()
        acc, cnt = prog(padded)
        t1 = time.monotonic()
        if self._lane is not None:
            self._lane.span("call", t0, t1, {"items": n, "rows": rows})
            self.last_trace = self.tracer.trace()
        if self.metrics is not None:
            reg = self.metrics
            reg.counter("mesh.calls").inc()
            reg.counter("mesh.items").inc(n)
            reg.gauge("mesh.devices").set(self.n_worker)
            reg.histogram("mesh.call_us").observe((t1 - t0) * 1e6)
            self.last_report = reg.finalize(reg.report(meta={
                "backend": "mesh", "items_in": n, "rows": rows,
                "wall_s": t1 - t0}))
        acc = np.asarray(acc)[0]
        cnt = np.asarray(cnt)[0]
        return [(int(k), acc[k].item()) for k in range(self.nkeys)
                if cnt[k] > 0]

    def _program(self, rows: int, dtype: str) -> Callable:
        key = (rows, dtype)
        if key in self._programs:
            return self._programs[key]
        t_compile = time.monotonic()

        import jax
        import jax.numpy as jnp
        from jax import lax
        from jax.sharding import PartitionSpec as P

        from .. import compat
        from . import dfarm

        W, nkeys, kind = self.n_worker, self.nkeys, self.kind
        pre, by = self.pre, self.by

        def body(xf):                       # (rows, 2) per worker column
            x, flag = xf[:, :1], xf[:, 1]
            for f in pre:
                x = f(x)                    # elementwise maps, (rows, 1)
            aug = jnp.concatenate([x, flag[:, None].astype(x.dtype)], axis=1)
            keys = jnp.asarray(by(x[:, 0])).astype(jnp.int32)
            # every row travels to its key's owner; padding rows carry an
            # arbitrary (valid) destination, their flag keeps them inert
            dest = jnp.clip(keys, 0, nkeys - 1) % W
            # capacity = rows: even "every local row to one worker" fits,
            # so the exchange can never drop (unlike capacity-factor MoE)
            recv, _ = dfarm.dispatch(aug, dest, WORKER_AXIS, rows)
            flat = recv.reshape(-1, 2)      # (W*rows, payload+flag)
            vals = flat[:, 0]
            valid = flat[:, 1] != 0
            k2 = jnp.asarray(by(vals)).astype(jnp.int32)
            # invalid rows (padding, unfilled capacity slots) reduce into
            # segment nkeys, which is sliced away
            k2 = jnp.where(valid, jnp.clip(k2, 0, nkeys - 1), nkeys)
            ones = jnp.where(valid, 1, 0).astype(jnp.int32)
            cnt = jax.ops.segment_sum(ones, k2, nkeys + 1)[:nkeys]
            cnt = lax.psum(cnt, WORKER_AXIS)
            if kind == "count":
                acc = cnt.astype(jnp.int32)
            elif kind == "sum":
                seg = jax.ops.segment_sum(vals, k2, nkeys + 1)[:nkeys]
                acc = lax.psum(seg, WORKER_AXIS)
            elif kind == "min":
                seg = jax.ops.segment_min(vals, k2, nkeys + 1)[:nkeys]
                acc = lax.pmin(seg, WORKER_AXIS)
            else:                           # "max"
                seg = jax.ops.segment_max(vals, k2, nkeys + 1)[:nkeys]
                acc = lax.pmax(seg, WORKER_AXIS)
            # each worker returns its (replicated) copy as one row; vma
            # typing on newer JAX wants an explicit worker-varying cast
            acc = compat.vma_align(acc[None, :], (WORKER_AXIS,))
            cnt = compat.vma_align(cnt[None, :], (WORKER_AXIS,))
            return acc, cnt

        fn = jax.jit(compat.shard_map(
            body, mesh=self.mesh, in_specs=(P(WORKER_AXIS),),
            out_specs=(P(WORKER_AXIS), P(WORKER_AXIS)),
            check_vma=self.check_vma))
        if self._lane is not None:
            self._lane.span("compile", t_compile, time.monotonic(),
                            {"rows": rows, "dtype": dtype})
        if self.metrics is not None:
            self.metrics.counter("mesh.compiles").inc()
        self._programs[key] = fn
        return fn
