"""Device-level SPSC channels — the paper's queues, re-materialised on a mesh.

On a cache-coherent multi-core the fence-free SPSC queue works because
producer and consumer each own one index.  On a TPU mesh the analogous
asymmetric point-to-point primitive is ``lax.ppermute`` (collective-permute):
every (src, dst) edge has exactly one producer and one consumer, it crosses
ICI links directly, and — crucially — it is *not* a mesh-wide barrier the
way all-reduce/all-gather are.  The FastFlow translation table:

    memory fence / atomic op   →  global collective (all-*)
    SPSC ring slot             →  ppermute'd block, double-buffered
    queue capacity             →  number of in-flight slots in the scan carry

All helpers below are meant to be called *inside* ``jax.shard_map`` with the
relevant axis name in scope.  They are pure functions: a "channel" is a value
threaded through a ``lax.scan`` carry, and "capacity=2" (double buffering)
means keeping two slots in the carry so the compiler can overlap the permute
of slot A with compute on slot B — the TPU equivalent of FastFlow's
buffer-ahead.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import axis_size as _axis_size

__all__ = [
    "ring_send",
    "chain_send",
    "reverse_chain_send",
    "RingChannel",
    "double_buffered_ring",
]

PyTree = Any


def ring_send(x: PyTree, axis_name: str, displacement: int = 1) -> PyTree:
    """SPSC send around a ring: device i -> device (i+displacement) mod n.

    Single producer / single consumer per edge; no barrier semantics.
    """
    n = _axis_size(axis_name)
    perm = [(i, (i + displacement) % n) for i in range(n)]
    return jax.tree.map(lambda t: lax.ppermute(t, axis_name, perm), x)


def chain_send(x: PyTree, axis_name: str, displacement: int = 1) -> PyTree:
    """Non-wrapping SPSC send (pipeline edge): i -> i+displacement.

    Devices with no inbound edge receive zeros (an empty slot).
    """
    n = _axis_size(axis_name)
    perm = [(i, i + displacement) for i in range(n) if 0 <= i + displacement < n]
    return jax.tree.map(lambda t: lax.ppermute(t, axis_name, perm), x)


def reverse_chain_send(x: PyTree, axis_name: str) -> PyTree:
    """Backward pipeline edge: i -> i-1 (for gradients / feedback)."""
    return chain_send(x, axis_name, displacement=-1)


class RingChannel:
    """A cyclic SPSC channel of given capacity over a mesh axis.

    ``capacity`` slots circulate; ``step`` rotates all of them by one hop and
    hands the arriving slot to the caller.  With capacity 2 the compiler can
    hide a hop behind one compute step (double buffering); larger capacities
    trade memory for more overlap slack — exactly the queue-capacity
    trade-off of the paper, in functional clothing.
    """

    def __init__(self, axis_name: str, capacity: int = 2, displacement: int = 1):
        assert capacity >= 1
        self.axis_name = axis_name
        self.capacity = capacity
        self.displacement = displacement

    def init(self, slot: PyTree) -> Tuple[PyTree, ...]:
        """Fill all slots with this device's initial block."""
        return tuple(jax.tree.map(jnp.asarray, slot) for _ in range(self.capacity))

    def step(self, slots: Tuple[PyTree, ...], outgoing: PyTree) -> Tuple[PyTree, Tuple[PyTree, ...]]:
        """Send ``outgoing``; return (arrived, new_slots).

        ``arrived`` is the block produced ``capacity`` hops ago by the
        neighbour — i.e. a pop from the SPSC ring.
        """
        arrived = slots[0]
        moved = ring_send(outgoing, self.axis_name, self.displacement)
        new_slots = slots[1:] + (moved,)
        return arrived, new_slots


def double_buffered_ring(
    body: Callable[[int, PyTree, PyTree], Tuple[PyTree, PyTree]],
    x0: PyTree,
    carry0: PyTree,
    axis_name: str,
    *,
    hops: int | None = None,
) -> PyTree:
    """Run ``hops`` steps of compute-overlapped ring circulation.

    Each step: ``carry, y = body(hop, carry, block)`` runs on the resident
    block while the *next* block is already in flight (the permute for hop
    k+1 is issued before the compute of hop k consumes its operand, letting
    XLA's async collective-permute overlap the two).  This is the canonical
    schedule used by ring attention and ring MoE dispatch in this repo.
    """
    n_axis = _axis_size(axis_name)
    hops = n_axis if hops is None else hops

    def step(state, hop):
        carry, block = state
        # issue the send first so it can overlap with the body's compute
        next_block = ring_send(block, axis_name)
        carry, _ = body(hop, carry, block)
        return (carry, next_block), None

    (carry, _), _ = lax.scan(step, (carry0, x0), jnp.arange(hops))
    return carry
