"""AdamW on raw pytrees (no optax dependency — everything built in-repo).

Production knobs: moment dtype (bf16 halves optimizer HBM for the ≥90B
archs — the difference between fitting and not fitting the assigned mesh,
see EXPERIMENTS §Dry-run), decoupled weight decay, global-norm clipping.
Moments inherit the parameter's sharding automatically (same tree).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "clip_by_global_norm"]


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params: Any, moment_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def adamw_update(params: Any, grads: Any, state: AdamWState, *,
                 lr: jnp.ndarray, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 max_grad_norm: float = 1.0) -> Tuple[Any, AdamWState, dict]:
    grads, gn = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu32 = mu.astype(jnp.float32) * b1 + g32 * (1 - b1)
        nu32 = nu.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
        d = (mu32 / c1) / (jnp.sqrt(nu32 / c2) + eps)
        # decoupled weight decay — skip 1-D tensors (norm scales, biases)
        wd = weight_decay if p.ndim > 1 else 0.0
        new_p = p.astype(jnp.float32) - lr * (d + wd * p.astype(jnp.float32))
        return new_p.astype(p.dtype), mu32.astype(mu.dtype), nu32.astype(nu.dtype)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda v: isinstance(v, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda v: isinstance(v, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda v: isinstance(v, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), {"grad_norm": gn}
