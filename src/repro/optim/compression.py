"""Error-feedback int8 gradient compression for data-parallel all-reduce.

The DP gradient all-reduce is the largest *inter-pod* collective in
training.  Quantising to int8 with a per-tensor-chunk scale cuts its bytes
4× (vs fp32) / 2× (vs bf16); the quantisation residual is carried in an
error-feedback buffer added to the next step's gradient, which keeps SGD
convergence (Karimireddy et al., 2019).

``ef_int8_psum`` is meant for a *manual*-DP training step (shard_map over
the dp axes): quantise → psum int32 → dequantise → fold residual.  The
roofline collective term records the byte reduction in §Perf.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import axis_size as _axis_size

__all__ = ["int8_quantize", "int8_dequantize", "ef_int8_psum"]

_CHUNK = 1024


def int8_quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-chunk symmetric int8 quantisation. Returns (q, scales)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % _CHUNK
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(-1, _CHUNK)
    scale = jnp.max(jnp.abs(chunks), axis=1, keepdims=True) / 127.0
    q = jnp.round(chunks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jnp.ndarray, scale: jnp.ndarray, shape, dtype) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def ef_int8_psum(grads: Any, residual: Any, axis_name: str) -> Tuple[Any, Any]:
    """Compressed mean-all-reduce with error feedback.

    grads/residual: matching pytrees (residual fp32).  Returns
    (reduced_grads, new_residual).  Call inside shard_map over ``axis_name``.
    """
    n = _axis_size(axis_name)

    def one(g, r):
        x = g.astype(jnp.float32) + r
        flat = x.reshape(-1)
        pad = (-flat.shape[0]) % _CHUNK
        chunks = jnp.pad(flat, (0, pad)).reshape(-1, _CHUNK)
        # SHARED per-chunk scale (pmax): sum of int8 codes then decodes
        # exactly with one scale — per-replica scales do not mix.
        scale = jnp.max(jnp.abs(chunks), axis=1, keepdims=True) / 127.0
        scale = lax.pmax(scale, axis_name)
        q = jnp.round(chunks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
        # int8 codes accumulate in int32 to avoid overflow across replicas
        summed = lax.psum(q.astype(jnp.int32), axis_name)
        approx = int8_dequantize(summed.astype(jnp.float32) / n, scale,
                                 g.shape, jnp.float32)
        new_r = x - int8_dequantize(q, scale, g.shape, jnp.float32)
        return approx.astype(g.dtype), new_r

    out = jax.tree.map(one, grads, residual)
    g_new = jax.tree.map(lambda t: t[0], out, is_leaf=lambda v: isinstance(v, tuple))
    r_new = jax.tree.map(lambda t: t[1], out, is_leaf=lambda v: isinstance(v, tuple))
    return g_new, r_new
