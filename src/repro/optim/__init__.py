from .adamw import AdamWState, adamw_init, adamw_update, clip_by_global_norm
from .schedule import cosine_schedule
from .compression import ef_int8_psum, int8_quantize, int8_dequantize
from .accumulate import accumulate_grads

__all__ = [
    "AdamWState", "adamw_init", "adamw_update", "clip_by_global_norm",
    "cosine_schedule", "ef_int8_psum", "int8_quantize", "int8_dequantize",
    "accumulate_grads",
]
