"""Gradient accumulation with a single deferred reduction.

Microbatches stream through ``lax.scan`` (the input pipeline shape:
emitter → worker, one SPSC slot per microbatch); gradients accumulate in
fp32 locally and the cross-replica reduction happens ONCE at the end —
overlap-friendly and 1/n_micro the collective bytes of per-microbatch
reduction.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["accumulate_grads"]


def accumulate_grads(loss_grad_fn: Callable, params: Any,
                     micro_batches: Any) -> Tuple[jnp.ndarray, Any, Any]:
    """loss_grad_fn(params, batch) -> ((loss, metrics), grads).

    micro_batches: pytree with a leading n_micro axis on every leaf.
    Returns (mean_loss, metrics_of_last, mean_grads fp32).
    """
    def body(carry, mb):
        loss_acc, g_acc = carry
        (loss, metrics), grads = loss_grad_fn(params, mb)
        g_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
        return (loss_acc + loss, g_acc), metrics

    n = jax.tree.leaves(micro_batches)[0].shape[0]
    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss_sum, g_sum), metrics = lax.scan(body, (jnp.float32(0), g0), micro_batches)
    inv = 1.0 / n
    return loss_sum * inv, metrics, jax.tree.map(lambda g: g * inv, g_sum)
