"""Attention: GQA + RoPE + optional sliding window.

Two execution paths:
  * ``chunked``  — pure-jnp flash-style attention: double tiling over query
    and key/value chunks with an online-softmax carry inside ``lax.scan``.
    Never materialises the (S, S) score matrix, so 32k prefill fits.  This is
    what the dry-run lowers (it compiles for any XLA backend) and it is the
    numerical oracle for the Pallas kernel in ``repro/kernels``.
  * ``naive``    — materialised scores; used for tiny shapes and as the
    reference in tests.

With ``causal_skip=True`` the chunked path only visits the lower-triangular
(query-chunk, kv-chunk) pairs — S(S+ck)/2 instead of S² score FLOPs — by
enumerating the valid pairs statically (beyond-paper optimisation, §Perf).

Decode (single new token against a KV cache) is a separate, simpler path.
All shapes: q (B, S, H, Dh); k/v (B, T, Hkv, Dh) with H % Hkv == 0.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from .layers import scan_unroll

__all__ = ["attention", "decode_attention"]

_NEG = -1e30


def _repeat_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def naive_attention(q, k, v, *, causal: bool, window: Optional[int],
                    q_offset: int = 0) -> jnp.ndarray:
    """Materialised reference. q_offset: absolute position of q[0] vs k[0]."""
    B, S, H, Dh = q.shape
    T = k.shape[1]
    k = _repeat_kv(k, H // k.shape[2])
    v = _repeat_kv(v, H // v.shape[2])
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores *= Dh ** -0.5
    qpos = jnp.arange(S)[:, None] + q_offset
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, _NEG)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)


def _chunk_body(q_blk, k_blk, v_blk, carry, qpos, kpos, kv_len, *, causal, window, scale):
    """One (q-chunk × kv-chunk) flash step. carry = (m, l, acc) in fp32."""
    m, l, acc = carry
    s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk).astype(jnp.float32) * scale
    mask = kpos[None, :] < kv_len  # mask kv padding
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None], s, _NEG)
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m - m_new)
    l_new = l * alpha + p.sum(axis=-1)
    acc_new = acc * alpha[..., None] + jnp.einsum(
        "bhqk,bkhd->bhqd", p.astype(v_blk.dtype), v_blk).astype(jnp.float32)
    return m_new, l_new, acc_new


def chunked_attention(q, k, v, *, causal: bool, window: Optional[int],
                      q_chunk: int, kv_chunk: int, causal_skip: bool = False,
                      q_offset: int = 0) -> jnp.ndarray:
    B, S, H, Dh = q.shape
    T = k.shape[1]
    groups = H // k.shape[2]
    scale = Dh ** -0.5
    cq = min(q_chunk, S)
    ck = min(kv_chunk, T)
    nq, nk = -(-S // cq), -(-T // ck)
    # pad to multiples
    qp = jnp.pad(q, ((0, 0), (0, nq * cq - S), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * ck - T), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * ck - T), (0, 0), (0, 0)))
    kp = kp.reshape(B, nk, ck, *kp.shape[2:])
    vp = vp.reshape(B, nk, ck, *vp.shape[2:])
    kpos_all = jnp.arange(nk * ck)

    def q_block(qi, q_blk):
        qpos = qi * cq + jnp.arange(cq) + q_offset
        m0 = jnp.full((B, H, cq), _NEG, jnp.float32)
        l0 = jnp.zeros((B, H, cq), jnp.float32)
        a0 = jnp.zeros((B, H, cq, Dh), jnp.float32)

        if causal_skip and causal and q_offset == 0 and S == T:
            # triangular schedule: q-chunk qi only needs kv-chunks [0, qi·cq/ck]
            # (static upper bound via scan length == nk but sliced per row is
            # dynamic; instead enumerate with a fori over a *dynamic* count)
            n_valid = jnp.minimum(((qi + 1) * cq + ck - 1) // ck, nk)

            def body(ki, carry):
                k_blk = lax.dynamic_index_in_dim(kp, ki, 1, keepdims=False)
                v_blk = lax.dynamic_index_in_dim(vp, ki, 1, keepdims=False)
                kpos = lax.dynamic_slice_in_dim(kpos_all, ki * ck, ck)
                k_blk = _repeat_kv(k_blk, groups)
                v_blk = _repeat_kv(v_blk, groups)
                return _chunk_body(q_blk, k_blk, v_blk, carry, qpos, kpos, T,
                                   causal=causal, window=window, scale=scale)

            m, l, acc = lax.fori_loop(0, n_valid, body, (m0, l0, a0))
        else:
            def step(carry, inputs):
                k_blk, v_blk, kpos = inputs
                k_blk = _repeat_kv(k_blk, groups)
                v_blk = _repeat_kv(v_blk, groups)
                return _chunk_body(q_blk, k_blk, v_blk, carry, qpos, kpos, T,
                                   causal=causal, window=window, scale=scale), None

            (m, l, acc), _ = lax.scan(
                step, (m0, l0, a0),
                (kp.transpose(1, 0, 2, 3, 4), vp.transpose(1, 0, 2, 3, 4),
                 kpos_all.reshape(nk, ck)), unroll=scan_unroll())
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.astype(q.dtype)                       # (B, H, cq, Dh)

    qp = qp.reshape(B, nq, cq, H, Dh)
    _, outs = lax.scan(lambda c, args: (c, q_block(*args)), 0,
                       (jnp.arange(nq), qp.transpose(1, 0, 2, 3, 4)),
                       unroll=scan_unroll())
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, nq * cq, H, Dh)
    return out[:, :S]


def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              impl: str = "chunked", q_chunk: int = 1024, kv_chunk: int = 512,
              causal_skip: bool = False, q_offset: int = 0) -> jnp.ndarray:
    if impl == "naive" or q.shape[1] * k.shape[1] <= 256 * 256:
        return naive_attention(q, k, v, causal=causal, window=window, q_offset=q_offset)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             q_chunk=q_chunk, kv_chunk=kv_chunk,
                             causal_skip=causal_skip, q_offset=q_offset)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: Optional[int] = None,
                     rolling: bool = False,
                     start_pos: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Single-step attention against a cache.

    q: (B, 1, H, Dh); caches: (B, T, Hkv, Dh); cache_len: scalar — number of
    valid entries (the new token's k/v already written).  With
    ``rolling=True`` the cache is a circular SWA buffer where *all* T slots
    are valid once full; masking is by slot validity only.
    ``start_pos`` (B,) masks slots before a request's admission — the
    continuous-batching farm admits requests into recycled slots mid-stream.
    """
    B, _, H, Dh = q.shape
    T, Hkv = k_cache.shape[1], k_cache.shape[2]
    g = H // Hkv
    # grouped-GQA form: NEVER materialise repeated KV.  The repeat would
    # break the cache's sequence (T) sharding under GSPMD and trigger a
    # full cache all-gather per layer (§Perf H2 — found by the exact
    # accounting: 2.15 GB/layer/step of avoidable all-gather).
    qg = q.reshape(B, 1, Hkv, g, Dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache).astype(jnp.float32)
    s = s * (Dh ** -0.5)
    slot = jnp.arange(T)
    if rolling:
        valid = jnp.broadcast_to(slot < jnp.minimum(cache_len, T), (B, T))
    else:
        valid = jnp.broadcast_to(slot < cache_len, (B, T))
        if window is not None:
            valid &= slot[None, :] > cache_len - 1 - window
    if start_pos is not None and not rolling:
        valid &= slot[None, :] >= start_pos[:, None]
    s = jnp.where(valid[:, None, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, H, Dh)
