"""Model configuration for the unified LM family.

One dataclass covers all 10 assigned architectures (dense / MoE / SSM /
hybrid / VLM / audio).  Exact table values live in ``repro/configs/*.py``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig", "param_count", "active_param_count", "pad_to"]


def pad_to(x: int, multiple: int) -> int:
    return ((x + multiple - 1) // multiple) * multiple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    n_shared_experts: int = 0       # kimi-k2 style always-on expert(s)
    # --- attention ---
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_compute_dtype: str = "float32"   # bf16 matmuls in the SSD chunk (§Perf)
    # --- layer layout ---
    attn_every: int = 1             # hybrid: one attn block per this many layers (0 = attn-free)
    shared_attn_block: bool = False # zamba2: the interleaved attn block shares params
    cross_attn_every: int = 0       # vlm: one cross-attn block per this many layers
    n_codebooks: int = 0            # audio: parallel EnCodec codebook heads
    vision_patches: int = 1601      # vlm stub frontend: patches per image
    vision_dim: int = 1280
    # --- numerics / runtime ---
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    attn_impl: str = "chunked"      # chunked (pure-jnp flash) | naive | pallas
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 512
    causal_skip: bool = False       # triangular block schedule (skip fully-masked kv blocks)
    moe_backend: str = "local_gather"   # local_gather | a2a | ring | dense
    moe_wire_dtype: Optional[str] = None
    remat: bool = True
    loss_chunk: int = 0             # 0 = unchunked cross-entropy
    # --- sharding knobs (consumed by parallel/rules.py) ---
    pad_heads_to: int = 16          # pad attention heads so TP divides; 0 = off
    pad_vocab_to: int = 16
    optimizer_dtype: str = "float32"   # adam moments; "bfloat16" for ≥90B archs
    sequence_parallel: bool = False    # SP for norm regions (hillclimb lever)
    serve_params_replicated: bool = False  # inference: no FSDP shard on params
                                           # (set per-cell by launch/steps.py
                                           # when param_bytes/mp fits HBM)

    # ------------------------------------------------------------------
    @property
    def hdim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def n_heads_padded(self) -> int:
        if self.pad_heads_to and self.n_heads % self.pad_heads_to:
            return pad_to(self.n_heads, self.pad_heads_to)
        return self.n_heads

    @property
    def vocab_padded(self) -> int:
        if self.pad_vocab_to and self.vocab_size % self.pad_vocab_to:
            return pad_to(self.vocab_size, self.pad_vocab_to)
        return self.vocab_size

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # layer layout -----------------------------------------------------
    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind, length n_layers."""
        kinds = []
        for i in range(self.n_layers):
            if self.family in ("ssm",):
                kinds.append("ssm")
            elif self.family == "hybrid":
                # one (possibly shared) attention block per `attn_every`
                if self.attn_every and (i + 1) % self.attn_every == 0:
                    kinds.append("attn_shared" if self.shared_attn_block else "attn")
                else:
                    kinds.append("ssm")
            elif self.family == "vlm":
                if self.cross_attn_every and (i + 1) % self.cross_attn_every == 0:
                    kinds.append("cross")
                else:
                    kinds.append("attn")
            else:
                kinds.append("attn")
        return tuple(kinds)

    def smoke(self) -> "ModelConfig":
        """A reduced same-family config that runs a step on 1 CPU device."""
        small = dict(
            n_layers=max(2, min(4, self.attn_every or 2, self.cross_attn_every or 2)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads else 4,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            head_dim=16,
            pad_heads_to=0,
            pad_vocab_to=0,
            remat=False,
        )
        if self.family == "hybrid":
            small["n_layers"] = 2 * (self.attn_every or 2)
        if self.family == "vlm":
            small["n_layers"] = 2 * (self.cross_attn_every or 2)
            small["vision_patches"] = 8
            small["vision_dim"] = 32
        if self.n_experts:
            small["n_experts"] = min(self.n_experts, 8)
            small["top_k"] = min(self.top_k, 2)
            small["d_ff"] = 64
        if self.ssm_state:
            small["ssm_state"] = 16
            small["ssm_headdim"] = 16
            small["ssm_chunk"] = 8
        if self.sliding_window:
            small["sliding_window"] = 16
        return self.replace(name=self.name + "-smoke", **small)


# ---------------------------------------------------------------------------
def _attn_params(cfg: ModelConfig) -> int:
    h, kv, dh, d = cfg.n_heads, cfg.n_kv_heads, cfg.hdim, cfg.d_model
    return d * h * dh + 2 * d * kv * dh + h * dh * d


def _mlp_params(cfg: ModelConfig, d_ff: int) -> int:
    return 3 * cfg.d_model * d_ff  # SwiGLU: gate, up, down


def _ssm_params(cfg: ModelConfig) -> int:
    d, di, n, hh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    # Wz, Wx, WB, WC, Wdt, out_proj, conv, A, D, dt_bias
    return d * di * 2 + d * n * 2 + d * hh + di * d + cfg.ssm_conv * (di + 2 * n) + 2 * hh + hh


def param_count(cfg: ModelConfig) -> int:
    """Total parameters (unpadded dims, embedding included)."""
    total = cfg.vocab_size * cfg.d_model  # embedding (tied LM head not double counted)
    total += cfg.vocab_size * cfg.d_model  # untied LM head
    kinds = cfg.layer_kinds()
    shared_counted = False
    for k in kinds:
        if k == "attn":
            total += _attn_params(cfg)
            if cfg.n_experts:
                total += cfg.d_model * cfg.n_experts                    # router
                total += cfg.n_experts * _mlp_params(cfg, cfg.d_ff)     # experts
                total += cfg.n_shared_experts * _mlp_params(cfg, cfg.d_ff)
            elif cfg.d_ff:
                total += _mlp_params(cfg, cfg.d_ff)
        elif k == "attn_shared":
            if not shared_counted:
                total += _attn_params(cfg) + (_mlp_params(cfg, cfg.d_ff) if cfg.d_ff else 0)
                shared_counted = True
        elif k == "cross":
            total += _attn_params(cfg) + (_mlp_params(cfg, cfg.d_ff) if cfg.d_ff else 0)
            total += cfg.vision_dim * cfg.d_model  # vision projection
        elif k == "ssm":
            total += _ssm_params(cfg)
    if cfg.n_codebooks:
        total += (cfg.n_codebooks - 1) * cfg.vocab_size * cfg.d_model  # extra heads
    return total


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: only routed experts)."""
    if not cfg.n_experts:
        return param_count(cfg)
    total = param_count(cfg)
    n_moe_layers = sum(1 for k in cfg.layer_kinds() if k == "attn")
    inactive = (cfg.n_experts - cfg.top_k) * _mlp_params(cfg, cfg.d_ff) * n_moe_layers
    return total - inactive
