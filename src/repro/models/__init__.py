from .config import ModelConfig, active_param_count, param_count
from .model import (batch_pspecs, cache_pspecs, decode_step, init_cache,
                    init_params, loss_fn, params_pspecs, prefill)

__all__ = [
    "ModelConfig", "param_count", "active_param_count",
    "init_params", "params_pspecs", "loss_fn", "prefill", "decode_step",
    "init_cache", "cache_pspecs", "batch_pspecs",
]
