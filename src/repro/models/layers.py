"""Shared building blocks: RMSNorm, RoPE, SwiGLU, embeddings, init."""
from __future__ import annotations

import os
from typing import Dict

import jax
import jax.numpy as jnp

from .config import ModelConfig

__all__ = [
    "rms_norm", "rope_freqs", "apply_rope", "swiglu", "dense_init",
    "embed_init", "Params", "scan_unroll",
]


def scan_unroll() -> bool:
    """True when the dry-run requests fully-unrolled scans: XLA's
    cost_analysis counts a while-loop body ONCE, so exact FLOP/byte roofline
    terms need straight-line HLO (REPRO_UNROLL=1; see launch/dryrun.py)."""
    return os.environ.get("REPRO_UNROLL", "0") == "1"

Params = Dict[str, jnp.ndarray]


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """(head_dim//2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotate pairs. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                                   # (dh/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv       # (..., seq, dh/2)
    cos = jnp.cos(ang)[..., :, None, :]                           # (..., seq, 1, dh/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray, w_down: jnp.ndarray) -> jnp.ndarray:
    g = x @ w_gate
    u = x @ w_up
    return (jax.nn.silu(g) * u) @ w_down


def dense_init(key, shape, in_axis_size: int, dtype) -> jnp.ndarray:
    """Scaled-normal init (1/sqrt(fan_in))."""
    std = in_axis_size ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)
