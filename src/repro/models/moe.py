"""Mixture-of-Experts layer — the farm skeleton at device level.

Token→expert routing *is* the paper's farm: the router is the Emitter, the
expert shards are the Workers, and the weighted recombination is the
order-preserving Collector (the (expert, slot) pair is the tag).  Three
interchangeable dispatch backends expose the paper's design space:

  * ``local_gather`` (default) — FastFlow-style **no-symmetric-exchange**
    dispatch.  Activations between blocks are replicated over the ``model``
    axis (Megatron layout), so every model-device already *has* every local
    token; each worker simply gathers the copies addressed to its own
    experts into a capacity-bounded buffer, computes, scatters back, and the
    single ``psum`` that TP needs anyway combines the results.  Collective
    cost: one psum of (tokens × d) — *independent of top-k*.  This is the
    "consume from your SPSC endpoint instead of a global exchange" insight.
  * ``a2a`` — the classic symmetric exchange (GShard/Switch): tokens are
    split over model-devices, routed with ``lax.all_to_all`` via
    ``repro.core.dfarm``, processed, exchanged back, then all-gathered.
    Collective cost scales with top-k (2 × tokens × k × cf × d / N exchanged
    + gather).  This is the baseline the §Perf comparison beats for k ≥ 2.
  * ``ring`` — the a2a decomposed into n-1 SPSC ring hops
    (``dfarm.dispatch(backend="ring")``): same bytes as a2a but point-to-
    point, so each hop's transfer overlaps per-hop expert compute.
  * ``dense`` — every expert on every token, one-hot combine; the test
    oracle.

Expert sharding adapts to the mesh: with E % N == 0 experts are sharded over
``model`` (E/N experts per device, full d_ff); otherwise d_ff is sharded
(all experts per device, d_ff/N each).  Both arrive inside shard_map as a
local (E_loc, d, f_loc) tensor and share one code path.  Capacity-factor
routing with per-expert static capacity keeps all shapes static.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import axis_size as _axis_size

from ..core import dfarm
from ..parallel.context import psum_compat
from .config import ModelConfig

__all__ = ["moe_apply", "moe_init", "router_aux_loss", "expert_shard_kind"]


def expert_shard_kind(n_experts: int, model_axis_size: int) -> str:
    """'ep' (experts over model) or 'tp' (d_ff over model)."""
    return "ep" if n_experts % model_axis_size == 0 else "tp"


def moe_init(key, cfg: ModelConfig):
    from .layers import dense_init
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    params = {
        "router": dense_init(ks[0], (d, E), d, jnp.float32),
        "w_gate": dense_init(ks[1], (E, d, f), d, cfg.param_dtype),
        "w_up": dense_init(ks[2], (E, d, f), d, cfg.param_dtype),
        "w_down": dense_init(ks[3], (E, f, d), f, cfg.param_dtype),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        ks2 = jax.random.split(ks[4], 3)
        params["shared"] = {
            "w_gate": dense_init(ks2[0], (d, fs), d, cfg.param_dtype),
            "w_up": dense_init(ks2[1], (d, fs), d, cfg.param_dtype),
            "w_down": dense_init(ks2[2], (fs, d), fs, cfg.param_dtype),
        }
    return params


def _route(tokens: jnp.ndarray, router_w: jnp.ndarray, top_k: int):
    """Returns (gate_weights (Tk,k), expert_ids (Tk,k), probs (Tk,E))."""
    logits = tokens.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_logits, ids = lax.top_k(logits, top_k)
    gates = jax.nn.softmax(top_logits, axis=-1)          # renormalise over k
    return gates, ids, probs


def router_aux_loss(probs: jnp.ndarray, ids: jnp.ndarray, n_experts: int) -> jnp.ndarray:
    """Switch-style load-balancing loss: E * Σ_e f_e · p̄_e."""
    hot = jax.nn.one_hot(ids[..., 0], n_experts, dtype=jnp.float32)
    f_e = hot.mean(axis=0)
    p_e = probs.mean(axis=0)
    return n_experts * jnp.sum(f_e * p_e)


def _expert_ffn(buf: jnp.ndarray, wg, wu, wd) -> jnp.ndarray:
    """(E_loc, C, d) → (E_loc, C, d) batched SwiGLU (exact grouped-FLOPs)."""
    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    u = jnp.einsum("ecd,edf->ecf", buf, wu)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd)


def _shared_ffn(x, shared) -> jnp.ndarray:
    g = x @ shared["w_gate"]
    u = x @ shared["w_up"]
    return (jax.nn.silu(g) * u) @ shared["w_down"]


def _dispatch_local(tokens, eid_flat, gate_flat, e_loc, capacity):
    """Gather copies owned by this worker into (E_loc, C, d); return combine fn."""
    tk, k = eid_flat.shape[0] // tokens.shape[0], None  # unused; clarity only
    onehot = jax.nn.one_hot(eid_flat, e_loc, dtype=jnp.int32)       # OOB rows → 0
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.sum(pos * onehot, axis=1)                              # rank in expert
    valid = (eid_flat >= 0) & (eid_flat < e_loc) & (pos < capacity)
    src = jnp.repeat(jnp.arange(tokens.shape[0]), eid_flat.shape[0] // tokens.shape[0])
    buf = jnp.zeros((e_loc, capacity, tokens.shape[1]), tokens.dtype)
    eid_safe = jnp.where(valid, eid_flat, e_loc)                     # drop row
    buf = buf.at[eid_safe, pos].set(
        jnp.where(valid[:, None], tokens[src], 0), mode="drop")

    def combine(out_buf):
        got = out_buf[jnp.clip(eid_flat, 0, e_loc - 1), jnp.clip(pos, 0, capacity - 1)]
        got = jnp.where(valid[:, None], got, 0)
        return got.astype(jnp.float32) * gate_flat[:, None]

    return buf, combine


def moe_apply(x: jnp.ndarray, params, cfg: ModelConfig, *,
              axis_name: Optional[str] = "model",
              backend: Optional[str] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Apply the MoE block.  x: (B, S, d) replicated over the model axis.

    Returns (out, aux_loss).  Must be wrapped by the model's partial-manual
    shard_map when a mesh is in use (`axis_name` in scope); with
    ``axis_name=None`` runs single-device semantics (the oracle path).
    """
    backend = backend or cfg.moe_backend
    B, S, d = x.shape
    E, k, cf = cfg.n_experts, cfg.top_k, cfg.capacity_factor
    tokens = x.reshape(-1, d)
    tk = tokens.shape[0]

    gates, ids, probs = _route(tokens, params["router"], k)
    aux = router_aux_loss(probs, ids, E)

    if backend == "dense" or axis_name is None:
        out = _moe_dense(tokens, params, gates, ids, cfg)
    else:
        n = _axis_size(axis_name)
        e_loc = params["w_gate"].shape[0]        # local shard (post shard_map)
        n_groups = E // e_loc
        me = lax.axis_index(axis_name)
        group = (me * n_groups) // n
        eid_flat = ids.reshape(-1) - group * e_loc      # local expert id or OOB
        gate_flat = gates.reshape(-1)
        capacity = max(1, int(tk * k * cf / E + 0.999))

        if backend == "local_gather":
            buf, combine = _dispatch_local(tokens, eid_flat, gate_flat, e_loc, capacity)
            out_buf = _expert_ffn(buf, params["w_gate"], params["w_up"], params["w_down"])
            contrib = combine(out_buf)                               # (tk*k, d) fp32
            out = contrib.reshape(tk, k, d).sum(axis=1)
        elif backend in ("a2a", "ring"):
            assert n_groups == n, "a2a/ring dispatch needs E % model_axis == 0"
            # each device routes only its 1/n slice of the (replicated) tokens
            slc = tk // n
            my_tok = lax.dynamic_slice_in_dim(tokens, me * slc, slc, axis=0)
            my_ids = lax.dynamic_slice_in_dim(ids, me * slc, slc, axis=0)
            my_gates = lax.dynamic_slice_in_dim(gates, me * slc, slc, axis=0)
            items = jnp.repeat(my_tok, k, axis=0)                    # (slc*k, d)
            flat_ids = my_ids.reshape(-1)
            dest = (flat_ids // e_loc).astype(jnp.int32)
            # ship the (local expert id + 1) with the payload so the worker
            # can regroup without re-routing; slot 0 ⇒ empty buffer entry.
            tagged = jnp.concatenate(
                [items, (flat_ids % e_loc + 1).astype(items.dtype)[:, None]], axis=1)
            cap_dev = max(1, int(slc * k * cf / n + 0.999))
            recv, info = dfarm.dispatch(tagged, dest, axis_name, cap_dev,
                                        backend=backend,
                                        wire_dtype=_wire(cfg))
            recv_flat = recv.reshape(-1, d + 1)
            recv_tok, recv_tag = recv_flat[:, :d], recv_flat[:, d]
            eid1 = jnp.round(recv_tag).astype(jnp.int32) - 1         # -1 ⇒ empty
            cap2 = recv_flat.shape[0]
            # per-LOCAL-expert capacity (cap2 already includes cf headroom)
            cap_e = max(1, -(-cap2 // e_loc) * 2)
            buf, combine = _dispatch_local(
                recv_tok, eid1, jnp.ones((cap2,), jnp.float32), e_loc, cap_e)
            out_buf = _expert_ffn(buf, params["w_gate"], params["w_up"], params["w_down"])
            back_flat = combine(out_buf).astype(x.dtype)             # (cap2, d)
            processed = jnp.concatenate(
                [back_flat, recv_tag[:, None].astype(x.dtype)], axis=1)
            processed = processed.reshape(recv.shape[0], -1, d + 1)
            got = dfarm.combine(processed, info, axis_name, backend=backend,
                                wire_dtype=_wire(cfg))[:, :d]        # (slc*k, d)
            my_out = (got.astype(jnp.float32).reshape(slc, k, d)
                      * my_gates[..., None]).sum(axis=1)
            out = jnp.zeros((tk, d), jnp.float32)
            out = lax.dynamic_update_slice_in_dim(out, my_out, me * slc, axis=0)
            # psum below combines the per-device shards (and doubles as the
            # TP reduce for the shared expert)
        else:
            raise ValueError(f"unknown moe backend {backend!r}")

        if "shared" in params:
            out = out + _shared_ffn(tokens, params["shared"]).astype(jnp.float32)
        # one psum combines disjoint expert contributions (ep layout) or
        # partial f-slices (tp layout) — and doubles as the block's TP reduce.
        # Reduce in model dtype: halves collective bytes vs fp32.
        out = psum_compat(out.astype(x.dtype), axis_name)
        return out.reshape(B, S, d), aux

    if "shared" in params:
        out = out + _shared_ffn(tokens, params["shared"]).astype(jnp.float32)
    return out.astype(x.dtype).reshape(B, S, d), aux


def _wire(cfg: ModelConfig):
    return jnp.dtype(cfg.moe_wire_dtype) if cfg.moe_wire_dtype else None


def _moe_dense(tokens, params, gates, ids, cfg: ModelConfig) -> jnp.ndarray:
    """Oracle: run every expert on every token, combine by routing weights."""
    E = cfg.n_experts
    g = jnp.einsum("td,edf->tef", tokens, params["w_gate"])
    u = jnp.einsum("td,edf->tef", tokens, params["w_up"])
    h = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * u, params["w_down"])
    # scatter top-k gates into a (Tk, E) combine matrix
    weight = jnp.zeros((tokens.shape[0], E), jnp.float32)
    weight = weight.at[jnp.arange(tokens.shape[0])[:, None], ids].add(gates)
    return jnp.einsum("ted,te->td", h.astype(jnp.float32), weight)
