"""Mamba2 / SSD (state-space duality) mixer — chunked, matmul-rich form.

The SSD recurrence  h_t = a_t·h_{t-1} + dt_t·(B_t ⊗ x_t),  y_t = C_t·h_t + D·x_t
is evaluated chunk-by-chunk: inside a chunk everything is dense matmuls
(MXU-friendly — this is the TPU adaptation of the paper's "keep the stream
flowing through compute-dense stages"), and chunks are connected by a
sequential ``lax.scan`` carrying the (B, H, P, N) state — a streaming
pipeline over time, one SPSC hop per chunk.

Shapes: u (B, T, d_model); internally x (B, T, H, P) with H·P = d_inner,
B/C (B, T, N) single-group, dt (B, T, H), A (H,) negative reals.

The pure-jnp implementation here is the oracle for the Pallas kernel in
``repro/kernels/ssd_scan.py`` and is what the dry-run lowers.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import dense_init, rms_norm, scan_unroll

__all__ = ["ssm_init", "ssm_apply", "ssm_decode", "ssd_chunked", "ssd_reference", "init_ssm_cache"]


# --------------------------------------------------------------------------
# SSD core
# --------------------------------------------------------------------------
def ssd_reference(x, dt, A, B, C, h0=None):
    """Naive sequential recurrence (test oracle). x (b,t,h,p), dt (b,t,h),
    A (h,), B,C (b,t,n). Returns y (b,t,h,p), h_final (b,h,p,n)."""
    b, t, h, p = x.shape
    n = B.shape[-1]
    h_state = jnp.zeros((b, h, p, n), jnp.float32) if h0 is None else h0

    def step(h_state, inp):
        x_t, dt_t, B_t, C_t = inp
        a = jnp.exp(dt_t * A)                                   # (b,h)
        upd = jnp.einsum("bhp,bn->bhpn", x_t * dt_t[..., None], B_t)
        h_state = h_state * a[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", h_state, C_t)
        return h_state, y

    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          B.transpose(1, 0, 2).astype(jnp.float32),
          C.transpose(1, 0, 2).astype(jnp.float32))
    h_state, ys = lax.scan(step, h_state, xs)
    return ys.transpose(1, 0, 2, 3), h_state


def _segsum(dA):
    """(b,l,h) → (b,h,l,l) lower-triangular cumulative log-decay."""
    l = dA.shape[1]
    x = dA.transpose(0, 2, 1)                                   # (b,h,l)
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]                   # sum_{j<k<=i}
    mask = jnp.tril(jnp.ones((l, l), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, h0=None, compute_dtype=jnp.float32):
    """Chunked SSD. Same contract as ssd_reference.  ``compute_dtype``
    applies to the intra-chunk matmuls only (decays/state stay fp32) —
    halving the memory-roofline term for the memory-bound SSM archs."""
    b, t, h, p = x.shape
    n = B.shape[-1]
    l = min(chunk, t)
    assert t % l == 0, f"seq {t} not divisible by chunk {l}"
    nc = t // l
    f32 = jnp.float32
    xr = x.reshape(b, nc, l, h, p).astype(f32)
    dtr = dt.reshape(b, nc, l, h).astype(f32)
    Br = B.reshape(b, nc, l, n).astype(f32)
    Cr = C.reshape(b, nc, l, n).astype(f32)
    h_init = jnp.zeros((b, h, p, n), f32) if h0 is None else h0.astype(f32)

    def per_chunk(h_prev, inp):
        xc, dtc, Bc, Cc = inp                                   # (b,l,h,p) ...
        dA = dtc * A                                            # (b,l,h)
        dA_cum = jnp.cumsum(dA, axis=1)                         # (b,l,h)
        # intra-chunk (dual / attention-like form)
        L = jnp.exp(_segsum(dA))                                # (b,h,l,l)
        scores = jnp.einsum("bln,bsn->bls", Cc.astype(compute_dtype),
                            Bc.astype(compute_dtype))           # (b,l,l)
        gated = (scores.astype(f32)[:, None] * L).astype(compute_dtype)
        xdt = (xc * dtc[..., None]).astype(compute_dtype)       # (b,l,h,p)
        y_diag = jnp.einsum("bhls,bshp->blhp", gated, xdt,
                            preferred_element_type=f32)
        # contribution of the inbound state (the SPSC slot from chunk c-1)
        state_decay = jnp.exp(dA_cum)                           # (b,l,h)
        y_off = jnp.einsum("bln,bhpn,blh->blhp", Cc, h_prev, state_decay)
        # new state = decayed old + within-chunk accumulation
        decay_to_end = jnp.exp(dA_cum[:, -1:, :] - dA_cum)      # (b,l,h)
        states = jnp.einsum("bln,blh,blhp->bhpn", Bc, decay_to_end * dtc, xc)
        h_new = h_prev * jnp.exp(dA_cum[:, -1])[..., None, None] + states
        return h_new, y_diag + y_off

    hs, ys = lax.scan(
        per_chunk, h_init,
        (xr.transpose(1, 0, 2, 3, 4), dtr.transpose(1, 0, 2, 3),
         Br.transpose(1, 0, 2, 3), Cr.transpose(1, 0, 2, 3)),
        unroll=scan_unroll())
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, t, h, p)
    return y, hs


# --------------------------------------------------------------------------
# full Mamba2 block
# --------------------------------------------------------------------------
def ssm_init(key, cfg: ModelConfig) -> Dict:
    d, di, n, hh, kk = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    dt = jnp.exp(jax.random.uniform(ks[4], (hh,), jnp.float32,
                                    jnp.log(0.001), jnp.log(0.1)))
    return {
        "w_z": dense_init(ks[0], (d, di), d, cfg.param_dtype),
        "w_xbc": dense_init(ks[1], (d, di + 2 * n), d, cfg.param_dtype),
        "w_dt": dense_init(ks[2], (d, hh), d, cfg.param_dtype),
        "dt_bias": jnp.log(jnp.expm1(dt)),                     # softplus inverse
        "A_log": jnp.log(jnp.arange(1, hh + 1, dtype=jnp.float32)),
        "D": jnp.ones((hh,), jnp.float32),
        "conv_w": (jax.random.normal(ks[3], (kk, di + 2 * n), jnp.float32)
                   * (kk ** -0.5)).astype(cfg.param_dtype),
        "norm": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks[5], (di, d), di, cfg.param_dtype),
    }


def _causal_conv(xbc: jnp.ndarray, conv_w: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv over time. xbc (B,T,Ch); conv_w (K,Ch).
    Returns (out (B,T,Ch), new_state (B,K-1,Ch))."""
    k = conv_w.shape[0]
    if state is None:
        state = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    padded = jnp.concatenate([state, xbc], axis=1)              # (B, T+K-1, Ch)
    out = sum(padded[:, i:i + xbc.shape[1]] * conv_w[i] for i in range(k))
    new_state = padded[:, -(k - 1):] if k > 1 else state
    return out, new_state


def _block_inputs(params, u, cfg: ModelConfig, conv_state=None):
    di, n, hh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = u @ params["w_z"]                                       # (B,T,di)
    xbc = u @ params["w_xbc"]                                   # (B,T,di+2n)
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], conv_state)
    xbc = jax.nn.silu(xbc)
    x, B, C = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus((u @ params["w_dt"]).astype(jnp.float32)
                         + params["dt_bias"])                   # (B,T,H)
    A = -jnp.exp(params["A_log"])                               # (H,)
    xh = x.reshape(*x.shape[:-1], hh, cfg.ssm_headdim)
    return z, xh, dt, A, B, C, new_conv


def ssm_apply(params, u, cfg: ModelConfig, *, h0=None, conv_state=None,
              return_cache: bool = False):
    """Full-sequence Mamba2 block. u (B,T,d) → (B,T,d) [+cache]."""
    z, xh, dt, A, B, C, new_conv = _block_inputs(params, u, cfg, conv_state)
    y, h_final = ssd_chunked(xh, dt, A, B, C, cfg.ssm_chunk, h0=h0,
                             compute_dtype=jnp.dtype(cfg.ssm_compute_dtype))
    y = y + xh.astype(jnp.float32) * params["D"][:, None]
    y = y.reshape(*u.shape[:-1], cfg.d_inner).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["w_out"]
    if return_cache:
        return out, {"h": h_final, "conv": new_conv}
    return out


def init_ssm_cache(batch: int, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    return {
        "h": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state),
                       jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner + 2 * cfg.ssm_state),
                          dtype),
    }


def ssm_decode(params, u, cache: Dict, cfg: ModelConfig):
    """Single-token step. u (B,1,d) → ((B,1,d), new_cache).  O(1) in context
    length — this is why the SSM archs run the 500k-decode cell."""
    z, xh, dt, A, B, C, new_conv = _block_inputs(params, u, cfg, cache["conv"])
    x_t = xh[:, 0].astype(jnp.float32)                          # (B,H,P)
    dt_t = dt[:, 0]                                             # (B,H)
    a = jnp.exp(dt_t * A)                                       # (B,H)
    upd = jnp.einsum("bhp,bn->bhpn", x_t * dt_t[..., None], B[:, 0].astype(jnp.float32))
    h = cache["h"] * a[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", h, C[:, 0].astype(jnp.float32))
    y = y + x_t * params["D"][:, None]
    y = y.reshape(u.shape[0], 1, cfg.d_inner).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    return y @ params["w_out"], {"h": h, "conv": new_conv}
