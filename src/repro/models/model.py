"""Unified decoder LM covering all 10 assigned architectures.

One parameter tree + one forward, assembled from the block zoo
(self-attention / dense-MLP / MoE / Mamba2-SSD / cross-attention) according
to ``cfg.layer_kinds()``.  Execution is grouped into homogeneous segments
scanned with ``lax.scan`` (+ optional remat), so compile time is O(1) in
depth:

  * uniform   — dense / moe / ssm / audio: one stacked segment;
  * hybrid    — zamba2: groups of (attn_every-1) SSM blocks + 1 attention
                block whose parameters are *shared* across groups;
  * vlm       — llama-3.2-vision: groups of (cross_attn_every-1) self-attn
                blocks + 1 cross-attention block over vision embeddings.

Distribution: GSPMD constraints (`parallel.shard`) everywhere, except three
regions with hand-placed collectives under FULL-manual shard_map
(`parallel.manual_model`): embedding lookup, vocab-parallel cross-entropy,
and MoE dispatch (the farm) — with explicit ZeRO-3 gathers inside
(`fsdp_gather`).  Head/vocab padding makes every sharded dim divide the
mesh (padded heads are hard-masked so numerics equal the unpadded model).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..parallel.context import (current_ctx, fsdp_gather, manual_model,
                                psum_compat, shard)
from .attention import attention, decode_attention
from .config import ModelConfig
from .layers import (dense_init, embed_init, rms_norm, apply_rope,
                     scan_unroll, swiglu)
from .moe import moe_apply, moe_init
from .ssm import init_ssm_cache, ssm_apply, ssm_decode, ssm_init

__all__ = [
    "init_params", "params_pspecs", "loss_fn", "prefill", "decode_step",
    "init_cache", "cache_pspecs", "batch_pspecs", "segment_counts",
]

Params = Dict[str, Any]


# ==========================================================================
# layout
# ==========================================================================
def segment_counts(cfg: ModelConfig) -> Dict[str, int]:
    kinds = cfg.layer_kinds()
    if cfg.family == "hybrid":
        n_groups = sum(1 for k in kinds if k in ("attn", "attn_shared"))
        inner = cfg.attn_every - 1
        assert n_groups * cfg.attn_every == cfg.n_layers
        return {"groups": n_groups, "ssm_per_group": inner}
    if cfg.family == "vlm":
        n_groups = sum(1 for k in kinds if k == "cross")
        inner = cfg.cross_attn_every - 1
        assert n_groups * cfg.cross_attn_every == cfg.n_layers
        return {"groups": n_groups, "self_per_group": inner}
    return {"blocks": cfg.n_layers}


def _kv_heads_alloc(cfg: ModelConfig) -> int:
    # MHA: pad kv together with q heads; GQA: keep kv unpadded (replicated)
    return cfg.n_heads_padded if cfg.n_kv_heads == cfg.n_heads else cfg.n_kv_heads


# ==========================================================================
# init (+ matching PartitionSpec token trees)
# ==========================================================================
def _attn_block_init(key, cfg: ModelConfig, cross: bool = False):
    d, hp, kv, dh = cfg.d_model, cfg.n_heads_padded, _kv_heads_alloc(cfg), cfg.hdim
    ks = jax.random.split(key, 8)
    p = {
        "norm1": jnp.ones((d,), jnp.float32),
        "wq": dense_init(ks[0], (d, hp, dh), d, cfg.param_dtype),
        "wk": dense_init(ks[1], (d, kv, dh), d, cfg.param_dtype),
        "wv": dense_init(ks[2], (d, kv, dh), d, cfg.param_dtype),
        "wo": dense_init(ks[3], (hp, dh, d), hp * dh, cfg.param_dtype),
    }
    if cfg.family == "moe" and not cross:
        p["norm2"] = jnp.ones((d,), jnp.float32)
        p["moe"] = moe_init(ks[4], cfg)
    elif cfg.d_ff:
        p["norm2"] = jnp.ones((d,), jnp.float32)
        p["mlp"] = {
            "w_gate": dense_init(ks[5], (d, cfg.d_ff), d, cfg.param_dtype),
            "w_up": dense_init(ks[6], (d, cfg.d_ff), d, cfg.param_dtype),
            "w_down": dense_init(ks[7], (cfg.d_ff, d), cfg.d_ff, cfg.param_dtype),
        }
    return p


def _fsdp_tok(cfg: ModelConfig):
    """'dp' for training (ZeRO-3); None for replicated-param serving."""
    return None if cfg.serve_params_replicated else "dp"


def _attn_block_specs(cfg: ModelConfig, mp_size: int, cross: bool = False):
    dp = _fsdp_tok(cfg)
    p = {
        "norm1": None,
        "wq": (dp, "mp", None),
        "wk": (dp, None, None),
        "wv": (dp, None, None),
        "wo": ("mp", None, dp),
    }
    if cfg.family == "moe" and not cross:
        ep = cfg.n_experts % max(mp_size, 1) == 0
        moe = {
            "router": (dp, None),
            "w_gate": ("mp", dp, None) if ep else (None, dp, "mp"),
            "w_up": ("mp", dp, None) if ep else (None, dp, "mp"),
            "w_down": ("mp", None, dp) if ep else (None, "mp", dp),
        }
        if cfg.n_shared_experts:
            moe["shared"] = {"w_gate": (dp, "mp"), "w_up": (dp, "mp"),
                             "w_down": ("mp", dp)}
        p["norm2"] = None
        p["moe"] = moe
    elif cfg.d_ff:
        p["norm2"] = None
        p["mlp"] = {"w_gate": (dp, "mp"), "w_up": (dp, "mp"),
                    "w_down": ("mp", dp)}
    return p


def _ssm_specs(cfg: ModelConfig):
    # SSD state mixes (H, P, N) non-separably with n_groups=1, so Mamba
    # params replicate over the model axis (documented limitation: Mamba TP
    # requires grouped B/C); FSDP over data still shards storage.
    dp = _fsdp_tok(cfg)
    return {
        "w_z": (dp, None), "w_xbc": (dp, None), "w_dt": (dp, None),
        "dt_bias": None, "A_log": None, "D": None,
        "conv_w": (None, dp), "norm": None, "w_out": (None, dp),
    }


def _stacked(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_params(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 10)
    segs = segment_counts(cfg)
    params: Params = {
        "embed": embed_init(ks[0], cfg.vocab_padded, cfg.d_model, cfg.param_dtype),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if cfg.n_codebooks:
        params["lm_head"] = jax.vmap(
            lambda k: embed_init(k, cfg.vocab_padded, cfg.d_model, cfg.param_dtype)
        )(jax.random.split(ks[1], cfg.n_codebooks))
    else:
        params["lm_head"] = embed_init(ks[1], cfg.vocab_padded, cfg.d_model, cfg.param_dtype)

    if cfg.family == "hybrid":
        g, inner = segs["groups"], segs["ssm_per_group"]
        params["ssm"] = _stacked(lambda k: _stacked(partial(ssm_init, cfg=cfg), k, inner), ks[2], g)
        params["shared_attn"] = _attn_block_init(ks[3], cfg)   # ONE block, reused
    elif cfg.family == "vlm":
        g, inner = segs["groups"], segs["self_per_group"]
        params["self"] = _stacked(lambda k: _stacked(partial(_attn_block_init, cfg=cfg), k, inner), ks[2], g)
        params["cross"] = _stacked(partial(_attn_block_init, cfg=cfg, cross=True), ks[3], g)
        params["vision_proj"] = dense_init(ks[4], (cfg.vision_dim, cfg.d_model),
                                           cfg.vision_dim, cfg.param_dtype)
    elif cfg.family == "ssm":
        params["blocks"] = _stacked(partial(ssm_init, cfg=cfg), ks[2], segs["blocks"])
    else:
        params["blocks"] = _stacked(partial(_attn_block_init, cfg=cfg), ks[2], segs["blocks"])
    return params


def params_pspecs(cfg: ModelConfig, mp_size: int = 16) -> Params:
    """Same tree structure as init_params, with sharding-token tuples.
    Stacked segments get a leading ``None`` dim prepended per stack level."""
    def prepend(tree, n_lead: int):
        return jax.tree.map(
            lambda s: tuple([None] * n_lead) + (s if isinstance(s, tuple) else ()),
            tree, is_leaf=lambda v: v is None or type(v) is tuple)

    segs = segment_counts(cfg)
    dp = _fsdp_tok(cfg)
    specs: Params = {
        "embed": ("mp", dp),
        "final_norm": None,
        "lm_head": (None, "mp", dp) if cfg.n_codebooks else ("mp", dp),
    }
    if cfg.family == "hybrid":
        specs["ssm"] = prepend(_ssm_specs(cfg), 2)
        specs["shared_attn"] = _attn_block_specs(cfg, mp_size)
    elif cfg.family == "vlm":
        specs["self"] = prepend(_attn_block_specs(cfg, mp_size), 2)
        specs["cross"] = prepend(_attn_block_specs(cfg, mp_size, cross=True), 1)
        specs["vision_proj"] = (dp, None)
    elif cfg.family == "ssm":
        specs["blocks"] = prepend(_ssm_specs(cfg), 1)
    else:
        specs["blocks"] = prepend(_attn_block_specs(cfg, mp_size), 1)
    return specs


# ==========================================================================
# manual-collective regions (embedding, vocab-parallel CE)
# ==========================================================================
def _dp_tok(batch_size: int):
    """'dp' if the batch divides the dp axes, else replicated (B=1 cells)."""
    ctx = current_ctx()
    if ctx is None or batch_size % max(ctx.dp_size, 1) != 0:
        return None
    return "dp"


def _embed_lookup(table: jnp.ndarray, ids: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    ctx = current_ctx()
    if ctx is None:
        return table[ids]
    b = _dp_tok(ids.shape[0])

    tspec = ("mp", _fsdp_tok(cfg))

    def local(tbl, ids):
        tbl = fsdp_gather(tbl, tspec)
        v_loc = tbl.shape[0]
        me = lax.axis_index(ctx.model_axis)
        loc = ids - me * v_loc
        ok = (loc >= 0) & (loc < v_loc)
        emb = jnp.where(ok[..., None], tbl[jnp.clip(loc, 0, v_loc - 1)], 0)
        return psum_compat(emb, ctx.model_axis)

    return manual_model(local, [tspec, (b, None)],
                        (b, None, None))(table, ids)


def _vocab_ce(x: jnp.ndarray, head: jnp.ndarray, labels: jnp.ndarray,
              cfg: ModelConfig) -> jnp.ndarray:
    """Vocab-parallel cross entropy; x (B,S,d), head (V,d) model-sharded,
    labels (B,S).  Never materialises replicated (B,S,V) logits."""
    ctx = current_ctx()

    def chunk_loss(x_c, labels_c, head):
        if ctx is None:
            logits = (x_c.astype(jnp.float32) @ head.astype(jnp.float32).T)
            logits = logits[..., :cfg.vocab_size]
            lse = jax.scipy.special.logsumexp(logits, axis=-1)
            lab = jnp.take_along_axis(logits, labels_c[..., None], axis=-1)[..., 0]
            return lse - lab

        hspec = ("mp", _fsdp_tok(cfg))

        def local(x_c, head, labels_c):
            head = fsdp_gather(head, hspec)
            v_loc = head.shape[0]
            me = lax.axis_index(ctx.model_axis)
            logits = x_c.astype(jnp.float32) @ head.astype(jnp.float32).T  # (B,s,V/n)
            # mask vocab padding (global ids >= vocab_size)
            gid = me * v_loc + jnp.arange(v_loc)
            logits = jnp.where(gid < cfg.vocab_size, logits, -1e30)
            # stabiliser only — detach BEFORE pmax (pmax has no JVP rule;
            # with symbolic-zero tangents it is skipped by autodiff)
            gmax = lax.pmax(lax.stop_gradient(jnp.max(logits, axis=-1)),
                            ctx.model_axis)
            se = jnp.sum(jnp.exp(logits - gmax[..., None]), axis=-1)
            lse = jnp.log(lax.psum(se, ctx.model_axis)) + gmax
            loc = labels_c - me * v_loc
            ok = (loc >= 0) & (loc < v_loc)
            lab = jnp.where(ok, jnp.take_along_axis(
                logits, jnp.clip(loc, 0, v_loc - 1)[..., None], axis=-1)[..., 0], 0.0)
            lab = lax.psum(lab, ctx.model_axis)
            return lse - lab

        b = _dp_tok(x_c.shape[0])
        return manual_model(local, [(b, None, None), hspec, (b, None)],
                            (b, None))(x_c, head, labels_c)

    S = x.shape[1]
    csize = cfg.loss_chunk if cfg.loss_chunk and S % cfg.loss_chunk == 0 else S
    if csize == S:
        per_tok = chunk_loss(x, labels, head)
    else:
        nc = S // csize
        xs = x.reshape(x.shape[0], nc, csize, -1).transpose(1, 0, 2, 3)
        ls = labels.reshape(labels.shape[0], nc, csize).transpose(1, 0, 2)
        body = jax.checkpoint(lambda xc, lc: chunk_loss(xc, lc, head))
        _, per_tok = lax.scan(lambda c, args: (c, body(*args)), 0, (xs, ls),
                              unroll=scan_unroll())
        per_tok = per_tok.transpose(1, 0, 2).reshape(labels.shape)
    return per_tok


def _logits_full(x: jnp.ndarray, head: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Decode-time logits (B, V) — small, gathered replicated."""
    logits = jnp.einsum("bd,vd->bv", x.astype(jnp.float32), head.astype(jnp.float32))
    return logits[:, :cfg.vocab_size]


# ==========================================================================
# blocks
# ==========================================================================
def _head_mask(cfg: ModelConfig):
    hp = cfg.n_heads_padded
    if hp == cfg.n_heads:
        return None
    return (jnp.arange(hp) < cfg.n_heads).astype(cfg.param_dtype)


def _attn_core(p, x, cfg: ModelConfig, *, positions, mode: str,
               kv_cache=None, cache_len=None, rolling=False,
               ext_kv=None, start_pos=None):
    """Shared attention path. Returns (delta, new_kv_cache or None)."""
    B = x.shape[0]
    hp, dh = cfg.n_heads_padded, cfg.hdim
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    q = shard(q, "dp", None, "mp", None)
    if ext_kv is not None:  # cross-attention: kv from the vision stream
        k, v = ext_kv
        new_cache = None
    else:
        k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if mode == "decode" and ext_kv is None:
        k_cache, v_cache = kv_cache
        T = k_cache.shape[1]
        slot = (cache_len % T) if rolling else cache_len
        k_cache = lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), slot, axis=1)
        v_cache = lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), slot, axis=1)
        new_cache = (k_cache, v_cache)
        attn = decode_attention(q, k_cache, v_cache, cache_len + 1,
                                window=cfg.sliding_window, rolling=rolling,
                                start_pos=start_pos)
    elif mode == "decode":  # cross-attn decode: attend the cached vision kv
        attn = decode_attention(q, k, v, jnp.int32(k.shape[1]))
        new_cache = None
    else:
        causal = ext_kv is None
        attn = attention(q, k, v, causal=causal, window=cfg.sliding_window,
                         impl=cfg.attn_impl, q_chunk=cfg.attn_q_chunk,
                         kv_chunk=cfg.attn_kv_chunk, causal_skip=cfg.causal_skip)
        if ext_kv is None and mode == "prefill":
            new_cache = (k, v)
        else:
            new_cache = None
    mask = _head_mask(cfg)
    if mask is not None:
        attn = attn * mask[None, None, :, None]
    out = jnp.einsum("bshk,hkd->bsd", attn.astype(x.dtype), p["wo"])
    out = shard(out, "dp", None, None)
    return out, new_cache


def _ffn_part(p, x, cfg: ModelConfig):
    """MLP or MoE sub-block (with pre-norm + residual). Returns (x, aux)."""
    if "moe" in p:
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        ctx = current_ctx()
        if ctx is None:
            delta, aux = moe_apply(h, p["moe"], cfg, axis_name=None)
        else:
            ep = cfg.n_experts % ctx.mp_size == 0
            dp = _fsdp_tok(cfg)
            espec = ("mp", dp, None) if ep else (None, dp, "mp")
            dspec = ("mp", None, dp) if ep else (None, "mp", dp)
            mspec = {"router": (dp, None),
                     "w_gate": espec, "w_up": espec, "w_down": dspec}
            if "shared" in p["moe"]:
                mspec["shared"] = {"w_gate": (dp, "mp"), "w_up": (dp, "mp"),
                                   "w_down": ("mp", dp)}
            b = _dp_tok(h.shape[0])

            def local(h_, m_):
                m_ = fsdp_gather(m_, mspec)          # explicit ZeRO-3 gather
                return moe_apply(h_, m_, cfg, axis_name=ctx.model_axis)

            fn = manual_model(local, [(b, None, None), mspec],
                              [(b, None, None), None])
            delta, aux = fn(h, p["moe"])
        return x + delta, aux
    if "mlp" in p:
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        m = p["mlp"]
        delta = swiglu(h, m["w_gate"], m["w_up"], m["w_down"])
        delta = shard(delta, "dp", None, None)
        return x + delta, jnp.float32(0)
    return x, jnp.float32(0)


def _attn_block(p, x, cfg, *, positions, mode, kv_cache=None, cache_len=None,
                rolling=False, ext_kv=None, start_pos=None):
    delta, new_cache = _attn_core(p, x, cfg, positions=positions, mode=mode,
                                  kv_cache=kv_cache, cache_len=cache_len,
                                  rolling=rolling, ext_kv=ext_kv,
                                  start_pos=start_pos)
    x = x + delta
    x, aux = _ffn_part(p, x, cfg)
    return x, aux, new_cache


# ==========================================================================
# forward
# ==========================================================================
def _maybe_remat(fn, cfg: ModelConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


def _vision_kv(params, vision_embeds, cfg: ModelConfig):
    """Project the (stub) vision embeddings once; per-cross-layer K/V are
    computed from this shared stream inside each cross block."""
    return (vision_embeds @ params["vision_proj"]).astype(params["vision_proj"].dtype)


def forward_hidden(params: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                   mode: str = "train", positions=None, cache=None,
                   cache_len=None, vision_stream=None, start_pos=None):
    """Run all blocks. x: (B,S,d) embeddings. Returns (x, aux, new_cache)."""
    aux_total = jnp.float32(0)
    new_cache: Dict[str, Any] = {}
    rolling = cfg.sliding_window is not None and mode == "decode"

    if cfg.family in ("dense", "moe", "audio"):
        def body(carry, xs):
            x, aux = carry
            p = xs["p"]
            kvc = (xs["k"], xs["v"]) if mode == "decode" else None
            x, a, nc = _attn_block(p, x, cfg, positions=positions, mode=mode,
                                   kv_cache=kvc, cache_len=cache_len,
                                   rolling=rolling, start_pos=start_pos)
            ys = {}
            if nc is not None:
                ys = {"k": nc[0], "v": nc[1]}
            return (x, aux + a), ys

        xs = {"p": params["blocks"]}
        if mode == "decode":
            xs["k"], xs["v"] = cache["k"], cache["v"]
        (x, aux_total), ys = lax.scan(_maybe_remat(body, cfg), (x, aux_total), xs,
                                      unroll=scan_unroll())
        if mode in ("decode", "prefill") and ys:
            new_cache = ys

    elif cfg.family == "ssm":
        x, aux_total, new_cache = _ssm_segment(params["blocks"], x, cfg, mode,
                                               cache, aux_total)

    elif cfg.family == "hybrid":
        segs = segment_counts(cfg)
        shared_p = params["shared_attn"]

        def group(carry, xs):
            x, aux, clen = carry
            # inner ssm stack
            x, _, ssm_c = _ssm_segment_inner(xs["ssm"], x, cfg, mode,
                                             {"h": xs.get("h"), "conv": xs.get("conv")})
            # shared attention block
            kvc = (xs["k"], xs["v"]) if mode == "decode" else None
            x, a, nc = _attn_block(shared_p, x, cfg, positions=positions,
                                   mode=mode, kv_cache=kvc, cache_len=clen,
                                   start_pos=start_pos)
            ys = dict(ssm_c)
            if nc is not None:
                ys["k"], ys["v"] = nc
            return (x, aux + a, clen), ys

        xs = {"ssm": params["ssm"]}
        if mode == "decode":
            xs.update({"h": cache["h"], "conv": cache["conv"],
                       "k": cache["k"], "v": cache["v"]})
        (x, aux_total, _), ys = lax.scan(_maybe_remat(group, cfg),
                                         (x, aux_total, cache_len if cache_len is not None else jnp.int32(0)), xs,
                                         unroll=scan_unroll())
        if mode in ("decode", "prefill") and ys:
            new_cache = ys

    elif cfg.family == "vlm":
        def group(carry, xs):
            x, aux, clen = carry

            def inner(c2, p_inner):
                x2, aux2 = c2
                kvc = (p_inner["k"], p_inner["v"]) if mode == "decode" else None
                x2, a2, nc2 = _attn_block(p_inner["p"], x2, cfg, positions=positions,
                                          mode=mode, kv_cache=kvc, cache_len=clen,
                                          start_pos=start_pos)
                ys2 = {"k": nc2[0], "v": nc2[1]} if nc2 is not None else {}
                return (x2, aux2 + a2), ys2

            xs_in = {"p": xs["self"]}
            if mode == "decode":
                xs_in["k"], xs_in["v"] = xs["k"], xs["v"]
            (x, aux), ys_inner = lax.scan(inner, (x, aux), xs_in,
                                          unroll=scan_unroll())
            # cross-attn over the vision stream
            pc = xs["cross"]
            kc = jnp.einsum("bpd,dhk->bphk", vision_stream, pc["wk"])
            vc = jnp.einsum("bpd,dhk->bphk", vision_stream, pc["wv"])
            x, a, _ = _attn_block(pc, x, cfg, positions=positions, mode=mode,
                                  ext_kv=(kc, vc))
            return (x, aux + a, clen), ys_inner

        xs = {"self": params["self"], "cross": params["cross"]}
        if mode == "decode":
            xs["k"], xs["v"] = cache["k"], cache["v"]
        (x, aux_total, _), ys = lax.scan(_maybe_remat(group, cfg),
                                         (x, aux_total, cache_len if cache_len is not None else jnp.int32(0)), xs,
                                         unroll=scan_unroll())
        if mode in ("decode", "prefill") and ys:
            new_cache = ys
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux_total, new_cache


def _ssm_segment_inner(p_stack, x, cfg, mode, cache):
    """Scan a stacked ssm sub-segment. cache leaves may be None (train)."""
    def body(x, xs):
        if mode == "decode":
            delta, nc = ssm_decode(xs["p"], x, {"h": xs["h"], "conv": xs["conv"]}, cfg)
            return x + delta, nc
        if mode == "prefill":
            delta, nc = ssm_apply(xs["p"], x, cfg, return_cache=True)
            return x + delta, nc
        return x + ssm_apply(xs["p"], x, cfg), {}

    xs = {"p": p_stack}
    if mode == "decode":
        xs["h"], xs["conv"] = cache["h"], cache["conv"]
    x, ys = lax.scan(body, x, xs, unroll=scan_unroll())
    return x, jnp.float32(0), ys


def _ssm_segment(p_stack, x, cfg, mode, cache, aux):
    x, _, ys = _ssm_segment_inner(p_stack, x, cfg, mode, cache or {})
    return x, aux, ys


# ==========================================================================
# entry points
# ==========================================================================
def _embed_batch(params, batch, cfg: ModelConfig):
    if cfg.family == "audio":
        x = batch["frames"].astype(cfg.param_dtype)       # stub frontend
    else:
        x = _embed_lookup(params["embed"], batch["tokens"], cfg)
    x = shard(x, "dp", None, None)
    vision = None
    if cfg.family == "vlm":
        vision = _vision_kv(params, batch["vision_embeds"].astype(cfg.param_dtype), cfg)
    return x, vision


def loss_fn(params: Params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    x, vision = _embed_batch(params, batch, cfg)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x, aux, _ = forward_hidden(params, x, cfg, mode="train",
                               positions=positions, vision_stream=vision)
    if cfg.n_codebooks:
        losses = []
        for cb in range(cfg.n_codebooks):
            per = _vocab_ce(x, params["lm_head"][cb], batch["labels"][:, cb], cfg)
            losses.append(per.mean())
        ce = sum(losses) / cfg.n_codebooks
    else:
        ce = _vocab_ce(x, params["lm_head"], batch["labels"], cfg).mean()
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


def prefill(params: Params, batch, cfg: ModelConfig):
    """Forward pass that also returns the populated cache + last logits."""
    x, vision = _embed_batch(params, batch, cfg)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x, _, new_cache = forward_hidden(params, x, cfg, mode="prefill",
                                     positions=positions, vision_stream=vision)
    if cfg.sliding_window is not None and "k" in new_cache:
        w = min(cfg.sliding_window, S)
        new_cache["k"] = new_cache["k"][:, :, -w:]
        new_cache["v"] = new_cache["v"][:, :, -w:]
    last = x[:, -1]
    if cfg.n_codebooks:
        logits = jnp.stack([_logits_full(last, params["lm_head"][cb], cfg)
                            for cb in range(cfg.n_codebooks)], axis=1)
    else:
        logits = _logits_full(last, params["lm_head"], cfg)
    return logits, new_cache


def decode_step(params: Params, batch, cache, cache_len, cfg: ModelConfig):
    """One token for every sequence in the batch.

    batch: {"tokens": (B,1)} (or {"frames": (B,1,d)} for audio);
    cache_len: scalar int32 — valid length before this step.
    Returns (logits, new_cache)."""
    x, vision = _embed_batch(params, batch, cfg)
    positions = jnp.broadcast_to(cache_len, (x.shape[0], 1))
    start_pos = batch.get("start_pos")
    x, _, new_cache = forward_hidden(params, x, cfg, mode="decode",
                                     positions=positions, cache=cache,
                                     cache_len=cache_len, vision_stream=vision,
                                     start_pos=start_pos)
    last = x[:, -1]
    if cfg.n_codebooks:
        logits = jnp.stack([_logits_full(last, params["lm_head"][cb], cfg)
                            for cb in range(cfg.n_codebooks)], axis=1)
    else:
        logits = _logits_full(last, params["lm_head"], cfg)
    return logits, new_cache


# ==========================================================================
# caches & input specs
# ==========================================================================
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Allocate an (empty) decode cache matching forward_hidden's layout."""
    kv = _kv_heads_alloc(cfg)
    dh = cfg.hdim
    T = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    dt = cfg.param_dtype
    segs = segment_counts(cfg)

    def kv_pair(n):
        return (jnp.zeros((n, batch, T, kv, dh), dt),
                jnp.zeros((n, batch, T, kv, dh), dt))

    if cfg.family in ("dense", "moe", "audio"):
        k, v = kv_pair(segs["blocks"])
        return {"k": k, "v": v}
    if cfg.family == "ssm":
        c = jax.vmap(lambda _: init_ssm_cache(batch, cfg, dt))(jnp.arange(segs["blocks"]))
        return c
    if cfg.family == "hybrid":
        g, inner = segs["groups"], segs["ssm_per_group"]
        ssm_c = jax.vmap(lambda _: jax.vmap(lambda __: init_ssm_cache(batch, cfg, dt))(jnp.arange(inner)))(jnp.arange(g))
        k, v = kv_pair(g)
        return {"h": ssm_c["h"], "conv": ssm_c["conv"], "k": k, "v": v}
    if cfg.family == "vlm":
        g, inner = segs["groups"], segs["self_per_group"]
        k = jnp.zeros((g, inner, batch, T, kv, dh), dt)
        return {"k": k, "v": jnp.zeros_like(k)}
    raise ValueError(cfg.family)


def cache_pspecs(cfg: ModelConfig, batch: int, dp_divisible: bool) -> Dict[str, Any]:
    b = "dp" if dp_divisible else None
    kv_spec = (None, b, "mp", None, None)
    if cfg.family in ("dense", "moe", "audio"):
        return {"k": kv_spec, "v": kv_spec}
    if cfg.family == "ssm":
        return {"h": (None, b, None, None, None), "conv": (None, b, None, None)}
    if cfg.family == "hybrid":
        return {"h": (None, None, b, None, None, None),
                "conv": (None, None, b, None, None),
                "k": kv_spec, "v": kv_spec}
    if cfg.family == "vlm":
        s = (None, None, b, "mp", None, None)
        return {"k": s, "v": s}
    raise ValueError(cfg.family)


def batch_pspecs(cfg: ModelConfig, batch: int, dp_size: int) -> Dict[str, Any]:
    b = "dp" if batch % max(dp_size, 1) == 0 else None
    out = {}
    if cfg.family == "audio":
        out["frames"] = (b, None, None)
        out["labels"] = (b, None, None)
    else:
        out["tokens"] = (b, None)
        out["labels"] = (b, None)
    if cfg.family == "vlm":
        out["vision_embeds"] = (b, None, None)
    return out
