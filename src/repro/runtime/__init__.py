from .checkpoint import AsyncCheckpointer, latest_step, restore
from .fault import FaultTolerantRunner, Heartbeat

__all__ = ["AsyncCheckpointer", "restore", "latest_step",
           "FaultTolerantRunner", "Heartbeat"]
