"""Async sharded checkpointing — the Collector pattern applied to I/O.

The train loop never blocks on checkpoint I/O: it enqueues a (step, state)
reference onto a lock-free SPSC ring and continues; a dedicated writer
thread (the paper's Collector) drains the ring, pulls arrays off device and
writes an atomically-renamed step directory:

    <dir>/step_000123/ arrays.npz  manifest.json      (tmp → os.replace)

Restore is **mesh-agnostic** (elastic): arrays are loaded on host and
``jax.device_put`` with the *target* shardings, so a job checkpointed on a
16×16 mesh restarts unchanged on 2×16×16 (or on 1 CPU device in the tests).
The manifest keys are tree paths, so restore also tolerates superset trees.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.spsc import EOS, SPSCQueue

__all__ = ["AsyncCheckpointer", "restore", "latest_step"]


def _flatten(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}, treedef


def save_sync(state: Any, step: int, directory: str) -> str:
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f".tmp_step_{step:09d}")
    final = os.path.join(directory, f"step_{step:09d}")
    os.makedirs(tmp, exist_ok=True)
    named, _ = _flatten(state)
    arrays = {}
    for k, v in named.items():
        a = np.asarray(jax.device_get(v))
        if a.dtype.name == "bfloat16":
            a = a.astype(np.float32)   # lossless widen; numpy can't store bf16
        arrays[k] = a
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "time": time.time(),
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.isdir(final):
        import shutil
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    return final


class AsyncCheckpointer:
    def __init__(self, directory: str, *, keep: int = 3, ring: int = 2):
        self.directory = directory
        self.keep = keep
        self._ring = SPSCQueue(ring)
        self._written: list[int] = []
        self._errors: list[BaseException] = []
        self._pending = 0
        self._thread = threading.Thread(target=self._writer, name="ckpt-collector",
                                        daemon=True)
        self._thread.start()

    def _writer(self) -> None:
        while True:
            item = self._ring.pop_wait()
            if item is EOS:
                return
            step, state = item
            try:
                save_sync(state, step, self.directory)
                self._written.append(step)
                self._gc()
            except BaseException as e:  # pragma: no cover
                self._errors.append(e)
            finally:
                self._pending -= 1

    def _gc(self) -> None:
        steps = sorted(self._written)
        for s in steps[:-self.keep]:
            path = os.path.join(self.directory, f"step_{s:09d}")
            if os.path.isdir(path):
                import shutil
                shutil.rmtree(path, ignore_errors=True)
            self._written.remove(s)

    def save(self, state: Any, step: int) -> None:
        """Non-blocking.  SNAPSHOTS the state with an on-device copy first:
        train steps donate their input buffers (``donate_argnums``), so the
        caller's references become invalid the moment the next step runs —
        the copy is what makes async checkpointing safe under donation."""
        snap = jax.tree.map(
            lambda x: x.copy() if hasattr(x, "copy") else x, state)
        self._pending += 1
        self._ring.push_wait((step, snap))

    def wait(self) -> None:
        """Block until every enqueued checkpoint is durably published."""
        while self._pending > 0:
            time.sleep(0.005)
        if self._errors:
            raise self._errors[0]

    def close(self) -> None:
        self._ring.push_wait(EOS)
        self._thread.join(timeout=60)
        if self._errors:
            raise self._errors[0]


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(template: Any, directory: str, step: Optional[int] = None,
            shardings: Optional[Any] = None) -> Any:
    """Load into the structure of ``template``; optionally placed with
    ``shardings`` (same tree structure) — the elastic-restart path."""
    step = latest_step(directory) if step is None else step
    assert step is not None, f"no checkpoint in {directory}"
    path = os.path.join(directory, f"step_{step:09d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    named, treedef = _flatten(template)
    if shardings is not None:
        shards, _ = _flatten(shardings)
    out = {}
    for k, tpl in named.items():
        a = arrays[k]
        if hasattr(tpl, "dtype") and a.dtype != tpl.dtype:
            a = jnp.asarray(a).astype(tpl.dtype)   # handles bf16 and friends
        if shardings is not None and k in shards:
            out[k] = jax.device_put(a, shards[k])
        else:
            out[k] = jax.device_put(a)
    leaves = [out[k] for k in named.keys()]
    return jax.tree_util.tree_unflatten(treedef, leaves)
