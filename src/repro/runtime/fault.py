"""Failure detection + restart-from-checkpoint + straggler policy.

At thousands of nodes, failures are routine.  The runtime's contract:

  * **Heartbeat** — every participant bumps a counter; a monitor thread
    flags members silent for > ``timeout`` (in a real deployment this wraps
    the coordination-service barrier; here it guards host-side workers —
    data emitter, checkpoint collector, farm workers).
  * **FaultTolerantRunner** — wraps the train step; on an exception
    (device loss, preemption, injected test fault) it restores the last
    published checkpoint and replays.  Together with the deterministic data
    pipeline (pure f(seed, step)) this gives exactly-once step semantics.
  * **Straggler mitigation** — at the farm level (``core/farm.py``): tasks
    older than straggler_factor × p95 are speculatively re-issued and
    deduplicated by tag at the collector.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, Optional

from .checkpoint import AsyncCheckpointer, latest_step, restore

__all__ = ["Heartbeat", "FaultTolerantRunner"]


class Heartbeat:
    def __init__(self, members, timeout: float = 30.0):
        self.timeout = timeout
        self._last: Dict[str, float] = {m: time.monotonic() for m in members}
        self._lock = threading.Lock()

    def beat(self, member: str) -> None:
        self._last[member] = time.monotonic()

    def dead(self) -> list:
        now = time.monotonic()
        return [m for m, t in self._last.items() if now - t > self.timeout]


class FaultTolerantRunner:
    """run(step_fn) with restore-on-failure semantics.

    step_fn(state, step) -> state.  ``state`` must be checkpointable.
    """

    def __init__(self, ckpt_dir: str, *, ckpt_every: int = 50,
                 max_restarts: int = 3, shardings: Optional[Any] = None):
        self.ckpt = AsyncCheckpointer(ckpt_dir)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.shardings = shardings
        self.restarts = 0

    def run(self, step_fn: Callable[[Any, int], Any], state: Any,
            start_step: int, n_steps: int,
            on_step: Optional[Callable[[int, Any], None]] = None) -> Any:
        step = start_step
        while step < start_step + n_steps:
            try:
                state = step_fn(state, step)
                if on_step is not None:
                    on_step(step, state)
                step += 1
                if step % self.ckpt_every == 0:
                    self.ckpt.save(state, step)
            except Exception:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                self.ckpt.wait()
                last = latest_step(self.ckpt_dir)
                if last is None:
                    # nothing published yet: replay from the caller's state
                    step = start_step
                    continue
                state = restore(state, self.ckpt_dir, last, self.shardings)
                step = last
        self.ckpt.save(state, step)
        self.ckpt.wait()
        return state
