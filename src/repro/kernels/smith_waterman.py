"""Smith-Waterman local alignment — Pallas TPU kernel.

Hardware adaptation (DESIGN.md §2): Farrar's SSE2 *striped* layout exists to
dodge SSE lane-shift latency and fixes F with a speculative "lazy-F" loop —
both pointless on TPU.  We keep the paper's *algorithmic* asset (the query
profile) and replace the SSE mechanics with TPU-native ones:

  * the **query axis is the 128-lane vector axis**; the whole query column
    state (H, E) lives in VMEM as (rows=Q/128 · sublanes, 128 lanes);
  * the subject **streams** through the kernel in HBM→VMEM tiles (the grid's
    sequential dimension — this kernel is itself a FastFlow pipeline: one
    SPSC hop per tile, state carried in VMEM scratch);
  * Farrar's lazy-F loop is replaced by a **closed-form prefix-max**: with
    gap_open ≥ gap_extend, F[i,j] = max_{k<i}(Ĥ[k,j] + k·ge) − go − (i−1)·ge
    where Ĥ is H computed without F — one associative scan on the VPU,
    exact, no data-dependent iteration (which TPUs hate);
  * substitution scores come from a dynamic row slice of the profile tile
    resident in VMEM (profile[c] — one sublane read per subject char).

Limitations (documented): the within-column prefix-max runs over the padded
query length Qp; queries longer than one VMEM block (Qp ≤ 8192 comfortably)
would need a second-level carry, not implemented here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["sw_pallas", "DEFAULT_TILE"]

NEG = -1e9  # python float: keeps pallas kernels constant-free
DEFAULT_TILE = 512          # subject chars per grid step


def _prefix_max_exclusive(x: jnp.ndarray) -> jnp.ndarray:
    """Exclusive running max along the last axis (log-depth, VPU-friendly)."""
    n = x.shape[-1]
    x = jnp.concatenate([jnp.full(x.shape[:-1] + (1,), NEG, x.dtype), x[..., :-1]], -1)
    shift = 1
    while shift < n:
        pad = jnp.full(x.shape[:-1] + (shift,), NEG, x.dtype)
        x = jnp.maximum(x, jnp.concatenate([pad, x[..., :-shift]], -1))
        shift *= 2
    return x


def _sw_kernel(profile_ref, subject_ref, out_ref, h_ref, e_ref, best_ref,
               *, gap_open: float, gap_extend: float, tile: int, q_len: int):
    """Grid: (num_subject_tiles,) — sequential; column state in VMEM scratch."""
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)
        e_ref[...] = jnp.full_like(e_ref, NEG)
        best_ref[...] = jnp.zeros_like(best_ref)

    prof = profile_ref[...]                      # (A, Qp) VMEM-resident tile
    chars = subject_ref[...]                     # (tile,) int32 (padded with A)
    qp = prof.shape[1]
    idx = lax.broadcasted_iota(jnp.float32, (1, qp), 1)
    qmask = idx < q_len                          # padded query lanes

    def per_char(j, carry):
        h, e, best = carry                       # (1, Qp) each
        c = chars[j]
        valid = c < prof.shape[0]
        row = jnp.clip(c, 0, prof.shape[0] - 1)
        s = jax.lax.dynamic_slice_in_dim(prof, row, 1, axis=0)     # (1, Qp)
        e_new = jnp.maximum(h - gap_open, e - gap_extend)
        h_shift = jnp.concatenate([jnp.zeros((1, 1), h.dtype), h[:, :-1]], axis=1)
        h_hat = jnp.maximum(jnp.maximum(h_shift + s, e_new), 0.0)
        h_hat = jnp.where(qmask, h_hat, 0.0)
        # closed-form F: exclusive prefix-max over the query axis
        p = _prefix_max_exclusive(h_hat + idx * gap_extend)
        f = p - gap_open - (idx - 1.0) * gap_extend
        h_new = jnp.where(qmask, jnp.maximum(h_hat, f), 0.0)
        best = jnp.maximum(best, jnp.max(h_new))
        h = jnp.where(valid, h_new, h)
        e = jnp.where(valid, e_new, e)
        best = jnp.where(valid, best, carry[2])
        return h, e, best

    h, e, best = lax.fori_loop(
        0, tile, per_char, (h_ref[...], e_ref[...], best_ref[0, 0]))
    h_ref[...] = h
    e_ref[...] = e
    best_ref[...] = jnp.full_like(best_ref, best)

    @pl.when(t == pl.num_programs(0) - 1)
    def _emit():
        out_ref[...] = jnp.full_like(out_ref, best_ref[0, 0])


@functools.partial(jax.jit, static_argnames=("gap_open", "gap_extend", "tile",
                                             "interpret", "q_len"))
def sw_pallas(profile: jnp.ndarray, subject: jnp.ndarray, *, gap_open: float,
              gap_extend: float, q_len: int, tile: int = DEFAULT_TILE,
              interpret: bool = True) -> jnp.ndarray:
    """Best local-alignment score for one (query-profile, subject) pair.

    profile: (A, Qp) f32, Qp a multiple of 128; subject: (Dp,) int32 padded
    with value >= A.  q_len: true query length (<= Qp).
    """
    A, Qp = profile.shape
    Dp = subject.shape[0]
    assert Qp % 128 == 0, "query block must fill 128-lane registers"
    assert Dp % tile == 0, "subject must be padded to the tile size"
    grid = (Dp // tile,)
    kernel = functools.partial(_sw_kernel, gap_open=float(gap_open),
                               gap_extend=float(gap_extend), tile=tile,
                               q_len=q_len)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((A, Qp), lambda t: (0, 0)),          # profile: resident
            pl.BlockSpec((tile,), lambda t: (t,)),            # subject: streamed
        ],
        out_specs=pl.BlockSpec((1, 1), lambda t: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((1, Qp), jnp.float32),   # H column state
            pltpu.VMEM((1, Qp), jnp.float32),   # E column state
            pltpu.VMEM((1, 1), jnp.float32),    # running best
        ],
        interpret=interpret,
    )(profile, subject)
    return out[0, 0]
