"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True everywhere in this repo because the container
is CPU-only; on a real TPU runtime set ``REPRO_PALLAS_COMPILE=1`` (or pass
``interpret=False``) to lower the kernels natively.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention as _flash
from .smith_waterman import sw_pallas as _sw
from .ssd_scan import ssd_scan as _ssd

__all__ = ["smith_waterman", "flash_attention_op", "ssd_scan_op",
           "build_profile", "BLOSUM50", "AA_ALPHABET", "encode_seq"]

_INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"

# ---------------------------------------------------------------------------
# Smith-Waterman front-end: alphabet, BLOSUM50, profiles
# ---------------------------------------------------------------------------
AA_ALPHABET = "ARNDCQEGHILKMFPSTWYVBZX*"        # 24 codes, BLOSUM order

# BLOSUM50 (upper triangle source: NCBI), 24x24
_B50 = """
 5 -2 -1 -2 -1 -1 -1  0 -2 -1 -2 -1 -1 -3 -1  1  0 -3 -2  0 -2 -1 -1 -5
-2  7 -1 -2 -4  1  0 -3  0 -4 -3  3 -2 -3 -3 -1 -1 -3 -1 -3 -1  0 -1 -5
-1 -1  7  2 -2  0  0  0  1 -3 -4  0 -2 -4 -2  1  0 -4 -2 -3  4  0 -1 -5
-2 -2  2  8 -4  0  2 -1 -1 -4 -4 -1 -4 -5 -1  0 -1 -5 -3 -4  5  1 -1 -5
-1 -4 -2 -4 13 -3 -3 -3 -3 -2 -2 -3 -2 -2 -4 -1 -1 -5 -3 -1 -3 -3 -2 -5
-1  1  0  0 -3  7  2 -2  1 -3 -2  2  0 -4 -1  0 -1 -1 -1 -3  0  4 -1 -5
-1  0  0  2 -3  2  6 -3  0 -4 -3  1 -2 -3 -1 -1 -1 -3 -2 -3  1  5 -1 -5
 0 -3  0 -1 -3 -2 -3  8 -2 -4 -4 -2 -3 -4 -2  0 -2 -3 -3 -4 -1 -2 -2 -5
-2  0  1 -1 -3  1  0 -2 10 -4 -3  0 -1 -1 -2 -1 -2 -3  2 -4  0  0 -1 -5
-1 -4 -3 -4 -2 -3 -4 -4 -4  5  2 -3  2  0 -3 -3 -1 -3 -1  4 -4 -3 -1 -5
-2 -3 -4 -4 -2 -2 -3 -4 -3  2  5 -3  3  1 -4 -3 -1 -2 -1  1 -4 -3 -1 -5
-1  3  0 -1 -3  2  1 -2  0 -3 -3  6 -2 -4 -1  0 -1 -3 -2 -3  0  1 -1 -5
-1 -2 -2 -4 -2  0 -2 -3 -1  2  3 -2  7  0 -3 -2 -1 -1  0  1 -3 -1 -1 -5
-3 -3 -4 -5 -2 -4 -3 -4 -1  0  1 -4  0  8 -4 -3 -2  1  4 -1 -4 -4 -2 -5
-1 -3 -2 -1 -4 -1 -1 -2 -2 -3 -4 -1 -3 -4 10 -1 -1 -4 -3 -3 -2 -1 -2 -5
 1 -1  1  0 -1  0 -1  0 -1 -3 -3  0 -2 -3 -1  5  2 -4 -2 -2  0  0 -1 -5
 0 -1  0 -1 -1 -1 -1 -2 -2 -1 -1 -1 -1 -2 -1  2  5 -3 -2  0  0 -1  0 -5
-3 -3 -4 -5 -5 -1 -3 -3 -3 -3 -2 -3 -1  1 -4 -4 -3 15  2 -3 -5 -2 -3 -5
-2 -1 -2 -3 -3 -1 -2 -3  2 -1 -1 -2  0  4 -3 -2 -2  2  8 -1 -3 -2 -1 -5
 0 -3 -3 -4 -1 -3 -3 -4 -4  4  1 -3  1 -1 -3 -2  0 -3 -1  5 -4 -3 -1 -5
-2 -1  4  5 -3  0  1 -1  0 -4 -4  0 -3 -4 -2  0  0 -5 -3 -4  5  2 -1 -5
-1  0  0  1 -3  4  5 -2  0 -3 -3  1 -1 -4 -1  0 -1 -2 -2 -3  2  5 -1 -5
-1 -1 -1 -1 -2 -1 -1 -2 -1 -1 -1 -1 -1 -2 -2 -1  0 -3 -1 -1 -1 -1 -1 -5
-5 -5 -5 -5 -5 -5 -5 -5 -5 -5 -5 -5 -5 -5 -5 -5 -5 -5 -5 -5 -5 -5 -5  1
"""
BLOSUM50 = jnp.asarray(
    [[int(v) for v in row.split()] for row in _B50.strip().splitlines()],
    jnp.float32)


def encode_seq(seq: str) -> jnp.ndarray:
    lut = {c: i for i, c in enumerate(AA_ALPHABET)}
    return jnp.asarray([lut.get(c, lut["X"]) for c in seq.upper()], jnp.int32)


def build_profile(query: jnp.ndarray, matrix: jnp.ndarray = BLOSUM50,
                  pad_to: int = 128) -> Tuple[jnp.ndarray, int]:
    """Farrar's query profile, TPU layout: (A, Qp) with Qp multiple of 128.
    Padded query positions score a large negative so they never align."""
    q_len = int(query.shape[0])
    qp = -(-q_len // pad_to) * pad_to
    prof = matrix[:, query]                                 # (A, Q)
    prof = jnp.pad(prof, ((0, 0), (0, qp - q_len)), constant_values=-1e4)
    return prof, q_len


def smith_waterman(query: jnp.ndarray, subject: jnp.ndarray, *,
                   gap_open: float = 10.0, gap_extend: float = 2.0,
                   matrix: jnp.ndarray = BLOSUM50, tile: int = 512,
                   interpret: Optional[bool] = None) -> jnp.ndarray:
    """Best local alignment score of two encoded sequences (the paper's
    application, Sec. 4.2).  Handles padding internally."""
    interpret = _INTERPRET if interpret is None else interpret
    prof, q_len = build_profile(query, matrix)
    dlen = int(subject.shape[0])
    dp = -(-dlen // tile) * tile
    subj = jnp.pad(subject, (0, dp - dlen), constant_values=matrix.shape[0])
    return _sw(prof, subj, gap_open=gap_open, gap_extend=gap_extend,
               q_len=q_len, tile=tile, interpret=interpret)


def flash_attention_op(q, k, v, *, causal=True, window=None,
                       interpret: Optional[bool] = None, **kw):
    interpret = _INTERPRET if interpret is None else interpret
    return _flash(q, k, v, causal=causal, window=window,
                  interpret=interpret, **kw)


def ssd_scan_op(x, dt, A, B, C, *, chunk=256, interpret: Optional[bool] = None):
    interpret = _INTERPRET if interpret is None else interpret
    return _ssd(x, dt, A, B, C, chunk=chunk, interpret=interpret)
