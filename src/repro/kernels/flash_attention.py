"""Flash attention (GQA / causal / sliding-window) — Pallas TPU kernel.

Tiling: grid (B, H, nq, nk) with the kv dimension minor-most (sequential);
online-softmax state (m, l, acc) lives in VMEM scratch and is reset at
kv-block 0.  GQA is zero-copy: the K/V BlockSpec index map sends query head
``h`` to kv head ``h // group`` — no repeated KV ever materialises in HBM.
Causal + sliding-window masking is block-level: fully-masked kv blocks skip
their matmuls entirely via ``pl.when`` (the triangular schedule).

Block sizes default to (128, 512) — q tile fills the 128-lane registers, kv
tile amortises HBM→VMEM latency; VMEM footprint per step ≈
bq·D + bk·D·2 + bq·bk scores ≈ 0.6 MB at D=128 — far under the ~16 MB VMEM
budget, leaving room for double buffering (the compiler's async copies are
the SPSC queue here).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

NEG = -1e9  # python float: keeps pallas kernels constant-free


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window, bq: int, bk: int,
               seq_q: int, seq_k: int):
    qi, ki = pl.program_id(2), pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk
    # block-level schedule: skip blocks that are entirely masked out
    live = jnp.bool_(True)
    if causal:
        live &= k_start <= q_start + bq - 1
    if window is not None:
        live &= k_start + bk - 1 > q_start - window

    @pl.when(live)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)                   # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                   # (bk, D)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq,bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < seq_k
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG)
        m_prev = m_scr[...][:, :1]                            # (bq,1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_scr[...][:, :1] * alpha + p.sum(axis=-1, keepdims=True)
        acc = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ()))).astype(jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)
        acc_scr[...] = acc

    @pl.when(ki == pl.num_programs(3) - 1)
    def _emit():
        l = l_scr[...][:, :1]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    bq: int = 128, bk: int = 512, interpret: bool = True):
    """q (B,H,S,D); k/v (B,Hkv,T,D), H % Hkv == 0. Returns (B,H,S,D)."""
    B, H, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    group = H // Hkv
    bq = min(bq, S)
    bk = min(bk, T)
    nq, nk = -(-S // bq), -(-T // bk)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, nq * bq - S), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, nk * bk - T), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, nk * bk - T), (0, 0)))
    kernel = functools.partial(
        _fa_kernel, scale=D ** -0.5, causal=causal, window=window,
        bq=bq, bk=bk, seq_q=S, seq_k=T)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki: (b, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # m
            pltpu.VMEM((bq, 128), jnp.float32),   # l
            pltpu.VMEM((bq, D), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :S]
