"""Pure-jnp oracles for every Pallas kernel in this package.

Each reference is deliberately implemented with a *different* algorithmic
mechanism than its kernel (e.g. sequential F-scan vs closed-form prefix-max
in Smith-Waterman) so that agreement is meaningful evidence of correctness.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["sw_ref", "sw_numpy", "attention_ref", "ssd_ref"]

NEG = jnp.float32(-1e9)


# --------------------------------------------------------------------------
# Smith-Waterman, affine gaps (gap_open charged on the first gap residue)
# --------------------------------------------------------------------------
def sw_ref(profile: jnp.ndarray, subject: jnp.ndarray, gap_open: float,
           gap_extend: float, subject_len: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Oracle: outer scan over subject chars, INNER SEQUENTIAL scan over the
    query for F (the column-direction gap) — no prefix-max closed form.

    profile: (A, Q) f32 — profile[c, i] = score(query_i, char c)
    subject: (D,) int32 character codes; entries ≥ A (or beyond
    subject_len) are padding and are skipped.
    Returns the best local alignment score (scalar f32).
    """
    A, Q = profile.shape
    D = subject.shape[0]
    slen = jnp.int32(D) if subject_len is None else subject_len

    def per_char(carry, inp):
        h_prev, e_prev, best = carry
        j, c = inp
        prof = profile[jnp.clip(c, 0, A - 1)]                       # (Q,)
        e = jnp.maximum(h_prev - gap_open, e_prev - gap_extend)     # gap in col dir
        diag = jnp.concatenate([jnp.zeros((1,), jnp.float32), h_prev[:-1]]) + prof
        h_hat = jnp.maximum(jnp.maximum(diag, e), 0.0)

        def f_step(f_prev_and_h, i):
            f_prev, h_up = f_prev_and_h
            f_i = jnp.maximum(h_up - gap_open, f_prev - gap_extend)
            h_i = jnp.maximum(h_hat[i], f_i)
            return (f_i, h_i), h_i

        (_, _), h = lax.scan(f_step, (NEG, jnp.float32(0)), jnp.arange(Q))
        valid = (j < slen) & (c < A)
        h = jnp.where(valid, h, h_prev)
        e = jnp.where(valid, e, e_prev)
        best = jnp.where(valid, jnp.maximum(best, h.max()), best)
        return (h, e, best), None

    init = (jnp.zeros((Q,), jnp.float32), jnp.full((Q,), NEG), jnp.float32(0))
    (h, e, best), _ = lax.scan(per_char, init, (jnp.arange(D), subject))
    return best


def sw_numpy(query: str, subject: str, score_fn, gap_open: float, gap_extend: float) -> float:
    """Cell-by-cell numpy triple-check for tiny cases (used by tests only)."""
    import numpy as np
    Q, D = len(query), len(subject)
    H = np.zeros((Q + 1, D + 1))
    E = np.full((Q + 1, D + 1), -1e9)
    F = np.full((Q + 1, D + 1), -1e9)
    best = 0.0
    for i in range(1, Q + 1):
        for j in range(1, D + 1):
            E[i, j] = max(H[i, j - 1] - gap_open, E[i, j - 1] - gap_extend)
            F[i, j] = max(H[i - 1, j] - gap_open, F[i - 1, j] - gap_extend)
            H[i, j] = max(0.0, H[i - 1, j - 1] + score_fn(query[i - 1], subject[j - 1]),
                          E[i, j], F[i, j])
            best = max(best, H[i, j])
    return best


# --------------------------------------------------------------------------
# Flash attention oracle (materialised, fp32)
# --------------------------------------------------------------------------
def attention_ref(q, k, v, *, causal: bool = True, window: Optional[int] = None):
    """q (B,H,S,D); k/v (B,Hkv,T,D). Returns (B,H,S,D)."""
    B, H, S, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    g = H // Hkv
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32) * (D ** -0.5)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p.astype(v.dtype), v)


# --------------------------------------------------------------------------
# SSD oracle: token-by-token recurrence (see also models/ssm.ssd_reference)
# --------------------------------------------------------------------------
def ssd_ref(x, dt, A, B, C, h0=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    from ..models.ssm import ssd_reference
    return ssd_reference(x, dt, A, B, C, h0=h0)
