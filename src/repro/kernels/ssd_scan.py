"""Mamba2 SSD chunk scan — Pallas TPU kernel.

Grid (B, n_chunks) with the chunk dimension minor-most (sequential); the
inter-chunk state (H, P, N) is VMEM scratch carried across grid steps —
the streaming-pipeline structure again: each chunk is one SPSC hop, and the
heavy intra-chunk math is dense matmuls for the MXU:

  y_diag = (L ⊙ (C·Bᵀ)) · (dt·X)      — (l,l)×(l,HP) per head-group
  y_off  = C · h_prev (decayed)        — (l,N)×(N,HP)
  h_new  = decay·h_prev + Bᵀ·(decay·dt·X)

All recurrence state stays in fp32; inputs may be bf16.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan"]


def _segsum(dA):
    """(l, h) → (h, l, l) lower-triangular decay (log-space)."""
    l = dA.shape[0]
    cs = jnp.cumsum(dA, axis=0)                                 # (l,h)
    seg = cs.T[:, :, None] - cs.T[:, None, :]                   # (h,l,l)
    mask = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    return jnp.where(mask[None], seg, -jnp.inf)


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_scr,
                *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)          # (l, H, P)
    dt = dt_ref[0].astype(jnp.float32)        # (l, H)
    A = a_ref[...].astype(jnp.float32)        # (H,)
    B = b_ref[0].astype(jnp.float32)          # (l, N)
    C = c_ref[0].astype(jnp.float32)          # (l, N)
    h_prev = h_scr[...]                       # (H, P, N)

    dA = dt * A                               # (l, H)
    dA_cum = jnp.cumsum(dA, axis=0)           # (l, H)
    L = jnp.exp(_segsum(dA))                  # (H, l, l)
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))  # (l, l)
    gated = scores[None] * L                  # (H, l, l)
    xdt = x * dt[..., None]                   # (l, H, P)
    y_diag = jnp.einsum("hls,shp->lhp", gated, xdt)
    state_decay = jnp.exp(dA_cum)             # (l, H)
    y_off = jnp.einsum("ln,hpn,lh->lhp", C, h_prev, state_decay)
    decay_to_end = jnp.exp(dA_cum[-1:] - dA_cum)                  # (l, H)
    states = jnp.einsum("ln,lh,lhp->hpn", B, decay_to_end * dt, x)
    h_new = h_prev * jnp.exp(dA_cum[-1])[:, None, None] + states
    h_scr[...] = h_new
    y_ref[0] = (y_diag + y_off).astype(y_ref.dtype)

    @pl.when(ci == pl.num_programs(1) - 1)
    def _emit():
        hout_ref[0] = h_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 256, interpret: bool = True):
    """x (b,T,H,P); dt (b,T,H); A (H,); B/C (b,T,N).
    Returns (y (b,T,H,P) fp32, h_final (b,H,P,N) fp32)."""
    b, T, H, P = x.shape
    N = B.shape[-1]
    l = min(chunk, T)
    assert T % l == 0
    nc = T // l
    kernel = functools.partial(_ssd_kernel, chunk=l)
    y, h = pl.pallas_call(
        kernel,
        grid=(b, nc),
        in_specs=[
            pl.BlockSpec((1, l, H, P), lambda bi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, l, H), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((H,), lambda bi, ci: (0,)),
            pl.BlockSpec((1, l, N), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, l, N), lambda bi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, l, H, P), lambda bi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, H, P, N), lambda bi, ci: (bi, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, T, H, P), jnp.float32),
            jax.ShapeDtypeStruct((b, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
    return y, h
