"""Mamba2-130M — pure SSD, attention-free [arXiv:2405.21060; unverified].

d_ff=0 (no MLP): 24 Mamba2 blocks only.  Vocab 50280 pads to 50288 for the
16-wide model axis.  O(1)-state decode ⇒ runs the long_500k cell."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=12,            # unused (attention-free); kept for interface
    n_kv_heads=12,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=256,
)
