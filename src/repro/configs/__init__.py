"""Assigned architecture registry (10 archs × their shape sets).

Every config is importable as ``repro.configs.<id>`` and selectable by
``--arch <id>`` in the launchers.  ``SHAPES`` defines the assigned
input-shape cells; ``long_500k`` is only listed for archs with sub-quadratic
decode (SSM / hybrid / sliding-window) — see DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from ..models.config import ModelConfig

from .kimi_k2_1t_a32b import CONFIG as kimi_k2_1t_a32b
from .mixtral_8x7b import CONFIG as mixtral_8x7b
from .phi3_mini_3_8b import CONFIG as phi3_mini_3_8b
from .mistral_nemo_12b import CONFIG as mistral_nemo_12b
from .starcoder2_7b import CONFIG as starcoder2_7b
from .deepseek_coder_33b import CONFIG as deepseek_coder_33b
from .llama_3_2_vision_90b import CONFIG as llama_3_2_vision_90b
from .musicgen_medium import CONFIG as musicgen_medium
from .zamba2_2_7b import CONFIG as zamba2_2_7b
from .mamba2_130m import CONFIG as mamba2_130m

ARCHS: Dict[str, ModelConfig] = {
    c.name: c for c in [
        kimi_k2_1t_a32b, mixtral_8x7b, phi3_mini_3_8b, mistral_nemo_12b,
        starcoder2_7b, deepseek_coder_33b, llama_3_2_vision_90b,
        musicgen_medium, zamba2_2_7b, mamba2_130m,
    ]
}


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str               # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)

# archs whose decode is sub-quadratic (SSM state / rolling SWA window):
SUBQUADRATIC = {"mamba2-130m", "zamba2-2.7b", "mixtral-8x7b"}


def cell_applicable(arch: str, shape: ShapeCell) -> Tuple[bool, str]:
    if shape.name == "long_500k" and arch not in SUBQUADRATIC:
        return False, "pure full-attention arch: 524k dense-KV decode is the quadratic case the spec excludes"
    return True, ""


def get(arch: str) -> ModelConfig:
    return ARCHS[arch]
