"""Phi-3-mini 3.8B — dense, RoPE, SwiGLU, MHA (kv=32) [arXiv:2404.14219; unverified]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32_064,
    head_dim=96,
    rope_theta=10_000.0,
    loss_chunk=1024,
)
