"""MusicGen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

Audio frontend is a STUB per assignment: ``input_specs()`` provides
precomputed frame embeddings (B, S, d_model); the model predicts 4 parallel
EnCodec codebooks (vocab 2048 each).  24 MHA heads pad to 32 masked heads."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    head_dim=64,
    n_codebooks=4,
    rope_theta=10_000.0,
)
