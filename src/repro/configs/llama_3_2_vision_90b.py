"""Llama-3.2-Vision 90B — cross-attention image layers every 5th layer
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

The vision frontend is a STUB per assignment: ``input_specs()`` provides
precomputed patch embeddings (B, 1601, 1280); the backbone projects them
once and cross-attends in 20 of the 100 layers."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128_256,
    head_dim=128,
    cross_attn_every=5,
    vision_patches=1601,
    vision_dim=1280,
    rope_theta=500_000.0,
    optimizer_dtype="bfloat16",
    loss_chunk=512,
)
