"""Kimi K2 — trillion-parameter MoE [arXiv:2501.kimi2; unverified].

Table values: 61L, d_model=7168, 64H (GQA kv=8), expert d_ff=2048,
vocab=163840, MoE 384 experts top-8.  One shared expert (public K2 detail)
is enabled via ``n_shared_experts=1``.  Optimizer moments in bf16: at 1T
params fp32 moments cannot fit any assigned mesh (see EXPERIMENTS §Dry-run).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=163_840,
    head_dim=112,            # 7168 / 64
    n_experts=384,
    top_k=8,
    n_shared_experts=1,
    rope_theta=50_000.0,
    optimizer_dtype="bfloat16",
    loss_chunk=512,
)
