"""StarCoder2-7B — dense GQA kv=4, RoPE [arXiv:2402.19173; hf].

36 heads do not divide the 16-wide model axis; the framework pads to 48
masked heads (numerics-exact, see models/model.py)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49_152,
    head_dim=128,
    rope_theta=100_000.0,
    loss_chunk=1024,
)
