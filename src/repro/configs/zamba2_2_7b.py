"""Zamba2-2.7B — Mamba2 backbone + shared attention block [arXiv:2411.15242; hf].

54 layers as 9 groups of (5 Mamba2 blocks + 1 attention block); the
attention block's parameters are genuinely SHARED across all 9 occurrences
(``shared_attn_block=True``), as in the paper's shared-transformer design."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32_000,
    head_dim=80,
    attn_every=6,
    shared_attn_block=True,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=256,
    rope_theta=10_000.0,
)
