"""DeepSeek-Coder 33B — llama-arch dense GQA [arXiv:2401.14196; hf].

56 heads pad to 64 masked heads for the 16-wide model axis."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab_size=32_256,
    head_dim=128,
    rope_theta=100_000.0,
    loss_chunk=512,
)
