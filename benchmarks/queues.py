"""Queue primitive overhead (substrate of paper Fig. 6).

Measures per-operation cost of the paper's lock-free SPSC ring vs the
lock-based MPMC baseline, single-threaded (pure op cost) and across a
2-thread producer/consumer stream (hand-off cost).  The absolute numbers
are Python-level; the paper's *claim* is the relative ordering
(SPSC < lock-based), which is what the derived column reports.
"""
from __future__ import annotations

import threading
import time

from repro.core import EOS, LockQueue, SPSCQueue

N = 200_000


def _ops_per_sec_single(qcls) -> float:
    q = qcls(1024)
    t0 = time.perf_counter()
    for i in range(N):
        q.push(i)
        q.pop()
    return N / (time.perf_counter() - t0)


def _stream_us_per_item(qcls, n=100_000) -> float:
    q = qcls(1024)
    done = []

    def cons():
        c = 0
        while True:
            item = q.pop_wait()
            if item is EOS:
                break
            c += 1
        done.append(c)

    t = threading.Thread(target=cons)
    t.start()
    t0 = time.perf_counter()
    for i in range(n):
        q.push_wait(i)
    q.push_wait(EOS)
    t.join()
    dt = time.perf_counter() - t0
    assert done[0] == n
    return dt / n * 1e6


def run(emit):
    for qcls, name in [(SPSCQueue, "spsc"), (LockQueue, "lock")]:
        ops = _ops_per_sec_single(qcls)
        emit(f"queue_single_{name}", 1e6 / ops, f"ops_per_sec={ops:.0f}")
    spsc_us = _stream_us_per_item(SPSCQueue)
    lock_us = _stream_us_per_item(LockQueue)
    emit("queue_stream_spsc", spsc_us, f"lock_over_spsc={lock_us/spsc_us:.2f}x")
    emit("queue_stream_lock", lock_us, "")
