"""Queue primitive overhead (substrate of paper Fig. 6, plus the Fig. 5
hand-off analogue across process boundaries).

Measures per-operation cost of the paper's lock-free SPSC ring vs the
lock-based MPMC baseline, three ways:

* single-threaded push/pop (pure op cost);
* a 2-thread producer/consumer stream (in-process hand-off cost:
  ``SPSCQueue`` vs ``LockQueue``);
* a 2-**process** producer/consumer stream (cross-process hand-off cost:
  the shared-memory ``ShmRing`` vs ``multiprocessing.Queue``, the
  lock-and-pipe baseline every Python program reaches for).  The threaded
  ``LockQueue`` number is carried into the derived column as the
  reference point the paper's Fig. 5 uses.

The absolute numbers are Python-level; the paper's *claim* is the
relative ordering (lock-free SPSC < locked), which is what the derived
columns report — now on both sides of the process boundary.
"""
from __future__ import annotations

import multiprocessing as mp
import threading
import time

from repro.core import EOS, LockQueue, ShmRing, SPSCQueue

N = 200_000
N_XPROC = 20_000


def _ops_per_sec_single(qcls) -> float:
    q = qcls(1024)
    t0 = time.perf_counter()
    for i in range(N):
        q.push(i)
        q.pop()
    return N / (time.perf_counter() - t0)


def _stream_us_per_item(qcls, n=100_000) -> float:
    q = qcls(1024)
    done = []

    def cons():
        c = 0
        while True:
            item = q.pop_wait()
            if item is EOS:
                break
            c += 1
        done.append(c)

    t = threading.Thread(target=cons)
    t.start()
    t0 = time.perf_counter()
    for i in range(n):
        q.push_wait(i)
    q.push_wait(EOS)
    t.join()
    dt = time.perf_counter() - t0
    assert done[0] == n
    return dt / n * 1e6


# -- cross-process hand-off (the procs backend's edge primitive) -------------
def _shm_consumer(ring, reply):
    reply.put("up")  # warm-up ack: spawn/import cost ends HERE
    c = 0
    while True:
        item = ring.pop_wait()
        if item is EOS:
            break
        c += 1
    reply.put(c)


def _mpq_consumer(q, reply):
    reply.put("up")
    c = 0
    while True:
        item = q.get()
        if item is EOS:
            break
        c += 1
    reply.put(c)


def _xproc_us_per_item(kind: str, n=None) -> float:
    """Parent producer -> spawned child consumer, n items + EOS.  The
    clock starts only after the child's ready handshake, so spawn and
    import cost never inflate the per-item figure."""
    n = N_XPROC if n is None else n  # read at call time: CI shrinks it
    ctx = mp.get_context("spawn")
    reply = ctx.Queue()
    if kind == "shm":
        chan = ShmRing(1024)
        p = ctx.Process(target=_shm_consumer, args=(chan, reply), daemon=True)

        def push(item):  # a consumer that dies mid-stream must fail fast,
            if not chan.push_wait(item, timeout=120):  # not wedge the run
                raise RuntimeError("shm consumer stalled")
    else:
        chan = ctx.Queue(1024)
        p = ctx.Process(target=_mpq_consumer, args=(chan, reply), daemon=True)

        def push(item):
            chan.put(item, timeout=120)  # queue.Full on a stalled consumer
    p.start()
    try:
        assert reply.get(timeout=120) == "up"  # dead child fails, not hangs
        t0 = time.perf_counter()
        for i in range(n):
            push(i)
        push(EOS)
        got = reply.get(timeout=120)
        dt = time.perf_counter() - t0
        p.join(30)
        assert got == n
    finally:
        if p.is_alive():
            p.terminate()
        if kind == "shm":
            chan.unlink()
    return dt / n * 1e6


def run(emit):
    for qcls, name in [(SPSCQueue, "spsc"), (LockQueue, "lock")]:
        ops = _ops_per_sec_single(qcls)
        emit(f"queue_single_{name}", 1e6 / ops, f"ops_per_sec={ops:.0f}")
    spsc_us = _stream_us_per_item(SPSCQueue)
    lock_us = _stream_us_per_item(LockQueue)
    emit("queue_stream_spsc", spsc_us, f"lock_over_spsc={lock_us/spsc_us:.2f}x")
    emit("queue_stream_lock", lock_us, "")
    shm_us = _xproc_us_per_item("shm")
    mpq_us = _xproc_us_per_item("mpq")
    emit("queue_xproc_shm", shm_us,
         f"mpq_over_shm={mpq_us/shm_us:.2f}x "
         f"threadlock_over_shm={lock_us/shm_us:.2f}x")
    emit("queue_xproc_mpq", mpq_us, "")
