"""Queue primitive overhead (substrate of paper Fig. 6, plus the Fig. 5
hand-off analogue across process boundaries).

Measures per-operation cost of the paper's lock-free SPSC ring vs the
lock-based MPMC baseline, three ways:

* single-threaded push/pop (pure op cost);
* a 2-thread producer/consumer stream (in-process hand-off cost:
  ``SPSCQueue`` vs ``LockQueue``);
* a 2-**process** producer/consumer stream (cross-process hand-off cost:
  the shared-memory ``ShmRing`` vs ``multiprocessing.Queue``, the
  lock-and-pipe baseline every Python program reaches for).  The threaded
  ``LockQueue`` number is carried into the derived column as the
  reference point the paper's Fig. 5 uses.

The absolute numbers are Python-level; the paper's *claim* is the
relative ordering (lock-free SPSC < locked), which is what the derived
columns report — now on both sides of the process boundary.

Two further row families cost out the zero-copy data plane:

* ``queue_xproc_np16k_{zerocopy,pickle,spill}`` — a 16 KiB numpy array
  handed to a spawned consumer three ways: the typed zero-copy slot (one
  aligned memcpy in, one out), the pickled slot at a payload-sized
  ``slot_size`` (``zero_copy=False`` — the fallback codec on the same
  ring), and the default-slot spill side-channel (one file per item —
  what every ≥16 KiB payload paid before typed slots existed, since the
  default 248-byte slot spills anything bigger).  The derived column
  reports both ratios; the acceptance bar is zerocopy ≥ 5× faster than
  the spill path it replaces.  Against inline pickle the codec-level
  gap is ~4.5× (dumps+loads ≈ 22 µs vs two memcpys ≈ 5 µs); the wall
  ratio reaches it only when producer and consumer overlap on separate
  cores — a single-CPU container timeshares them and adds the DRAM
  traffic both modes share, compressing the printed ratio to ~2-3×.
* ``queue_xproc_batched`` — small ints via ``push_many`` (batch frames)
  vs the one-slot-per-item ``queue_xproc_shm`` row: the per-item ring
  protocol cost amortised across a packed slot.

The ``queue_trace_{off,sampled}`` rows cost out the observability
layer's claim that it may not disturb what it measures: one vertex's
per-item cycle (ingress ring op, ``FnNode.svc`` call, egress ring op —
the code shape ``WorkerVertex._loop`` runs) with the svc trace bracket
compiled in, as ``tracer=None`` (the tracing-off hot path: two
attribute checks per item) and as a 1-in-16 sampled ``VertexTracer``.
The single-threaded cycle is the comparison substrate *because* it is
near-deterministic — the 2-thread stream's scheduler noise (±10%)
would swamp the ~2% effect being bounded — and the estimator is the
median over paired adjacent measurements, so clock drift shared by
both arms cancels in each ratio.  The off path is ASSERTED within 5%
of the plain cycle, so a hot-path regression in ``repro.core.obs``
fails the bench run, not just a dashboard.
"""
from __future__ import annotations

import multiprocessing as mp
import threading
import time

from repro.core import EOS, LockQueue, ShmRing, SPSCQueue
from repro.core.obs import VertexTracer

N = 200_000
N_XPROC = 20_000
N_PAYLOAD = 2_000
N_TRACE = 10_000  # items per trace-overhead round: NOT CI-shrunk — the
PAYLOAD_BYTES = 16_384  # 5% assertion needs its fixed many-short-rounds shape


def _ops_per_sec_single(qcls) -> float:
    q = qcls(1024)
    t0 = time.perf_counter()
    for i in range(N):
        q.push(i)
        q.pop()
    return N / (time.perf_counter() - t0)


def _stream_us_per_item(qcls, n=100_000) -> float:
    q = qcls(1024)
    done = []

    def cons():
        c = 0
        while True:
            item = q.pop_wait()
            if item is EOS:
                break
            c += 1
        done.append(c)

    t = threading.Thread(target=cons)
    t.start()
    t0 = time.perf_counter()
    for i in range(n):
        q.push_wait(i)
    q.push_wait(EOS)
    t.join()
    dt = time.perf_counter() - t0
    assert done[0] == n
    return dt / n * 1e6


def _vertex_cycle_us(tracer, n, traced=True) -> float:
    """One timed pass of the per-item vertex cycle: ingress ring op,
    ``FnNode.svc`` call, egress ring op — with (``traced=True``) or
    without the svc trace bracket.  ``tracer=None`` under the bracket
    is the tracing-off hot path every vertex pays; a sampled
    :class:`VertexTracer` the 1-in-N path.  The lane is reset after
    the pass so buffer dynamics stay identical across repeats."""
    from repro.core.skeleton import FnNode
    qin, qout = SPSCQueue(1024), SPSCQueue(1024)
    svc = FnNode(lambda x: x + 1).svc
    tr = tracer
    t0 = time.perf_counter()
    if traced:
        for i in range(n):
            qin.push(i)
            item = qin.pop()
            tb = tr.begin() if tr is not None else 0.0
            out = svc(item)
            if tr is not None:
                tr.end(tb, "svc")
            qout.push(out)
            qout.pop()
    else:
        for i in range(n):
            qin.push(i)
            item = qin.pop()
            out = svc(item)
            qout.push(out)
            qout.pop()
    dt = time.perf_counter() - t0
    if tr is not None:
        tr.events.clear()
        tr.dropped = 0
    return dt / n * 1e6


class _Tap:
    """A minimal monitor target: wraps the benchmark's two live rings
    behind the same ``sample_depths``/``results`` surface a ``Graph``
    offers, so the Monitor thread reads the SAME head/tail cache lines
    the hot loop is bouncing — the realistic interference shape."""

    def __init__(self, rings):
        self.rings = rings
        self.results: list = []

    def sample_depths(self, into):
        for i, r in enumerate(self.rings):
            try:
                into[f"bench-vertex-{i}"] = len(r)
            except TypeError:
                pass
        return into


def _monitor_cycle_us(qin, qout, svc, n) -> float:
    """The plain (untraced) vertex cycle on caller-supplied rings, so the
    monitored and unmonitored arms run the identical code path."""
    t0 = time.perf_counter()
    for i in range(n):
        qin.push(i)
        item = qin.pop()
        out = svc(item)
        qout.push(out)
        qout.pop()
    return (time.perf_counter() - t0) / n * 1e6


def _monitor_overhead(n, pairs=75):
    """Paired-ratio estimate of the live Monitor's cost to the stream it
    watches: each round times the plain vertex cycle without and with an
    attached sampler thread (0.5 ms cadence, reading the cycle's own
    rings), estimator is the median round — same discipline as
    :func:`_trace_overhead`.  Returns ``(off_us, on_us, on_ratio)``."""
    import statistics
    from repro.core.monitor import Monitor
    from repro.core.skeleton import FnNode
    qin, qout = SPSCQueue(1024), SPSCQueue(1024)
    svc = FnNode(lambda x: x + 1).svc
    tap = _Tap([qin, qout])
    mon = Monitor(interval_s=0.0005, capacity=512)
    offs, ons, ratios = [], [], []
    for _ in range(pairs):
        off = _monitor_cycle_us(qin, qout, svc, n)
        mon.attach(tap)
        on = _monitor_cycle_us(qin, qout, svc, n)
        mon.detach()
        offs.append(off)
        ons.append(on)
        ratios.append(on / off)
    return (statistics.median(offs), statistics.median(ons),
            statistics.median(ratios))


def _trace_overhead(n, pairs=75):
    """Paired-ratio estimate of the trace bracket's cost: each round
    measures plain / off / sampled back to back (shared drift cancels
    in the per-round ratio), the estimator is the median round — many
    SHORT rounds, so a scheduler spike lands in a few rounds the median
    ignores instead of smearing over one long measurement (on a shared
    single-core VM this is the difference between ±0.5% and ±4% on the
    estimate).  Returns ``(off_us, sampled_us, off_ratio,
    sampled_ratio)``."""
    import statistics
    tr = VertexTracer("bench-vertex", sample=16, capacity=4096)
    offs, sampleds, off_r, smp_r = [], [], [], []
    for _ in range(pairs):
        p = _vertex_cycle_us(None, n, traced=False)
        o = _vertex_cycle_us(None, n, traced=True)
        s = _vertex_cycle_us(tr, n, traced=True)
        offs.append(o)
        sampleds.append(s)
        off_r.append(o / p)
        smp_r.append(s / p)
    return (statistics.median(offs), statistics.median(sampleds),
            statistics.median(off_r), statistics.median(smp_r))


# -- cross-process hand-off (the procs backend's edge primitive) -------------
def _shm_consumer(ring, reply):
    reply.put("up")  # warm-up ack: spawn/import cost ends HERE
    c = 0
    while True:
        item = ring.pop_wait()
        if item is EOS:
            break
        c += 1
    reply.put(c)


def _mpq_consumer(q, reply):
    reply.put("up")
    c = 0
    while True:
        item = q.get()
        if item is EOS:
            break
        c += 1
    reply.put(c)


def _xproc_us_per_item(kind: str, n=None) -> float:
    """Parent producer -> spawned child consumer, n items + EOS.  The
    clock starts only after the child's ready handshake, so spawn and
    import cost never inflate the per-item figure."""
    n = N_XPROC if n is None else n  # read at call time: CI shrinks it
    ctx = mp.get_context("spawn")
    reply = ctx.Queue()
    if kind == "shm":
        chan = ShmRing(1024)
        p = ctx.Process(target=_shm_consumer, args=(chan, reply), daemon=True)

        def push(item):  # a consumer that dies mid-stream must fail fast,
            if not chan.push_wait(item, timeout=120):  # not wedge the run
                raise RuntimeError("shm consumer stalled")
    else:
        chan = ctx.Queue(1024)
        p = ctx.Process(target=_mpq_consumer, args=(chan, reply), daemon=True)

        def push(item):
            chan.put(item, timeout=120)  # queue.Full on a stalled consumer
    p.start()
    try:
        assert reply.get(timeout=120) == "up"  # dead child fails, not hangs
        t0 = time.perf_counter()
        for i in range(n):
            push(i)
        push(EOS)
        got = reply.get(timeout=120)
        dt = time.perf_counter() - t0
        p.join(30)
        assert got == n
    finally:
        if p.is_alive():
            p.terminate()
        if kind == "shm":
            chan.unlink()
    return dt / n * 1e6


# -- payload hand-off: zero-copy slots vs pickle vs spill --------------------
def _prefault(ring, write: bool = False) -> None:
    """Touch every page of this process's mapping: first-touch page
    faults (~4 pages per 16 KiB slot, in BOTH processes) would otherwise
    bill several µs/item to whichever mode runs on a fresh segment.  The
    producer must WRITE (a read fault on a tmpfs hole just maps the
    shared zero page; the later real write still pays the allocation)."""
    mv = ring._mv
    if write:
        data_off = 128  # never scribble on the head/tail cache lines
        for off in range(data_off, len(mv), 4096):
            mv[off] = 0
    else:
        for off in range(0, len(mv), 4096):
            mv[off]


def _np_consumer(ring, reply):
    import numpy  # noqa: F401  — the decoder needs it; the ready
    _prefault(ring)  # handshake must end import AND fault cost, not start it
    reply.put("up")
    c = 0
    while True:
        item = ring.pop_wait(timeout=120)
        if item is EOS:
            break
        c += int(item.shape[0] > 0)
    reply.put(c)


def _payload_ring(mode: str, nbytes: int, cap: int) -> ShmRing:
    if mode == "zerocopy":
        return ShmRing(cap, slot_size=nbytes + 128, zero_copy=True)
    if mode == "pickle":
        # pickle framing adds ~130 bytes over the raw buffer; the slot is
        # sized so the pickled array stays inline (no spill)
        return ShmRing(cap, slot_size=nbytes + 512, zero_copy=False)
    assert mode == "spill"
    return ShmRing(cap, zero_copy=False)  # default slot: every item spills


def _xproc_payload_us(mode: str, n=None, nbytes=None) -> float:
    """16 KiB numpy arrays, parent producer -> spawned child consumer,
    same ready-handshake discipline as :func:`_xproc_us_per_item`.

    The ring holds the whole stream (capacity > n): the producer never
    blocks, so no sleep/wake scheduling noise is billed to either mode —
    on separate cores the consumer drains concurrently (pipelined wall),
    on a single CPU the wall is the sum of both sides' work either way."""
    import numpy as np
    n = N_PAYLOAD if n is None else n
    nbytes = PAYLOAD_BYTES if nbytes is None else nbytes
    payload = np.arange(nbytes // 4, dtype=np.float32)
    ctx = mp.get_context("spawn")
    reply = ctx.Queue()
    chan = _payload_ring(mode, nbytes, n + 2)
    _prefault(chan, write=True)  # allocate pages before the child maps them
    p = ctx.Process(target=_np_consumer, args=(chan, reply), daemon=True)
    p.start()
    try:
        assert reply.get(timeout=120) == "up"
        t0 = time.perf_counter()
        for _ in range(n):
            if not chan.push_wait(payload, timeout=120):
                raise RuntimeError("payload consumer stalled")
        chan.push_wait(EOS, timeout=120)
        got = reply.get(timeout=120)
        dt = time.perf_counter() - t0
        p.join(30)
        assert got == n
    finally:
        if p.is_alive():
            p.terminate()
        chan.unlink()
    return dt / n * 1e6


def _xproc_batched_us(n=None, batch=64) -> float:
    """Small ints through ``push_many`` batch frames — the consumer is
    the plain :func:`_shm_consumer` (``pop`` unpacks batches itself)."""
    n = N_XPROC if n is None else n
    ctx = mp.get_context("spawn")
    reply = ctx.Queue()
    chan = ShmRing(1024)
    p = ctx.Process(target=_shm_consumer, args=(chan, reply), daemon=True)
    p.start()
    try:
        assert reply.get(timeout=120) == "up"
        items = list(range(n))
        t0 = time.perf_counter()
        i = 0
        deadline = t0 + 120
        while i < n:
            pushed = chan.push_many(items[i:i + batch])
            if pushed == 0:
                if time.perf_counter() > deadline:
                    raise RuntimeError("batched consumer stalled")
                time.sleep(0)
                continue
            i += pushed
        chan.push_wait(EOS, timeout=120)
        got = reply.get(timeout=120)
        dt = time.perf_counter() - t0
        p.join(30)
        assert got == n
    finally:
        if p.is_alive():
            p.terminate()
        chan.unlink()
    return dt / n * 1e6


def run(emit):
    for qcls, name in [(SPSCQueue, "spsc"), (LockQueue, "lock")]:
        ops = _ops_per_sec_single(qcls)
        emit(f"queue_single_{name}", 1e6 / ops, f"ops_per_sec={ops:.0f}")
    spsc_us = _stream_us_per_item(SPSCQueue)
    lock_us = _stream_us_per_item(LockQueue)
    emit("queue_stream_spsc", spsc_us, f"lock_over_spsc={lock_us/spsc_us:.2f}x")
    emit("queue_stream_lock", lock_us, "")
    off_us, sampled_us, off_ratio, sampled_ratio = _trace_overhead(N_TRACE)
    emit("queue_trace_off", off_us,
         f"off_over_plain={off_ratio:.3f}x")
    emit("queue_trace_sampled", sampled_us,
         f"sampled_over_plain={sampled_ratio:.2f}x")
    assert off_ratio <= 1.05, (
        f"tracing-off hot path costs {(off_ratio - 1) * 100:.1f}% on a "
        f"vertex cycle (budget: 5%) — repro.core.obs regressed")
    mon_off_us, mon_on_us, mon_ratio = _monitor_overhead(N_TRACE)
    emit("queue_monitor_off", mon_off_us, "")
    emit("queue_monitor_on", mon_on_us,
         f"on_over_off={mon_ratio:.3f}x")
    assert mon_ratio <= 1.05, (
        f"live Monitor sampling costs {(mon_ratio - 1) * 100:.1f}% on a "
        f"vertex cycle (budget: 5%) — repro.core.monitor regressed")
    shm_us = _xproc_us_per_item("shm")
    mpq_us = _xproc_us_per_item("mpq")
    emit("queue_xproc_shm", shm_us,
         f"mpq_over_shm={mpq_us/shm_us:.2f}x "
         f"threadlock_over_shm={lock_us/shm_us:.2f}x")
    emit("queue_xproc_mpq", mpq_us, "")
    batched_us = _xproc_batched_us()
    emit("queue_xproc_batched", batched_us,
         f"single_over_batched={shm_us/batched_us:.2f}x")
    zc_us = _xproc_payload_us("zerocopy")
    pk_us = _xproc_payload_us("pickle")
    sp_us = _xproc_payload_us("spill")
    emit("queue_xproc_np16k_zerocopy", zc_us,
         f"spill_over_zerocopy={sp_us/zc_us:.2f}x "
         f"pickle_over_zerocopy={pk_us/zc_us:.2f}x")
    emit("queue_xproc_np16k_pickle", pk_us, "")
    emit("queue_xproc_np16k_spill", sp_us, "")
