"""Skeleton parity — the same IR on both backends, overhead vs fusion.

``farm_composition.py`` measures what the thread graph adds per hand-off;
this module measures what the *lowering choice* is worth: one skeleton,
``Pipeline(Farm(f, W), Farm(g, W))``, executed

  * on the **threads** backend — every task crosses two dispatch/merge
    arbiter pairs plus the inter-farm SPSC edge (per-item hand-off cost);
  * on the **mesh** backend — ONE compiled shard_map program (farms fused,
    no host hop between f and g); reported steady-state, after one warm-up
    call paid the compile.

The ratio (``fused_speedup``) is the measured argument for the ROADMAP's
graph-level fusion policy: below the hand-off overhead threshold, lowering
to the fused program wins regardless of parallel width.  Outputs of the
two backends are asserted identical (ordering included) on every run, so
the benchmark doubles as a parity smoke test (CI runs it with a tight item
budget).

Same CSV contract as the other benchmark modules:
``name,us_per_call,derived``.
"""
from __future__ import annotations

import time

from repro.core import Farm, Pipeline, lower

NTASKS = 2_000
NWORKERS = 2


def _f(x):
    return x * 3 + 1


def _g(x):
    return x - 7


def run(emit):
    skel = Pipeline(Farm(_f, NWORKERS, ordered=True),
                    Farm(_g, NWORKERS, ordered=True))
    xs = list(range(NTASKS))
    want = [_g(_f(x)) for x in xs]

    threads = lower(skel, "threads")
    t0 = time.perf_counter()
    out_t = threads(xs)
    dt_threads = time.perf_counter() - t0
    assert out_t == want, "threads backend output mismatch"

    mesh = lower(skel, "mesh")
    out_m = mesh(xs)                       # warm-up: pays the XLA compile
    assert out_m == want, "mesh backend output mismatch"
    t0 = time.perf_counter()
    out_m = mesh(xs)
    dt_mesh = time.perf_counter() - t0
    assert out_m == want

    us_t = dt_threads / NTASKS * 1e6
    us_m = dt_mesh / NTASKS * 1e6
    emit("skeleton_parity_threads", us_t,
         f"nworkers={NWORKERS},handoff=2xdispatch+2xmerge+1xspsc")
    emit("skeleton_parity_mesh", us_m,
         f"one_shard_map=1,fused_speedup={us_t / max(us_m, 1e-9):.2f}")
