"""Paper Fig. 7 + Table 1 — Smith-Waterman database search, GCUPS.

UniProt is not available offline, so the reference database is synthesised
with the Swiss-Prot release 57.5 statistics the paper quotes (mean length
352, min 2, long tail) and queries mirror the paper's P02232/P10635/P27895
lengths (144 / 497 / 1000).  The pipeline is the paper's: a farm streams
⟨query, subject⟩ pairs through the vectorised SW kernel; the collector
gathers scores in order.  GCUPS = |Q|·|D| / (T·1e9).

Both of the paper's gap regimes (10-2k, 5-2k) are exercised; Table 1's
min/max/avg per-task service times are reported for each query length.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import FnNode, TaskFarm
from repro.kernels import ops

QUERY_LENS = [144, 497, 1000]          # P02232, P10635, P27895
DB_SIZE = 64                           # sequences (interpret-mode sized)
MEAN_LEN = 352


def gcups(qlen: int, db_cells: int, seconds: float) -> float:
    return qlen * db_cells / (seconds * 1e9)


def _make_db(rng) -> list:
    lens = np.clip(rng.gamma(2.0, MEAN_LEN / 2.0, DB_SIZE).astype(int), 2, 2000)
    return [rng.integers(0, 20, int(l)).astype(np.int32) for l in lens]


def run(emit):
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    db = _make_db(rng)
    db_res = int(sum(len(s) for s in db))
    for gap_open, tag in [(10.0, "10-2k"), (5.0, "5-2k")]:
        for qlen in QUERY_LENS:
            query = jnp.asarray(rng.integers(0, 20, qlen), jnp.int32)
            # warm the kernel cache (compile once per subject-pad bucket)
            _ = ops.smith_waterman(query, jnp.asarray(db[0]), gap_open=gap_open,
                                   gap_extend=2.0)
            times = []

            def worker(subj):
                t0 = time.perf_counter()
                s = float(ops.smith_waterman(query, jnp.asarray(subj),
                                             gap_open=gap_open, gap_extend=2.0))
                times.append(time.perf_counter() - t0)
                return s

            farm = TaskFarm(2, preserve_order=True)
            farm.add_stream(db)
            farm.add_worker(FnNode(worker))
            t0 = time.perf_counter()
            scores = farm.run_and_wait()
            dt = time.perf_counter() - t0
            assert len(scores) == DB_SIZE and all(s >= 0 for s in scores)
            g = gcups(qlen, db_res, dt)
            emit(f"sw_{tag}_q{qlen}", dt / DB_SIZE * 1e6,
                 f"gcups={g:.6f},task_min_us={min(times)*1e6:.0f},"
                 f"task_max_us={max(times)*1e6:.0f},"
                 f"task_avg_us={np.mean(times)*1e6:.0f}")
