"""Scheduling-policy sweep + fusion payoff — the paper's fine-grain case.

The paper's headline result (Sec. 6: 35-226% over OpenMP/Cilk/TBB on
fine-grain Smith-Waterman tasks) rests on cheap hand-offs *and* smart
placement.  This module measures the placement half on the threads
backend: one ordered farm, policies × grain sizes, over a **skewed**
stream — every ``SKEW_EVERY``-th task costs ``SKEW_FACTOR``× the base
grain, and the skew period is a multiple of the worker count, so
round-robin lands *every* slow task on worker 0 (worst-case head-of-line
blocking).  ``ondemand`` / ``worksteal`` / ``costmodel`` rebalance; their
``vs_rr`` speedup is the measured value of the scheduling layer.

Workers "service" a task by sleeping its grain — i.e. they release the
GIL, like the real workers this farm exists for (JAX dispatch, NumPy
kernels, I/O).  A pure-Python spin would hold the GIL and serialize all
compute regardless of placement, making every policy measure the same
wall-clock; sleeping isolates exactly what this benchmark is about —
placement — from the CPython artifact.

Then the fusion rows: a two-stage fine-grain pipeline lowered with and
without the grain-aware fusion pass, at a grain pinned *below* the
auto-calibrated hand-off threshold (``sched.calibrate_handoff_us`` — the
in-library version of the skeleton_parity measurement).  Fusion must
remove at least one vertex and keep the output identical; the speedup is
the per-hand-off saving the ROADMAP's fusion item predicted.

Ordered-output equality across every policy and both fusion modes is
asserted on every run, so the benchmark doubles as a parity smoke test
(CI runs it with a tight item budget).

Same CSV contract as the other benchmark modules:
``name,us_per_call,derived``.
"""
from __future__ import annotations

import time

from repro.core import Farm, Pipeline, Stage, lower
from repro.core.sched import calibrate_handoff_us

NTASKS = 800
NWORKERS = 4
GRAINS_US = (100, 400)
SKEW_EVERY = 8      # every 8th task is slow (8 ≡ 0 mod NWORKERS: rr pins
SKEW_FACTOR = 20    # them all to one worker) ... and slow by 20x the grain
POLICIES = ("rr", "ondemand", "worksteal", "costmodel")
REPEATS = 2


def _spin(us: float) -> None:
    end = time.perf_counter() + us * 1e-6
    while time.perf_counter() < end:
        pass


def _timed(prog, xs, want):
    best = None
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        out = prog(xs)
        dt = time.perf_counter() - t0
        assert out == want, "ordered-output mismatch"
        best = dt if best is None else min(best, dt)
    return best


def run(emit):
    xs = list(range(NTASKS))
    # -- policies × grains on the skewed farm --------------------------------
    for grain in GRAINS_US:
        def worker(x, g=grain):
            # GIL-releasing service (see module docstring)
            time.sleep(g * (SKEW_FACTOR if x % SKEW_EVERY == 0 else 1) * 1e-6)
            return x

        base_rr = None
        for pol in POLICIES:
            prog = lower(Farm(worker, NWORKERS, ordered=True, scheduling=pol),
                         "threads")
            us = _timed(prog, xs, xs) / NTASKS * 1e6
            if pol == "rr":
                base_rr = us
            emit(f"sched_{pol}_grain{grain}us", us,
                 f"nworkers={NWORKERS},skew={SKEW_FACTOR}x/{SKEW_EVERY},"
                 f"vs_rr={base_rr / us:.2f}")

    # -- fusion at sub-threshold grain ---------------------------------------
    thr = calibrate_handoff_us()
    g_us = max(thr / 4, 0.05)          # guaranteed below the threshold

    def _fa(x, g=g_us):
        _spin(g)
        return x + 1

    def _fb(x, g=g_us):
        _spin(g)
        return x * 2

    two = Pipeline(Stage(_fa, grain=g_us), Stage(_fb, grain=g_us))
    want = [(x + 1) * 2 for x in xs]
    unfused = lower(two, "threads", fuse=False)
    fused = lower(two, "threads", fuse="auto", fuse_threshold_us=thr)
    n_un = len(unfused.to_graph(xs).vertices)
    n_fu = len(fused.to_graph(xs).vertices)
    assert n_fu < n_un, "fusion must remove at least one vertex hand-off"
    t_un = _timed(unfused, xs, want)
    t_fu = _timed(fused, xs, want)
    emit("fusion_unfused_2stage", t_un / NTASKS * 1e6, f"vertices={n_un}")
    emit("fusion_fused_2stage", t_fu / NTASKS * 1e6,
         f"vertices={n_fu},handoff_us={thr:.2f},grain_us={g_us:.2f},"
         f"speedup={t_un / t_fu:.2f}")
