"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms, per (arch × shape) cell on the single-pod 16×16 mesh (TPU v5e):

  compute    = FLOPs_global / (chips · 197e12)   [s]
  memory     = bytes_global / (chips · 819e9)    [s]
  collective = coll_bytes_per_device / 50e9      [s]

``compiled.cost_analysis()`` reports the PER-DEVICE partitioned program
(verified by calibration in tests), so FLOPs_global = flops/dev · chips and
the chips cancel: compute = flops_per_device / peak.  Collective bytes are
summed operand sizes of all-gather/all-reduce/reduce-scatter/all-to-all/
collective-permute in the per-device optimized HLO; dividing by one 50 GB/s
link is the conservative single-link serialisation model (a ring all-reduce
actually pushes ≈2·(n-1)/n · size through each link, so the real time is
slightly BELOW this bound for AR and slightly above for multi-hop a2a).

MODEL_FLOPS uses 6·N·D for training (N = params, active params for MoE) and
2·N·D for inference; the ratio MODEL_FLOPS / FLOPs_global exposes
remat/redundancy waste.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / ICI link

_here = os.path.dirname(os.path.abspath(__file__))
DEFAULT_PATH = os.path.join(_here, "..", "reports", "dryrun.jsonl")
EXACT_PATH = os.path.join(_here, "..", "reports", "exact.jsonl")


def load_cells(path: str = DEFAULT_PATH, mesh: str = "16x16") -> List[dict]:
    cells = {}
    if not os.path.exists(path):
        return []
    with open(path) as f:
        for line in f:
            rec = json.loads(line)
            if rec.get("mesh") == mesh and not rec.get("unrolled"):
                cells[(rec["arch"], rec["shape"])] = rec   # last write wins
    return [_fold_exact(r) for r in cells.values()]


def _load_exact(path: str = EXACT_PATH) -> Dict:
    """Two-point unrolled records per cell: {(arch, shape): [rec_small, rec_big]}."""
    out: Dict = {}
    if not os.path.exists(path):
        return out
    with open(path) as f:
        for line in f:
            rec = json.loads(line)
            if rec.get("status") == "OK" and rec.get("unrolled"):
                out.setdefault((rec["arch"], rec["shape"]), {})[rec["n_layers"]] = rec
    return out


_EXACT_CACHE: Optional[Dict] = None


def _fold_exact(rec: dict) -> dict:
    """Replace loop-undercounted costs with the two-point extrapolation
    cost(L) = a + b·L fitted on fully-unrolled reduced-depth compiles.
    Memory_analysis fields stay from the scanned (deployable) program."""
    global _EXACT_CACHE
    if _EXACT_CACHE is None:
        _EXACT_CACHE = _load_exact()
    pts = _EXACT_CACHE.get((rec.get("arch"), rec.get("shape")))
    if not pts or len(pts) < 2 or rec.get("status") != "OK":
        return rec
    from repro.configs import ARCHS
    l_full = ARCHS[rec["arch"]].n_layers
    (l1, r1), (l2, r2) = sorted(pts.items())[:2]

    def extrap(f):
        b = (f(r2) - f(r1)) / (l2 - l1)
        return max(f(r1) + b * (l_full - l1), 0.0)

    rec = dict(rec)
    rec["flops_per_device"] = extrap(lambda r: r["flops_per_device"])
    rec["bytes_accessed_per_device"] = extrap(lambda r: r["bytes_accessed_per_device"])
    coll = {}
    for op in r1["collectives"]:
        coll[op] = {
            "count": int(extrap(lambda r: r["collectives"][op]["count"])),
            "bytes": extrap(lambda r: r["collectives"][op]["bytes"]),
        }
    rec["collectives"] = coll
    rec["cost_source"] = f"exact-extrapolated(L={l1},{l2}→{l_full})"
    return rec


def model_flops(arch: str, shape: str, n_devices: int) -> float:
    from repro.configs import ARCHS, SHAPES
    from repro.models import active_param_count
    cfg = ARCHS[arch]
    cell = next(s for s in SHAPES if s.name == shape)
    n = active_param_count(cfg)
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * cell.global_batch       # decode: one token per sequence


def analyse(rec: dict) -> Optional[Dict]:
    if rec.get("status") != "OK":
        return None
    chips = rec.get("n_devices", 256)
    fl_dev = rec["flops_per_device"]
    by_dev = rec["bytes_accessed_per_device"]
    coll_dev = sum(v["bytes"] for v in rec["collectives"].values())
    compute = fl_dev / PEAK_FLOPS
    memory = by_dev / HBM_BW
    collective = coll_dev / LINK_BW
    mf = model_flops(rec["arch"], rec["shape"], chips)
    ratio = mf / (fl_dev * chips) if fl_dev else 0.0
    dom = max((("compute", compute), ("memory", memory),
               ("collective", collective)), key=lambda kv: kv[1])
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "compute_s": compute, "memory_s": memory, "collective_s": collective,
        "dominant": dom[0], "dominant_s": dom[1],
        "model_flops": mf, "hlo_flops_global": fl_dev * chips,
        "useful_ratio": ratio,
        "coll_bytes_dev": coll_dev,
        "coll_detail": rec["collectives"],
        "temp_bytes_dev": rec.get("temp_size", 0),
        "arg_bytes_dev": rec.get("argument_size", 0),
        # roofline fraction: useful compute time over the bound (max of terms)
        "roofline_fraction": (mf / PEAK_FLOPS / chips) / max(compute, memory, collective)
        if max(compute, memory, collective) > 0 else 0.0,
        **({"cost_source": rec["cost_source"]} if "cost_source" in rec else {}),
    }


def table(path: str = DEFAULT_PATH, mesh: str = "16x16") -> List[Dict]:
    out = []
    for rec in load_cells(path, mesh):
        a = analyse(rec)
        if a:
            out.append(a)
    return sorted(out, key=lambda r: (r["arch"], r["shape"]))


def run(emit):
    rows = table()
    if not rows:
        emit("roofline", 0.0, "no dryrun.jsonl — run repro.launch.dryrun first")
        return
    for r in rows:
        emit(f"roofline_{r['arch']}_{r['shape']}", r["dominant_s"] * 1e6,
             f"dom={r['dominant']},compute_s={r['compute_s']:.3e},"
             f"memory_s={r['memory_s']:.3e},collective_s={r['collective_s']:.3e},"
             f"useful_ratio={r['useful_ratio']:.3f},"
             f"roofline_frac={r['roofline_fraction']:.3f}")
