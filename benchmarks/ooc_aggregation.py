"""Out-of-core keyed aggregation: wall time + peak RSS per scale tier.

The parquet-aggregator fight (ROADMAP): a single-process in-memory
keyed fold vs the streaming parallel aggregation — FastFlow's claim is
that with cheap enough hand-offs the parallel pipeline wins on *time*,
and the out-of-core layer (``repro.core.oocore``) makes it win on
*memory* too.  Per scale tier this module records both sides:

``ooc_<tier>_inmem``
    the baseline: the library's own single-process aggregation — the
    pre-oocore ``reduce_by_key`` path (unbounded in-memory ``_KeyFold``
    partitions on the threads backend), every row crossing the shuffle
    individually.  This is what a user of this library ran before
    ``oocore`` existed, so it is the comparison the subsystem claims to
    improve — not a hand-tuned raw loop (which pays no streaming
    hand-offs and answers a different question);
``ooc_<tier>_ooc``
    ``shard_reduce``: sharded combining readers → keyed shuffle in
    ``KeyBatch`` messages → budgeted ``SpillFold`` partitions, on the
    procs backend (``pool=False`` so vertex processes exit and their
    RSS is visible to ``RUSAGE_CHILDREN``).

Every measured configuration runs in its OWN subprocess: ``ru_maxrss``
is a process-lifetime high-water mark, so sharing one interpreter
across configs (or with other benchmark modules) would contaminate
every later reading with the largest earlier one.  The child prints one
JSON line; the parent emits ``us_per_row`` with the memory axis in the
derived column — the first peak-RSS numbers in ``BENCH_results.json``.

The dataset is synthetic but shaped like the real workload: a skewed
(≈80/20) key distribution over a large key space, with a per-row decode
cost (crc of a formatted id) both sides pay identically.  Deterministic
from the row index alone — every shard process regenerates its own row
ranges, no input file.

Tier knobs (set attributes before calling :func:`run`, or
``REPRO_OOC_TIERS=small,large``): ``TIERS`` picks the tiers, ``CFG``
holds per-tier row counts/key space/budget.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import zlib

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# per-tier config: rows, hot/cold key-space split, per-partition byte
# budget, and the network shape.  combine_limit is the map-side
# combiner's byte bound (per reader) — hot keys stay resident in it
# (recency order), so shuffle volume collapses to roughly the cold tail.
CFG = {
    "small": dict(nrows=20_000, hot=256, cold=20_000, budget=256 << 10,
                  combine_limit=512 << 10, nleft=2, nright=2,
                  batch_rows=4096),
    "medium": dict(nrows=200_000, hot=1024, cold=200_000, budget=512 << 10,
                   combine_limit=1 << 20, nleft=2, nright=2,
                   batch_rows=8192),
    "large": dict(nrows=1_000_000, hot=1024, cold=1_000_000, budget=1 << 20,
                  combine_limit=2 << 20, nleft=2, nright=2,
                  batch_rows=8192),
}
TIERS = tuple(t.strip() for t in os.environ.get(
    "REPRO_OOC_TIERS", "small").split(",") if t.strip())
TIMEOUT = 600.0


class SynthRows:
    """Deterministic skewed row source: ``reader(lo, hi)`` -> list of
    ``(key, value)`` rows.  ~80% of rows hit ``hot`` keys, ~20% spray
    over a ``cold`` key space; the value derives from a crc over the
    formatted row id — the per-row decode cost a real columnar scan
    pays, identical for both measured paths."""

    def __init__(self, nrows: int, hot: int, cold: int):
        self.nrows = nrows
        self.hot = hot
        self.cold = cold

    def __call__(self, lo: int, hi: int):
        crc = zlib.crc32
        hot, cold = self.hot, self.cold
        rows = []
        for i in range(lo, hi):
            h = (i * 2654435761) & 0xFFFFFFFF
            k = h % hot if h % 5 else hot + (h // 5) % cold
            rows.append((k, float(crc(b"row-%d" % i) & 0xFFFF)))
        return rows


def row_key(row):
    return row[0]


def row_stats(acc, row):
    """Seeded fold: (count, total) per key."""
    return (acc[0] + 1, acc[1] + row[1])


def merge_stats(a, b):
    return (a[0] + b[0], a[1] + b[1])


def _run_inmem(cfg: dict) -> dict:
    """Baseline: the pre-oocore library path — ``reduce_by_key`` with
    unbounded in-memory ``_KeyFold`` partitions, single process (threads
    backend), every row a streamed hand-off."""
    from repro.core import lower, reduce_by_key
    from repro.core.oocore import _entry_nbytes

    reader = SynthRows(cfg["nrows"], cfg["hot"], cfg["cold"])
    step = cfg["batch_rows"]

    def rows():
        for lo in range(0, cfg["nrows"], step):
            yield from reader(lo, min(lo + step, cfg["nrows"]))

    prog = lower(reduce_by_key(row_key, row_stats, init=(0, 0.0),
                               nleft=cfg["nleft"], nright=cfg["nright"]),
                 "threads")
    out = prog(rows())
    state = sum(_entry_nbytes(k, v) for k, v in out)
    return {"distinct_keys": len(out), "est_state_bytes": state,
            "spills": 0, "spill_bytes": 0, "stalls": 0}


def _run_ooc(cfg: dict) -> dict:
    """shard_reduce on the procs backend, budgeted right row."""
    from repro.core import lower, shard_reduce

    reader = SynthRows(cfg["nrows"], cfg["hot"], cfg["cold"])
    skel = shard_reduce(reader, row_key, row_stats, init=(0, 0.0),
                        combine=merge_stats, nleft=cfg["nleft"],
                        nright=cfg["nright"], budget=cfg["budget"],
                        batch_rows=cfg["batch_rows"],
                        combine_limit=cfg["combine_limit"])
    prog = lower(skel, "procs", pool=False)  # children must exit: their
    g = prog.to_graph(None)                  # RSS reads via RUSAGE_CHILDREN
    g.run()
    out = g.wait(TIMEOUT)
    return {"distinct_keys": len(out),
            "est_state_bytes": cfg["budget"] * cfg["nright"],
            "spills": skel.stats.spills,
            "spill_bytes": skel.stats.spill_bytes,
            "stalls": skel.stats.backpressure_stalls}


def child_main(mode: str, cfg_json: str) -> None:
    """One measured configuration, alone in this interpreter (ru_maxrss
    is a lifetime high-water mark).  Prints one JSON result line."""
    import resource
    import time

    cfg = json.loads(cfg_json)
    t0 = time.perf_counter()
    extra = _run_inmem(cfg) if mode == "inmem" else _run_ooc(cfg)
    wall = time.perf_counter() - t0
    self_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    child_kb = resource.getrusage(resource.RUSAGE_CHILDREN).ru_maxrss
    print(json.dumps(dict(extra, wall_s=wall, maxrss_kb=max(self_kb, child_kb),
                          self_kb=self_kb, child_kb=child_kb)), flush=True)


def _measure(mode: str, cfg: dict) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_ROOT, "src"), _ROOT] +
        ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    code = (f"import benchmarks.ooc_aggregation as m; "
            f"m.child_main({mode!r}, {json.dumps(cfg)!r})")
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=_ROOT,
                         capture_output=True, text=True, timeout=TIMEOUT)
    if out.returncode != 0:
        raise RuntimeError(
            f"ooc_aggregation child ({mode}) failed:\n{out.stderr[-2000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def run(emit) -> None:
    ncpu = os.cpu_count() or 1
    for tier in TIERS:
        cfg = CFG[tier]
        for mode in ("inmem", "ooc"):
            r = _measure(mode, cfg)
            emit(f"ooc_{tier}_{mode}", r["wall_s"] * 1e6 / cfg["nrows"],
                 f"maxrss_kb={r['maxrss_kb']} wall_s={r['wall_s']:.3f} "
                 f"nrows={cfg['nrows']} distinct_keys={r['distinct_keys']} "
                 f"budget_bytes={cfg['budget']}x{cfg['nright']} "
                 f"est_state_bytes={r['est_state_bytes']} "
                 f"spills={r['spills']} spill_bytes={r['spill_bytes']} "
                 f"stalls={r['stalls']} ncpu={ncpu}")


if __name__ == "__main__":
    run(lambda name, us, derived="": print(f"{name},{us:.3f},{derived}"))
