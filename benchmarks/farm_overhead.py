"""Paper Fig. 6 — farm communication overhead vs computational grain.

The paper sweeps the per-task compute time Tc (0.5 µs … 100 µs) and plots
speedup over the sequential run for FastFlow vs lock-based frameworks.  On
this 1-core container wall-clock speedup is meaningless, so we reproduce the
figure the way the paper itself *explains* it: measure the per-task farm
overhead T_over (emitter→worker→collector hand-off cost) for each queue
substrate, then derive the speedup model

    S(n) = n · Tc / (Tc + T_over)          (perfect compute overlap)

which is the asymptote the paper's curves approach.  The CSV reports
T_over per substrate and the derived S(8) at each grain — the paper's
qualitative result (lock-free keeps S≈n down to ~µs grains; lock-based
collapses below ~10 µs) falls out of the measured T_over ratio.
"""
from __future__ import annotations

import time

from repro.core import FnNode, LockQueue, SPSCQueue, TaskFarm

GRAINS_US = [0.5, 1, 5, 10, 50, 100]
NTASKS = 3_000


def _busy_wait(us: float):
    end = time.perf_counter() + us * 1e-6
    while time.perf_counter() < end:
        pass


def _farm_us_per_task(qcls, grain_us: float, nworkers: int = 2) -> float:
    farm = TaskFarm(nworkers, queue_class=qcls, capacity=256)
    farm.add_stream(range(NTASKS))
    farm.add_worker(FnNode(lambda x: (_busy_wait(grain_us), x)[1]))
    t0 = time.perf_counter()
    out = farm.run_and_wait()
    dt = time.perf_counter() - t0
    assert len(out) == NTASKS
    return dt / NTASKS * 1e6


def run(emit):
    # pure hand-off overhead at zero grain
    over = {}
    for qcls, name in [(SPSCQueue, "fastflow"), (LockQueue, "lockbased")]:
        over[name] = _farm_us_per_task(qcls, 0.0)
        emit(f"farm_overhead_{name}", over[name], "grain=0us,n=2")
    for grain in GRAINS_US:
        us_ff = _farm_us_per_task(SPSCQueue, grain)
        t_over_ff = max(us_ff - grain, 1e-3)
        t_over_lk = max(over["lockbased"], 1e-3)
        s8_ff = 8 * grain / (grain + t_over_ff)
        s8_lk = 8 * grain / (grain + t_over_lk)
        emit(f"farm_grain_{grain}us", us_ff,
             f"derived_S8_fastflow={min(s8_ff,8):.2f},derived_S8_lockbased={min(s8_lk,8):.2f}")
