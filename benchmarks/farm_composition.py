"""Composition overhead — what the graph runtime adds on top of Fig. 6.

``farm_overhead.py`` measures the hand-off cost of ONE farm; this module
measures the shapes the composition layer enables (FastFlow tutorial
TR-12-04):

  * ``pipe2farm`` — ``Pipeline(Farm(f, 2), Farm(g, 2))``: per-task cost of
    a task crossing TWO dispatch/merge arbiter pairs plus the inter-farm
    SPSC edge, vs the sequential ``g(f(x))`` baseline;
  * ``feedback``  — a wrap-around farm in which every task makes ``k`` loop
    trips (collector → emitter SPSC edge) before leaving: per-*trip* cost
    of the cyclic path, the building block of divide-and-conquer and the
    macro-data-flow executor (paper Sec. 5).

Same CSV contract as the other benchmark modules:
``name,us_per_call,derived``.
"""
from __future__ import annotations

import time

from repro.core import Farm, Pipeline

NTASKS = 2_000
LOOP_TRIPS = 4


def _f(x):
    return x + 1


def _g(x):
    return x * 2


def _pipe_of_farms_us(ntasks: int) -> float:
    net = Pipeline(Farm(_f, 2, ordered=True), Farm(_g, 2, ordered=True))
    t0 = time.perf_counter()
    out = net.run_and_wait(range(ntasks))
    dt = time.perf_counter() - t0
    assert out == [_g(_f(x)) for x in range(ntasks)]
    return dt / ntasks * 1e6


def _sequential_us(ntasks: int) -> float:
    t0 = time.perf_counter()
    out = [_g(_f(x)) for x in range(ntasks)]
    dt = time.perf_counter() - t0
    assert len(out) == ntasks
    return dt / ntasks * 1e6


def _feedback_us_per_trip(ntasks: int, trips: int) -> float:
    def route(res):
        x, depth = res
        if depth == 0:
            return x, []
        return None, [(x, depth - 1)]

    net = Farm(lambda t: t, 2, feedback=route)
    t0 = time.perf_counter()
    out = net.run_and_wait([(x, trips) for x in range(ntasks)])
    dt = time.perf_counter() - t0
    assert sorted(out) == list(range(ntasks))
    return dt / (ntasks * (trips + 1)) * 1e6


def run(emit):
    seq = _sequential_us(NTASKS)
    pipe = _pipe_of_farms_us(NTASKS)
    emit("farm_composition_pipe2farm", pipe,
         f"seq_baseline_us={seq:.3f},overhead_us={max(pipe - seq, 0):.3f}")
    trip = _feedback_us_per_trip(NTASKS // 2, LOOP_TRIPS)
    emit("farm_composition_feedback_trip", trip, f"trips={LOOP_TRIPS}")
