"""Threads-vs-procs farm speedup over grain (the GIL-escape curve).

The thread backend's farm cannot beat serial on a pure-Python (GIL-holding)
``svc``: the GIL serialises the workers, and the spinning arbiters tax the
workers' quanta on top.  The procs backend runs the same farm as worker
*processes* over shared-memory SPSC rings, so the same svc actually scales
with cores.  This module measures both backends steady-state — through
their Accelerator surfaces (caller = source+sink), with spawn/ready cost
excluded — on a 4-worker ordered farm of a calibrated pure-Python spin
kernel, across grains, ``REPEATS`` runs each, medians reported.

Rows: ``proc_farm_threads_g{G}`` / ``proc_farm_procs_g{G}`` (median
us/task) with the per-grain median speedup in the derived column, and a
``proc_farm_peak`` summary row (best median speedup over the grain sweep).

Caveat for small/oversubscribed hosts: the attainable ratio is bounded by
real core availability; on a 2-core box the curve peaks well below the
paper's 8-core numbers but must still clear 1× wherever the GIL (not the
hardware) is the binding constraint.
"""
from __future__ import annotations

import statistics
import time

from repro.core import Accelerator, Farm, ProcAccelerator

NTASKS = 1200
GRAINS_US = (100, 300, 1000)
NWORKERS = 4
REPEATS = 3


class SpinSvc:
    """Pure-Python CPU-bound svc: ~``loops`` iterations of integer math,
    GIL held throughout (no C-level release points beyond the interpreter
    loop).  A class, not a closure, so the procs backend can pickle it."""

    def __init__(self, loops: int):
        self.loops = loops

    def __call__(self, x):
        acc = x
        for _ in range(self.loops):
            acc = (acc * 1103515245 + 12345) % 2147483648
        return acc


def calibrate_loops(target_us: float) -> int:
    """Loop count for ~``target_us`` of spin on this machine, now.

    Best of three probes: a single probe can land on a scheduler stall
    (noisy/oversubscribed hosts) and inflate the unit cost by orders of
    magnitude, silently shrinking every grain in the sweep."""
    probe = SpinSvc(10_000)
    unit = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        probe(1)
        unit = min(unit, (time.perf_counter() - t0) / 10_000)
    return max(1, int(target_us / 1e6 / unit))


def _run(acc_cls, work, n: int, want) -> float:
    """One steady-state run through an accelerator (threads or procs):
    spawn + ready excluded, offload→EOS→drain timed, output checked."""
    acc = acc_cls(Farm(work, NWORKERS, ordered=True))
    t0 = time.perf_counter()
    for x in range(n):
        acc.offload(x)
    out = acc.wait(600)
    dt = time.perf_counter() - t0
    assert out == want, "farm output mismatch"
    return dt


def run(emit):
    peak = 0.0
    peak_grain = 0
    for grain in GRAINS_US:
        loops = calibrate_loops(grain)
        work = SpinSvc(loops)
        n = max(50, int(NTASKS * min(1.0, 300 / grain)))
        t0 = time.perf_counter()
        want = [work(x) for x in range(n)]  # the serial reference, timed
        serial = time.perf_counter() - t0
        ts, ps = [], []
        for _ in range(REPEATS):
            ts.append(_run(Accelerator, work, n, want))
            ps.append(_run(ProcAccelerator, work, n, want))
        tm, pm = statistics.median(ts), statistics.median(ps)
        speedup = tm / pm
        if speedup > peak:
            peak, peak_grain = speedup, grain
        emit(f"proc_farm_threads_g{grain}", tm / n * 1e6,
             f"n={n} nworkers={NWORKERS} vs_serial={serial / tm:.2f}x")
        emit(f"proc_farm_procs_g{grain}", pm / n * 1e6,
             f"procs_speedup={speedup:.2f}x vs_serial={serial / pm:.2f}x")
    emit("proc_farm_peak", 0.0,
         f"procs_speedup={peak:.2f}x_at_{peak_grain}us")
