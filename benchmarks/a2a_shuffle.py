"""Hand-off cost of the all-to-all edge matrix: threads vs procs over
nleft×nright.

The paper's per-hand-off overhead argument (Sec. 3.1, Fig. 5/6) is about
ONE ring; an all-to-all holds ``N×M`` of them, but any single item still
crosses exactly two (scatter→left, left→right), so the per-item cost
should stay nearly flat as the matrix grows — that flatness IS the
lock-free claim at network scale (a locked/arbitrated exchange degrades
with fan-in).  This module streams ``NITEMS`` ints through
``AllToAll(identity, identity, by=mod)`` at several matrix shapes and
reports µs/item for both host backends.

Procs rows use the ready-handshake (``wait_ready``) so spawn/import cost
stays out of the figure, and a smaller stream (`NITEMS_PROCS`) because a
cross-process hand-off is ~µs, not ~hundred-ns.

Rows: ``a2a_threads_{N}x{M}`` / ``a2a_procs_{N}x{M}`` (us/item, derived
column carries the stream size and edge count).
"""
from __future__ import annotations

import time

from repro.core import AllToAll, lower

NITEMS = 20_000
NITEMS_PROCS = 4_000
SHAPES = ((1, 1), (2, 2), (4, 4), (2, 4))
TIMEOUT = 300.0


def _ident(x):
    return x


def _mod(x):
    return x % 7


def _skel(nl: int, nr: int) -> AllToAll:
    return AllToAll(_ident, _ident, by=_mod, nleft=nl, nright=nr)


def _run_threads(nl: int, nr: int, n: int) -> float:
    prog = lower(_skel(nl, nr), "threads")
    xs = list(range(n))
    t0 = time.perf_counter()
    out = prog(xs)
    dt = time.perf_counter() - t0
    assert sorted(out) == xs, "a2a threads output mismatch"
    return dt


def _run_procs(nl: int, nr: int, n: int) -> float:
    prog = lower(_skel(nl, nr), "procs")
    xs = list(range(n))
    g = prog.to_graph(xs)
    g.run()
    g.wait_ready()               # exclude spawn/import from the figure
    t0 = time.perf_counter()
    out = g.wait(TIMEOUT)
    dt = time.perf_counter() - t0
    assert sorted(out) == xs, "a2a procs output mismatch"
    return dt


def run(emit):
    for nl, nr in SHAPES:
        edges = nl * nr
        dt = _run_threads(nl, nr, NITEMS)
        emit(f"a2a_threads_{nl}x{nr}", dt / NITEMS * 1e6,
             f"n={NITEMS} edges={edges}")
        dp = _run_procs(nl, nr, NITEMS_PROCS)
        emit(f"a2a_procs_{nl}x{nr}", dp / NITEMS_PROCS * 1e6,
             f"n={NITEMS_PROCS} edges={edges}")


if __name__ == "__main__":
    run(lambda name, us, derived="": print(f"{name},{us:.3f},{derived}"))
