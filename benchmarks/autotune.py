"""Autotune payoff — profile-guided re-lowering vs hand-tuned knobs.

Two scenarios, each on the threads AND procs backends (the acceptance
axes of the self-tuning ROADMAP item):

**Skewed fine-grain Farm∘Farm** — two back-to-back farms of ~µs tasks
(every ``SKEW_EVERY``-th task ``SKEW_FACTOR``× slower).  The hand knob
is the declared ``grain=``: sub-threshold declarations let the static
fusion pass merge the two farms into one (halving the arbiter crossings
per item), a mis-declaration keeps them apart.  The grid sweeps
``GRAIN_GRID`` and keeps the best; ``lower(tune=True)`` must land within
~10% of that best *without being told the grain* — the pilot measures
it.  (``ratio_vs_hand`` in the derived column; ≤ 1.10 is the target.)

**Mis-grained pipeline** — three ~sub-µs stages all declaring
``grain=10000``, the porting-study failure mode: the static lowering
trusts the declaration, never fuses, and pays two vertex hand-offs per
item.  ``tune=True`` measures the real service times, fuses the chain,
and micro-batches the survivor.  (``speedup_vs_static``; ≥ 1.3× is the
target.)

The tuned timings are steady-state: the pilot/tuning cost is paid once
on a warm-up call and the measured calls go straight to the tuned
program — the amortization story ``TunedProgram`` exists for.  Ordered
parity is asserted on every measured call, so the benchmark doubles as
a correctness smoke for the retune rewrite.

Workers are module-level functions (the procs backend pickles them to
spawned vertices).  Same CSV contract as the other benchmark modules:
``name,us_per_call,derived``.
"""
from __future__ import annotations

import time

from repro.core import Farm, Pipeline, Stage, lower
from repro.core.sched import clear_handoff_cache

NTASKS = 6000
PILOT = 400
REPEATS = 3
NWORKERS = 4
SKEW_EVERY = 8        # 8 ≡ 0 mod NWORKERS: rr pins every slow task
SKEW_FACTOR = 8       # ... and slow means 8× the base grain
FINE_US = 1.0         # farm scenario's base service time
STAGE_US = 0.5        # pipeline scenario's per-stage service time
MISGRAIN = 10000      # the hand mis-declaration (µs) both scenarios tune away
GRAIN_GRID = (None, 1, 50, MISGRAIN)
BACKENDS = ("threads", "procs")


def _spin(us: float) -> None:
    end = time.perf_counter() + us * 1e-6
    while time.perf_counter() < end:
        pass


def _farm_f(x):
    _spin(FINE_US * (SKEW_FACTOR if x % SKEW_EVERY == 0 else 1))
    return x + 1


def _farm_g(x):
    _spin(FINE_US)
    return x * 2


def _st_a(x):
    _spin(STAGE_US)
    return x + 1


def _st_b(x):
    _spin(STAGE_US)
    return x * 2


def _st_c(x):
    _spin(STAGE_US)
    return x - 3


def _timed(prog, xs, want):
    best = None
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        out = prog(xs)
        dt = time.perf_counter() - t0
        assert out == want, "ordered-output mismatch"
        best = dt if best is None else min(best, dt)
    return best


def _farm_skel(grain):
    return Pipeline(Farm(_farm_f, NWORKERS, ordered=True, grain=grain),
                    Farm(_farm_g, NWORKERS, ordered=True, grain=grain))


def run(emit):
    xs = list(range(NTASKS))
    clear_handoff_cache()  # don't inherit a threshold from another module

    # -- scenario A: skewed fine-grain Farm∘Farm -----------------------------
    want = [(_x + 1) * 2 for _x in xs]
    for b in BACKENDS:
        best_us, best_grain = None, None
        for g in GRAIN_GRID:
            prog = lower(_farm_skel(g), b)
            us = _timed(prog, xs, want) / NTASKS * 1e6
            if best_us is None or us < best_us:
                best_us, best_grain = us, g
        emit(f"farm_skew_{b}_hand_best", best_us,
             f"nworkers={NWORKERS},grain={best_grain},"
             f"grid={len(GRAIN_GRID)}")
        tp = lower(_farm_skel(MISGRAIN), b, tune=True, tune_pilot=PILOT)
        assert tp(xs) == want      # warm-up: pays the pilot + re-lower once
        us_t = _timed(tp, xs, want) / NTASKS * 1e6
        emit(f"farm_skew_{b}_tuned", us_t,
             f"pilot={PILOT},ratio_vs_hand={us_t / best_us:.3f}")

    # -- scenario B: mis-grained pipeline ------------------------------------
    skel = Pipeline(Stage(_st_a, grain=MISGRAIN), Stage(_st_b, grain=MISGRAIN),
                    Stage(_st_c, grain=MISGRAIN))
    want = [_st_c(_st_b(_st_a(_x))) for _x in xs]
    for b in BACKENDS:
        static = lower(skel, b)    # trusts the declared (wrong) grain
        us_s = _timed(static, xs, want) / NTASKS * 1e6
        emit(f"pipe_misgrain_{b}_static", us_s, f"declared_grain={MISGRAIN}")
        tp = lower(skel, b, tune=True, tune_pilot=PILOT)
        assert tp(xs) == want      # warm-up
        us_t = _timed(tp, xs, want) / NTASKS * 1e6
        emit(f"pipe_misgrain_{b}_tuned", us_t,
             f"pilot={PILOT},speedup_vs_static={us_s / us_t:.2f}")
